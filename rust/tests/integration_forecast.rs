//! Forecast subsystem integration contract (DESIGN.md §11).
//!
//! Three guarantees are pinned here:
//!
//! * **Thread invariance** — forecaster updates are a pure fold over
//!   Observer-visible state and draw no RNG, so the `forecast_grid`
//!   preset collates to the bit-identical digest at 1 and 8 threads,
//!   and under the batched harness (which routes portfolio points
//!   through the scalar fallback and `lookahead_bid` through the
//!   batched lanes).
//! * **The behavioral headline** — on the shipped regime-switching
//!   showdown, the proactive migrator suffers strictly fewer
//!   market-level interruptions than the reactive §10 rule: it reads
//!   the volatile entry's forecast interruption rate and stays off it,
//!   while the reactive rule chases the low sticker price.
//! * **Planner support** — forecast-driven kinds are heuristic
//!   candidates: never analytically pruned, never given a closed-form
//!   surface.

use volatile_sgd::exp::{presets, SpecScenario};
use volatile_sgd::opt::{self, PlannerConfig};
use volatile_sgd::sweep::{
    run_sweep, run_sweep_batched, SweepConfig, SweepResults,
};

/// Shrink the preset for test speed without touching the forecast
/// semantics under test: fewer replicates come from `SweepConfig`;
/// the axis already has two values and the portfolio entries must not
/// be reduced (the showdown *is* the 3-entry lineup).
fn forecast_scenario() -> SpecScenario {
    let spec = presets::spec("forecast_grid").unwrap();
    SpecScenario::new(spec).unwrap()
}

fn sweep(sc: &SpecScenario, threads: usize) -> SweepResults {
    run_sweep(sc, &SweepConfig { replicates: 2, seed: 7, threads })
        .unwrap()
}

#[test]
fn forecast_grid_digest_is_thread_invariant() {
    let sc = forecast_scenario();
    assert_eq!(
        sweep(&sc, 1).digest(),
        sweep(&sc, 8).digest(),
        "forecast_grid: digest is thread-dependent — a forecaster \
         update consumed RNG or broke the per-market stream contract"
    );
}

#[test]
fn forecast_grid_batched_matches_scalar() {
    let sc = forecast_scenario();
    for threads in [1, 8] {
        let cfg = SweepConfig { replicates: 2, seed: 7, threads };
        let scalar = run_sweep(&sc, &cfg).unwrap();
        let batched = run_sweep_batched(&sc, &cfg).unwrap();
        assert_eq!(
            scalar.digest(),
            batched.digest(),
            "forecast_grid: batched digest diverges from the scalar \
             oracle at {threads} threads"
        );
    }
}

/// The pinned headline: summed over the grid, `proactive` sees
/// strictly fewer `preempt_events` than the reactive `migrate` rule.
/// The volatile entry is priced to be the reactive rule's favourite
/// (lowest price/speed), while its interruption rate q in {0.4, 0.55}
/// makes the forecast score (1-q̂)·speed / (E[1/y]·level) keep the
/// proactive fleet on the calm c5 fixture.
#[test]
fn proactive_suffers_fewer_preemptions_than_reactive_migrate() {
    let sc = forecast_scenario();
    let results = sweep(&sc, 2);
    let pe = results
        .metric_names
        .iter()
        .position(|m| m == "preempt_events")
        .expect("forecast_grid must record preempt_events");
    let sum_for = |suffix: &str| -> f64 {
        let pts: Vec<&_> = results
            .points
            .iter()
            .filter(|p| p.label.ends_with(suffix))
            .collect();
        assert_eq!(pts.len(), 2, "expected one {suffix} point per q");
        pts.iter().map(|p| p.stats[pe].mean()).sum()
    };
    let reactive = sum_for("/migrate");
    let proactive = sum_for("/proactive");
    assert!(
        reactive > 0.0,
        "the reactive rule never got interrupted — the showdown is \
         not exercising the volatile market"
    );
    assert!(
        proactive < reactive,
        "proactive must suffer strictly fewer preemptions than the \
         reactive rule, got {proactive} vs {reactive}"
    );
}

/// Forecast-driven candidates ride the planner's heuristic path: no
/// analytic pruning, no closed-form surface — every lattice point
/// reaches the simulation ladder.
#[test]
fn planner_simulates_forecast_candidates_without_pruning() {
    let plan_text = r#"
name = "forecast_plan"
seed = 7
strategies = ["one_bid", "proactive"]
axes = ["h"]

[objective]
goal = "min_cost"

[search]
ladder = [2]

[job]
n = 4
eps = 0.35
j = 400

[runtime]
kind = "exp"
lambda = 0.25
delta = 0.5

[overhead]
checkpoint_cost_s = 2.0
restart_delay_s = 6.0

[[portfolio]]
label = "calm"
kind = "uniform"
lo = 0.2
hi = 1.0
q = 0.02

[[portfolio]]
label = "volatile"
kind = "uniform"
lo = 0.1
hi = 0.6
speed = 1.4
q = 0.3

[strategy.proactive]
kind = "proactive_migrate"
window = 32
horizon_s = 300.0

[axis.h]
path = "strategy.proactive.hysteresis"
values = [0.0, 0.1]
"#;
    let plan = opt::PlanSpec::from_str(plan_text).unwrap();
    let outcome =
        opt::run_plan(&plan, &PlannerConfig { seed: 7, threads: 2 })
            .unwrap();
    let counts = outcome.counts();
    assert_eq!(
        counts.infeasible + counts.dominated,
        0,
        "forecast candidates must never be analytically pruned"
    );
    assert!(counts.evaluated >= 2, "lattice must reach simulation");
    assert!(outcome.incumbent.is_some());
    for c in &outcome.candidates {
        assert!(
            c.surface.is_none(),
            "{}: forecast candidates have no closed-form surface",
            c.label
        );
    }
}
