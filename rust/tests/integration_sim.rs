//! Cross-module integration: Monte-Carlo simulation vs the closed-form
//! theory (Lemmas 1–2, Theorem 2/3 predictions), and end-to-end strategy
//! orderings on the virtual-clock scheduler.

use volatile_sgd::coordinator::backend::SyntheticBackend;
use volatile_sgd::coordinator::scheduler::{Scheduler, SchedulerParams};
use volatile_sgd::coordinator::strategy::{FixedBids, StaticWorkers};
use volatile_sgd::market::{BidVector, PriceModel};
use volatile_sgd::preempt::PreemptionModel;
use volatile_sgd::sim::PriceSource;
use volatile_sgd::theory::bids::BidProblem;
use volatile_sgd::theory::bounds::{ErrorBound, SgdHyper};
use volatile_sgd::theory::runtime_model::RuntimeModel;
use volatile_sgd::util::rng::Rng;
use volatile_sgd::util::stats::OnlineStats;

fn bound() -> ErrorBound {
    ErrorBound::new(SgdHyper::paper_cnn())
}

fn problem(theta: f64) -> BidProblem {
    BidProblem {
        bound: bound(),
        price: PriceModel::uniform_paper(),
        runtime: RuntimeModel::Deterministic { r: 10.0 },
        n: 8,
        eps: 0.35,
        theta,
    }
}

/// Run one uniform-bid simulation and return (cost, elapsed).
fn run_uniform(b: f64, j: u64, seed: u64) -> (f64, f64) {
    let mut s = FixedBids::new("mc", BidVector::uniform(8, b), j);
    let mut backend = SyntheticBackend::new(bound());
    let mut rng = Rng::new(seed);
    let params = SchedulerParams {
        runtime: RuntimeModel::Deterministic { r: 10.0 },
        idle_step: 10.0, // slot length == iteration length: the i.i.d.
        // price-per-slot model of Lemma 1
        theta_cap: f64::INFINITY,
        stride: 1_000,
        max_slots: 100_000_000,
        ..Default::default()
    };
    let r = Scheduler::new(params)
        .run(
            &mut s,
            &mut backend,
            &PriceSource::Iid(PriceModel::uniform_paper()),
            &mut rng,
        )
        .unwrap();
    (r.cost, r.elapsed)
}

#[test]
fn monte_carlo_matches_lemma1_and_lemma2() {
    // Lemma 1: E[tau] = J E[R] / F(b); Lemma 2: E[C] closed form.
    // With idle_step == iteration runtime, the discrete-slot simulation
    // is exactly the paper's geometric-waiting model.
    let pb = problem(f64::INFINITY);
    let j = 2_000u64;
    for &b in &[0.4, 0.6, 0.9] {
        let mut cost = OnlineStats::new();
        let mut time = OnlineStats::new();
        for seed in 0..30 {
            let (c, t) = run_uniform(b, j, seed);
            cost.push(c);
            time.push(t);
        }
        let want_t = pb.expected_time_uniform(j, b);
        let want_c = pb.expected_cost_uniform(j, b);
        assert!(
            (time.mean() - want_t).abs() < 0.03 * want_t,
            "b={b}: E[tau] mc={} formula={}",
            time.mean(),
            want_t
        );
        assert!(
            (cost.mean() - want_c).abs() < 0.03 * want_c,
            "b={b}: E[C] mc={} formula={}",
            cost.mean(),
            want_c
        );
    }
}

#[test]
fn monte_carlo_two_bid_recip_matches_formula() {
    // E[1/y | y>0] under two bids == the Theorem-3 expression
    let pb = problem(f64::INFINITY);
    let (b1, b2, n1) = (0.8, 0.4, 4usize);
    let bids = BidVector::two_group(8, n1, b1, b2);
    let mut rng = Rng::new(5);
    let price = PriceModel::uniform_paper();
    let mut sum = 0.0;
    let mut cnt = 0u64;
    use volatile_sgd::market::process::PriceDist;
    for _ in 0..200_000 {
        let p = price.sample(&mut rng);
        let y = bids.active_count(p);
        if y > 0 {
            sum += 1.0 / y as f64;
            cnt += 1;
        }
    }
    let mc = sum / cnt as f64;
    let want = pb.expected_recip_two(n1, b1, b2);
    assert!((mc - want).abs() < 2e-3, "mc={mc} want={want}");
}

#[test]
fn theorem2_bid_is_cheapest_feasible_in_simulation() {
    // simulate the Theorem-2 bid against over- and under-bidding
    let pb = problem(300_000.0);
    let plan = pb.optimal_one_bid().unwrap();
    let avg = |b: f64| -> (f64, f64) {
        let mut c = OnlineStats::new();
        let mut t = OnlineStats::new();
        for seed in 100..120 {
            let (cc, tt) = run_uniform(b, plan.j, seed);
            c.push(cc);
            t.push(tt);
        }
        (c.mean(), t.mean())
    };
    let (c_star, t_star) = avg(plan.b);
    // meets the deadline on average
    assert!(t_star <= pb.theta * 1.03, "t={t_star} theta={}", pb.theta);
    // higher bid: faster but costlier
    let (c_hi, t_hi) = avg((plan.b + 0.15).min(1.0));
    assert!(t_hi <= t_star * 1.01);
    assert!(c_hi >= c_star * 0.99, "c_hi={c_hi} c*={c_star}");
    // lower bid: cheaper but blows the deadline
    let (c_lo, t_lo) = avg(plan.b - 0.1);
    assert!(c_lo <= c_star * 1.01);
    assert!(t_lo > pb.theta, "lower bid should miss the deadline");
}

#[test]
fn preemption_error_worse_than_on_demand_at_same_mean_workers() {
    // Remark 1/2 end-to-end: Bernoulli preemption with E[y] = 4 gives
    // worse final error than 4 dedicated workers for the same J.
    let j = 5_000u64;
    let run = |model: PreemptionModel, n: usize, seed: u64| -> f64 {
        let mut s = StaticWorkers {
            label: "static_n".to_string(),
            n,
            j,
            model,
            unit_price: 0.1,
        };
        let mut backend = SyntheticBackend::new(bound());
        let mut rng = Rng::new(seed);
        let r = Scheduler::new(SchedulerParams {
            runtime: RuntimeModel::Deterministic { r: 10.0 },
            ..Default::default()
        })
        .run(&mut s, &mut backend, &PriceSource::Fixed(0.1), &mut rng)
        .unwrap();
        r.final_error
    };
    let mut preempted = OnlineStats::new();
    for seed in 0..10 {
        preempted.push(run(
            PreemptionModel::Bernoulli { q: 0.5 },
            8,
            seed,
        ));
    }
    let dedicated = run(PreemptionModel::None, 4, 999);
    assert!(
        preempted.mean() > dedicated,
        "preempted {} should exceed dedicated {}",
        preempted.mean(),
        dedicated
    );
}

#[test]
fn trace_replay_is_deterministic_given_seed() {
    use volatile_sgd::exp::fig4;
    let trace = fig4::default_trace(3);
    let p = fig4::Fig4Params::default();
    let a = fig4::run(&trace, &p).unwrap();
    let b = fig4::run(&trace, &p).unwrap();
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.total_cost, y.total_cost);
        assert_eq!(x.total_time, y.total_time);
    }
}

#[test]
fn checkpoint_restore_resumes_identically() {
    use volatile_sgd::coordinator::ParameterServer;
    // the same gradient stream applied after a restore gives the same theta
    let mut ps = ParameterServer::new(vec![0.5f32; 64], 0.1);
    let mut rng = Rng::new(11);
    let mut grads = Vec::new();
    for _ in 0..10 {
        let g: Vec<f32> =
            (0..64).map(|_| rng.gaussian() as f32).collect();
        grads.push(g);
    }
    for g in &grads[..5] {
        ps.begin_iteration();
        ps.push_gradient(g);
        ps.finish_iteration();
    }
    let ck = ps.checkpoint();
    let replay = |start: &volatile_sgd::coordinator::server::Checkpoint| {
        let mut ps2 = ParameterServer::new(vec![0.0; 64], 0.1);
        ps2.restore(start);
        for g in &grads[5..] {
            ps2.begin_iteration();
            ps2.push_gradient(g);
            ps2.finish_iteration();
        }
        ps2.theta().to_vec()
    };
    let a = replay(&ck);
    let b = replay(&ck);
    assert_eq!(a, b);
    // and matches continuing without the restore
    for g in &grads[5..] {
        ps.begin_iteration();
        ps.push_gradient(g);
        ps.finish_iteration();
    }
    assert_eq!(ps.theta(), a.as_slice());
}
