//! CLI smoke tests: drive the leader binary end to end.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_volatile-sgd"))
}

fn run_ok(args: &[&str]) -> String {
    let out = bin()
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn volatile-sgd");
    assert!(
        out.status.success(),
        "{args:?} failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).to_string()
}

#[test]
fn help_lists_subcommands() {
    let out = run_ok(&["help"]);
    for cmd in ["train", "simulate", "optimal-bid", "plan-workers"] {
        assert!(out.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn unknown_subcommand_fails() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown"));
}

#[test]
fn optimal_bid_prints_theorems() {
    let out = run_ok(&[
        "optimal-bid",
        "--market",
        "uniform",
        "--n",
        "8",
        "--n1",
        "4",
        "--eps",
        "0.35",
        "--theta",
        "150000",
    ]);
    assert!(out.contains("Theorem 2"), "missing Theorem 2 line:\n{out}");
    assert!(out.contains("Theorem 3"), "missing Theorem 3 line:\n{out}");
    assert!(out.contains("saving"), "missing saving line:\n{out}");
}

#[test]
fn plan_workers_prints_both_theorems() {
    let out = run_ok(&["plan-workers", "--eps", "0.1"]);
    assert!(out.contains("Theorem 4"));
    assert!(out.contains("Theorem 5"));
}

#[test]
fn simulate_one_bid_writes_series() {
    let out = run_ok(&["simulate", "--strategy", "one_bid"]);
    assert!(out.contains("one_bid"), "{out}");
    assert!(out.contains("series ->"));
    let csv = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("out/simulate_one_bid.csv");
    assert!(csv.exists());
    let text = std::fs::read_to_string(csv).unwrap();
    assert!(text.starts_with("clock,iter,cost,error,accuracy,active"));
    assert!(text.lines().count() > 10);
}

/// The event-native policies run from the `simulate` surface with
/// their dedicated flags (DESIGN.md §6).
#[test]
fn simulate_event_native_policies_run() {
    let out = run_ok(&[
        "simulate",
        "--strategy",
        "elastic_fleet",
        "--budget-rate",
        "2.5",
    ]);
    assert!(out.contains("elastic_fleet"), "{out}");
    assert!(out.contains("budget"), "{out}");
    assert!(out.contains("series ->"), "{out}");

    let out = run_ok(&[
        "simulate",
        "--strategy",
        "notice_rebid",
        "--rebid-factor",
        "2.0",
        "--checkpoint-every",
        "25",
        "--checkpoint-cost",
        "5",
        "--lost-work",
    ]);
    assert!(out.contains("notice_rebid"), "{out}");
    assert!(out.contains("rebid x2"), "{out}");
    assert!(out.contains("overhead:"), "{out}");

    // knob misuse is a clean error, not a panic
    let out = bin()
        .args(["simulate", "--strategy", "one_bid", "--budget-rate", "1.0"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr)
        .contains("only applies to elastic_fleet"));
    // non-finite knob values are clean errors too (f64 parses "inf")
    let out = bin()
        .args([
            "simulate",
            "--strategy",
            "elastic_fleet",
            "--budget-rate",
            "inf",
        ])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr)
        .contains("must be finite"));
}

#[test]
fn sweep_preset_equals_legacy_fig_flag_and_is_thread_deterministic() {
    // figure-default J keeps the Theorem 2/3 plans feasible (theta
    // scales with J); 2 replicates keeps the smoke test quick. One pair
    // of runs pins BOTH contracts: `--fig 3` (the pre-redesign surface)
    // and `--preset fig3` (the spec path) print identical digests, at
    // different thread counts.
    let a = run_ok(&[
        "sweep", "--fig", "3", "--replicates", "2", "--seed", "77",
        "--threads", "1",
    ]);
    let b = run_ok(&[
        "sweep", "--preset", "fig3", "--replicates", "2", "--seed", "77",
        "--threads", "4",
    ]);
    let digest = |out: &str| {
        out.lines()
            .find(|l| l.contains("digest:"))
            .map(str::trim)
            .map(str::to_string)
            .expect("digest line")
    };
    assert_eq!(
        digest(&a),
        digest(&b),
        "--preset fig3 must reproduce --fig 3 bit-for-bit"
    );
    assert!(a.contains("jobs/s"), "throughput line missing:\n{a}");
    let csv = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("out/sweep_fig3.csv");
    assert!(csv.exists());
}

#[test]
fn sweep_spec_file_with_machine_readable_output() {
    let out = run_ok(&[
        "sweep",
        "--spec",
        "../examples/configs/preempt_grid.toml",
        "--replicates",
        "1",
        "--j",
        "500",
        "--threads",
        "2",
        "--out",
        "out/spec_smoke.csv",
        "--json",
    ]);
    assert!(out.contains("sweep preempt_grid"), "{out}");
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let csv =
        std::fs::read_to_string(root.join("out/spec_smoke.csv")).unwrap();
    let header = csv.lines().next().unwrap();
    assert!(header.starts_with("label,"), "{header}");
    assert!(header.contains("cost_mean") && header.contains("cost_missing"));
    assert!(csv.contains("n=2 q=0.1/static,"), "{csv}");
    let json = std::fs::read_to_string(
        root.join("out/sweep_preempt_grid.json"),
    )
    .unwrap();
    assert!(json.contains("\"scenario\": \"preempt_grid\""));
    assert!(json.contains("\"points\""));
}

#[test]
fn sweep_check_validates_without_running() {
    let out = run_ok(&["sweep", "--preset", "fig5", "--check"]);
    // the auditable one-line summary: spec count + resolved grid points
    assert!(
        out.contains("check OK: 1 spec validated, 12 grid points resolved"),
        "{out}"
    );
    assert!(out.contains("fig5:"), "{out}");
    assert!(!out.contains("digest"), "--check must not run the sweep");
    // a broken spec fails loudly, naming the problem
    let bad = bin()
        .args(["sweep", "--preset", "nope"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .unwrap();
    assert!(!bad.status.success());
    assert!(String::from_utf8_lossy(&bad.stderr).contains("unknown preset"));
}

#[test]
fn help_mentions_sweep() {
    let out = run_ok(&["help"]);
    assert!(out.contains("sweep"), "help missing sweep:\n{out}");
    assert!(out.contains("optimize"), "help missing optimize:\n{out}");
}

#[test]
fn help_mentions_the_service_subcommands() {
    let out = run_ok(&["help"]);
    for cmd in ["serve", "submit", "status", "stats", "shutdown"] {
        assert!(out.contains(cmd), "help missing {cmd}:\n{out}");
    }
}

/// `serve --check` validates the listener address and every shipped
/// preset without binding a socket or running a replicate.
#[test]
fn serve_check_validates_listener_and_presets() {
    let out =
        run_ok(&["serve", "--listen", "127.0.0.1:2020", "--check"]);
    assert!(out.contains("check OK:"), "{out}");
    assert!(out.contains("7 sweep presets"), "{out}");
    assert!(out.contains("1 planner preset"), "{out}");
    assert!(!out.contains("listening"), "--check must not bind");
    // a garbage listen address is a clean error, not a bind attempt
    let bad = bin()
        .args(["serve", "--listen", "not an address", "--check"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .unwrap();
    assert!(!bad.status.success());
    assert!(
        String::from_utf8_lossy(&bad.stderr).contains("listen address"),
        "{}",
        String::from_utf8_lossy(&bad.stderr)
    );
}

#[test]
fn optimize_check_validates_the_shipped_preset() {
    // --spec omitted: the embedded optimize_deadline preset
    let out = run_ok(&["optimize", "--check"]);
    assert!(
        out.contains(
            "check OK: 1 plan spec validated, 36 lattice points resolved"
        ),
        "{out}"
    );
    assert!(out.contains("optimize_deadline:"), "{out}");
    assert!(!out.contains("digest"), "--check must not run the planner");
    // the explicit --spec path validates the same file
    let out = run_ok(&[
        "optimize",
        "--spec",
        "../examples/configs/optimize_deadline.toml",
        "--check",
    ]);
    assert!(out.contains("36 lattice points resolved"), "{out}");
    // a sweep-only spec (no [objective]) fails loudly
    let bad = bin()
        .args(["optimize", "--spec", "../examples/configs/fig5.toml"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .unwrap();
    assert!(!bad.status.success());
    assert!(
        String::from_utf8_lossy(&bad.stderr).contains("[objective]"),
        "{}",
        String::from_utf8_lossy(&bad.stderr)
    );
}

#[test]
fn optimize_writes_csv_and_json_outputs() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let dir = root.join("out/opt_cli_smoke");
    let _ = std::fs::remove_dir_all(&dir);
    let out = run_ok(&[
        "optimize",
        "--seed",
        "7",
        "--threads",
        "2",
        "--out",
        "out/opt_cli_smoke",
        "--json",
    ]);
    assert!(out.contains("== optimize optimize_deadline"), "{out}");
    assert!(out.contains("incumbent:"), "{out}");
    assert!(out.contains("pareto frontier"), "{out}");
    assert!(out.contains("digest:"), "{out}");
    let csv = std::fs::read_to_string(
        dir.join("optimize_optimize_deadline.csv"),
    )
    .unwrap();
    assert!(csv.starts_with("rank,label,strategy,fate"), "{csv}");
    assert!(csv.lines().count() > 36, "every lattice point reported");
    let json = std::fs::read_to_string(
        dir.join("optimize_optimize_deadline.json"),
    )
    .unwrap();
    assert!(json.contains("\"planner\": \"optimize_deadline\""));
    assert!(json.contains("\"frontier\""));
    assert!(json.contains("\"rungs\""));
}

#[test]
fn info_requires_or_reads_artifacts() {
    let have = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.txt")
        .exists();
    if have {
        let out = run_ok(&["info"]);
        assert!(out.contains("model cnn"));
        assert!(out.contains("PJRT platform"));
    } else {
        let out = bin()
            .arg("info")
            .current_dir(env!("CARGO_MANIFEST_DIR"))
            .output()
            .unwrap();
        assert!(!out.status.success());
    }
}
