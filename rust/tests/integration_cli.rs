//! CLI smoke tests: drive the leader binary end to end.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_volatile-sgd"))
}

fn run_ok(args: &[&str]) -> String {
    let out = bin()
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn volatile-sgd");
    assert!(
        out.status.success(),
        "{args:?} failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).to_string()
}

#[test]
fn help_lists_subcommands() {
    let out = run_ok(&["help"]);
    for cmd in ["train", "simulate", "optimal-bid", "plan-workers"] {
        assert!(out.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn unknown_subcommand_fails() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown"));
}

#[test]
fn optimal_bid_prints_theorems() {
    let out = run_ok(&[
        "optimal-bid",
        "--market",
        "uniform",
        "--n",
        "8",
        "--n1",
        "4",
        "--eps",
        "0.35",
        "--theta",
        "150000",
    ]);
    assert!(out.contains("Theorem 2"), "missing Theorem 2 line:\n{out}");
    assert!(out.contains("Theorem 3"), "missing Theorem 3 line:\n{out}");
    assert!(out.contains("saving"), "missing saving line:\n{out}");
}

#[test]
fn plan_workers_prints_both_theorems() {
    let out = run_ok(&["plan-workers", "--eps", "0.1"]);
    assert!(out.contains("Theorem 4"));
    assert!(out.contains("Theorem 5"));
}

#[test]
fn simulate_one_bid_writes_series() {
    let out = run_ok(&["simulate", "--strategy", "one_bid"]);
    assert!(out.contains("one_bid"), "{out}");
    assert!(out.contains("series ->"));
    let csv = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("out/simulate_one_bid.csv");
    assert!(csv.exists());
    let text = std::fs::read_to_string(csv).unwrap();
    assert!(text.starts_with("clock,iter,cost,error,accuracy,active"));
    assert!(text.lines().count() > 10);
}

#[test]
fn sweep_subcommand_is_deterministic_across_threads() {
    // figure-default J keeps the Theorem 2/3 plans feasible (theta
    // scales with J); 2 replicates keeps the smoke test quick
    let run_sweep = |threads: &str| {
        run_ok(&[
            "sweep", "--fig", "3", "--replicates", "2", "--seed", "77",
            "--threads", threads,
        ])
    };
    let a = run_sweep("1");
    let b = run_sweep("4");
    let digest = |out: &str| {
        out.lines()
            .find(|l| l.contains("digest:"))
            .map(str::trim)
            .map(str::to_string)
            .expect("digest line")
    };
    assert_eq!(digest(&a), digest(&b), "sweep digest differs by threads");
    assert!(a.contains("jobs/s"), "throughput line missing:\n{a}");
    let csv = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("out/sweep_fig3.csv");
    assert!(csv.exists());
}

#[test]
fn help_mentions_sweep() {
    let out = run_ok(&["help"]);
    assert!(out.contains("sweep"), "help missing sweep:\n{out}");
}

#[test]
fn info_requires_or_reads_artifacts() {
    let have = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.txt")
        .exists();
    if have {
        let out = run_ok(&["info"]);
        assert!(out.contains("model cnn"));
        assert!(out.contains("PJRT platform"));
    } else {
        let out = bin()
            .arg("info")
            .current_dir(env!("CARGO_MANIFEST_DIR"))
            .output()
            .unwrap();
        assert!(!out.status.success());
    }
}
