//! Sweep-harness integration: the determinism contract end to end,
//! driven through the declarative scenario specs (the presets are
//! ordinary `examples/configs/*.toml` files).
//!
//! The tentpole guarantee is that `--threads` is a pure throughput knob:
//! for a fixed seed, a sweep's collated results (Welford statistics,
//! CSV table, digest) are bit-identical at any thread count, because
//! every (grid-point, replicate) job derives its RNG from
//! `Rng::stream(seed, job)` and collation folds outputs in job order.

use volatile_sgd::exp::presets;
use volatile_sgd::exp::SpecScenario;
use volatile_sgd::sweep::{run_sweep, SweepConfig};

/// A small Fig. 3 grid: one distribution x four strategies. Default J
/// keeps the Theorem 2/3 plans feasible (their deadlines scale with it).
fn small_fig3() -> SpecScenario {
    let mut spec = presets::spec("fig3").unwrap();
    spec.markets.truncate(1); // uniform only
    SpecScenario::new(spec).unwrap()
}

#[test]
fn fig3_sweep_identical_at_threads_1_and_8() {
    let sweep = small_fig3();
    let base = SweepConfig { replicates: 3, seed: 2020, threads: 1 };
    let serial = run_sweep(&sweep, &base).unwrap();
    let par = run_sweep(
        &sweep,
        &SweepConfig { threads: 8, ..base },
    )
    .unwrap();

    // the digest pins every count, mean, variance, min and max bit
    assert_eq!(serial.digest(), par.digest());
    // and the exported table is textually identical
    assert_eq!(serial.to_table().to_csv(), par.to_table().to_csv());
    assert_eq!(
        serial.to_labeled_table().to_csv(),
        par.to_labeled_table().to_csv()
    );
    // sanity: the sweep actually covered the grid
    assert_eq!(serial.points.len(), 4);
    assert_eq!(serial.throughput.jobs, 12);
    for p in &serial.points {
        // every replicate reported total_cost (metric 2) as finite
        assert_eq!(p.stats[2].count(), 3, "{}", p.label);
    }
}

#[test]
fn fig3_sweep_reruns_reproduce_exactly() {
    let sweep = small_fig3();
    let cfg = SweepConfig { replicates: 2, seed: 7, threads: 4 };
    let a = run_sweep(&sweep, &cfg).unwrap();
    let b = run_sweep(&sweep, &cfg).unwrap();
    assert_eq!(a.digest(), b.digest());
    // a different seed must change the statistics
    let c = run_sweep(
        &sweep,
        &SweepConfig { seed: 8, ..cfg },
    )
    .unwrap();
    assert_ne!(a.digest(), c.digest());
}

#[test]
fn fig5_grid_sweep_deterministic_and_cached_stats_exact() {
    use volatile_sgd::preempt::{PreemptionModel, RecipTable};

    let mut spec = presets::spec("fig5").unwrap();
    spec.job.j = 1_000;
    let sweep = SpecScenario::new(spec).unwrap();
    let base = SweepConfig { replicates: 4, seed: 11, threads: 1 };
    let serial = run_sweep(&sweep, &base).unwrap();
    let par = run_sweep(
        &sweep,
        &SweepConfig { threads: 8, ..base },
    )
    .unwrap();
    assert_eq!(serial.digest(), par.digest());
    assert_eq!(serial.points.len(), 12); // 4 n x 3 q

    // the cached recip_exact metric (index 4) equals the direct exact
    // computation for its grid point, with zero variance across
    // replicates (it is a per-point constant)
    for (idx, p) in serial.points.iter().enumerate() {
        let vals = sweep.grid().point(idx);
        let (n, q) = (vals[0] as usize, vals[1]);
        let want = RecipTable::build(
            &PreemptionModel::Bernoulli { q },
            n,
        )
        .recip(n);
        let recip = &p.stats[4];
        assert_eq!(recip.count(), 4);
        assert!(
            (recip.mean() - want).abs() < 1e-15,
            "{}: {} vs {want}",
            p.label,
            recip.mean()
        );
        assert_eq!(recip.variance(), 0.0, "{}", p.label);
    }
}

#[test]
fn thread_count_does_not_leak_into_labels_or_metrics() {
    // with the market lineup truncated to one entry, the singleton
    // market part drops out of labels (the full 2-market preset keeps
    // "uniform/...", pinned in the presets unit tests)
    let sweep = small_fig3();
    let cfg = SweepConfig { replicates: 1, seed: 1, threads: 6 };
    let out = run_sweep(&sweep, &cfg).unwrap();
    let labels: Vec<String> =
        out.points.iter().map(|p| p.label.clone()).collect();
    assert_eq!(
        labels,
        vec!["no_interruptions", "one_bid", "two_bids", "dynamic"]
    );
    assert_eq!(out.metric_names[0], "cost_at_target");
}
