//! Batched-vs-scalar determinism contract (DESIGN.md §8): for every
//! shipped preset — frictionless, overhead-enabled, and event-native
//! alike — `run_sweep_batched` must collate to the *bit-identical*
//! digest `run_sweep` produces, at 1 and 8 threads. The scalar path is
//! the oracle; digests are never re-pinned to the batched executor.
//! Lane edge cases (one replicate, replicate counts that don't divide
//! evenly, lineup mode) ride along.

use volatile_sgd::exp::spec::MarketKind;
use volatile_sgd::exp::{presets, ScenarioSpec, SpecScenario};
use volatile_sgd::sweep::{run_sweep, run_sweep_batched, SweepConfig};

/// A shipped preset reduced for test speed: first market only, at most
/// two values per axis, iteration budget capped where that cannot
/// change plan feasibility (fixed-price markets have no Theorem-2/3
/// deadline coupling). Reductions shrink the point space without
/// changing what any single replicate does, and both executors see the
/// identical spec.
fn reduced(name: &str, j_cap: u64) -> SpecScenario {
    let mut spec = presets::spec(name).unwrap();
    // `.all()` on an empty lineup is vacuously true, and portfolio
    // specs keep `markets` empty — spell the guard out so their
    // bid-coupled entries are never j-capped either
    if !spec.markets.is_empty()
        && spec
            .markets
            .iter()
            .all(|m| matches!(m.kind, MarketKind::Fixed { .. }))
    {
        spec.job.j = spec.job.j.min(j_cap);
    }
    if spec.markets.len() > 1 {
        spec.markets.truncate(1);
    }
    for ax in &mut spec.axes {
        if ax.values.len() > 2 {
            ax.values.truncate(2);
        }
    }
    SpecScenario::new(spec)
        .unwrap_or_else(|e| panic!("reduced {name}: {e:#}"))
}

fn assert_batched_equals_scalar(
    name: &str,
    sc: &SpecScenario,
    cfg: &SweepConfig,
) {
    let scalar = run_sweep(sc, cfg).unwrap();
    let batched = run_sweep_batched(sc, cfg).unwrap();
    assert_eq!(
        scalar.digest(),
        batched.digest(),
        "{name}: batched digest diverges from the scalar oracle \
         (replicates={}, threads={})",
        cfg.replicates,
        cfg.threads
    );
    // digests hash labels + collated stats; pin throughput bookkeeping
    // separately since it is deliberately excluded from the hash
    assert_eq!(scalar.throughput.jobs, batched.throughput.jobs);
}

#[test]
fn every_preset_batched_digest_matches_scalar_at_1_and_8_threads() {
    for name in presets::PRESET_NAMES {
        let sc = reduced(name, 600);
        let base = SweepConfig { replicates: 3, seed: 2020, threads: 1 };
        assert_batched_equals_scalar(name, &sc, &base);
        assert_batched_equals_scalar(
            name,
            &sc,
            &SweepConfig { threads: 8, ..base },
        );
    }
}

/// Replicate-count edge cases on a frictionless per-strategy preset
/// (fast path) and the overhead preset (scalar-fallback path): a single
/// lane, and a count chosen not to divide any plausible lane width.
#[test]
fn lane_count_edge_cases() {
    for name in ["fig3", "checkpoint_grid"] {
        let sc = reduced(name, 400);
        for replicates in [1, 7] {
            let cfg = SweepConfig { replicates, seed: 5, threads: 1 };
            assert_batched_equals_scalar(name, &sc, &cfg);
        }
    }
}

/// Lineup mode consumes one stream per replicate across the whole
/// strategy lineup in entry order; the batched executor must reproduce
/// that interleaving exactly (fig4 is the shipped lineup preset).
#[test]
fn lineup_mode_preserves_per_replicate_stream_order() {
    let sc = reduced("fig4", 600);
    let cfg = SweepConfig { replicates: 4, seed: 11, threads: 8 };
    assert_batched_equals_scalar("fig4", &sc, &cfg);
}

/// The event-native presets exercise the lockstep kernel's full event
/// stream (rebids on preemption notices, price-revision fleet
/// resizing); a digest match here means the batched kernel's event
/// emission order is the engine's, not an approximation of it.
#[test]
fn event_native_presets_take_the_batched_path_bit_identically() {
    for name in ["adaptive_grid", "notice_grid"] {
        let sc = reduced(name, 600);
        let cfg = SweepConfig { replicates: 3, seed: 23, threads: 8 };
        assert_batched_equals_scalar(name, &sc, &cfg);
    }
}

/// The reference runner stays on the scalar oracle inside
/// `run_sweep_batched` — same digest by construction, pinned here so a
/// future fast path for it cannot silently change results.
#[test]
fn reference_runner_is_unchanged_under_the_batched_harness() {
    let mut spec = presets::spec("fig3").unwrap();
    spec.markets.truncate(1);
    let sc = SpecScenario::new(spec)
        .unwrap()
        .with_reference_runner()
        .unwrap();
    let cfg = SweepConfig { replicates: 2, seed: 7, threads: 1 };
    assert_batched_equals_scalar("fig3(reference)", &sc, &cfg);
}

/// Const-only points (no simulation) go through `run_block`'s fallback
/// too; a spec that never simulates must still collate identically.
#[test]
fn const_only_spec_survives_the_batched_harness() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("../examples/configs/fig2.toml");
    let spec = ScenarioSpec::from_file(&dir).unwrap();
    let sc = SpecScenario::new(spec).unwrap();
    let cfg = SweepConfig { replicates: 2, seed: 3, threads: 1 };
    assert_batched_equals_scalar("fig2(file)", &sc, &cfg);
}
