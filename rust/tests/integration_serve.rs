//! End-to-end daemon tests: an in-process server on an ephemeral port,
//! driven through the real TCP client.
//!
//! The load-bearing contract is ISSUE-grade determinism: a daemon
//! result — cold, warm or partially warm — carries the same FNV digest
//! as the offline CLI run of the same spec and seed, at any thread
//! count.

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use volatile_sgd::exp::{ScenarioSpec, SpecScenario};
use volatile_sgd::opt::{self, PlanSpec, PlannerConfig};
use volatile_sgd::serve::client;
use volatile_sgd::serve::protocol::{
    bare_request_json, submit_request_json, SubmitReq,
};
use volatile_sgd::serve::state::ServerState;
use volatile_sgd::serve::{DrainReport, ServeConfig, Server};
use volatile_sgd::sweep::{run_sweep_batched, SweepConfig};
use volatile_sgd::util::json::JsonValue;

const SPEC: &str = r#"
name = "serve-e2e"
strategies = ["static_workers"]
axes = ["q"]
metrics = ["cost", "iters", "recip_exact"]

[job]
n = 4
j = 40

[runtime]
kind = "deterministic"
r = 10.0

[market]
kind = "fixed"

[axis.q]
path = "job.preempt_q"
values = [0.2, 0.4]
"#;

/// SPEC with its grid shifted one value: the 0.4 point overlaps.
const SPEC_SHIFTED: &str = r#"
name = "serve-e2e"
strategies = ["static_workers"]
axes = ["q"]
metrics = ["cost", "iters", "recip_exact"]

[job]
n = 4
j = 40

[runtime]
kind = "deterministic"
r = 10.0

[market]
kind = "fixed"

[axis.q]
path = "job.preempt_q"
values = [0.4, 0.6]
"#;

const PLAN: &str = r#"
name = "serve-plan"
strategies = ["static_workers"]
axes = ["price"]

[objective]
goal = "min_cost"

[search]
ladder = [2]
min_keep = 1

[job]
n = 4
j = 50
preempt_q = 0.3

[runtime]
kind = "deterministic"
r = 10.0

[market]
kind = "fixed"

[axis.price]
path = "job.unit_price"
values = [1.0, 2.0]
"#;

struct Daemon {
    addr: String,
    state: Arc<ServerState>,
    handle: thread::JoinHandle<DrainReport>,
}

fn start(threads: usize) -> Daemon {
    let server = Server::bind(&ServeConfig {
        listen: "127.0.0.1:0".into(),
        threads,
    })
    .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let state = server.state();
    let handle = thread::spawn(move || server.run().unwrap());
    Daemon { addr, state, handle }
}

impl Daemon {
    /// Submit, wait for completion, return (job id, digest hex).
    fn submit_and_wait(&self, req: &SubmitReq) -> (u64, String) {
        let ack = client::roundtrip(&self.addr, &submit_request_json(req))
            .unwrap();
        let job = ack.get("job").and_then(JsonValue::as_u64).unwrap();
        let (result, _) =
            client::wait_result(&self.addr, job, Duration::from_secs(120))
                .unwrap();
        let digest = result
            .get("digest")
            .and_then(JsonValue::as_str)
            .expect("result digest")
            .to_string();
        (job, digest)
    }

    fn stats(&self) -> JsonValue {
        client::roundtrip(&self.addr, &bare_request_json("stats")).unwrap()
    }

    fn shutdown(self) -> DrainReport {
        client::roundtrip(&self.addr, &bare_request_json("shutdown"))
            .unwrap();
        self.handle.join().unwrap()
    }
}

fn stat(v: &JsonValue, key: &str) -> u64 {
    v.get(key).and_then(JsonValue::as_u64).unwrap_or_else(|| {
        panic!("stats field {key} missing or not an integer")
    })
}

#[test]
fn daemon_digest_matches_offline_cold_and_warm_at_any_thread_count() {
    // ground truth: the offline CLI path at threads = 1
    let spec = ScenarioSpec::from_str(SPEC).unwrap();
    let cfg = SweepConfig { replicates: 3, seed: 11, threads: 1 };
    let offline =
        run_sweep_batched(&SpecScenario::new(spec).unwrap(), &cfg).unwrap();
    let want = format!("{:016x}", offline.digest());

    // the daemon runs the same work at threads = 4
    let daemon = start(4);
    let req = SubmitReq {
        spec_toml: Some(SPEC.into()),
        seed: Some(11),
        replicates: Some(3),
        ..Default::default()
    };
    let (job0, cold) = daemon.submit_and_wait(&req);
    assert_eq!(cold, want, "cold daemon digest != offline digest");

    let pool_after_cold = stat(&daemon.stats(), "pool_jobs");
    assert_eq!(pool_after_cold, offline.throughput.jobs);

    // warm repeat: tier-A hit — same digest, no new pool work
    let (job1, warm) = daemon.submit_and_wait(&req);
    assert_ne!(job0, job1, "a hit still gets its own job record");
    assert_eq!(warm, want, "warm daemon digest != offline digest");
    let s = daemon.stats();
    assert_eq!(stat(&s, "tier_a_hits"), 1);
    assert_eq!(stat(&s, "pool_jobs"), pool_after_cold);
    assert_eq!(stat(&s, "jobs_done"), 1, "the hit never reached the pool");

    let report = daemon.shutdown();
    assert_eq!(report.jobs_done, 1);
    assert_eq!(report.jobs_failed, 0);
    assert_eq!(report.pool_jobs, pool_after_cold);
}

#[test]
fn overlapping_grids_share_tier_b_artifacts_with_unchanged_digests() {
    // offline truth for the shifted grid
    let cfg = SweepConfig { replicates: 2, seed: 5, threads: 1 };
    let offline = |text: &str| {
        let sc =
            SpecScenario::new(ScenarioSpec::from_str(text).unwrap()).unwrap();
        format!("{:016x}", run_sweep_batched(&sc, &cfg).unwrap().digest())
    };

    let daemon = start(2);
    let req = |text: &str| SubmitReq {
        spec_toml: Some(text.into()),
        seed: Some(5),
        replicates: Some(2),
        ..Default::default()
    };
    let (_, first) = daemon.submit_and_wait(&req(SPEC));
    assert_eq!(first, offline(SPEC));
    let s = daemon.stats();
    assert_eq!(stat(&s, "tier_b_misses"), 2, "cold grid: both points novel");
    assert_eq!(stat(&s, "tier_b_entries"), 2);

    // shifted grid: different request fingerprint (no tier-A hit), but
    // the overlapping q = 0.4 point is served from tier B — and the
    // partially-warm digest still equals the offline run's
    let (_, second) = daemon.submit_and_wait(&req(SPEC_SHIFTED));
    assert_eq!(second, offline(SPEC_SHIFTED));
    assert_ne!(first, second);
    let s = daemon.stats();
    assert_eq!(stat(&s, "tier_a_hits"), 0);
    assert_eq!(stat(&s, "tier_b_hits"), 1, "shared point reused");
    assert_eq!(stat(&s, "tier_b_misses"), 3, "only the novel point prepared");
    assert_eq!(stat(&s, "tier_b_entries"), 3);
    daemon.shutdown();
}

#[test]
fn optimize_submissions_match_the_offline_planner() {
    let plan = PlanSpec::from_str(PLAN).unwrap();
    let outcome =
        opt::run_plan(&plan, &PlannerConfig { seed: 7, threads: 1 }).unwrap();
    let want = format!("{:016x}", outcome.digest());

    let daemon = start(2);
    // kind auto-detected from the [objective] table
    let req = SubmitReq {
        spec_toml: Some(PLAN.into()),
        seed: Some(7),
        ..Default::default()
    };
    let (_, cold) = daemon.submit_and_wait(&req);
    assert_eq!(cold, want, "daemon planner digest != offline digest");
    let (_, warm) = daemon.submit_and_wait(&req);
    assert_eq!(warm, want);
    let s = daemon.stats();
    assert_eq!(stat(&s, "tier_a_hits"), 1);
    // planner pool work: rung replicates x surviving members
    let sims: u64 = outcome
        .rungs
        .iter()
        .map(|r| r.replicates * r.members.len() as u64)
        .sum();
    assert_eq!(stat(&s, "pool_jobs"), sims);
    daemon.shutdown();
}

#[test]
fn invalid_submissions_and_unknown_jobs_are_clean_server_errors() {
    let daemon = start(1);
    let e = client::roundtrip(
        &daemon.addr,
        &submit_request_json(&SubmitReq {
            preset: Some("fig9".into()),
            ..Default::default()
        }),
    )
    .unwrap_err()
    .to_string();
    assert!(e.contains("server:"), "{e}");
    assert!(e.contains("unknown preset"), "{e}");

    let e = client::roundtrip(&daemon.addr, "{\"cmd\": \"status\", \"job\": 42}")
        .unwrap_err()
        .to_string();
    assert!(e.contains("unknown job 42"), "{e}");

    // a rejected submission leaves no queued or executed work behind
    let s = daemon.stats();
    assert_eq!(stat(&s, "queue_depth"), 0);
    assert_eq!(stat(&s, "jobs_done") + stat(&s, "jobs_failed"), 0);
    let report = daemon.shutdown();
    assert_eq!(report.jobs_done + report.jobs_failed, 0);
}

#[test]
fn shutdown_drains_already_admitted_work() {
    let daemon = start(1);
    // queue two jobs, then immediately ask for shutdown: both must
    // still complete (drain finishes admitted work, rejects new work)
    let submit = |seed: u64| {
        let ack = client::roundtrip(
            &daemon.addr,
            &submit_request_json(&SubmitReq {
                spec_toml: Some(SPEC.into()),
                seed: Some(seed),
                replicates: Some(2),
                ..Default::default()
            }),
        )
        .unwrap();
        ack.get("job").and_then(JsonValue::as_u64).unwrap()
    };
    let a = submit(1);
    let b = submit(2);
    assert_ne!(a, b);
    let report = daemon.shutdown();
    assert_eq!(report.jobs_done, 2, "drain must finish admitted jobs");
    assert_eq!(report.jobs_failed, 0);
}
