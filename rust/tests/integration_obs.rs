//! Telemetry integration: the digest-neutrality contract end to end
//! (DESIGN.md §12).
//!
//! The tentpole guarantee is that observability is *provably inert*:
//! attaching a trace sink and a metrics registry to a sweep never
//! changes a result bit, because the observers draw no RNG and
//! wall-clock values only flow out of the run (span lines, latency
//! histograms) — never into the FNV digest. Pinned here for every
//! shipped preset, on both executors, at 1 and 8 threads.

use volatile_sgd::exp::presets::{self, PRESET_NAMES};
use volatile_sgd::exp::SpecScenario;
use volatile_sgd::obs::{
    meta_line, validate_trace, Registry, TraceSink,
};
use volatile_sgd::sweep::{
    run_sweep, run_sweep_batched, run_sweep_batched_with, run_sweep_with,
    SweepConfig, Telemetry,
};

/// A per-test temp path that parallel test binaries cannot collide on.
fn tmp_trace(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "volatile_sgd_obs_{}_{tag}.jsonl",
        std::process::id()
    ))
}

/// A small Fig. 3 grid (one market x four strategies) for the tests
/// that probe structure rather than coverage.
fn small_fig3() -> SpecScenario {
    let mut spec = presets::spec("fig3").unwrap();
    spec.markets.truncate(1);
    SpecScenario::new(spec).unwrap()
}

#[test]
fn telemetry_is_digest_neutral_for_every_preset() {
    for name in PRESET_NAMES {
        let scenario =
            SpecScenario::new(presets::spec(name).unwrap()).unwrap();
        for threads in [1usize, 8] {
            let cfg = SweepConfig { replicates: 2, seed: 2020, threads };
            let off = run_sweep_batched(&scenario, &cfg).unwrap();

            let path = tmp_trace(&format!("{name}_{threads}"));
            let reg = Registry::new();
            let sink = TraceSink::create(path.to_str().unwrap()).unwrap();
            sink.write_line(&meta_line("sweep", name, cfg.seed, threads));
            let tel =
                Telemetry { trace: Some(&sink), registry: Some(&reg) };
            let on = run_sweep_batched_with(&scenario, &cfg, tel).unwrap();
            sink.flush().unwrap();

            // the digest pins every count, mean, variance, min, max bit
            assert_eq!(
                off.digest(),
                on.digest(),
                "{name} threads={threads}: telemetry changed the digest"
            );
            assert_eq!(
                off.to_table().to_csv(),
                on.to_table().to_csv(),
                "{name} threads={threads}"
            );
            // and the trace it produced is a valid schema-1 file
            let text = std::fs::read_to_string(&path).unwrap();
            let sum = validate_trace(&text)
                .unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert!(sum.spans > 0, "{name}: no timing spans recorded");
            std::fs::remove_file(&path).ok();
        }
    }
}

#[test]
fn scalar_path_tracing_is_digest_neutral_too() {
    let scenario = small_fig3();
    let cfg = SweepConfig { replicates: 2, seed: 7, threads: 4 };
    let off = run_sweep(&scenario, &cfg).unwrap();

    let path = tmp_trace("scalar_fig3");
    let reg = Registry::new();
    let sink = TraceSink::create(path.to_str().unwrap()).unwrap();
    sink.write_line(&meta_line("sweep", "fig3", cfg.seed, cfg.threads));
    let on = run_sweep_with(
        &scenario,
        &cfg,
        Telemetry { trace: Some(&sink), registry: Some(&reg) },
    )
    .unwrap();
    sink.flush().unwrap();

    assert_eq!(off.digest(), on.digest());
    // the scalar executor attributes every traced run to its path
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.contains("\"path\":\"scalar\""), "no path attribution");
    validate_trace(&text).unwrap();
    std::fs::remove_file(&path).ok();
}

/// The fig3 acceptance check: a traced run exports the engine's event
/// stream as strict JSONL — every line parses under `util::json`, the
/// kinds come from the known set, and per-event sim-time is monotone
/// within each replicate (all enforced by `validate_trace`).
#[test]
fn fig3_trace_exports_engine_events_and_spans() {
    let scenario = small_fig3();
    let cfg = SweepConfig { replicates: 2, seed: 2020, threads: 2 };
    let path = tmp_trace("events_fig3");
    let sink = TraceSink::create(path.to_str().unwrap()).unwrap();
    sink.write_line(&meta_line("sweep", "fig3", cfg.seed, cfg.threads));
    run_sweep_batched_with(
        &scenario,
        &cfg,
        Telemetry { trace: Some(&sink), registry: None },
    )
    .unwrap();
    sink.flush().unwrap();

    let text = std::fs::read_to_string(&path).unwrap();
    let sum = validate_trace(&text).unwrap();
    assert!(sum.events > 0, "engine events were not exported");
    assert!(
        sum.kinds.get("iteration_done").copied().unwrap_or(0) > 0,
        "kinds: {:?}",
        sum.kinds
    );
    // spans: prepare per point + run per point + pool + collate
    let npts = 4u64;
    assert!(sum.spans >= 2 * npts + 2, "spans: {}", sum.spans);
    assert_eq!(sum.lines, 1 + sum.events + sum.spans);
    std::fs::remove_file(&path).ok();
}

#[test]
fn registry_accounts_for_every_stage_and_pool_job() {
    let scenario = small_fig3();
    let cfg = SweepConfig { replicates: 3, seed: 5, threads: 4 };
    let npts = 4u64;

    // batched: one pool job per point, run latency spread per replicate
    let reg = Registry::new();
    let tel = Telemetry { trace: None, registry: Some(&reg) };
    run_sweep_batched_with(&scenario, &cfg, tel).unwrap();
    assert_eq!(reg.histogram("sweep_prepare_us").count(), npts);
    assert_eq!(
        reg.histogram("sweep_run_us").count(),
        npts * cfg.replicates
    );
    assert_eq!(reg.histogram("sweep_pool_us").count(), 1);
    assert_eq!(reg.histogram("sweep_collate_us").count(), 1);
    assert_eq!(
        reg.counter("sweep_pool_own_jobs").get()
            + reg.counter("sweep_pool_stolen_jobs").get(),
        npts,
        "batched pool jobs = grid points"
    );

    // scalar: one pool job per (point, replicate)
    let reg = Registry::new();
    let tel = Telemetry { trace: None, registry: Some(&reg) };
    run_sweep_with(&scenario, &cfg, tel).unwrap();
    assert_eq!(
        reg.histogram("sweep_run_us").count(),
        npts * cfg.replicates
    );
    assert_eq!(
        reg.counter("sweep_pool_own_jobs").get()
            + reg.counter("sweep_pool_stolen_jobs").get(),
        npts * cfg.replicates,
        "scalar pool jobs = points x replicates"
    );
}
