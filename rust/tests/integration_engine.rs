//! Engine/scheduler equivalence: every shipped overhead-free preset
//! collates to the *same digest* through the pre-engine lockstep loop
//! (`Scheduler::run_reference`, kept verbatim as the oracle) and the
//! new event engine, at 1 and 8 threads — the §5 determinism contract
//! at full preset scale. The overhead-enabled `checkpoint_grid` preset
//! has no pre-engine equivalent; it is pinned for thread-determinism
//! and sane ledger metrics instead.

use volatile_sgd::exp::presets;
use volatile_sgd::exp::{ScenarioSpec, SpecScenario};
use volatile_sgd::sweep::{run_sweep, Scenario, SweepConfig};

fn configs_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("../examples/configs")
}

/// Shrink a spec's iteration budget where that cannot change plan
/// feasibility (preemptible fixed-price scenarios have no Theorem-2/3
/// deadline coupling), keeping the suite quick. Both runners see the
/// same spec, so equivalence is unaffected.
fn quick(mut spec: ScenarioSpec, j: u64) -> ScenarioSpec {
    use volatile_sgd::exp::spec::MarketKind;
    if spec
        .markets
        .iter()
        .all(|m| matches!(m.kind, MarketKind::Fixed { .. }))
    {
        spec.job.j = spec.job.j.min(j);
    }
    spec
}

#[test]
fn every_overhead_free_preset_is_engine_reference_identical() {
    let mut checked = 0;
    for entry in
        std::fs::read_dir(configs_dir()).expect("examples/configs exists")
    {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("toml") {
            continue;
        }
        let spec = ScenarioSpec::from_file(&path)
            .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        if spec.overhead.enabled() {
            continue; // no pre-engine equivalent exists by design
        }
        if spec.strategies.iter().any(|e| e.kind.event_native()) {
            // event-native policies (sim::policy) have no lockstep
            // form either; tests/integration_policy.rs pins their
            // thread-determinism instead
            continue;
        }
        let spec = quick(spec, 800);
        let name = spec.name.clone();
        checked += 1;

        let engine = SpecScenario::new(spec.clone())
            .unwrap_or_else(|e| panic!("{name}: {e:#}"));
        let reference = SpecScenario::new(spec)
            .unwrap()
            .with_reference_runner()
            .unwrap();
        let cfg = |threads| SweepConfig { replicates: 2, seed: 77, threads };
        let digests = [
            run_sweep(&engine, &cfg(1)).unwrap().digest(),
            run_sweep(&engine, &cfg(8)).unwrap().digest(),
            run_sweep(&reference, &cfg(1)).unwrap().digest(),
            run_sweep(&reference, &cfg(8)).unwrap().digest(),
        ];
        assert!(
            digests.iter().all(|d| *d == digests[0]),
            "{name}: engine/reference x threads digests diverge: {digests:x?}"
        );
    }
    assert!(checked >= 5, "expected >= 5 overhead-free presets, {checked}");
}

#[test]
fn checkpoint_grid_runs_thread_deterministic_with_sane_ledger() {
    let mut spec = presets::spec("checkpoint_grid").unwrap();
    spec.job.j = 400; // quick; the shipped default is 2000
    let sc = SpecScenario::new(spec).unwrap();
    assert_eq!(sc.points(), 9);

    let base = SweepConfig { replicates: 2, seed: 13, threads: 1 };
    let serial = run_sweep(&sc, &base).unwrap();
    let par =
        run_sweep(&sc, &SweepConfig { threads: 8, ..base }).unwrap();
    assert_eq!(serial.digest(), par.digest());

    let idx = |name: &str| {
        serial
            .metric_names
            .iter()
            .position(|m| m == name)
            .unwrap_or_else(|| panic!("missing metric {name}"))
    };
    let mean = |p: usize, m: &str| serial.points[p].stats[idx(m)].mean();
    // layout: q slowest, delay fastest -> points 0..3 are q=0.1
    // work loss and recomputation grow with q at fixed delay=0
    assert!(mean(0, "lost_iters") < mean(6, "lost_iters"));
    // recovery lag is billed only when the delay axis switches it on
    assert_eq!(mean(3, "restart_time"), 0.0);
    assert!(mean(4, "restart_time") > 0.0);
    // ledger identity: restart_time = delay x restarts, and every
    // interruption but possibly the trailing one restarts
    let pe = mean(5, "preempt_events");
    assert!(pe > 0.0);
    assert!(mean(5, "restart_time") >= 120.0 * (pe - 1.0).max(0.0) - 1e-9);
    assert!(mean(5, "restart_time") <= 120.0 * pe + 1e-9);
    // the discount erosion headline: same net work, much higher cost
    // at the high-churn corner than the calm one
    assert!(mean(8, "cost") > mean(0, "cost"));
    for p in 0..9 {
        assert!(mean(p, "checkpoint_time") > 0.0, "point {p}");
        assert!(
            serial.points[p].stats[idx("iters")].mean() > 0.0,
            "point {p}"
        );
    }
}
