//! Scenario-spec integration: every shipped config validates, the
//! fig3 preset reproduces the pre-redesign sweep exactly (same point
//! space, same plans, same runner — hence the same digests), and a
//! scenario that was *not* expressible before the redesign runs from a
//! TOML file with no new Rust code.

use volatile_sgd::exp::fig3::{self, Fig3Params, STRATEGY_NAMES};
use volatile_sgd::exp::presets;
use volatile_sgd::exp::{PlannedStrategy, ScenarioSpec, SpecScenario};
use volatile_sgd::market::PriceModel;
use volatile_sgd::sweep::{run_sweep, Scenario, SweepConfig};
use volatile_sgd::theory::bids::BidProblem;
use volatile_sgd::theory::bounds::{ErrorBound, SgdHyper};
use volatile_sgd::theory::runtime_model::RuntimeModel;

fn configs_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("../examples/configs")
}

#[test]
fn every_shipped_config_parses_and_validates() {
    let mut seen = 0;
    for entry in
        std::fs::read_dir(configs_dir()).expect("examples/configs exists")
    {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("toml") {
            continue;
        }
        seen += 1;
        let spec = ScenarioSpec::from_file(&path)
            .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        let sc = SpecScenario::new(spec)
            .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        assert!(sc.points() > 0, "{}", path.display());
    }
    assert!(seen >= 5, "expected >= 5 shipped configs, found {seen}");
}

/// The digest hashes labels, metric names and every collated statistic
/// bit; replicate RNG streams are a pure function of the point order.
/// So "spec path == pre-redesign `sweep --fig 3`" reduces to: same
/// point space (pinned in `presets` unit tests), same per-point plans
/// (pinned here against the figure harness's own plan builder), and the
/// same replicate runner (both call `run_synthetic_rng` via
/// `PlannedStrategy::build`).
#[test]
fn fig3_preset_plans_match_figure_harness_exactly() {
    let sc = presets::scenario("fig3").unwrap();
    let p = Fig3Params::default();
    // the figure harness's problem setting for the uniform market
    let bound = ErrorBound::new(SgdHyper::paper_cnn());
    let runtime = RuntimeModel::ExpStragglers { lambda: 0.25, delta: 0.5 };
    let theta = p.deadline_slack * p.j as f64 * runtime.expected(p.n);
    let pb = BidProblem {
        bound,
        price: PriceModel::uniform_paper(),
        runtime,
        n: p.n,
        eps: p.eps,
        theta,
    };
    for (idx, name) in STRATEGY_NAMES.iter().enumerate() {
        let want = fig3::plan_strategy(&pb, &p, idx).unwrap();
        // uniform market points are 0..4 in the preset's ordering
        let ctx = sc.prepare(idx).unwrap();
        let got = &ctx.plans()[0];
        assert_eq!(got.name(), *name);
        assert_eq!(got.name(), want.name());
        assert_eq!(got.target_iters(), want.target_iters(), "{name}");
        match (got, &want) {
            (
                PlannedStrategy::Fixed { bids: a, .. },
                PlannedStrategy::Fixed { bids: b, .. },
            ) => {
                assert_eq!(a.n(), b.n(), "{name}");
                assert_eq!(a.n1, b.n1, "{name}");
                assert_eq!(a.b1.to_bits(), b.b1.to_bits(), "{name}");
                assert_eq!(a.b2.to_bits(), b.b2.to_bits(), "{name}");
            }
            (
                PlannedStrategy::Dynamic { stages: a, .. },
                PlannedStrategy::Dynamic { stages: b, .. },
            ) => {
                assert_eq!(a.len(), b.len(), "{name}");
                for (x, y) in a.iter().zip(b.iter()) {
                    assert_eq!(x.n, y.n, "{name}");
                    assert_eq!(x.n1, y.n1, "{name}");
                    assert_eq!(x.until_iter, y.until_iter, "{name}");
                }
            }
            other => panic!("plan shape mismatch for {name}: {other:?}"),
        }
    }
}

/// Not expressible before the redesign: a (fleet-size x preemption
/// probability) grid over BOTH Sec. V provisioning strategies, straight
/// from a shipped TOML file — zero scenario-specific Rust.
#[test]
fn novel_preempt_grid_runs_from_toml_only() {
    let mut spec =
        ScenarioSpec::from_file(configs_dir().join("preempt_grid.toml"))
            .unwrap();
    spec.job.j = 800; // keep the test quick; the shipped default is 4000
    let sc = SpecScenario::new(spec).unwrap();
    assert_eq!(sc.points(), 24); // 3 n x 4 q x 2 strategies

    let base = SweepConfig { replicates: 2, seed: 13, threads: 1 };
    let serial = run_sweep(&sc, &base).unwrap();
    let par =
        run_sweep(&sc, &SweepConfig { threads: 4, ..base }).unwrap();
    assert_eq!(serial.digest(), par.digest());

    let labels: Vec<&str> =
        serial.points.iter().map(|p| p.label.as_str()).collect();
    assert!(labels.contains(&"n=2 q=0.1/static"), "{labels:?}");
    assert!(labels.contains(&"n=8 q=0.7/growing"), "{labels:?}");
    let cost_idx = 0; // "cost" is the first metric
    for p in &serial.points {
        assert_eq!(p.stats[cost_idx].count(), 2, "{}", p.label);
        assert!(p.stats[cost_idx].mean() > 0.0, "{}", p.label);
    }
    // n_match_exact (last metric) is a per-point constant >= n_baseline
    let nm_idx = serial.metric_names.len() - 1;
    for p in &serial.points {
        let nm = p.stats[nm_idx].mean();
        assert!(nm >= 2.0, "{}: n_match {nm}", p.label);
        assert_eq!(p.stats[nm_idx].variance(), 0.0, "{}", p.label);
    }
}

/// Lineup mode end to end on a single generated trace: the whole
/// lineup runs inside each replicate and the savings/accuracy
/// comparisons come out as finite, baseline-relative numbers.
#[test]
fn fig4_preset_lineup_mode_produces_comparisons() {
    let mut spec = presets::spec("fig4").unwrap();
    spec.axes[0].values = vec![7.0]; // one trace seed
    let sc = SpecScenario::new(spec).unwrap();
    assert_eq!(sc.points(), 1);
    let out = run_sweep(
        &sc,
        &SweepConfig { replicates: 1, seed: 2020, threads: 1 },
    )
    .unwrap();
    let p = &out.points[0];
    assert_eq!(p.label, "trace_seed=7");
    let metric = |name: &str| {
        let i = out
            .metric_names
            .iter()
            .position(|m| m == name)
            .unwrap_or_else(|| panic!("missing metric {name}"));
        p.stats[i].mean()
    };
    assert!(metric("noint_cost") > 0.0);
    assert!(metric("one_bid_cost") > 0.0);
    assert!(metric("two_bids_cost") > 0.0);
    // savings are defined relative to the baseline's own cost
    let s1 = metric("one_bid_saving_pct");
    let s2 = metric("two_bids_saving_pct");
    assert!(s1.is_finite() && s2.is_finite());
    assert!(
        (metric("noint_cost") * (1.0 - s1 / 100.0) - metric("one_bid_cost"))
            .abs()
            < 1e-9 * metric("noint_cost").max(1.0)
    );
    assert!(metric("one_bid_acc_ratio") > 0.0);
}

/// A minimal JSON well-formedness scan: balanced braces/brackets
/// outside strings, and no bare `inf`/`NaN` float tokens (both invalid
/// JSON — `util::json::num` must emit `null` instead).
fn assert_valid_json(json: &str, what: &str) {
    let (mut depth, mut in_str, mut esc) = (0i64, false, false);
    for c in json.chars() {
        if in_str {
            if esc {
                esc = false;
            } else if c == '\\' {
                esc = true;
            } else if c == '"' {
                in_str = false;
            }
            continue;
        }
        match c {
            '"' => in_str = true,
            '{' | '[' => depth += 1,
            '}' | ']' => depth -= 1,
            _ => {}
        }
        assert!(depth >= 0, "{what}: unbalanced close");
    }
    assert_eq!(depth, 0, "{what}: unbalanced JSON");
    assert!(!in_str, "{what}: unterminated string");
    assert!(!json.contains("inf"), "{what}: bare inf token:\n{json}");
    assert!(!json.contains("NaN"), "{what}: bare NaN token:\n{json}");
}

/// Regression (float formatting audit): the fig3 preset's
/// no-interruptions strategy plans bids at +inf ("above any price"),
/// the historical way non-finite floats leaked toward `--json`. The
/// end-to-end payload must stay parseable, and non-finite *statistics*
/// (all replicates missing) must serialise as `null`, not `NaN`/`inf`.
#[test]
fn sweep_json_stays_valid_with_inf_bids_and_missing_metrics() {
    use volatile_sgd::sweep::{PointSummary, SweepResults};
    use volatile_sgd::util::stats::OnlineStats;

    // end to end: inf-bid lineup through the production JSON writer
    let mut spec = presets::spec("fig3").unwrap();
    spec.markets.truncate(1);
    let sc = SpecScenario::new(spec).unwrap();
    let cfg = SweepConfig { replicates: 2, seed: 2020, threads: 1 };
    let results = run_sweep(&sc, &cfg).unwrap();
    let json = results.to_json("fig3", &cfg);
    assert_valid_json(&json, "fig3 --json");
    assert!(json.contains("\"no_interruptions\""));

    // adversarial: force non-finite collated statistics directly
    let mut poisoned = OnlineStats::new();
    poisoned.push(f64::INFINITY);
    let hostile = SweepResults {
        metric_names: vec!["m".to_string()],
        points: vec![
            PointSummary {
                label: "empty".to_string(),
                stats: vec![OnlineStats::new()], // n = 0: mean undefined
                missing: vec![2],
            },
            PointSummary {
                label: "poisoned".to_string(),
                stats: vec![poisoned],
                missing: vec![0],
            },
        ],
        throughput: results.throughput,
    };
    let json = hostile.to_json("hostile", &cfg);
    assert_valid_json(&json, "hostile --json");
    assert!(json.contains("null"), "non-finite stats must null out");
}
