//! Planner end to end on the shipped `optimize_deadline` preset: the
//! whole two-stage plan is digest-identical at threads 1 vs 8 with the
//! same incumbent and frontier; every analytically-pruned point is
//! justified by the closed-form bounds (a surviving dominating witness
//! or a violated declared constraint); and every feasible
//! recommendation satisfies its declared constraints when its rung is
//! re-simulated through the engine path.

use volatile_sgd::opt::{
    self, build_scenario, evaluate_rung, run_plan, Fate, PlanOutcome,
    PlanSpec, PlannerConfig,
};

fn preset_plan() -> PlanSpec {
    PlanSpec::from_str(opt::preset_toml()).unwrap()
}

fn outcome(threads: usize) -> PlanOutcome {
    run_plan(&preset_plan(), &PlannerConfig { seed: 2020, threads })
        .unwrap()
}

#[test]
fn preset_digest_incumbent_and_frontier_are_thread_invariant() {
    let serial = outcome(1);
    let par = outcome(8);
    assert_eq!(serial.digest(), par.digest(), "threads must be pure");
    assert_eq!(serial.incumbent_label(), par.incumbent_label());
    assert_eq!(serial.frontier_labels(), par.frontier_labels());
    assert!(
        serial.incumbent.is_some(),
        "the shipped preset must produce a feasible incumbent"
    );
    assert!(!serial.frontier_labels().is_empty());
    // same ladder trace, member by member
    assert_eq!(serial.rungs.len(), par.rungs.len());
    for (a, b) in serial.rungs.iter().zip(&par.rungs) {
        assert_eq!(a.members, b.members);
        assert_eq!(a.seed, b.seed);
    }
}

#[test]
fn preset_pruning_is_justified_by_the_closed_forms() {
    let out = outcome(2);
    let c = out.counts();
    assert_eq!(out.lattice_points, 36); // 2 n x 3 budget x 2 thresh x 3
    assert_eq!(c.folded, 24, "scoped axes fold exact duplicates");
    assert_eq!(
        c.plan_errors, 3,
        "eps = 0.35 sits below the n = 4 noise floor for the two \
         bidding strategies (one_bid + two deadline_aware candidates)"
    );
    assert_eq!(c.evaluated, 9);
    for cand in &out.candidates {
        match &cand.fate {
            Fate::Dominated { by } => {
                // the witness survived, and its closed-form surface is
                // no worse on every axis — the pruned point is
                // provably dominated per the bounds
                let w = &out.candidates[*by];
                assert!(
                    matches!(w.fate, Fate::Evaluated { .. }),
                    "witness of '{}' must survive",
                    cand.label
                );
                let (a, b) =
                    (w.surface.unwrap(), cand.surface.unwrap());
                assert!(
                    a.cost <= b.cost && a.time <= b.time && a.err <= b.err,
                    "'{}' not actually dominated by '{}'",
                    cand.label,
                    w.label
                );
            }
            Fate::Infeasible { violated } => {
                let s = cand.surface.expect("infeasible needs a surface");
                assert!(
                    out.objective
                        .violation(s.cost, s.time, s.err)
                        .is_some(),
                    "'{}' pruned without a closed-form violation: \
                     {violated}",
                    cand.label
                );
            }
            Fate::PlanError { error } => {
                assert!(error.contains("noise floor"), "{error}");
            }
            Fate::Folded { into } => {
                assert!(!matches!(
                    out.candidates[*into].fate,
                    Fate::Folded { .. }
                ));
            }
            Fate::Evaluated { .. } => {}
        }
    }
    // every surviving recommendation carries simulated evidence
    for &ci in &out.recommendations {
        assert!(out.candidates[ci].sim.is_some());
        assert!(out.candidates[ci].rank.is_some());
    }
}

#[test]
fn feasible_recommendations_hold_their_constraints_when_resimulated() {
    let out = outcome(4);
    let scenario = build_scenario(&preset_plan()).unwrap();
    assert!(!out.rungs.is_empty());
    let mut verified = 0usize;
    for (ri, rung) in out.rungs.iter().enumerate() {
        let points: Vec<usize> = rung
            .members
            .iter()
            .map(|&ci| out.candidates[ci].point)
            .collect();
        // independent re-simulation through the sweep pool + event
        // engine (different thread count on purpose)
        let replay = evaluate_rung(
            &scenario,
            &points,
            rung.replicates,
            rung.seed,
            2,
        )
        .unwrap();
        for (k, &ci) in rung.members.iter().enumerate() {
            let cand = &out.candidates[ci];
            // recorded stats come from the deepest rung only
            if cand.fate != (Fate::Evaluated { rung: ri }) {
                continue;
            }
            let stats = &replay.points[k].stats;
            let (cost, time, err) =
                (stats[0].mean(), stats[1].mean(), stats[2].mean());
            let sim = cand.sim.unwrap();
            assert_eq!(cost, sim.cost_mean, "{}", cand.label);
            assert_eq!(time, sim.time_mean, "{}", cand.label);
            assert_eq!(err, sim.err_mean, "{}", cand.label);
            if cand.feasible {
                assert!(
                    out.objective.feasible(cost, time, err),
                    "recommended '{}' violates its constraints when \
                     re-simulated",
                    cand.label
                );
                verified += 1;
            }
        }
    }
    assert!(verified > 0, "no feasible recommendation was re-verified");
    // the incumbent itself is among the verified feasible candidates
    let inc = out.incumbent.unwrap();
    assert!(out.candidates[inc].feasible);
    assert_eq!(out.candidates[inc].rank, Some(1));
}

/// Non-vacuous dominance on the public API: identical preemptible
/// fleets at escalating unit prices — only the cheapest offering is
/// ever simulated, and each pruned point names a surviving witness
/// whose closed-form surface dominates it.
#[test]
fn dominance_pruning_never_simulates_a_beaten_candidate() {
    let text = r#"
name = "offerings"
strategies = ["static_workers"]
axes = ["price"]

[objective]
goal = "min_cost"

[search]
ladder = [2, 4]
min_keep = 1

[job]
n = 4
j = 80
preempt_q = 0.3

[runtime]
kind = "deterministic"
r = 10.0

[market]
kind = "fixed"

[axis.price]
path = "job.unit_price"
values = [1.0, 2.0, 3.0]
"#;
    let plan = PlanSpec::from_str(text).unwrap();
    let serial = run_plan(&plan, &PlannerConfig { seed: 9, threads: 1 })
        .unwrap();
    let par = run_plan(&plan, &PlannerConfig { seed: 9, threads: 8 })
        .unwrap();
    assert_eq!(serial.digest(), par.digest());
    let c = serial.counts();
    assert_eq!(c.dominated, 2);
    assert_eq!(c.evaluated, 1);
    for rung in &serial.rungs {
        assert_eq!(rung.members, vec![0], "beaten candidates never run");
    }
    for cand in &serial.candidates[1..] {
        match &cand.fate {
            Fate::Dominated { by } => {
                let w = &serial.candidates[*by];
                let (a, b) =
                    (w.surface.unwrap(), cand.surface.unwrap());
                assert!(a.cost < b.cost);
                assert_eq!(a.time, b.time);
                assert_eq!(a.err, b.err);
            }
            other => panic!("expected Dominated, got {other:?}"),
        }
    }
    assert_eq!(serial.incumbent_label(), Some("price=1"));
    assert_eq!(serial.frontier_labels(), vec!["price=1"]);
}
