//! Portfolio subsystem integration contract (DESIGN.md §10).
//!
//! Three guarantees are pinned here:
//!
//! * **Degenerate compatibility** — every shipped single-`[market]`
//!   preset, re-declared as a one-entry `[[portfolio]]`, parses to the
//!   very same spec (same fingerprint) and sweeps to the bit-identical
//!   digest at 1 and 8 threads. Adopting the portfolio schema can never
//!   move an existing result.
//! * **Thread invariance** — the portfolio executor's per-market RNG
//!   stream contract holds: `portfolio_grid` and `spot_replay` produce
//!   equal digests at 1 and 8 threads.
//! * **Content-addressed trace identity** — spec fingerprints hash
//!   trace-file *bytes*, never the path string, and the strict loader's
//!   error paths reject bad fixtures at parse (`--check`) time.

use std::fs;
use std::path::PathBuf;

use volatile_sgd::exp::{presets, ScenarioSpec, SpecScenario};
use volatile_sgd::opt::{self, PlannerConfig};
use volatile_sgd::sweep::{run_sweep, SweepConfig};

/// Shrink a parsed spec for test speed without touching anything that
/// feeds the portfolio semantics under test: the j cap follows the
/// `integration_batch` rule (only fixed-price markets, whose plans
/// have no Theorem-2/3 deadline coupling). Applied identically to
/// both sides of every comparison.
fn reduce(spec: &mut ScenarioSpec) {
    use volatile_sgd::exp::spec::MarketKind;
    if !spec.markets.is_empty()
        && spec
            .markets
            .iter()
            .all(|m| matches!(m.kind, MarketKind::Fixed { .. }))
    {
        spec.job.j = spec.job.j.min(600);
    }
    for ax in &mut spec.axes {
        if ax.values.len() > 2 {
            ax.values.truncate(2);
        }
    }
}

fn digest(sc: &SpecScenario, threads: usize) -> u64 {
    run_sweep(sc, &SweepConfig { replicates: 2, seed: 7, threads })
        .unwrap()
        .digest()
}

/// Every shipped preset with a single `[market]` table, rewritten as a
/// one-entry `[[portfolio]]`: same fingerprint, same sweep digest at 1
/// and 8 threads. The rewrite is textual (`[market]` ->
/// `[[portfolio]]`), so `market.kind` becomes `portfolio.0.kind` and
/// the parse-time degenerate lowering must reconstruct the classic
/// lineup — label included — bit for bit.
#[test]
fn degenerate_portfolio_matches_every_single_market_preset() {
    let mut covered = 0;
    for name in presets::PRESET_NAMES {
        let toml = presets::preset_toml(name).unwrap();
        if !toml.contains("\n[market]\n") {
            continue; // markets lineup or portfolio preset
        }
        covered += 1;
        let ported = toml.replace("\n[market]\n", "\n[[portfolio]]\n");
        let mut a = ScenarioSpec::from_str(toml).unwrap();
        let mut b = ScenarioSpec::from_str(&ported)
            .unwrap_or_else(|e| panic!("{name} as portfolio: {e:#}"));
        assert!(
            b.portfolio.is_none(),
            "{name}: a default one-entry portfolio must lower to the \
             classic markets lineup"
        );
        assert_eq!(
            a.fingerprint(),
            b.fingerprint(),
            "{name}: degenerate portfolio changes the spec fingerprint"
        );
        reduce(&mut a);
        reduce(&mut b);
        let a = SpecScenario::new(a).unwrap();
        let b = SpecScenario::new(b).unwrap();
        for threads in [1, 8] {
            assert_eq!(
                digest(&a, threads),
                digest(&b, threads),
                "{name}: degenerate portfolio digest diverges at \
                 {threads} threads"
            );
        }
    }
    assert!(covered >= 3, "expected several single-[market] presets");
}

/// The two shipped portfolio-era presets run end to end and their
/// digests are thread-invariant (the RNG-stream-per-market contract).
#[test]
fn portfolio_presets_are_thread_invariant() {
    for name in ["portfolio_grid", "spot_replay"] {
        let mut spec = presets::spec(name).unwrap();
        reduce(&mut spec);
        let sc = SpecScenario::new(spec).unwrap();
        assert_eq!(
            digest(&sc, 1),
            digest(&sc, 8),
            "{name}: digest is thread-dependent"
        );
    }
}

/// The migrate strategy actually migrates on the shipped grid: its
/// checkpoint ledger is non-zero (each move bills checkpoint_cost_s),
/// while the pinned one_bid baseline's stays zero.
#[test]
fn portfolio_migration_is_billed_through_the_overhead_ledger() {
    let sc = presets::scenario("portfolio_grid").unwrap();
    let results = run_sweep(
        &sc,
        &SweepConfig { replicates: 2, seed: 7, threads: 2 },
    )
    .unwrap();
    let metrics = sc.spec().metrics.clone();
    let ck = metrics.iter().position(|m| m == "checkpoint_time").unwrap();
    let mut migrate_ck = 0.0;
    let mut one_bid_ck = 0.0;
    for p in &results.points {
        let mean = p.stats[ck].mean();
        if p.label.ends_with("/migrate") {
            migrate_ck += mean;
        } else {
            one_bid_ck += mean;
        }
    }
    assert!(
        migrate_ck > 0.0,
        "migrate never moved: checkpoint_time sum is {migrate_ck}"
    );
    assert_eq!(
        one_bid_ck, 0.0,
        "the single-market baseline must never checkpoint"
    );
}

/// `spot_replay` sweeps a committed fixture end to end with zero
/// scenario Rust: point space, labels and the replay point's series
/// all come straight from the TOML + CSV pair.
#[test]
fn spot_replay_runs_from_the_committed_fixture() {
    let sc = presets::scenario("spot_replay").unwrap();
    assert_eq!(sc.points(), 4);
    let results = run_sweep(
        &sc,
        &SweepConfig { replicates: 2, seed: 7, threads: 2 },
    )
    .unwrap();
    let labels: Vec<&str> =
        results.points.iter().map(|p| p.label.as_str()).collect();
    assert_eq!(
        labels,
        vec![
            "replay/one_bid",
            "replay/no_interruption",
            "synthetic/one_bid",
            "synthetic/no_interruption",
        ]
    );
    // every point simulated to a positive cost on finite iterations
    for p in &results.points {
        let cost = p.stats[0].mean(); // total_cost is the first metric
        assert!(cost > 0.0, "{}: no cost accrued", p.label);
    }
}

// ---------------------------------------------------------------
// Content-addressed trace identity (DESIGN.md §9 regression)
// ---------------------------------------------------------------

fn tracefile_spec(path: &str) -> String {
    format!(
        r#"
name = "trace_id"
strategies = ["one_bid"]
metrics = ["total_cost"]
[job]
n = 2
j = 200
[market]
kind = "tracefile"
path = "{path}"
cdf_resolution = 100.0
"#
    )
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(name)
}

/// Two paths to identical bytes fingerprint the same; editing the
/// bytes behind one path changes its fingerprint. This is the serve
/// daemon's cache-poisoning guard: a stale entry can never be served
/// for a mutated trace file.
#[test]
fn spec_fingerprint_hashes_trace_content_not_path() {
    let a = tmp("vsgd_port_id_a.csv");
    let b = tmp("vsgd_port_id_b.csv");
    fs::write(&a, "100,0.5\n200,0.6\n300,0.4\n").unwrap();
    fs::write(&b, "100,0.5\n200,0.6\n300,0.4\n").unwrap();
    let fp = |p: &PathBuf| {
        ScenarioSpec::from_str(&tracefile_spec(p.to_str().unwrap()))
            .unwrap()
            .fingerprint()
    };
    assert_eq!(
        fp(&a),
        fp(&b),
        "same bytes at different paths must share a fingerprint"
    );
    fs::write(&b, "100,0.5\n200,0.6\n300,0.9\n").unwrap();
    assert_ne!(
        fp(&a),
        fp(&b),
        "edited bytes at the same path must change the fingerprint"
    );
    let _ = fs::remove_file(&a);
    let _ = fs::remove_file(&b);
}

/// The legacy `kind = "trace"` + path market gets the same treatment:
/// its fingerprint follows the file content.
#[test]
fn legacy_trace_path_market_is_content_hashed_too() {
    let a = tmp("vsgd_port_legacy.csv");
    fs::write(&a, "t,p\n100,0.5\n200,0.6\n").unwrap();
    let spec_text = format!(
        r#"
name = "legacy"
strategies = ["one_bid"]
metrics = ["total_cost"]
[job]
n = 2
j = 200
[market]
kind = "trace"
path = "{}"
cdf_resolution = 100.0
"#,
        a.to_str().unwrap()
    );
    let fp1 = ScenarioSpec::from_str(&spec_text).unwrap().fingerprint();
    fs::write(&a, "t,p\n100,0.5\n200,0.9\n").unwrap();
    let fp2 = ScenarioSpec::from_str(&spec_text).unwrap().fingerprint();
    assert_ne!(fp1, fp2, "same path, different bytes, same fingerprint");
    let _ = fs::remove_file(&a);
}

/// Strict-loader error paths surface at spec parse (`--check`) time
/// with the offending detail named: unsorted rows, non-positive
/// prices, empty files, and unknown columns are all data errors.
#[test]
fn strict_loader_errors_surface_at_parse_time() {
    let cases: [(&str, &str, &str); 4] = [
        (
            "vsgd_port_unsorted.csv",
            "timestamp,price\n200,0.5\n100,0.6\n",
            "not strictly increasing",
        ),
        ("vsgd_port_negative.csv", "100,-0.5\n", "got -0.5"),
        ("vsgd_port_empty.csv", "", "empty trace file"),
        (
            "vsgd_port_columns.csv",
            "timestamp,price,zone\n100,0.5,us\n",
            "zone",
        ),
    ];
    for (name, content, needle) in cases {
        let p = tmp(name);
        fs::write(&p, content).unwrap();
        let err = ScenarioSpec::from_str(&tracefile_spec(
            p.to_str().unwrap(),
        ))
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains(needle),
            "{name}: expected '{needle}' in: {msg}"
        );
        let _ = fs::remove_file(&p);
    }
    // a missing file is a parse error too, not a mid-sweep surprise
    let err = ScenarioSpec::from_str(&tracefile_spec(
        "/nonexistent/vsgd_port_missing.csv",
    ))
    .unwrap_err();
    assert!(format!("{err:#}").contains("vsgd_port_missing.csv"));
}

// ---------------------------------------------------------------
// Spec-level guard rails
// ---------------------------------------------------------------

#[test]
fn portfolio_spec_guard_rails() {
    let base = r#"
name = "guard"
strategies = ["migrate"]
metrics = ["total_cost"]
[job]
n = 2
j = 200
[strategy.migrate]
kind = "portfolio_migrate"
"#;
    // portfolio_migrate without [[portfolio]] is rejected by name
    let single = format!(
        "{base}\n[market]\nkind = \"uniform\"\nlo = 0.2\nhi = 1.0\n"
    );
    let err = SpecScenario::new(ScenarioSpec::from_str(&single).unwrap())
        .unwrap_err();
    assert!(format!("{err:#}").contains("needs [[portfolio]]"));

    // [[portfolio]] + [market] in one spec is ambiguous
    let both = format!(
        "{single}\n[[portfolio]]\nkind = \"uniform\"\nlo = 0.2\nhi = 1.0\n"
    );
    let err = ScenarioSpec::from_str(&both).unwrap_err();
    assert!(format!("{err:#}").contains("declare one or the other"));

    // periodic checkpointing cannot combine with migration billing
    let ckpt = format!(
        "{base}\n[overhead]\ncheckpoint_every_iters = 5\n\
         checkpoint_cost_s = 1.0\n\
         [[portfolio]]\nkind = \"uniform\"\nlo = 0.2\nhi = 1.0\n\
         [[portfolio]]\nkind = \"uniform\"\nlo = 0.3\nhi = 1.2\nspeed = 1.5\n"
    );
    let err = SpecScenario::new(ScenarioSpec::from_str(&ckpt).unwrap())
        .unwrap_err();
    assert!(format!("{err:#}").contains("checkpoint_every_iters"));

    // market.* axes are reserved for classic specs
    let axis = r#"
name = "guard_axis"
strategies = ["migrate"]
axes = ["lo"]
metrics = ["total_cost"]
[job]
n = 2
j = 200
[strategy.migrate]
kind = "portfolio_migrate"
[[portfolio]]
kind = "uniform"
lo = 0.2
hi = 1.0
[[portfolio]]
kind = "uniform"
lo = 0.3
hi = 1.2
speed = 1.5
[axis.lo]
path = "market.lo"
values = [0.1, 0.2]
"#;
    let err = SpecScenario::new(ScenarioSpec::from_str(axis).unwrap())
        .unwrap_err();
    assert!(format!("{err:#}").contains("portfolio.<idx>"));
}

// ---------------------------------------------------------------
// Planner lattice support
// ---------------------------------------------------------------

/// A portfolio plan runs through the optimizer end to end, and no
/// portfolio candidate is ever analytically pruned — every non-folded
/// lattice point must reach the simulation ladder (heuristic fate),
/// because no single-market closed form describes a multi-market run.
#[test]
fn planner_simulates_portfolio_candidates_without_pruning() {
    let plan_text = r#"
name = "portfolio_plan"
seed = 7
strategies = ["one_bid", "migrate"]
axes = ["h"]

[objective]
goal = "min_cost"

[search]
ladder = [2]

[job]
n = 4
eps = 0.35
j = 400

[runtime]
kind = "exp"
lambda = 0.25
delta = 0.5

[overhead]
checkpoint_cost_s = 2.0
restart_delay_s = 6.0

[[portfolio]]
label = "cheap"
kind = "uniform"
lo = 0.2
hi = 1.0

[[portfolio]]
label = "fast"
kind = "uniform"
lo = 0.35
hi = 1.4
speed = 1.6
q = 0.05

[strategy.migrate]
kind = "portfolio_migrate"

[axis.h]
path = "strategy.migrate.hysteresis"
values = [0.0, 0.2]
"#;
    let plan = opt::PlanSpec::from_str(plan_text).unwrap();
    let outcome = opt::run_plan(
        &plan,
        &PlannerConfig { seed: 7, threads: 2 },
    )
    .unwrap();
    let counts = outcome.counts();
    assert_eq!(counts.infeasible + counts.dominated, 0,
        "portfolio candidates must never be analytically pruned");
    assert!(counts.evaluated >= 2, "lattice must reach simulation");
    assert!(outcome.incumbent.is_some());
    for c in &outcome.candidates {
        assert!(
            c.surface.is_none(),
            "{}: portfolio candidates have no closed-form surface",
            c.label
        );
    }
}
