//! Event-reactive policy presets end to end: both shipped policy
//! grids (`adaptive_grid`, `notice_grid`) run through the sweep
//! harness at 1 and 8 threads with identical digests — the DESIGN.md
//! §6 determinism contract for reactive runs (policies mutate state on
//! events but consume no RNG outside `decide`) — and their headline
//! behaviours hold: the elastic fleet's spend tracks its budget, the
//! rebid policy escapes repeated preemptions, and a notice window
//! covering the checkpoint cost eliminates lost work entirely.

use volatile_sgd::exp::presets;
use volatile_sgd::sweep::{run_sweep, SweepConfig};

fn collate(
    name: &str,
    threads: usize,
    seed: u64,
) -> volatile_sgd::sweep::SweepResults {
    let sc = presets::scenario(name).unwrap();
    run_sweep(&sc, &SweepConfig { replicates: 2, seed, threads }).unwrap()
}

#[test]
fn adaptive_grid_thread_deterministic_and_budget_scales_the_fleet() {
    let serial = collate("adaptive_grid", 1, 41);
    let par = collate("adaptive_grid", 8, 41);
    assert_eq!(serial.digest(), par.digest(), "threads must be pure");

    let idx = |name: &str| {
        serial
            .metric_names
            .iter()
            .position(|m| m == name)
            .unwrap_or_else(|| panic!("missing metric {name}"))
    };
    let mean = |p: usize, m: &str| serial.points[p].stats[idx(m)].mean();
    // layout: budget slowest, q, then strategy fastest — elastic points
    // are even indices. At fixed q, a larger budget admits a larger
    // fleet, so the elastic entry's spend grows with its budget.
    let elastic = |b: usize, q: usize| (b * 3 + q) * 2;
    assert!(
        mean(elastic(0, 0), "cost") < mean(elastic(3, 0), "cost"),
        "an 8x budget must buy a visibly larger fleet"
    );
    // the elastic fleet never idles into the deadline: it completes its
    // full iteration budget at every grid point
    for b in 0..4 {
        for q in 0..3 {
            assert_eq!(
                mean(elastic(b, q), "iters"),
                10_000.0,
                "elastic budget={b} q={q}"
            );
        }
    }
    // the static Theorem-2 baseline ignores both axes but still runs
    // at every point of the comparison grid
    for p in (1..serial.points.len()).step_by(2) {
        assert!(mean(p, "iters") > 0.0, "one_bid point {p}");
    }
}

#[test]
fn notice_grid_thread_deterministic_and_notice_eliminates_lost_work() {
    let serial = collate("notice_grid", 1, 42);
    let par = collate("notice_grid", 8, 42);
    assert_eq!(serial.digest(), par.digest(), "threads must be pure");

    let idx = |name: &str| {
        serial
            .metric_names
            .iter()
            .position(|m| m == name)
            .unwrap_or_else(|| panic!("missing metric {name}"))
    };
    let mean = |p: usize, m: &str| serial.points[p].stats[idx(m)].mean();
    // layout: notice slowest, factor, then strategy (rebid, then
    // checkpoint_only) fastest
    let point = |notice: usize, factor: usize, strat: usize| {
        (notice * 3 + factor) * 2 + strat
    };
    // with no notice, the reactive policy escapes preemptions by
    // rebidding while the checkpoint-only baseline keeps getting cut
    // and recomputing
    for factor in 0..3 {
        let rebid = point(0, factor, 0);
        let ckpt = point(0, factor, 1);
        assert!(
            mean(rebid, "preempt_events") < mean(ckpt, "preempt_events"),
            "factor {factor}: rebidding must reduce interruptions"
        );
        assert!(
            mean(rebid, "lost_iters") < mean(ckpt, "lost_iters"),
            "factor {factor}: rebidding must reduce recomputation"
        );
    }
    // a notice window covering the checkpoint cost (30 s >= 10 s)
    // emergency-saves on every preemption: zero lost work, exactly,
    // for both strategies at every factor
    for factor in 0..3 {
        for strat in 0..2 {
            assert_eq!(
                mean(point(2, factor, strat), "lost_iters"),
                0.0,
                "covered notice must save all work (f={factor} s={strat})"
            );
        }
    }
    // the ledger stays coherent: checkpoints are billed wherever
    // periodic checkpointing is on
    for p in 0..serial.points.len() {
        assert!(mean(p, "checkpoint_time") > 0.0, "point {p}");
        assert!(mean(p, "iters") > 0.0, "point {p}");
    }
}
