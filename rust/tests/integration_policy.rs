//! Event-reactive policy presets end to end: both shipped policy
//! grids (`adaptive_grid`, `notice_grid`) run through the sweep
//! harness at 1 and 8 threads with identical digests — the DESIGN.md
//! §6 determinism contract for reactive runs (policies mutate state on
//! events but consume no RNG outside `decide`) — and their headline
//! behaviours hold: the elastic fleet's spend tracks its budget, the
//! rebid policy escapes repeated preemptions, and a notice window
//! covering the checkpoint cost eliminates lost work entirely.

use volatile_sgd::exp::{presets, ScenarioSpec, SpecScenario};
use volatile_sgd::sweep::{run_sweep, SweepConfig};

fn collate(
    name: &str,
    threads: usize,
    seed: u64,
) -> volatile_sgd::sweep::SweepResults {
    let sc = presets::scenario(name).unwrap();
    run_sweep(&sc, &SweepConfig { replicates: 2, seed, threads }).unwrap()
}

#[test]
fn adaptive_grid_thread_deterministic_and_budget_scales_the_fleet() {
    let serial = collate("adaptive_grid", 1, 41);
    let par = collate("adaptive_grid", 8, 41);
    assert_eq!(serial.digest(), par.digest(), "threads must be pure");

    let idx = |name: &str| {
        serial
            .metric_names
            .iter()
            .position(|m| m == name)
            .unwrap_or_else(|| panic!("missing metric {name}"))
    };
    let mean = |p: usize, m: &str| serial.points[p].stats[idx(m)].mean();
    // layout: budget slowest, q, then strategy fastest — elastic points
    // are even indices. At fixed q, a larger budget admits a larger
    // fleet, so the elastic entry's spend grows with its budget.
    let elastic = |b: usize, q: usize| (b * 3 + q) * 2;
    assert!(
        mean(elastic(0, 0), "cost") < mean(elastic(3, 0), "cost"),
        "an 8x budget must buy a visibly larger fleet"
    );
    // the elastic fleet never idles into the deadline: it completes its
    // full iteration budget at every grid point
    for b in 0..4 {
        for q in 0..3 {
            assert_eq!(
                mean(elastic(b, q), "iters"),
                10_000.0,
                "elastic budget={b} q={q}"
            );
        }
    }
    // the static Theorem-2 baseline ignores both axes but still runs
    // at every point of the comparison grid
    for p in (1..serial.points.len()).step_by(2) {
        assert!(mean(p, "iters") > 0.0, "one_bid point {p}");
    }
}

#[test]
fn notice_grid_thread_deterministic_and_notice_eliminates_lost_work() {
    let serial = collate("notice_grid", 1, 42);
    let par = collate("notice_grid", 8, 42);
    assert_eq!(serial.digest(), par.digest(), "threads must be pure");

    let idx = |name: &str| {
        serial
            .metric_names
            .iter()
            .position(|m| m == name)
            .unwrap_or_else(|| panic!("missing metric {name}"))
    };
    let mean = |p: usize, m: &str| serial.points[p].stats[idx(m)].mean();
    // layout: notice slowest, factor, then strategy (rebid, then
    // checkpoint_only) fastest
    let point = |notice: usize, factor: usize, strat: usize| {
        (notice * 3 + factor) * 2 + strat
    };
    // with no notice, the reactive policy escapes preemptions by
    // rebidding while the checkpoint-only baseline keeps getting cut
    // and recomputing
    for factor in 0..3 {
        let rebid = point(0, factor, 0);
        let ckpt = point(0, factor, 1);
        assert!(
            mean(rebid, "preempt_events") < mean(ckpt, "preempt_events"),
            "factor {factor}: rebidding must reduce interruptions"
        );
        assert!(
            mean(rebid, "lost_iters") < mean(ckpt, "lost_iters"),
            "factor {factor}: rebidding must reduce recomputation"
        );
    }
    // a notice window covering the checkpoint cost (30 s >= 10 s)
    // emergency-saves on every preemption: zero lost work, exactly,
    // for both strategies at every factor
    for factor in 0..3 {
        for strat in 0..2 {
            assert_eq!(
                mean(point(2, factor, strat), "lost_iters"),
                0.0,
                "covered notice must save all work (f={factor} s={strat})"
            );
        }
    }
    // the ledger stays coherent: checkpoints are billed wherever
    // periodic checkpointing is on
    for p in 0..serial.points.len() {
        assert!(mean(p, "checkpoint_time") > 0.0, "point {p}");
        assert!(mean(p, "iters") > 0.0, "point {p}");
    }
}

// ---------------------------------------------------------------
// Trace-driven behavioral headlines (the shipped policy grids above
// run on synthetic closed-form markets only; this pins the same
// event-reactive semantics against a committed EC2 fixture)
// ---------------------------------------------------------------

/// NoticeRebid + ElasticFleet against the committed c5.xlarge spot
/// history, under the full overhead model.
const TRACE_POLICIES: &str = r#"
name = "policy_replay"
strategies = ["rebid", "elastic", "one_bid"]
metrics = ["total_cost", "iters", "preempt_events", "lost_iters", "checkpoint_time"]

[job]
n = 8
eps = 0.35
j = 4000
preempt_q = 0.4

[runtime]
kind = "exp"
lambda = 0.25
delta = 0.5

[overhead]
checkpoint_every_iters = 4
checkpoint_cost_s = 10.0
restart_delay_s = 30.0
lost_work_on_preempt = true
preempt_notice_s = 30.0

[market]
kind = "tracefile"
path = "examples/traces/ec2_c5xlarge_uswest2a.csv"
resample_s = 3600.0
cdf_resolution = 900.0

[strategy.rebid]
kind = "notice_rebid"
rebid_factor = 1.5

[strategy.elastic]
kind = "elastic_fleet"
budget_rate = 1.2

[strategy.one_bid]
kind = "one_bid"
"#;

/// The notice-window and elastic-fleet headlines survive the move
/// from closed-form markets to a recorded price history: a notice
/// covering the checkpoint cost still eliminates lost work *exactly*,
/// the elastic fleet still completes its full iteration budget, and
/// the digest stays thread-invariant on the trace-driven run.
#[test]
fn trace_replay_policies_hold_their_headlines() {
    let sc =
        SpecScenario::new(ScenarioSpec::from_str(TRACE_POLICIES).unwrap())
            .unwrap();
    let base = SweepConfig { replicates: 2, seed: 13, threads: 1 };
    let serial = run_sweep(&sc, &base).unwrap();
    let par =
        run_sweep(&sc, &SweepConfig { threads: 8, ..base }).unwrap();
    assert_eq!(serial.digest(), par.digest(), "threads must be pure");

    let idx = |name: &str| {
        serial.metric_names.iter().position(|m| m == name).unwrap()
    };
    let mean = |p: usize, m: &str| serial.points[p].stats[idx(m)].mean();
    // point order follows the lineup: rebid, elastic, one_bid
    for (p, label) in ["rebid", "elastic", "one_bid"].iter().enumerate() {
        assert_eq!(serial.points[p].label, *label);
        // q = 0.4 on 8 workers: the fixture run is interruption-heavy
        assert!(mean(p, "preempt_events") > 0.0, "{label}");
        // 30 s notice >= 10 s checkpoint: every preemption
        // emergency-saves, so no iteration is ever recomputed
        assert_eq!(
            mean(p, "lost_iters"),
            0.0,
            "{label}: a covered notice must save all work"
        );
        assert!(mean(p, "total_cost") > 0.0, "{label}");
    }
    // the elastic fleet never idles into a stall: it completes its
    // full iteration budget on the recorded history too
    assert_eq!(mean(1, "iters"), 4000.0, "elastic must finish the job");

    // with the notice window gone, the checkpoint-only baseline loses
    // uncheckpointed work on the very same fixture
    let uncovered =
        TRACE_POLICIES.replace("preempt_notice_s = 30.0", "");
    let sc =
        SpecScenario::new(ScenarioSpec::from_str(&uncovered).unwrap())
            .unwrap();
    let bare = run_sweep(&sc, &base).unwrap();
    assert!(
        bare.points[2].stats[idx("lost_iters")].mean() > 0.0,
        "one_bid with no notice must recompute lost work"
    );
}

/// Strict `--check` error paths for the forecaster keys (DESIGN.md
/// §11): bad values are rejected at parse time with the offending
/// strategy named, and a misspelled key is rejected *by table path*.
#[test]
fn forecaster_keys_fail_strict_check_by_name() {
    let base = r#"
name = "forecast_check"
strategies = ["proactive", "lookahead"]
metrics = ["total_cost"]

[job]
n = 4
j = 400

[[portfolio]]
label = "home"
kind = "uniform"
lo = 0.2
hi = 1.0

[[portfolio]]
label = "away"
kind = "uniform"
lo = 0.1
hi = 0.6
q = 0.2

[strategy.proactive]
kind = "proactive_migrate"
window = 48
horizon_s = 300.0
smoothing = 1.0

[strategy.lookahead]
kind = "lookahead_bid"
window = 32
innovation_threshold = 3.0
"#;
    assert!(ScenarioSpec::from_str(base).is_ok());
    for (needle, replacement, expect) in [
        ("window = 48", "window = -3", "window"),
        ("window = 32", "window = 0", "window"),
        ("horizon_s = 300.0", "horizon_s = 0.0", "horizon_s"),
        ("horizon_s = 300.0", "horizon_s = -1.0", "horizon_s"),
        ("smoothing = 1.0", "smoothing = -0.5", "smoothing"),
        (
            "innovation_threshold = 3.0",
            "innovation_threshold = 0.0",
            "innovation_threshold",
        ),
    ] {
        let bad = base.replace(needle, replacement);
        assert_ne!(bad, base, "needle '{needle}' not found");
        let err = format!("{:#}", ScenarioSpec::from_str(&bad).unwrap_err());
        assert!(
            err.contains(expect),
            "'{replacement}' should fail --check naming '{expect}', \
             got: {err}"
        );
    }
    // a misspelled forecaster key is named by its full table path
    let bad = base.replace("smoothing = 1.0", "smoothign = 1.0");
    let err = format!("{:#}", ScenarioSpec::from_str(&bad).unwrap_err());
    assert!(err.contains("smoothign"), "{err}");
    assert!(err.contains("in table [strategy.proactive]"), "{err}");
}
