//! PJRT integration: real artifact execution. These tests require
//! `make artifacts` to have run; they are skipped (pass vacuously, with a
//! note) when artifacts/ is absent so `cargo test` works on a fresh
//! checkout. The whole file is additionally compile-gated on the `pjrt`
//! feature: without it the engine is a stub whose `cpu()` always errors,
//! and a checkout that *does* have artifacts would otherwise panic here
//! instead of skipping.
#![cfg(feature = "pjrt")]

use volatile_sgd::coordinator::backend::{RealBackend, TrainingBackend};
use volatile_sgd::data::CifarLike;
use volatile_sgd::manifest::Manifest;
use volatile_sgd::runtime::{BatchInput, ModelRuntime, PjrtEngine};
use volatile_sgd::util::rng::Rng;

fn artifacts() -> Option<Manifest> {
    Manifest::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")).ok()
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(m) => m,
            None => {
                eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
                return;
            }
        }
    };
}

fn cnn_batch(
    mm: &volatile_sgd::manifest::ModelManifest,
    seed: u64,
) -> (Vec<f32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let data = CifarLike::generate(64, 1.0, &mut rng);
    let idx: Vec<usize> = (0..mm.batch()).collect();
    let (mut xs, mut ys) = (Vec::new(), Vec::new());
    data.gather(&idx, &mut xs, &mut ys);
    (xs, ys)
}

#[test]
fn grad_and_eval_agree_on_loss() {
    let manifest = require_artifacts!();
    let engine = PjrtEngine::cpu().unwrap();
    let mm = manifest.model("cnn").unwrap();
    let rt = ModelRuntime::load(&engine, mm).unwrap();
    let theta = mm.load_theta0().unwrap();
    let (xs, ys) = cnn_batch(mm, 1);
    let mut grad = vec![0f32; mm.d];
    let g = rt
        .grad_step(&theta, BatchInput::F32(&xs), &ys, &mut grad)
        .unwrap();
    let e = rt.eval_step(&theta, BatchInput::F32(&xs), &ys).unwrap();
    assert!((g.loss - e.loss).abs() < 1e-4, "{} vs {}", g.loss, e.loss);
    assert_eq!(g.correct, e.correct);
    // gradient is non-trivial and finite
    let norm: f64 = grad.iter().map(|&x| (x as f64) * (x as f64)).sum();
    assert!(norm.is_finite() && norm > 1e-6, "grad norm {norm}");
}

#[test]
fn apply_artifact_matches_native_update() {
    let manifest = require_artifacts!();
    let engine = PjrtEngine::cpu().unwrap();
    let mm = manifest.model("cnn").unwrap();
    let rt = ModelRuntime::load(&engine, mm).unwrap();
    let theta0 = mm.load_theta0().unwrap();
    let (xs, ys) = cnn_batch(mm, 2);
    let mut grad = vec![0f32; mm.d];
    rt.grad_step(&theta0, BatchInput::F32(&xs), &ys, &mut grad)
        .unwrap();

    // pallas sgd_update artifact
    let mut via_artifact = theta0.clone();
    rt.apply_step(&mut via_artifact, &grad, 0.05).unwrap();
    // native fused update
    let mut acc =
        volatile_sgd::coordinator::GradAccumulator::new(mm.d);
    acc.add(&grad);
    let mut via_native = theta0.clone();
    acc.apply_into(&mut via_native, 0.05);

    let max_diff = via_artifact
        .iter()
        .zip(&via_native)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-5, "pallas vs native update diff {max_diff}");
}

#[test]
fn gradient_descends_the_loss() {
    // one SGD step on a fixed batch must reduce that batch's loss
    let manifest = require_artifacts!();
    let engine = PjrtEngine::cpu().unwrap();
    let mm = manifest.model("cnn").unwrap();
    let rt = ModelRuntime::load(&engine, mm).unwrap();
    let mut theta = mm.load_theta0().unwrap();
    let (xs, ys) = cnn_batch(mm, 3);
    let mut grad = vec![0f32; mm.d];
    let before = rt
        .grad_step(&theta, BatchInput::F32(&xs), &ys, &mut grad)
        .unwrap();
    rt.apply_step(&mut theta, &grad, 0.001).unwrap();
    let after = rt.eval_step(&theta, BatchInput::F32(&xs), &ys).unwrap();
    assert!(
        after.loss < before.loss,
        "loss should drop: {} -> {}",
        before.loss,
        after.loss
    );
}

#[test]
fn real_training_loss_decreases_with_volatile_workers() {
    let manifest = require_artifacts!();
    let engine = PjrtEngine::cpu().unwrap();
    let mm = manifest.model("cnn").unwrap();
    let rt = ModelRuntime::load(&engine, mm).unwrap();
    let theta0 = mm.load_theta0().unwrap();
    let mut rng = Rng::new(4);
    let data = CifarLike::generate(1_024, 1.0, &mut rng.split(1));
    let mut backend =
        RealBackend::new(&rt, theta0, 0.05, data, 4, &mut rng);
    let mut first = f64::NAN;
    let mut rng2 = Rng::new(5);
    for i in 0..40 {
        // volatile worker count: alternate 1..4 active
        let y = 1 + (i % 4);
        let s = backend.step(y, &mut rng2).unwrap();
        if first.is_nan() {
            first = s.error;
        }
    }
    let last = backend.error();
    assert!(
        last < first * 0.7,
        "EMA loss should drop >30%: {first} -> {last}"
    );
}

#[test]
fn lm_artifacts_execute() {
    let manifest = require_artifacts!();
    let Ok(mm) = manifest.model("lm_tiny") else {
        eprintln!("skipping: lm_tiny not exported");
        return;
    };
    let engine = PjrtEngine::cpu().unwrap();
    let rt = ModelRuntime::load(&engine, mm).unwrap();
    let theta = mm.load_theta0().unwrap();
    let mut rng = Rng::new(6);
    let corpus = volatile_sgd::data::MarkovCorpus::generate(
        10_000, 256, 4, &mut rng,
    );
    let (b, t) = (mm.input_shape[0], mm.input_shape[1]);
    let (mut xs, mut ys) = (Vec::new(), Vec::new());
    corpus.batch(b, t, &mut rng, &mut xs, &mut ys);
    let mut grad = vec![0f32; mm.d];
    let s = rt
        .grad_step(&theta, BatchInput::I32(&xs), &ys, &mut grad)
        .unwrap();
    // fresh init: loss ~ ln(256)
    assert!((s.loss - 5.545).abs() < 0.5, "lm init loss {}", s.loss);
    assert!(grad.iter().any(|&g| g != 0.0));
}

#[test]
fn batch_shape_mismatches_are_rejected() {
    let manifest = require_artifacts!();
    let engine = PjrtEngine::cpu().unwrap();
    let mm = manifest.model("cnn").unwrap();
    let rt = ModelRuntime::load(&engine, mm).unwrap();
    let theta = mm.load_theta0().unwrap();
    let mut grad = vec![0f32; mm.d];
    // wrong x length
    assert!(rt
        .grad_step(&theta, BatchInput::F32(&[0.0; 7]), &[0; 32], &mut grad)
        .is_err());
    // wrong dtype
    let (xs, _) = cnn_batch(mm, 7);
    let _ = xs;
    assert!(rt
        .grad_step(
            &theta,
            BatchInput::I32(&vec![0i32; 32 * 3072]),
            &[0; 32],
            &mut grad
        )
        .is_err());
    // wrong theta length
    assert!(rt
        .grad_step(
            &theta[..100],
            BatchInput::F32(&vec![0f32; 32 * 3072]),
            &[0; 32],
            &mut grad
        )
        .is_err());
}
