//! # volatile-sgd
//!
//! Reproduction of **"Machine Learning on Volatile Instances"** (Zhang,
//! Wang, Joshi, Joe-Wong — INFOCOM 2020): cost-optimal distributed
//! synchronous SGD on spot / preemptible cloud instances.
//!
//! The crate is the Layer-3 **rust coordinator** of a three-layer stack:
//! JAX/Pallas author the model + kernels at build time (`python/compile`),
//! `make artifacts` lowers them once to HLO-text artifacts, and this crate
//! loads and executes them via the PJRT C API — Python is never on the
//! training path.
//!
//! Map of the crate (see DESIGN.md for the paper-to-module index):
//!
//! * [`market`] — spot-price processes, empirical CDFs, trace replay, bids;
//! * [`preempt`] — GCP/Azure-style preemption models + exact E[1/y];
//! * [`theory`] — Theorems 1–5 and Corollary 1 as executable solvers;
//! * [`coordinator`] — parameter server, gradient aggregation, scheduler,
//!   strategies;
//! * [`sim`] — virtual-clock cost/time accounting and the
//!   discrete-event engine (typed events, reactive policies, observer
//!   hooks, checkpoint/restart overhead);
//! * [`sweep`] — parallel deterministic sweep harness (grids, replicates,
//!   work-stealing pool, Welford collation);
//! * [`runtime`] — PJRT bridge to the AOT artifacts;
//! * [`data`] — synthetic CIFAR-like images + Markov corpus;
//! * [`exp`] — per-figure experiment harnesses (Figs. 1–5) plus the
//!   declarative scenario-spec API (`exp::spec`, `exp::presets`): any
//!   sweep as a TOML file driven by one generic `Scenario`;
//! * [`opt`] — the strategy planner: analytic Theorem-2/3 pruning over
//!   a candidate lattice, successive-halving simulation refinement,
//!   ranked recommendations + Pareto frontier (`volatile-sgd
//!   optimize`);
//! * [`obs`] — the unified telemetry layer: metric registry
//!   (counters/gauges/log2 histograms), structured JSONL run tracing
//!   (`--trace-out`), per-stage timing spans, and Prometheus text
//!   exposition (`stats --prom`) — RNG-free and digest-neutral by
//!   construction;
//! * [`serve`] — planner-as-a-service: a resident daemon (`volatile-sgd
//!   serve`) with a newline-delimited JSON protocol, a FIFO admission
//!   queue onto one shared pool, and a two-tier content-addressed warm
//!   cache (finished reports + prepared per-point artifacts);
//! * [`config`], [`manifest`], [`metrics`], [`util`] — substrates.

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod manifest;
pub mod market;
pub mod metrics;
pub mod obs;
pub mod opt;
pub mod preempt;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod sweep;
pub mod theory;
pub mod util;
