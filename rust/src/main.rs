//! `volatile-sgd` — the leader binary.
//!
//! ```text
//! volatile-sgd info        [--artifacts DIR]
//! volatile-sgd train       [--model cnn] [--iters 200] [--workers 4] [--lr 0.05]
//! volatile-sgd simulate    [--config FILE] [--strategy one_bid|two_bids|...]
//!                          [--checkpoint-every N] [--checkpoint-cost S]
//!                          [--restart-delay S] [--lost-work]
//!                          [--rebid-factor X] [--budget-rate X]
//!                          [--escalate-threshold X] [--trace-out FILE]
//! volatile-sgd optimal-bid [--market uniform|gaussian] [--n 8] [--n1 4]
//!                          [--eps 0.35] [--theta 120000] [--two-bids]
//! volatile-sgd plan-workers [--eps 0.1] [--q 0.5] [--chi 1.0] [--theta-iters 40000]
//! volatile-sgd fig2|fig3|fig4|fig5  [--out out/] [--threads N]
//! volatile-sgd sweep       [--spec FILE | --preset fig2..fig5|checkpoint_grid
//!                           |adaptive_grid|notice_grid | --fig 2|3|4|5]
//!                          [--threads N] [--replicates R] [--seed S] [--j J]
//!                          [--out DIR|results.csv] [--json [FILE]] [--check]
//!                          [--no-batch] [--trace-out FILE]
//! volatile-sgd trace-check --file FILE
//! volatile-sgd optimize    [--spec FILE] [--threads N] [--seed S]
//!                          [--out DIR|results.csv] [--json [FILE]] [--check]
//! volatile-sgd serve       [--listen 127.0.0.1:2020] [--threads N] [--check]
//! volatile-sgd submit      [--addr HOST:PORT] [--preset NAME | --spec FILE]
//!                          [--kind sweep|optimize] [--seed S]
//!                          [--replicates R] [--j J] [--wait]
//!                          [--timeout SECS] [--out FILE]
//! volatile-sgd status      [--addr HOST:PORT] --job N
//! volatile-sgd result      [--addr HOST:PORT] --job N [--out FILE]
//! volatile-sgd stats       [--addr HOST:PORT] [--prom]
//! volatile-sgd shutdown    [--addr HOST:PORT]
//! ```
//!
//! `sweep` is the one entry point for every scenario: a spec file
//! (`--spec`), a shipped preset (`--preset`, also reachable as the
//! legacy `--fig N`), same schema either way — see DESIGN.md §4.
//! `optimize` is the planner on top of it: a scenario spec plus
//! `[objective]`/`[search]` tables (DESIGN.md §7; the shipped preset
//! `examples/configs/optimize_deadline.toml` runs when `--spec` is
//! omitted). `serve` keeps the same machinery resident: a daemon with a
//! two-tier content-addressed warm cache and one shared pool, driven by
//! the `submit`/`status`/`result`/`stats`/`shutdown` client subcommands
//! over newline-delimited JSON (DESIGN.md §9). `--trace-out FILE` (on
//! `sweep` and `simulate`) exports the engine's observer event stream
//! plus per-stage timing spans as schema-documented JSONL;
//! `trace-check` validates such a file; `stats --prom` fetches the
//! daemon's metrics as Prometheus text exposition (DESIGN.md §12 —
//! telemetry never perturbs a digest). `--threads`
//! parallelises the simulation jobs on the
//! work-stealing sweep pool — `0` (or omitting the flag) uses every
//! available core; results are bit-identical at any thread count
//! (every job's RNG is a pure function of its job identity — see
//! DESIGN.md §3).
//!
//! Python is never invoked here: `train` runs the AOT artifacts over PJRT.

use anyhow::{bail, Context, Result};

use volatile_sgd::cli::Args;
use volatile_sgd::config::{ExperimentConfig, StrategyKind};
use volatile_sgd::coordinator::backend::{RealBackend, TrainingBackend};
use volatile_sgd::data::CifarLike;
use volatile_sgd::exp;
use volatile_sgd::exp::{PlanInputs, PlannedStrategy, ScenarioSpec};
use volatile_sgd::manifest::Manifest;
use volatile_sgd::market::PriceModel;
use volatile_sgd::runtime::{ModelRuntime, PjrtEngine};
use volatile_sgd::obs::{meta_line, validate_trace, TraceObs, TraceSink};
use volatile_sgd::sim::{Observer, PriceSource};
use volatile_sgd::sweep::Scenario;
use volatile_sgd::theory::bids::BidProblem;
use volatile_sgd::theory::bounds::{ErrorBound, SgdHyper};
use volatile_sgd::theory::runtime_model::RuntimeModel;
use volatile_sgd::theory::workers::WorkerProblem;
use volatile_sgd::util::rng::Rng;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_help();
        return;
    }
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "volatile-sgd — distributed SGD on volatile instances \
         (Zhang et al., INFOCOM 2020 reproduction)\n\n\
         subcommands:\n  \
         info          show artifacts / platform\n  \
         train         real PJRT training on the synthetic dataset\n  \
         simulate      run one strategy or event-reactive policy from a\n                \
         config via the event engine ([overhead] checkpoint/\n                \
         restart model; --checkpoint-every/--checkpoint-cost/\n                \
         --restart-delay/--lost-work override it; policy knobs:\n                \
         --rebid-factor/--budget-rate/--escalate-threshold)\n  \
         optimal-bid   Theorem 2 / Theorem 3 bid calculator\n  \
         plan-workers  Theorem 4 / Theorem 5 provisioning planner\n  \
         fig2..fig5    regenerate the paper's figures (CSV + summary)\n  \
         sweep         replicated Monte-Carlo sweep of a declarative\n                \
         scenario spec (--spec file.toml | --preset fig2..fig5,\n                \
         checkpoint_grid, adaptive_grid, notice_grid | --fig N;\n                \
         --out results.csv / --json for machine-readable output;\n                \
         --check validates without running; deterministic for a\n                \
         fixed --seed at any --threads; --threads 0 or omitted\n                \
         = all cores; --trace-out FILE exports the run as\n                \
         structured JSONL without perturbing the digest)\n  \
         trace-check   validate a --trace-out JSONL file (--file FILE):\n                \
         strict parse, schema, monotone per-replicate sim\n                \
         time; prints event/span tallies\n  \
         optimize      strategy planner: analytic Theorem-2/3 pruning\n                \
         over a candidate lattice + successive-halving\n                \
         simulation refinement; ranked recommendations and\n                \
         the Pareto frontier over (cost, time, error)\n                \
         (--spec plan.toml with [objective]/[search] tables,\n                \
         default: the shipped optimize_deadline preset;\n                \
         --out/--json/--check/--seed/--threads as in sweep)\n  \
         serve         resident planner service: sweep/optimize\n                \
         submissions over newline-delimited JSON, one shared\n                \
         pool, two-tier content-addressed warm cache\n                \
         (--listen 127.0.0.1:2020; --check validates the\n                \
         listener and every shipped preset without binding)\n  \
         submit        send a spec to a running daemon (--preset NAME\n                \
         | --spec FILE; --seed/--replicates/--j as in sweep;\n                \
         --wait polls and prints the offline-identical\n                \
         digest line; --out FILE saves the result)\n  \
         status|result poll a submitted job / fetch its report\n                \
         (--job N)\n  \
         stats         service counters: cache hit rates per tier,\n                \
         queue depth, jobs/sec (--prom: Prometheus text\n                \
         exposition with per-job latency histograms)\n  \
         shutdown      ask the daemon to drain and exit\n"
    );
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "info" => cmd_info(&args),
        "train" => cmd_train(&args),
        "simulate" => cmd_simulate(&args),
        "optimal-bid" => cmd_optimal_bid(&args),
        "plan-workers" => cmd_plan_workers(&args),
        "fig2" => cmd_fig2(&args),
        "fig3" => cmd_fig3(&args),
        "fig4" => cmd_fig4(&args),
        "fig5" => cmd_fig5(&args),
        "sweep" => cmd_sweep(&args),
        "trace-check" => cmd_trace_check(&args),
        "optimize" => cmd_optimize(&args),
        "serve" => cmd_serve(&args),
        "submit" => cmd_submit(&args),
        "status" => cmd_job_query(&args, "status"),
        "result" => cmd_job_query(&args, "result"),
        "stats" => cmd_stats(&args),
        "shutdown" => cmd_shutdown(&args),
        "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try 'help')"),
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.str("artifacts", "artifacts");
    let engine = PjrtEngine::cpu()?;
    println!("PJRT platform: {}", engine.platform());
    let manifest = Manifest::load(&dir).with_context(|| {
        format!("loading {dir}/manifest.txt — run `make artifacts`")
    })?;
    let mut names: Vec<_> = manifest.models.keys().collect();
    names.sort();
    for name in names {
        let m = &manifest.models[name];
        println!(
            "model {name}: d={} input={:?} ({}) labels={:?} layers={}",
            m.d,
            m.input_shape,
            m.input_dtype,
            m.label_shape,
            m.layers.len()
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let dir = args.str("artifacts", "artifacts");
    let model = args.str("model", "cnn");
    let iters = args.u64("iters", 200)?;
    let workers = args.usize("workers", 4)?;
    let lr = args.f64("lr", 0.05)? as f32;
    let seed = args.u64("seed", 42)?;
    if model != "cnn" {
        bail!("`train` drives the CNN workload; use examples/e2e_train for the LM");
    }

    let manifest = Manifest::load(&dir)?;
    let mm = manifest.model(&model)?;
    let engine = PjrtEngine::cpu()?;
    println!("compiling {model} artifacts on {} ...", engine.platform());
    let rt = ModelRuntime::load(&engine, mm)?;
    let theta0 = mm.load_theta0()?;

    let mut rng = Rng::new(seed);
    let data = CifarLike::generate(4_096, 1.0, &mut rng.split(1));
    let mut backend =
        RealBackend::new(&rt, theta0, lr, data, workers, &mut rng);
    println!(
        "training: {iters} iters x {workers} workers (batch {})",
        mm.batch()
    );
    let t0 = std::time::Instant::now();
    for i in 1..=iters {
        let stats = backend.step(workers, &mut rng)?;
        if i % 20 == 0 || i == iters {
            println!(
                "iter {i:>5}  loss(ema)={:.4}  acc(ema)={:.4}  [{:.1} ms/iter]",
                stats.error,
                stats.accuracy,
                t0.elapsed().as_secs_f64() * 1e3 / i as f64
            );
        }
    }
    let eval = backend.evaluate(1_024)?;
    println!(
        "held-in eval: loss={:.4} accuracy={:.4}",
        eval.error, eval.accuracy
    );
    Ok(())
}

fn describe_plan(plan: &PlannedStrategy) {
    match plan {
        PlannedStrategy::Fixed { name, bids, j } => println!(
            "plan {name}: J={j}  bids b1={:.4} (n1={}) b2={:.4}",
            bids.b1, bids.n1, bids.b2
        ),
        PlannedStrategy::Dynamic { name, stages, j, .. } => {
            println!("plan {name}: J={j}  {} stages", stages.len())
        }
        PlannedStrategy::StaticWorkers { name, n, j, unit_price, .. } => {
            println!("plan {name}: n={n}  J={j}  ${unit_price}/worker/t")
        }
        PlannedStrategy::DynamicWorkers { name, eta, j, .. } => {
            println!("plan {name}: eta={eta}  J'={j}")
        }
        PlannedStrategy::NoticeRebid {
            name, bids, j, rebid_factor, ..
        } => println!(
            "plan {name}: J={j}  base bid {:.4}  rebid x{rebid_factor} on \
             preemption",
            bids.b1
        ),
        PlannedStrategy::ElasticFleet { name, j, table, budget_rate } => {
            println!(
                "plan {name}: J={j}  fleet 1..={}  budget \
                 ${budget_rate}/unit-time",
                table.n_max()
            )
        }
        PlannedStrategy::DeadlineAware { name, bids, j, threshold, .. } => {
            println!(
                "plan {name}: J={j}  bid {:.4}  escalate below {threshold}",
                bids.b1
            )
        }
        PlannedStrategy::PortfolioMigrate { name, n, j, hysteresis } => {
            println!(
                "plan {name}: n={n}  J={j}  migrate on effective price \
                 (hysteresis {hysteresis})"
            )
        }
        PlannedStrategy::ProactiveMigrate {
            name,
            n,
            j,
            hysteresis,
            window,
            horizon_s,
            ..
        } => println!(
            "plan {name}: n={n}  J={j}  migrate on forecast score \
             (window {window}, horizon {horizon_s}s, hysteresis \
             {hysteresis})"
        ),
        PlannedStrategy::LookaheadBid {
            name,
            bids,
            j,
            window,
            innovation_threshold,
            ..
        } => println!(
            "plan {name}: J={j}  base bid {:.4}  rescaled by EWMA level \
             (window {window}, regime threshold {innovation_threshold})",
            bids.b1
        ),
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_file(path)?,
        None => ExperimentConfig::from_str("")?,
    };
    // --strategy overrides the config; both route through the one
    // shared StrategyKind -> PlannedStrategy build path
    let mut kind = match args.get("strategy") {
        Some(name) => StrategyKind::from_name(name, cfg.n)?,
        None => cfg.strategy.clone(),
    };
    if args.get("n1").is_some() {
        let v = args.usize("n1", 0)?;
        match &mut kind {
            StrategyKind::TwoBids { n1 }
            | StrategyKind::BidFractions { n1, .. }
            | StrategyKind::DynamicBids { n1, .. } => *n1 = v,
            _ => bail!(
                "--n1 only applies to two_bids / bid_fractions / dynamic"
            ),
        }
    }
    // event-native policy knobs (DESIGN.md §6)
    if let Some(v) = args.f64_opt("rebid-factor")? {
        match &mut kind {
            StrategyKind::NoticeRebid { rebid_factor }
                if v.is_finite() && v >= 1.0 =>
            {
                *rebid_factor = v;
            }
            StrategyKind::NoticeRebid { .. } => {
                bail!("--rebid-factor must be finite and >= 1, got {v}")
            }
            _ => bail!("--rebid-factor only applies to notice_rebid"),
        }
    }
    if let Some(v) = args.f64_opt("budget-rate")? {
        match &mut kind {
            StrategyKind::ElasticFleet { budget_rate }
                if v.is_finite() && v > 0.0 =>
            {
                *budget_rate = v;
            }
            StrategyKind::ElasticFleet { .. } => {
                bail!("--budget-rate must be finite and > 0, got {v}")
            }
            _ => bail!("--budget-rate only applies to elastic_fleet"),
        }
    }
    if let Some(v) = args.f64_opt("escalate-threshold")? {
        match &mut kind {
            StrategyKind::DeadlineAware { escalate_threshold }
                if v.is_finite() && v > 0.0 && v <= 1.0 =>
            {
                *escalate_threshold = v;
            }
            StrategyKind::DeadlineAware { .. } => {
                bail!("--escalate-threshold must be in (0, 1], got {v}")
            }
            _ => {
                bail!("--escalate-threshold only applies to deadline_aware")
            }
        }
    }
    let name = kind.canonical_name();
    let pb = BidProblem {
        bound: cfg.bound,
        price: cfg.price.clone(),
        runtime: cfg.runtime,
        n: cfg.n,
        eps: cfg.eps,
        theta: cfg.theta,
    };
    let prices = match &cfg.trace {
        Some(t) => PriceSource::Trace(t.clone()),
        None => PriceSource::Iid(cfg.price.clone()),
    };
    let cap = cfg.theta * 4.0;
    // the no-interruption plan picks its own J (Theorem 1); only an
    // explicit job.j in the config raises that floor. Other kinds need
    // an iteration budget, defaulting to the paper's 10^4.
    let j = cfg.j_fixed.unwrap_or(match &kind {
        StrategyKind::NoInterruption => 0,
        _ => 10_000,
    });
    let plan = exp::build_plan(
        name,
        &kind,
        &PlanInputs {
            pb: Some(&pb),
            n: cfg.n,
            j,
            preempt_q: cfg.preempt_q,
            unit_price: exp::fig5::PREEMPTIBLE_PRICE,
        },
    )?;
    describe_plan(&plan);
    // every plan runs as an engine Policy: classic kinds through the
    // lockstep adapter (bit-identical to the old path), event-native
    // kinds (notice_rebid / elastic_fleet / deadline_aware) directly
    let mut policy = plan.build_policy()?;
    // [overhead] from the config, with CLI overrides, executed by the
    // event engine; without either this is exactly the lockstep run
    let mut overhead = cfg.overhead;
    if let Some(k) = args.u64_opt("checkpoint-every")? {
        overhead.checkpoint_every_iters = k;
    }
    if let Some(s) = args.f64_opt("checkpoint-cost")? {
        overhead.checkpoint_cost_s = s;
    }
    if let Some(s) = args.f64_opt("restart-delay")? {
        overhead.restart_delay_s = s;
    }
    if args.get("lost-work").is_some() {
        // tri-state: bare `--lost-work` switches it on, an explicit
        // `--lost-work false` switches a config default off
        overhead.lost_work_on_preempt = args.bool("lost-work");
    }
    overhead.validate()?;
    let mut params = exp::RunParams::lockstep(cfg.runtime, cap);
    params.overhead = overhead;
    let mut rng = Rng::new(cfg.seed);
    // --trace-out: attach a structured-trace observer; the observer
    // draws no RNG, so the traced run is bit-identical to the plain one
    let trace_sink = match args.get("trace-out") {
        Some(path) => Some((path.to_string(), TraceSink::create(path)?)),
        None => None,
    };
    let result = match &trace_sink {
        Some((_, sink)) => {
            sink.write_line(&meta_line("simulate", name, cfg.seed, 1));
            let mut tracer = TraceObs::new(sink, 0, 0, "scalar");
            let r = exp::run_policy_engine_obs(
                policy.as_mut(),
                cfg.bound,
                &prices,
                &params,
                &mut rng,
                &mut [&mut tracer as &mut dyn Observer],
            )?;
            tracer.finish();
            r
        }
        None => exp::run_policy_engine(
            policy.as_mut(),
            cfg.bound,
            &prices,
            &params,
            &mut rng,
        )?,
    };
    if let Some((path, sink)) = &trace_sink {
        sink.flush()?;
        println!("trace -> {path}");
    }
    if overhead.enabled() {
        println!(
            "overhead: {} preemptions, {} restarts ({:.1}s lag), \
             {} checkpoints ({:.1}s), {} lost iters",
            result.preemptions,
            result.restarts,
            result.restart_time,
            result.checkpoints,
            result.checkpoint_time,
            result.lost_iters
        );
    }
    let result = volatile_sgd::coordinator::RunResult::from(result);
    println!("{}", exp::summarize(name, &result));
    let out = cfg.out_dir.join(format!("simulate_{name}.csv"));
    result.series.table().write(&out)?;
    println!("series -> {}", out.display());
    Ok(())
}

fn cmd_optimal_bid(args: &Args) -> Result<()> {
    let market = args.str("market", "uniform");
    let price = match market.as_str() {
        "uniform" => PriceModel::uniform_paper(),
        "gaussian" => PriceModel::gaussian_paper(),
        other => bail!("--market must be uniform|gaussian, got {other}"),
    };
    let n = args.usize("n", 8)?;
    let n1 = args.usize("n1", n / 2)?;
    let eps = args.f64("eps", 0.35)?;
    let theta = args.f64("theta", 120_000.0)?;
    let pb = BidProblem {
        bound: ErrorBound::new(SgdHyper::paper_cnn()),
        price,
        runtime: RuntimeModel::ExpStragglers { lambda: 0.25, delta: 0.5 },
        n,
        eps,
        theta,
    };
    let one = pb.optimal_one_bid()?;
    println!(
        "Theorem 2 (one bid):  b*={:.4}  J={}  E[C]={:.1}  E[tau]={:.1}",
        one.b, one.j, one.expected_cost, one.expected_time
    );
    if args.bool("two-bids") || args.get("n1").is_some() {
        let two = pb.cooptimize_j_two_bids(n1)?;
        println!(
            "Theorem 3 (two bids): b1*={:.4} b2*={:.4} gamma={:.3} J={} \
             E[C]={:.1} E[tau]={:.1}",
            two.b1, two.b2, two.gamma, two.j, two.expected_cost,
            two.expected_time
        );
        println!(
            "two-bid saving vs one bid: {:.1}%",
            100.0 * (one.expected_cost - two.expected_cost)
                / one.expected_cost
        );
    }
    Ok(())
}

fn cmd_plan_workers(args: &Args) -> Result<()> {
    let wp = WorkerProblem {
        bound: ErrorBound::new(SgdHyper::paper_cnn()),
        d: args.f64("d", 1.0)?,
        chi: args.f64("chi", 1.0)?,
        eps: args.f64("eps", 0.1)?,
        theta_iters: args.u64("theta-iters", 40_000)?,
    };
    let plan = wp.optimal_static()?;
    println!(
        "Theorem 4 (static):  J*={}  n*={}  cost proxy J*n = {}",
        plan.j, plan.n, plan.cost_proxy
    );
    let eta = args.f64("eta", 1.0004)?;
    let jd = wp.dynamic_iterations(eta, plan.j.max(1));
    println!(
        "Theorem 5 (dynamic): eta={eta}  J'={jd}  (vs static J={})",
        plan.j
    );
    let q = args.f64("q", 0.5)?;
    if let Ok(d) = wp.optimize_eta(
        args.usize("n0", 2)?,
        args.f64("r", 10.0)?,
        q,
        args.f64("theta", 2_000_000.0)?,
        args.u64("j-max", 40_000)?,
    ) {
        println!(
            "problem (20)-(23): eta*={:.6}  J={}  cost proxy={:.1}  \
             err bound={:.4}",
            d.eta, d.j, d.cost_proxy, d.err_bound
        );
    }
    Ok(())
}

fn out_dir(args: &Args) -> std::path::PathBuf {
    std::path::PathBuf::from(args.str("out", "out"))
}

fn cmd_fig2(args: &Args) -> Result<()> {
    let out = exp::fig2::run(5_000, 8, 4, args.threads()?)?;
    let dir = out_dir(args);
    out.surfaces.write(dir.join("fig2_surfaces.csv"))?;
    out.fig1.write(dir.join("fig1_series.csv"))?;
    println!(
        "fig2: monotonicities {} ({} grid points) -> {}",
        if out.monotone_ok { "OK" } else { "VIOLATED" },
        out.surfaces.rows.len(),
        dir.display()
    );
    Ok(())
}

fn cmd_fig3(args: &Args) -> Result<()> {
    let p = exp::fig3::Fig3Params {
        j: args.u64("j", 10_000)?,
        seed: args.u64("seed", 2020)?,
        threads: args.threads()?,
        ..Default::default()
    };
    let dir = out_dir(args);
    for (dist, name) in [
        (PriceModel::uniform_paper(), "uniform"),
        (PriceModel::gaussian_paper(), "gaussian"),
    ] {
        let out = exp::fig3::run(dist, name, &p)?;
        exp::fig3::print_summary(&out);
        for o in &out.outcomes {
            o.series
                .table()
                .write(dir.join(format!("fig3_{name}_{}.csv", o.name)))?;
        }
    }
    println!("series -> {}", dir.display());
    Ok(())
}

fn cmd_fig4(args: &Args) -> Result<()> {
    let trace = match args.get("trace") {
        Some(path) => volatile_sgd::market::SpotTrace::load(path)?,
        None => exp::fig4::default_trace(args.u64("trace-seed", 7)?),
    };
    let p = exp::fig4::Fig4Params {
        j: args.u64("j", 10_000)?,
        seed: args.u64("seed", 2020)?,
        threads: args.threads()?,
        ..Default::default()
    };
    let out = exp::fig4::run(&trace, &p)?;
    exp::fig4::print_summary(&out);
    let dir = out_dir(args);
    for o in &out.outcomes {
        o.series
            .table()
            .write(dir.join(format!("fig4_{}.csv", o.name)))?;
    }
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join("fig4_trace.csv"), trace.to_csv())?;
    println!("series -> {}", dir.display());
    Ok(())
}

fn cmd_fig5(args: &Args) -> Result<()> {
    let p = exp::fig5::Fig5Params {
        j: args.u64("j", 10_000)?,
        q: args.f64("q", 0.5)?,
        seed: args.u64("seed", 2020)?,
        threads: args.threads()?,
        ..Default::default()
    };
    let out = exp::fig5::run(&p)?;
    exp::fig5::print_summary(&out);
    let dir = out_dir(args);
    let mut t = volatile_sgd::util::csv::Table::new(&[
        "n_or_eta", "iters", "cost", "error", "accuracy", "acc_per_dollar",
    ]);
    for o in out.panel_a.iter().chain(&out.panel_b) {
        t.push(vec![
            o.n_or_eta,
            o.iters as f64,
            o.cost,
            o.final_error,
            o.final_accuracy,
            o.accuracy_per_dollar,
        ]);
    }
    t.write(dir.join("fig5_outcomes.csv"))?;
    println!("series -> {}", dir.display());
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    use volatile_sgd::sweep::{
        run_sweep_batched_with, run_sweep_with, SweepConfig, Telemetry,
    };

    // resolve the spec: --spec FILE > --preset NAME > --fig N (legacy
    // alias; default fig3). Every path yields the same ScenarioSpec
    // schema — presets ARE spec files.
    let mut spec = if let Some(path) = args.get("spec") {
        ScenarioSpec::from_file(path)?
    } else if let Some(name) = args.get("preset") {
        exp::presets::spec(name)?
    } else {
        exp::presets::spec(&args.str("fig", "3"))?
    };
    // --j overrides the job iteration budget (the Theorem 2/3 deadlines
    // scale with it; figure presets default to the paper's J = 10^4)
    if let Some(j) = args.u64_opt("j")? {
        spec.job.j = j;
    }

    // CLI flags override spec-level defaults, which override built-ins
    let cfg = SweepConfig {
        replicates: args
            .u64_opt("replicates")?
            .or(spec.replicates)
            .unwrap_or(8),
        seed: args.u64_opt("seed")?.or(spec.seed).unwrap_or(2020),
        threads: args.threads()?,
    };
    let scenario = volatile_sgd::exp::SpecScenario::new(spec)?;
    let name = scenario.spec().name.clone();

    if args.bool("check") {
        // the one-line audit trail CI greps for
        let combos =
            scenario.spec().market_dim() * scenario.grid().num_points();
        println!(
            "check OK: 1 spec validated, {combos} grid points {} \
             ({name}: {} sweep points x {} metrics, {} strategies, \
             {} market(s))",
            resolution_grade(combos),
            scenario.points(),
            scenario.metrics().len(),
            scenario.spec().strategies.len(),
            scenario.spec().market_dim()
        );
        return Ok(());
    }

    // --trace-out: stream the engine's observer events + per-stage
    // timing spans to a JSONL file. The trace hooks draw no RNG and
    // wall-clock never reaches the digest, so traced and untraced runs
    // print the same digest line (pinned by the obs test suite).
    let trace_sink = match args.get("trace-out") {
        Some(path) => Some((path.to_string(), TraceSink::create(path)?)),
        None => None,
    };
    if let Some((_, sink)) = &trace_sink {
        sink.write_line(&meta_line("sweep", &name, cfg.seed, cfg.threads));
    }
    let tel = Telemetry {
        trace: trace_sink.as_ref().map(|(_, sink)| sink),
        registry: None,
    };

    // the batched SoA replicate executor is the default; --no-batch
    // drops to the scalar per-replicate path (digests are identical by
    // contract, so this is a triage knob, not a results knob)
    let results = if args.bool("no-batch") {
        run_sweep_with(&scenario, &cfg, tel)?
    } else {
        run_sweep_batched_with(&scenario, &cfg, tel)?
    };
    if let Some((path, sink)) = &trace_sink {
        sink.flush()?;
        println!("trace -> {path}");
    }
    println!(
        "== sweep {name}  ({} points x {} replicates, seed {})",
        results.points.len(),
        cfg.replicates,
        cfg.seed
    );
    results.print();
    println!("  digest: {:016x}", results.digest());

    // --out: a *.csv path gets the labeled machine-readable table; a
    // directory (default "out") keeps the legacy numeric table
    let out = args.str("out", "out");
    if out.ends_with(".csv") {
        let path = std::path::PathBuf::from(&out);
        results.to_labeled_table().write(&path)?;
        println!("collated stats -> {}", path.display());
    } else {
        let path = std::path::PathBuf::from(&out)
            .join(format!("sweep_{name}.csv"));
        results.to_table().write(&path)?;
        println!("collated stats -> {}", path.display());
    }
    if let Some(jflag) = args.get("json") {
        // bare --json lands next to the CSV: the --out directory, or the
        // parent of an --out *.csv file
        let path = json_out_path(jflag, &out, &format!("sweep_{name}.json"));
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(&path, results.to_json(&name, &cfg))?;
        println!("json -> {}", path.display());
    }
    Ok(())
}

/// Resolve a `--json [FILE]` flag: an explicit FILE wins; a bare flag
/// lands `default_name` next to the CSV (the `--out` directory, or the
/// parent of an `--out *.csv` file).
fn json_out_path(
    jflag: &str,
    out: &str,
    default_name: &str,
) -> std::path::PathBuf {
    if jflag == "true" {
        let base = if out.ends_with(".csv") {
            std::path::Path::new(out)
                .parent()
                .filter(|p| !p.as_os_str().is_empty())
                .unwrap_or_else(|| std::path::Path::new("out"))
                .to_path_buf()
        } else {
            std::path::PathBuf::from(out)
        };
        base.join(default_name)
    } else {
        std::path::PathBuf::from(jflag)
    }
}

/// How thoroughly the load-time dry-run validated a spec's (market x
/// grid) combinations — exhaustive resolution up to the `exp::spec`
/// limit, per-axis-value checks beyond it. The `--check` audit line
/// must not claim more than actually ran.
fn resolution_grade(combos: usize) -> &'static str {
    if combos <= volatile_sgd::exp::spec::FULL_RESOLVE_LIMIT {
        "resolved"
    } else {
        "range-checked (grid too large to resolve exhaustively)"
    }
}

fn cmd_optimize(args: &Args) -> Result<()> {
    use volatile_sgd::opt;

    // --spec FILE, defaulting to the shipped optimize_deadline preset
    let plan = match args.get("spec") {
        Some(path) => opt::PlanSpec::from_file(path)?,
        None => opt::PlanSpec::from_str(opt::preset_toml())?,
    };
    let seed = args.u64_opt("seed")?.or(plan.scenario.seed).unwrap_or(2020);
    let threads = args.threads()?;

    if args.bool("check") {
        // the run path builds (and so validates) the scenario inside
        // run_plan; build it here only for the check summary
        let scenario = opt::build_scenario(&plan)?;
        let combos =
            scenario.spec().market_dim() * scenario.grid().num_points();
        println!(
            "check OK: 1 plan spec validated, {} lattice points {} \
             ({}: {} strategies x {} grid x {} market(s); goal \
             {}, ladder {:?})",
            scenario.points(),
            resolution_grade(combos),
            scenario.spec().name,
            scenario.spec().strategies.len(),
            scenario.grid().num_points(),
            scenario.spec().market_dim(),
            plan.objective.goal.name(),
            plan.search.ladder
        );
        return Ok(());
    }

    let outcome =
        opt::run_plan(&plan, &opt::PlannerConfig { seed, threads })?;
    let name = outcome.name.clone();
    opt::report::print(&outcome);

    // --out: a *.csv path gets the full candidate table; a directory
    // (default "out") names it optimize_<name>.csv — same conventions
    // as `sweep`
    let out = args.str("out", "out");
    let csv_path = if out.ends_with(".csv") {
        std::path::PathBuf::from(&out)
    } else {
        std::path::PathBuf::from(&out).join(format!("optimize_{name}.csv"))
    };
    opt::report::to_csv(&outcome).write(&csv_path)?;
    println!("recommendations -> {}", csv_path.display());
    if let Some(jflag) = args.get("json") {
        let path =
            json_out_path(jflag, &out, &format!("optimize_{name}.json"));
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(&path, opt::report::to_json(&outcome, threads))?;
        println!("json -> {}", path.display());
    }
    Ok(())
}

/// Where the client subcommands look for a daemon unless --addr says
/// otherwise (2020: the paper's year).
const DEFAULT_ADDR: &str = "127.0.0.1:2020";

fn cmd_serve(args: &Args) -> Result<()> {
    use volatile_sgd::serve;

    let listen = args.str("listen", DEFAULT_ADDR);
    if args.bool("check") {
        println!("{}", serve::check(&listen)?);
        return Ok(());
    }
    let cfg = serve::ServeConfig { listen, threads: args.threads()? };
    let server = serve::Server::bind(&cfg)?;
    serve::install_sigint_handler();
    println!(
        "serve: listening on {} ({} worker threads); SIGINT or the \
         shutdown command drains",
        server.local_addr()?,
        cfg.threads
    );
    let report = server.run()?;
    println!(
        "serve: drained after {:.1}s — {} jobs done, {} failed, \
         {} pool jobs executed",
        report.uptime_s, report.jobs_done, report.jobs_failed,
        report.pool_jobs
    );
    Ok(())
}

fn cmd_submit(args: &Args) -> Result<()> {
    use volatile_sgd::serve::{client, protocol};
    use volatile_sgd::util::json::JsonValue;

    let addr = args.str("addr", DEFAULT_ADDR);
    let spec_toml = match args.get("spec") {
        Some(path) => Some(
            std::fs::read_to_string(path)
                .with_context(|| format!("reading {path}"))?,
        ),
        None => None,
    };
    let req = protocol::SubmitReq {
        kind: args.get("kind").map(str::to_string),
        preset: args.get("preset").map(str::to_string),
        spec_toml,
        seed: args.u64_opt("seed")?,
        replicates: args.u64_opt("replicates")?,
        j: args.u64_opt("j")?,
    };
    let ack =
        client::roundtrip(&addr, &protocol::submit_request_json(&req))?;
    let job = ack
        .get("job")
        .and_then(JsonValue::as_u64)
        .context("malformed submit acknowledgement")?;
    let state =
        ack.get("state").and_then(JsonValue::as_str).unwrap_or("?");
    let mut notes = String::new();
    if ack.get("cached").and_then(JsonValue::as_bool) == Some(true) {
        notes.push_str(" (tier-A cache hit)");
    }
    if ack.get("coalesced").and_then(JsonValue::as_bool) == Some(true) {
        notes.push_str(" (coalesced onto an identical in-flight job)");
    }
    println!("submitted job {job}: {state}{notes}");
    if args.bool("wait") {
        let timeout =
            std::time::Duration::from_secs(args.u64("timeout", 600)?);
        let (result, raw) = client::wait_result(&addr, job, timeout)?;
        let digest = result
            .get("digest")
            .and_then(JsonValue::as_str)
            .context("result carried no digest")?;
        // the exact line the offline `sweep`/`optimize` runs print, so
        // daemon-vs-CLI determinism is a plain `diff`
        println!("  digest: {digest}");
        if let Some(out) = args.get("out") {
            std::fs::write(out, format!("{raw}\n"))
                .with_context(|| format!("writing {out}"))?;
            println!("result -> {out}");
        }
    }
    Ok(())
}

/// `status` / `result`: one request line out, the response line printed
/// verbatim (it is already a single machine-readable JSON line).
fn cmd_job_query(args: &Args, cmd: &str) -> Result<()> {
    use volatile_sgd::serve::{client, protocol};

    let addr = args.str("addr", DEFAULT_ADDR);
    let job = args
        .u64_opt("job")?
        .context("--job N is required (the id `submit` printed)")?;
    let (_, raw) =
        client::roundtrip_raw(&addr, &protocol::job_request_json(cmd, job))?;
    if let (true, Some(out)) = (cmd == "result", args.get("out")) {
        std::fs::write(out, format!("{raw}\n"))
            .with_context(|| format!("writing {out}"))?;
        println!("result -> {out}");
    } else {
        println!("{raw}");
    }
    Ok(())
}

fn cmd_stats(args: &Args) -> Result<()> {
    use volatile_sgd::serve::{client, protocol};

    let addr = args.str("addr", DEFAULT_ADDR);
    if args.bool("prom") {
        // the exposition already ends in a newline
        print!("{}", client::fetch_prom(&addr)?);
        return Ok(());
    }
    let (_, raw) =
        client::roundtrip_raw(&addr, &protocol::bare_request_json("stats"))?;
    println!("{raw}");
    Ok(())
}

/// `trace-check --file FILE`: strict validation of a `--trace-out`
/// JSONL file — every line parses, the meta line leads, event kinds
/// are known, sim-time is monotone per replicate. Prints the tally
/// line CI greps for.
fn cmd_trace_check(args: &Args) -> Result<()> {
    let path = args
        .get("file")
        .context("--file FILE is required (a --trace-out JSONL file)")?;
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {path}"))?;
    let sum = validate_trace(&text)
        .with_context(|| format!("validating {path}"))?;
    println!(
        "trace OK: {} lines ({} events, {} spans)",
        sum.lines, sum.events, sum.spans
    );
    for (kind, n) in &sum.kinds {
        println!("  {kind}: {n}");
    }
    Ok(())
}

fn cmd_shutdown(args: &Args) -> Result<()> {
    use volatile_sgd::serve::{client, protocol};

    let addr = args.str("addr", DEFAULT_ADDR);
    client::roundtrip(&addr, &protocol::bare_request_json("shutdown"))?;
    println!("shutdown: daemon at {addr} is draining");
    Ok(())
}
