//! Shared daemon state: the job registry, the tier-A report cache, the
//! admission queue's sending half, and the service metrics.
//!
//! Cache keying (DESIGN.md §9): a submission's **request fingerprint**
//! is the spec's content-addressed fingerprint
//! ([`crate::exp::ScenarioSpec::fingerprint`] /
//! [`crate::opt::PlanSpec::fingerprint`] — layout-invariant, seed- and
//! replicate-exempt) extended with the *effective* seed and replicate
//! count after CLI-style overrides. Tier A maps request fingerprints to
//! finished single-line reports; tier B is the process-wide
//! [`PrepareCache`] shared by every sweep and planner execution.

use std::collections::HashMap;
use std::sync::atomic::AtomicBool;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::config::toml::Doc;
use crate::exp::spec::{CachedSpecScenario, PrepareCache};
use crate::exp::{presets, ScenarioSpec, SpecScenario};
use crate::obs::{Counter, Histogram, Registry};
use crate::opt::{self, PlanSpec, PlannerConfig};
use crate::sweep::{run_sweep_batched_with, SweepConfig, Telemetry};
use crate::util::fnv::Fnv;

use super::protocol::{compact_json, JobView, StatsView, SubmitReq};

/// Lifecycle of one submission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
}

impl JobState {
    pub fn name(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
        }
    }
}

/// One submission's registry entry. `payload` is the finished
/// single-line report, shared (`Arc`) with the tier-A cache.
#[derive(Clone, Debug)]
pub struct JobRecord {
    pub id: u64,
    pub name: String,
    pub fingerprint: u64,
    pub state: JobState,
    pub cached: bool,
    pub digest: Option<u64>,
    pub payload: Option<Arc<String>>,
    pub error: Option<String>,
}

impl JobRecord {
    pub fn view(&self, coalesced: bool) -> JobView {
        JobView {
            id: self.id,
            state: self.state.name(),
            name: self.name.clone(),
            fingerprint: self.fingerprint,
            cached: self.cached,
            coalesced,
            digest: self.digest,
            payload: self.payload.clone(),
            error: self.error.clone(),
        }
    }
}

/// A finished report in the tier-A cache.
#[derive(Clone, Debug)]
struct TierAEntry {
    payload: Arc<String>,
    digest: u64,
    name: String,
}

/// One unit of admitted work, executed FIFO by the single executor
/// thread (the admission queue *is* the `mpsc` channel: submissions are
/// served in arrival order, and every execution runs on the one shared
/// sweep pool at the daemon's `--threads`).
pub enum WorkItem {
    Sweep {
        id: u64,
        spec: ScenarioSpec,
        cfg: SweepConfig,
        enqueued: Instant,
    },
    Optimize {
        id: u64,
        plan: Box<PlanSpec>,
        seed: u64,
        enqueued: Instant,
    },
}

impl WorkItem {
    fn id(&self) -> u64 {
        match self {
            WorkItem::Sweep { id, .. } | WorkItem::Optimize { id, .. } => *id,
        }
    }

    fn enqueued(&self) -> Instant {
        match self {
            WorkItem::Sweep { enqueued, .. }
            | WorkItem::Optimize { enqueued, .. } => *enqueued,
        }
    }
}

/// First-class service metrics: named handles into the daemon's one
/// [`Registry`] (wall-clock only ever feeds *metrics*, never results —
/// digests stay pure). The counters back both the JSON `stats` reply
/// (via [`ServerState::stats_view`], byte-compatible with the
/// pre-registry format) and the Prometheus exposition; the histograms
/// are per-job latencies in microseconds (DESIGN.md §12).
pub struct Metrics {
    pub requests: Arc<Counter>,
    pub submits: Arc<Counter>,
    pub tier_a_hits: Arc<Counter>,
    pub tier_a_misses: Arc<Counter>,
    pub coalesced: Arc<Counter>,
    pub jobs_done: Arc<Counter>,
    pub jobs_failed: Arc<Counter>,
    /// replicate jobs executed on the shared pool (sweep replicates +
    /// planner rung simulations) — frozen across a tier-A hit, which is
    /// what the CI warm-hit smoke asserts
    pub pool_jobs: Arc<Counter>,
    pub exec_micros: Arc<Counter>,
    /// admission -> execution-start wait per job
    pub job_queue_wait_us: Arc<Histogram>,
    /// submit-side validate/fingerprint (build_work) per submission
    pub job_prepare_us: Arc<Histogram>,
    /// executor wall-clock per job
    pub job_execute_us: Arc<Histogram>,
}

impl Metrics {
    fn new(reg: &Registry) -> Metrics {
        Metrics {
            requests: reg.counter("serve_requests"),
            submits: reg.counter("serve_submits"),
            tier_a_hits: reg.counter("serve_tier_a_hits"),
            tier_a_misses: reg.counter("serve_tier_a_misses"),
            coalesced: reg.counter("serve_coalesced"),
            jobs_done: reg.counter("serve_jobs_done"),
            jobs_failed: reg.counter("serve_jobs_failed"),
            pool_jobs: reg.counter("serve_pool_jobs"),
            exec_micros: reg.counter("serve_exec_us"),
            job_queue_wait_us: reg.histogram("serve_job_queue_wait_us"),
            job_prepare_us: reg.histogram("serve_job_prepare_us"),
            job_execute_us: reg.histogram("serve_job_execute_us"),
        }
    }
}

/// The state shared by the accept loop, every connection handler and
/// the executor thread.
pub struct ServerState {
    pub threads: usize,
    pub started: Instant,
    pub jobs: Mutex<Vec<JobRecord>>,
    tier_a: Mutex<HashMap<u64, TierAEntry>>,
    pub prepare_cache: PrepareCache,
    /// the daemon's one telemetry registry: service counters, per-job
    /// latency histograms, sweep per-stage histograms and planner stage
    /// counters all land here and surface through `stats --prom`
    pub registry: Arc<Registry>,
    pub metrics: Metrics,
    /// sending half of the admission queue; `None` once draining —
    /// dropping it is what lets the executor finish the queue and exit
    tx: Mutex<Option<Sender<WorkItem>>>,
    pub shutdown: AtomicBool,
}

/// Acknowledgement for a submit: the job's view plus whether this
/// submission coalesced onto an already-admitted identical job.
pub struct SubmitAck {
    pub view: JobView,
}

impl ServerState {
    pub fn new(threads: usize) -> (Arc<ServerState>, Receiver<WorkItem>) {
        let (tx, rx) = mpsc::channel();
        let registry = Arc::new(Registry::new());
        let metrics = Metrics::new(&registry);
        let state = Arc::new(ServerState {
            threads,
            started: Instant::now(),
            jobs: Mutex::new(Vec::new()),
            tier_a: Mutex::new(HashMap::new()),
            prepare_cache: PrepareCache::new(),
            registry,
            metrics,
            tx: Mutex::new(Some(tx)),
            shutdown: AtomicBool::new(false),
        });
        (state, rx)
    }

    /// Stop admitting: drop the queue's sender so the executor drains
    /// what is already admitted and exits.
    pub fn close_queue(&self) {
        *self.tx.lock().unwrap() = None;
    }

    pub fn job_view(&self, id: u64) -> Result<JobView> {
        let jobs = self.jobs.lock().unwrap();
        match jobs.get(id as usize) {
            Some(rec) => Ok(rec.view(false)),
            None => bail!(
                "unknown job {id} ({} submitted so far)",
                jobs.len()
            ),
        }
    }

    pub fn stats_view(&self) -> StatsView {
        let m = &self.metrics;
        let queue_depth = self
            .jobs
            .lock()
            .unwrap()
            .iter()
            .filter(|j| j.state == JobState::Queued)
            .count() as u64;
        StatsView {
            uptime_s: self.started.elapsed().as_secs_f64(),
            requests: m.requests.get(),
            submits: m.submits.get(),
            tier_a_hits: m.tier_a_hits.get(),
            tier_a_misses: m.tier_a_misses.get(),
            tier_a_entries: self.tier_a.lock().unwrap().len() as u64,
            tier_b_hits: self.prepare_cache.hits(),
            tier_b_misses: self.prepare_cache.misses(),
            tier_b_entries: self.prepare_cache.len() as u64,
            coalesced: m.coalesced.get(),
            queue_depth,
            jobs_done: m.jobs_done.get(),
            jobs_failed: m.jobs_failed.get(),
            pool_jobs: m.pool_jobs.get(),
            exec_seconds: m.exec_micros.get() as f64 / 1e6,
        }
    }

    /// Refresh the registry gauges that mirror sampled state (queue
    /// depth, cache occupancy, tier-B counters living in
    /// [`PrepareCache`]'s own atomics) so a Prometheus scrape sees
    /// them. Called by the `stats --prom` handler just before
    /// rendering.
    pub fn sync_gauges(&self) {
        let queued = self
            .jobs
            .lock()
            .unwrap()
            .iter()
            .filter(|j| j.state == JobState::Queued)
            .count() as u64;
        let reg = &self.registry;
        reg.gauge("serve_queue_depth").set(queued);
        reg.gauge("serve_tier_a_entries")
            .set(self.tier_a.lock().unwrap().len() as u64);
        reg.gauge("serve_tier_b_hits").set(self.prepare_cache.hits());
        reg.gauge("serve_tier_b_misses")
            .set(self.prepare_cache.misses());
        reg.gauge("serve_tier_b_entries")
            .set(self.prepare_cache.len() as u64);
        reg.gauge("serve_uptime_s")
            .set(self.started.elapsed().as_secs());
    }

    /// Validate, fingerprint and admit one submission. Tier-A hits are
    /// answered synchronously (a new `done` record pointing at the
    /// cached report, zero recomputation); identical in-flight work is
    /// coalesced (the twin's job id comes back); everything else is
    /// queued.
    pub fn submit(&self, req: SubmitReq) -> Result<SubmitAck> {
        self.metrics.submits.inc();
        let prep = Instant::now();
        let built = build_work(self.threads, req);
        self.metrics
            .job_prepare_us
            .record(prep.elapsed().as_micros() as u64);
        let (name, fingerprint, item_for) = built?;

        let mut jobs = self.jobs.lock().unwrap();
        // tier A: the finished report is already content-addressed
        if let Some(entry) = self.tier_a.lock().unwrap().get(&fingerprint) {
            self.metrics.tier_a_hits.inc();
            let id = jobs.len() as u64;
            let rec = JobRecord {
                id,
                name: entry.name.clone(),
                fingerprint,
                state: JobState::Done,
                cached: true,
                digest: Some(entry.digest),
                payload: Some(Arc::clone(&entry.payload)),
                error: None,
            };
            let view = rec.view(false);
            jobs.push(rec);
            return Ok(SubmitAck { view });
        }
        self.metrics.tier_a_misses.inc();

        // coalesce onto an identical queued/running submission instead
        // of admitting duplicate work
        if let Some(twin) = jobs.iter().find(|j| {
            j.fingerprint == fingerprint
                && matches!(j.state, JobState::Queued | JobState::Running)
        }) {
            self.metrics.coalesced.inc();
            return Ok(SubmitAck { view: twin.view(true) });
        }

        let id = jobs.len() as u64;
        let rec = JobRecord {
            id,
            name,
            fingerprint,
            state: JobState::Queued,
            cached: false,
            digest: None,
            payload: None,
            error: None,
        };
        let view = rec.view(false);
        jobs.push(rec);
        drop(jobs);

        let sent = match self.tx.lock().unwrap().as_ref() {
            Some(tx) => tx.send(item_for(id)).is_ok(),
            None => false,
        };
        if !sent {
            let mut jobs = self.jobs.lock().unwrap();
            jobs[id as usize].state = JobState::Failed;
            jobs[id as usize].error =
                Some("server is draining; submission rejected".into());
            self.metrics.jobs_failed.inc();
            bail!("server is draining; submission rejected");
        }
        Ok(SubmitAck { view })
    }
}

fn sweep_request_fingerprint(
    spec: &ScenarioSpec,
    seed: u64,
    replicates: u64,
) -> u64 {
    let mut h = Fnv::new();
    h.bytes(b"serve-req/sweep/v1");
    h.u64(spec.fingerprint());
    h.u64(seed);
    h.u64(replicates);
    h.finish()
}

fn optimize_request_fingerprint(plan: &PlanSpec, seed: u64) -> u64 {
    let mut h = Fnv::new();
    h.bytes(b"serve-req/optimize/v1");
    h.u64(plan.fingerprint());
    h.u64(seed);
    h.finish()
}

/// Resolve a preset name to its embedded TOML: the seven sweep presets
/// plus the shipped planner preset.
pub fn preset_text(name: &str) -> Result<&'static str> {
    if name == "optimize_deadline" {
        return Ok(opt::preset_toml());
    }
    presets::preset_toml(name).map_err(|e| {
        anyhow::anyhow!("{e}; the planner preset is optimize_deadline")
    })
}

type ItemFor = Box<dyn FnOnce(u64) -> WorkItem>;

/// Resolve, validate (the same machinery `--check` runs) and
/// fingerprint one submission, deferring only the job id. The spec
/// defaults and CLI-flag precedence mirror `cmd_sweep` / `cmd_optimize`
/// exactly — that equivalence is what makes a daemon digest comparable
/// to an offline run.
fn build_work(
    threads: usize,
    req: SubmitReq,
) -> Result<(String, u64, ItemFor)> {
    let text: String = match (&req.preset, &req.spec_toml) {
        (Some(_), Some(_)) => {
            bail!("give either 'preset' or 'spec_toml', not both")
        }
        (Some(p), None) => preset_text(p)?.to_string(),
        (None, Some(t)) => t.clone(),
        (None, None) => bail!("submit needs 'preset' or 'spec_toml'"),
    };
    let doc = Doc::parse(&text)?;
    let is_plan = doc
        .entries
        .keys()
        .any(|k| k == "objective" || k.starts_with("objective."));
    let optimize = match req.kind.as_deref() {
        None => is_plan,
        Some("sweep") => {
            ensure!(
                !is_plan,
                "spec has an [objective] table; submit it with kind = \
                 \"optimize\""
            );
            false
        }
        Some("optimize") => {
            ensure!(
                is_plan,
                "kind \"optimize\" needs a spec with an [objective] table"
            );
            true
        }
        Some(other) => {
            bail!("kind must be \"sweep\" or \"optimize\", got '{other}'")
        }
    };

    if optimize {
        ensure!(
            req.replicates.is_none(),
            "the [search] ladder governs planner evidence; 'replicates' \
             is not accepted for optimize submissions"
        );
        ensure!(
            req.j.is_none(),
            "set job.j in the plan spec; 'j' is not accepted for optimize \
             submissions"
        );
        let plan = PlanSpec::from_str(&text)?;
        let seed = req.seed.or(plan.scenario.seed).unwrap_or(2020);
        // --check-grade validation before admission
        opt::build_scenario(&plan).context("validating plan spec")?;
        let fingerprint = optimize_request_fingerprint(&plan, seed);
        let name = plan.scenario.name.clone();
        let plan = Box::new(plan);
        Ok((
            name,
            fingerprint,
            Box::new(move |id| WorkItem::Optimize {
                id,
                plan,
                seed,
                enqueued: Instant::now(),
            }),
        ))
    } else {
        let mut spec = ScenarioSpec::from_str(&text)?;
        if let Some(j) = req.j {
            ensure!(j > 0, "'j' must be > 0");
            spec.job.j = j;
        }
        let replicates = req.replicates.or(spec.replicates).unwrap_or(8);
        ensure!(replicates > 0, "'replicates' must be > 0");
        let seed = req.seed.or(spec.seed).unwrap_or(2020);
        // --check-grade validation before admission
        SpecScenario::new(spec.clone()).context("validating spec")?;
        let fingerprint = sweep_request_fingerprint(&spec, seed, replicates);
        let name = spec.name.clone();
        let cfg = SweepConfig { replicates, seed, threads };
        Ok((
            name,
            fingerprint,
            Box::new(move |id| WorkItem::Sweep {
                id,
                spec,
                cfg,
                enqueued: Instant::now(),
            }),
        ))
    }
}

/// The executor thread: drains the admission queue FIFO until every
/// sender is gone (drain = `close_queue` + queue empty), publishing
/// each finished report to the registry and the tier-A cache.
pub fn executor_loop(state: &Arc<ServerState>, rx: Receiver<WorkItem>) {
    while let Ok(item) = rx.recv() {
        let id = item.id();
        state
            .metrics
            .job_queue_wait_us
            .record(item.enqueued().elapsed().as_micros() as u64);
        state.jobs.lock().unwrap()[id as usize].state = JobState::Running;
        let t0 = Instant::now();
        let outcome = match item {
            WorkItem::Sweep { spec, cfg, .. } => exec_sweep(state, spec, &cfg),
            WorkItem::Optimize { plan, seed, .. } => {
                exec_optimize(state, &plan, seed)
            }
        };
        let exec_us = t0.elapsed().as_micros() as u64;
        state.metrics.exec_micros.add(exec_us);
        state.metrics.job_execute_us.record(exec_us);
        match outcome {
            Ok((payload, digest)) => {
                let (fp, name) = {
                    let mut jobs = state.jobs.lock().unwrap();
                    let rec = &mut jobs[id as usize];
                    rec.state = JobState::Done;
                    rec.digest = Some(digest);
                    rec.payload = Some(Arc::clone(&payload));
                    (rec.fingerprint, rec.name.clone())
                };
                state.metrics.jobs_done.inc();
                state
                    .tier_a
                    .lock()
                    .unwrap()
                    .insert(fp, TierAEntry { payload, digest, name });
            }
            Err(e) => {
                let mut jobs = state.jobs.lock().unwrap();
                let rec = &mut jobs[id as usize];
                rec.state = JobState::Failed;
                rec.error = Some(format!("{e:#}"));
                state.metrics.jobs_failed.inc();
            }
        }
    }
}

fn exec_sweep(
    state: &ServerState,
    spec: ScenarioSpec,
    cfg: &SweepConfig,
) -> Result<(Arc<String>, u64)> {
    let scenario = SpecScenario::new(spec)?;
    let name = scenario.spec().name.clone();
    let warm = CachedSpecScenario::new(&scenario, &state.prepare_cache);
    // registry-only telemetry: per-stage histograms and pool counters
    // accumulate across jobs; no trace sink (results stay untouched
    // either way — the digest-neutrality contract, DESIGN.md §12)
    let tel = Telemetry { trace: None, registry: Some(&state.registry) };
    let results = run_sweep_batched_with(&warm, cfg, tel)?;
    state.metrics.pool_jobs.add(results.throughput.jobs);
    let digest = results.digest();
    let payload = Arc::new(compact_json(&results.to_json(&name, cfg)));
    Ok((payload, digest))
}

fn exec_optimize(
    state: &ServerState,
    plan: &PlanSpec,
    seed: u64,
) -> Result<(Arc<String>, u64)> {
    let cfg = PlannerConfig { seed, threads: state.threads };
    let outcome = opt::run_plan_instrumented(
        plan,
        &cfg,
        &state.prepare_cache,
        Some(&state.registry),
    )?;
    let sims: u64 = outcome
        .rungs
        .iter()
        .map(|r| r.replicates * r.members.len() as u64)
        .sum();
    state.metrics.pool_jobs.add(sims);
    let digest = outcome.digest();
    let payload =
        Arc::new(compact_json(&opt::report::to_json(&outcome, state.threads)));
    Ok((payload, digest))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: &str = r#"
name = "serve-state"
strategies = ["static_workers"]
metrics = ["cost", "recip_exact"]

[job]
n = 4
j = 50
preempt_q = 0.3

[runtime]
kind = "deterministic"
r = 10.0

[market]
kind = "fixed"
"#;

    fn drain(state: &Arc<ServerState>, rx: Receiver<WorkItem>) {
        state.close_queue();
        executor_loop(state, rx);
    }

    #[test]
    fn submit_executes_and_second_submission_hits_tier_a() {
        let (state, rx) = ServerState::new(1);
        let req = SubmitReq {
            spec_toml: Some(SPEC.into()),
            seed: Some(11),
            replicates: Some(3),
            ..Default::default()
        };
        let ack = state.submit(req.clone()).unwrap();
        assert_eq!(ack.view.state, "queued");
        drain(&state, rx);
        let done = state.job_view(ack.view.id).unwrap();
        assert_eq!(done.state, "done");
        let digest = done.digest.unwrap();
        let pool_before = state.stats_view().pool_jobs;
        assert_eq!(pool_before, 3); // one point x 3 replicates

        // warm repeat: answered from tier A, no work admitted
        let warm = state.submit(req).unwrap();
        assert_eq!(warm.view.state, "done");
        assert!(warm.view.cached);
        assert_eq!(warm.view.digest, Some(digest));
        let s = state.stats_view();
        assert_eq!(s.tier_a_hits, 1);
        assert_eq!(s.pool_jobs, pool_before);
        assert_eq!(s.jobs_done, 1);

        // the registry saw the same traffic the JSON view reports, plus
        // the per-job and per-stage latency histograms
        let m = &state.metrics;
        assert_eq!(m.job_queue_wait_us.count(), 1);
        assert_eq!(m.job_execute_us.count(), 1);
        assert_eq!(m.job_prepare_us.count(), 2); // cold + warm submit
        assert_eq!(
            state.registry.counter("serve_jobs_done").get(),
            s.jobs_done
        );
        assert_eq!(
            state.registry.histogram("sweep_run_us").count(),
            3 // one point x 3 replicates
        );
    }

    #[test]
    fn effective_seed_and_replicates_key_the_request() {
        let (state, _rx) = ServerState::new(1);
        let base = SubmitReq {
            spec_toml: Some(SPEC.into()),
            seed: Some(11),
            replicates: Some(3),
            ..Default::default()
        };
        let a = state.submit(base.clone()).unwrap();
        let b = state
            .submit(SubmitReq { seed: Some(12), ..base.clone() })
            .unwrap();
        let c = state
            .submit(SubmitReq { replicates: Some(4), ..base.clone() })
            .unwrap();
        assert_ne!(a.view.fingerprint, b.view.fingerprint);
        assert_ne!(a.view.fingerprint, c.view.fingerprint);
        // identical effective work coalesces onto the in-flight twin
        let twin = state.submit(base).unwrap();
        assert!(twin.view.coalesced);
        assert_eq!(twin.view.id, a.view.id);
        assert_eq!(state.stats_view().coalesced, 1);
    }

    #[test]
    fn invalid_submissions_fail_before_admission() {
        let (state, _rx) = ServerState::new(1);
        // unknown preset
        let e = state
            .submit(SubmitReq {
                preset: Some("fig9".into()),
                ..Default::default()
            })
            .unwrap_err()
            .to_string();
        assert!(e.contains("unknown preset"), "{e}");
        // bad spec body: the --check machinery rejects it by key name
        let e = state
            .submit(SubmitReq {
                spec_toml: Some(SPEC.replace("[job]", "[job]\nepss = 1")),
                ..Default::default()
            })
            .unwrap_err()
            .to_string();
        assert!(e.contains("job.epss"), "{e}");
        // neither body nor preset
        assert!(state
            .submit(SubmitReq::default())
            .unwrap_err()
            .to_string()
            .contains("'preset' or 'spec_toml'"));
        // nothing was admitted or executed
        let s = state.stats_view();
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.jobs_done + s.jobs_failed, 0);
    }

    #[test]
    fn draining_rejects_new_submissions() {
        let (state, rx) = ServerState::new(1);
        drain(&state, rx);
        let e = state
            .submit(SubmitReq {
                spec_toml: Some(SPEC.into()),
                ..Default::default()
            })
            .unwrap_err()
            .to_string();
        assert!(e.contains("draining"), "{e}");
    }
}
