//! The serve wire protocol: newline-delimited JSON over TCP.
//!
//! One request per connection, one line each way (DESIGN.md §9 has the
//! full grammar):
//!
//! ```text
//! request  := submit | status | result | stats | shutdown
//! submit   := {"cmd": "submit", "kind"?, "preset"? | "spec_toml"?,
//!              "seed"?, "replicates"?, "j"?}
//! status   := {"cmd": "status", "job": N}
//! result   := {"cmd": "result", "job": N}
//! stats    := {"cmd": "stats", "format"?}   format: "json" | "prom"
//! shutdown := {"cmd": "shutdown"}
//! response := {"ok": true, ...} | {"ok": false, "error": "..."}
//! ```
//!
//! `format: "prom"` asks for the Prometheus text exposition instead of
//! the JSON counter block; the reply is still one JSON line, with the
//! exposition carried (escaped) in a `"prom"` string field
//! (DESIGN.md §12). The default JSON `stats` reply is byte-compatible
//! with the pre-registry daemon — pinned by a regression test below.
//!
//! Requests are parsed with the strict [`crate::util::json`] reader and
//! audited like the spec loader: unknown keys are rejected *by name*
//! per command, so a typo (`"sede"`) fails loudly instead of being
//! silently ignored. Responses are built with the shared emission
//! convention ([`crate::util::json::esc`] / [`crate::util::json::num`])
//! and are always a single line — multi-line payloads (sweep / planner
//! reports) are flattened by [`compact_json`] before embedding.

use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::util::json::{esc, num, JsonValue};

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Submit(SubmitReq),
    Status { job: u64 },
    Result { job: u64 },
    /// `prom` selects the Prometheus text exposition; the default is
    /// the JSON counter block.
    Stats { prom: bool },
    Shutdown,
}

/// The body of a `submit` request. Exactly one of `preset` /
/// `spec_toml` carries the spec; `seed` / `replicates` / `j` override
/// the spec's defaults the same way the offline CLI flags do (so a
/// daemon submission and a CLI run describe identical work).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SubmitReq {
    /// `"sweep"` | `"optimize"`; absent = auto-detect (a spec with an
    /// `[objective]` table is a planner spec)
    pub kind: Option<String>,
    pub preset: Option<String>,
    pub spec_toml: Option<String>,
    pub seed: Option<u64>,
    pub replicates: Option<u64>,
    pub j: Option<u64>,
}

fn str_field(v: &JsonValue, key: &str) -> Result<Option<String>> {
    match v.get(key) {
        None => Ok(None),
        Some(f) => Ok(Some(
            f.as_str()
                .with_context(|| format!("'{key}' must be a string"))?
                .to_string(),
        )),
    }
}

fn u64_field(v: &JsonValue, key: &str) -> Result<Option<u64>> {
    match v.get(key) {
        None => Ok(None),
        Some(f) => Ok(Some(f.as_u64().with_context(|| {
            format!("'{key}' must be a non-negative integer")
        })?)),
    }
}

/// Parse one request line (strict; see module docs).
pub fn parse_request(line: &str) -> Result<Request> {
    let v = JsonValue::parse(line.trim())?;
    let JsonValue::Obj(fields) = &v else {
        bail!("request must be a JSON object");
    };
    let cmd = v
        .get("cmd")
        .context("missing 'cmd'")?
        .as_str()
        .context("'cmd' must be a string")?;
    let allowed: &[&str] = match cmd {
        "submit" => &[
            "cmd",
            "kind",
            "preset",
            "spec_toml",
            "seed",
            "replicates",
            "j",
        ],
        "status" | "result" => &["cmd", "job"],
        "stats" => &["cmd", "format"],
        "shutdown" => &["cmd"],
        other => bail!(
            "unknown cmd '{other}' (expected submit, status, result, \
             stats or shutdown)"
        ),
    };
    for (k, _) in fields {
        ensure!(
            allowed.contains(&k.as_str()),
            "unknown key '{k}' for cmd '{cmd}'"
        );
    }
    let job = |v: &JsonValue| -> Result<u64> {
        u64_field(v, "job")?.context("'job' is required")
    };
    Ok(match cmd {
        "submit" => Request::Submit(SubmitReq {
            kind: str_field(&v, "kind")?,
            preset: str_field(&v, "preset")?,
            spec_toml: str_field(&v, "spec_toml")?,
            seed: u64_field(&v, "seed")?,
            replicates: u64_field(&v, "replicates")?,
            j: u64_field(&v, "j")?,
        }),
        "status" => Request::Status { job: job(&v)? },
        "result" => Request::Result { job: job(&v)? },
        "stats" => {
            let prom = match str_field(&v, "format")?.as_deref() {
                None | Some("json") => false,
                Some("prom") => true,
                Some(other) => bail!(
                    "format must be \"json\" or \"prom\", got '{other}'"
                ),
            };
            Request::Stats { prom }
        }
        _ => Request::Shutdown,
    })
}

// ---------------------------------------------------- request builders

/// Render a submit request line (the client half of `parse_request`).
pub fn submit_request_json(req: &SubmitReq) -> String {
    let mut out = String::from("{\"cmd\": \"submit\"");
    for (key, val) in [
        ("kind", &req.kind),
        ("preset", &req.preset),
        ("spec_toml", &req.spec_toml),
    ] {
        if let Some(s) = val {
            out.push_str(&format!(", \"{key}\": \"{}\"", esc(s)));
        }
    }
    for (key, val) in
        [("seed", req.seed), ("replicates", req.replicates), ("j", req.j)]
    {
        if let Some(n) = val {
            out.push_str(&format!(", \"{key}\": {n}"));
        }
    }
    out.push('}');
    out
}

/// Render a `status` / `result` request line.
pub fn job_request_json(cmd: &str, job: u64) -> String {
    format!("{{\"cmd\": \"{cmd}\", \"job\": {job}}}")
}

/// Render a `stats` / `shutdown` request line.
pub fn bare_request_json(cmd: &str) -> String {
    format!("{{\"cmd\": \"{cmd}\"}}")
}

/// Render a `stats` request asking for the Prometheus exposition.
pub fn prom_stats_request_json() -> String {
    "{\"cmd\": \"stats\", \"format\": \"prom\"}".to_string()
}

// --------------------------------------------------- response builders

/// Everything a response needs to say about one job — a plain snapshot
/// so rendering happens outside the registry lock.
#[derive(Clone, Debug)]
pub struct JobView {
    pub id: u64,
    pub state: &'static str,
    pub name: String,
    pub fingerprint: u64,
    pub cached: bool,
    pub coalesced: bool,
    pub digest: Option<u64>,
    pub payload: Option<Arc<String>>,
    pub error: Option<String>,
}

/// Service counters for the `stats` response, already sampled.
#[derive(Clone, Copy, Debug, Default)]
pub struct StatsView {
    pub uptime_s: f64,
    pub requests: u64,
    pub submits: u64,
    pub tier_a_hits: u64,
    pub tier_a_misses: u64,
    pub tier_a_entries: u64,
    pub tier_b_hits: u64,
    pub tier_b_misses: u64,
    pub tier_b_entries: u64,
    pub coalesced: u64,
    pub queue_depth: u64,
    pub jobs_done: u64,
    pub jobs_failed: u64,
    pub pool_jobs: u64,
    pub exec_seconds: f64,
}

pub fn err_response(msg: &str) -> String {
    format!("{{\"ok\": false, \"error\": \"{}\"}}", esc(msg))
}

fn job_head(j: &JobView) -> String {
    format!(
        "\"job\": {}, \"state\": \"{}\", \"name\": \"{}\", \
         \"fingerprint\": \"{:016x}\", \"cached\": {}",
        j.id,
        j.state,
        esc(&j.name),
        j.fingerprint,
        j.cached
    )
}

pub fn submit_response(j: &JobView) -> String {
    let mut out =
        format!("{{\"ok\": true, {}, \"coalesced\": {}", job_head(j), j.coalesced);
    if let Some(d) = j.digest {
        out.push_str(&format!(", \"digest\": \"{d:016x}\""));
    }
    out.push('}');
    out
}

pub fn status_response(j: &JobView) -> String {
    let mut out = format!("{{\"ok\": true, {}", job_head(j));
    if let Some(d) = j.digest {
        out.push_str(&format!(", \"digest\": \"{d:016x}\""));
    }
    if let Some(e) = &j.error {
        out.push_str(&format!(", \"error\": \"{}\"", esc(e)));
    }
    out.push('}');
    out
}

pub fn result_response(j: &JobView) -> String {
    match (j.state, &j.payload, &j.error) {
        ("done", Some(payload), _) => format!(
            "{{\"ok\": true, {}, \"digest\": \"{:016x}\", \"result\": {}}}",
            job_head(j),
            j.digest.unwrap_or(0),
            payload
        ),
        ("failed", _, Some(e)) => {
            err_response(&format!("job {} failed: {e}", j.id))
        }
        (state, _, _) => err_response(&format!(
            "job {} is still {state}; poll status until it is done",
            j.id
        )),
    }
}

pub fn stats_response(s: &StatsView) -> String {
    let executed = s.jobs_done + s.jobs_failed;
    let jobs_per_sec = if s.exec_seconds > 1e-12 {
        s.pool_jobs as f64 / s.exec_seconds
    } else {
        0.0
    };
    let avg_exec_s = if executed > 0 {
        s.exec_seconds / executed as f64
    } else {
        0.0
    };
    format!(
        "{{\"ok\": true, \"uptime_s\": {}, \"requests\": {}, \
         \"submits\": {}, \"tier_a_hits\": {}, \"tier_a_misses\": {}, \
         \"tier_a_entries\": {}, \"tier_b_hits\": {}, \
         \"tier_b_misses\": {}, \"tier_b_entries\": {}, \
         \"coalesced\": {}, \"queue_depth\": {}, \"jobs_done\": {}, \
         \"jobs_failed\": {}, \"pool_jobs\": {}, \"exec_seconds\": {}, \
         \"jobs_per_sec\": {}, \"avg_exec_s\": {}}}",
        num(s.uptime_s),
        s.requests,
        s.submits,
        s.tier_a_hits,
        s.tier_a_misses,
        s.tier_a_entries,
        s.tier_b_hits,
        s.tier_b_misses,
        s.tier_b_entries,
        s.coalesced,
        s.queue_depth,
        s.jobs_done,
        s.jobs_failed,
        s.pool_jobs,
        num(s.exec_seconds),
        num(jobs_per_sec),
        num(avg_exec_s),
    )
}

/// Wrap a Prometheus text exposition in the one-line JSON envelope:
/// the exposition's newlines are escaped into the `"prom"` string, so
/// the wire stays one line per reply. Clients unescape by parsing the
/// line and reading the field.
pub fn prom_stats_response(exposition: &str) -> String {
    format!("{{\"ok\": true, \"prom\": \"{}\"}}", esc(exposition))
}

/// Flatten a multi-line JSON document to one wire line: newlines (and
/// the indentation that follows them) are dropped *outside* strings.
/// Safe for every payload this crate emits — `esc` never leaves a raw
/// newline inside a string literal.
pub fn compact_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut in_str = false;
    let mut escaped = false;
    let mut chars = s.chars().peekable();
    while let Some(c) = chars.next() {
        if in_str {
            out.push(c);
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_str = false;
            }
            continue;
        }
        match c {
            '"' => {
                in_str = true;
                out.push(c);
            }
            '\n' | '\r' => {
                while matches!(chars.peek(), Some(' ' | '\t')) {
                    chars.next();
                }
            }
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_builders_round_trip_through_parse() {
        let req = SubmitReq {
            kind: Some("sweep".into()),
            preset: Some("fig3".into()),
            spec_toml: None,
            seed: Some(7),
            replicates: Some(2),
            j: None,
        };
        let line = submit_request_json(&req);
        assert_eq!(parse_request(&line).unwrap(), Request::Submit(req));
        // an inline spec body with newlines and quotes survives the wire
        let req = SubmitReq {
            spec_toml: Some("name = \"x\"\n[job]\nn = 4\n".into()),
            ..Default::default()
        };
        let line = submit_request_json(&req);
        assert!(!line.contains('\n'), "wire lines must be single-line");
        assert_eq!(parse_request(&line).unwrap(), Request::Submit(req));
        assert_eq!(
            parse_request(&job_request_json("status", 3)).unwrap(),
            Request::Status { job: 3 }
        );
        assert_eq!(
            parse_request(&job_request_json("result", 0)).unwrap(),
            Request::Result { job: 0 }
        );
        assert_eq!(
            parse_request(&bare_request_json("stats")).unwrap(),
            Request::Stats { prom: false }
        );
        assert_eq!(
            parse_request(&prom_stats_request_json()).unwrap(),
            Request::Stats { prom: true }
        );
        assert_eq!(
            parse_request("{\"cmd\": \"stats\", \"format\": \"json\"}")
                .unwrap(),
            Request::Stats { prom: false }
        );
        let e = parse_request("{\"cmd\": \"stats\", \"format\": \"xml\"}")
            .unwrap_err()
            .to_string();
        assert!(e.contains("\"json\" or \"prom\""), "{e}");
        assert_eq!(
            parse_request(&bare_request_json("shutdown")).unwrap(),
            Request::Shutdown
        );
    }

    #[test]
    fn unknown_cmds_and_keys_rejected_by_name() {
        let e = parse_request("{\"cmd\": \"frobnicate\"}")
            .unwrap_err()
            .to_string();
        assert!(e.contains("unknown cmd 'frobnicate'"), "{e}");
        let e = parse_request("{\"cmd\": \"submit\", \"sede\": 1}")
            .unwrap_err()
            .to_string();
        assert!(e.contains("unknown key 'sede'"), "{e}");
        let e = parse_request("{\"cmd\": \"stats\", \"job\": 1}")
            .unwrap_err()
            .to_string();
        assert!(e.contains("unknown key 'job'"), "{e}");
        // missing / mistyped required fields
        assert!(parse_request("{\"cmd\": \"status\"}")
            .unwrap_err()
            .to_string()
            .contains("'job' is required"));
        assert!(parse_request("{\"cmd\": \"status\", \"job\": -1}")
            .unwrap_err()
            .to_string()
            .contains("non-negative integer"));
        assert!(parse_request("[1]").unwrap_err().to_string().contains(
            "JSON object"
        ));
        // malformed JSON surfaces the reader's byte-offset errors
        assert!(parse_request("{\"cmd\": ")
            .unwrap_err()
            .to_string()
            .contains("byte"));
    }

    #[test]
    fn compact_json_is_string_aware() {
        let doc = "{\n  \"a\": \"ke\\\"ep\",\n  \"b\": [1,\n    2]\n}\n";
        assert_eq!(compact_json(doc), "{\"a\": \"ke\\\"ep\", \"b\": [1,2]}");
        // a \n *escape* inside a string is content, not layout
        let doc = "{\n  \"s\": \"line\\u000abreak\"\n}";
        assert_eq!(compact_json(doc), "{\"s\": \"line\\u000abreak\"}");
    }

    #[test]
    fn responses_are_single_line_and_parse_back() {
        let view = JobView {
            id: 2,
            state: "done",
            name: "fig3".into(),
            fingerprint: 0xabc,
            cached: true,
            coalesced: false,
            digest: Some(0x1234),
            payload: Some(Arc::new("{\"scenario\": \"fig3\"}".into())),
            error: None,
        };
        for line in [
            submit_response(&view),
            status_response(&view),
            result_response(&view),
            err_response("bad \"spec\""),
            stats_response(&StatsView::default()),
        ] {
            assert!(!line.contains('\n'), "{line}");
            let v = JsonValue::parse(&line).unwrap();
            assert!(v.get("ok").is_some(), "{line}");
        }
        let v = JsonValue::parse(&result_response(&view)).unwrap();
        assert_eq!(v.get("digest").unwrap().as_str(), Some("0000000000001234"));
        assert_eq!(
            v.get("result").unwrap().get("scenario").unwrap().as_str(),
            Some("fig3")
        );
        // result on an unfinished job is a clean error, not a panic
        let queued = JobView {
            state: "queued",
            digest: None,
            payload: None,
            ..view
        };
        let v = JsonValue::parse(&result_response(&queued)).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
    }

    /// The registry unification must not move a byte of the JSON
    /// `stats` reply: this pins the exact wire line for a fixed view.
    #[test]
    fn stats_response_bytes_are_pinned() {
        let s = StatsView {
            uptime_s: 1.5,
            requests: 10,
            submits: 4,
            tier_a_hits: 1,
            tier_a_misses: 3,
            tier_a_entries: 2,
            tier_b_hits: 5,
            tier_b_misses: 6,
            tier_b_entries: 7,
            coalesced: 1,
            queue_depth: 0,
            jobs_done: 2,
            jobs_failed: 0,
            pool_jobs: 24,
            exec_seconds: 2.0,
        };
        assert_eq!(
            stats_response(&s),
            "{\"ok\": true, \"uptime_s\": 1.5, \"requests\": 10, \
             \"submits\": 4, \"tier_a_hits\": 1, \"tier_a_misses\": 3, \
             \"tier_a_entries\": 2, \"tier_b_hits\": 5, \
             \"tier_b_misses\": 6, \"tier_b_entries\": 7, \
             \"coalesced\": 1, \"queue_depth\": 0, \"jobs_done\": 2, \
             \"jobs_failed\": 0, \"pool_jobs\": 24, \"exec_seconds\": 2, \
             \"jobs_per_sec\": 12, \"avg_exec_s\": 1}"
        );
    }

    #[test]
    fn prom_response_round_trips_the_exposition() {
        let exposition =
            "# TYPE volatile_sgd_serve_requests_total counter\n\
             volatile_sgd_serve_requests_total 3\n";
        let line = prom_stats_response(exposition);
        assert!(!line.contains('\n'), "{line}");
        let v = JsonValue::parse(&line).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(
            v.get("prom").unwrap().as_str(),
            Some(exposition),
            "escaping must round-trip the exposition exactly"
        );
    }
}
