//! Planner-as-a-service: a resident daemon with a warm artifact cache.
//!
//! `volatile-sgd serve --listen 127.0.0.1:2020` turns the offline
//! sweep/optimize machinery into a long-lived service (DESIGN.md §9).
//! Clients submit spec TOML (inline or a shipped preset name) over a
//! newline-delimited JSON protocol ([`protocol`]); every submission is
//! validated with the same machinery as `--check`, fingerprinted
//! content-addressably, and admitted FIFO to ONE shared sweep pool.
//! Repeat work never recomputes:
//!
//! * **tier A** — finished reports, keyed by the full request
//!   fingerprint (spec fingerprint + effective seed/replicates);
//! * **tier B** — prepared per-grid-point artifacts
//!   ([`crate::exp::PrepareCache`]), keyed by point fingerprint and
//!   shared behind `Arc` across *overlapping* grids, so a submission
//!   that moves one axis value only prepares the novel points.
//!
//! Determinism contract: a daemon result — cold, warm or partially
//! warm — carries the same FNV digest line as the offline CLI run of
//! the same spec and seed, at any `--threads` (the executor reuses
//! `run_sweep_batched` / `run_plan_cached`, whose digests are already
//! thread-count-invariant, and caching only short-circuits pure
//! recomputation). Shutdown (SIGINT or the `shutdown` command) drains:
//! open connections finish, admitted jobs complete, new submissions are
//! rejected, and a [`DrainReport`] summarises the session.

pub mod client;
pub mod protocol;
pub mod state;

use std::io::{self, BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use crate::exp::presets::PRESET_NAMES;
use crate::exp::{ScenarioSpec, SpecScenario};
use crate::opt::{self, PlanSpec};
use crate::sweep::Scenario;

use crate::obs::render_prometheus;
use protocol::{
    err_response, parse_request, prom_stats_response, result_response,
    stats_response, status_response, submit_response, Request,
};
use state::{executor_loop, preset_text, ServerState, WorkItem};

/// How the daemon listens and executes.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// listen address, e.g. `127.0.0.1:2020` (`:0` picks an ephemeral
    /// port — the bound address is reported by [`Server::local_addr`])
    pub listen: String,
    /// worker threads for the one shared sweep pool
    pub threads: usize,
}

/// What a drained daemon hands back to its caller.
#[derive(Clone, Copy, Debug)]
pub struct DrainReport {
    pub jobs_done: u64,
    pub jobs_failed: u64,
    pub pool_jobs: u64,
    pub uptime_s: f64,
}

/// Set by the SIGINT handler; the accept loop polls it.
static SIGINT_HIT: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" fn on_sigint(_signum: i32) {
    SIGINT_HIT.store(true, Ordering::SeqCst);
}

/// Route SIGINT to a graceful drain instead of process death. Raw
/// `signal(2)` through the libc std already links — no new crates.
#[cfg(unix)]
pub fn install_sigint_handler() {
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    unsafe {
        signal(SIGINT, on_sigint);
    }
}

#[cfg(not(unix))]
pub fn install_sigint_handler() {}

/// A bound, not-yet-running daemon: the listener plus the executor
/// thread consuming the admission queue.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
    executor: Option<thread::JoinHandle<()>>,
}

impl Server {
    /// Bind the listener and start the executor thread. The accept
    /// loop itself runs in [`Server::run`].
    pub fn bind(cfg: &ServeConfig) -> Result<Server> {
        ensure!(cfg.threads > 0, "serve needs at least one worker thread");
        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("binding {}", cfg.listen))?;
        listener
            .set_nonblocking(true)
            .context("making the listener non-blocking")?;
        let (state, rx) = ServerState::new(cfg.threads);
        let executor = spawn_executor(&state, rx)?;
        Ok(Server { listener, state, executor: Some(executor) })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().context("reading bound address")
    }

    /// Shared state handle (in-process tests drive the daemon and read
    /// its metrics through this).
    pub fn state(&self) -> Arc<ServerState> {
        Arc::clone(&self.state)
    }

    /// Accept until shutdown (SIGINT or the `shutdown` command), then
    /// drain: join open connections, close the admission queue so the
    /// executor finishes every admitted job, and report the session.
    pub fn run(mut self) -> Result<DrainReport> {
        let mut conns: Vec<thread::JoinHandle<()>> = Vec::new();
        loop {
            if SIGINT_HIT.load(Ordering::SeqCst)
                || self.state.shutdown.load(Ordering::SeqCst)
            {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let state = Arc::clone(&self.state);
                    conns.push(thread::spawn(move || {
                        handle_conn(&state, stream);
                    }));
                    conns.retain(|h| !h.is_finished());
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    thread::sleep(Duration::from_millis(25));
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e).context("accepting a connection"),
            }
        }
        for h in conns {
            let _ = h.join();
        }
        self.state.close_queue();
        if let Some(h) = self.executor.take() {
            let _ = h.join();
        }
        let s = self.state.stats_view();
        Ok(DrainReport {
            jobs_done: s.jobs_done,
            jobs_failed: s.jobs_failed,
            pool_jobs: s.pool_jobs,
            uptime_s: s.uptime_s,
        })
    }
}

fn spawn_executor(
    state: &Arc<ServerState>,
    rx: Receiver<WorkItem>,
) -> Result<thread::JoinHandle<()>> {
    let state = Arc::clone(state);
    thread::Builder::new()
        .name("serve-executor".into())
        .spawn(move || executor_loop(&state, rx))
        .context("spawning the executor thread")
}

/// One connection: read one request line, write one response line.
/// I/O failures only cost this connection, never the daemon.
fn handle_conn(state: &Arc<ServerState>, stream: TcpStream) {
    let _ = serve_one(state, stream);
}

fn serve_one(state: &Arc<ServerState>, stream: TcpStream) -> io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    stream.set_write_timeout(Some(Duration::from_secs(30)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    if line.trim().is_empty() {
        return Ok(());
    }
    let response = dispatch(state, &line);
    let mut stream = reader.into_inner();
    stream.write_all(response.as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()
}

/// Route one request line to the state machine; every outcome —
/// including a parse or validation error — is a single `ok`-flagged
/// response line.
pub fn dispatch(state: &Arc<ServerState>, line: &str) -> String {
    state.metrics.requests.inc();
    let req = match parse_request(line) {
        Ok(req) => req,
        Err(e) => return err_response(&format!("{e:#}")),
    };
    match req {
        Request::Submit(req) => match state.submit(req) {
            Ok(ack) => submit_response(&ack.view),
            Err(e) => err_response(&format!("{e:#}")),
        },
        Request::Status { job } => match state.job_view(job) {
            Ok(view) => status_response(&view),
            Err(e) => err_response(&format!("{e:#}")),
        },
        Request::Result { job } => match state.job_view(job) {
            Ok(view) => result_response(&view),
            Err(e) => err_response(&format!("{e:#}")),
        },
        Request::Stats { prom: false } => {
            stats_response(&state.stats_view())
        }
        Request::Stats { prom: true } => {
            // Gauges (queue depth, cache sizes, uptime) are sampled at
            // exposition time; counters and histograms are already live
            // in the registry.
            state.sync_gauges();
            prom_stats_response(&render_prometheus(&state.registry))
        }
        Request::Shutdown => {
            state.shutdown.store(true, Ordering::SeqCst);
            "{\"ok\": true, \"draining\": true}".to_string()
        }
    }
}

/// `volatile-sgd serve --check`: validate the listener address and
/// prove every shipped preset loads, resolves and fingerprints —
/// without binding a socket or running a single replicate. Returns the
/// auditable one-line summary.
pub fn check(listen: &str) -> Result<String> {
    let addrs: Vec<SocketAddr> = listen
        .to_socket_addrs()
        .with_context(|| format!("listen address '{listen}'"))?
        .collect();
    ensure!(
        !addrs.is_empty(),
        "listen address '{listen}' resolves to no socket address"
    );
    let mut points = 0usize;
    for name in PRESET_NAMES {
        let spec = ScenarioSpec::from_str(preset_text(name)?)
            .with_context(|| format!("preset '{name}'"))?;
        let scenario = SpecScenario::new(spec)
            .with_context(|| format!("preset '{name}'"))?;
        for p in 0..scenario.points() {
            scenario
                .point_fingerprint(p)
                .with_context(|| format!("preset '{name}' point {p}"))?;
        }
        points += scenario.points();
    }
    let plan = PlanSpec::from_str(preset_text("optimize_deadline")?)
        .context("preset 'optimize_deadline'")?;
    opt::build_scenario(&plan).context("preset 'optimize_deadline'")?;
    let _ = plan.fingerprint();
    Ok(format!(
        "check OK: listen '{listen}' resolves to {} address(es); \
         {} sweep presets ({points} points fingerprinted) + 1 planner \
         preset validate; protocol v1",
        addrs.len(),
        PRESET_NAMES.len(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_validates_every_shipped_preset() {
        let line = check("127.0.0.1:2020").unwrap();
        assert!(line.starts_with("check OK:"), "{line}");
        assert!(line.contains("10 sweep presets"), "{line}");
        assert!(line.contains("1 planner preset"), "{line}");
        // an unresolvable listen address fails loudly
        assert!(check("not an address").is_err());
    }

    #[test]
    fn dispatch_turns_every_failure_into_an_ok_false_line() {
        let (state, _rx) = ServerState::new(1);
        for bad in [
            "not json",
            "{\"cmd\": \"frobnicate\"}",
            "{\"cmd\": \"status\", \"job\": 99}",
            "{\"cmd\": \"submit\", \"preset\": \"fig9\"}",
        ] {
            let resp = dispatch(&state, bad);
            let v = crate::util::json::JsonValue::parse(&resp).unwrap();
            assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{bad}");
            assert!(!resp.contains('\n'));
        }
        assert_eq!(state.stats_view().requests, 4);
    }

    #[test]
    fn prom_stats_reply_is_a_well_formed_exposition() {
        use crate::obs::looks_well_formed;
        use crate::util::json::JsonValue;
        let (state, _rx) = ServerState::new(1);
        // burn two requests so the counter is provably nonzero
        let _ = dispatch(&state, "{\"cmd\": \"stats\"}");
        let resp = dispatch(&state, "{\"cmd\": \"stats\", \"format\": \"prom\"}");
        let v = JsonValue::parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        let text = v.get("prom").unwrap().as_str().unwrap().to_string();
        assert!(looks_well_formed(&text), "{text}");
        assert!(
            text.contains("volatile_sgd_serve_requests_total 2"),
            "{text}"
        );
        // gauges were synced at exposition time
        assert!(text.contains("volatile_sgd_serve_queue_depth 0"), "{text}");
        // histogram families render with cumulative buckets
        assert!(
            text.contains("volatile_sgd_serve_job_execute_us_bucket"),
            "{text}"
        );
    }

    #[test]
    fn shutdown_request_flips_the_drain_flag() {
        let (state, _rx) = ServerState::new(1);
        let resp = dispatch(&state, "{\"cmd\": \"shutdown\"}");
        assert!(resp.contains("\"draining\": true"), "{resp}");
        assert!(state.shutdown.load(Ordering::SeqCst));
    }
}
