//! Thin daemon client: one TCP connection per request, one JSON line
//! each way. Backs the `submit` / `status` / `result` / `stats` /
//! `shutdown` CLI subcommands and the integration tests.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::util::json::JsonValue;

use super::protocol::{job_request_json, prom_stats_request_json};

/// Send one request line, read the single response line, enforce the
/// `ok` flag (a server-side error becomes an `Err` carrying the
/// server's message) and hand back the parsed body plus the raw line
/// (which the CLI prints verbatim).
pub fn roundtrip_raw(addr: &str, line: &str) -> Result<(JsonValue, String)> {
    let stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting to {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(120)))?;
    stream.set_write_timeout(Some(Duration::from_secs(30)))?;
    let mut writer = stream.try_clone().context("cloning stream")?;
    writer.write_all(line.as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;
    let mut response = String::new();
    BufReader::new(stream)
        .read_line(&mut response)
        .context("reading response")?;
    let raw = response.trim().to_string();
    let v = JsonValue::parse(&raw)
        .with_context(|| format!("parsing response line {raw:?}"))?;
    match v.get("ok").and_then(JsonValue::as_bool) {
        Some(true) => Ok((v, raw)),
        Some(false) => {
            let msg = v
                .get("error")
                .and_then(JsonValue::as_str)
                .unwrap_or("unknown error");
            bail!("server: {msg}")
        }
        None => bail!("malformed response (no 'ok' flag): {raw}"),
    }
}

/// [`roundtrip_raw`] when only the parsed body matters.
pub fn roundtrip(addr: &str, line: &str) -> Result<JsonValue> {
    roundtrip_raw(addr, line).map(|(v, _)| v)
}

/// `stats --prom`: fetch the Prometheus text exposition. The wire
/// reply carries it JSON-escaped in one line; this unwraps it back to
/// the multi-line text a scraper (or a human) expects.
pub fn fetch_prom(addr: &str) -> Result<String> {
    let v = roundtrip(addr, &prom_stats_request_json())?;
    match v.get("prom").and_then(JsonValue::as_str) {
        Some(text) => Ok(text.to_string()),
        None => bail!("malformed prom stats reply (no 'prom' field)"),
    }
}

/// Poll `status` until the job settles, then fetch `result`. A failed
/// job surfaces as an `Err` carrying the server's failure message (the
/// `result` command reports it).
pub fn wait_result(
    addr: &str,
    job: u64,
    timeout: Duration,
) -> Result<(JsonValue, String)> {
    let t0 = Instant::now();
    loop {
        let status = roundtrip(addr, &job_request_json("status", job))?;
        if matches!(
            status.get("state").and_then(JsonValue::as_str),
            Some("done" | "failed")
        ) {
            return roundtrip_raw(addr, &job_request_json("result", job));
        }
        if t0.elapsed() > timeout {
            bail!("timed out after {timeout:?} waiting for job {job}");
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}
