//! The Layer-3 coordinator: the paper's system contribution.
//!
//! * [`aggregate`] — the gradient-sum/update hot path (zero-alloc,
//!   unrolled; benchmarked in `hotpath`);
//! * [`server`] — the parameter server owning flat theta (eq. 5 with
//!   n -> y_j), with checkpoint/restore for preemption recovery;
//! * [`backend`] — what a "gradient step" means: real PJRT execution of
//!   the AOT artifacts, or the Theorem-1 synthetic recursion for fast
//!   full-J figure sweeps;
//! * [`strategy`] — the bidding / provisioning policies of Secs. IV–VI
//!   (No-interruptions, Optimal-one-bid, Optimal-two-bids, Dynamic
//!   rebidding, static-n, dynamic-n_j);
//! * [`scheduler`] — the lockstep façade over the discrete-event
//!   engine (`sim::engine`): the virtual-clock training loop tying
//!   market, preemption, runtime model, backend and strategy together,
//!   plus the verbatim pre-engine loop kept as the determinism oracle.

pub mod aggregate;
pub mod backend;
pub mod scheduler;
pub mod server;
pub mod strategy;

pub use aggregate::GradAccumulator;
pub use backend::{RealBackend, StepStats, SyntheticBackend, TrainingBackend};
pub use scheduler::{RunResult, Scheduler, SchedulerParams};
pub use server::ParameterServer;
pub use strategy::{Strategy, StrategyState};
