//! The parameter server: owns flat theta, applies eq. (5) with the
//! iteration's actual active count y_j, survives preemptions via
//! checkpoints.
//!
//! Deployed per the paper: the PS lives on a reliable (on-demand)
//! instance, so its state never disappears — but *workers* do, and the
//! checkpoint/restore path is what lets a fresh worker VM rejoin without
//! a handshake beyond fetching theta (persistent spot requests resume
//! exactly this way).

use crate::coordinator::aggregate::GradAccumulator;

/// Synchronous-SGD parameter server state.
///
/// Optionally applies heavy-ball momentum (`v <- m v + mean_grad;
/// theta <- theta - lr v`). The paper's analysis is plain SGD (momentum
/// 0, the default); the transformer e2e example needs momentum to make
/// progress at CPU-feasible step counts.
#[derive(Clone, Debug)]
pub struct ParameterServer {
    theta: Vec<f32>,
    acc: GradAccumulator,
    lr: f32,
    momentum: f32,
    velocity: Vec<f32>,
    iter: u64,
}

/// A point-in-time checkpoint (theta + iteration counter).
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub theta: Vec<f32>,
    pub iter: u64,
}

impl ParameterServer {
    pub fn new(theta0: Vec<f32>, lr: f32) -> Self {
        let d = theta0.len();
        ParameterServer {
            theta: theta0,
            acc: GradAccumulator::new(d),
            lr,
            momentum: 0.0,
            velocity: Vec::new(),
            iter: 0,
        }
    }

    /// Enable heavy-ball momentum (0.0 disables; allocates the velocity
    /// buffer lazily).
    pub fn set_momentum(&mut self, momentum: f32) {
        assert!((0.0..1.0).contains(&momentum), "momentum in [0,1)");
        self.momentum = momentum;
        if momentum > 0.0 && self.velocity.is_empty() {
            self.velocity = vec![0.0; self.theta.len()];
        }
    }

    pub fn theta(&self) -> &[f32] {
        &self.theta
    }

    pub fn d(&self) -> usize {
        self.theta.len()
    }

    pub fn iter(&self) -> u64 {
        self.iter
    }

    pub fn lr(&self) -> f32 {
        self.lr
    }

    pub fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }

    /// Start a new iteration: clear the accumulator.
    pub fn begin_iteration(&mut self) {
        self.acc.reset();
    }

    /// Receive one worker's gradient.
    pub fn push_gradient(&mut self, grad: &[f32]) {
        self.acc.add(grad);
    }

    /// Borrow-split accessor for the gradient fan-in: workers read theta
    /// while the accumulator collects their gradients (disjoint fields, so
    /// no aliasing gymnastics in the backend).
    pub fn split_mut(&mut self) -> (&[f32], &mut GradAccumulator) {
        (&self.theta, &mut self.acc)
    }

    /// Aggregate + update. Returns the number of gradients averaged
    /// (0 = no update happened; the scheduler never calls this with an
    /// empty active set, but defensive anyway).
    pub fn finish_iteration(&mut self) -> u32 {
        let k = self.acc.count();
        if k == 0 {
            return 0;
        }
        if self.momentum > 0.0 {
            // v <- m v + mean_grad; theta <- theta - lr v
            let mean = self.acc.mean();
            for ((v, g), t) in self
                .velocity
                .iter_mut()
                .zip(&mean)
                .zip(&mut self.theta)
            {
                *v = self.momentum * *v + *g;
                *t -= self.lr * *v;
            }
            self.iter += 1;
        } else if self.acc.apply_into(&mut self.theta, self.lr) {
            self.iter += 1;
        }
        k
    }

    pub fn checkpoint(&self) -> Checkpoint {
        Checkpoint { theta: self.theta.clone(), iter: self.iter }
    }

    pub fn restore(&mut self, ck: &Checkpoint) {
        assert_eq!(ck.theta.len(), self.theta.len(), "checkpoint width");
        self.theta.clone_from(&ck.theta);
        self.iter = ck.iter;
        self.acc.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grad_const(d: usize, v: f32) -> Vec<f32> {
        vec![v; d]
    }

    #[test]
    fn update_averages_active_workers_only() {
        // eq. (5) with y_j = 2 out of n = 4 provisioned
        let mut ps = ParameterServer::new(vec![1.0; 4], 0.5);
        ps.begin_iteration();
        ps.push_gradient(&grad_const(4, 2.0));
        ps.push_gradient(&grad_const(4, 4.0));
        assert_eq!(ps.finish_iteration(), 2);
        // theta = 1 - 0.5 * mean(2,4) = 1 - 1.5
        assert_eq!(ps.theta(), &[-0.5; 4]);
        assert_eq!(ps.iter(), 1);
    }

    #[test]
    fn empty_iteration_is_not_counted() {
        let mut ps = ParameterServer::new(vec![1.0; 2], 0.1);
        ps.begin_iteration();
        assert_eq!(ps.finish_iteration(), 0);
        assert_eq!(ps.iter(), 0);
        assert_eq!(ps.theta(), &[1.0, 1.0]);
    }

    #[test]
    fn checkpoint_restore_roundtrip() {
        let mut ps = ParameterServer::new(vec![0.0; 3], 1.0);
        ps.begin_iteration();
        ps.push_gradient(&[1.0, 2.0, 3.0]);
        ps.finish_iteration();
        let ck = ps.checkpoint();
        // diverge
        ps.begin_iteration();
        ps.push_gradient(&[9.0, 9.0, 9.0]);
        ps.finish_iteration();
        assert_ne!(ps.theta(), ck.theta.as_slice());
        ps.restore(&ck);
        assert_eq!(ps.theta(), ck.theta.as_slice());
        assert_eq!(ps.iter(), 1);
    }

    #[test]
    fn momentum_matches_manual_heavy_ball() {
        let mut ps = ParameterServer::new(vec![1.0f32; 2], 0.1);
        ps.set_momentum(0.9);
        let (mut v, mut th) = (vec![0.0f32; 2], vec![1.0f32; 2]);
        for step in 0..5 {
            let g = vec![0.5 + step as f32, -1.0];
            ps.begin_iteration();
            ps.push_gradient(&g);
            ps.finish_iteration();
            for i in 0..2 {
                v[i] = 0.9 * v[i] + g[i];
                th[i] -= 0.1 * v[i];
            }
        }
        for i in 0..2 {
            assert!((ps.theta()[i] - th[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn zero_momentum_is_plain_sgd() {
        let mut a = ParameterServer::new(vec![1.0f32; 3], 0.2);
        let mut b = ParameterServer::new(vec![1.0f32; 3], 0.2);
        b.set_momentum(0.0);
        for ps in [&mut a, &mut b] {
            ps.begin_iteration();
            ps.push_gradient(&[1.0, 2.0, 3.0]);
            ps.finish_iteration();
        }
        assert_eq!(a.theta(), b.theta());
    }

    #[test]
    fn variable_worker_counts_across_iterations() {
        // y_1 = 1, y_2 = 3: each iteration divides by its own count
        let mut ps = ParameterServer::new(vec![0.0; 1], 1.0);
        ps.begin_iteration();
        ps.push_gradient(&[3.0]);
        ps.finish_iteration();
        assert_eq!(ps.theta()[0], -3.0);
        ps.begin_iteration();
        ps.push_gradient(&[1.0]);
        ps.push_gradient(&[2.0]);
        ps.push_gradient(&[3.0]);
        ps.finish_iteration();
        assert_eq!(ps.theta()[0], -5.0);
        assert_eq!(ps.iter(), 2);
    }
}
