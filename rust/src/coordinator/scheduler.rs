//! The virtual-clock training loop: market/preemption -> active set ->
//! gradient step -> cost/time accounting (Secs. III–VI end to end).
//!
//! Semantics (matching the paper's model exactly):
//! * a *slot* begins by reading the price in effect at the current clock;
//! * the strategy resolves the active set; an empty set is **not** an SGD
//!   iteration — the clock advances by `idle_step` (the paper re-draws
//!   the price "every 4 seconds after the job is interrupted") and the
//!   wait is accounted as idle time;
//! * a non-empty set runs one synchronous iteration: duration sampled
//!   from the runtime model R(y) = max_k r_k + Delta, each active worker
//!   billed at the slot's price for the duration (prices assumed constant
//!   within an iteration, Sec. IV-B);
//! * the loop ends at the strategy's target iteration count, the deadline
//!   `theta_cap`, or a hard slot cap (runaway guard).
//!
//! Since the event-engine redesign (DESIGN.md §5) this module is the
//! *lockstep façade* over [`crate::sim::engine`]: [`Scheduler::run`]
//! wraps the strategy in a [`LockstepPolicy`] and drives the engine
//! with `OverheadModel::none()`, which consumes the RNG stream in the
//! identical order — so results are bit-identical to the pre-engine
//! loop. That pre-engine loop is kept verbatim as
//! [`Scheduler::run_reference`], the oracle the equivalence tests
//! (`tests/integration_engine.rs`) compare the engine against.

use anyhow::Result;

use crate::metrics::{Point, Series};
use crate::sim::{
    CostMeter, Engine, EngineParams, EngineResult, LockstepPolicy,
    OverheadModel, PriceSource,
};
use crate::theory::runtime_model::RuntimeModel;
use crate::util::rng::Rng;

use super::backend::TrainingBackend;
use super::strategy::{Strategy, StrategyState};

/// Loop parameters.
pub struct SchedulerParams {
    pub runtime: RuntimeModel,
    /// idle re-check interval when no workers are active (paper: 4 s)
    pub idle_step: f64,
    /// hard wall-clock cap (usually the deadline theta, or a multiple)
    pub theta_cap: f64,
    /// record a series point every `stride` iterations
    pub stride: u64,
    /// runaway guard on total slots (idle + busy)
    pub max_slots: u64,
}

impl Default for SchedulerParams {
    fn default() -> Self {
        SchedulerParams {
            runtime: RuntimeModel::paper_default(),
            idle_step: 4.0,
            theta_cap: f64::INFINITY,
            stride: 10,
            max_slots: 50_000_000,
        }
    }
}

impl SchedulerParams {
    /// The equivalent engine configuration with the paper's
    /// frictionless overhead model.
    pub fn to_engine_params(&self) -> EngineParams {
        EngineParams {
            runtime: self.runtime,
            idle_step: self.idle_step,
            theta_cap: self.theta_cap,
            stride: self.stride,
            max_slots: self.max_slots,
            overhead: OverheadModel::none(),
        }
    }
}

/// Outcome of a run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub series: Series,
    pub iters: u64,
    pub cost: f64,
    pub elapsed: f64,
    pub idle_time: f64,
    pub final_error: f64,
    pub final_accuracy: f64,
    /// true if the run hit theta_cap/max_slots before finishing J iters
    pub truncated: bool,
}

impl From<EngineResult> for RunResult {
    fn from(r: EngineResult) -> Self {
        RunResult {
            series: r.series,
            iters: r.iters,
            cost: r.cost,
            elapsed: r.elapsed,
            idle_time: r.idle_time,
            final_error: r.final_error,
            final_accuracy: r.final_accuracy,
            truncated: r.truncated,
        }
    }
}

/// Widening for the reference (pre-engine) runner: a lockstep result
/// with an all-zero overhead ledger — the one place the zero-fill is
/// spelled out.
impl From<RunResult> for EngineResult {
    fn from(r: RunResult) -> Self {
        EngineResult {
            series: r.series,
            iters: r.iters,
            cost: r.cost,
            elapsed: r.elapsed,
            idle_time: r.idle_time,
            final_error: r.final_error,
            final_accuracy: r.final_accuracy,
            truncated: r.truncated,
            preemptions: 0,
            restarts: 0,
            checkpoints: 0,
            checkpoint_time: 0.0,
            restart_time: 0.0,
            lost_iters: 0,
        }
    }
}

/// Drives one training run.
pub struct Scheduler {
    pub params: SchedulerParams,
}

impl Scheduler {
    pub fn new(params: SchedulerParams) -> Self {
        Scheduler { params }
    }

    /// Run an event-reactive [`crate::sim::Policy`] through the engine
    /// under this scheduler's loop knobs — the coordinator-level entry
    /// for the `sim::policy` suite (DESIGN.md §6). Classic strategies
    /// keep using [`Scheduler::run`], which is this method through the
    /// lockstep adapter.
    pub fn run_policy(
        &self,
        policy: &mut dyn crate::sim::Policy,
        backend: &mut dyn TrainingBackend,
        prices: &PriceSource,
        rng: &mut Rng,
    ) -> Result<RunResult> {
        let engine = Engine::new(self.params.to_engine_params());
        let res = engine.run(policy, backend, prices, rng, &mut [])?;
        Ok(res.into())
    }

    /// Run the paper's lockstep loop through the event engine
    /// (RNG-identical to [`Scheduler::run_reference`]; pinned by the
    /// engine-equivalence tests).
    pub fn run(
        &self,
        strategy: &mut dyn Strategy,
        backend: &mut dyn TrainingBackend,
        prices: &PriceSource,
        rng: &mut Rng,
    ) -> Result<RunResult> {
        self.run_policy(&mut LockstepPolicy(strategy), backend, prices, rng)
    }

    /// The pre-engine lockstep loop, kept verbatim as the determinism
    /// oracle: the engine with `OverheadModel::none()` must reproduce
    /// this function bit for bit (same RNG-consumption order, same
    /// `CostMeter` operation order). Do not "improve" this body —
    /// its value is that it does not change.
    pub fn run_reference(
        &self,
        strategy: &mut dyn Strategy,
        backend: &mut dyn TrainingBackend,
        prices: &PriceSource,
        rng: &mut Rng,
    ) -> Result<RunResult> {
        let mut meter = CostMeter::new();
        let mut series = Series::default();
        let mut iter = 0u64;
        let mut slots = 0u64;
        let mut last = (backend.error(), backend.accuracy());
        let target = strategy.target_iters();
        let mut truncated = false;

        while iter < target {
            slots += 1;
            if slots > self.params.max_slots
                || meter.elapsed() >= self.params.theta_cap
            {
                truncated = true;
                break;
            }
            let price = prices.price_at(meter.elapsed(), rng);
            let decision = strategy.decide(price, rng);
            let y = decision.active.len();
            if y == 0 {
                meter.idle(self.params.idle_step);
                continue;
            }
            let dur = self.params.runtime.sample(y, rng);
            let stats = backend.step(y, rng)?;
            meter.charge(y, decision.price, dur);
            iter += 1;
            last = (stats.error, stats.accuracy);
            strategy.on_iteration(&StrategyState {
                iter,
                clock: meter.elapsed(),
                cost: meter.cost(),
                error: stats.error,
            })?;
            if iter % self.params.stride == 0 || iter == target {
                series.push(Point {
                    clock: meter.elapsed(),
                    iter,
                    cost: meter.cost(),
                    error: stats.error,
                    accuracy: stats.accuracy,
                    active: y,
                });
            }
        }

        Ok(RunResult {
            series,
            iters: iter,
            cost: meter.cost(),
            elapsed: meter.elapsed(),
            idle_time: meter.idle_time(),
            final_error: last.0,
            final_accuracy: last.1,
            truncated,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{SyntheticBackend, TrainingBackend};
    use crate::coordinator::strategy::FixedBids;
    use crate::market::{BidVector, PriceModel};
    use crate::preempt::PreemptionModel;
    use crate::theory::bounds::{ErrorBound, SgdHyper};

    fn bound() -> ErrorBound {
        ErrorBound::new(SgdHyper::paper_cnn())
    }

    fn sched(theta_cap: f64) -> Scheduler {
        Scheduler::new(SchedulerParams {
            runtime: RuntimeModel::Deterministic { r: 10.0 },
            idle_step: 4.0,
            theta_cap,
            stride: 50,
            max_slots: 10_000_000,
        })
    }

    #[test]
    fn high_bid_never_idles() {
        let mut s = FixedBids::new("noint", BidVector::uniform(4, 1.0), 500);
        let mut b = SyntheticBackend::new(bound());
        let mut rng = Rng::new(1);
        let res = sched(f64::INFINITY)
            .run(
                &mut s,
                &mut b,
                &PriceSource::Iid(PriceModel::uniform_paper()),
                &mut rng,
            )
            .unwrap();
        assert_eq!(res.iters, 500);
        assert_eq!(res.idle_time, 0.0);
        assert!(!res.truncated);
        // deterministic runtime: elapsed = 500 * 10
        assert!((res.elapsed - 5_000.0).abs() < 1e-9);
        // cost = sum over iterations of 4 * price * 10, price ~ U[0.2, 1]
        assert!(res.cost > 4.0 * 0.2 * 5_000.0);
        assert!(res.cost < 4.0 * 1.0 * 5_000.0);
    }

    #[test]
    fn low_bid_accumulates_idle_time() {
        // bid at the 10th percentile: ~90% of slots idle
        let mut s = FixedBids::new("low", BidVector::uniform(4, 0.28), 100);
        let mut b = SyntheticBackend::new(bound());
        let mut rng = Rng::new(2);
        let res = sched(f64::INFINITY)
            .run(
                &mut s,
                &mut b,
                &PriceSource::Iid(PriceModel::uniform_paper()),
                &mut rng,
            )
            .unwrap();
        assert_eq!(res.iters, 100);
        assert!(res.idle_time > 0.0);
        // expected idle slots ~ 100 * 0.9/0.1 = 900, each 4 s
        assert!(res.idle_time > 1_000.0, "idle={}", res.idle_time);
        // paid only while running: mean price <= bid
        assert!(res.cost <= 4.0 * 0.28 * 100.0 * 10.0 + 1e-9);
    }

    #[test]
    fn deadline_cap_truncates() {
        let mut s = FixedBids::new("noint", BidVector::uniform(2, 1.0), 10_000);
        let mut b = SyntheticBackend::new(bound());
        let mut rng = Rng::new(3);
        let res = sched(500.0)
            .run(
                &mut s,
                &mut b,
                &PriceSource::Iid(PriceModel::uniform_paper()),
                &mut rng,
            )
            .unwrap();
        assert!(res.truncated);
        assert!(res.iters < 10_000);
        assert!(res.elapsed <= 500.0 + 10.0 + 1e-9);
    }

    #[test]
    fn error_matches_theorem1_with_constant_workers() {
        let j = 400u64;
        let mut s = FixedBids::new("noint", BidVector::uniform(8, 1.0), j);
        let b0 = bound();
        let mut b = SyntheticBackend::new(b0);
        let mut rng = Rng::new(4);
        let res = sched(f64::INFINITY)
            .run(
                &mut s,
                &mut b,
                &PriceSource::Iid(PriceModel::uniform_paper()),
                &mut rng,
            )
            .unwrap();
        let want = b0.phi_const(j, 1.0 / 8.0);
        assert!(
            (res.final_error - want).abs() < 1e-9,
            "{} vs {}",
            res.final_error,
            want
        );
    }

    #[test]
    fn preemptible_fixed_price_cost_accounting() {
        use crate::coordinator::strategy::StaticWorkers;
        let mut s = StaticWorkers {
            label: "static_n".to_string(),
            n: 4,
            j: 200,
            model: PreemptionModel::None,
            unit_price: 0.1,
        };
        let mut b = SyntheticBackend::new(bound());
        let mut rng = Rng::new(5);
        let res = sched(f64::INFINITY)
            .run(&mut s, &mut b, &PriceSource::Fixed(999.0), &mut rng)
            .unwrap();
        // spot price source is ignored by preemptible strategies:
        // cost = 4 workers * 0.1 * 10 s * 200 iters
        assert!((res.cost - 4.0 * 0.1 * 10.0 * 200.0).abs() < 1e-9);
    }

    #[test]
    fn series_records_stride_points() {
        let mut s = FixedBids::new("noint", BidVector::uniform(2, 1.0), 200);
        let mut b = SyntheticBackend::new(bound());
        let mut rng = Rng::new(6);
        let res = sched(f64::INFINITY)
            .run(
                &mut s,
                &mut b,
                &PriceSource::Iid(PriceModel::uniform_paper()),
                &mut rng,
            )
            .unwrap();
        assert_eq!(res.series.len(), 4); // every 50 of 200
        assert_eq!(res.series.last().unwrap().iter, 200);
        // cost series is nondecreasing
        let costs: Vec<f64> =
            res.series.points.iter().map(|p| p.cost).collect();
        assert!(costs.windows(2).all(|w| w[1] >= w[0]));
    }

    /// The engine path and the verbatim pre-engine loop must agree to
    /// the bit — every field, every series point — across strategy
    /// shapes and seeds. This is the §5 determinism contract in
    /// miniature (the preset-level version lives in
    /// tests/integration_engine.rs).
    #[test]
    fn engine_run_matches_reference_bit_for_bit() {
        use crate::coordinator::strategy::StaticWorkers;
        let prices = [
            PriceSource::Iid(PriceModel::uniform_paper()),
            PriceSource::Iid(PriceModel::gaussian_paper()),
            PriceSource::Fixed(0.3),
        ];
        for seed in [1u64, 7, 42] {
            for prices in &prices {
                // FixedBids and StaticWorkers carry no mutable run
                // state, so one instance can serve both paths in turn
                let mk: Vec<Box<dyn Strategy>> = vec![
                    Box::new(FixedBids::new(
                        "two",
                        BidVector::two_group(8, 4, 0.8, 0.4),
                        300,
                    )),
                    Box::new(StaticWorkers {
                        label: "static_n".to_string(),
                        n: 4,
                        j: 300,
                        model: PreemptionModel::Bernoulli { q: 0.5 },
                        unit_price: 0.1,
                    }),
                ];
                for mut s in mk {
                    let mut b1 = SyntheticBackend::new(bound());
                    let mut b2 = SyntheticBackend::new(bound());
                    let mut r1 = Rng::new(seed);
                    let mut r2 = Rng::new(seed);
                    let a = sched(2_000.0)
                        .run(s.as_mut(), &mut b1, prices, &mut r1)
                        .unwrap();
                    let b = sched(2_000.0)
                        .run_reference(s.as_mut(), &mut b2, prices, &mut r2)
                        .unwrap();
                    assert_eq!(a.iters, b.iters);
                    assert_eq!(a.truncated, b.truncated);
                    assert_eq!(a.cost.to_bits(), b.cost.to_bits());
                    assert_eq!(a.elapsed.to_bits(), b.elapsed.to_bits());
                    assert_eq!(a.idle_time.to_bits(), b.idle_time.to_bits());
                    assert_eq!(
                        a.final_error.to_bits(),
                        b.final_error.to_bits()
                    );
                    assert_eq!(a.series.len(), b.series.len());
                    for (x, y) in a.series.points.iter().zip(&b.series.points)
                    {
                        assert_eq!(x.iter, y.iter);
                        assert_eq!(x.clock.to_bits(), y.clock.to_bits());
                        assert_eq!(x.cost.to_bits(), y.cost.to_bits());
                        assert_eq!(x.error.to_bits(), y.error.to_bits());
                    }
                    // the generators advanced identically too
                    assert_eq!(r1.next_u64(), r2.next_u64());
                }
            }
        }
    }

    /// Regression (PR 3 satellite): a run truncated before its first
    /// iteration reports the backend's *current* error/accuracy, not
    /// `(err0, 0.0)`. A pre-warmed backend makes the old hard-coded
    /// zero visible.
    #[test]
    fn truncation_before_first_iteration_reports_backend_state() {
        let mut b = SyntheticBackend::new(bound());
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            b.step(4, &mut rng).unwrap();
        }
        let (err0, acc0) = (b.error(), b.accuracy());
        assert!(acc0 > 0.0, "warmed backend has nonzero accuracy proxy");
        let mut s = FixedBids::new("noint", BidVector::uniform(4, 1.0), 100);
        // theta_cap 0: the very first slot hits the deadline
        for reference in [false, true] {
            let mut backend = b.clone();
            let mut r = Rng::new(10);
            let sc = sched(0.0);
            let prices = PriceSource::Fixed(0.5);
            let res = if reference {
                sc.run_reference(&mut s, &mut backend, &prices, &mut r)
            } else {
                sc.run(&mut s, &mut backend, &prices, &mut r)
            }
            .unwrap();
            assert!(res.truncated);
            assert_eq!(res.iters, 0);
            assert_eq!(res.final_error.to_bits(), err0.to_bits());
            assert_eq!(res.final_accuracy.to_bits(), acc0.to_bits());
        }
    }
}
