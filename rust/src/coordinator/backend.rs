//! What a "gradient step" means to the scheduler.
//!
//! [`RealBackend`] runs the AOT artifacts over PJRT: genuine SGD on the
//! synthetic CIFAR-like dataset, with the parameter server doing the
//! aggregation. The error signal is the measured training loss.
//!
//! [`SyntheticBackend`] advances Theorem 1's recursion
//! `err <- beta err + (alpha^2 L M / 2) / y` instead of touching floats.
//! It makes full-J (10^4-iteration) strategy sweeps run in microseconds,
//! which the figure benches need; the real backend validates the same
//! orderings at reduced J (see EXPERIMENTS.md). Its "accuracy" is the
//! monotone proxy `1 - err / A` (documented in DESIGN.md §2).

use anyhow::Result;

use crate::data::{Batcher, CifarLike};
use crate::runtime::{BatchInput, ModelRuntime, WorkerPool};
use crate::theory::bounds::ErrorBound;
use crate::util::rng::Rng;

use super::server::ParameterServer;

/// Per-iteration training signal handed to the scheduler.
#[derive(Clone, Copy, Debug)]
pub struct StepStats {
    /// error measure: training loss (real) or Theorem-1 bound (synthetic)
    pub error: f64,
    /// accuracy in [0,1]: batch train accuracy (real) or 1 - err/A proxy
    pub accuracy: f64,
}

/// One synchronous-SGD iteration with `y` active workers.
pub trait TrainingBackend {
    fn step(&mut self, y: usize, rng: &mut Rng) -> Result<StepStats>;
    /// Current error estimate without stepping.
    fn error(&self) -> f64;
    /// Current accuracy estimate without stepping — what a run
    /// truncated before its first iteration reports (the proxy at
    /// start, not a hard-coded zero).
    fn accuracy(&self) -> f64 {
        0.0
    }
    /// Cheap snapshot of the learning state for the engine's
    /// checkpoint/rollback overhead model. `None` means the backend
    /// cannot roll back (lost work then only rewinds the iteration
    /// counter, never the learning signal).
    fn snapshot(&self) -> Option<f64> {
        None
    }
    /// Restore a state captured by [`TrainingBackend::snapshot`].
    fn restore(&mut self, snap: f64) {
        let _ = snap;
    }
}

// ------------------------------------------------------------- synthetic

/// Theorem-1 recursion backend.
#[derive(Clone, Debug)]
pub struct SyntheticBackend {
    bound: ErrorBound,
    err: f64,
}

impl SyntheticBackend {
    pub fn new(bound: ErrorBound) -> Self {
        let err = bound.hyper.a0;
        SyntheticBackend { bound, err }
    }

    fn acc(&self) -> f64 {
        (1.0 - self.err / self.bound.hyper.a0).clamp(0.0, 1.0)
    }
}

impl TrainingBackend for SyntheticBackend {
    fn step(&mut self, y: usize, _rng: &mut Rng) -> Result<StepStats> {
        assert!(y > 0, "synthetic step with zero workers");
        self.err = self.bound.step(self.err, y);
        Ok(StepStats { error: self.err, accuracy: self.acc() })
    }

    fn error(&self) -> f64 {
        self.err
    }

    fn accuracy(&self) -> f64 {
        self.acc()
    }

    // Theorem-1 state is one f64: checkpoint/rollback is exact.
    fn snapshot(&self) -> Option<f64> {
        Some(self.err)
    }

    fn restore(&mut self, snap: f64) {
        self.err = snap;
    }
}

// ------------------------------------------------------------------ real

/// PJRT-backed backend: real gradients on the CIFAR-like dataset.
pub struct RealBackend<'rt> {
    rt: &'rt ModelRuntime,
    pub server: ParameterServer,
    pool: WorkerPool,
    data: CifarLike,
    batcher: Batcher,
    /// scratch batch buffers
    xb: Vec<f32>,
    yb: Vec<i32>,
    /// smoothed loss (EMA) as the error estimate
    err_ema: f64,
    acc_ema: f64,
    ema_beta: f64,
    batch: usize,
}

impl<'rt> RealBackend<'rt> {
    pub fn new(
        rt: &'rt ModelRuntime,
        theta0: Vec<f32>,
        lr: f32,
        data: CifarLike,
        max_workers: usize,
        rng: &mut Rng,
    ) -> Self {
        let batch = rt.manifest.batch();
        let batcher = Batcher::new(data.n, batch, rng);
        let d = rt.d();
        RealBackend {
            rt,
            server: ParameterServer::new(theta0, lr),
            pool: WorkerPool::new(max_workers, d),
            data,
            batcher,
            xb: Vec::new(),
            yb: Vec::new(),
            err_ema: f64::NAN,
            acc_ema: 0.0,
            ema_beta: 0.05,
            batch,
        }
    }

    pub fn theta(&self) -> &[f32] {
        self.server.theta()
    }

    /// Full-dataset (first `cap` samples) evaluation via the eval artifact.
    pub fn evaluate(&mut self, cap: usize) -> Result<StepStats> {
        let nb = (self.data.n.min(cap)) / self.batch;
        let mut loss_sum = 0.0;
        let mut correct = 0.0;
        let preds = self.rt.manifest.preds_per_batch() as f64;
        for b in 0..nb.max(1) {
            let idx: Vec<usize> =
                (b * self.batch..(b + 1) * self.batch).collect();
            self.data.gather(&idx, &mut self.xb, &mut self.yb);
            let s = self.rt.eval_step(
                self.server.theta(),
                BatchInput::F32(&self.xb),
                &self.yb,
            )?;
            loss_sum += s.loss as f64;
            correct += s.correct as f64;
        }
        Ok(StepStats {
            error: loss_sum / nb.max(1) as f64,
            accuracy: correct / (nb.max(1) as f64 * preds),
        })
    }
}

impl TrainingBackend for RealBackend<'_> {
    fn step(&mut self, y: usize, rng: &mut Rng) -> Result<StepStats> {
        assert!(y > 0, "real step with zero workers");
        assert!(y <= self.pool.max_workers());
        // deal one disjoint mini-batch per active worker
        let mut flat_x: Vec<f32> = Vec::new();
        let mut flat_y: Vec<i32> = Vec::new();
        for _ in 0..y {
            let idx = self.batcher.next(rng).to_vec();
            self.data.gather(&idx, &mut self.xb, &mut self.yb);
            flat_x.extend_from_slice(&self.xb);
            flat_y.extend_from_slice(&self.yb);
        }
        let xin = self.batch * crate::data::cifar_like::DIM;
        let batches: Vec<(BatchInput<'_>, &[i32])> = (0..y)
            .map(|w| {
                (
                    BatchInput::F32(&flat_x[w * xin..(w + 1) * xin]),
                    &flat_y[w * self.batch..(w + 1) * self.batch],
                )
            })
            .collect();
        self.server.begin_iteration();
        let (theta, acc) = self.server.split_mut();
        let stats = self.pool.run_iteration(
            self.rt,
            theta,
            &batches,
            |_slot, grad, _s| acc.add(grad),
        )?;
        self.server.finish_iteration();
        let preds = self.rt.manifest.preds_per_batch() as f64;
        let acc = stats.correct as f64 / preds;
        if self.err_ema.is_nan() {
            self.err_ema = stats.loss as f64;
            self.acc_ema = acc;
        } else {
            self.err_ema = (1.0 - self.ema_beta) * self.err_ema
                + self.ema_beta * stats.loss as f64;
            self.acc_ema =
                (1.0 - self.ema_beta) * self.acc_ema + self.ema_beta * acc;
        }
        Ok(StepStats { error: self.err_ema, accuracy: self.acc_ema })
    }

    fn error(&self) -> f64 {
        if self.err_ema.is_nan() {
            f64::INFINITY
        } else {
            self.err_ema
        }
    }

    fn accuracy(&self) -> f64 {
        self.acc_ema
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::theory::bounds::SgdHyper;

    #[test]
    fn synthetic_matches_phi_seq() {
        let bound = ErrorBound::new(SgdHyper::paper_cnn());
        let mut b = SyntheticBackend::new(bound);
        let mut rng = Rng::new(1);
        let ys = [4usize, 8, 2, 8, 1];
        for &y in &ys {
            b.step(y, &mut rng).unwrap();
        }
        let rs: Vec<f64> = ys.iter().map(|&y| 1.0 / y as f64).collect();
        assert!((b.error() - bound.phi_seq(&rs)).abs() < 1e-12);
    }

    #[test]
    fn synthetic_accuracy_monotone() {
        let bound = ErrorBound::new(SgdHyper::paper_cnn());
        let mut b = SyntheticBackend::new(bound);
        let mut rng = Rng::new(2);
        let mut prev = -1.0;
        for _ in 0..200 {
            let s = b.step(8, &mut rng).unwrap();
            assert!(s.accuracy >= prev - 1e-12);
            prev = s.accuracy;
        }
        assert!(prev > 0.0);
    }

    #[test]
    #[should_panic]
    fn synthetic_zero_workers_panics() {
        let bound = ErrorBound::new(SgdHyper::paper_cnn());
        let mut b = SyntheticBackend::new(bound);
        let mut rng = Rng::new(3);
        let _ = b.step(0, &mut rng);
    }
}
