//! Coordination strategies: who is active each iteration, and how plans
//! adapt mid-run (Secs. IV–VI).
//!
//! * Spot strategies resolve the active set from the current price via a
//!   [`BidVector`]; the Dynamic strategy additionally re-optimises its
//!   bids at a stage boundary after growing the worker group, exactly as
//!   Sec. VI describes ("add four more workers and re-compute the optimal
//!   bids by subtracting the consumed time from the original deadline and
//!   taking J to be the number of remaining iterations").
//! * Preemptible strategies ignore prices and provision `n_j` workers,
//!   with the platform preempting each independently (Sec. V); the
//!   dynamic-n_j variant grows the fleet as `ceil(n0 eta^{j-1})`
//!   (Theorem 5).

use anyhow::Result;

use crate::market::process::PriceDist;
use crate::market::BidVector;
use crate::preempt::PreemptionModel;
use crate::theory::bids::BidProblem;
use crate::util::rng::Rng;

/// Observable run state handed to strategies for re-planning.
#[derive(Clone, Copy, Debug, Default)]
pub struct StrategyState {
    pub iter: u64,
    pub clock: f64,
    pub cost: f64,
    pub error: f64,
}

/// How many workers are active this iteration slot, and at what price.
#[derive(Clone, Debug)]
pub struct ActiveDecision {
    /// indices of active workers (empty = idle slot, not an iteration)
    pub active: Vec<usize>,
    /// per-worker per-time cost rate actually charged
    pub price: f64,
}

/// A lockstep coordination strategy: decides an active set per price
/// slot and reacts to completed iterations.
///
/// Superseded by the event-reactive [`crate::sim::engine::Policy`] —
/// any `Strategy` adapts into a `Policy` through the blanket
/// [`crate::sim::engine::LockstepPolicy`] wrapper (iteration events
/// map onto [`Strategy::on_iteration`], every other event is
/// ignored), so the seven `StrategyKind`s run on the engine unchanged.
pub trait Strategy {
    /// Display label. Owned (not `&'static`) so config-defined lineups
    /// can name their entries — two dynamic strategies with different
    /// stage schedules must be distinguishable in tables and CSV.
    fn name(&self) -> &str;

    /// Total SGD iterations this strategy intends to run.
    fn target_iters(&self) -> u64;

    /// Resolve the active set for the next iteration slot. `price` is the
    /// prevailing spot price (preemptible strategies may ignore it and
    /// charge their own fixed rate).
    fn decide(&mut self, price: f64, rng: &mut Rng) -> ActiveDecision;

    /// [`Strategy::decide`] into a caller-owned buffer, returning the
    /// charged price — the allocation-free form the batched replicate
    /// executor (`sim::batch`) calls on its per-slot hot path. Must
    /// consume the RNG and fill `active` exactly as `decide` would; the
    /// default delegates, concrete strategies override with their
    /// `*_into` primitives.
    fn decide_into(
        &mut self,
        price: f64,
        rng: &mut Rng,
        active: &mut Vec<usize>,
    ) -> f64 {
        let d = self.decide(price, rng);
        active.clear();
        active.extend_from_slice(&d.active);
        d.price
    }

    /// Called after every completed iteration; strategies may re-plan.
    fn on_iteration(&mut self, state: &StrategyState) -> Result<()> {
        let _ = state;
        Ok(())
    }

    /// Upper bound on concurrently active workers (pool sizing).
    fn max_workers(&self) -> usize;
}

// Delegating impls so `Box<dyn Strategy>` and `&mut dyn Strategy`
// plug straight into generic adapters like `LockstepPolicy<S>`.
impl<S: Strategy + ?Sized> Strategy for Box<S> {
    fn name(&self) -> &str {
        (**self).name()
    }

    fn target_iters(&self) -> u64 {
        (**self).target_iters()
    }

    fn decide(&mut self, price: f64, rng: &mut Rng) -> ActiveDecision {
        (**self).decide(price, rng)
    }

    fn decide_into(
        &mut self,
        price: f64,
        rng: &mut Rng,
        active: &mut Vec<usize>,
    ) -> f64 {
        (**self).decide_into(price, rng, active)
    }

    fn on_iteration(&mut self, state: &StrategyState) -> Result<()> {
        (**self).on_iteration(state)
    }

    fn max_workers(&self) -> usize {
        (**self).max_workers()
    }
}

impl<S: Strategy + ?Sized> Strategy for &mut S {
    fn name(&self) -> &str {
        (**self).name()
    }

    fn target_iters(&self) -> u64 {
        (**self).target_iters()
    }

    fn decide(&mut self, price: f64, rng: &mut Rng) -> ActiveDecision {
        (**self).decide(price, rng)
    }

    fn decide_into(
        &mut self,
        price: f64,
        rng: &mut Rng,
        active: &mut Vec<usize>,
    ) -> f64 {
        (**self).decide_into(price, rng, active)
    }

    fn on_iteration(&mut self, state: &StrategyState) -> Result<()> {
        (**self).on_iteration(state)
    }

    fn max_workers(&self) -> usize {
        (**self).max_workers()
    }
}

// ------------------------------------------------------- spot strategies

/// Fixed bid vector for the whole job: covers No-interruptions (bid the
/// support max), Optimal-one-bid (Theorem 2) and Optimal-two-bids
/// (Theorem 3), depending on the vector it is built with.
pub struct FixedBids {
    pub label: String,
    pub bids: BidVector,
    pub j: u64,
}

impl FixedBids {
    pub fn new(label: impl Into<String>, bids: BidVector, j: u64) -> Self {
        FixedBids { label: label.into(), bids, j }
    }
}

impl Strategy for FixedBids {
    fn name(&self) -> &str {
        &self.label
    }

    fn target_iters(&self) -> u64 {
        self.j
    }

    fn decide(&mut self, price: f64, _rng: &mut Rng) -> ActiveDecision {
        ActiveDecision { active: self.bids.active_set(price), price }
    }

    fn decide_into(
        &mut self,
        price: f64,
        _rng: &mut Rng,
        active: &mut Vec<usize>,
    ) -> f64 {
        self.bids.active_set_into(price, active);
        price
    }

    fn max_workers(&self) -> usize {
        self.bids.n()
    }
}

/// Sec. VI Dynamic strategy: stage 1 runs a small two-bid group; at the
/// stage boundary the fleet doubles and bids are re-optimised for the
/// remaining error/deadline budget.
pub struct DynamicBids {
    label: String,
    problem: BidProblem,
    stages: Vec<StageSpec>,
    current: usize,
    bids: BidVector,
    j_total: u64,
    stage_started_at: f64,
}

/// One stage of the dynamic plan.
#[derive(Clone, Copy, Debug)]
pub struct StageSpec {
    pub n: usize,
    pub n1: usize,
    /// iterations to run before advancing to the next stage (last stage
    /// runs to the job's total J)
    pub until_iter: u64,
}

impl DynamicBids {
    /// `problem` carries the job-level (eps, theta); stage plans target
    /// what is *achievable* at each stage's fleet size (a 4-worker first
    /// stage cannot reach a sub-noise-floor final target — it just has to
    /// make good progress per dollar until the fleet grows).
    pub fn new(
        label: impl Into<String>,
        problem: BidProblem,
        stages: Vec<StageSpec>,
        j_total: u64,
    ) -> Result<Self> {
        assert!(!stages.is_empty());
        let mut me = DynamicBids {
            label: label.into(),
            bids: BidVector::uniform(stages[0].n, 1.0), // replaced below
            problem,
            stages,
            current: 0,
            j_total,
            stage_started_at: 0.0,
        };
        let a0 = me.problem.bound.hyper.a0;
        me.replan(&StrategyState { iter: 0, clock: 0.0, cost: 0.0, error: a0 })?;
        Ok(me)
    }

    /// Re-plan from the observed run state: the generalised Theorem 3
    /// targets the job eps from the *current* error, with Q clamped into
    /// the stage's admissible band (Q <= 1/n1 means the target is slack —
    /// bid low; Q <= 1/n means it is unreachable in the remaining budget —
    /// run everything and bid deadline-tight, best effort).
    fn replan(&mut self, state: &StrategyState) -> Result<()> {
        let stage = self.stages[self.current];
        let remaining_j = self.j_total.saturating_sub(state.iter).max(1);
        let remaining_theta = (self.problem.theta - state.clock).max(1.0);
        let mut p = self.problem.clone();
        p.n = stage.n;
        p.theta = remaining_theta;
        let h = &p.bound.hyper;
        let bj = h.beta().powf(remaining_j as f64);
        let q_raw = (p.eps - bj * state.error)
            / (h.k_noise() * (1.0 - bj));
        let rn = 1.0 / stage.n as f64;
        let rn1 = 1.0 / stage.n1 as f64;
        // clamp into the stage-admissible band (the paper's condition)
        let q = q_raw.clamp(rn * 1.0001 + 1e-12, rn1);
        self.stage_started_at = state.clock;
        match p.two_bids_for_q(q, remaining_j, stage.n1) {
            Ok(plan) => {
                self.bids =
                    BidVector::two_group(stage.n, stage.n1, plan.b1, plan.b2);
                Ok(())
            }
            Err(_) => {
                // deadline-infeasible at this stage size: run the whole
                // fleet at a deadline-tight uniform bid (best effort)
                let u = (remaining_j as f64 * p.runtime.expected(stage.n)
                    / remaining_theta)
                    .clamp(1e-6, 1.0);
                let b = p.price.inv_cdf(u);
                self.bids = BidVector::uniform(stage.n, b);
                Ok(())
            }
        }
    }
}

impl Strategy for DynamicBids {
    fn name(&self) -> &str {
        &self.label
    }

    fn target_iters(&self) -> u64 {
        self.j_total
    }

    fn decide(&mut self, price: f64, _rng: &mut Rng) -> ActiveDecision {
        ActiveDecision { active: self.bids.active_set(price), price }
    }

    fn decide_into(
        &mut self,
        price: f64,
        _rng: &mut Rng,
        active: &mut Vec<usize>,
    ) -> f64 {
        self.bids.active_set_into(price, active);
        price
    }

    fn on_iteration(&mut self, state: &StrategyState) -> Result<()> {
        if self.current + 1 < self.stages.len()
            && state.iter >= self.stages[self.current].until_iter
        {
            self.current += 1;
            self.replan(state)?;
        }
        Ok(())
    }

    fn max_workers(&self) -> usize {
        self.stages.iter().map(|s| s.n).max().unwrap()
    }
}

// ------------------------------------------------ preemptible strategies

/// Sec. V static provisioning: n workers at a fixed unit price, preempted
/// by the platform per the preemption model.
pub struct StaticWorkers {
    /// display label (config lineups may run several distinct entries)
    pub label: String,
    pub n: usize,
    pub j: u64,
    pub model: PreemptionModel,
    /// fixed $/worker/time (e.g. the GCP preemptible price)
    pub unit_price: f64,
}

impl Strategy for StaticWorkers {
    fn name(&self) -> &str {
        &self.label
    }

    fn target_iters(&self) -> u64 {
        self.j
    }

    fn decide(&mut self, _price: f64, rng: &mut Rng) -> ActiveDecision {
        ActiveDecision {
            active: self.model.draw_active(self.n, rng),
            price: self.unit_price,
        }
    }

    fn decide_into(
        &mut self,
        _price: f64,
        rng: &mut Rng,
        active: &mut Vec<usize>,
    ) -> f64 {
        self.model.draw_active_into(self.n, rng, active);
        self.unit_price
    }

    fn max_workers(&self) -> usize {
        self.n
    }
}

/// Theorem 5 dynamic provisioning: n_j = ceil(n0 eta^{j-1}).
pub struct DynamicWorkers {
    pub label: String,
    pub n0: usize,
    pub eta: f64,
    pub j: u64,
    pub model: PreemptionModel,
    pub unit_price: f64,
    pub cap: usize,
    iter: u64,
}

impl DynamicWorkers {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        label: impl Into<String>,
        n0: usize,
        eta: f64,
        j: u64,
        model: PreemptionModel,
        unit_price: f64,
        cap: usize,
    ) -> Self {
        assert!(eta > 1.0, "Theorem 5 requires eta > 1");
        DynamicWorkers {
            label: label.into(),
            n0,
            eta,
            j,
            model,
            unit_price,
            cap,
            iter: 0,
        }
    }

    /// The provisioned fleet size at (0-based) iteration `j`.
    pub fn n_at(&self, j: u64) -> usize {
        ((self.n0 as f64 * self.eta.powf(j as f64)).ceil() as usize)
            .clamp(1, self.cap)
    }
}

impl Strategy for DynamicWorkers {
    fn name(&self) -> &str {
        &self.label
    }

    fn target_iters(&self) -> u64 {
        self.j
    }

    fn decide(&mut self, _price: f64, rng: &mut Rng) -> ActiveDecision {
        let n = self.n_at(self.iter);
        ActiveDecision {
            active: self.model.draw_active(n, rng),
            price: self.unit_price,
        }
    }

    fn decide_into(
        &mut self,
        _price: f64,
        rng: &mut Rng,
        active: &mut Vec<usize>,
    ) -> f64 {
        let n = self.n_at(self.iter);
        self.model.draw_active_into(n, rng, active);
        self.unit_price
    }

    fn on_iteration(&mut self, state: &StrategyState) -> Result<()> {
        self.iter = state.iter;
        Ok(())
    }

    fn max_workers(&self) -> usize {
        self.n_at(self.j.saturating_sub(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::market::PriceModel;
    use crate::theory::bounds::{ErrorBound, SgdHyper};
    use crate::theory::runtime_model::RuntimeModel;

    fn problem() -> BidProblem {
        BidProblem {
            bound: ErrorBound::new(SgdHyper::paper_cnn()),
            price: PriceModel::uniform_paper(),
            runtime: RuntimeModel::Deterministic { r: 10.0 },
            n: 8,
            eps: 0.35,
            theta: 150_000.0,
        }
    }

    /// Twin instances on twin RNG streams: `decide_into` must yield the
    /// same active set and charged price as `decide`, clear stale buffer
    /// contents, and leave the stream in the same state — the batched
    /// executor's per-slot contract (DESIGN.md §8).
    fn assert_decide_into_equiv(
        mut a: Box<dyn Strategy>,
        mut b: Box<dyn Strategy>,
        seed: u64,
    ) {
        let mut ra = Rng::new(seed);
        let mut rb = Rng::new(seed);
        let mut buf = vec![usize::MAX; 2]; // stale junk must vanish
        for &p in &[0.1, 0.45, 0.62, 0.9, 0.3, 0.75] {
            let d = a.decide(p, &mut ra);
            let charged = b.decide_into(p, &mut rb, &mut buf);
            assert_eq!(buf, d.active, "{}: price {p}", a.name());
            assert_eq!(
                charged.to_bits(),
                d.price.to_bits(),
                "{}: price {p}",
                a.name()
            );
        }
        assert_eq!(ra.next_u64(), rb.next_u64(), "{}: RNG diverged", a.name());
    }

    #[test]
    fn decide_into_matches_decide_for_all_classic_strategies() {
        let bids = || BidVector::two_group(8, 4, 0.8, 0.4);
        assert_decide_into_equiv(
            Box::new(FixedBids::new("fixed", bids(), 100)),
            Box::new(FixedBids::new("fixed", bids(), 100)),
            7,
        );
        let stages = || {
            vec![
                StageSpec { n: 4, n1: 2, until_iter: 100 },
                StageSpec { n: 8, n1: 4, until_iter: u64::MAX },
            ]
        };
        assert_decide_into_equiv(
            Box::new(
                DynamicBids::new("dyn", problem(), stages(), 2_000).unwrap(),
            ),
            Box::new(
                DynamicBids::new("dyn", problem(), stages(), 2_000).unwrap(),
            ),
            11,
        );
        let sw = || StaticWorkers {
            label: "static".to_string(),
            n: 6,
            j: 50,
            model: PreemptionModel::Bernoulli { q: 0.4 },
            unit_price: 0.3,
        };
        assert_decide_into_equiv(Box::new(sw()), Box::new(sw()), 13);
        let dw = || {
            DynamicWorkers::new(
                "dyn_n",
                5,
                1.01,
                10_000,
                PreemptionModel::Uniform,
                0.1,
                64,
            )
        };
        assert_decide_into_equiv(Box::new(dw()), Box::new(dw()), 17);
    }

    #[test]
    fn fixed_bids_resolve_by_price() {
        let mut s = FixedBids::new(
            "two",
            BidVector::two_group(8, 4, 0.8, 0.4),
            100,
        );
        let mut rng = Rng::new(1);
        assert_eq!(s.decide(0.3, &mut rng).active.len(), 8);
        assert_eq!(s.decide(0.6, &mut rng).active.len(), 4);
        assert_eq!(s.decide(0.9, &mut rng).active.len(), 0);
        assert_eq!(s.max_workers(), 8);
    }

    #[test]
    fn dynamic_bids_replan_grows_fleet() {
        let p = problem();
        let stages = vec![
            StageSpec { n: 4, n1: 2, until_iter: 100 },
            StageSpec { n: 8, n1: 4, until_iter: u64::MAX },
        ];
        let mut s = DynamicBids::new("dynamic", p, stages, 2_000).unwrap();
        assert_eq!(s.max_workers(), 8);
        let mut rng = Rng::new(2);
        // stage 1: at most 4 workers
        let d = s.decide(0.2, &mut rng);
        assert!(d.active.len() <= 4);
        // cross the boundary
        s.on_iteration(&StrategyState {
            iter: 100,
            clock: 5_000.0,
            cost: 10.0,
            error: 1.0,
        })
        .unwrap();
        let d2 = s.decide(0.2, &mut rng);
        assert!(d2.active.len() > 4, "fleet should have grown");
    }

    #[test]
    fn dynamic_workers_schedule_monotone() {
        let s = DynamicWorkers::new(
            "dynamic_n",
            1,
            1.001,
            10_000,
            PreemptionModel::Bernoulli { q: 0.5 },
            0.1,
            1_000_000,
        );
        let mut prev = 0;
        for j in (0..10_000).step_by(500) {
            let n = s.n_at(j);
            assert!(n >= prev);
            prev = n;
        }
        assert!(prev > 1);
    }

    #[test]
    fn dynamic_workers_cap_respected() {
        let s = DynamicWorkers::new(
            "dynamic_n",
            1,
            1.01,
            100_000,
            PreemptionModel::None,
            0.1,
            64,
        );
        assert_eq!(s.n_at(99_999), 64);
        assert_eq!(s.max_workers(), 64);
    }

    #[test]
    fn static_workers_bernoulli_draws() {
        let mut s = StaticWorkers {
            label: "static_n".to_string(),
            n: 10,
            j: 100,
            model: PreemptionModel::Bernoulli { q: 0.5 },
            unit_price: 0.2,
        };
        let mut rng = Rng::new(3);
        let mut total = 0usize;
        for _ in 0..1000 {
            let d = s.decide(123.0, &mut rng); // price ignored
            assert_eq!(d.price, 0.2);
            total += d.active.len();
        }
        let mean = total as f64 / 1000.0;
        assert!((mean - 5.0).abs() < 0.5, "mean active {mean}");
    }
}
