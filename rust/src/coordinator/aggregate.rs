//! Gradient aggregation: the coordinator's numeric hot path.
//!
//! Synchronous SGD (eq. 5) averages the y_j worker gradients and applies
//! theta <- theta - alpha * mean. With D ~ 0.5-1M floats and thousands of
//! replayed iterations this loop dominates coordinator CPU time, so:
//!
//! * buffers are allocated once and reused (`reset` keeps capacity);
//! * `add` and the fused `apply_into` are written as straight-line slice
//!   loops over fixed-width chunks that LLVM auto-vectorises (verified by
//!   the `hotpath` bench: ~memory-bandwidth on this host);
//! * the mean + update is fused into a single pass (one read of the sum,
//!   one read+write of theta) instead of a scale pass followed by axpy.

/// Accumulates worker gradients for one iteration and applies the update.
#[derive(Clone, Debug)]
pub struct GradAccumulator {
    sum: Vec<f32>,
    count: u32,
}

const LANES: usize = 8;

impl GradAccumulator {
    pub fn new(d: usize) -> Self {
        GradAccumulator { sum: vec![0.0; d], count: 0 }
    }

    pub fn d(&self) -> usize {
        self.sum.len()
    }

    pub fn count(&self) -> u32 {
        self.count
    }

    /// Clear for the next iteration (no reallocation).
    pub fn reset(&mut self) {
        self.sum.iter_mut().for_each(|x| *x = 0.0);
        self.count = 0;
    }

    /// sum += grad (one worker's contribution).
    pub fn add(&mut self, grad: &[f32]) {
        assert_eq!(grad.len(), self.sum.len(), "gradient width mismatch");
        self.count += 1;
        let (s_chunks, s_tail) = as_chunks_mut::<LANES>(&mut self.sum);
        let (g_chunks, g_tail) = as_chunks::<LANES>(grad);
        for (s, g) in s_chunks.iter_mut().zip(g_chunks) {
            for i in 0..LANES {
                s[i] += g[i];
            }
        }
        for (s, g) in s_tail.iter_mut().zip(g_tail) {
            *s += *g;
        }
    }

    /// Fused mean + SGD step: theta -= lr * sum / count. Returns false if
    /// no gradients were added (caller should treat as a skipped update).
    pub fn apply_into(&self, theta: &mut [f32], lr: f32) -> bool {
        if self.count == 0 {
            return false;
        }
        assert_eq!(theta.len(), self.sum.len());
        let scale = lr / self.count as f32;
        let (t_chunks, t_tail) = as_chunks_mut::<LANES>(theta);
        let (s_chunks, s_tail) = as_chunks::<LANES>(&self.sum);
        for (t, s) in t_chunks.iter_mut().zip(s_chunks) {
            for i in 0..LANES {
                t[i] -= scale * s[i];
            }
        }
        for (t, s) in t_tail.iter_mut().zip(s_tail) {
            *t -= scale * *s;
        }
        true
    }

    /// Mean gradient (allocating; used by tests and the apply-artifact
    /// path, not the hot loop).
    pub fn mean(&self) -> Vec<f32> {
        assert!(self.count > 0, "mean of empty accumulator");
        let inv = 1.0 / self.count as f32;
        self.sum.iter().map(|s| s * inv).collect()
    }
}

/// Stable-Rust stand-in for `slice::as_chunks` (not yet stabilised for
/// our toolchain's MSRV policy): split into fixed-size arrays + tail.
fn as_chunks<const N: usize>(xs: &[f32]) -> (&[[f32; N]], &[f32]) {
    let mid = xs.len() / N * N;
    let (head, tail) = xs.split_at(mid);
    // SAFETY: head.len() is a multiple of N; [f32; N] has the same layout
    let chunks = unsafe {
        std::slice::from_raw_parts(head.as_ptr().cast(), head.len() / N)
    };
    (chunks, tail)
}

fn as_chunks_mut<const N: usize>(
    xs: &mut [f32],
) -> (&mut [[f32; N]], &mut [f32]) {
    let mid = xs.len() / N * N;
    let (head, tail) = xs.split_at_mut(mid);
    // SAFETY: as above
    let chunks = unsafe {
        std::slice::from_raw_parts_mut(head.as_mut_ptr().cast(), head.len() / N)
    };
    (chunks, tail)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{for_all, Gen};

    #[test]
    fn mean_of_two_gradients() {
        let mut acc = GradAccumulator::new(3);
        acc.add(&[1.0, 2.0, 3.0]);
        acc.add(&[3.0, 2.0, 1.0]);
        assert_eq!(acc.mean(), vec![2.0, 2.0, 2.0]);
        assert_eq!(acc.count(), 2);
    }

    #[test]
    fn apply_matches_naive() {
        let d = 1037; // odd length exercises the tail path
        let mut acc = GradAccumulator::new(d);
        let g1: Vec<f32> = (0..d).map(|i| (i as f32).sin()).collect();
        let g2: Vec<f32> = (0..d).map(|i| (i as f32).cos()).collect();
        let g3: Vec<f32> = (0..d).map(|i| (i as f32 * 0.1).tanh()).collect();
        acc.add(&g1);
        acc.add(&g2);
        acc.add(&g3);
        let mut theta: Vec<f32> = (0..d).map(|i| i as f32 * 0.01).collect();
        let mut naive = theta.clone();
        let lr = 0.1f32;
        assert!(acc.apply_into(&mut theta, lr));
        for i in 0..d {
            naive[i] -= lr * (g1[i] + g2[i] + g3[i]) / 3.0;
        }
        for i in 0..d {
            assert!(
                (theta[i] - naive[i]).abs() <= 1e-6,
                "i={i}: {} vs {}",
                theta[i],
                naive[i]
            );
        }
    }

    #[test]
    fn empty_apply_is_noop() {
        let acc = GradAccumulator::new(4);
        let mut theta = vec![1.0f32; 4];
        assert!(!acc.apply_into(&mut theta, 0.5));
        assert_eq!(theta, vec![1.0; 4]);
    }

    #[test]
    fn reset_keeps_capacity_and_zeroes() {
        let mut acc = GradAccumulator::new(5);
        acc.add(&[1.0; 5]);
        acc.reset();
        assert_eq!(acc.count(), 0);
        acc.add(&[2.0; 5]);
        assert_eq!(acc.mean(), vec![2.0; 5]);
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        let mut acc = GradAccumulator::new(4);
        acc.add(&[0.0; 5]);
    }

    #[test]
    fn prop_aggregation_linearity() {
        // sum of k identical gradients averages to the gradient itself
        for_all("aggregate linearity", |g: &mut Gen| {
            let d = g.u64_in(1, 200) as usize;
            let k = g.u64_in(1, 9) as usize;
            let grad = g.vec_f64(d, -5.0, 5.0);
            let gf: Vec<f32> = grad.iter().map(|&x| x as f32).collect();
            let mut acc = GradAccumulator::new(d);
            for _ in 0..k {
                acc.add(&gf);
            }
            let m = acc.mean();
            for i in 0..d {
                if (m[i] - gf[i]).abs() > 1e-4 {
                    return Err(format!(
                        "mean[{i}]={} != grad {}",
                        m[i], gf[i]
                    ));
                }
            }
            Ok(())
        });
    }
}
