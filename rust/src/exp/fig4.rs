//! Fig. 4: bidding strategies replayed against a c5.xlarge-style spot
//! price *trace* (auto-correlated prices — the robustness check).
//!
//! The paper downloads `DescribeSpotPriceHistory` for us-west-2a; offline
//! we use the regime-switching generator (DESIGN.md §2 records the
//! substitution). Methodology matches the paper: estimate F from the
//! historical trace (time-weighted empirical CDF), compute the optimal
//! bids from the estimate, then replay the *actual* path. Headlines:
//! cost reduction of one-bid / two-bids vs No-interruptions (paper:
//! 26.27% / 65.46%) at >= 96% of its accuracy.
//!
//! The empirical-CDF estimate and the Theorem 2/3 plans are computed
//! once per trace (via the shared [`build_plan`] path) and shared by the
//! three strategy simulations, which run as parallel pool jobs. The
//! replicated many-trace Monte-Carlo view is the `fig4` preset spec
//! (`examples/configs/fig4.toml`): a lineup-mode scenario gridded over
//! `market.trace_seed`, with one cached trace + plan set per grid point.

use anyhow::Result;

use crate::config::StrategyKind;
use crate::market::{EmpiricalCdf, PriceModel, SpotTrace, TraceGenConfig};
use crate::sim::PriceSource;
use crate::sweep::run_indexed;
use crate::theory::bids::BidProblem;
use crate::theory::bounds::{ErrorBound, SgdHyper};
use crate::theory::runtime_model::RuntimeModel;
use crate::util::rng::Rng;

use super::fig3::StrategyOutcome;
use super::spec::{build_plan, PlanInputs};
use super::{accuracy_for_error, run_synthetic_rng, PlannedStrategy};

#[derive(Clone, Debug)]
pub struct Fig4Output {
    pub outcomes: Vec<StrategyOutcome>,
    /// percent cost saved vs no-interruptions: [one_bid, two_bids]
    pub savings_vs_noint: [Option<f64>; 2],
    /// final accuracy as a fraction of no-interruptions' final accuracy
    pub accuracy_ratio: [f64; 2],
    pub trace_mean_price: f64,
    pub trace_horizon: f64,
}

#[derive(Clone, Debug)]
pub struct Fig4Params {
    pub j: u64,
    pub n: usize,
    pub n1: usize,
    pub eps: f64,
    pub deadline_slack: f64,
    pub seed: u64,
    /// sweep-pool workers for the strategy runs
    pub threads: usize,
}

impl Default for Fig4Params {
    fn default() -> Self {
        Fig4Params {
            j: 10_000,
            n: 8,
            n1: 4,
            // eps sits mid-band between the n=8 and n1=4 noise floors
            // (0.25, 0.5): Q(eps) ~ 0.21 makes gamma* ~ 1/3, so the
            // second bid group genuinely idles through expensive periods
            // (the regime where the paper's two-bid savings come from)
            eps: 0.45,
            deadline_slack: 2.0,
            seed: 2020,
            threads: 1,
        }
    }
}

/// The c5.xlarge-style generator parameters used by the bench and the
/// `fig4` preset spec (hour units: prices $/h, times h). Also the
/// defaults for `market.kind = "trace"` scenario specs.
pub fn default_trace_config() -> TraceGenConfig {
    TraceGenConfig {
        horizon: 24.0 * 28.0,      // four weeks
        revision_interval: 0.5,    // <= hourly revisions
        floor: 0.068,
        cap: 0.17,
        base: 0.085,
        regime_switch_prob: 0.02,
        contended_mult: 1.45,
        spike_prob: 0.004,
        reversion: 0.15,
        noise: 0.035,
    }
}

/// Generate the default c5.xlarge-style trace used by the bench.
pub fn default_trace(seed: u64) -> SpotTrace {
    let mut rng = Rng::new(seed);
    SpotTrace::generate(&default_trace_config(), &mut rng)
}

/// Everything pure in the trace, computed once: the time-weighted F
/// estimate and the three strategy plans derived from it.
struct TracePlans {
    est: EmpiricalCdf,
    plans: Vec<PlannedStrategy>,
    bound: ErrorBound,
    runtime: RuntimeModel,
    target_acc: f64,
    cap: f64,
}

fn plan_for_trace(trace: &SpotTrace, p: &Fig4Params) -> Result<TracePlans> {
    let bound = ErrorBound::new(SgdHyper::paper_cnn());
    // hour units: mean gradient time 6 s = 1/600 h, server overhead ~1 s
    let runtime =
        RuntimeModel::ExpStragglers { lambda: 600.0, delta: 0.0003 };
    let theta = p.deadline_slack * p.j as f64 * runtime.expected(p.n);
    // F estimated from history (time-weighted), as the paper does —
    // computed once here and reused for plans and the mean-price summary
    let est = trace.empirical_cdf(0.02);
    let pb = BidProblem {
        bound,
        price: PriceModel::Empirical(est.clone()),
        runtime,
        n: p.n,
        eps: p.eps,
        theta,
    };

    let inputs = PlanInputs {
        pb: Some(&pb),
        n: p.n,
        j: p.j,
        preempt_q: 0.0,
        unit_price: super::fig5::PREEMPTIBLE_PRICE,
    };
    let plans = vec![
        build_plan("no_interruptions", &StrategyKind::NoInterruption, &inputs)?,
        build_plan("one_bid", &StrategyKind::OneBid, &inputs)?,
        build_plan(
            "two_bids",
            &StrategyKind::TwoBids { n1: p.n1 },
            &inputs,
        )?,
    ];
    Ok(TracePlans {
        est,
        plans,
        bound,
        runtime,
        target_acc: accuracy_for_error(&bound, p.eps),
        cap: trace.horizon(),
    })
}

pub fn run(trace: &SpotTrace, p: &Fig4Params) -> Result<Fig4Output> {
    let tp = plan_for_trace(trace, p)?;
    let prices = PriceSource::Trace(trace.clone());

    // seed + i reproduces the seed repo's exact realizations (the
    // calibrated savings/accuracy assertions were tuned on them) while
    // staying a pure function of the job index
    let outcomes: Vec<StrategyOutcome> =
        run_indexed(p.threads, tp.plans.len(), |i| -> Result<StrategyOutcome> {
            let mut s = tp.plans[i].build()?;
            let mut rng = Rng::new(p.seed + i as u64);
            let r = run_synthetic_rng(
                s.as_mut(),
                tp.bound,
                &prices,
                tp.runtime,
                tp.cap,
                &mut rng,
            )?;
            Ok(StrategyOutcome {
                name: tp.plans[i].name().to_string(),
                cost_at_target: r.series.cost_at_accuracy(tp.target_acc),
                time_at_target: r.series.time_at_accuracy(tp.target_acc),
                total_cost: r.cost,
                total_time: r.elapsed,
                series: r.series,
            })
        })
        .into_iter()
        .collect::<Result<_>>()?;

    let noint = &outcomes[0];
    let base_acc = noint
        .series
        .last()
        .map(|pt| pt.accuracy)
        .unwrap_or(0.0)
        .max(1e-9);
    let mut savings = [None, None];
    let mut acc_ratio = [0.0, 0.0];
    for (i, name) in ["one_bid", "two_bids"].iter().enumerate() {
        let o = outcomes.iter().find(|o| o.name == *name).unwrap();
        savings[i] =
            Some(100.0 * (noint.total_cost - o.total_cost) / noint.total_cost);
        acc_ratio[i] = o
            .series
            .last()
            .map(|pt| pt.accuracy)
            .unwrap_or(0.0)
            / base_acc;
    }

    Ok(Fig4Output {
        outcomes,
        savings_vs_noint: savings,
        accuracy_ratio: acc_ratio,
        trace_mean_price: tp.est.mean(),
        trace_horizon: trace.horizon(),
    })
}

pub fn print_summary(out: &Fig4Output) {
    println!(
        "== Fig. 4 [trace replay]  horizon={:.0} h, mean price ${:.4}/h",
        out.trace_horizon, out.trace_mean_price
    );
    for o in &out.outcomes {
        println!(
            "  {:<18} cost_total={:<9.3} time_total={:<8.1} final_acc={:.4}",
            o.name,
            o.total_cost,
            o.total_time,
            o.series.last().map(|p| p.accuracy).unwrap_or(0.0),
        );
    }
    for (i, name) in ["one_bid", "two_bids"].iter().enumerate() {
        if let Some(s) = out.savings_vs_noint[i] {
            println!(
                "  {name} saves {s:.2}% of cost vs no-interruptions at \
                 {:.2}% of its accuracy",
                100.0 * out.accuracy_ratio[i]
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_replay_savings_ordering() {
        let trace = default_trace(7);
        let p = Fig4Params::default();
        let out = run(&trace, &p).unwrap();
        let s1 = out.savings_vs_noint[0].unwrap();
        let s2 = out.savings_vs_noint[1].unwrap();
        assert!(s1 > 0.0, "one-bid should save vs no-interruptions: {s1}");
        assert!(s2 > s1, "two-bids should save more: {s2} vs {s1}");
        // accuracy within ~15% of the no-interruption baseline (the
        // paper reports ~96-97%; exact ratios depend on the trace path)
        assert!(out.accuracy_ratio[0] > 0.85, "{:?}", out.accuracy_ratio);
        assert!(out.accuracy_ratio[1] > 0.85, "{:?}", out.accuracy_ratio);
    }

    #[test]
    fn threaded_replay_matches_serial() {
        let trace = default_trace(8);
        let serial = Fig4Params::default();
        let threaded = Fig4Params { threads: 4, ..serial.clone() };
        let a = run(&trace, &serial).unwrap();
        let b = run(&trace, &threaded).unwrap();
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.total_cost.to_bits(), y.total_cost.to_bits());
        }
    }
}
