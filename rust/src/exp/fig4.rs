//! Fig. 4: bidding strategies replayed against a c5.xlarge-style spot
//! price *trace* (auto-correlated prices — the robustness check).
//!
//! The paper downloads `DescribeSpotPriceHistory` for us-west-2a; offline
//! we use the regime-switching generator (DESIGN.md §2 records the
//! substitution). Methodology matches the paper: estimate F from the
//! historical trace (time-weighted empirical CDF), compute the optimal
//! bids from the estimate, then replay the *actual* path. Headlines:
//! cost reduction of one-bid / two-bids vs No-interruptions (paper:
//! 26.27% / 65.46%) at >= 96% of its accuracy.

use anyhow::{Context, Result};

use crate::coordinator::strategy::FixedBids;
use crate::market::{BidVector, PriceModel, SpotTrace, TraceGenConfig};
use crate::sim::PriceSource;
use crate::theory::bids::BidProblem;
use crate::theory::bounds::{ErrorBound, SgdHyper};
use crate::theory::runtime_model::RuntimeModel;
use crate::util::rng::Rng;

use super::fig3::StrategyOutcome;
use super::{accuracy_for_error, run_synthetic};

#[derive(Clone, Debug)]
pub struct Fig4Output {
    pub outcomes: Vec<StrategyOutcome>,
    /// percent cost saved vs no-interruptions: [one_bid, two_bids]
    pub savings_vs_noint: [Option<f64>; 2],
    /// final accuracy as a fraction of no-interruptions' final accuracy
    pub accuracy_ratio: [f64; 2],
    pub trace_mean_price: f64,
    pub trace_horizon: f64,
}

pub struct Fig4Params {
    pub j: u64,
    pub n: usize,
    pub n1: usize,
    pub eps: f64,
    pub deadline_slack: f64,
    pub seed: u64,
}

impl Default for Fig4Params {
    fn default() -> Self {
        Fig4Params {
            j: 10_000,
            n: 8,
            n1: 4,
            // eps sits mid-band between the n=8 and n1=4 noise floors
            // (0.25, 0.5): Q(eps) ~ 0.21 makes gamma* ~ 1/3, so the
            // second bid group genuinely idles through expensive periods
            // (the regime where the paper's two-bid savings come from)
            eps: 0.45,
            deadline_slack: 2.0,
            seed: 2020,
        }
    }
}

/// Generate the default c5.xlarge-style trace used by the bench (hour
/// units: prices $/h, times h).
pub fn default_trace(seed: u64) -> SpotTrace {
    let cfg = TraceGenConfig {
        horizon: 24.0 * 28.0,      // four weeks
        revision_interval: 0.5,    // <= hourly revisions
        floor: 0.068,
        cap: 0.17,
        base: 0.085,
        regime_switch_prob: 0.02,
        contended_mult: 1.45,
        spike_prob: 0.004,
        reversion: 0.15,
        noise: 0.035,
    };
    let mut rng = Rng::new(seed);
    SpotTrace::generate(&cfg, &mut rng)
}

pub fn run(trace: &SpotTrace, p: &Fig4Params) -> Result<Fig4Output> {
    let bound = ErrorBound::new(SgdHyper::paper_cnn());
    // hour units: mean gradient time 6 s = 1/600 h, server overhead ~1 s
    let runtime =
        RuntimeModel::ExpStragglers { lambda: 600.0, delta: 0.0003 };
    let theta = p.deadline_slack * p.j as f64 * runtime.expected(p.n);
    // F estimated from history (time-weighted), as the paper does
    let est = trace.empirical_cdf(0.02);
    let price_model = PriceModel::Empirical(est);
    let pb = BidProblem {
        bound,
        price: price_model,
        runtime,
        n: p.n,
        eps: p.eps,
        theta,
    };
    let prices = PriceSource::Trace(trace.clone());
    let target_acc = accuracy_for_error(&bound, p.eps);
    let cap = trace.horizon();

    let mut outcomes = Vec::new();

    let noint_plan = pb.no_interruption_plan()?;
    {
        let mut s = FixedBids::new(
            "no_interruptions",
            BidVector::uniform(p.n, 1.0), // above the 0.17 cap
            noint_plan.j.max(p.j),
        );
        let r = run_synthetic(&mut s, bound, &prices, runtime, cap, p.seed)?;
        outcomes.push(super::fig3::StrategyOutcome {
            name: "no_interruptions",
            cost_at_target: r.series.cost_at_accuracy(target_acc),
            time_at_target: r.series.time_at_accuracy(target_acc),
            total_cost: r.cost,
            total_time: r.elapsed,
            series: r.series,
        });
    }
    {
        let plan = pb.optimal_one_bid().context("fig4 one-bid")?;
        let mut s = FixedBids::new(
            "one_bid",
            BidVector::uniform(p.n, plan.b),
            plan.j,
        );
        let r =
            run_synthetic(&mut s, bound, &prices, runtime, cap, p.seed + 1)?;
        outcomes.push(super::fig3::StrategyOutcome {
            name: "one_bid",
            cost_at_target: r.series.cost_at_accuracy(target_acc),
            time_at_target: r.series.time_at_accuracy(target_acc),
            total_cost: r.cost,
            total_time: r.elapsed,
            series: r.series,
        });
    }
    {
        let plan = pb.cooptimize_j_two_bids(p.n1).context("fig4 two-bid")?;
        let mut s = FixedBids::new(
            "two_bids",
            BidVector::two_group(p.n, p.n1, plan.b1, plan.b2),
            plan.j,
        );
        let r =
            run_synthetic(&mut s, bound, &prices, runtime, cap, p.seed + 2)?;
        outcomes.push(super::fig3::StrategyOutcome {
            name: "two_bids",
            cost_at_target: r.series.cost_at_accuracy(target_acc),
            time_at_target: r.series.time_at_accuracy(target_acc),
            total_cost: r.cost,
            total_time: r.elapsed,
            series: r.series,
        });
    }

    let noint = &outcomes[0];
    let base_acc = noint
        .series
        .last()
        .map(|pt| pt.accuracy)
        .unwrap_or(0.0)
        .max(1e-9);
    let mut savings = [None, None];
    let mut acc_ratio = [0.0, 0.0];
    for (i, name) in ["one_bid", "two_bids"].iter().enumerate() {
        let o = outcomes.iter().find(|o| o.name == *name).unwrap();
        savings[i] =
            Some(100.0 * (noint.total_cost - o.total_cost) / noint.total_cost);
        acc_ratio[i] = o
            .series
            .last()
            .map(|pt| pt.accuracy)
            .unwrap_or(0.0)
            / base_acc;
    }

    let mean_price = {
        let cdf = trace.empirical_cdf(0.02);
        cdf.mean()
    };

    Ok(Fig4Output {
        outcomes,
        savings_vs_noint: savings,
        accuracy_ratio: acc_ratio,
        trace_mean_price: mean_price,
        trace_horizon: trace.horizon(),
    })
}

pub fn print_summary(out: &Fig4Output) {
    println!(
        "== Fig. 4 [trace replay]  horizon={:.0} h, mean price ${:.4}/h",
        out.trace_horizon, out.trace_mean_price
    );
    for o in &out.outcomes {
        println!(
            "  {:<18} cost_total={:<9.3} time_total={:<8.1} final_acc={:.4}",
            o.name,
            o.total_cost,
            o.total_time,
            o.series.last().map(|p| p.accuracy).unwrap_or(0.0),
        );
    }
    for (i, name) in ["one_bid", "two_bids"].iter().enumerate() {
        if let Some(s) = out.savings_vs_noint[i] {
            println!(
                "  {name} saves {s:.2}% of cost vs no-interruptions at \
                 {:.2}% of its accuracy",
                100.0 * out.accuracy_ratio[i]
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_replay_savings_ordering() {
        let trace = default_trace(7);
        let p = Fig4Params::default();
        let out = run(&trace, &p).unwrap();
        let s1 = out.savings_vs_noint[0].unwrap();
        let s2 = out.savings_vs_noint[1].unwrap();
        assert!(s1 > 0.0, "one-bid should save vs no-interruptions: {s1}");
        assert!(s2 > s1, "two-bids should save more: {s2} vs {s1}");
        // accuracy within ~15% of the no-interruption baseline (the
        // paper reports ~96-97%; exact ratios depend on the trace path)
        assert!(out.accuracy_ratio[0] > 0.85, "{:?}", out.accuracy_ratio);
        assert!(out.accuracy_ratio[1] > 0.85, "{:?}", out.accuracy_ratio);
    }
}
