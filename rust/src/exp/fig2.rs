//! Fig. 2 (and Fig. 1): the analytic surfaces behind Theorem 3's proof.
//!
//! Fig. 2 plots expected error / cost / completion time against F(b1) and
//! gamma = F(b2)/F(b1), showing the monotonicities that make the
//! two-variable optimisation separable. We regenerate all four panels on
//! a grid (CSV: `fig2_surfaces.csv`) and verify the monotonicities
//! programmatically. Fig. 1's schematic (error/cost vs time for different
//! worker counts) is regenerated as two simulated runs.
//!
//! The surface grid is evaluated row-per-job on the sweep pool (one job
//! per F(b1) value); rows are collected in index order and the
//! monotonicity checks run over the assembled table, so the output is
//! identical at any thread count.

use anyhow::Result;

use crate::coordinator::strategy::FixedBids;
use crate::market::{BidVector, PriceModel};
use crate::market::process::PriceDist;
use crate::sim::PriceSource;
use crate::sweep::run_indexed;
use crate::theory::bids::BidProblem;
use crate::theory::bounds::{ErrorBound, SgdHyper};
use crate::theory::runtime_model::RuntimeModel;
use crate::util::csv::Table;

use super::run_synthetic;

pub struct Fig2Output {
    /// columns: f_b1, gamma, err_bound, exp_cost, exp_time
    pub surfaces: Table,
    /// Fig. 1 series: columns time, err_n2, cost_n2, err_n8, cost_n8
    pub fig1: Table,
    pub monotone_ok: bool,
}

pub fn run(j: u64, n: usize, n1: usize, threads: usize) -> Result<Fig2Output> {
    let bound = ErrorBound::new(SgdHyper::paper_cnn());
    let pb = BidProblem {
        bound,
        price: PriceModel::uniform_paper(),
        runtime: RuntimeModel::ExpStragglers { lambda: 0.25, delta: 0.5 },
        n,
        eps: 0.35,
        theta: f64::INFINITY,
    };
    let grid = 25usize;

    // one job per F(b1) row: each returns the row's (gamma-sweep) points
    let rows: Vec<Vec<[f64; 5]>> = run_indexed(threads, grid, |row| {
        let f1 = (row + 1) as f64 / grid as f64;
        let b1 = pb.price.inv_cdf(f1);
        (0..=grid)
            .map(|g| {
                let gamma = g as f64 / grid as f64;
                let b2 = pb.price.inv_cdf(gamma * f1);
                let r = pb.expected_recip_two(n1, b1, b2);
                let err = bound.phi_const(j, r);
                let cost = pb.expected_cost_two(j, n1, b1, b2);
                let time = pb.expected_time_two(j, n1, b1, b2);
                [f1, gamma, err, cost, time]
            })
            .collect()
    });

    // assemble + monotonicity checks over the deterministic row order
    let mut surfaces =
        Table::new(&["f_b1", "gamma", "err_bound", "exp_cost", "exp_time"]);
    let mut monotone_ok = true;
    let mut prev_cost_along_gamma = vec![0.0; grid + 1];
    for (row, points) in rows.iter().enumerate() {
        let mut prev_err = f64::INFINITY;
        for (g, &[f1, gamma, err, cost, time]) in points.iter().enumerate() {
            surfaces.push(vec![f1, gamma, err, cost, time]);
            // Fig. 2a: error decreasing in gamma
            if err > prev_err + 1e-9 {
                monotone_ok = false;
            }
            prev_err = err;
            // Fig. 2b/2d: cost increasing in gamma and in F(b1)
            if row > 0 && cost + 1e-9 < prev_cost_along_gamma[g] {
                monotone_ok = false;
            }
            prev_cost_along_gamma[g] = cost;
        }
    }

    // ---- Fig. 1: error & cost vs time for n = 2 vs n = 8 (no preemption)
    let runtime = RuntimeModel::ExpStragglers { lambda: 0.25, delta: 0.5 };
    let prices = PriceSource::Iid(PriceModel::uniform_paper());
    let runs = run_indexed(threads, 2, |k| {
        let (workers, seed) = [(2usize, 11u64), (8, 12)][k];
        let mut s = FixedBids::new(
            "fig1",
            BidVector::uniform(workers, 1.0),
            j.min(3_000),
        );
        run_synthetic(&mut s, bound, &prices, runtime, f64::INFINITY, seed)
    });
    let mut runs = runs.into_iter();
    let r2 = runs.next().unwrap()?;
    let r8 = runs.next().unwrap()?;
    let mut fig1 =
        Table::new(&["time", "err_n2", "cost_n2", "err_n8", "cost_n8"]);
    let len = r2.series.len().min(r8.series.len());
    for k in 0..len {
        let p2 = &r2.series.points[k];
        let p8 = &r8.series.points[k];
        fig1.push(vec![p2.clock, p2.error, p2.cost, p8.error, p8.cost]);
    }

    Ok(Fig2Output { surfaces, fig1, monotone_ok })
}

#[cfg(test)]
mod tests {
    #[test]
    fn surfaces_are_monotone_and_complete() {
        let out = super::run(5_000, 8, 4, 1).unwrap();
        assert!(out.monotone_ok, "Fig. 2 monotonicities violated");
        assert_eq!(out.surfaces.rows.len(), 25 * 26);
        assert!(!out.fig1.rows.is_empty());
    }

    #[test]
    fn fig1_more_workers_less_error_more_cost() {
        let out = super::run(5_000, 8, 4, 1).unwrap();
        let last = out.fig1.rows.last().unwrap();
        let (err2, cost2, err8, cost8) = (last[1], last[2], last[3], last[4]);
        assert!(err8 < err2, "more workers should give lower error");
        assert!(cost8 > cost2, "more workers should cost more");
    }

    #[test]
    fn threaded_surfaces_identical_to_serial() {
        let a = super::run(2_000, 8, 4, 1).unwrap();
        let b = super::run(2_000, 8, 4, 4).unwrap();
        assert_eq!(a.monotone_ok, b.monotone_ok);
        assert_eq!(a.surfaces.to_csv(), b.surfaces.to_csv());
        assert_eq!(a.fig1.to_csv(), b.fig1.to_csv());
    }
}
