//! Fig. 2 (and Fig. 1): the analytic surfaces behind Theorem 3's proof.
//!
//! Fig. 2 plots expected error / cost / completion time against F(b1) and
//! gamma = F(b2)/F(b1), showing the monotonicities that make the
//! two-variable optimisation separable. We regenerate all four panels on
//! a grid (CSV: `fig2_surfaces.csv`) and verify the monotonicities
//! programmatically. Fig. 1's schematic (error/cost vs time for different
//! worker counts) is regenerated as two simulated runs.
//!
//! The surface grid is *data*: the `fig2` preset spec
//! (`examples/configs/fig2.toml`) declares a `bid_fractions` strategy
//! with axes over `f1` and `gamma` and the analytic point-constant
//! metrics `bound_err` / `exp_cost` / `exp_time`; this module just runs
//! that spec on the sweep harness (threads = a pure throughput knob) and
//! reassembles the rows + monotonicity checks.

use anyhow::{Context, Result};

use crate::config::StrategyKind;
use crate::market::BidVector;
use crate::sim::PriceSource;
use crate::sweep::{run_indexed, run_sweep, SweepConfig};
use crate::theory::bounds::{ErrorBound, SgdHyper};
use crate::theory::runtime_model::RuntimeModel;
use crate::util::csv::Table;

use super::spec::SpecScenario;
use super::{presets, run_synthetic};
use crate::coordinator::strategy::FixedBids;

pub struct Fig2Output {
    /// columns: f_b1, gamma, err_bound, exp_cost, exp_time
    pub surfaces: Table,
    /// Fig. 1 series: columns time, err_n2, cost_n2, err_n8, cost_n8
    pub fig1: Table,
    pub monotone_ok: bool,
}

pub fn run(j: u64, n: usize, n1: usize, threads: usize) -> Result<Fig2Output> {
    // ---- Fig. 2: the preset spec, overridden to this call's (j, n, n1)
    let mut spec = presets::spec("fig2")?;
    spec.job.j = j;
    spec.job.n = n;
    for e in &mut spec.strategies {
        if let StrategyKind::BidFractions { n1: s_n1, .. } = &mut e.kind {
            *s_n1 = n1;
        }
    }
    let scenario = SpecScenario::new(spec)?;
    // all three metrics are per-point constants, so one replicate is the
    // exact value (the seed never gets consumed)
    let results = run_sweep(
        &scenario,
        &SweepConfig { replicates: 1, seed: 0, threads },
    )?;
    let metric = |name: &str| {
        results
            .metric_names
            .iter()
            .position(|m| m.as_str() == name)
            .with_context(|| format!("fig2 spec lacks metric {name}"))
    };
    let (mi_err, mi_cost, mi_time) =
        (metric("bound_err")?, metric("exp_cost")?, metric("exp_time")?);

    // assemble + monotonicity checks over the deterministic row order
    // (first axis = F(b1) slowest, second = gamma fastest)
    let f1s = scenario.spec().axes[0].values.clone();
    let gammas = scenario.spec().axes[1].values.clone();
    let mut surfaces =
        Table::new(&["f_b1", "gamma", "err_bound", "exp_cost", "exp_time"]);
    let mut monotone_ok = true;
    let mut prev_cost_along_gamma = vec![0.0; gammas.len()];
    for (row, &f1) in f1s.iter().enumerate() {
        let mut prev_err = f64::INFINITY;
        for (g, &gamma) in gammas.iter().enumerate() {
            let point = &results.points[row * gammas.len() + g];
            let err = point.stats[mi_err].mean();
            let cost = point.stats[mi_cost].mean();
            let time = point.stats[mi_time].mean();
            surfaces.push(vec![f1, gamma, err, cost, time]);
            // Fig. 2a: error decreasing in gamma
            if err > prev_err + 1e-9 {
                monotone_ok = false;
            }
            prev_err = err;
            // Fig. 2b/2d: cost increasing in gamma and in F(b1)
            if row > 0 && cost + 1e-9 < prev_cost_along_gamma[g] {
                monotone_ok = false;
            }
            prev_cost_along_gamma[g] = cost;
        }
    }

    // ---- Fig. 1: error & cost vs time for n = 2 vs n = 8 (no preemption)
    let bound = ErrorBound::new(SgdHyper::paper_cnn());
    let runtime = RuntimeModel::ExpStragglers { lambda: 0.25, delta: 0.5 };
    let prices = PriceSource::Iid(crate::market::PriceModel::uniform_paper());
    let runs = run_indexed(threads, 2, |k| {
        let (workers, seed) = [(2usize, 11u64), (8, 12)][k];
        let mut s = FixedBids::new(
            "fig1",
            BidVector::uniform(workers, 1.0),
            j.min(3_000),
        );
        run_synthetic(&mut s, bound, &prices, runtime, f64::INFINITY, seed)
    });
    let mut runs = runs.into_iter();
    let r2 = runs.next().unwrap()?;
    let r8 = runs.next().unwrap()?;
    let mut fig1 =
        Table::new(&["time", "err_n2", "cost_n2", "err_n8", "cost_n8"]);
    let len = r2.series.len().min(r8.series.len());
    for k in 0..len {
        let p2 = &r2.series.points[k];
        let p8 = &r8.series.points[k];
        fig1.push(vec![p2.clock, p2.error, p2.cost, p8.error, p8.cost]);
    }

    Ok(Fig2Output { surfaces, fig1, monotone_ok })
}

#[cfg(test)]
mod tests {
    #[test]
    fn surfaces_are_monotone_and_complete() {
        let out = super::run(5_000, 8, 4, 1).unwrap();
        assert!(out.monotone_ok, "Fig. 2 monotonicities violated");
        assert_eq!(out.surfaces.rows.len(), 25 * 26);
        assert!(!out.fig1.rows.is_empty());
    }

    #[test]
    fn fig1_more_workers_less_error_more_cost() {
        let out = super::run(5_000, 8, 4, 1).unwrap();
        let last = out.fig1.rows.last().unwrap();
        let (err2, cost2, err8, cost8) = (last[1], last[2], last[3], last[4]);
        assert!(err8 < err2, "more workers should give lower error");
        assert!(cost8 > cost2, "more workers should cost more");
    }

    #[test]
    fn threaded_surfaces_identical_to_serial() {
        let a = super::run(2_000, 8, 4, 1).unwrap();
        let b = super::run(2_000, 8, 4, 4).unwrap();
        assert_eq!(a.monotone_ok, b.monotone_ok);
        assert_eq!(a.surfaces.to_csv(), b.surfaces.to_csv());
        assert_eq!(a.fig1.to_csv(), b.fig1.to_csv());
    }
}
