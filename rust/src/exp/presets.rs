//! Shipped preset scenarios: the paper's figures as spec *files*.
//!
//! Each preset is an ordinary `examples/configs/*.toml` scenario spec,
//! embedded at compile time so `volatile-sgd sweep --preset fig3` works
//! from any directory. The TOML files are the single source of truth —
//! there is no Rust-side figure grid left to drift from them; a preset
//! is exactly what `sweep --spec examples/configs/fig3.toml` would run.

use anyhow::{bail, Result};

use super::spec::{ScenarioSpec, SpecScenario};

/// Preset names: the figures, then the engine-era scenarios, then the
/// portfolio and forecast demos.
pub const PRESET_NAMES: [&str; 10] = [
    "fig2",
    "fig3",
    "fig4",
    "fig5",
    "checkpoint_grid",
    "adaptive_grid",
    "notice_grid",
    "portfolio_grid",
    "spot_replay",
    "forecast_grid",
];

/// The embedded TOML text of a preset (accepts `fig3` or bare `3`).
pub fn preset_toml(name: &str) -> Result<&'static str> {
    Ok(match name {
        "fig2" | "2" => include_str!("../../../examples/configs/fig2.toml"),
        "fig3" | "3" => include_str!("../../../examples/configs/fig3.toml"),
        "fig4" | "4" => include_str!("../../../examples/configs/fig4.toml"),
        "fig5" | "5" => include_str!("../../../examples/configs/fig5.toml"),
        "checkpoint_grid" => {
            include_str!("../../../examples/configs/checkpoint_grid.toml")
        }
        "adaptive_grid" => {
            include_str!("../../../examples/configs/adaptive_grid.toml")
        }
        "notice_grid" => {
            include_str!("../../../examples/configs/notice_grid.toml")
        }
        "portfolio_grid" => {
            include_str!("../../../examples/configs/portfolio_grid.toml")
        }
        "spot_replay" => {
            include_str!("../../../examples/configs/spot_replay.toml")
        }
        "forecast_grid" => {
            include_str!("../../../examples/configs/forecast_grid.toml")
        }
        other => bail!(
            "unknown preset '{other}' (available: fig2, fig3, fig4, fig5, \
             checkpoint_grid, adaptive_grid, notice_grid, portfolio_grid, \
             spot_replay, forecast_grid)"
        ),
    })
}

/// Parse a preset into a spec (callers may override fields before
/// building the scenario — see `exp::fig2`).
pub fn spec(name: &str) -> Result<ScenarioSpec> {
    ScenarioSpec::from_str(preset_toml(name)?)
}

/// Parse + validate a preset into a runnable scenario.
pub fn scenario(name: &str) -> Result<SpecScenario> {
    SpecScenario::new(spec(name)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::Scenario;

    #[test]
    fn every_preset_parses_and_validates() {
        for name in PRESET_NAMES {
            let sc = scenario(name).unwrap_or_else(|e| {
                panic!("preset {name} failed to validate: {e:#}")
            });
            assert!(sc.points() > 0, "{name} has no points");
        }
    }

    #[test]
    fn checkpoint_grid_preset_is_an_overhead_scenario() {
        let sc = scenario("checkpoint_grid").unwrap();
        assert_eq!(sc.points(), 9); // 3 q x 3 delay
        assert_eq!(sc.label(0), "q=0.1 delay=0");
        assert_eq!(sc.label(8), "q=0.7 delay=120");
        let spec = sc.spec();
        assert!(spec.overhead.enabled());
        assert!(spec.overhead.lost_work_on_preempt);
        assert_eq!(spec.overhead.checkpoint_every_iters, 10);
        assert!(spec.metrics.iter().any(|m| m == "lost_iters"));
        // the figure presets stay frictionless: their digests are
        // pinned to the pre-engine lockstep loop
        for name in ["fig2", "fig3", "fig4", "fig5"] {
            assert!(
                !spec_is_overhead(name),
                "{name} must not enable [overhead]"
            );
        }
    }

    fn spec_is_overhead(name: &str) -> bool {
        spec(name).unwrap().overhead.enabled()
    }

    /// The two event-native presets (DESIGN.md §6): point spaces,
    /// labels, and the policy/overhead wiring each demonstrates.
    #[test]
    fn policy_presets_ship_event_native_lineups() {
        let sc = scenario("adaptive_grid").unwrap();
        assert_eq!(sc.points(), 24); // 4 budget x 3 q x 2 strategies
        assert_eq!(sc.label(0), "budget=0.6 q=0.1/elastic");
        assert_eq!(sc.label(23), "budget=4.8 q=0.7/one_bid");
        assert!(
            sc.spec().strategies.iter().any(|e| e.kind.event_native()),
            "adaptive_grid must line up an event-native policy"
        );
        assert!(!sc.spec().overhead.enabled());

        let sc = scenario("notice_grid").unwrap();
        assert_eq!(sc.points(), 18); // 3 notice x 3 factor x 2 strategies
        assert_eq!(sc.label(0), "notice=0 factor=1.1/rebid");
        assert_eq!(sc.label(17), "notice=30 factor=2.5/checkpoint_only");
        assert!(sc.spec().strategies.iter().any(|e| e.kind.event_native()));
        assert!(sc.spec().overhead.enabled());
        assert!(sc.spec().overhead.lost_work_on_preempt);
        assert_eq!(sc.spec().overhead.checkpoint_every_iters, 4);
    }

    /// The fig3 preset must reproduce the pre-redesign `sweep --fig 3`
    /// point space exactly: same ordering, same labels, same metric
    /// names. Together with the shared plan builder and replicate
    /// runner this pins digest equality with the old hand-rolled
    /// `Fig3Sweep` (labels and metric names are hashed into the digest;
    /// streams are a pure function of the point order).
    #[test]
    fn fig3_preset_matches_pre_redesign_grid() {
        let sc = scenario("fig3").unwrap();
        assert_eq!(sc.points(), 8);
        let labels: Vec<String> = (0..8).map(|p| sc.label(p)).collect();
        assert_eq!(
            labels,
            vec![
                "uniform/no_interruptions",
                "uniform/one_bid",
                "uniform/two_bids",
                "uniform/dynamic",
                "gaussian/no_interruptions",
                "gaussian/one_bid",
                "gaussian/two_bids",
                "gaussian/dynamic",
            ]
        );
        assert_eq!(
            sc.metrics(),
            vec![
                "cost_at_target",
                "time_at_target",
                "total_cost",
                "total_time",
                "final_error",
                "final_accuracy",
                "iters",
            ]
        );
    }

    #[test]
    fn fig5_preset_matches_pre_redesign_grid() {
        let sc = scenario("fig5").unwrap();
        assert_eq!(sc.points(), 12); // 4 n x 3 q
        assert_eq!(sc.label(0), "n=2 q=0.3");
        assert_eq!(sc.label(11), "n=16 q=0.7");
        assert_eq!(sc.metrics()[0], "cost");
        assert_eq!(sc.metrics()[4], "recip_exact");
    }

    /// The two portfolio-era presets (DESIGN.md §10): point spaces,
    /// labels, and the multi-market wiring each demonstrates.
    #[test]
    fn portfolio_presets_ship_multi_market_lineups() {
        let sc = scenario("portfolio_grid").unwrap();
        assert_eq!(sc.points(), 6); // 3 q x 2 strategies
        assert_eq!(sc.label(0), "q1=0.02/one_bid");
        assert_eq!(sc.label(5), "q1=0.25/migrate");
        let spec = sc.spec();
        let entries = spec.portfolio.as_ref().unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].label, "cheap");
        assert_eq!(entries[1].label, "fast");
        assert_eq!(entries[1].speed, 1.6);
        assert!(spec.markets.is_empty(), "portfolio replaces [market]");
        assert_eq!(spec.market_dim(), 1);
        assert!(spec.overhead.enabled(), "migration must be billed");
        assert_eq!(spec.overhead.checkpoint_every_iters, 0);

        let sc = scenario("spot_replay").unwrap();
        assert_eq!(sc.points(), 4); // 2 markets x 2 strategies
        assert_eq!(sc.label(0), "replay/one_bid");
        assert_eq!(sc.label(3), "synthetic/no_interruption");
        assert!(sc.spec().portfolio.is_none());
        // the replay market is the strict content-hashed loader
        assert!(matches!(
            sc.spec().markets[0].kind,
            crate::exp::spec::MarketKind::TraceStrict {
                ref path,
                resample_s,
                content_fnv,
                ..
            } if path.ends_with("ec2_c5xlarge_uswest2a.csv")
                && resample_s == 7200.0
                && content_fnv != 0
        ));
    }

    /// The forecast-era preset (DESIGN.md §11): the regime-switching
    /// showdown lines up both proactive kinds against their reactive
    /// counterparts over a 3-entry fixture/synthetic portfolio.
    #[test]
    fn forecast_preset_ships_the_proactive_showdown() {
        let sc = scenario("forecast_grid").unwrap();
        assert_eq!(sc.points(), 10); // 2 q x 5 strategies
        assert_eq!(sc.label(0), "q2=0.4/one_bid");
        assert_eq!(sc.label(9), "q2=0.55/proactive");
        let spec = sc.spec();
        let entries = spec.portfolio.as_ref().unwrap();
        assert_eq!(entries.len(), 3);
        assert_eq!(entries[0].label, "c5");
        assert_eq!(entries[1].label, "m5");
        assert_eq!(entries[2].label, "volatile");
        assert!(matches!(
            entries[0].kind,
            crate::exp::spec::MarketKind::TraceStrict { ref path, .. }
                if path.ends_with("ec2_c5xlarge_uswest2a.csv")
        ));
        assert!(matches!(
            entries[2].kind,
            crate::exp::spec::MarketKind::TraceGen { ref cfg, .. }
                if cfg.horizon == 260000.0 && cfg.revision_interval == 600.0
        ));
        // both forecast-driven kinds are in the lineup, as
        // event-native policies
        for label in ["proactive", "lookahead"] {
            let e = spec
                .strategies
                .iter()
                .find(|e| e.label == label)
                .unwrap_or_else(|| panic!("missing strategy '{label}'"));
            assert!(e.kind.event_native(), "'{label}' must be event-native");
        }
        assert!(spec.overhead.enabled(), "migration must be billed");
        assert!(spec.metrics.iter().any(|m| m == "preempt_events"));
    }

    #[test]
    fn fig4_preset_is_lineup_mode_over_trace_seeds() {
        let sc = scenario("fig4").unwrap();
        assert_eq!(sc.points(), 3);
        assert_eq!(sc.label(0), "trace_seed=7");
        assert_eq!(
            sc.metrics(),
            vec![
                "noint_cost",
                "one_bid_cost",
                "two_bids_cost",
                "one_bid_saving_pct",
                "two_bids_saving_pct",
                "one_bid_acc_ratio",
                "two_bids_acc_ratio",
            ]
        );
    }
}
