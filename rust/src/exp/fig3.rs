//! Fig. 3: bidding strategies under the paper's two synthetic price
//! distributions (Uniform[0.2,1] and truncated Gaussian(0.6, 0.175)).
//!
//! Four strategies, exactly as Sec. VI stages them:
//! * No-interruptions — bid above the price cap [Sharma et al.];
//! * Optimal-one-bid  — Theorem 2;
//! * Optimal-two-bids — Theorem 3 (n = 8, n1 = 4);
//! * Dynamic          — start with (n=4, n1=2), after `stage_iters`
//!   add four workers and re-optimise the bids for the remaining budget.
//!
//! Panels (a,b): accuracy-vs-cost trajectories. Panels (c,d): cumulative
//! cost-vs-time with the marker at the target-accuracy crossing; the
//! headline numbers are each strategy's cost overhead at the target
//! relative to Dynamic (paper: +134%/+82%/+46% under uniform,
//! +103%/+101%/+43% under Gaussian).
//!
//! Execution goes through the sweep pool: bid plans are computed once per
//! strategy via the shared [`build_plan`] path, then the four simulations
//! run as parallel jobs, each seeded purely from its job index (`seed +
//! i`, the seed repo's scheme) — identical results at any `threads`.
//! The replicated Monte-Carlo view of this figure is the `fig3` preset
//! spec (`examples/configs/fig3.toml`, see [`super::presets`]) — not a
//! hand-rolled `Scenario` impl.

use anyhow::Result;

use crate::config::StrategyKind;
use crate::market::PriceModel;
use crate::metrics::Series;
use crate::sim::PriceSource;
use crate::sweep::run_indexed;
use crate::theory::bids::BidProblem;
use crate::theory::bounds::{ErrorBound, SgdHyper};
use crate::theory::runtime_model::RuntimeModel;
use crate::util::rng::Rng;

use super::spec::{build_plan, PlanInputs};
use super::{accuracy_for_error, run_synthetic_rng, PlannedStrategy};

/// One strategy's trajectory + headline numbers.
#[derive(Clone, Debug)]
pub struct StrategyOutcome {
    pub name: String,
    pub series: Series,
    pub total_cost: f64,
    pub total_time: f64,
    pub cost_at_target: Option<f64>,
    pub time_at_target: Option<f64>,
}

#[derive(Clone, Debug)]
pub struct Fig3Output {
    pub dist_name: &'static str,
    pub target_accuracy: f64,
    pub outcomes: Vec<StrategyOutcome>,
    /// percent cost overhead vs Dynamic at the target accuracy, in the
    /// paper's order: [no_interruptions, one_bid, two_bids]
    pub overhead_vs_dynamic: [Option<f64>; 3],
}

#[derive(Clone, Debug)]
pub struct Fig3Params {
    pub j: u64,
    pub n: usize,
    pub n1: usize,
    pub eps: f64,
    /// deadline multiplier over the uninterrupted runtime (paper: 2x)
    pub deadline_slack: f64,
    pub stage_iters: u64,
    pub seed: u64,
    /// sweep-pool workers for the strategy runs (1 = serial; any value
    /// yields identical results)
    pub threads: usize,
}

impl Default for Fig3Params {
    fn default() -> Self {
        Fig3Params {
            j: 10_000,
            n: 8,
            n1: 4,
            eps: 0.35,
            deadline_slack: 2.0,
            stage_iters: 4_000,
            seed: 2020,
            threads: 1,
        }
    }
}

/// The four staged strategies of Sec. VI, in the paper's order.
pub const STRATEGY_NAMES: [&str; 4] =
    ["no_interruptions", "one_bid", "two_bids", "dynamic"];

/// The shared (BidProblem, deadline, target) setting for one distribution.
fn problem_for(dist: &PriceModel, p: &Fig3Params) -> (BidProblem, f64, f64) {
    let bound = ErrorBound::new(SgdHyper::paper_cnn());
    let runtime = RuntimeModel::ExpStragglers { lambda: 0.25, delta: 0.5 };
    // deadline: slack x estimated uninterrupted total runtime (Sec. VI)
    let theta = p.deadline_slack * p.j as f64 * runtime.expected(p.n);
    let pb = BidProblem {
        bound,
        price: dist.clone(),
        runtime,
        n: p.n,
        eps: p.eps,
        theta,
    };
    let target_acc = accuracy_for_error(&bound, p.eps);
    let cap = theta * 4.0; // generous hard cap; runs should finish early
    (pb, target_acc, cap)
}

/// Compute one strategy's plan (index into [`STRATEGY_NAMES`]) via the
/// shared [`build_plan`] path. This is the pure per-grid-point work the
/// sweep harness caches.
pub fn plan_strategy(
    pb: &BidProblem,
    p: &Fig3Params,
    strategy: usize,
) -> Result<PlannedStrategy> {
    let name = STRATEGY_NAMES[strategy];
    let kind = match name {
        "no_interruptions" => StrategyKind::NoInterruption,
        "one_bid" => StrategyKind::OneBid,
        "two_bids" => StrategyKind::TwoBids { n1: p.n1 },
        "dynamic" => StrategyKind::DynamicBids {
            n1: p.n1,
            stage_iters: p.stage_iters,
        },
        other => unreachable!("unknown strategy {other}"),
    };
    build_plan(
        name,
        &kind,
        &PlanInputs {
            pb: Some(pb),
            n: p.n,
            j: p.j,
            preempt_q: 0.0,
            unit_price: super::fig5::PREEMPTIBLE_PRICE,
        },
    )
}

pub fn run(
    dist: PriceModel,
    dist_name: &'static str,
    p: &Fig3Params,
) -> Result<Fig3Output> {
    let (pb, target_acc, cap) = problem_for(&dist, p);
    let prices = PriceSource::Iid(dist.clone());

    // plan all four strategies in parallel (two_bids co-optimisation is
    // the slow one; the others finish early and their worker steals)
    let plans: Vec<PlannedStrategy> =
        run_indexed(p.threads, STRATEGY_NAMES.len(), |i| {
            plan_strategy(&pb, p, i)
        })
        .into_iter()
        .collect::<Result<_>>()?;

    // run the four simulations as pool jobs. Seeding stays `seed + i`
    // (the seed repo's scheme, still a pure function of the job index,
    // so any thread count reproduces it): the figure tests' calibrated
    // assertions were tuned against these exact realizations. The fig3
    // preset spec uses Rng::stream for its replicates instead.
    let outcomes: Vec<StrategyOutcome> =
        run_indexed(p.threads, plans.len(), |i| -> Result<StrategyOutcome> {
            let mut strategy = plans[i].build()?;
            let mut rng = Rng::new(p.seed + i as u64);
            let r = run_synthetic_rng(
                strategy.as_mut(),
                pb.bound,
                &prices,
                pb.runtime,
                cap,
                &mut rng,
            )?;
            Ok(outcome(plans[i].name().to_string(), r, target_acc))
        })
        .into_iter()
        .collect::<Result<_>>()?;

    let dyn_cost = outcomes[3].cost_at_target;
    let mut overhead = [None, None, None];
    if let Some(dc) = dyn_cost {
        for slot in 0..3 {
            if let Some(c) = outcomes[slot].cost_at_target {
                overhead[slot] = Some(100.0 * (c - dc) / dc);
            }
        }
    }

    Ok(Fig3Output {
        dist_name,
        target_accuracy: target_acc,
        outcomes,
        overhead_vs_dynamic: overhead,
    })
}

fn outcome(
    name: String,
    r: crate::coordinator::scheduler::RunResult,
    target_acc: f64,
) -> StrategyOutcome {
    StrategyOutcome {
        name,
        cost_at_target: r.series.cost_at_accuracy(target_acc),
        time_at_target: r.series.time_at_accuracy(target_acc),
        total_cost: r.cost,
        total_time: r.elapsed,
        series: r.series,
    }
}

pub fn print_summary(out: &Fig3Output) {
    println!(
        "== Fig. 3 [{}]  target accuracy {:.4}",
        out.dist_name, out.target_accuracy
    );
    for o in &out.outcomes {
        println!(
            "  {:<18} cost_total={:<10.1} time_total={:<10.1} \
             cost@target={:<10} time@target={}",
            o.name,
            o.total_cost,
            o.total_time,
            o.cost_at_target
                .map(|c| format!("{c:.1}"))
                .unwrap_or_else(|| "n/a".into()),
            o.time_at_target
                .map(|t| format!("{t:.1}"))
                .unwrap_or_else(|| "n/a".into()),
        );
    }
    let names = ["no_interruptions", "one_bid", "two_bids"];
    for (i, name) in names.iter().enumerate() {
        if let Some(pct) = out.overhead_vs_dynamic[i] {
            println!("  {name} cost overhead vs dynamic: {pct:+.1}%");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_orderings_match_paper() {
        let p = Fig3Params { j: 10_000, ..Default::default() };
        let out = run(PriceModel::uniform_paper(), "uniform", &p).unwrap();
        // everyone reaches the target
        for o in &out.outcomes {
            assert!(
                o.cost_at_target.is_some(),
                "{} never reached target accuracy",
                o.name
            );
        }
        let cost = |name: &str| {
            out.outcomes
                .iter()
                .find(|o| o.name == name)
                .unwrap()
                .cost_at_target
                .unwrap()
        };
        // the paper's ordering: dynamic < two_bids < one_bid < no_int
        assert!(cost("dynamic") < cost("two_bids"));
        assert!(cost("two_bids") < cost("one_bid"));
        assert!(cost("one_bid") < cost("no_interruptions"));
        // no-interruptions is the fastest to target
        let t = |name: &str| {
            out.outcomes
                .iter()
                .find(|o| o.name == name)
                .unwrap()
                .time_at_target
                .unwrap()
        };
        assert!(t("no_interruptions") <= t("one_bid"));
    }

    #[test]
    fn parallel_run_matches_serial_exactly() {
        // default J: the Theorem 2/3 deadlines scale with it and a much
        // smaller J makes the plans infeasible
        let serial = Fig3Params::default();
        let parallel = Fig3Params { threads: 4, ..serial.clone() };
        let a = run(PriceModel::uniform_paper(), "uniform", &serial).unwrap();
        let b =
            run(PriceModel::uniform_paper(), "uniform", &parallel).unwrap();
        assert_eq!(a.outcomes.len(), b.outcomes.len());
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.total_cost.to_bits(), y.total_cost.to_bits());
            assert_eq!(x.total_time.to_bits(), y.total_time.to_bits());
            assert_eq!(x.series.len(), y.series.len());
        }
    }
}
