//! Fig. 3: bidding strategies under the paper's two synthetic price
//! distributions (Uniform[0.2,1] and truncated Gaussian(0.6, 0.175)).
//!
//! Four strategies, exactly as Sec. VI stages them:
//! * No-interruptions — bid above the price cap [Sharma et al.];
//! * Optimal-one-bid  — Theorem 2;
//! * Optimal-two-bids — Theorem 3 (n = 8, n1 = 4);
//! * Dynamic          — start with (n=4, n1=2), after `stage_iters`
//!   add four workers and re-optimise the bids for the remaining budget.
//!
//! Panels (a,b): accuracy-vs-cost trajectories. Panels (c,d): cumulative
//! cost-vs-time with the marker at the target-accuracy crossing; the
//! headline numbers are each strategy's cost overhead at the target
//! relative to Dynamic (paper: +134%/+82%/+46% under uniform,
//! +103%/+101%/+43% under Gaussian).

use anyhow::{Context, Result};

use crate::coordinator::strategy::{DynamicBids, FixedBids, StageSpec};
use crate::market::{BidVector, PriceModel};
use crate::metrics::Series;
use crate::sim::PriceSource;
use crate::theory::bids::BidProblem;
use crate::theory::bounds::{ErrorBound, SgdHyper};
use crate::theory::runtime_model::RuntimeModel;

use super::{accuracy_for_error, run_synthetic};

/// One strategy's trajectory + headline numbers.
#[derive(Clone, Debug)]
pub struct StrategyOutcome {
    pub name: &'static str,
    pub series: Series,
    pub total_cost: f64,
    pub total_time: f64,
    pub cost_at_target: Option<f64>,
    pub time_at_target: Option<f64>,
}

#[derive(Clone, Debug)]
pub struct Fig3Output {
    pub dist_name: &'static str,
    pub target_accuracy: f64,
    pub outcomes: Vec<StrategyOutcome>,
    /// percent cost overhead vs Dynamic at the target accuracy, in the
    /// paper's order: [no_interruptions, one_bid, two_bids]
    pub overhead_vs_dynamic: [Option<f64>; 3],
}

pub struct Fig3Params {
    pub j: u64,
    pub n: usize,
    pub n1: usize,
    pub eps: f64,
    /// deadline multiplier over the uninterrupted runtime (paper: 2x)
    pub deadline_slack: f64,
    pub stage_iters: u64,
    pub seed: u64,
}

impl Default for Fig3Params {
    fn default() -> Self {
        Fig3Params {
            j: 10_000,
            n: 8,
            n1: 4,
            eps: 0.35,
            deadline_slack: 2.0,
            stage_iters: 4_000,
            seed: 2020,
        }
    }
}

pub fn run(dist: PriceModel, dist_name: &'static str, p: &Fig3Params) -> Result<Fig3Output> {
    let bound = ErrorBound::new(SgdHyper::paper_cnn());
    let runtime = RuntimeModel::ExpStragglers { lambda: 0.25, delta: 0.5 };
    // deadline: slack x estimated uninterrupted total runtime (Sec. VI)
    let theta = p.deadline_slack * p.j as f64 * runtime.expected(p.n);
    let pb = BidProblem {
        bound,
        price: dist.clone(),
        runtime,
        n: p.n,
        eps: p.eps,
        theta,
    };
    let prices = PriceSource::Iid(dist.clone());
    let target_acc = accuracy_for_error(&bound, p.eps);
    let cap = theta * 4.0; // generous hard cap; runs should finish early

    let mut outcomes: Vec<StrategyOutcome> = Vec::new();

    // -------- No-interruptions: bid the support max, J for r = 1/n
    let noint_plan = pb.no_interruption_plan()?;
    {
        let (_, hi) = crate::market::process::PriceDist::support(&dist);
        let mut s = FixedBids::new(
            "no_interruptions",
            BidVector::uniform(p.n, hi),
            noint_plan.j.max(p.j),
        );
        let r = run_synthetic(&mut s, bound, &prices, runtime, cap, p.seed)?;
        outcomes.push(outcome("no_interruptions", r, target_acc));
    }

    // -------- Optimal-one-bid (Theorem 2)
    {
        let plan = pb.optimal_one_bid().context("one-bid plan")?;
        let mut s = FixedBids::new(
            "one_bid",
            BidVector::uniform(p.n, plan.b),
            plan.j,
        );
        let r =
            run_synthetic(&mut s, bound, &prices, runtime, cap, p.seed + 1)?;
        outcomes.push(outcome("one_bid", r, target_acc));
    }

    // -------- Optimal-two-bids (Theorem 3, J chosen by co-optimisation)
    {
        let plan = pb
            .cooptimize_j_two_bids(p.n1)
            .context("two-bid plan")?;
        let mut s = FixedBids::new(
            "two_bids",
            BidVector::two_group(p.n, p.n1, plan.b1, plan.b2),
            plan.j,
        );
        let r =
            run_synthetic(&mut s, bound, &prices, runtime, cap, p.seed + 2)?;
        outcomes.push(outcome("two_bids", r, target_acc));
    }

    // -------- Dynamic (Sec. VI): grow 4 -> 8 and re-optimise
    {
        let stages = vec![
            StageSpec {
                n: p.n / 2,
                n1: (p.n1 / 2).max(1),
                until_iter: p.stage_iters,
            },
            StageSpec { n: p.n, n1: p.n1, until_iter: u64::MAX },
        ];
        let mut s = DynamicBids::new(pb.clone(), stages, p.j)?;
        let r =
            run_synthetic(&mut s, bound, &prices, runtime, cap, p.seed + 3)?;
        outcomes.push(outcome("dynamic", r, target_acc));
    }

    let dyn_cost = outcomes[3].cost_at_target;
    let mut overhead = [None, None, None];
    if let Some(dc) = dyn_cost {
        for (slot, idx) in [(0usize, 0usize), (1, 1), (2, 2)] {
            if let Some(c) = outcomes[idx].cost_at_target {
                overhead[slot] = Some(100.0 * (c - dc) / dc);
            }
        }
    }

    Ok(Fig3Output {
        dist_name,
        target_accuracy: target_acc,
        outcomes,
        overhead_vs_dynamic: overhead,
    })
}

fn outcome(
    name: &'static str,
    r: crate::coordinator::scheduler::RunResult,
    target_acc: f64,
) -> StrategyOutcome {
    StrategyOutcome {
        name,
        cost_at_target: r.series.cost_at_accuracy(target_acc),
        time_at_target: r.series.time_at_accuracy(target_acc),
        total_cost: r.cost,
        total_time: r.elapsed,
        series: r.series,
    }
}

pub fn print_summary(out: &Fig3Output) {
    println!(
        "== Fig. 3 [{}]  target accuracy {:.4}",
        out.dist_name, out.target_accuracy
    );
    for o in &out.outcomes {
        println!(
            "  {:<18} cost_total={:<10.1} time_total={:<10.1} \
             cost@target={:<10} time@target={}",
            o.name,
            o.total_cost,
            o.total_time,
            o.cost_at_target
                .map(|c| format!("{c:.1}"))
                .unwrap_or_else(|| "n/a".into()),
            o.time_at_target
                .map(|t| format!("{t:.1}"))
                .unwrap_or_else(|| "n/a".into()),
        );
    }
    let names = ["no_interruptions", "one_bid", "two_bids"];
    for (i, name) in names.iter().enumerate() {
        if let Some(pct) = out.overhead_vs_dynamic[i] {
            println!("  {name} cost overhead vs dynamic: {pct:+.1}%");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_orderings_match_paper() {
        let p = Fig3Params { j: 10_000, ..Default::default() };
        let out = run(PriceModel::uniform_paper(), "uniform", &p).unwrap();
        // everyone reaches the target
        for o in &out.outcomes {
            assert!(
                o.cost_at_target.is_some(),
                "{} never reached target accuracy",
                o.name
            );
        }
        let cost = |name: &str| {
            out.outcomes
                .iter()
                .find(|o| o.name == name)
                .unwrap()
                .cost_at_target
                .unwrap()
        };
        // the paper's ordering: dynamic < two_bids < one_bid < no_int
        assert!(cost("dynamic") < cost("two_bids"));
        assert!(cost("two_bids") < cost("one_bid"));
        assert!(cost("one_bid") < cost("no_interruptions"));
        // no-interruptions is the fastest to target
        let t = |name: &str| {
            out.outcomes
                .iter()
                .find(|o| o.name == name)
                .unwrap()
                .time_at_target
                .unwrap()
        };
        assert!(t("no_interruptions") <= t("one_bid"));
    }
}
