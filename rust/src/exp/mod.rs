//! Per-figure experiment harnesses.
//!
//! Each submodule regenerates one figure of the paper's evaluation with
//! the same moving parts the paper used (strategies, price models,
//! J/eps/theta settings), emitting CSV series plus a printed summary of
//! the headline comparisons. They are invoked by `cargo bench` (one bench
//! target per figure), by the examples, and by the CLI.
//!
//! Since the sweep refactor every figure runs its strategy simulations
//! through [`crate::sweep::run_indexed`]: runs are planned up front
//! (expensive bid optimisation cached per grid point), executed on the
//! work-stealing pool with RNGs that are pure functions of each job's
//! index, and collected in plan order — so `threads` is a pure
//! throughput knob and results are identical at any thread count.
//!
//! The replicated Monte-Carlo view of each figure is no longer a
//! hand-rolled `Scenario` impl per figure: [`spec`] defines a
//! declarative, TOML-loadable [`ScenarioSpec`] (market x strategy
//! lineup x grid axes x metric set) with one generic [`SpecScenario`]
//! driver, and [`presets`] ships fig2–fig5 as spec files
//! (`examples/configs/*.toml`). `volatile-sgd sweep --spec file.toml`
//! or `--preset fig3` is the one entry point.

pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod presets;
pub mod spec;

use anyhow::{ensure, Result};

use crate::coordinator::backend::SyntheticBackend;
use crate::coordinator::scheduler::{RunResult, Scheduler, SchedulerParams};
use crate::coordinator::strategy::{
    DynamicBids, DynamicWorkers, FixedBids, StageSpec, StaticWorkers,
    Strategy,
};
use crate::market::BidVector;
use crate::preempt::{PreemptionModel, RecipTable};
use crate::sim::{
    DeadlineAware, ElasticFleet, Engine, EngineParams, EngineResult,
    LockstepPolicy, NoticeRebid, Policy, PriceSource,
};
use crate::theory::bids::BidProblem;
use crate::theory::bounds::ErrorBound;
use crate::theory::runtime_model::RuntimeModel;
use crate::util::rng::Rng;

pub use spec::{
    build_plan, CachedSpecScenario, PlanInputs, PrepareCache, ScenarioSpec,
    SpecScenario,
};

/// How one synthetic run executes: the engine loop knobs (now
/// spec-configurable under `[runtime]`) plus the `[overhead]`
/// worker-lifecycle model — exactly [`EngineParams`], under the name
/// the experiment layer has always used. `EngineParams::lockstep`
/// reproduces the pre-redesign constants, which is what keeps every
/// shipped preset digest bit-identical.
pub type RunParams = EngineParams;

/// Run one event-reactive [`Policy`] on the engine against the
/// synthetic (Theorem-1) backend — the full-fidelity entry point:
/// overhead modelling and the engine's event ledger included. Classic
/// strategies reach this through [`run_synthetic_engine`] /
/// [`PlannedStrategy::build_policy`] via the `LockstepPolicy` adapter.
pub fn run_policy_engine(
    policy: &mut dyn Policy,
    bound: ErrorBound,
    prices: &PriceSource,
    params: &RunParams,
    rng: &mut Rng,
) -> Result<EngineResult> {
    let engine = Engine::new(*params);
    let mut backend = SyntheticBackend::new(bound);
    engine.run(policy, &mut backend, prices, rng, &mut [])
}

/// Run one strategy on the event engine against the synthetic
/// (Theorem-1) backend: [`run_policy_engine`] through the lockstep
/// adapter.
pub fn run_synthetic_engine(
    strategy: &mut dyn Strategy,
    bound: ErrorBound,
    prices: &PriceSource,
    params: &RunParams,
    rng: &mut Rng,
) -> Result<EngineResult> {
    run_policy_engine(
        &mut LockstepPolicy(strategy),
        bound,
        prices,
        params,
        rng,
    )
}

/// Run one strategy through the *pre-engine* lockstep loop
/// ([`Scheduler::run_reference`]) — the determinism oracle for the
/// engine-equivalence tests. Rejects overhead configurations (the old
/// loop cannot express them); overhead ledger fields come back zero.
pub fn run_synthetic_reference(
    strategy: &mut dyn Strategy,
    bound: ErrorBound,
    prices: &PriceSource,
    params: &RunParams,
    rng: &mut Rng,
) -> Result<RunResult> {
    ensure!(
        !params.overhead.enabled(),
        "the reference lockstep loop cannot model [overhead]"
    );
    let sp = SchedulerParams {
        runtime: params.runtime,
        idle_step: params.idle_step,
        theta_cap: params.theta_cap,
        stride: params.stride,
        max_slots: params.max_slots,
    };
    let mut backend = SyntheticBackend::new(bound);
    Scheduler::new(sp).run_reference(strategy, &mut backend, prices, rng)
}

/// Run one strategy against the synthetic (Theorem-1) backend, drawing
/// all randomness from the caller's generator — the sweep-friendly entry
/// point (pair it with [`Rng::stream`] for order-independent seeding).
/// Equivalent to [`run_synthetic_engine`] with
/// [`EngineParams::lockstep`].
pub fn run_synthetic_rng(
    strategy: &mut dyn Strategy,
    bound: ErrorBound,
    prices: &PriceSource,
    runtime: RuntimeModel,
    theta_cap: f64,
    rng: &mut Rng,
) -> Result<RunResult> {
    run_synthetic_engine(
        strategy,
        bound,
        prices,
        &RunParams::lockstep(runtime, theta_cap),
        rng,
    )
    .map(RunResult::from)
}

/// Seeded convenience wrapper around [`run_synthetic_rng`].
pub fn run_synthetic(
    strategy: &mut dyn Strategy,
    bound: ErrorBound,
    prices: &PriceSource,
    runtime: RuntimeModel,
    theta_cap: f64,
    seed: u64,
) -> Result<RunResult> {
    let mut rng = Rng::new(seed);
    run_synthetic_rng(strategy, bound, prices, runtime, theta_cap, &mut rng)
}

/// A fully-planned strategy: the pure, cacheable product of the (often
/// expensive) Theorem 2/3 bid optimisation, from which a fresh mutable
/// [`Strategy`] can be built per replicate. Plans are `Send + Sync`, so
/// one plan computed in a sweep's prepare phase serves every replicate
/// job on every worker thread.
///
/// This is the one `StrategyKind -> runnable strategy` currency: the
/// figure harnesses, the `simulate` subcommand and the declarative
/// scenario specs ([`spec`]) all obtain plans through
/// [`spec::build_plan`] and instantiate them here. Names are owned so
/// config-defined lineup entries keep their labels (two dynamic plans
/// with different stage schedules stay distinguishable).
#[derive(Clone, Debug)]
pub enum PlannedStrategy {
    /// Fixed bid vector for the whole job (no-interruptions, one-bid,
    /// two-bids, bid-fractions — depending on the vector).
    Fixed { name: String, bids: BidVector, j: u64 },
    /// Sec. VI dynamic strategy: staged fleet growth + re-optimisation.
    Dynamic {
        name: String,
        problem: BidProblem,
        stages: Vec<StageSpec>,
        j: u64,
    },
    /// Sec. V static provisioning of preemptible instances (Theorem 4).
    StaticWorkers {
        name: String,
        n: usize,
        j: u64,
        model: PreemptionModel,
        unit_price: f64,
    },
    /// Sec. V dynamic provisioning n_j = ceil(n0 eta^{j-1}) (Theorem 5).
    DynamicWorkers {
        name: String,
        n0: usize,
        eta: f64,
        j: u64,
        model: PreemptionModel,
        unit_price: f64,
        cap: usize,
    },
    /// Event-native (`sim::policy`): rebid by `rebid_factor` after
    /// every preemption, saturating at `bid_cap`.
    NoticeRebid {
        name: String,
        bids: BidVector,
        j: u64,
        rebid_factor: f64,
        bid_cap: f64,
    },
    /// Event-native: budget-constrained fleet resizing at each price
    /// revision; the exact `E[1/y]` table is computed once per grid
    /// point (in `prepare`) and cloned into each replicate's policy.
    ElasticFleet {
        name: String,
        j: u64,
        table: RecipTable,
        budget_rate: f64,
    },
    /// Event-native: escalate to on-demand (bid = ∞) when the
    /// completion proxy falls below `threshold`.
    DeadlineAware {
        name: String,
        bids: BidVector,
        j: u64,
        theta: f64,
        p_active: f64,
        slot_time: f64,
        threshold: f64,
    },
}

impl PlannedStrategy {
    pub fn name(&self) -> &str {
        match self {
            PlannedStrategy::Fixed { name, .. }
            | PlannedStrategy::Dynamic { name, .. }
            | PlannedStrategy::StaticWorkers { name, .. }
            | PlannedStrategy::DynamicWorkers { name, .. }
            | PlannedStrategy::NoticeRebid { name, .. }
            | PlannedStrategy::ElasticFleet { name, .. }
            | PlannedStrategy::DeadlineAware { name, .. } => name,
        }
    }

    /// The iteration budget the plan targets.
    pub fn target_iters(&self) -> u64 {
        match self {
            PlannedStrategy::Fixed { j, .. }
            | PlannedStrategy::Dynamic { j, .. }
            | PlannedStrategy::StaticWorkers { j, .. }
            | PlannedStrategy::DynamicWorkers { j, .. }
            | PlannedStrategy::NoticeRebid { j, .. }
            | PlannedStrategy::ElasticFleet { j, .. }
            | PlannedStrategy::DeadlineAware { j, .. } => *j,
        }
    }

    /// True for the event-native policy plans, which have no lockstep
    /// [`Strategy`] form: [`PlannedStrategy::build`] rejects them and
    /// the pre-engine reference runner cannot execute them.
    pub fn event_native(&self) -> bool {
        matches!(
            self,
            PlannedStrategy::NoticeRebid { .. }
                | PlannedStrategy::ElasticFleet { .. }
                | PlannedStrategy::DeadlineAware { .. }
        )
    }

    /// Instantiate a fresh event-reactive [`Policy`] for one run — the
    /// engine-native entry every runner uses: classic plans adapt
    /// through [`LockstepPolicy`] (identical RNG/accounting order, so
    /// digests are unchanged), event-native plans build their
    /// `sim::policy` implementation directly.
    pub fn build_policy(&self) -> Result<Box<dyn Policy>> {
        Ok(match self {
            PlannedStrategy::NoticeRebid {
                name,
                bids,
                j,
                rebid_factor,
                bid_cap,
            } => Box::new(NoticeRebid::new(
                name.clone(),
                bids.clone(),
                *j,
                *rebid_factor,
                *bid_cap,
            )),
            PlannedStrategy::ElasticFleet {
                name,
                j,
                table,
                budget_rate,
            } => Box::new(ElasticFleet::new(
                name.clone(),
                *j,
                table.clone(),
                *budget_rate,
            )),
            PlannedStrategy::DeadlineAware {
                name,
                bids,
                j,
                theta,
                p_active,
                slot_time,
                threshold,
            } => Box::new(DeadlineAware::new(
                name.clone(),
                bids.clone(),
                *j,
                *theta,
                *p_active,
                *slot_time,
                *threshold,
            )),
            classic => Box::new(LockstepPolicy(classic.build()?)),
        })
    }

    /// Instantiate a fresh lockstep strategy for one run. Errors for
    /// the event-native plans (use [`PlannedStrategy::build_policy`]).
    pub fn build(&self) -> Result<Box<dyn Strategy>> {
        ensure!(
            !self.event_native(),
            "plan '{}' is an event-native policy with no lockstep \
             Strategy form; build it with build_policy() and run it on \
             the event engine",
            self.name()
        );
        Ok(match self {
            PlannedStrategy::Fixed { name, bids, j } => {
                Box::new(FixedBids::new(name.clone(), bids.clone(), *j))
            }
            PlannedStrategy::Dynamic { name, problem, stages, j } => {
                Box::new(DynamicBids::new(
                    name.clone(),
                    problem.clone(),
                    stages.clone(),
                    *j,
                )?)
            }
            PlannedStrategy::StaticWorkers {
                name, n, j, model, unit_price,
            } => Box::new(StaticWorkers {
                label: name.clone(),
                n: *n,
                j: *j,
                model: model.clone(),
                unit_price: *unit_price,
            }),
            PlannedStrategy::DynamicWorkers {
                name,
                n0,
                eta,
                j,
                model,
                unit_price,
                cap,
            } => Box::new(DynamicWorkers::new(
                name.clone(),
                *n0,
                *eta,
                *j,
                model.clone(),
                *unit_price,
                *cap,
            )),
            PlannedStrategy::NoticeRebid { .. }
            | PlannedStrategy::ElasticFleet { .. }
            | PlannedStrategy::DeadlineAware { .. } => {
                unreachable!("rejected by the event_native guard above")
            }
        })
    }
}

/// Accuracy proxy corresponding to an error target (see DESIGN.md §2):
/// the synthetic backend reports accuracy = 1 - err / A.
pub fn accuracy_for_error(bound: &ErrorBound, eps: f64) -> f64 {
    (1.0 - eps / bound.hyper.a0).clamp(0.0, 1.0)
}

/// Pretty one-line summary for a run.
pub fn summarize(name: &str, r: &RunResult) -> String {
    format!(
        "{name:<18} iters={:<6} cost={:<10.2} time={:<10.1} idle={:<9.1} \
         err={:.4} acc={:.4}{}",
        r.iters,
        r.cost,
        r.elapsed,
        r.idle_time,
        r.final_error,
        r.final_accuracy,
        if r.truncated { "  [TRUNCATED]" } else { "" }
    )
}
