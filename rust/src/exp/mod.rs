//! Per-figure experiment harnesses.
//!
//! Each submodule regenerates one figure of the paper's evaluation with
//! the same moving parts the paper used (strategies, price models,
//! J/eps/theta settings), emitting CSV series plus a printed summary of
//! the headline comparisons. They are invoked by `cargo bench` (one bench
//! target per figure), by the examples, and by the CLI.
//!
//! Since the sweep refactor every figure runs its strategy simulations
//! through [`crate::sweep::run_indexed`]: runs are planned up front
//! (expensive bid optimisation cached per grid point), executed on the
//! work-stealing pool with RNGs that are pure functions of each job's
//! index, and collected in plan order — so `threads` is a pure
//! throughput knob and results are identical at any thread count.
//!
//! The replicated Monte-Carlo view of each figure is no longer a
//! hand-rolled `Scenario` impl per figure: [`spec`] defines a
//! declarative, TOML-loadable [`ScenarioSpec`] (market x strategy
//! lineup x grid axes x metric set) with one generic [`SpecScenario`]
//! driver, and [`presets`] ships fig2–fig5 as spec files
//! (`examples/configs/*.toml`). `volatile-sgd sweep --spec file.toml`
//! or `--preset fig3` is the one entry point.

pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod presets;
pub mod spec;

use anyhow::{bail, ensure, Result};

use crate::coordinator::backend::{SyntheticBackend, TrainingBackend};
use crate::coordinator::scheduler::{RunResult, Scheduler, SchedulerParams};
use crate::coordinator::strategy::{
    ActiveDecision, DynamicBids, DynamicWorkers, FixedBids, StageSpec,
    StaticWorkers, Strategy,
};
use crate::market::{BidVector, MarketPortfolio, MigrationRule};
use crate::preempt::{PreemptionModel, RecipTable};
use crate::sim::{
    CostMeter, DeadlineAware, ElasticFleet, Engine, EngineParams,
    EngineResult, EngineState, Event, LockstepPolicy, LookaheadBid,
    NoticeRebid, Observer, Policy, PriceSource, ProactiveMigrator,
    SeriesRecorder,
};
use crate::theory::bids::BidProblem;
use crate::theory::bounds::ErrorBound;
use crate::theory::runtime_model::RuntimeModel;
use crate::util::rng::Rng;

pub use spec::{
    build_plan, CachedSpecScenario, PlanInputs, PrepareCache, ScenarioSpec,
    SpecScenario,
};

/// How one synthetic run executes: the engine loop knobs (now
/// spec-configurable under `[runtime]`) plus the `[overhead]`
/// worker-lifecycle model — exactly [`EngineParams`], under the name
/// the experiment layer has always used. `EngineParams::lockstep`
/// reproduces the pre-redesign constants, which is what keeps every
/// shipped preset digest bit-identical.
pub type RunParams = EngineParams;

/// Run one event-reactive [`Policy`] on the engine against the
/// synthetic (Theorem-1) backend — the full-fidelity entry point:
/// overhead modelling and the engine's event ledger included. Classic
/// strategies reach this through [`run_synthetic_engine`] /
/// [`PlannedStrategy::build_policy`] via the `LockstepPolicy` adapter.
pub fn run_policy_engine(
    policy: &mut dyn Policy,
    bound: ErrorBound,
    prices: &PriceSource,
    params: &RunParams,
    rng: &mut Rng,
) -> Result<EngineResult> {
    run_policy_engine_obs(policy, bound, prices, params, rng, &mut [])
}

/// [`run_policy_engine`] with extra [`Observer`]s spliced into the
/// engine's event stream (run tracing, DESIGN.md §12). Observers see
/// every event the policy sees, draw zero RNG, and cannot perturb the
/// run — results are bit-identical with or without them.
pub fn run_policy_engine_obs(
    policy: &mut dyn Policy,
    bound: ErrorBound,
    prices: &PriceSource,
    params: &RunParams,
    rng: &mut Rng,
    extra: &mut [&mut dyn Observer],
) -> Result<EngineResult> {
    let engine = Engine::new(*params);
    let mut backend = SyntheticBackend::new(bound);
    engine.run(policy, &mut backend, prices, rng, extra)
}

/// Run one strategy on the event engine against the synthetic
/// (Theorem-1) backend: [`run_policy_engine`] through the lockstep
/// adapter.
pub fn run_synthetic_engine(
    strategy: &mut dyn Strategy,
    bound: ErrorBound,
    prices: &PriceSource,
    params: &RunParams,
    rng: &mut Rng,
) -> Result<EngineResult> {
    run_policy_engine(
        &mut LockstepPolicy(strategy),
        bound,
        prices,
        params,
        rng,
    )
}

/// Run one strategy through the *pre-engine* lockstep loop
/// ([`Scheduler::run_reference`]) — the determinism oracle for the
/// engine-equivalence tests. Rejects overhead configurations (the old
/// loop cannot express them); overhead ledger fields come back zero.
pub fn run_synthetic_reference(
    strategy: &mut dyn Strategy,
    bound: ErrorBound,
    prices: &PriceSource,
    params: &RunParams,
    rng: &mut Rng,
) -> Result<RunResult> {
    ensure!(
        !params.overhead.enabled(),
        "the reference lockstep loop cannot model [overhead]"
    );
    let sp = SchedulerParams {
        runtime: params.runtime,
        idle_step: params.idle_step,
        theta_cap: params.theta_cap,
        stride: params.stride,
        max_slots: params.max_slots,
    };
    let mut backend = SyntheticBackend::new(bound);
    Scheduler::new(sp).run_reference(strategy, &mut backend, prices, rng)
}

/// Run one strategy against the synthetic (Theorem-1) backend, drawing
/// all randomness from the caller's generator — the sweep-friendly entry
/// point (pair it with [`Rng::stream`] for order-independent seeding).
/// Equivalent to [`run_synthetic_engine`] with
/// [`EngineParams::lockstep`].
pub fn run_synthetic_rng(
    strategy: &mut dyn Strategy,
    bound: ErrorBound,
    prices: &PriceSource,
    runtime: RuntimeModel,
    theta_cap: f64,
    rng: &mut Rng,
) -> Result<RunResult> {
    run_synthetic_engine(
        strategy,
        bound,
        prices,
        &RunParams::lockstep(runtime, theta_cap),
        rng,
    )
    .map(RunResult::from)
}

/// Seeded convenience wrapper around [`run_synthetic_rng`].
pub fn run_synthetic(
    strategy: &mut dyn Strategy,
    bound: ErrorBound,
    prices: &PriceSource,
    runtime: RuntimeModel,
    theta_cap: f64,
    seed: u64,
) -> Result<RunResult> {
    let mut rng = Rng::new(seed);
    run_synthetic_rng(strategy, bound, prices, runtime, theta_cap, &mut rng)
}

/// One portfolio run's immutable inputs: the validated entry set and a
/// per-entry [`PriceSource`], index-aligned with the entries.
pub struct PortfolioRun<'a> {
    pub port: &'a MarketPortfolio,
    pub sources: &'a [PriceSource],
}

/// The fleet the `portfolio_migrate` plan moves between markets: all
/// `n` workers active every slot at the quoted price, consuming no RNG
/// (placement is the migration rule's job, not a bid resolution).
struct FleetPolicy {
    name: String,
    n: usize,
    j: u64,
}

impl Policy for FleetPolicy {
    fn name(&self) -> &str {
        &self.name
    }

    fn target_iters(&self) -> u64 {
        self.j
    }

    fn max_workers(&self) -> usize {
        self.n
    }

    fn decide(&mut self, price: f64, _rng: &mut Rng) -> ActiveDecision {
        ActiveDecision { active: (0..self.n).collect(), price }
    }
}

/// Run one plan across a market portfolio — the multi-market sibling of
/// [`run_policy_engine`] (DESIGN.md §10).
///
/// **RNG-stream-per-market contract.** One `next_u64` off the caller's
/// replicate stream seeds the run; market `i` draws its price and its
/// market-level interruption from `Rng::stream(root, i)` and the policy
/// (decide / runtime sample / backend step) from `Rng::stream(root, m)`.
/// Every stream is a pure function of the replicate identity, so sweep
/// digests stay bit-identical at any thread count.
///
/// **Slot order.** Per slot: deadline check; every market's price +
/// availability draw (index order); migration (`portfolio_migrate` and
/// `proactive_migrate` only — billed as a checkpoint at the old
/// market's price plus a restart at the new one's, consuming the
/// slot); `PriceRevision` on the current market; unavailable market ->
/// preemption episode + idle; otherwise decide / restore / iterate
/// exactly as the single-market engine, with the iteration runtime
/// divided by the current entry's `speed`. The `proactive_migrate`
/// forecasters fold the slot's draws (RNG-free) before the migration
/// decision, so the forecast always includes the slot being decided.
///
/// **Preemption accounting.** `preempt_events` counts *market-level
/// interruptions suffered by an active fleet* — whether the episode is
/// recovered by idling in place or by a forced migration to a
/// still-available entry. (A migration out of an interrupting market
/// emits `WorkerPreempted` before the checkpoint/restart billing.)
/// That keeps the metric comparable across reactive and proactive
/// placement: a policy that moves *before* the interruption genuinely
/// records fewer events, not just different bookkeeping (DESIGN.md
/// §11).
///
/// Periodic checkpointing and `lost_work_on_preempt` are rejected: in a
/// portfolio the `[overhead]` knobs price *migrations* (and restart
/// recovery), and silently double-charging them would corrupt the
/// comparison against single-market baselines.
pub fn run_portfolio_engine(
    plan: &PlannedStrategy,
    run: &PortfolioRun<'_>,
    bound: ErrorBound,
    params: &RunParams,
    rng: &mut Rng,
) -> Result<EngineResult> {
    run_portfolio_engine_obs(plan, run, bound, params, rng, &mut [])
}

/// [`run_portfolio_engine`] with extra [`Observer`]s spliced into the
/// event stream (run tracing, DESIGN.md §12). Observers additionally
/// receive [`Observer::on_market`] once for the home entry before the
/// first slot and again after every migration; like the single-market
/// variant they draw zero RNG and cannot perturb the run.
pub fn run_portfolio_engine_obs(
    plan: &PlannedStrategy,
    run: &PortfolioRun<'_>,
    bound: ErrorBound,
    params: &RunParams,
    rng: &mut Rng,
    extra: &mut [&mut dyn Observer],
) -> Result<EngineResult> {
    let m = run.port.len();
    ensure!(m > 0, "portfolio run with no entries");
    ensure!(
        run.sources.len() == m,
        "portfolio run needs one price source per entry ({} != {m})",
        run.sources.len()
    );
    ensure!(
        portfolio_overhead_ok(params),
        "portfolio runs price migrations through [overhead]; \
         checkpoint_every_iters and lost_work_on_preempt are not supported"
    );
    run.port.validate()?;
    ensure!(params.idle_step > 0.0, "idle_step must be > 0");
    ensure!(params.stride >= 1, "stride must be >= 1");
    params.overhead.validate()?;
    let ov = params.overhead;

    let root = rng.next_u64();
    let mut market_rngs: Vec<Rng> =
        (0..m).map(|i| Rng::stream(root, i as u64)).collect();
    let mut policy_rng = Rng::stream(root, m as u64);

    /// How the fleet is placed across entries: the reactive
    /// effective-price rule (DESIGN.md §10) or the forecast scorer
    /// (§11). The forecast variant carries per-market estimator state,
    /// updated once per slot with zero RNG draws.
    enum Migrator {
        Rule(MigrationRule),
        Forecast(ProactiveMigrator),
    }

    let (mut policy, mut migrate): (Box<dyn Policy>, Option<Migrator>) =
        match plan {
            PlannedStrategy::PortfolioMigrate { name, n, j, hysteresis } => {
                let rule = MigrationRule { hysteresis: *hysteresis };
                rule.validate()?;
                (
                    Box::new(FleetPolicy {
                        name: name.clone(),
                        n: *n,
                        j: *j,
                    }),
                    Some(Migrator::Rule(rule)),
                )
            }
            PlannedStrategy::ProactiveMigrate {
                name,
                n,
                j,
                hysteresis,
                window,
                horizon_s,
                smoothing,
            } => (
                Box::new(FleetPolicy { name: name.clone(), n: *n, j: *j }),
                Some(Migrator::Forecast(ProactiveMigrator::new(
                    *n,
                    m,
                    *hysteresis,
                    *window,
                    *horizon_s,
                    *smoothing,
                    ov.checkpoint_cost_s + ov.restart_delay_s,
                ))),
            ),
            // classic / event-native plans are pinned to entry 0 (the
            // "home" market) and never migrate
            classic => (classic.build_policy()?, None),
        };

    let mut backend = SyntheticBackend::new(bound);
    let mut meter = CostMeter::new();
    let mut recorder = SeriesRecorder::new(params.stride);
    let mut iter = 0u64;
    let mut slots = 0u64;
    let target = policy.target_iters();
    let mut truncated = false;
    let mut last = (backend.error(), backend.accuracy());
    let mut current = 0usize;
    let mut was_active = false;
    let mut interrupted = false;
    let mut prev_price = 0.0f64;
    let (mut preemptions, mut restarts, mut checkpoints) = (0u64, 0u64, 0u64);
    let (mut checkpoint_time, mut restart_time) = (0.0f64, 0.0f64);
    let mut prices = vec![0.0f64; m];
    let mut avail = vec![true; m];
    for obs in extra.iter_mut() {
        obs.on_market(current);
    }

    fn emit(
        policy: &mut dyn Policy,
        recorder: &mut SeriesRecorder,
        extra: &mut [&mut dyn Observer],
        ev: Event,
        st: EngineState,
    ) -> Result<()> {
        policy.on_event(&ev, &st)?;
        recorder.on_event(&ev, &st);
        for obs in extra.iter_mut() {
            obs.on_event(&ev, &st);
        }
        Ok(())
    }
    macro_rules! state {
        ($active:expr, $price:expr) => {
            EngineState {
                iter,
                target,
                clock: meter.elapsed(),
                cost: meter.cost(),
                idle_time: meter.idle_time(),
                error: last.0,
                accuracy: last.1,
                active: $active,
                price: $price,
            }
        };
    }

    while iter < target {
        slots += 1;
        if slots > params.max_slots || meter.elapsed() >= params.theta_cap {
            truncated = true;
            emit(
                policy.as_mut(),
                &mut recorder,
                extra,
                Event::DeadlineHit,
                state!(0, prev_price),
            )?;
            break;
        }
        // every market's slot draws, in index order, each off its own
        // stream — so the set of draws per slot is fixed regardless of
        // which market the fleet occupies
        for i in 0..m {
            prices[i] =
                run.sources[i].price_at(meter.elapsed(), &mut market_rngs[i]);
            avail[i] = !market_rngs[i].bool(run.port.entries[i].q);
        }
        let move_to = match &mut migrate {
            Some(Migrator::Rule(rule)) => {
                rule.target(run.port, current, &prices, &avail)
            }
            Some(Migrator::Forecast(f)) => {
                // fold this slot's draws first (RNG-free), then decide
                f.observe_slot(&prices, &avail);
                f.target(run.port, current, &prices, &avail)
            }
            None => None,
        };
        if let Some(to) = move_to {
            // a migration out of an interrupting market is still an
            // interruption the active fleet suffered: ledger it
            // before billing the move (see "Preemption accounting")
            if !avail[current] && was_active {
                preemptions += 1;
                emit(
                    policy.as_mut(),
                    &mut recorder,
                    extra,
                    Event::WorkerPreempted { notice: ov.preempt_notice_s },
                    state!(0, prices[current]),
                )?;
            }
            // the move consumes the slot: checkpoint on the market
            // being left, restart lag on the one being entered
            let n_move = policy.max_workers();
            meter.charge(n_move, prices[current], ov.checkpoint_cost_s);
            checkpoint_time += ov.checkpoint_cost_s;
            checkpoints += 1;
            emit(
                policy.as_mut(),
                &mut recorder,
                extra,
                Event::CheckpointDone,
                state!(n_move, prices[current]),
            )?;
            meter.charge(n_move, prices[to], ov.restart_delay_s);
            restart_time += ov.restart_delay_s;
            restarts += 1;
            current = to;
            for obs in extra.iter_mut() {
                obs.on_market(current);
            }
            prev_price = prices[current];
            emit(
                policy.as_mut(),
                &mut recorder,
                extra,
                Event::WorkerRestored,
                state!(n_move, prices[current]),
            )?;
            continue;
        }
        emit(
            policy.as_mut(),
            &mut recorder,
            Event::PriceRevision { price: prices[current] },
            state!(0, prices[current]),
        )?;
        if !avail[current] {
            // market-level interruption: the whole fleet loses the slot
            if was_active {
                preemptions += 1;
                was_active = false;
                interrupted = true;
                emit(
                    policy.as_mut(),
                    &mut recorder,
                    extra,
                    Event::WorkerPreempted { notice: ov.preempt_notice_s },
                    state!(0, prices[current]),
                )?;
            }
            meter.idle(params.idle_step);
            continue;
        }
        let decision = policy.decide(prices[current], &mut policy_rng);
        let y = decision.active.len();
        if y == 0 {
            if was_active {
                preemptions += 1;
                was_active = false;
                interrupted = true;
                emit(
                    policy.as_mut(),
                    &mut recorder,
                    extra,
                    Event::WorkerPreempted { notice: ov.preempt_notice_s },
                    state!(0, prices[current]),
                )?;
            }
            meter.idle(params.idle_step);
            continue;
        }
        if interrupted {
            if ov.restart_delay_s > 0.0 {
                meter.charge(y, decision.price, ov.restart_delay_s);
                restart_time += ov.restart_delay_s;
            }
            restarts += 1;
            interrupted = false;
            emit(
                policy.as_mut(),
                &mut recorder,
                extra,
                Event::WorkerRestored,
                state!(y, decision.price),
            )?;
        }
        let dur = params.runtime.sample(y, &mut policy_rng)
            / run.port.entries[current].speed;
        let stats = backend.step(y, &mut policy_rng)?;
        meter.charge(y, decision.price, dur);
        iter += 1;
        last = (stats.error, stats.accuracy);
        was_active = true;
        prev_price = decision.price;
        emit(
            policy.as_mut(),
            &mut recorder,
            Event::IterationDone,
            state!(y, decision.price),
        )?;
    }

    Ok(EngineResult {
        series: recorder.into_series(),
        iters: iter,
        cost: meter.cost(),
        elapsed: meter.elapsed(),
        idle_time: meter.idle_time(),
        final_error: last.0,
        final_accuracy: last.1,
        truncated,
        preemptions,
        restarts,
        checkpoints,
        checkpoint_time,
        restart_time,
        lost_iters: 0,
    })
}

/// The `[overhead]` knobs a portfolio run can express: migration and
/// restart billing only (see [`run_portfolio_engine`]).
fn portfolio_overhead_ok(params: &RunParams) -> bool {
    params.overhead.checkpoint_every_iters == 0
        && !params.overhead.lost_work_on_preempt
}

/// A fully-planned strategy: the pure, cacheable product of the (often
/// expensive) Theorem 2/3 bid optimisation, from which a fresh mutable
/// [`Strategy`] can be built per replicate. Plans are `Send + Sync`, so
/// one plan computed in a sweep's prepare phase serves every replicate
/// job on every worker thread.
///
/// This is the one `StrategyKind -> runnable strategy` currency: the
/// figure harnesses, the `simulate` subcommand and the declarative
/// scenario specs ([`spec`]) all obtain plans through
/// [`spec::build_plan`] and instantiate them here. Names are owned so
/// config-defined lineup entries keep their labels (two dynamic plans
/// with different stage schedules stay distinguishable).
#[derive(Clone, Debug)]
pub enum PlannedStrategy {
    /// Fixed bid vector for the whole job (no-interruptions, one-bid,
    /// two-bids, bid-fractions — depending on the vector).
    Fixed { name: String, bids: BidVector, j: u64 },
    /// Sec. VI dynamic strategy: staged fleet growth + re-optimisation.
    Dynamic {
        name: String,
        problem: BidProblem,
        stages: Vec<StageSpec>,
        j: u64,
    },
    /// Sec. V static provisioning of preemptible instances (Theorem 4).
    StaticWorkers {
        name: String,
        n: usize,
        j: u64,
        model: PreemptionModel,
        unit_price: f64,
    },
    /// Sec. V dynamic provisioning n_j = ceil(n0 eta^{j-1}) (Theorem 5).
    DynamicWorkers {
        name: String,
        n0: usize,
        eta: f64,
        j: u64,
        model: PreemptionModel,
        unit_price: f64,
        cap: usize,
    },
    /// Event-native (`sim::policy`): rebid by `rebid_factor` after
    /// every preemption, saturating at `bid_cap`.
    NoticeRebid {
        name: String,
        bids: BidVector,
        j: u64,
        rebid_factor: f64,
        bid_cap: f64,
    },
    /// Event-native: budget-constrained fleet resizing at each price
    /// revision; the exact `E[1/y]` table is computed once per grid
    /// point (in `prepare`) and cloned into each replicate's policy.
    ElasticFleet {
        name: String,
        j: u64,
        table: RecipTable,
        budget_rate: f64,
    },
    /// Event-native: escalate to on-demand (bid = ∞) when the
    /// completion proxy falls below `threshold`.
    DeadlineAware {
        name: String,
        bids: BidVector,
        j: u64,
        theta: f64,
        p_active: f64,
        slot_time: f64,
        threshold: f64,
    },
    /// Portfolio-native: place the whole fleet on one `[[portfolio]]`
    /// entry and follow the cheapest effective price (price / speed)
    /// across entries, with hysteresis; each migration is billed as a
    /// checkpoint + restart via `[overhead]` (DESIGN.md §10). Only
    /// [`run_portfolio_engine`] can execute this plan.
    PortfolioMigrate { name: String, n: usize, j: u64, hysteresis: f64 },
    /// Portfolio-native, forecast-driven (`sim::forecast`, DESIGN.md
    /// §11): score every entry by forecast progress-per-dollar
    /// (sliding-window q̂, EWMA price level) and migrate *before*
    /// preemption when the best entry clears the hysteresis band after
    /// paying the move cost amortized over `horizon_s`. Only
    /// [`run_portfolio_engine`] can execute this plan.
    ProactiveMigrate {
        name: String,
        n: usize,
        j: u64,
        hysteresis: f64,
        window: usize,
        horizon_s: f64,
        smoothing: f64,
    },
    /// Event-native, forecast-driven: the Theorem-2 one-bid plan
    /// rescaled online against an EWMA price-level forecast with a
    /// regime-change detector (`sim::forecast::LookaheadBid`).
    LookaheadBid {
        name: String,
        bids: BidVector,
        j: u64,
        window: usize,
        innovation_threshold: f64,
        base_level: f64,
        bid_cap: f64,
    },
}

impl PlannedStrategy {
    pub fn name(&self) -> &str {
        match self {
            PlannedStrategy::Fixed { name, .. }
            | PlannedStrategy::Dynamic { name, .. }
            | PlannedStrategy::StaticWorkers { name, .. }
            | PlannedStrategy::DynamicWorkers { name, .. }
            | PlannedStrategy::NoticeRebid { name, .. }
            | PlannedStrategy::ElasticFleet { name, .. }
            | PlannedStrategy::DeadlineAware { name, .. }
            | PlannedStrategy::PortfolioMigrate { name, .. }
            | PlannedStrategy::ProactiveMigrate { name, .. }
            | PlannedStrategy::LookaheadBid { name, .. } => name,
        }
    }

    /// The iteration budget the plan targets.
    pub fn target_iters(&self) -> u64 {
        match self {
            PlannedStrategy::Fixed { j, .. }
            | PlannedStrategy::Dynamic { j, .. }
            | PlannedStrategy::StaticWorkers { j, .. }
            | PlannedStrategy::DynamicWorkers { j, .. }
            | PlannedStrategy::NoticeRebid { j, .. }
            | PlannedStrategy::ElasticFleet { j, .. }
            | PlannedStrategy::DeadlineAware { j, .. }
            | PlannedStrategy::PortfolioMigrate { j, .. }
            | PlannedStrategy::ProactiveMigrate { j, .. }
            | PlannedStrategy::LookaheadBid { j, .. } => *j,
        }
    }

    /// True for the event-native policy plans, which have no lockstep
    /// [`Strategy`] form: [`PlannedStrategy::build`] rejects them and
    /// the pre-engine reference runner cannot execute them.
    pub fn event_native(&self) -> bool {
        matches!(
            self,
            PlannedStrategy::NoticeRebid { .. }
                | PlannedStrategy::ElasticFleet { .. }
                | PlannedStrategy::DeadlineAware { .. }
                | PlannedStrategy::PortfolioMigrate { .. }
                | PlannedStrategy::ProactiveMigrate { .. }
                | PlannedStrategy::LookaheadBid { .. }
        )
    }

    /// Instantiate a fresh event-reactive [`Policy`] for one run — the
    /// engine-native entry every runner uses: classic plans adapt
    /// through [`LockstepPolicy`] (identical RNG/accounting order, so
    /// digests are unchanged), event-native plans build their
    /// `sim::policy` implementation directly.
    pub fn build_policy(&self) -> Result<Box<dyn Policy>> {
        Ok(match self {
            PlannedStrategy::NoticeRebid {
                name,
                bids,
                j,
                rebid_factor,
                bid_cap,
            } => Box::new(NoticeRebid::new(
                name.clone(),
                bids.clone(),
                *j,
                *rebid_factor,
                *bid_cap,
            )),
            PlannedStrategy::ElasticFleet {
                name,
                j,
                table,
                budget_rate,
            } => Box::new(ElasticFleet::new(
                name.clone(),
                *j,
                table.clone(),
                *budget_rate,
            )),
            PlannedStrategy::DeadlineAware {
                name,
                bids,
                j,
                theta,
                p_active,
                slot_time,
                threshold,
            } => Box::new(DeadlineAware::new(
                name.clone(),
                bids.clone(),
                *j,
                *theta,
                *p_active,
                *slot_time,
                *threshold,
            )),
            PlannedStrategy::LookaheadBid {
                name,
                bids,
                j,
                window,
                innovation_threshold,
                base_level,
                bid_cap,
            } => Box::new(LookaheadBid::new(
                name.clone(),
                bids.clone(),
                *j,
                *window,
                *innovation_threshold,
                *base_level,
                *bid_cap,
            )),
            PlannedStrategy::PortfolioMigrate { name, .. }
            | PlannedStrategy::ProactiveMigrate { name, .. } => bail!(
                "plan '{name}' places workers across a portfolio; it has \
                 no single-market Policy form — run it through \
                 run_portfolio_engine"
            ),
            classic => Box::new(LockstepPolicy(classic.build()?)),
        })
    }

    /// Instantiate a fresh lockstep strategy for one run. Errors for
    /// the event-native plans (use [`PlannedStrategy::build_policy`]).
    pub fn build(&self) -> Result<Box<dyn Strategy>> {
        ensure!(
            !self.event_native(),
            "plan '{}' is an event-native policy with no lockstep \
             Strategy form; build it with build_policy() and run it on \
             the event engine",
            self.name()
        );
        Ok(match self {
            PlannedStrategy::Fixed { name, bids, j } => {
                Box::new(FixedBids::new(name.clone(), bids.clone(), *j))
            }
            PlannedStrategy::Dynamic { name, problem, stages, j } => {
                Box::new(DynamicBids::new(
                    name.clone(),
                    problem.clone(),
                    stages.clone(),
                    *j,
                )?)
            }
            PlannedStrategy::StaticWorkers {
                name, n, j, model, unit_price,
            } => Box::new(StaticWorkers {
                label: name.clone(),
                n: *n,
                j: *j,
                model: model.clone(),
                unit_price: *unit_price,
            }),
            PlannedStrategy::DynamicWorkers {
                name,
                n0,
                eta,
                j,
                model,
                unit_price,
                cap,
            } => Box::new(DynamicWorkers::new(
                name.clone(),
                *n0,
                *eta,
                *j,
                model.clone(),
                *unit_price,
                *cap,
            )),
            PlannedStrategy::NoticeRebid { .. }
            | PlannedStrategy::ElasticFleet { .. }
            | PlannedStrategy::DeadlineAware { .. }
            | PlannedStrategy::PortfolioMigrate { .. }
            | PlannedStrategy::ProactiveMigrate { .. }
            | PlannedStrategy::LookaheadBid { .. } => {
                unreachable!("rejected by the event_native guard above")
            }
        })
    }
}

/// Accuracy proxy corresponding to an error target (see DESIGN.md §2):
/// the synthetic backend reports accuracy = 1 - err / A.
pub fn accuracy_for_error(bound: &ErrorBound, eps: f64) -> f64 {
    (1.0 - eps / bound.hyper.a0).clamp(0.0, 1.0)
}

/// Pretty one-line summary for a run.
pub fn summarize(name: &str, r: &RunResult) -> String {
    format!(
        "{name:<18} iters={:<6} cost={:<10.2} time={:<10.1} idle={:<9.1} \
         err={:.4} acc={:.4}{}",
        r.iters,
        r.cost,
        r.elapsed,
        r.idle_time,
        r.final_error,
        r.final_accuracy,
        if r.truncated { "  [TRUNCATED]" } else { "" }
    )
}
