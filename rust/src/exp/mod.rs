//! Per-figure experiment harnesses.
//!
//! Each submodule regenerates one figure of the paper's evaluation with
//! the same moving parts the paper used (strategies, price models,
//! J/eps/theta settings), emitting CSV series plus a printed summary of
//! the headline comparisons. They are invoked by `cargo bench` (one bench
//! target per figure), by the examples, and by the CLI.

pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;

use anyhow::Result;

use crate::coordinator::backend::SyntheticBackend;
use crate::coordinator::scheduler::{RunResult, Scheduler, SchedulerParams};
use crate::coordinator::strategy::Strategy;
use crate::sim::PriceSource;
use crate::theory::bounds::ErrorBound;
use crate::theory::runtime_model::RuntimeModel;
use crate::util::rng::Rng;

/// Run one strategy against the synthetic (Theorem-1) backend.
pub fn run_synthetic(
    strategy: &mut dyn Strategy,
    bound: ErrorBound,
    prices: &PriceSource,
    runtime: RuntimeModel,
    theta_cap: f64,
    seed: u64,
) -> Result<RunResult> {
    let params = SchedulerParams {
        runtime,
        idle_step: 4.0,
        theta_cap,
        stride: 10,
        max_slots: 200_000_000,
    };
    let mut backend = SyntheticBackend::new(bound);
    let mut rng = Rng::new(seed);
    Scheduler::new(params).run(strategy, &mut backend, prices, &mut rng)
}

/// Accuracy proxy corresponding to an error target (see DESIGN.md §2):
/// the synthetic backend reports accuracy = 1 - err / A.
pub fn accuracy_for_error(bound: &ErrorBound, eps: f64) -> f64 {
    (1.0 - eps / bound.hyper.a0).clamp(0.0, 1.0)
}

/// Pretty one-line summary for a run.
pub fn summarize(name: &str, r: &RunResult) -> String {
    format!(
        "{name:<18} iters={:<6} cost={:<10.2} time={:<10.1} idle={:<9.1} \
         err={:.4} acc={:.4}{}",
        r.iters,
        r.cost,
        r.elapsed,
        r.idle_time,
        r.final_error,
        r.final_accuracy,
        if r.truncated { "  [TRUNCATED]" } else { "" }
    )
}
