//! Per-figure experiment harnesses.
//!
//! Each submodule regenerates one figure of the paper's evaluation with
//! the same moving parts the paper used (strategies, price models,
//! J/eps/theta settings), emitting CSV series plus a printed summary of
//! the headline comparisons. They are invoked by `cargo bench` (one bench
//! target per figure), by the examples, and by the CLI.
//!
//! Since the sweep refactor every figure runs its strategy simulations
//! through [`crate::sweep::run_indexed`]: runs are planned up front
//! (expensive bid optimisation cached per grid point), executed on the
//! work-stealing pool with RNGs that are pure functions of each job's
//! index, and collected in plan order — so `threads` is a pure
//! throughput knob and results are identical at any thread count. The
//! `Fig*Sweep` types in the submodules expose the same experiments as
//! Monte-Carlo [`crate::sweep::Scenario`]s (replicates seeded via
//! [`Rng::stream`]) for the `sweep` CLI subcommand.

pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;

use anyhow::Result;

use crate::coordinator::backend::SyntheticBackend;
use crate::coordinator::scheduler::{RunResult, Scheduler, SchedulerParams};
use crate::coordinator::strategy::{
    DynamicBids, FixedBids, StageSpec, Strategy,
};
use crate::market::BidVector;
use crate::sim::PriceSource;
use crate::theory::bids::BidProblem;
use crate::theory::bounds::ErrorBound;
use crate::theory::runtime_model::RuntimeModel;
use crate::util::rng::Rng;

/// Run one strategy against the synthetic (Theorem-1) backend, drawing
/// all randomness from the caller's generator — the sweep-friendly entry
/// point (pair it with [`Rng::stream`] for order-independent seeding).
pub fn run_synthetic_rng(
    strategy: &mut dyn Strategy,
    bound: ErrorBound,
    prices: &PriceSource,
    runtime: RuntimeModel,
    theta_cap: f64,
    rng: &mut Rng,
) -> Result<RunResult> {
    let params = SchedulerParams {
        runtime,
        idle_step: 4.0,
        theta_cap,
        stride: 10,
        max_slots: 200_000_000,
    };
    let mut backend = SyntheticBackend::new(bound);
    Scheduler::new(params).run(strategy, &mut backend, prices, rng)
}

/// Seeded convenience wrapper around [`run_synthetic_rng`].
pub fn run_synthetic(
    strategy: &mut dyn Strategy,
    bound: ErrorBound,
    prices: &PriceSource,
    runtime: RuntimeModel,
    theta_cap: f64,
    seed: u64,
) -> Result<RunResult> {
    let mut rng = Rng::new(seed);
    run_synthetic_rng(strategy, bound, prices, runtime, theta_cap, &mut rng)
}

/// A fully-planned strategy: the pure, cacheable product of the (often
/// expensive) Theorem 2/3 bid optimisation, from which a fresh mutable
/// [`Strategy`] can be built per replicate. Plans are `Send + Sync`, so
/// one plan computed in a sweep's prepare phase serves every replicate
/// job on every worker thread.
#[derive(Clone, Debug)]
pub enum PlannedStrategy {
    /// Fixed bid vector for the whole job (no-interruptions, one-bid,
    /// two-bids, depending on the vector).
    Fixed { name: &'static str, bids: BidVector, j: u64 },
    /// Sec. VI dynamic strategy: staged fleet growth + re-optimisation.
    Dynamic { problem: BidProblem, stages: Vec<StageSpec>, j: u64 },
}

impl PlannedStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            PlannedStrategy::Fixed { name, .. } => *name,
            PlannedStrategy::Dynamic { .. } => "dynamic",
        }
    }

    /// Instantiate a fresh strategy for one run.
    pub fn build(&self) -> Result<Box<dyn Strategy>> {
        Ok(match self {
            PlannedStrategy::Fixed { name, bids, j } => {
                Box::new(FixedBids::new(*name, bids.clone(), *j))
            }
            PlannedStrategy::Dynamic { problem, stages, j } => Box::new(
                DynamicBids::new(problem.clone(), stages.clone(), *j)?,
            ),
        })
    }
}

/// Accuracy proxy corresponding to an error target (see DESIGN.md §2):
/// the synthetic backend reports accuracy = 1 - err / A.
pub fn accuracy_for_error(bound: &ErrorBound, eps: f64) -> f64 {
    (1.0 - eps / bound.hyper.a0).clamp(0.0, 1.0)
}

/// Pretty one-line summary for a run.
pub fn summarize(name: &str, r: &RunResult) -> String {
    format!(
        "{name:<18} iters={:<6} cost={:<10.2} time={:<10.1} idle={:<9.1} \
         err={:.4} acc={:.4}{}",
        r.iters,
        r.cost,
        r.elapsed,
        r.idle_time,
        r.final_error,
        r.final_accuracy,
        if r.truncated { "  [TRUNCATED]" } else { "" }
    )
}
