//! Declarative scenario specs: one config-driven surface for every sweep.
//!
//! The paper's whole evaluation is one shape — pick a price/preemption
//! model, a strategy lineup, a grid over (eps, theta, n, q, ...), then
//! Monte-Carlo it. [`ScenarioSpec`] is that shape as data: a typed,
//! TOML-loadable description composing
//!
//! * a **market lineup** (uniform / gaussian / trace / fixed price),
//! * a **runtime model**, the engine loop knobs (`[runtime]
//!   idle_step/stride/max_slots`) and the SGD bound constants,
//! * an optional **`[overhead]` worker-lifecycle model** (checkpoint
//!   cadence/cost, restart delay, lost work on preemption — DESIGN.md
//!   §5) executed by the event engine,
//! * a **strategy lineup** (`Vec<StrategyKind>`-shaped entries with
//!   owned labels),
//! * zero or more **grid axes** — any numeric field is sweepable via an
//!   axis path like `job.eps`, `job.preempt_q`, `market.trace_seed` or
//!   `strategy.<label>.stage_iters`,
//! * and a requested **metric set**.
//!
//! [`SpecScenario`] implements [`sweep::Scenario`] generically off a
//! spec: `prepare` does the cached pure work per grid point (CDF
//! estimation, trace generation, Theorem 2/3 bid plans, exact `E[1/y]`
//! tables), `run` executes replicates via [`PlannedStrategy`]. The
//! determinism contract of DESIGN.md §3 is inherited wholesale: points
//! are numbered (market-major, then grid, then strategy), replicate
//! RNGs are pure functions of job identity, and results are
//! bit-identical at any thread count.
//!
//! A new scenario is a TOML file, not a new Rust module — all seven
//! shipped presets under `examples/configs/` are ordinary spec files
//! (see [`super::presets`]); schema details are documented in
//! DESIGN.md §4, the event-native policy kinds (`notice_rebid`,
//! `elastic_fleet`, `deadline_aware`) in §6.
//!
//! # Example
//!
//! ```
//! use volatile_sgd::exp::{ScenarioSpec, SpecScenario};
//! use volatile_sgd::sweep::{run_sweep, Scenario, SweepConfig};
//!
//! let spec = ScenarioSpec::from_str(r#"
//! name = "doc"
//! strategies = ["static_workers"]
//! metrics = ["cost", "recip_exact"]
//!
//! [job]
//! n = 4
//! j = 50
//! preempt_q = 0.3
//!
//! [runtime]
//! kind = "deterministic"
//! r = 10.0
//!
//! [market]
//! kind = "fixed"
//! "#).unwrap();
//! let scenario = SpecScenario::new(spec).unwrap();   // --check-grade
//! assert_eq!(scenario.points(), 1);
//! let cfg = SweepConfig { replicates: 2, seed: 1, threads: 2 };
//! let results = run_sweep(&scenario, &cfg).unwrap();
//! assert_eq!(results.points.len(), 1);
//! ```

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Context, Result};

use crate::config::toml::{Doc, TrackedDoc};
use crate::config::StrategyKind;
use crate::coordinator::strategy::StageSpec;
use crate::market::process::PriceDist;
use crate::market::{
    tracefile, BidVector, MarketPortfolio, PortfolioEntry, PriceModel,
    SpotTrace, TraceGenConfig,
};
use crate::preempt::{jensen_penalty, PreemptionModel, RecipTable};
use crate::coordinator::backend::SyntheticBackend;
use crate::obs::TraceObs;
use crate::sim::{
    run_batch, run_batch_traced, BatchLane, EngineResult, Observer,
    OverheadModel, PriceSource,
};
use crate::sweep::{Grid, Scenario};
use crate::theory::bids::BidProblem;
use crate::theory::bounds::{ErrorBound, SgdHyper};
use crate::theory::runtime_model::RuntimeModel;
use crate::util::fnv::Fnv;
use crate::util::rng::Rng;

use super::{
    accuracy_for_error, run_policy_engine, run_policy_engine_obs,
    run_portfolio_engine, run_portfolio_engine_obs,
    run_synthetic_reference, PlannedStrategy, PortfolioRun, RunParams,
};

// ===================================================================
// Spec data model
// ===================================================================

/// How the grid crosses with the strategy lineup.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SweepMode {
    /// Each (market, grid point, strategy) is its own point; metrics
    /// describe one strategy's run. The default.
    PerStrategy,
    /// Each (market, grid point) is one point; every replicate runs the
    /// *whole* lineup sequentially on a shared RNG stream and metrics
    /// compare entries against the first (the baseline) — the Fig. 4
    /// savings shape.
    Lineup,
}

/// Job-level knobs shared by every strategy (entry overrides aside).
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub n: usize,
    pub eps: f64,
    /// explicit deadline; when absent it is derived as
    /// `deadline_slack * j * E[runtime(n)]` (infinite for fixed-price
    /// markets, which have no bid deadline)
    pub theta: Option<f64>,
    pub deadline_slack: f64,
    pub j: u64,
    pub preempt_q: f64,
    /// baseline fleet for the Theorem-4 `n_match_exact` metric
    pub n_baseline: usize,
    /// $/worker/time for preemptible strategies
    pub unit_price: f64,
}

/// One market model in the lineup.
#[derive(Clone, Debug)]
pub struct MarketSpec {
    pub label: String,
    pub kind: MarketKind,
}

#[derive(Clone, Debug)]
pub enum MarketKind {
    Uniform { lo: f64, hi: f64 },
    Gaussian { mean: f64, std: f64, lo: f64, hi: f64 },
    /// Preemptible-platform case: a constant price, no bidding.
    Fixed { price: f64 },
    /// Replay a trace loaded from CSV; F estimated from it. Identity
    /// is the file's *content* hash, never its path (DESIGN.md §9).
    TraceFile { path: String, cdf_resolution: f64, content_fnv: u64 },
    /// Generate a regime-switching trace (DESIGN.md §2), seeded
    /// deterministically; F estimated from the generated path.
    TraceGen { cfg: TraceGenConfig, seed: u64, cdf_resolution: f64 },
    /// `kind = "tracefile"`: the strict CSV/JSON spot-history loader
    /// (`market::tracefile`) — validated at parse/`--check` time,
    /// optionally resampled onto a fixed revision grid, identified by
    /// content hash (DESIGN.md §10).
    TraceStrict {
        path: String,
        cdf_resolution: f64,
        /// resample interval in seconds (0 = replay raw timestamps)
        resample_s: f64,
        content_fnv: u64,
    },
}

/// One `[[portfolio]]` entry as parsed: the market kind plus the
/// portfolio-level knobs. `q` is the *market-level* per-slot
/// interruption probability — independent of `job.preempt_q` (which
/// models per-worker preemption inside a market) and defaulting to 0:
/// a portfolio entry interrupts only when it says so.
#[derive(Clone, Debug)]
pub struct PortfolioEntrySpec {
    pub label: String,
    pub kind: MarketKind,
    /// per-iteration runtime is divided by this (1.0 = paper baseline)
    pub speed: f64,
    /// market-level per-slot interruption probability, in [0, 1)
    pub q: f64,
}

/// One strategy lineup entry: an owned label, a kind, and optional
/// per-entry overrides of the job-level fleet/preemption/price knobs.
#[derive(Clone, Debug)]
pub struct StrategyEntry {
    pub label: String,
    pub kind: StrategyKind,
    pub n: Option<usize>,
    pub preempt_q: Option<f64>,
    pub unit_price: Option<f64>,
}

/// One grid axis: a display name, a dotted field path, and the values.
#[derive(Clone, Debug)]
pub struct AxisSpec {
    pub name: String,
    pub path: String,
    pub values: Vec<f64>,
}

/// Engine loop knobs, spec-configurable under `[runtime]` (historically
/// compiled-in `SchedulerParams` constants) and grid-sweepable by
/// dotted path (`runtime.idle_step`, `runtime.stride`,
/// `runtime.max_slots`).
#[derive(Clone, Copy, Debug)]
pub struct SchedKnobs {
    /// idle re-check interval when no workers are active (paper: 4 s)
    pub idle_step: f64,
    /// record a series point every `stride` iterations
    pub stride: u64,
    /// runaway guard on total slots (idle + busy)
    pub max_slots: u64,
}

impl Default for SchedKnobs {
    /// The pre-redesign `run_synthetic_rng` constants — the values
    /// every shipped preset's digest is pinned against.
    fn default() -> Self {
        SchedKnobs { idle_step: 4.0, stride: 10, max_slots: 200_000_000 }
    }
}

/// A fully-parsed scenario spec. Public fields: presets are ordinary
/// specs and callers (figure harnesses, tests) may override them
/// programmatically before building a [`SpecScenario`].
#[derive(Clone, Debug)]
pub struct ScenarioSpec {
    pub name: String,
    pub mode: SweepMode,
    pub job: JobSpec,
    pub runtime: RuntimeModel,
    pub sched: SchedKnobs,
    pub overhead: OverheadModel,
    pub sgd: SgdHyper,
    pub markets: Vec<MarketSpec>,
    /// the `[[portfolio]]` entry set; `Some` makes this a multi-market
    /// portfolio spec (one point per grid x strategy; `markets` stays
    /// empty). A one-entry portfolio with default speed/q lowers to a
    /// classic `markets` lineup at parse time, so its digest is
    /// bit-identical to the equivalent `[market]` spec by construction.
    pub portfolio: Option<Vec<PortfolioEntrySpec>>,
    pub strategies: Vec<StrategyEntry>,
    pub axes: Vec<AxisSpec>,
    pub metrics: Vec<String>,
    /// default replicate count / master seed (CLI flags override)
    pub replicates: Option<u64>,
    pub seed: Option<u64>,
}

impl ScenarioSpec {
    pub fn from_str(text: &str) -> Result<Self> {
        Self::from_doc(&Doc::parse(text)?)
    }

    pub fn from_file(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading spec {}", path.display()))?;
        Self::from_str(&text)
            .with_context(|| format!("parsing spec {}", path.display()))
    }

    pub fn from_doc(doc: &Doc) -> Result<Self> {
        let d = TrackedDoc::new(doc);
        let spec = Self::from_tracked(&d, true)?;
        reject_unknown_keys(&d, &spec.strategies)?;
        Ok(spec)
    }

    /// Parse the scenario portion of an already-tracked doc, leaving
    /// the unknown-key audit to the caller — the hook [`crate::opt`]
    /// uses to host a scenario beside its own `[objective]`/`[search]`
    /// tables in one file (the caller reads its tables through the same
    /// `TrackedDoc`, then runs [`reject_unknown_keys`] once over the
    /// union). `require_metrics` gates the non-empty `metrics` check:
    /// planner specs carry no metric list — the planner reports its own
    /// cost/time/error columns.
    pub(crate) fn from_tracked(
        d: &TrackedDoc,
        require_metrics: bool,
    ) -> Result<Self> {
        let name = d.str_or("name", "scenario")?;
        let mode = match d.str_or("mode", "per_strategy")?.as_str() {
            "per_strategy" => SweepMode::PerStrategy,
            "lineup" => SweepMode::Lineup,
            other => {
                bail!("mode must be per_strategy | lineup, got '{other}'")
            }
        };
        let replicates = d.u64_opt("replicates")?;
        let seed = d.u64_opt("seed")?;

        // ------------------------------------------------------- job
        let n = d.usize_or("job.n", 8)?;
        ensure!(n >= 1, "job.n must be >= 1, got {n}");
        let eps = d.f64_or("job.eps", 0.35)?;
        ensure!(eps > 0.0, "job.eps must be > 0, got {eps}");
        let theta = d.f64_opt("job.theta")?;
        if let Some(t) = theta {
            ensure!(t > 0.0, "job.theta must be > 0, got {t}");
        }
        let deadline_slack = d.f64_or("job.deadline_slack", 2.0)?;
        ensure!(
            deadline_slack > 0.0,
            "job.deadline_slack must be > 0, got {deadline_slack}"
        );
        let j = d.u64_or("job.j", 10_000)?;
        ensure!(j >= 1, "job.j must be >= 1");
        let preempt_q = d.f64_or("job.preempt_q", 0.5)?;
        ensure!(
            (0.0..1.0).contains(&preempt_q),
            "job.preempt_q must be in [0, 1), got {preempt_q}"
        );
        let n_baseline = d.usize_or("job.n_baseline", 2)?;
        ensure!(n_baseline >= 1, "job.n_baseline must be >= 1");
        let unit_price =
            d.f64_or("job.unit_price", super::fig5::PREEMPTIBLE_PRICE)?;
        ensure!(unit_price >= 0.0, "job.unit_price must be >= 0");
        let job = JobSpec {
            n,
            eps,
            theta,
            deadline_slack,
            j,
            preempt_q,
            n_baseline,
            unit_price,
        };

        // --------------------------------------------------- runtime
        let runtime = match d.str_or("runtime.kind", "exp")?.as_str() {
            "exp" => RuntimeModel::ExpStragglers {
                lambda: d.f64_or("runtime.lambda", 0.25)?,
                delta: d.f64_or("runtime.delta", 0.5)?,
            },
            "deterministic" => RuntimeModel::Deterministic {
                r: d.f64_or("runtime.r", 10.0)?,
            },
            other => bail!("unknown runtime.kind '{other}'"),
        };
        // loop knobs (defaults = the pre-redesign compiled-in values)
        let knob_defaults = SchedKnobs::default();
        let sched = SchedKnobs {
            idle_step: d.f64_or("runtime.idle_step", knob_defaults.idle_step)?,
            stride: d.u64_or("runtime.stride", knob_defaults.stride)?,
            max_slots: d
                .u64_or("runtime.max_slots", knob_defaults.max_slots)?,
        };
        ensure!(
            sched.idle_step > 0.0,
            "runtime.idle_step must be > 0, got {}",
            sched.idle_step
        );
        ensure!(sched.stride >= 1, "runtime.stride must be >= 1");
        ensure!(sched.max_slots >= 1, "runtime.max_slots must be >= 1");

        // -------------------------------------------------- overhead
        let overhead = OverheadModel {
            checkpoint_every_iters: d
                .u64_or("overhead.checkpoint_every_iters", 0)?,
            checkpoint_cost_s: d.f64_or("overhead.checkpoint_cost_s", 0.0)?,
            restart_delay_s: d.f64_or("overhead.restart_delay_s", 0.0)?,
            lost_work_on_preempt: d
                .bool_or("overhead.lost_work_on_preempt", false)?,
            preempt_notice_s: d.f64_or("overhead.preempt_notice_s", 0.0)?,
        };
        overhead.validate()?;

        // ------------------------------------------------------- sgd
        let defaults = SgdHyper::paper_cnn();
        let sgd = SgdHyper {
            alpha: d.f64_or("sgd.alpha", defaults.alpha)?,
            c: d.f64_or("sgd.c", defaults.c)?,
            mu: d.f64_or("sgd.mu", defaults.mu)?,
            l: d.f64_or("sgd.l", defaults.l)?,
            m: d.f64_or("sgd.m", defaults.m)?,
            a0: d.f64_or("sgd.a0", defaults.a0)?,
        };
        sgd.validate().map_err(anyhow::Error::msg)?;

        // --------------------------------------------------- markets
        let market_labels = d.str_array_or_empty("markets")?;
        let mut portfolio = if d.has("portfolio.0.kind") {
            ensure!(
                market_labels.is_empty() && !d.has("market.kind"),
                "[[portfolio]] replaces the [market] table / markets \
                 lineup; declare one or the other"
            );
            Some(parse_portfolio(d)?)
        } else {
            None
        };
        // degenerate lowering: a one-entry portfolio with default
        // speed/q IS the classic single-market spec — lower it so the
        // digest is bit-identical to the `[market]` form
        let mut markets = Vec::new();
        if let Some(entries) = &portfolio {
            if entries.len() == 1
                && entries[0].speed == 1.0
                && entries[0].q == 0.0
            {
                let e = &entries[0];
                let label = if e.label == "m0" {
                    market_label(&e.kind)
                } else {
                    e.label.clone()
                };
                markets = vec![MarketSpec { label, kind: e.kind.clone() }];
                portfolio = None;
            }
        }
        // the restriction only binds on portfolios that survive
        // lowering: a degenerate one-entry portfolio IS a classic
        // market table, so lineup-mode specs may use that form too
        ensure!(
            portfolio.is_none() || mode == SweepMode::PerStrategy,
            "mode = \"lineup\" does not support multi-market \
             [[portfolio]] specs"
        );
        if portfolio.is_none() && markets.is_empty() {
            markets = if market_labels.is_empty() {
                if !d.has("market.kind") {
                    bail!(
                        "missing required [market] table (set market.kind, \
                         declare a markets = [...] lineup, or add \
                         [[portfolio]] entries)"
                    );
                }
                let kind = parse_market(d, "market")?;
                vec![MarketSpec { label: market_label(&kind), kind }]
            } else {
                market_labels
                    .iter()
                    .map(|label| {
                        let prefix = format!("market.{label}");
                        ensure!(
                            d.has(&format!("{prefix}.kind")),
                            "market '{label}' needs a [market.{label}] table \
                             with a kind"
                        );
                        Ok(MarketSpec {
                            label: label.clone(),
                            kind: parse_market(d, &prefix)?,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?
            };
        }

        // ------------------------------------------------ strategies
        let labels = d.str_array_or_empty("strategies")?;
        ensure!(
            !labels.is_empty(),
            "missing required key 'strategies' (a non-empty array of \
             lineup labels)"
        );
        for (i, l) in labels.iter().enumerate() {
            ensure!(
                !labels[..i].contains(l),
                "duplicate strategy label '{l}'"
            );
        }
        let strategies = labels
            .iter()
            .map(|label| parse_strategy(d, label, n))
            .collect::<Result<Vec<_>>>()?;

        // -------------------------------------------------------- axes
        let axis_names = d.str_array_or_empty("axes")?;
        let axes = axis_names
            .iter()
            .map(|an| {
                let prefix = format!("axis.{an}");
                let path = d.require_str(&format!("{prefix}.path"))?;
                let values = d.f64_array(&format!("{prefix}.values"))?;
                ensure!(!values.is_empty(), "axis '{an}' has no values");
                Ok(AxisSpec { name: an.clone(), path, values })
            })
            .collect::<Result<Vec<_>>>()?;

        // ----------------------------------------------------- metrics
        let metrics = d.str_array_or_empty("metrics")?;
        ensure!(
            !require_metrics || !metrics.is_empty(),
            "missing required key 'metrics' (a non-empty array of metric \
             names)"
        );

        Ok(ScenarioSpec {
            name,
            mode,
            job,
            runtime,
            sched,
            overhead,
            sgd,
            markets,
            portfolio,
            strategies,
            axes,
            metrics,
            replicates,
            seed,
        })
    }

    /// Market-axis width of the point space: portfolio specs are ONE
    /// market dimension (the portfolio itself), classic specs one per
    /// lineup entry.
    pub fn market_dim(&self) -> usize {
        match &self.portfolio {
            Some(_) => 1,
            None => self.markets.len(),
        }
    }
}

/// Parse the `[[portfolio]]` array-of-tables: flattened by the TOML
/// layer to `portfolio.<idx>.*` keys, indices dense from 0.
fn parse_portfolio(d: &TrackedDoc) -> Result<Vec<PortfolioEntrySpec>> {
    let mut entries = Vec::new();
    for i in 0.. {
        let prefix = format!("portfolio.{i}");
        if !d.has(&format!("{prefix}.kind")) {
            // a gap means a malformed entry, not the end of the array
            ensure!(
                !d.has(&format!("{prefix}.label"))
                    && !d.has(&format!("{prefix}.speed"))
                    && !d.has(&format!("{prefix}.q")),
                "[[portfolio]] entry {i} has knobs but no kind"
            );
            break;
        }
        let label =
            d.str_or(&format!("{prefix}.label"), &format!("m{i}"))?;
        let kind = parse_market(d, &prefix)
            .with_context(|| format!("[[portfolio]] entry {i}"))?;
        let speed = d.f64_or(&format!("{prefix}.speed"), 1.0)?;
        ensure!(
            speed.is_finite() && speed > 0.0,
            "[[portfolio]] entry {i} ('{label}'): speed must be finite \
             and > 0, got {speed}"
        );
        let q = d.f64_or(&format!("{prefix}.q"), 0.0)?;
        ensure!(
            q.is_finite() && (0.0..1.0).contains(&q),
            "[[portfolio]] entry {i} ('{label}'): q must be in [0, 1), \
             got {q}"
        );
        ensure!(
            entries
                .iter()
                .all(|e: &PortfolioEntrySpec| e.label != label),
            "duplicate portfolio label '{label}'"
        );
        entries.push(PortfolioEntrySpec { label, kind, speed, q });
    }
    ensure!(
        !entries.is_empty(),
        "[[portfolio]] declared but no entry has a kind"
    );
    Ok(entries)
}

/// Unknown-key rejection over a fully-consumed [`TrackedDoc`]: names
/// the enclosing table path, and for strategy tables also the lineup
/// position — a misspelled `rebid_factor` inside `[strategy.rebid]`
/// reads back as `strategy[2].rebid_facto`, not as a stray bare key.
/// Shared by [`ScenarioSpec::from_doc`] and the planner spec parser
/// ([`crate::opt`]), which tracks its `[objective]`/`[search]` reads on
/// the same doc before auditing.
pub(crate) fn reject_unknown_keys(
    d: &TrackedDoc,
    strategies: &[StrategyEntry],
) -> Result<()> {
    let unknown = d.unknown_keys();
    if !unknown.is_empty() {
        let described: Vec<String> = unknown
            .iter()
            .map(|k| {
                let base = crate::config::toml::describe_key(k);
                let lineup = k
                    .strip_prefix("strategy.")
                    .and_then(|rest| rest.split_once('.'))
                    .and_then(|(label, field)| {
                        strategies
                            .iter()
                            .position(|e| e.label == label)
                            .map(|i| format!(" = strategy[{i}].{field}"))
                    })
                    .unwrap_or_default();
                format!("{base}{lineup}")
            })
            .collect();
        bail!("unknown key(s) in spec: {}", described.join(", "));
    }
    Ok(())
}

fn market_label(kind: &MarketKind) -> String {
    match kind {
        MarketKind::Uniform { .. } => "uniform",
        MarketKind::Gaussian { .. } => "gaussian",
        MarketKind::Fixed { .. } => "fixed",
        MarketKind::TraceFile { .. } | MarketKind::TraceGen { .. } => "trace",
        MarketKind::TraceStrict { .. } => "tracefile",
    }
    .to_string()
}

fn parse_market(d: &TrackedDoc, prefix: &str) -> Result<MarketKind> {
    let key = |f: &str| format!("{prefix}.{f}");
    Ok(match d.require_str(&key("kind"))?.as_str() {
        "uniform" => {
            let lo = d.f64_or(&key("lo"), 0.2)?;
            let hi = d.f64_or(&key("hi"), 1.0)?;
            ensure!(lo < hi, "{prefix}: need lo < hi, got [{lo}, {hi}]");
            MarketKind::Uniform { lo, hi }
        }
        "gaussian" => {
            let mean = d.f64_or(&key("mean"), 0.6)?;
            let std = d.f64_or(&key("std"), 0.175)?;
            let lo = d.f64_or(&key("lo"), 0.2)?;
            let hi = d.f64_or(&key("hi"), 1.0)?;
            ensure!(std > 0.0, "{prefix}: std must be > 0");
            ensure!(lo < hi, "{prefix}: need lo < hi, got [{lo}, {hi}]");
            MarketKind::Gaussian { mean, std, lo, hi }
        }
        "fixed" => {
            let price = d.f64_or(&key("price"), 0.0)?;
            ensure!(price >= 0.0, "{prefix}: price must be >= 0");
            MarketKind::Fixed { price }
        }
        "tracefile" => {
            let path = d.require_str(&key("path"))?;
            // strict load now: `--check` fails on a malformed trace
            // before a single replicate runs, and the content hash
            // becomes the market's cache identity (DESIGN.md §9/§10)
            let content_fnv = tracefile::content_fnv(&path)
                .with_context(|| format!("{prefix}: trace file '{path}'"))?;
            tracefile::load(&path)
                .with_context(|| format!("{prefix}: trace file '{path}'"))?;
            let resample_s = d.f64_or(&key("resample_s"), 0.0)?;
            ensure!(
                resample_s.is_finite() && resample_s >= 0.0,
                "{prefix}: resample_s must be finite and >= 0 \
                 (0 = replay raw timestamps), got {resample_s}"
            );
            MarketKind::TraceStrict {
                path,
                cdf_resolution: d.f64_or(&key("cdf_resolution"), 60.0)?,
                resample_s,
                content_fnv,
            }
        }
        "trace" => {
            if let Some(path) = d.str_opt(&key("path"))? {
                let content_fnv = tracefile::content_fnv(&path)
                    .with_context(|| {
                        format!("{prefix}: trace file '{path}'")
                    })?;
                MarketKind::TraceFile {
                    path,
                    // loaded traces default to the historical-feed scale
                    // used by `simulate --config` (seconds-resolution)
                    cdf_resolution: d.f64_or(&key("cdf_resolution"), 60.0)?,
                    content_fnv,
                }
            } else {
                let base = super::fig4::default_trace_config();
                MarketKind::TraceGen {
                    seed: d.u64_or(&key("trace_seed"), 7)?,
                    cdf_resolution: d.f64_or(&key("cdf_resolution"), 0.02)?,
                    cfg: TraceGenConfig {
                        horizon: d.f64_or(&key("horizon"), base.horizon)?,
                        revision_interval: d.f64_or(
                            &key("revision_interval"),
                            base.revision_interval,
                        )?,
                        floor: d.f64_or(&key("floor"), base.floor)?,
                        cap: d.f64_or(&key("cap"), base.cap)?,
                        base: d.f64_or(&key("base"), base.base)?,
                        regime_switch_prob: d.f64_or(
                            &key("regime_switch_prob"),
                            base.regime_switch_prob,
                        )?,
                        contended_mult: d.f64_or(
                            &key("contended_mult"),
                            base.contended_mult,
                        )?,
                        spike_prob: d
                            .f64_or(&key("spike_prob"), base.spike_prob)?,
                        reversion: d
                            .f64_or(&key("reversion"), base.reversion)?,
                        noise: d.f64_or(&key("noise"), base.noise)?,
                    },
                }
            }
        }
        other => bail!(
            "unknown market kind '{other}' (uniform | gaussian | trace | \
             tracefile | fixed)"
        ),
    })
}

fn parse_strategy(
    d: &TrackedDoc,
    label: &str,
    n_default: usize,
) -> Result<StrategyEntry> {
    let key = |f: &str| format!("strategy.{label}.{f}");
    // a bare label with no [strategy.<label>] table names its own kind
    let kind_name = if d.has(&key("kind")) {
        d.require_str(&key("kind"))?
    } else {
        label.to_string()
    };
    let mut kind = StrategyKind::from_name(&kind_name, n_default)
        .with_context(|| format!("strategy '{label}'"))?;
    match &mut kind {
        StrategyKind::TwoBids { n1 }
        | StrategyKind::BidFractions { n1, .. }
        | StrategyKind::DynamicBids { n1, .. } => {
            *n1 = d.usize_or(&key("n1"), *n1)?;
            ensure!(*n1 >= 1, "strategy '{label}': n1 must be >= 1");
        }
        _ => {}
    }
    match &mut kind {
        StrategyKind::BidFractions { f1, gamma, .. } => {
            *f1 = d.f64_or(&key("f1"), *f1)?;
            *gamma = d.f64_or(&key("gamma"), *gamma)?;
            ensure!(
                *f1 > 0.0 && *f1 <= 1.0,
                "strategy '{label}': f1 must be in (0, 1]"
            );
            ensure!(
                (0.0..=1.0).contains(gamma),
                "strategy '{label}': gamma must be in [0, 1]"
            );
        }
        StrategyKind::DynamicBids { stage_iters, .. } => {
            *stage_iters = d.u64_or(&key("stage_iters"), *stage_iters)?;
            ensure!(
                *stage_iters >= 1,
                "strategy '{label}': stage_iters must be >= 1"
            );
        }
        StrategyKind::DynamicWorkers { eta } => {
            *eta = d.f64_or(&key("eta"), *eta)?;
            ensure!(
                *eta > 1.0,
                "strategy '{label}': Theorem 5 requires eta > 1"
            );
        }
        StrategyKind::NoticeRebid { rebid_factor } => {
            *rebid_factor = d.f64_or(&key("rebid_factor"), *rebid_factor)?;
            ensure!(
                rebid_factor.is_finite() && *rebid_factor >= 1.0,
                "strategy '{label}': rebid_factor must be >= 1, got \
                 {rebid_factor}"
            );
        }
        StrategyKind::ElasticFleet { budget_rate } => {
            *budget_rate = d.f64_or(&key("budget_rate"), *budget_rate)?;
            ensure!(
                budget_rate.is_finite() && *budget_rate > 0.0,
                "strategy '{label}': budget_rate must be finite and > 0, \
                 got {budget_rate}"
            );
        }
        StrategyKind::DeadlineAware { escalate_threshold } => {
            *escalate_threshold =
                d.f64_or(&key("escalate_threshold"), *escalate_threshold)?;
            ensure!(
                escalate_threshold.is_finite()
                    && *escalate_threshold > 0.0
                    && *escalate_threshold <= 1.0,
                "strategy '{label}': escalate_threshold must be in (0, 1], \
                 got {escalate_threshold}"
            );
        }
        StrategyKind::PortfolioMigrate { hysteresis } => {
            *hysteresis = d.f64_or(&key("hysteresis"), *hysteresis)?;
            ensure!(
                hysteresis.is_finite() && (0.0..1.0).contains(hysteresis),
                "strategy '{label}': hysteresis must be in [0, 1), got \
                 {hysteresis}"
            );
        }
        StrategyKind::ProactiveMigrate {
            hysteresis,
            window,
            horizon_s,
            smoothing,
        } => {
            *hysteresis = d.f64_or(&key("hysteresis"), *hysteresis)?;
            ensure!(
                hysteresis.is_finite() && (0.0..1.0).contains(hysteresis),
                "strategy '{label}': hysteresis must be in [0, 1), got \
                 {hysteresis}"
            );
            *window = d.usize_or(&key("window"), *window)?;
            ensure!(
                *window >= 1,
                "strategy '{label}': window must be >= 1"
            );
            *horizon_s = d.f64_or(&key("horizon_s"), *horizon_s)?;
            ensure!(
                horizon_s.is_finite() && *horizon_s > 0.0,
                "strategy '{label}': horizon_s must be finite and > 0, got \
                 {horizon_s}"
            );
            *smoothing = d.f64_or(&key("smoothing"), *smoothing)?;
            ensure!(
                smoothing.is_finite() && *smoothing >= 0.0,
                "strategy '{label}': smoothing must be finite and >= 0, \
                 got {smoothing}"
            );
        }
        StrategyKind::LookaheadBid { window, innovation_threshold } => {
            *window = d.usize_or(&key("window"), *window)?;
            ensure!(
                *window >= 1,
                "strategy '{label}': window must be >= 1"
            );
            *innovation_threshold = d.f64_or(
                &key("innovation_threshold"),
                *innovation_threshold,
            )?;
            ensure!(
                innovation_threshold.is_finite()
                    && *innovation_threshold > 0.0,
                "strategy '{label}': innovation_threshold must be finite \
                 and > 0, got {innovation_threshold}"
            );
        }
        _ => {}
    }
    let n = d.usize_opt(&key("n"))?;
    if let Some(n) = n {
        ensure!(n >= 1, "strategy '{label}': n must be >= 1");
    }
    let preempt_q = d.f64_opt(&key("preempt_q"))?;
    if let Some(q) = preempt_q {
        ensure!(
            (0.0..1.0).contains(&q),
            "strategy '{label}': preempt_q must be in [0, 1)"
        );
    }
    let unit_price = d.f64_opt(&key("unit_price"))?;
    Ok(StrategyEntry {
        label: label.to_string(),
        kind,
        n,
        preempt_q,
        unit_price,
    })
}

// ===================================================================
// The one StrategyKind -> PlannedStrategy build path
// ===================================================================

/// Everything a plan needs besides the kind itself.
pub struct PlanInputs<'a> {
    /// the bid-optimisation problem; `None` for fixed-price markets
    /// (preemptible strategies never bid)
    pub pb: Option<&'a BidProblem>,
    /// fleet size for preemptible strategies
    pub n: usize,
    /// job-level iteration budget (bid plans may choose their own J)
    pub j: u64,
    pub preempt_q: f64,
    pub unit_price: f64,
}

/// Build the [`PlannedStrategy`] for one `StrategyKind`. This is the
/// single build path shared by the figure harnesses, `simulate`, and
/// [`SpecScenario::prepare`] — the expensive Theorem 2/3 optimisation
/// happens here, once per grid point.
pub fn build_plan(
    label: &str,
    kind: &StrategyKind,
    inp: &PlanInputs,
) -> Result<PlannedStrategy> {
    let need_pb = || {
        inp.pb.ok_or_else(|| {
            anyhow::anyhow!(
                "strategy '{label}' bids on spot prices, but the market \
                 has no price distribution (kind = \"fixed\")"
            )
        })
    };
    Ok(match kind {
        StrategyKind::NoInterruption => {
            let pb = need_pb()?;
            let plan = pb.no_interruption_plan()?;
            // "bid above the price cap" [Sharma et al.]: an unbounded bid
            // keeps every worker active at any realizable price — also
            // above the prices an *estimated* (empirical) support can
            // undershoot. Workers still pay the spot price, never the bid.
            PlannedStrategy::Fixed {
                name: label.to_string(),
                bids: BidVector::uniform(pb.n, f64::INFINITY),
                j: plan.j.max(inp.j),
            }
        }
        StrategyKind::OneBid => {
            let pb = need_pb()?;
            let plan = pb
                .optimal_one_bid()
                .with_context(|| format!("one-bid plan for '{label}'"))?;
            PlannedStrategy::Fixed {
                name: label.to_string(),
                bids: BidVector::uniform(pb.n, plan.b),
                j: plan.j,
            }
        }
        StrategyKind::TwoBids { n1 } => {
            let pb = need_pb()?;
            ensure!(
                *n1 >= 1 && *n1 < pb.n,
                "strategy '{label}': need 0 < n1 < n, got n1={n1} n={}",
                pb.n
            );
            let plan = pb
                .cooptimize_j_two_bids(*n1)
                .with_context(|| format!("two-bid plan for '{label}'"))?;
            PlannedStrategy::Fixed {
                name: label.to_string(),
                bids: BidVector::two_group(pb.n, *n1, plan.b1, plan.b2),
                j: plan.j,
            }
        }
        StrategyKind::BidFractions { n1, f1, gamma } => {
            let pb = need_pb()?;
            ensure!(
                *n1 >= 1 && *n1 <= pb.n,
                "strategy '{label}': need 0 < n1 <= n, got n1={n1} n={}",
                pb.n
            );
            let b1 = pb.price.inv_cdf(*f1);
            let b2 = pb.price.inv_cdf(*gamma * *f1);
            PlannedStrategy::Fixed {
                name: label.to_string(),
                bids: BidVector::two_group(pb.n, *n1, b1, b2),
                j: inp.j,
            }
        }
        StrategyKind::DynamicBids { n1, stage_iters } => {
            let pb = need_pb()?;
            ensure!(
                *n1 >= 1 && *n1 < pb.n,
                "strategy '{label}': need 0 < n1 < n, got n1={n1} n={}",
                pb.n
            );
            let stages = vec![
                StageSpec {
                    n: (pb.n / 2).max(1),
                    n1: (*n1 / 2).max(1),
                    until_iter: *stage_iters,
                },
                StageSpec { n: pb.n, n1: *n1, until_iter: u64::MAX },
            ];
            PlannedStrategy::Dynamic {
                name: label.to_string(),
                problem: pb.clone(),
                stages,
                j: inp.j,
            }
        }
        StrategyKind::StaticWorkers => PlannedStrategy::StaticWorkers {
            name: label.to_string(),
            n: inp.n,
            j: inp.j,
            model: preemption_model(inp.preempt_q),
            unit_price: inp.unit_price,
        },
        StrategyKind::DynamicWorkers { eta } => {
            ensure!(
                *eta > 1.0,
                "strategy '{label}': Theorem 5 requires eta > 1"
            );
            PlannedStrategy::DynamicWorkers {
                name: label.to_string(),
                n0: 1,
                eta: *eta,
                j: inp.j,
                model: preemption_model(inp.preempt_q),
                unit_price: inp.unit_price,
                cap: 100_000,
            }
        }
        StrategyKind::NoticeRebid { rebid_factor } => {
            let pb = need_pb()?;
            let plan = pb.optimal_one_bid().with_context(|| {
                format!("notice-rebid base plan for '{label}'")
            })?;
            // rebids saturate at the support max, above which every
            // worker is admitted at any realizable price
            PlannedStrategy::NoticeRebid {
                name: label.to_string(),
                bids: BidVector::uniform(pb.n, plan.b),
                j: plan.j,
                rebid_factor: *rebid_factor,
                bid_cap: pb.price.support().1,
            }
        }
        StrategyKind::ElasticFleet { budget_rate } => {
            // the exact E[1/y] table is the policy's resize oracle,
            // computed once per grid point right here in prepare
            let model = preemption_model(inp.preempt_q);
            PlannedStrategy::ElasticFleet {
                name: label.to_string(),
                j: inp.j,
                table: RecipTable::build(&model, inp.n),
                budget_rate: *budget_rate,
            }
        }
        StrategyKind::DeadlineAware { escalate_threshold } => {
            let pb = need_pb()?;
            let plan = pb.optimal_one_bid().with_context(|| {
                format!("deadline-aware base plan for '{label}'")
            })?;
            PlannedStrategy::DeadlineAware {
                name: label.to_string(),
                bids: BidVector::uniform(pb.n, plan.b),
                j: plan.j,
                theta: pb.theta,
                p_active: pb.price.cdf(plan.b),
                slot_time: pb.runtime.expected(pb.n),
                threshold: *escalate_threshold,
            }
        }
        // placement across a [[portfolio]], not a bid plan: nothing to
        // optimise here — the migration rule is evaluated per slot by
        // `run_portfolio_engine`
        StrategyKind::PortfolioMigrate { hysteresis } => {
            PlannedStrategy::PortfolioMigrate {
                name: label.to_string(),
                n: inp.n,
                j: inp.j,
                hysteresis: *hysteresis,
            }
        }
        // forecast-driven placement (DESIGN.md §11): like
        // portfolio_migrate, nothing to optimise ahead of time — the
        // estimators only exist inside `run_portfolio_engine`
        StrategyKind::ProactiveMigrate {
            hysteresis,
            window,
            horizon_s,
            smoothing,
        } => PlannedStrategy::ProactiveMigrate {
            name: label.to_string(),
            n: inp.n,
            j: inp.j,
            hysteresis: *hysteresis,
            window: *window,
            horizon_s: *horizon_s,
            smoothing: *smoothing,
        },
        StrategyKind::LookaheadBid { window, innovation_threshold } => {
            let pb = need_pb()?;
            let plan = pb.optimal_one_bid().with_context(|| {
                format!("lookahead-bid base plan for '{label}'")
            })?;
            // the static distribution's mean anchors the scale-family
            // re-plan: bids scale by forecast-level / base_level
            let (_, hi) = pb.price.support();
            PlannedStrategy::LookaheadBid {
                name: label.to_string(),
                bids: BidVector::uniform(pb.n, plan.b),
                j: plan.j,
                window: *window,
                innovation_threshold: *innovation_threshold,
                base_level: pb.price.price_mass_below(hi),
                bid_cap: hi,
            }
        }
    })
}

fn preemption_model(q: f64) -> PreemptionModel {
    if q == 0.0 {
        PreemptionModel::None
    } else {
        PreemptionModel::Bernoulli { q }
    }
}

fn kind_bids(kind: &StrategyKind) -> bool {
    matches!(
        kind,
        StrategyKind::NoInterruption
            | StrategyKind::OneBid
            | StrategyKind::TwoBids { .. }
            | StrategyKind::BidFractions { .. }
            | StrategyKind::DynamicBids { .. }
            | StrategyKind::NoticeRebid { .. }
            | StrategyKind::DeadlineAware { .. }
            | StrategyKind::LookaheadBid { .. }
    )
}

// ===================================================================
// Metric catalogue
// ===================================================================

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum MetricKind {
    // per-run metrics (per_strategy mode)
    CostAtTarget,
    TimeAtTarget,
    TotalCost,
    TotalTime,
    FinalError,
    FinalAccuracy,
    Iters,
    IdleTime,
    AccPerDollar,
    // engine overhead-ledger metrics (per run)
    PreemptEvents,
    LostIters,
    CheckpointTime,
    RestartTime,
    // per-point constants (computed once in prepare)
    RecipExact,
    PZero,
    JensenPenalty,
    NMatchExact,
    BoundErr,
    ExpCost,
    ExpTime,
    // lineup-comparison metrics (lineup mode), index into the lineup
    LineupCost(usize),
    LineupSavingPct(usize),
    LineupAccRatio(usize),
}

impl MetricKind {
    fn needs_run(self) -> bool {
        matches!(
            self,
            MetricKind::CostAtTarget
                | MetricKind::TimeAtTarget
                | MetricKind::TotalCost
                | MetricKind::TotalTime
                | MetricKind::FinalError
                | MetricKind::FinalAccuracy
                | MetricKind::Iters
                | MetricKind::IdleTime
                | MetricKind::AccPerDollar
                | MetricKind::PreemptEvents
                | MetricKind::LostIters
                | MetricKind::CheckpointTime
                | MetricKind::RestartTime
                | MetricKind::LineupCost(_)
                | MetricKind::LineupSavingPct(_)
                | MetricKind::LineupAccRatio(_)
        )
    }

    fn is_preempt_const(self) -> bool {
        matches!(
            self,
            MetricKind::RecipExact
                | MetricKind::PZero
                | MetricKind::JensenPenalty
                | MetricKind::NMatchExact
        )
    }

    fn is_analytic_const(self) -> bool {
        matches!(
            self,
            MetricKind::BoundErr | MetricKind::ExpCost | MetricKind::ExpTime
        )
    }
}

fn compile_metric(
    name: &str,
    mode: SweepMode,
    strategies: &[StrategyEntry],
) -> Result<MetricKind> {
    if mode == SweepMode::Lineup {
        for (i, e) in strategies.iter().enumerate() {
            if name == format!("{}_cost", e.label) {
                return Ok(MetricKind::LineupCost(i));
            }
            if i > 0 && name == format!("{}_saving_pct", e.label) {
                return Ok(MetricKind::LineupSavingPct(i));
            }
            if i > 0 && name == format!("{}_acc_ratio", e.label) {
                return Ok(MetricKind::LineupAccRatio(i));
            }
        }
    }
    let kind = match name {
        "cost_at_target" => MetricKind::CostAtTarget,
        "time_at_target" => MetricKind::TimeAtTarget,
        "total_cost" | "cost" => MetricKind::TotalCost,
        "total_time" | "time" => MetricKind::TotalTime,
        "final_error" => MetricKind::FinalError,
        "final_accuracy" => MetricKind::FinalAccuracy,
        "iters" => MetricKind::Iters,
        "idle_time" => MetricKind::IdleTime,
        "acc_per_dollar" => MetricKind::AccPerDollar,
        "preempt_events" => MetricKind::PreemptEvents,
        "lost_iters" => MetricKind::LostIters,
        "checkpoint_time" => MetricKind::CheckpointTime,
        "restart_time" => MetricKind::RestartTime,
        "recip_exact" => MetricKind::RecipExact,
        "p_zero" => MetricKind::PZero,
        "jensen_penalty" => MetricKind::JensenPenalty,
        "n_match_exact" => MetricKind::NMatchExact,
        "bound_err" => MetricKind::BoundErr,
        "exp_cost" => MetricKind::ExpCost,
        "exp_time" => MetricKind::ExpTime,
        other => bail!(
            "unknown metric '{other}' (run metrics: cost_at_target, \
             time_at_target, total_cost, total_time, final_error, \
             final_accuracy, iters, idle_time, acc_per_dollar, \
             preempt_events, lost_iters, checkpoint_time, restart_time; \
             point constants: recip_exact, p_zero, jensen_penalty, \
             n_match_exact, bound_err, exp_cost, exp_time; lineup mode \
             additionally derives <label>_cost, <label>_saving_pct, \
             <label>_acc_ratio)"
        ),
    };
    if mode == SweepMode::Lineup && kind.needs_run() {
        bail!(
            "metric '{name}' is per-run; in lineup mode use the derived \
             '<label>_cost' / '<label>_saving_pct' / '<label>_acc_ratio' \
             names"
        );
    }
    Ok(kind)
}

// ===================================================================
// SpecScenario: the generic Scenario driver
// ===================================================================

/// The point-resolved view of a spec: base values with one market
/// selected and every axis value applied.
#[derive(Clone, Debug)]
struct Resolved {
    job: JobSpec,
    runtime: RuntimeModel,
    sched: SchedKnobs,
    overhead: OverheadModel,
    sgd: SgdHyper,
    /// for `[[portfolio]]` specs this mirrors entry 0 (`resolve`
    /// re-syncs it after axes apply) so the single-market plan and
    /// deadline derivation run unchanged
    market: MarketSpec,
    strategies: Vec<StrategyEntry>,
    portfolio: Option<Vec<PortfolioEntrySpec>>,
}

/// Cached per-grid-point state (DESIGN.md §3 prepare phase): planned
/// strategies, the price source, the resolved engine run parameters,
/// and every point-constant metric.
pub struct SpecCtx {
    plans: Vec<PlannedStrategy>,
    prices: PriceSource,
    bound: ErrorBound,
    params: RunParams,
    target_acc: f64,
    /// [recip_exact, p_zero, jensen_penalty, n_match_exact]
    preempt_consts: [f64; 4],
    /// [bound_err, exp_cost, exp_time]
    analytic_consts: [f64; 3],
    needs_sim: bool,
    /// the first entry's bid problem (None for fixed-price markets) —
    /// the closed-form surface the planner prunes against
    pb: Option<BidProblem>,
    /// multi-market state when the spec declares `[[portfolio]]`: the
    /// validated portfolio plus one price source per entry, indexed
    /// like the entries (DESIGN.md §10). `None` on single-market specs.
    portfolio: Option<(MarketPortfolio, Vec<PriceSource>)>,
}

impl SpecCtx {
    /// The planned strategies cached for this point (one in
    /// per-strategy mode, the whole lineup in lineup mode) — exposed so
    /// tests can pin plan equivalence against the figure harnesses.
    pub fn plans(&self) -> &[PlannedStrategy] {
        &self.plans
    }

    /// The resolved engine run parameters for this point — exposed so
    /// tests can pin the `[runtime]` / `[overhead]` plumbing.
    pub fn run_params(&self) -> &RunParams {
        &self.params
    }

    /// The Theorem-1 bound evaluator for this point.
    pub fn bound(&self) -> &ErrorBound {
        &self.bound
    }

    /// The first lineup entry's bid-optimisation problem, when the
    /// market has a price distribution — the [`crate::opt`] planner
    /// evaluates its Theorem 2/3 closed-form surfaces on this.
    pub fn bid_problem(&self) -> Option<&BidProblem> {
        self.pb.as_ref()
    }

    /// True when prices are drawn i.i.d. from the configured model —
    /// the regime where the Lemma 1/2 closed forms are exact (trace
    /// replays only estimate F, fixed-price markets never bid). Gates
    /// the planner's admissible-surface classification (DESIGN.md §7).
    pub fn iid_prices(&self) -> bool {
        matches!(self.prices, PriceSource::Iid(_))
    }

    /// Run one replicate of plan `idx` on the event engine with this
    /// point's cached price source and run parameters — the one
    /// engine-path executor shared by [`SpecScenario::run`] and the
    /// planner's refinement stage, so a planner recommendation is
    /// re-verified by exactly the simulation the sweep would run.
    pub fn execute_engine(
        &self,
        idx: usize,
        rng: &mut Rng,
    ) -> Result<EngineResult> {
        let mut p = self.plans[idx].build_policy()?;
        run_policy_engine(p.as_mut(), self.bound, &self.prices, &self.params, rng)
    }

    /// True when this point runs across a `[[portfolio]]` — the regime
    /// where no single-market closed form applies, so the planner must
    /// treat every strategy point as heuristic (DESIGN.md §10).
    pub fn is_portfolio(&self) -> bool {
        self.portfolio.is_some()
    }

    /// Run one replicate of plan `idx` through the multi-market slot
    /// loop ([`run_portfolio_engine`]) on this point's cached per-entry
    /// price sources. Panics on single-market points; go through
    /// [`SpecCtx::execute_point`] unless portfolio-ness is already
    /// established.
    pub fn execute_portfolio(
        &self,
        idx: usize,
        rng: &mut Rng,
    ) -> Result<EngineResult> {
        let (port, sources) = self
            .portfolio
            .as_ref()
            .expect("execute_portfolio on a single-market point");
        run_portfolio_engine(
            &self.plans[idx],
            &PortfolioRun { port, sources },
            self.bound,
            &self.params,
            rng,
        )
    }

    /// The one scalar replicate dispatcher: portfolio points go through
    /// the multi-market slot loop, everything else through the engine.
    /// The sweep's per-strategy path and the planner's refinement stage
    /// both call this, so a planner recommendation is re-verified by
    /// exactly the simulation the sweep would run.
    pub fn execute_point(
        &self,
        idx: usize,
        rng: &mut Rng,
    ) -> Result<EngineResult> {
        if self.portfolio.is_some() {
            self.execute_portfolio(idx, rng)
        } else {
            self.execute_engine(idx, rng)
        }
    }

    /// Run one replicate *block* of plan `idx` through the batched
    /// structure-of-arrays executor (`sim::batch`) — lane `r` draws
    /// from `rngs[r]`. Bit-identical to one [`SpecCtx::execute_engine`]
    /// call per stream; the scalar path stays on as the equivalence
    /// oracle (`tests/integration_batch.rs` pins every shipped preset).
    pub fn execute_engine_batch(
        &self,
        idx: usize,
        rngs: &mut [Rng],
    ) -> Result<Vec<EngineResult>> {
        let lanes = rngs
            .iter()
            .map(|_| {
                Ok(BatchLane {
                    policy: self.plans[idx].build_policy()?,
                    backend: Box::new(SyntheticBackend::new(self.bound)),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        run_batch(&self.params, lanes, &self.prices, rngs)
    }

    /// [`SpecCtx::execute_point`] with a [`TraceObs`] spliced into the
    /// event stream (DESIGN.md §12) — bit-identical to the untraced
    /// run; the tracer consumes no RNG.
    pub fn execute_point_traced(
        &self,
        idx: usize,
        rng: &mut Rng,
        tracer: &mut TraceObs,
    ) -> Result<EngineResult> {
        if let Some((port, sources)) = self.portfolio.as_ref() {
            run_portfolio_engine_obs(
                &self.plans[idx],
                &PortfolioRun { port, sources },
                self.bound,
                &self.params,
                rng,
                &mut [tracer as &mut dyn Observer],
            )
        } else {
            let mut p = self.plans[idx].build_policy()?;
            run_policy_engine_obs(
                p.as_mut(),
                self.bound,
                &self.prices,
                &self.params,
                rng,
                &mut [tracer as &mut dyn Observer],
            )
        }
    }

    /// [`SpecCtx::execute_engine_batch`] with one [`TraceObs`] per lane
    /// — same bit-identical contract as the untraced batch.
    pub fn execute_engine_batch_traced(
        &self,
        idx: usize,
        rngs: &mut [Rng],
        tracers: &mut [TraceObs],
    ) -> Result<Vec<EngineResult>> {
        let lanes = rngs
            .iter()
            .map(|_| {
                Ok(BatchLane {
                    policy: self.plans[idx].build_policy()?,
                    backend: Box::new(SyntheticBackend::new(self.bound)),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        run_batch_traced(&self.params, lanes, &self.prices, rngs, tracers)
    }
}

/// Which replicate runner executes the simulations.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RunnerKind {
    /// The event engine (`sim::engine`) — the production path.
    #[default]
    Engine,
    /// The verbatim pre-engine lockstep loop
    /// (`Scheduler::run_reference`) — the determinism oracle used by
    /// the equivalence tests. Cannot model `[overhead]`; the engine's
    /// ledger metrics come back zero.
    Reference,
}

/// Largest (markets x grid) combination count the load-time dry-run
/// resolves *exhaustively*; above it, validation falls back to
/// per-axis-value path/range checks so `--check` stays fast. Public so
/// the CLI's check summary can report which grade of validation
/// actually ran.
pub const FULL_RESOLVE_LIMIT: usize = 100_000;

/// A [`Scenario`] generically driven by a [`ScenarioSpec`].
pub struct SpecScenario {
    spec: ScenarioSpec,
    grid: Grid,
    metrics: Vec<MetricKind>,
    runner: RunnerKind,
}

impl SpecScenario {
    pub fn new(spec: ScenarioSpec) -> Result<Self> {
        // compile the metric set
        let metrics = spec
            .metrics
            .iter()
            .map(|m| compile_metric(m, spec.mode, &spec.strategies))
            .collect::<Result<Vec<_>>>()?;

        // bidding strategies need a price distribution on every market
        for m in &spec.markets {
            if matches!(m.kind, MarketKind::Fixed { .. }) {
                if let Some(e) =
                    spec.strategies.iter().find(|e| kind_bids(&e.kind))
                {
                    bail!(
                        "strategy '{}' bids on spot prices, but market \
                         '{}' is fixed-price",
                        e.label,
                        m.label
                    );
                }
                if metrics.iter().any(|k| k.is_analytic_const()) {
                    bail!(
                        "metrics bound_err/exp_cost/exp_time need a price \
                         distribution, but market '{}' is fixed-price",
                        m.label
                    );
                }
            }
        }
        if let Some(entries) = &spec.portfolio {
            // migrations are billed as checkpoint + restart, which the
            // ledger cannot disentangle from a periodic-checkpoint or
            // lost-work schedule running at the same time
            ensure!(
                spec.overhead.checkpoint_every_iters == 0
                    && !spec.overhead.lost_work_on_preempt,
                "[[portfolio]] specs bill migrations as checkpoint + \
                 restart; overhead.checkpoint_every_iters and \
                 overhead.lost_work_on_preempt are not supported"
            );
            if metrics.iter().any(|k| k.is_analytic_const()) {
                bail!(
                    "metrics bound_err/exp_cost/exp_time are single-market \
                     closed forms; not available for [[portfolio]] specs"
                );
            }
            // classic strategies are pinned to entry 0, so only its
            // price process must support bidding
            if matches!(entries[0].kind, MarketKind::Fixed { .. }) {
                if let Some(e) =
                    spec.strategies.iter().find(|e| kind_bids(&e.kind))
                {
                    bail!(
                        "strategy '{}' bids on spot prices, but portfolio \
                         entry '{}' (the home market) is fixed-price",
                        e.label,
                        entries[0].label
                    );
                }
            }
        } else if let Some(e) = spec.strategies.iter().find(|e| {
            matches!(
                e.kind,
                StrategyKind::PortfolioMigrate { .. }
                    | StrategyKind::ProactiveMigrate { .. }
            )
        }) {
            bail!(
                "strategy '{}' ({}) places workers across markets; the \
                 spec needs [[portfolio]] entries",
                e.label,
                e.kind.canonical_name()
            );
        }
        if metrics.iter().any(|k| k.is_analytic_const()) {
            // in per-strategy mode every point's own plan feeds the
            // analytic constants, so every entry must have fixed bids;
            // in lineup mode only the first (baseline) entry does
            let must_fix: &[StrategyEntry] = match spec.mode {
                SweepMode::PerStrategy => &spec.strategies,
                SweepMode::Lineup => &spec.strategies[..1],
            };
            for e in must_fix {
                ensure!(
                    matches!(
                        e.kind,
                        StrategyKind::NoInterruption
                            | StrategyKind::OneBid
                            | StrategyKind::TwoBids { .. }
                            | StrategyKind::BidFractions { .. }
                    ),
                    "metrics bound_err/exp_cost/exp_time describe a fixed \
                     bid vector, but strategy '{}' has no fixed bids",
                    e.label
                );
            }
        }

        let mut grid = Grid::new();
        for a in &spec.axes {
            grid = grid.axis(&a.name, a.values.clone());
        }

        let me = SpecScenario {
            spec,
            grid,
            metrics,
            runner: RunnerKind::default(),
        };
        // dry-run so bad axis paths, out-of-range values and statically
        // broken points (inverted market bounds, n1 >= n, unstable SGD
        // constants) fail at load / `--check`, not mid-sweep. Resolving
        // every real grid point validates exactly the combinations that
        // will run — axis values are never cross-checked against mixes
        // that no point actually pairs. Degenerately huge grids (which
        // could never be swept anyway) fall back to per-value path/range
        // checks on a fresh scratch each, so --check stays fast.
        let total = me.spec.market_dim() * me.grid.num_points();
        for m in 0..me.spec.market_dim() {
            if total <= FULL_RESOLVE_LIMIT {
                for g in 0..me.grid.num_points() {
                    me.resolve(m, g).with_context(|| {
                        let site = if me.spec.portfolio.is_some() {
                            "portfolio".to_string()
                        } else {
                            format!(
                                "market '{}'",
                                me.spec.markets[m].label
                            )
                        };
                        format!("{site}, grid point {g}")
                    })?;
                }
            } else {
                for axis in &me.spec.axes {
                    for &v in &axis.values {
                        let mut scratch = me.resolved_base(m);
                        set_path(&mut scratch, &axis.path, v).with_context(
                            || format!("axis '{}'", axis.name),
                        )?;
                    }
                }
            }
        }
        Ok(me)
    }

    pub fn spec(&self) -> &ScenarioSpec {
        &self.spec
    }

    pub fn grid(&self) -> &Grid {
        &self.grid
    }

    /// Switch the replicate runner to the pre-engine reference loop —
    /// the oracle half of the engine-equivalence tests. Errors when the
    /// spec configures `[overhead]` or lines up an event-native policy
    /// (`notice_rebid` / `elastic_fleet` / `deadline_aware`), neither
    /// of which the reference loop can model.
    pub fn with_reference_runner(mut self) -> Result<Self> {
        ensure!(
            self.spec.portfolio.is_none(),
            "spec '{}' declares [[portfolio]]; the reference lockstep \
             loop is single-market",
            self.spec.name
        );
        ensure!(
            !self.spec.overhead.enabled(),
            "spec '{}' enables [overhead]; the reference lockstep loop \
             cannot model it",
            self.spec.name
        );
        if let Some(e) =
            self.spec.strategies.iter().find(|e| e.kind.event_native())
        {
            bail!(
                "spec '{}': strategy '{}' ({}) is event-native; the \
                 reference lockstep loop cannot run it",
                self.spec.name,
                e.label,
                e.kind.canonical_name()
            );
        }
        self.runner = RunnerKind::Reference;
        Ok(self)
    }

    fn strategy_count(&self) -> usize {
        match self.spec.mode {
            SweepMode::PerStrategy => self.spec.strategies.len(),
            SweepMode::Lineup => 1,
        }
    }

    /// point -> (market, grid point, strategy); market slowest, strategy
    /// fastest — the ordering the fig3 sweep has always used, so preset
    /// digests match the pre-redesign harness. `pub(crate)` because the
    /// planner's lattice folding ([`crate::opt`]) must agree with this
    /// ordering exactly — one implementation, not a copy.
    pub(crate) fn decode(&self, point: usize) -> (usize, usize, usize) {
        let s_count = self.strategy_count();
        let g_count = self.grid.num_points();
        let s = point % s_count;
        let rest = point / s_count;
        (rest / g_count, rest % g_count, s)
    }

    fn resolved_base(&self, market: usize) -> Resolved {
        // a [[portfolio]] spec has no [market] lineup: entry 0 stands
        // in as the resolved market, so the single-market plan and
        // deadline derivation in `prepare` run unchanged
        let market = match &self.spec.portfolio {
            Some(entries) => MarketSpec {
                label: entries[0].label.clone(),
                kind: entries[0].kind.clone(),
            },
            None => self.spec.markets[market].clone(),
        };
        Resolved {
            job: self.spec.job.clone(),
            runtime: self.spec.runtime,
            sched: self.spec.sched,
            overhead: self.spec.overhead,
            sgd: self.spec.sgd,
            market,
            strategies: self.spec.strategies.clone(),
            portfolio: self.spec.portfolio.clone(),
        }
    }

    fn resolve(&self, market: usize, gpt: usize) -> Result<Resolved> {
        let mut r = self.resolved_base(market);
        let vals = self.grid.point(gpt);
        for (axis, v) in self.spec.axes.iter().zip(vals) {
            set_path(&mut r, &axis.path, v)
                .with_context(|| format!("axis '{}'", axis.name))?;
        }
        // a portfolio.0.* axis may have morphed the home entry; the
        // stand-in market must keep mirroring it
        if let Some(entries) = &r.portfolio {
            r.market.kind = entries[0].kind.clone();
        }
        r.validate()?;
        Ok(r)
    }
}

impl Resolved {
    /// Cross-field checks on a fully-resolved point: single-field ranges
    /// are enforced by `set_path` / the parser, but only the final
    /// combination can be judged for coherence (an axis may legally move
    /// one side of a pair the other axis fixes later).
    fn validate(&self) -> Result<()> {
        self.sgd.validate().map_err(anyhow::Error::msg)?;
        self.overhead.validate()?;
        fn check_kind(label: &str, kind: &MarketKind) -> Result<()> {
            match kind {
                MarketKind::Uniform { lo, hi }
                | MarketKind::Gaussian { lo, hi, .. } => {
                    ensure!(
                        lo < hi,
                        "market '{label}': need lo < hi, got [{lo}, {hi}]"
                    );
                }
                MarketKind::Fixed { .. }
                | MarketKind::TraceFile { .. }
                | MarketKind::TraceStrict { .. }
                | MarketKind::TraceGen { .. } => {}
            }
            Ok(())
        }
        check_kind(&self.market.label, &self.market.kind)?;
        if let Some(entries) = &self.portfolio {
            // axes can morph entries after parse-time validation
            for e in entries {
                check_kind(&e.label, &e.kind)?;
                ensure!(
                    e.speed.is_finite() && e.speed > 0.0,
                    "portfolio entry '{}': speed must be finite and > 0, \
                     got {}",
                    e.label,
                    e.speed
                );
                ensure!(
                    e.q.is_finite() && (0.0..1.0).contains(&e.q),
                    "portfolio entry '{}': q must be in [0, 1), got {}",
                    e.label,
                    e.q
                );
            }
            ensure!(
                self.overhead.checkpoint_every_iters == 0
                    && !self.overhead.lost_work_on_preempt,
                "[[portfolio]] points cannot enable \
                 overhead.checkpoint_every_iters or \
                 overhead.lost_work_on_preempt (migration billing would \
                 double-count)"
            );
        }
        for e in &self.strategies {
            let n_e = e.n.unwrap_or(self.job.n);
            match &e.kind {
                StrategyKind::TwoBids { n1 }
                | StrategyKind::DynamicBids { n1, .. } => {
                    ensure!(
                        *n1 >= 1 && *n1 < n_e,
                        "strategy '{}': need 0 < n1 < n, got n1={n1} \
                         n={n_e}",
                        e.label
                    );
                }
                StrategyKind::BidFractions { n1, .. } => {
                    ensure!(
                        *n1 >= 1 && *n1 <= n_e,
                        "strategy '{}': need 0 < n1 <= n, got n1={n1} \
                         n={n_e}",
                        e.label
                    );
                }
                _ => {}
            }
        }
        Ok(())
    }
}

fn build_market(
    kind: &MarketKind,
) -> Result<(Option<PriceModel>, PriceSource, Option<f64>)> {
    Ok(match kind {
        MarketKind::Uniform { lo, hi } => {
            let pm = PriceModel::Uniform { lo: *lo, hi: *hi };
            (Some(pm.clone()), PriceSource::Iid(pm), None)
        }
        MarketKind::Gaussian { mean, std, lo, hi } => {
            let pm = PriceModel::TruncGaussian {
                mean: *mean,
                std: *std,
                lo: *lo,
                hi: *hi,
            };
            (Some(pm.clone()), PriceSource::Iid(pm), None)
        }
        MarketKind::Fixed { price } => {
            (None, PriceSource::Fixed(*price), None)
        }
        MarketKind::TraceFile { path, cdf_resolution, .. } => {
            // same path resolution as the parse-time content hash, so
            // the bytes fingerprinted are the bytes replayed
            let trace = SpotTrace::load(tracefile::resolve(path))?;
            let cdf = trace.empirical_cdf(*cdf_resolution);
            let horizon = trace.horizon();
            (
                Some(PriceModel::Empirical(cdf)),
                PriceSource::Trace(trace),
                Some(horizon),
            )
        }
        MarketKind::TraceStrict {
            path, cdf_resolution, resample_s, ..
        } => {
            let loaded = tracefile::load(path)?;
            let trace = if *resample_s > 0.0 {
                tracefile::resample(&loaded, *resample_s)?
            } else {
                loaded
            };
            let cdf = trace.empirical_cdf(*cdf_resolution);
            let horizon = trace.horizon();
            (
                Some(PriceModel::Empirical(cdf)),
                PriceSource::Trace(trace),
                Some(horizon),
            )
        }
        MarketKind::TraceGen { cfg, seed, cdf_resolution } => {
            let mut rng = Rng::new(*seed);
            let trace = SpotTrace::generate(cfg, &mut rng);
            let cdf = trace.empirical_cdf(*cdf_resolution);
            let horizon = trace.horizon();
            (
                Some(PriceModel::Empirical(cdf)),
                PriceSource::Trace(trace),
                Some(horizon),
            )
        }
    })
}

impl SpecScenario {
    /// Point-constant (analytic) metric values; NAN for run-derived
    /// kinds, which the callers below handle first.
    fn const_value(ctx: &SpecCtx, k: MetricKind) -> f64 {
        match k {
            MetricKind::RecipExact => ctx.preempt_consts[0],
            MetricKind::PZero => ctx.preempt_consts[1],
            MetricKind::JensenPenalty => ctx.preempt_consts[2],
            MetricKind::NMatchExact => ctx.preempt_consts[3],
            MetricKind::BoundErr => ctx.analytic_consts[0],
            MetricKind::ExpCost => ctx.analytic_consts[1],
            MetricKind::ExpTime => ctx.analytic_consts[2],
            _ => f64::NAN,
        }
    }

    /// Per-strategy metric extraction from one engine result. Shared
    /// verbatim by the scalar `run` path and the batched `run_block`
    /// path, so the two can only diverge inside the executor itself —
    /// never in the metric math.
    fn per_strategy_metrics(
        &self,
        ctx: &SpecCtx,
        r: &EngineResult,
    ) -> Vec<f64> {
        self.metrics
            .iter()
            .map(|&k| match k {
                MetricKind::CostAtTarget => r
                    .series
                    .cost_at_accuracy(ctx.target_acc)
                    .unwrap_or(f64::NAN),
                MetricKind::TimeAtTarget => r
                    .series
                    .time_at_accuracy(ctx.target_acc)
                    .unwrap_or(f64::NAN),
                MetricKind::TotalCost => r.cost,
                MetricKind::TotalTime => r.elapsed,
                MetricKind::FinalError => r.final_error,
                MetricKind::FinalAccuracy => r.final_accuracy,
                MetricKind::Iters => r.iters as f64,
                MetricKind::IdleTime => r.idle_time,
                MetricKind::AccPerDollar => {
                    if r.cost > 0.0 {
                        r.final_accuracy / r.cost
                    } else {
                        0.0
                    }
                }
                MetricKind::PreemptEvents => r.preemptions as f64,
                MetricKind::LostIters => r.lost_iters as f64,
                MetricKind::CheckpointTime => r.checkpoint_time,
                MetricKind::RestartTime => r.restart_time,
                other => Self::const_value(ctx, other),
            })
            .collect()
    }

    /// Lineup metric math over one replicate's `(cost, final accuracy)`
    /// per entry. Shared by `run` and `run_block` like
    /// [`SpecScenario::per_strategy_metrics`].
    fn lineup_metrics(
        &self,
        ctx: &SpecCtx,
        finals: &[(f64, f64)],
    ) -> Vec<f64> {
        let (base_cost, base_acc) = finals[0];
        let base_acc = base_acc.max(1e-9);
        self.metrics
            .iter()
            .map(|&k| match k {
                MetricKind::LineupCost(i) => finals[i].0,
                MetricKind::LineupSavingPct(i) => {
                    100.0 * (base_cost - finals[i].0) / base_cost.max(1e-9)
                }
                MetricKind::LineupAccRatio(i) => finals[i].1 / base_acc,
                other => Self::const_value(ctx, other),
            })
            .collect()
    }
}

impl Scenario for SpecScenario {
    type Ctx = SpecCtx;

    fn points(&self) -> usize {
        self.spec.market_dim()
            * self.grid.num_points()
            * self.strategy_count()
    }

    fn label(&self, point: usize) -> String {
        let (m, g, s) = self.decode(point);
        let mut parts = Vec::new();
        if self.spec.markets.len() > 1 {
            parts.push(self.spec.markets[m].label.clone());
        }
        if !self.spec.axes.is_empty() {
            parts.push(self.grid.label(g));
        }
        if self.spec.mode == SweepMode::PerStrategy
            && self.spec.strategies.len() > 1
        {
            parts.push(self.spec.strategies[s].label.clone());
        }
        if parts.is_empty() {
            parts.push(self.spec.strategies[s].label.clone());
        }
        parts.join("/")
    }

    fn metrics(&self) -> Vec<String> {
        self.spec.metrics.clone()
    }

    fn prepare(&self, point: usize) -> Result<SpecCtx> {
        let (m, g, s) = self.decode(point);
        let r = self.resolve(m, g)?; // validated: resolve() checks points
        let bound = ErrorBound::new(r.sgd);
        let (price_model, prices, mut horizon) =
            build_market(&r.market.kind)?;

        // [[portfolio]]: one price source per entry (entry 0 reuses the
        // build above — r.market mirrors it), and the replay cap is the
        // *shortest* recorded path so no entry runs past its trace
        let portfolio = match &r.portfolio {
            Some(entries) => {
                let mut sources = Vec::with_capacity(entries.len());
                sources.push(prices.clone());
                for e in &entries[1..] {
                    let (_, src, h) = build_market(&e.kind)
                        .with_context(|| {
                            format!("portfolio entry '{}'", e.label)
                        })?;
                    horizon = match (horizon, h) {
                        (Some(a), Some(b)) => Some(a.min(b)),
                        (a, None) => a,
                        (None, b) => b,
                    };
                    sources.push(src);
                }
                let port = MarketPortfolio::new(
                    entries
                        .iter()
                        .map(|e| PortfolioEntry {
                            label: e.label.clone(),
                            speed: e.speed,
                            q: e.q,
                        })
                        .collect(),
                )?;
                Some((port, sources))
            }
            None => None,
        };

        let theta = match (r.job.theta, &price_model) {
            (Some(t), _) => t,
            // the Sec. VI convention: deadline = slack x expected
            // uninterrupted total runtime
            (None, Some(_)) => {
                r.job.deadline_slack
                    * r.job.j as f64
                    * r.runtime.expected(r.job.n)
            }
            // preemptible platforms have no bid deadline
            (None, None) => f64::INFINITY,
        };
        let cap = match horizon {
            // trace replays stop at the end of the recorded path
            Some(h) => h,
            None if theta.is_finite() => theta * 4.0,
            None => f64::INFINITY,
        };
        let target_acc = accuracy_for_error(&bound, r.job.eps);

        let entries: Vec<&StrategyEntry> = match self.spec.mode {
            SweepMode::PerStrategy => vec![&r.strategies[s]],
            SweepMode::Lineup => r.strategies.iter().collect(),
        };
        let mut plans = Vec::with_capacity(entries.len());
        let mut first_pb: Option<BidProblem> = None;
        for e in &entries {
            let n_e = e.n.unwrap_or(r.job.n);
            let pb_e = price_model.as_ref().map(|price| BidProblem {
                bound,
                price: price.clone(),
                runtime: r.runtime,
                n: n_e,
                eps: r.job.eps,
                theta,
            });
            let plan = build_plan(
                &e.label,
                &e.kind,
                &PlanInputs {
                    pb: pb_e.as_ref(),
                    n: n_e,
                    j: r.job.j,
                    preempt_q: e.preempt_q.unwrap_or(r.job.preempt_q),
                    unit_price: e.unit_price.unwrap_or(r.job.unit_price),
                },
            )
            .with_context(|| format!("strategy '{}'", e.label))?;
            if first_pb.is_none() {
                first_pb = pb_e;
            }
            plans.push(plan);
        }

        // ---- point-constant metrics, computed once per grid point
        let preempt_consts = if self
            .metrics
            .iter()
            .any(|k| k.is_preempt_const())
        {
            let (n_c, q_c) = match self.spec.mode {
                SweepMode::PerStrategy => (
                    entries[0].n.unwrap_or(r.job.n),
                    entries[0].preempt_q.unwrap_or(r.job.preempt_q),
                ),
                SweepMode::Lineup => (r.job.n, r.job.preempt_q),
            };
            let model = PreemptionModel::Bernoulli { q: q_c };
            let n_base = r.job.n_baseline.max(1);
            // exact Theorem-4 match: smallest fleet whose conditional
            // E[1/y] is at least as good as the baseline's 1/n_base
            let table = RecipTable::build(&model, n_c.max(8 * n_base));
            let n_match = (1..=table.n_max())
                .find(|&mm| table.recip(mm) <= 1.0 / n_base as f64)
                .map(|mm| mm as f64)
                .unwrap_or(f64::NAN);
            [
                table.recip(n_c),
                model.p_zero(n_c),
                jensen_penalty(&model, n_c),
                n_match,
            ]
        } else {
            [f64::NAN; 4]
        };

        let analytic_consts = if self
            .metrics
            .iter()
            .any(|k| k.is_analytic_const())
        {
            match (&plans[0], &first_pb) {
                (PlannedStrategy::Fixed { bids, j, .. }, Some(pb)) => {
                    let (n1, b1, b2) = (bids.n1, bids.b1, bids.b2);
                    let recip = pb.expected_recip_two(n1, b1, b2);
                    [
                        bound.phi_const(*j, recip),
                        pb.expected_cost_two(*j, n1, b1, b2),
                        pb.expected_time_two(*j, n1, b1, b2),
                    ]
                }
                // validated in `new`, but axes could have morphed things
                _ => bail!(
                    "bound_err/exp_cost/exp_time need a fixed-bid first \
                     strategy and a price-model market"
                ),
            }
        } else {
            [f64::NAN; 3]
        };

        let needs_sim = self.metrics.iter().any(|k| k.needs_run());
        Ok(SpecCtx {
            plans,
            prices,
            bound,
            params: RunParams {
                runtime: r.runtime,
                idle_step: r.sched.idle_step,
                theta_cap: cap,
                stride: r.sched.stride,
                max_slots: r.sched.max_slots,
                overhead: r.overhead,
            },
            target_acc,
            preempt_consts,
            analytic_consts,
            needs_sim,
            pb: first_pb,
            portfolio,
        })
    }

    fn run(
        &self,
        _point: usize,
        ctx: &SpecCtx,
        rng: &mut Rng,
    ) -> Result<Vec<f64>> {
        if !ctx.needs_sim {
            return Ok(self
                .metrics
                .iter()
                .map(|&k| Self::const_value(ctx, k))
                .collect());
        }
        // one runner switch for both modes: the engine is the
        // production path (every plan becomes a Policy — classic kinds
        // through the lockstep adapter, so digests are unchanged), the
        // reference loop the equivalence oracle (overhead- and
        // policy-incapable; ledger fields come back zero)
        let execute = |idx: usize, rng: &mut Rng| -> Result<EngineResult> {
            match self.runner {
                RunnerKind::Engine => ctx.execute_point(idx, rng),
                RunnerKind::Reference => {
                    let mut s = ctx.plans[idx].build()?;
                    run_synthetic_reference(
                        s.as_mut(),
                        ctx.bound,
                        &ctx.prices,
                        &ctx.params,
                        rng,
                    )
                    .map(EngineResult::from)
                }
            }
        };
        match self.spec.mode {
            SweepMode::PerStrategy => {
                let r = execute(0, rng)?;
                Ok(self.per_strategy_metrics(ctx, &r))
            }
            SweepMode::Lineup => {
                // the lineup shares this replicate's stream, consumed in
                // entry order — still a pure function of job identity
                let mut finals = Vec::with_capacity(ctx.plans.len());
                for idx in 0..ctx.plans.len() {
                    let r = execute(idx, rng)?;
                    let acc =
                        r.series.last().map(|p| p.accuracy).unwrap_or(0.0);
                    finals.push((r.cost, acc));
                }
                Ok(self.lineup_metrics(ctx, &finals))
            }
        }
    }

    fn run_block(
        &self,
        point: usize,
        ctx: &SpecCtx,
        rngs: &mut [Rng],
    ) -> Result<Vec<Vec<f64>>> {
        // The reference runner stays on the scalar oracle, and
        // const-only points consume no RNG either way — both take the
        // default per-replicate loop; portfolio points do too, because
        // the SoA executor is single-market. Everything else goes
        // through the batched structure-of-arrays executor;
        // bit-identical digests are pinned by tests/integration_batch.rs.
        if !ctx.needs_sim
            || self.runner == RunnerKind::Reference
            || ctx.portfolio.is_some()
        {
            return rngs
                .iter_mut()
                .map(|rng| self.run(point, ctx, rng))
                .collect();
        }
        match self.spec.mode {
            SweepMode::PerStrategy => {
                let results = ctx.execute_engine_batch(0, rngs)?;
                Ok(results
                    .iter()
                    .map(|r| self.per_strategy_metrics(ctx, r))
                    .collect())
            }
            SweepMode::Lineup => {
                // entry-major over the same lane streams reproduces the
                // scalar order exactly: lane r consumes its stream in
                // entry order because each entry's batch reads from the
                // very same `rngs[r]` the previous entry left behind
                let mut finals: Vec<Vec<(f64, f64)>> =
                    vec![Vec::with_capacity(ctx.plans.len()); rngs.len()];
                for idx in 0..ctx.plans.len() {
                    let results = ctx.execute_engine_batch(idx, rngs)?;
                    for (lane, r) in results.into_iter().enumerate() {
                        let acc = r
                            .series
                            .last()
                            .map(|p| p.accuracy)
                            .unwrap_or(0.0);
                        finals[lane].push((r.cost, acc));
                    }
                }
                Ok(finals
                    .iter()
                    .map(|f| self.lineup_metrics(ctx, f))
                    .collect())
            }
        }
    }

    fn run_traced(
        &self,
        point: usize,
        ctx: &SpecCtx,
        rng: &mut Rng,
        tracer: &mut TraceObs,
    ) -> Result<Vec<f64>> {
        // const-only points and the reference oracle have no engine
        // event stream to export; the trace just carries no events
        if !ctx.needs_sim || self.runner == RunnerKind::Reference {
            return self.run(point, ctx, rng);
        }
        match self.spec.mode {
            SweepMode::PerStrategy => {
                let r = ctx.execute_point_traced(0, rng, tracer)?;
                Ok(self.per_strategy_metrics(ctx, &r))
            }
            SweepMode::Lineup => {
                // entry order matches [`SpecScenario::run`]; each entry
                // restarts the engine clock, so the tracer is told which
                // entry it is watching (sim-time is monotone per entry)
                let mut finals = Vec::with_capacity(ctx.plans.len());
                for idx in 0..ctx.plans.len() {
                    tracer.set_entry(idx);
                    let r = ctx.execute_point_traced(idx, rng, tracer)?;
                    let acc =
                        r.series.last().map(|p| p.accuracy).unwrap_or(0.0);
                    finals.push((r.cost, acc));
                }
                Ok(self.lineup_metrics(ctx, &finals))
            }
        }
    }

    fn run_block_traced(
        &self,
        point: usize,
        ctx: &SpecCtx,
        rngs: &mut [Rng],
        tracers: &mut [TraceObs],
    ) -> Result<Vec<Vec<f64>>> {
        if tracers.len() != rngs.len()
            || !ctx.needs_sim
            || self.runner == RunnerKind::Reference
        {
            return self.run_block(point, ctx, rngs);
        }
        if ctx.portfolio.is_some() {
            // the SoA executor is single-market; portfolio blocks run
            // the scalar slot loop per replicate, traced
            return rngs
                .iter_mut()
                .zip(tracers.iter_mut())
                .map(|(rng, t)| {
                    t.set_path("scalar");
                    self.run_traced(point, ctx, rng, t)
                })
                .collect();
        }
        match self.spec.mode {
            SweepMode::PerStrategy => {
                let results =
                    ctx.execute_engine_batch_traced(0, rngs, tracers)?;
                Ok(results
                    .iter()
                    .map(|r| self.per_strategy_metrics(ctx, r))
                    .collect())
            }
            SweepMode::Lineup => {
                // entry-major like [`SpecScenario::run_block`], with
                // every lane's tracer advanced to the current entry
                let mut finals: Vec<Vec<(f64, f64)>> =
                    vec![Vec::with_capacity(ctx.plans.len()); rngs.len()];
                for idx in 0..ctx.plans.len() {
                    for t in tracers.iter_mut() {
                        t.set_entry(idx);
                    }
                    let results =
                        ctx.execute_engine_batch_traced(idx, rngs, tracers)?;
                    for (lane, r) in results.into_iter().enumerate() {
                        let acc = r
                            .series
                            .last()
                            .map(|p| p.accuracy)
                            .unwrap_or(0.0);
                        finals[lane].push((r.cost, acc));
                    }
                }
                Ok(finals
                    .iter()
                    .map(|f| self.lineup_metrics(ctx, f))
                    .collect())
            }
        }
    }
}

// ===================================================================
// Axis paths
// ===================================================================

fn as_count(path: &str, v: f64, min: u64) -> Result<u64> {
    ensure!(
        v.fract() == 0.0 && v >= min as f64 && v <= u64::MAX as f64,
        "axis value for '{path}' must be an integer >= {min}, got {v}"
    );
    Ok(v as u64)
}

/// Apply one axis value to a resolved point. This match *is* the axis
/// grammar; DESIGN.md §4 documents it.
fn set_path(r: &mut Resolved, path: &str, v: f64) -> Result<()> {
    let parts: Vec<&str> = path.split('.').collect();
    match parts.as_slice() {
        ["job", field] => set_job(&mut r.job, path, *field, v),
        // the loop knobs live under [runtime] beside the runtime model
        ["runtime", "idle_step"] => {
            ensure!(v > 0.0, "'{path}' must be > 0, got {v}");
            r.sched.idle_step = v;
            Ok(())
        }
        ["runtime", "stride"] => {
            r.sched.stride = as_count(path, v, 1)?;
            Ok(())
        }
        ["runtime", "max_slots"] => {
            r.sched.max_slots = as_count(path, v, 1)?;
            Ok(())
        }
        ["runtime", field] => set_runtime(&mut r.runtime, path, *field, v),
        ["overhead", field] => {
            set_overhead(&mut r.overhead, path, *field, v)
        }
        ["sgd", field] => set_sgd(&mut r.sgd, path, *field, v),
        ["market", field] => {
            ensure!(
                r.portfolio.is_none(),
                "axis path '{path}': [[portfolio]] specs sweep markets \
                 via portfolio.<idx>.*"
            );
            set_market(&mut r.market.kind, path, *field, v)
        }
        ["portfolio", idx, field] => {
            let entries = r.portfolio.as_mut().ok_or_else(|| {
                anyhow::anyhow!(
                    "axis path '{path}' needs [[portfolio]] entries"
                )
            })?;
            let i: usize = idx.parse().map_err(|_| {
                anyhow::anyhow!(
                    "axis path '{path}': '{idx}' is not a portfolio \
                     entry index"
                )
            })?;
            ensure!(
                i < entries.len(),
                "axis path '{path}': the portfolio has {} entries",
                entries.len()
            );
            let e = &mut entries[i];
            match *field {
                "speed" => {
                    ensure!(
                        v.is_finite() && v > 0.0,
                        "'{path}' must be finite and > 0, got {v}"
                    );
                    e.speed = v;
                    Ok(())
                }
                "q" => {
                    ensure!(
                        (0.0..1.0).contains(&v),
                        "'{path}' must be in [0, 1), got {v}"
                    );
                    e.q = v;
                    Ok(())
                }
                // anything else addresses the entry's market kind,
                // same grammar as market.*
                _ => set_market(&mut e.kind, path, field, v),
            }
        }
        ["strategy", label, field] => {
            let e = r
                .strategies
                .iter_mut()
                .find(|e| e.label == **label)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "axis path '{path}': no strategy labelled '{label}'"
                    )
                })?;
            set_strategy(e, path, *field, v)
        }
        _ => bail!(
            "unsupported axis path '{path}' (expected job.*, runtime.*, \
             overhead.*, sgd.*, market.*, portfolio.<idx>.*, or \
             strategy.<label>.*)"
        ),
    }
}

fn set_overhead(
    ov: &mut OverheadModel,
    path: &str,
    field: &str,
    v: f64,
) -> Result<()> {
    match field {
        "checkpoint_every_iters" => {
            ov.checkpoint_every_iters = as_count(path, v, 0)?;
        }
        "checkpoint_cost_s" => {
            ensure!(v >= 0.0, "'{path}' must be >= 0, got {v}");
            ov.checkpoint_cost_s = v;
        }
        "restart_delay_s" => {
            ensure!(v >= 0.0, "'{path}' must be >= 0, got {v}");
            ov.restart_delay_s = v;
        }
        "preempt_notice_s" => {
            ensure!(v >= 0.0, "'{path}' must be >= 0, got {v}");
            ov.preempt_notice_s = v;
        }
        // booleans sweep as 0/1
        "lost_work_on_preempt" => {
            ensure!(
                v == 0.0 || v == 1.0,
                "'{path}' must be 0 or 1, got {v}"
            );
            ov.lost_work_on_preempt = v == 1.0;
        }
        _ => bail!("unsupported axis path '{path}'"),
    }
    Ok(())
}

fn set_job(job: &mut JobSpec, path: &str, field: &str, v: f64) -> Result<()> {
    match field {
        "n" => job.n = as_count(path, v, 1)? as usize,
        "eps" => {
            ensure!(v > 0.0, "'{path}' must be > 0, got {v}");
            job.eps = v;
        }
        "theta" => {
            ensure!(v > 0.0, "'{path}' must be > 0, got {v}");
            job.theta = Some(v);
        }
        "deadline_slack" => {
            ensure!(v > 0.0, "'{path}' must be > 0, got {v}");
            job.deadline_slack = v;
        }
        "j" => job.j = as_count(path, v, 1)?,
        "preempt_q" => {
            ensure!(
                (0.0..1.0).contains(&v),
                "'{path}' must be in [0, 1), got {v}"
            );
            job.preempt_q = v;
        }
        "n_baseline" => job.n_baseline = as_count(path, v, 1)? as usize,
        "unit_price" => {
            ensure!(v >= 0.0, "'{path}' must be >= 0, got {v}");
            job.unit_price = v;
        }
        _ => bail!("unsupported axis path '{path}'"),
    }
    Ok(())
}

fn set_runtime(
    rt: &mut RuntimeModel,
    path: &str,
    field: &str,
    v: f64,
) -> Result<()> {
    match (rt, field) {
        (RuntimeModel::ExpStragglers { lambda, .. }, "lambda") => {
            ensure!(v > 0.0, "'{path}' must be > 0, got {v}");
            *lambda = v;
        }
        (RuntimeModel::ExpStragglers { delta, .. }, "delta") => {
            ensure!(v >= 0.0, "'{path}' must be >= 0, got {v}");
            *delta = v;
        }
        (RuntimeModel::Deterministic { r }, "r") => {
            ensure!(v > 0.0, "'{path}' must be > 0, got {v}");
            *r = v;
        }
        _ => bail!(
            "axis path '{path}' does not match the configured runtime kind"
        ),
    }
    Ok(())
}

// stability (c <= L, beta in (0,1)) is a property of the final
// combination, judged by `Resolved::validate` once every axis applied
fn set_sgd(sgd: &mut SgdHyper, path: &str, field: &str, v: f64) -> Result<()> {
    match field {
        "alpha" => sgd.alpha = v,
        "c" => sgd.c = v,
        "mu" => sgd.mu = v,
        "l" => sgd.l = v,
        "m" => sgd.m = v,
        "a0" => sgd.a0 = v,
        _ => bail!("unsupported axis path '{path}'"),
    }
    Ok(())
}

fn set_market(
    kind: &mut MarketKind,
    path: &str,
    field: &str,
    v: f64,
) -> Result<()> {
    let mismatch = || {
        anyhow::anyhow!(
            "axis path '{path}' does not match the configured market kind"
        )
    };
    match kind {
        MarketKind::Uniform { lo, hi } => match field {
            "lo" => *lo = v,
            "hi" => *hi = v,
            _ => return Err(mismatch()),
        },
        MarketKind::Gaussian { mean, std, lo, hi } => match field {
            "mean" => *mean = v,
            "std" => {
                ensure!(v > 0.0, "'{path}' must be > 0, got {v}");
                *std = v;
            }
            "lo" => *lo = v,
            "hi" => *hi = v,
            _ => return Err(mismatch()),
        },
        MarketKind::Fixed { price } => match field {
            "price" => {
                ensure!(v >= 0.0, "'{path}' must be >= 0, got {v}");
                *price = v;
            }
            _ => return Err(mismatch()),
        },
        MarketKind::TraceFile { cdf_resolution, .. } => match field {
            "cdf_resolution" => {
                ensure!(v > 0.0, "'{path}' must be > 0, got {v}");
                *cdf_resolution = v;
            }
            _ => return Err(mismatch()),
        },
        MarketKind::TraceStrict { cdf_resolution, resample_s, .. } => {
            match field {
                "cdf_resolution" => {
                    ensure!(v > 0.0, "'{path}' must be > 0, got {v}");
                    *cdf_resolution = v;
                }
                "resample_s" => {
                    ensure!(
                        v.is_finite() && v >= 0.0,
                        "'{path}' must be >= 0, got {v}"
                    );
                    *resample_s = v;
                }
                _ => return Err(mismatch()),
            }
        }
        MarketKind::TraceGen { cfg, seed, cdf_resolution } => match field {
            "trace_seed" => *seed = as_count(path, v, 0)?,
            "cdf_resolution" => {
                ensure!(v > 0.0, "'{path}' must be > 0, got {v}");
                *cdf_resolution = v;
            }
            "horizon" => {
                ensure!(v > 0.0, "'{path}' must be > 0, got {v}");
                cfg.horizon = v;
            }
            "revision_interval" => cfg.revision_interval = v,
            "floor" => cfg.floor = v,
            "cap" => cfg.cap = v,
            "base" => cfg.base = v,
            "regime_switch_prob" => cfg.regime_switch_prob = v,
            "contended_mult" => cfg.contended_mult = v,
            "spike_prob" => cfg.spike_prob = v,
            "reversion" => cfg.reversion = v,
            "noise" => cfg.noise = v,
            _ => return Err(mismatch()),
        },
    }
    Ok(())
}

fn set_strategy(
    e: &mut StrategyEntry,
    path: &str,
    field: &str,
    v: f64,
) -> Result<()> {
    match field {
        "n" => {
            e.n = Some(as_count(path, v, 1)? as usize);
            return Ok(());
        }
        "preempt_q" => {
            ensure!(
                (0.0..1.0).contains(&v),
                "'{path}' must be in [0, 1), got {v}"
            );
            e.preempt_q = Some(v);
            return Ok(());
        }
        "unit_price" => {
            ensure!(v >= 0.0, "'{path}' must be >= 0, got {v}");
            e.unit_price = Some(v);
            return Ok(());
        }
        _ => {}
    }
    match (&mut e.kind, field) {
        (
            StrategyKind::TwoBids { n1 }
            | StrategyKind::BidFractions { n1, .. }
            | StrategyKind::DynamicBids { n1, .. },
            "n1",
        ) => *n1 = as_count(path, v, 1)? as usize,
        (StrategyKind::BidFractions { f1, .. }, "f1") => {
            ensure!(
                v > 0.0 && v <= 1.0,
                "'{path}' must be in (0, 1], got {v}"
            );
            *f1 = v;
        }
        (StrategyKind::BidFractions { gamma, .. }, "gamma") => {
            ensure!(
                (0.0..=1.0).contains(&v),
                "'{path}' must be in [0, 1], got {v}"
            );
            *gamma = v;
        }
        (StrategyKind::DynamicBids { stage_iters, .. }, "stage_iters") => {
            *stage_iters = as_count(path, v, 1)?;
        }
        (StrategyKind::DynamicWorkers { eta }, "eta") => {
            ensure!(v > 1.0, "'{path}' requires eta > 1, got {v}");
            *eta = v;
        }
        (StrategyKind::NoticeRebid { rebid_factor }, "rebid_factor") => {
            ensure!(
                v.is_finite() && v >= 1.0,
                "'{path}' must be >= 1, got {v}"
            );
            *rebid_factor = v;
        }
        (StrategyKind::ElasticFleet { budget_rate }, "budget_rate") => {
            ensure!(
                v.is_finite() && v > 0.0,
                "'{path}' must be finite and > 0, got {v}"
            );
            *budget_rate = v;
        }
        (
            StrategyKind::DeadlineAware { escalate_threshold },
            "escalate_threshold",
        ) => {
            ensure!(
                v.is_finite() && v > 0.0 && v <= 1.0,
                "'{path}' must be in (0, 1], got {v}"
            );
            *escalate_threshold = v;
        }
        (
            StrategyKind::PortfolioMigrate { hysteresis }
            | StrategyKind::ProactiveMigrate { hysteresis, .. },
            "hysteresis",
        ) => {
            ensure!(
                v.is_finite() && (0.0..1.0).contains(&v),
                "'{path}' must be in [0, 1), got {v}"
            );
            *hysteresis = v;
        }
        (
            StrategyKind::ProactiveMigrate { window, .. }
            | StrategyKind::LookaheadBid { window, .. },
            "window",
        ) => {
            *window = as_count(path, v, 1)? as usize;
        }
        (StrategyKind::ProactiveMigrate { horizon_s, .. }, "horizon_s") => {
            ensure!(
                v.is_finite() && v > 0.0,
                "'{path}' must be finite and > 0, got {v}"
            );
            *horizon_s = v;
        }
        (StrategyKind::ProactiveMigrate { smoothing, .. }, "smoothing") => {
            ensure!(
                v.is_finite() && v >= 0.0,
                "'{path}' must be finite and >= 0, got {v}"
            );
            *smoothing = v;
        }
        (
            StrategyKind::LookaheadBid { innovation_threshold, .. },
            "innovation_threshold",
        ) => {
            ensure!(
                v.is_finite() && v > 0.0,
                "'{path}' must be finite and > 0, got {v}"
            );
            *innovation_threshold = v;
        }
        _ => bail!(
            "axis path '{path}' does not match strategy '{}' (kind {})",
            e.label,
            e.kind.canonical_name()
        ),
    }
    Ok(())
}

// ===================================================================
// Content-addressed fingerprints + the tier-B prepare-artifact cache
// ===================================================================
//
// `prepare` is RNG-free and a pure function of the point-resolved spec
// (DESIGN.md §3): CDF estimates, generated traces, Theorem-2/3 plans
// and `RecipTable`s depend only on resolved field values. That purity
// makes prepare output *content-addressable*: hash every resolved
// field with the repo's one digest primitive (`util::fnv`) and two
// points with equal keys have interchangeable `SpecCtx`s — the serve
// daemon's tier-B warm cache (`crate::serve`) and the planner's shared
// prepare stage (`crate::opt::run_plan_cached`) both key on this.

fn hash_job(h: &mut Fnv, j: &JobSpec) {
    h.u64(j.n as u64);
    h.f64(j.eps);
    h.opt_f64(j.theta);
    h.f64(j.deadline_slack);
    h.u64(j.j);
    h.f64(j.preempt_q);
    h.u64(j.n_baseline as u64);
    h.f64(j.unit_price);
}

fn hash_runtime(h: &mut Fnv, r: &RuntimeModel) {
    match r {
        RuntimeModel::ExpStragglers { lambda, delta } => {
            h.u64(0);
            h.f64(*lambda);
            h.f64(*delta);
        }
        RuntimeModel::Deterministic { r } => {
            h.u64(1);
            h.f64(*r);
        }
    }
}

fn hash_sched(h: &mut Fnv, s: &SchedKnobs) {
    h.f64(s.idle_step);
    h.u64(s.stride);
    h.u64(s.max_slots);
}

fn hash_overhead(h: &mut Fnv, o: &OverheadModel) {
    h.u64(o.checkpoint_every_iters);
    h.f64(o.checkpoint_cost_s);
    h.f64(o.restart_delay_s);
    h.bool(o.lost_work_on_preempt);
    h.f64(o.preempt_notice_s);
}

fn hash_sgd(h: &mut Fnv, s: &SgdHyper) {
    h.f64(s.alpha);
    h.f64(s.c);
    h.f64(s.mu);
    h.f64(s.l);
    h.f64(s.m);
    h.f64(s.a0);
}

fn hash_market(h: &mut Fnv, m: &MarketSpec) {
    h.str(&m.label);
    hash_market_kind(h, &m.kind);
}

fn hash_market_kind(h: &mut Fnv, kind: &MarketKind) {
    match kind {
        MarketKind::Uniform { lo, hi } => {
            h.u64(0);
            h.f64(*lo);
            h.f64(*hi);
        }
        MarketKind::Gaussian { mean, std, lo, hi } => {
            h.u64(1);
            h.f64(*mean);
            h.f64(*std);
            h.f64(*lo);
            h.f64(*hi);
        }
        MarketKind::Fixed { price } => {
            h.u64(2);
            h.f64(*price);
        }
        // the file *content* is the identity, never the path string:
        // two paths to identical bytes share cache entries, and an
        // edited file is a different market even at the same path
        // (DESIGN.md §9)
        MarketKind::TraceFile { cdf_resolution, content_fnv, .. } => {
            h.u64(3);
            h.u64(*content_fnv);
            h.f64(*cdf_resolution);
        }
        MarketKind::TraceGen { cfg, seed, cdf_resolution } => {
            h.u64(4);
            h.f64(cfg.horizon);
            h.f64(cfg.revision_interval);
            h.f64(cfg.floor);
            h.f64(cfg.cap);
            h.f64(cfg.base);
            h.f64(cfg.regime_switch_prob);
            h.f64(cfg.contended_mult);
            h.f64(cfg.spike_prob);
            h.f64(cfg.reversion);
            h.f64(cfg.noise);
            h.u64(*seed);
            h.f64(*cdf_resolution);
        }
        MarketKind::TraceStrict {
            cdf_resolution, resample_s, content_fnv, ..
        } => {
            h.u64(5);
            h.u64(*content_fnv);
            h.f64(*cdf_resolution);
            h.f64(*resample_s);
        }
    }
}

fn hash_portfolio_entry(h: &mut Fnv, e: &PortfolioEntrySpec) {
    h.str(&e.label);
    hash_market_kind(h, &e.kind);
    h.f64(e.speed);
    h.f64(e.q);
}

fn hash_strategy_kind(h: &mut Fnv, k: &StrategyKind) {
    h.str(k.canonical_name());
    match k {
        StrategyKind::NoInterruption
        | StrategyKind::OneBid
        | StrategyKind::StaticWorkers => {}
        StrategyKind::TwoBids { n1 } => h.u64(*n1 as u64),
        StrategyKind::BidFractions { n1, f1, gamma } => {
            h.u64(*n1 as u64);
            h.f64(*f1);
            h.f64(*gamma);
        }
        StrategyKind::DynamicBids { n1, stage_iters } => {
            h.u64(*n1 as u64);
            h.u64(*stage_iters);
        }
        StrategyKind::DynamicWorkers { eta } => h.f64(*eta),
        StrategyKind::NoticeRebid { rebid_factor } => h.f64(*rebid_factor),
        StrategyKind::ElasticFleet { budget_rate } => h.f64(*budget_rate),
        StrategyKind::DeadlineAware { escalate_threshold } => {
            h.f64(*escalate_threshold)
        }
        StrategyKind::PortfolioMigrate { hysteresis } => {
            h.f64(*hysteresis)
        }
        StrategyKind::ProactiveMigrate {
            hysteresis,
            window,
            horizon_s,
            smoothing,
        } => {
            h.f64(*hysteresis);
            h.u64(*window as u64);
            h.f64(*horizon_s);
            h.f64(*smoothing);
        }
        StrategyKind::LookaheadBid { window, innovation_threshold } => {
            h.u64(*window as u64);
            h.f64(*innovation_threshold);
        }
    }
}

fn hash_entry(h: &mut Fnv, e: &StrategyEntry) {
    h.str(&e.label);
    hash_strategy_kind(h, &e.kind);
    match e.n {
        None => h.u64(0),
        Some(n) => {
            h.u64(1);
            h.u64(n as u64);
        }
    }
    h.opt_f64(e.preempt_q);
    h.opt_f64(e.unit_price);
}

impl ScenarioSpec {
    /// Content-addressed identity of the *work* this spec describes: an
    /// FNV-1a digest over every parsed field — name, mode, job /
    /// runtime / sched / overhead / sgd knobs, the full market and
    /// strategy lineups, all axes, and the metric list.
    ///
    /// Two properties the cache-key tests pin:
    ///
    /// * it is a function of the parsed value, not the TOML text —
    ///   reordering tables or reformatting cannot change it;
    /// * `replicates` / `seed` are deliberately **excluded**: they are
    ///   only defaults the CLI (or a serve request) may override, so
    ///   the *effective* values are hashed separately into the request
    ///   key (`crate::serve`).
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv::new();
        h.bytes(b"scenario-spec/v1");
        h.str(&self.name);
        h.u64(match self.mode {
            SweepMode::PerStrategy => 0,
            SweepMode::Lineup => 1,
        });
        hash_job(&mut h, &self.job);
        hash_runtime(&mut h, &self.runtime);
        hash_sched(&mut h, &self.sched);
        hash_overhead(&mut h, &self.overhead);
        hash_sgd(&mut h, &self.sgd);
        h.u64(self.markets.len() as u64);
        for m in &self.markets {
            hash_market(&mut h, m);
        }
        // appended only when present, so every pre-portfolio spec keeps
        // its exact historical fingerprint
        if let Some(entries) = &self.portfolio {
            h.bytes(b"portfolio/v1");
            h.u64(entries.len() as u64);
            for e in entries {
                hash_portfolio_entry(&mut h, e);
            }
        }
        h.u64(self.strategies.len() as u64);
        for e in &self.strategies {
            hash_entry(&mut h, e);
        }
        h.u64(self.axes.len() as u64);
        for a in &self.axes {
            h.str(&a.name);
            h.str(&a.path);
            h.u64(a.values.len() as u64);
            for &v in &a.values {
                h.f64(v);
            }
        }
        h.u64(self.metrics.len() as u64);
        for m in &self.metrics {
            h.str(m);
        }
        h.finish()
    }
}

impl SpecScenario {
    /// Content-addressed identity of one point's prepare artifact: an
    /// FNV-1a digest over everything [`Scenario::prepare`] reads — the
    /// sweep mode, the metric list (it gates which point constants are
    /// computed), every point-resolved field and, in per-strategy mode,
    /// only the one selected lineup entry (so overlapping grids — even
    /// from different specs — share artifacts whenever a point resolves
    /// identically). Equal keys mean interchangeable [`SpecCtx`]s,
    /// because prepare is RNG-free and pure per point (DESIGN.md §3).
    pub fn point_fingerprint(&self, point: usize) -> Result<u64> {
        let (m, g, s) = self.decode(point);
        let r = self.resolve(m, g)?;
        let mut h = Fnv::new();
        h.bytes(b"prepare-artifact/v1");
        h.u64(self.spec.metrics.len() as u64);
        for name in &self.spec.metrics {
            h.str(name);
        }
        hash_job(&mut h, &r.job);
        hash_runtime(&mut h, &r.runtime);
        hash_sched(&mut h, &r.sched);
        hash_overhead(&mut h, &r.overhead);
        hash_sgd(&mut h, &r.sgd);
        hash_market(&mut h, &r.market);
        // appended only when present — pre-portfolio artifact keys are
        // untouched
        if let Some(entries) = &r.portfolio {
            h.bytes(b"portfolio/v1");
            h.u64(entries.len() as u64);
            for e in entries {
                hash_portfolio_entry(&mut h, e);
            }
        }
        match self.spec.mode {
            SweepMode::PerStrategy => {
                h.u64(0);
                hash_entry(&mut h, &r.strategies[s]);
            }
            SweepMode::Lineup => {
                h.u64(1);
                h.u64(r.strategies.len() as u64);
                for e in &r.strategies {
                    hash_entry(&mut h, e);
                }
            }
        }
        Ok(h.finish())
    }
}

/// The tier-B warm artifact cache: prepared [`SpecCtx`]s behind [`Arc`],
/// keyed by [`SpecScenario::point_fingerprint`]. One instance is shared
/// by the serve daemon across every submission (`crate::serve`) and by
/// the planner's prepare stage (`crate::opt::run_plan_cached`), so an
/// overlapping grid recomputes only its novel points. Thread-safe; on a
/// concurrent miss the first insert wins, so every caller observes one
/// stable `Arc` identity per key.
#[derive(Default)]
pub struct PrepareCache {
    map: Mutex<HashMap<u64, Arc<SpecCtx>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PrepareCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch the prepared artifact for `point`, preparing (and caching)
    /// it on a miss. The prepare itself runs outside the map lock so
    /// concurrent novel points never serialise; two racers on the same
    /// novel key both prepare (both count as misses) but the loser
    /// adopts the winner's `Arc`.
    pub fn get_or_prepare(
        &self,
        scenario: &SpecScenario,
        point: usize,
    ) -> Result<Arc<SpecCtx>> {
        let key = scenario.point_fingerprint(point)?;
        if let Some(ctx) = self.map.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(ctx));
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let fresh = Arc::new(scenario.prepare(point)?);
        let mut map = self.map.lock().unwrap();
        Ok(Arc::clone(map.entry(key).or_insert(fresh)))
    }

    /// Artifact reuses served so far.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Artifacts prepared from scratch so far.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Distinct artifacts currently cached.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A [`Scenario`] adapter running a [`SpecScenario`] with its prepare
/// phase routed through a shared [`PrepareCache`]. Digest-identical to
/// the bare scenario at any thread count: prepare is pure, `run` /
/// `run_block` delegate verbatim, and replicate RNG streams are pure
/// functions of job identity — the cache can change *when* an artifact
/// is built, never what it contains.
pub struct CachedSpecScenario<'a> {
    inner: &'a SpecScenario,
    cache: &'a PrepareCache,
}

impl<'a> CachedSpecScenario<'a> {
    pub fn new(inner: &'a SpecScenario, cache: &'a PrepareCache) -> Self {
        CachedSpecScenario { inner, cache }
    }
}

impl Scenario for CachedSpecScenario<'_> {
    type Ctx = Arc<SpecCtx>;

    fn points(&self) -> usize {
        self.inner.points()
    }

    fn label(&self, point: usize) -> String {
        self.inner.label(point)
    }

    fn metrics(&self) -> Vec<String> {
        self.inner.metrics()
    }

    fn prepare(&self, point: usize) -> Result<Arc<SpecCtx>> {
        self.cache.get_or_prepare(self.inner, point)
    }

    fn run(
        &self,
        point: usize,
        ctx: &Arc<SpecCtx>,
        rng: &mut Rng,
    ) -> Result<Vec<f64>> {
        self.inner.run(point, ctx, rng)
    }

    fn run_block(
        &self,
        point: usize,
        ctx: &Arc<SpecCtx>,
        rngs: &mut [Rng],
    ) -> Result<Vec<Vec<f64>>> {
        self.inner.run_block(point, ctx, rngs)
    }

    // tracing forwards too — without these the cache adapter would
    // silently drop every event from a traced serve-side sweep
    fn run_traced(
        &self,
        point: usize,
        ctx: &Arc<SpecCtx>,
        rng: &mut Rng,
        tracer: &mut TraceObs,
    ) -> Result<Vec<f64>> {
        self.inner.run_traced(point, ctx, rng, tracer)
    }

    fn run_block_traced(
        &self,
        point: usize,
        ctx: &Arc<SpecCtx>,
        rngs: &mut [Rng],
        tracers: &mut [TraceObs],
    ) -> Result<Vec<Vec<f64>>> {
        self.inner.run_block_traced(point, ctx, rngs, tracers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::{run_sweep, SweepConfig};

    // top-level keys precede every [table]: a bare key after a header
    // would inherit the table's dotted prefix (flat-parser subset)
    const MINI: &str = r#"
name = "mini"
strategies = ["static_workers"]
axes = ["n", "q"]
metrics = ["cost", "final_error", "recip_exact", "p_zero"]

[job]
n = 4
eps = 0.35
j = 400

[runtime]
kind = "deterministic"
r = 10.0

[market]
kind = "fixed"
price = 0.0

[axis.n]
path = "job.n"
values = [2, 4]

[axis.q]
path = "job.preempt_q"
values = [0.3, 0.6]
"#;

    #[test]
    fn mini_spec_parses_and_runs() {
        let spec = ScenarioSpec::from_str(MINI).unwrap();
        assert_eq!(spec.name, "mini");
        assert_eq!(spec.markets.len(), 1);
        assert_eq!(spec.strategies.len(), 1);
        let sc = SpecScenario::new(spec).unwrap();
        assert_eq!(sc.points(), 4);
        assert_eq!(sc.label(0), "n=2 q=0.3");
        assert_eq!(sc.label(3), "n=4 q=0.6");
        let cfg = SweepConfig { replicates: 3, seed: 5, threads: 2 };
        let out = run_sweep(&sc, &cfg).unwrap();
        assert_eq!(out.points.len(), 4);
        // recip_exact is a per-point constant: zero variance
        let recip_idx = 2;
        for p in &out.points {
            assert_eq!(p.stats[recip_idx].count(), 3);
            assert_eq!(p.stats[recip_idx].variance(), 0.0, "{}", p.label);
        }
    }

    #[test]
    fn spec_sweep_deterministic_across_threads() {
        let sc = SpecScenario::new(ScenarioSpec::from_str(MINI).unwrap())
            .unwrap();
        let base = SweepConfig { replicates: 4, seed: 9, threads: 1 };
        let serial = run_sweep(&sc, &base).unwrap();
        let par =
            run_sweep(&sc, &SweepConfig { threads: 8, ..base }).unwrap();
        assert_eq!(serial.digest(), par.digest());
    }

    #[test]
    fn unknown_keys_rejected_by_name() {
        let bad = MINI.replace("[job]", "[job]\nepss = 0.2");
        let err = ScenarioSpec::from_str(&bad).unwrap_err().to_string();
        assert!(err.contains("job.epss"), "{err}");
        // the enclosing table path is part of the message
        assert!(err.contains("in table [job]"), "{err}");
    }

    /// A typo inside a `[strategy.<label>]` table is reported with the
    /// enclosing table path *and* the lineup position, so a spec with
    /// several entries pinpoints which one carries the stray key.
    #[test]
    fn strategy_table_unknown_keys_name_lineup_position() {
        let text = r#"
name = "typo"
strategies = ["one_bid", "static", "rebid"]
metrics = ["total_cost"]

[job]
n = 8

[market]
kind = "uniform"

[strategy.static]
kind = "static_workers"

[strategy.rebid]
kind = "notice_rebid"
rebid_facto = 2.0
"#;
        let err = ScenarioSpec::from_str(text).unwrap_err().to_string();
        assert!(err.contains("strategy.rebid.rebid_facto"), "{err}");
        assert!(err.contains("in table [strategy.rebid]"), "{err}");
        assert!(err.contains("strategy[2].rebid_facto"), "{err}");
        // a stray key in an unrelated table gets the table, no index
        let bad = MINI.replace("[runtime]", "[runtime]\nkindd = 1");
        let err = ScenarioSpec::from_str(&bad).unwrap_err().to_string();
        assert!(err.contains("in table [runtime]"), "{err}");
        assert!(!err.contains("strategy["), "{err}");
    }

    #[test]
    fn wrong_types_and_ranges_rejected() {
        for (needle, replacement, what) in [
            ("n = 4", "n = 0", "job.n zero"),
            ("eps = 0.35", "eps = -0.2", "negative eps"),
            ("eps = 0.35", "eps = \"high\"", "string eps"),
            ("j = 400", "j = 0", "zero j"),
        ] {
            let bad = MINI.replace(needle, replacement);
            assert!(
                ScenarioSpec::from_str(&bad).is_err(),
                "{what} should be rejected"
            );
        }
    }

    #[test]
    fn missing_required_tables_rejected() {
        let no_market = MINI
            .replace("[market]", "[ignored_market]")
            .replace("kind = \"fixed\"", "kind2 = \"fixed\"");
        let err =
            ScenarioSpec::from_str(&no_market).unwrap_err().to_string();
        assert!(err.contains("market"), "{err}");

        let no_strategies =
            MINI.replace("strategies = [\"static_workers\"]", "");
        let err =
            ScenarioSpec::from_str(&no_strategies).unwrap_err().to_string();
        assert!(err.contains("strategies"), "{err}");

        let no_metrics = MINI.replace(
            "metrics = [\"cost\", \"final_error\", \"recip_exact\", \"p_zero\"]",
            "",
        );
        let err =
            ScenarioSpec::from_str(&no_metrics).unwrap_err().to_string();
        assert!(err.contains("metrics"), "{err}");
    }

    #[test]
    fn bad_axis_paths_fail_at_load() {
        let bad = MINI.replace("path = \"job.n\"", "path = \"job.nn\"");
        let spec = ScenarioSpec::from_str(&bad).unwrap();
        assert!(SpecScenario::new(spec).is_err());
        // non-integer value for an integer path
        let bad = MINI.replace("values = [2, 4]", "values = [2.5, 4]");
        let spec = ScenarioSpec::from_str(&bad).unwrap();
        assert!(SpecScenario::new(spec).is_err());
    }

    #[test]
    fn statically_broken_points_fail_at_load() {
        // n1 >= n is known before any sweep runs; --check must reject it
        let bad_split = r#"
name = "bad_split"
strategies = ["two_bids"]
metrics = ["total_cost"]

[job]
n = 8

[market]
kind = "uniform"

[strategy.two_bids]
kind = "two_bids"
n1 = 8
"#;
        // load-time dry-run errors carry a "market, grid point" context;
        // the root cause shows in the {:#} chain
        let err = format!(
            "{:#}",
            SpecScenario::new(ScenarioSpec::from_str(bad_split).unwrap())
                .unwrap_err()
        );
        assert!(err.contains("n1"), "{err}");

        // an axis that inverts the market support is caught at load too
        let inverted = r#"
name = "inverted"
strategies = ["one_bid"]
axes = ["hi"]
metrics = ["total_cost"]

[job]
n = 8

[market]
kind = "uniform"
lo = 0.2
hi = 1.0

[axis.hi]
path = "market.hi"
values = [0.1, 1.0]
"#;
        let err = format!(
            "{:#}",
            SpecScenario::new(ScenarioSpec::from_str(inverted).unwrap())
                .unwrap_err()
        );
        assert!(err.contains("lo < hi"), "{err}");
    }

    #[test]
    fn sweeping_coupled_sgd_fields_judges_real_points_only() {
        // c and L move together across the grid; every real point is
        // stable even though (new c, base L) would not be. The load-time
        // dry-run must not reject combinations no point actually pairs.
        let text = r#"
name = "coupled"
strategies = ["static_workers"]
axes = ["c", "l"]
metrics = ["cost"]

[job]
n = 2
j = 50

[runtime]
kind = "deterministic"
r = 10.0

[market]
kind = "fixed"

[sgd]
c = 1.0
l = 1.5

[axis.c]
path = "sgd.c"
values = [2.0]

[axis.l]
path = "sgd.l"
values = [4.0]
"#;
        let sc =
            SpecScenario::new(ScenarioSpec::from_str(text).unwrap()).unwrap();
        assert_eq!(sc.points(), 1);
    }

    #[test]
    fn analytic_metrics_require_fixed_bid_entries() {
        let text = r#"
name = "mixed_analytic"
strategies = ["two_bids", "dynamic"]
metrics = ["bound_err"]

[job]
n = 8

[market]
kind = "uniform"
"#;
        let err = SpecScenario::new(ScenarioSpec::from_str(text).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("dynamic"), "{err}");
    }

    #[test]
    fn unknown_metric_rejected() {
        let bad = MINI.replace("\"p_zero\"", "\"p_zeroo\"");
        let spec = ScenarioSpec::from_str(&bad).unwrap();
        let err = SpecScenario::new(spec).unwrap_err().to_string();
        assert!(err.contains("p_zeroo"), "{err}");
    }

    #[test]
    fn fixed_market_rejects_bidding_strategies() {
        let bad = MINI.replace(
            "strategies = [\"static_workers\"]",
            "strategies = [\"one_bid\"]",
        );
        let spec = ScenarioSpec::from_str(&bad).unwrap();
        assert!(SpecScenario::new(spec).is_err());
    }

    #[test]
    fn distinct_dynamic_entries_keep_their_labels() {
        let text = r#"
name = "two_dynamics"
strategies = ["fast", "slow"]
metrics = ["total_cost"]

[job]
n = 8
eps = 0.35
j = 2000

[market]
kind = "uniform"
lo = 0.2
hi = 1.0

[strategy.fast]
kind = "dynamic"
stage_iters = 500

[strategy.slow]
kind = "dynamic"
stage_iters = 1500
"#;
        let spec = ScenarioSpec::from_str(text).unwrap();
        let sc = SpecScenario::new(spec).unwrap();
        assert_eq!(sc.points(), 2);
        assert_eq!(sc.label(0), "fast");
        assert_eq!(sc.label(1), "slow");
        let a = sc.prepare(0).unwrap();
        let b = sc.prepare(1).unwrap();
        assert_eq!(a.plans[0].name(), "fast");
        assert_eq!(b.plans[0].name(), "slow");
        // the two plans differ only in their stage schedule
        match (&a.plans[0], &b.plans[0]) {
            (
                PlannedStrategy::Dynamic { stages: sa, .. },
                PlannedStrategy::Dynamic { stages: sb, .. },
            ) => {
                assert_eq!(sa[0].until_iter, 500);
                assert_eq!(sb[0].until_iter, 1500);
            }
            other => panic!("expected dynamic plans, got {other:?}"),
        }
    }

    #[test]
    fn per_entry_overrides_apply() {
        let text = r#"
name = "mixed_fleet"
strategies = ["cheap", "on_demand"]
metrics = ["cost", "final_accuracy"]

[job]
n = 4
preempt_q = 0.5
unit_price = 0.1
j = 200

[runtime]
kind = "deterministic"
r = 10.0

[market]
kind = "fixed"

[strategy.cheap]
kind = "static_workers"

[strategy.on_demand]
kind = "static_workers"
preempt_q = 0.0
unit_price = 0.3
n = 2
"#;
        let sc =
            SpecScenario::new(ScenarioSpec::from_str(text).unwrap()).unwrap();
        let on_demand = sc.prepare(1).unwrap();
        match &on_demand.plans[0] {
            PlannedStrategy::StaticWorkers {
                n, model, unit_price, ..
            } => {
                assert_eq!(*n, 2);
                assert!(matches!(model, PreemptionModel::None));
                assert_eq!(*unit_price, 0.3);
            }
            other => panic!("expected static workers, got {other:?}"),
        }
    }

    const CKPT: &str = r#"
name = "ckpt"
strategies = ["static_workers"]
axes = ["delay"]
metrics = ["cost", "iters", "lost_iters", "restart_time", "preempt_events", "checkpoint_time"]

[job]
n = 2
eps = 0.35
j = 200
preempt_q = 0.5
unit_price = 0.1

[runtime]
kind = "deterministic"
r = 10.0
idle_step = 2.0
stride = 5
max_slots = 100000

[market]
kind = "fixed"
price = 0.0

[overhead]
checkpoint_every_iters = 5
checkpoint_cost_s = 1.0
restart_delay_s = 0.0
lost_work_on_preempt = true

[axis.delay]
path = "overhead.restart_delay_s"
values = [0.0, 30.0]
"#;

    #[test]
    fn overhead_and_runtime_knobs_parse_and_plumb() {
        let spec = ScenarioSpec::from_str(CKPT).unwrap();
        assert_eq!(spec.sched.idle_step, 2.0);
        assert_eq!(spec.sched.stride, 5);
        assert_eq!(spec.sched.max_slots, 100_000);
        assert_eq!(spec.overhead.checkpoint_every_iters, 5);
        assert!(spec.overhead.lost_work_on_preempt);
        let sc = SpecScenario::new(spec).unwrap();
        // the axis overrides restart_delay_s per point
        let p0 = sc.prepare(0).unwrap();
        let p1 = sc.prepare(1).unwrap();
        assert_eq!(p0.run_params().idle_step, 2.0);
        assert_eq!(p0.run_params().stride, 5);
        assert_eq!(p0.run_params().max_slots, 100_000);
        assert_eq!(p0.run_params().overhead.restart_delay_s, 0.0);
        assert_eq!(p1.run_params().overhead.restart_delay_s, 30.0);
        // bad knob / overhead values are load errors
        for (needle, replacement) in [
            ("idle_step = 2.0", "idle_step = 0.0"),
            ("stride = 5", "stride = 0"),
            ("checkpoint_cost_s = 1.0", "checkpoint_cost_s = -1.0"),
            ("lost_work_on_preempt = true", "lost_work_on_preempt = 2"),
        ] {
            let bad = CKPT.replace(needle, replacement);
            assert!(
                ScenarioSpec::from_str(&bad).is_err(),
                "{replacement} should be rejected"
            );
        }
    }

    #[test]
    fn overhead_sweep_runs_and_meters_recovery() {
        let sc =
            SpecScenario::new(ScenarioSpec::from_str(CKPT).unwrap()).unwrap();
        let base = SweepConfig { replicates: 2, seed: 21, threads: 1 };
        let serial = run_sweep(&sc, &base).unwrap();
        let par =
            run_sweep(&sc, &SweepConfig { threads: 4, ..base }).unwrap();
        assert_eq!(serial.digest(), par.digest());
        let idx = |name: &str| {
            serial.metric_names.iter().position(|m| m == name).unwrap()
        };
        for p in &serial.points {
            // q = 0.5 on 2 workers: full interruptions are frequent,
            // work is lost and recomputed
            assert!(p.stats[idx("preempt_events")].mean() > 0.0, "{}", p.label);
            assert!(p.stats[idx("lost_iters")].mean() > 0.0, "{}", p.label);
            assert!(p.stats[idx("checkpoint_time")].mean() > 0.0, "{}", p.label);
            assert!(p.stats[idx("cost")].mean() > 0.0, "{}", p.label);
        }
        // recovery lag is billed only where the axis switches it on
        assert_eq!(serial.points[0].stats[idx("restart_time")].mean(), 0.0);
        assert!(serial.points[1].stats[idx("restart_time")].mean() > 0.0);
    }

    const POLICIES: &str = r#"
name = "policies"
strategies = ["rebid", "elastic", "deadline"]
metrics = ["total_cost", "iters", "final_error", "preempt_events"]

[job]
n = 8
eps = 0.35
j = 10000
preempt_q = 0.4

[runtime]
kind = "deterministic"
r = 10.0

[market]
kind = "uniform"
lo = 0.2
hi = 1.0

[strategy.rebid]
kind = "notice_rebid"
rebid_factor = 2.0

[strategy.elastic]
kind = "elastic_fleet"
budget_rate = 1.2

[strategy.deadline]
kind = "deadline_aware"
escalate_threshold = 0.6
"#;

    /// All three event-native policies are reachable from a TOML
    /// lineup, plan through `build_plan` with their per-entry keys
    /// applied, and sweep digest-identically across thread counts.
    #[test]
    fn policy_kinds_parse_plan_and_run_deterministically() {
        let sc = SpecScenario::new(ScenarioSpec::from_str(POLICIES).unwrap())
            .unwrap();
        assert_eq!(sc.points(), 3);
        let rebid = sc.prepare(0).unwrap();
        match &rebid.plans()[0] {
            PlannedStrategy::NoticeRebid {
                rebid_factor,
                bid_cap,
                bids,
                ..
            } => {
                assert_eq!(*rebid_factor, 2.0);
                assert_eq!(*bid_cap, 1.0, "support max of Uniform[0.2, 1]");
                assert!(bids.b1 > 0.2 && bids.b1 < 1.0);
            }
            other => panic!("expected a notice-rebid plan, got {other:?}"),
        }
        let elastic = sc.prepare(1).unwrap();
        match &elastic.plans()[0] {
            PlannedStrategy::ElasticFleet { table, budget_rate, .. } => {
                assert_eq!(*budget_rate, 1.2);
                assert_eq!(table.n_max(), 8);
                // the cached table carries the entry's preemption model
                let want = PreemptionModel::Bernoulli { q: 0.4 }
                    .expected_recip(8);
                assert_eq!(table.recip(8).to_bits(), want.to_bits());
            }
            other => panic!("expected an elastic-fleet plan, got {other:?}"),
        }
        let deadline = sc.prepare(2).unwrap();
        match &deadline.plans()[0] {
            PlannedStrategy::DeadlineAware {
                threshold,
                p_active,
                theta,
                slot_time,
                ..
            } => {
                assert_eq!(*threshold, 0.6);
                assert!(*p_active > 0.0 && *p_active <= 1.0);
                assert!(theta.is_finite());
                assert_eq!(*slot_time, 10.0);
            }
            other => panic!("expected a deadline-aware plan, got {other:?}"),
        }
        // event-native plans have no lockstep Strategy form...
        assert!(rebid.plans()[0].build().is_err());
        // ...but build as engine policies
        assert_eq!(rebid.plans()[0].build_policy().unwrap().name(), "rebid");
        // thread count is a pure throughput knob for reactive runs too
        let base = SweepConfig { replicates: 2, seed: 11, threads: 1 };
        let serial = run_sweep(&sc, &base).unwrap();
        let par =
            run_sweep(&sc, &SweepConfig { threads: 8, ..base }).unwrap();
        assert_eq!(serial.digest(), par.digest());
        // the reference lockstep loop refuses event-native lineups
        let err = SpecScenario::new(ScenarioSpec::from_str(POLICIES).unwrap())
            .unwrap()
            .with_reference_runner()
            .unwrap_err()
            .to_string();
        assert!(err.contains("event-native"), "{err}");
    }

    #[test]
    fn policy_kind_params_validated_at_check_time() {
        for (needle, replacement) in [
            ("rebid_factor = 2.0", "rebid_factor = 0.9"),
            ("budget_rate = 1.2", "budget_rate = 0.0"),
            ("budget_rate = 1.2", "budget_rate = -3.0"),
            ("escalate_threshold = 0.6", "escalate_threshold = 1.5"),
            ("escalate_threshold = 0.6", "escalate_threshold = 0.0"),
        ] {
            let bad = POLICIES.replace(needle, replacement);
            assert!(
                ScenarioSpec::from_str(&bad).is_err(),
                "{replacement} should be rejected at parse/--check time"
            );
        }
        // axis values over the policy knobs are range-checked at load
        let lineup = "strategies = [\"rebid\", \"elastic\", \"deadline\"]";
        let axis_table = "[axis.factor]\n\
                          path = \"strategy.rebid.rebid_factor\"\n\
                          values = [0.5, 2.0]\n\n[strategy.rebid]";
        let with_axis = POLICIES
            .replace(lineup, &format!("{lineup}\naxes = [\"factor\"]"))
            .replace("[strategy.rebid]", axis_table);
        let spec = ScenarioSpec::from_str(&with_axis).unwrap();
        // the range failure sits under the "market, grid point" context:
        // assert on the {:#} chain, not the outermost message alone
        let err = format!("{:#}", SpecScenario::new(spec).unwrap_err());
        assert!(err.contains(">= 1"), "{err}");
        // bidding policy kinds are rejected on fixed-price markets
        let fixed = POLICIES.replace(
            "kind = \"uniform\"\nlo = 0.2\nhi = 1.0",
            "kind = \"fixed\"\nprice = 0.1",
        );
        let err = SpecScenario::new(ScenarioSpec::from_str(&fixed).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("fixed-price"), "{err}");
    }

    const FORECAST: &str = r#"
name = "forecast"
strategies = ["lookahead", "proactive"]
metrics = ["total_cost", "iters", "preempt_events"]

[job]
n = 4
eps = 0.35
j = 600
preempt_q = 0.2

[runtime]
kind = "deterministic"
r = 10.0

[overhead]
checkpoint_cost_s = 2.0
restart_delay_s = 6.0

[[portfolio]]
label = "home"
kind = "uniform"
lo = 0.2
hi = 1.0
q = 0.05

[[portfolio]]
label = "away"
kind = "uniform"
lo = 0.15
hi = 0.9
speed = 1.4
q = 0.2

[strategy.lookahead]
kind = "lookahead_bid"
window = 32
innovation_threshold = 4.0

[strategy.proactive]
kind = "proactive_migrate"
hysteresis = 0.08
window = 48
horizon_s = 300.0
smoothing = 0.5
"#;

    /// Both forecast-driven kinds (DESIGN.md §11) are reachable from a
    /// TOML lineup, plan through `build_plan` with their keys applied,
    /// and sweep digest-identically across thread counts.
    #[test]
    fn forecast_kinds_parse_plan_and_run_deterministically() {
        let sc = SpecScenario::new(ScenarioSpec::from_str(FORECAST).unwrap())
            .unwrap();
        assert_eq!(sc.points(), 2);
        let lookahead = sc.prepare(0).unwrap();
        match &lookahead.plans()[0] {
            PlannedStrategy::LookaheadBid {
                window,
                innovation_threshold,
                base_level,
                bid_cap,
                bids,
                ..
            } => {
                assert_eq!(*window, 32);
                assert_eq!(*innovation_threshold, 4.0);
                // closed form for Uniform[0.2, 1]: E[p] = 0.6, cap = hi
                assert!((base_level - 0.6).abs() < 1e-12, "{base_level}");
                assert_eq!(*bid_cap, 1.0, "support max of entry 0");
                assert!(bids.b1 > 0.2 && bids.b1 < 1.0);
            }
            other => panic!("expected a lookahead-bid plan, got {other:?}"),
        }
        let proactive = sc.prepare(1).unwrap();
        match &proactive.plans()[0] {
            PlannedStrategy::ProactiveMigrate {
                hysteresis,
                window,
                horizon_s,
                smoothing,
                n,
                ..
            } => {
                assert_eq!(*hysteresis, 0.08);
                assert_eq!(*window, 48);
                assert_eq!(*horizon_s, 300.0);
                assert_eq!(*smoothing, 0.5);
                assert_eq!(*n, 4);
            }
            other => panic!("expected a proactive plan, got {other:?}"),
        }
        // neither kind has a lockstep Strategy form...
        assert!(lookahead.plans()[0].build().is_err());
        assert!(proactive.plans()[0].build().is_err());
        // ...lookahead builds as an engine policy; proactive is
        // portfolio-placement state owned by the engine loop itself
        assert_eq!(
            lookahead.plans()[0].build_policy().unwrap().name(),
            "lookahead"
        );
        let err =
            proactive.plans()[0].build_policy().unwrap_err().to_string();
        assert!(err.contains("portfolio"), "{err}");
        // forecaster updates draw no RNG: thread count stays a pure
        // throughput knob
        let base = SweepConfig { replicates: 2, seed: 17, threads: 1 };
        let serial = run_sweep(&sc, &base).unwrap();
        let par =
            run_sweep(&sc, &SweepConfig { threads: 8, ..base }).unwrap();
        assert_eq!(serial.digest(), par.digest());
        // the reference lockstep loop refuses portfolio specs
        let err =
            SpecScenario::new(ScenarioSpec::from_str(FORECAST).unwrap())
                .unwrap()
                .with_reference_runner()
                .unwrap_err()
                .to_string();
        assert!(err.contains("[[portfolio]]"), "{err}");
    }

    #[test]
    fn forecast_kind_params_validated_at_check_time() {
        for (needle, replacement) in [
            ("window = 32", "window = 0"),
            ("window = 48", "window = -3"),
            ("innovation_threshold = 4.0", "innovation_threshold = 0.0"),
            ("innovation_threshold = 4.0", "innovation_threshold = -2.0"),
            ("horizon_s = 300.0", "horizon_s = 0.0"),
            ("horizon_s = 300.0", "horizon_s = -5.0"),
            ("smoothing = 0.5", "smoothing = -1.0"),
            ("hysteresis = 0.08", "hysteresis = 1.0"),
        ] {
            let bad = FORECAST.replace(needle, replacement);
            assert_ne!(bad, FORECAST, "needle '{needle}' not found");
            assert!(
                ScenarioSpec::from_str(&bad).is_err(),
                "{replacement} should be rejected at parse/--check time"
            );
        }
        // axis values over the forecaster knobs are range-checked at
        // load, under the "market, grid point" context chain
        let lineup = "strategies = [\"lookahead\", \"proactive\"]";
        let axis_table = "[axis.win]\n\
                          path = \"strategy.proactive.window\"\n\
                          values = [0.0, 64.0]\n\n[strategy.lookahead]";
        let with_axis = FORECAST
            .replace(lineup, &format!("{lineup}\naxes = [\"win\"]"))
            .replace("[strategy.lookahead]", axis_table);
        let spec = ScenarioSpec::from_str(&with_axis).unwrap();
        let err = format!("{:#}", SpecScenario::new(spec).unwrap_err());
        assert!(err.contains(">= 1"), "{err}");
        // proactive placement without a [[portfolio]] is refused with
        // the same guidance as the reactive migrate kind
        let single = POLICIES.replace(
            "kind = \"notice_rebid\"\nrebid_factor = 2.0",
            "kind = \"proactive_migrate\"",
        );
        let err =
            SpecScenario::new(ScenarioSpec::from_str(&single).unwrap())
                .unwrap_err()
                .to_string();
        assert!(err.contains("needs [[portfolio]]"), "{err}");
    }

    /// Every forecaster key is a resolved field: changing it must move
    /// the scenario fingerprint (serve's cache identity).
    #[test]
    fn forecast_keys_move_the_fingerprint() {
        let base = ScenarioSpec::from_str(FORECAST).unwrap().fingerprint();
        for (needle, replacement) in [
            ("window = 32", "window = 33"),
            ("window = 48", "window = 49"),
            ("innovation_threshold = 4.0", "innovation_threshold = 4.5"),
            ("hysteresis = 0.08", "hysteresis = 0.09"),
            ("horizon_s = 300.0", "horizon_s = 301.0"),
            ("smoothing = 0.5", "smoothing = 0.6"),
        ] {
            let mutated = FORECAST.replace(needle, replacement);
            assert_ne!(mutated, FORECAST, "needle '{needle}' not found");
            assert_ne!(
                ScenarioSpec::from_str(&mutated).unwrap().fingerprint(),
                base,
                "mutating '{needle}' -> '{replacement}' kept the key"
            );
        }
    }

    #[test]
    fn reference_runner_matches_engine_and_rejects_overhead() {
        // overhead-free spec: the reference loop and the engine collate
        // to the same digest (the §5 contract in miniature)
        let cfg = SweepConfig { replicates: 3, seed: 5, threads: 2 };
        let engine =
            SpecScenario::new(ScenarioSpec::from_str(MINI).unwrap()).unwrap();
        let reference =
            SpecScenario::new(ScenarioSpec::from_str(MINI).unwrap())
                .unwrap()
                .with_reference_runner()
                .unwrap();
        let a = run_sweep(&engine, &cfg).unwrap();
        let b = run_sweep(&reference, &cfg).unwrap();
        assert_eq!(a.digest(), b.digest());
        // an overhead-enabled spec has no reference equivalent
        let sc =
            SpecScenario::new(ScenarioSpec::from_str(CKPT).unwrap()).unwrap();
        assert!(sc.with_reference_runner().is_err());
    }

    // ---- content-addressed fingerprints + tier-B cache ----

    /// MINI with its tables and keys permuted: the parsed value is
    /// identical, only the TOML text layout differs.
    const MINI_REORDERED: &str = r#"
metrics = ["cost", "final_error", "recip_exact", "p_zero"]
axes = ["n", "q"]
strategies = ["static_workers"]
name = "mini"

[axis.q]
values = [0.3, 0.6]
path = "job.preempt_q"

[market]
price = 0.0
kind = "fixed"

[runtime]
r = 10.0
kind = "deterministic"

[axis.n]
values = [2, 4]
path = "job.n"

[job]
j = 400
eps = 0.35
n = 4
"#;

    #[test]
    fn fingerprint_is_layout_invariant_and_field_sensitive() {
        let base = ScenarioSpec::from_str(MINI).unwrap().fingerprint();
        // reordered tables, same parsed value -> same fingerprint
        let reordered =
            ScenarioSpec::from_str(MINI_REORDERED).unwrap().fingerprint();
        assert_eq!(base, reordered);
        // replicates/seed are CLI-overridable defaults: excluded
        let seeded = format!("replicates = 3\nseed = 42\n{MINI}");
        assert_eq!(
            ScenarioSpec::from_str(&seeded).unwrap().fingerprint(),
            base
        );
        // every resolved-field change must move the fingerprint
        for (needle, replacement) in [
            ("name = \"mini\"", "name = \"mini2\""),
            ("n = 4", "n = 8"),
            ("eps = 0.35", "eps = 0.36"),
            ("j = 400", "j = 401"),
            ("r = 10.0", "r = 10.5"),
            ("price = 0.0", "price = 0.01"),
            ("values = [2, 4]", "values = [2, 5]"),
            ("values = [0.3, 0.6]", "values = [0.3]"),
            ("\"p_zero\"]", "\"p_zero\", \"jensen_penalty\"]"),
            ("strategies = [\"static_workers\"]",
             "strategies = [\"dynamic_workers\"]"),
        ] {
            let mutated = MINI.replace(needle, replacement);
            assert_ne!(mutated, MINI, "needle '{needle}' not found");
            assert_ne!(
                ScenarioSpec::from_str(&mutated).unwrap().fingerprint(),
                base,
                "mutating '{needle}' -> '{replacement}' kept the key"
            );
        }
    }

    #[test]
    fn point_fingerprints_shared_across_overlapping_grids() {
        // two grids over job.preempt_q overlapping at q = 0.6
        let a = SpecScenario::new(ScenarioSpec::from_str(MINI).unwrap())
            .unwrap();
        let b = SpecScenario::new(
            ScenarioSpec::from_str(
                &MINI.replace("values = [0.3, 0.6]", "values = [0.6, 0.9]"),
            )
            .unwrap(),
        )
        .unwrap();
        // A's points are (n, q) = (2,.3) (2,.6) (4,.3) (4,.6);
        // B's are (2,.6) (2,.9) (4,.6) (4,.9): 1A=0B and 3A=2B overlap
        assert_eq!(
            a.point_fingerprint(1).unwrap(),
            b.point_fingerprint(0).unwrap()
        );
        assert_eq!(
            a.point_fingerprint(3).unwrap(),
            b.point_fingerprint(2).unwrap()
        );
        assert_ne!(
            a.point_fingerprint(0).unwrap(),
            b.point_fingerprint(1).unwrap()
        );
        // shared cache: the overlap reuses the same Arc, novel points
        // are prepared fresh
        let cache = PrepareCache::new();
        for p in 0..a.points() {
            cache.get_or_prepare(&a, p).unwrap();
        }
        assert_eq!(cache.misses(), 4);
        assert_eq!(cache.len(), 4);
        let shared = cache.get_or_prepare(&b, 0).unwrap();
        assert!(Arc::ptr_eq(&shared, &cache.get_or_prepare(&a, 1).unwrap()));
        for p in 0..b.points() {
            cache.get_or_prepare(&b, p).unwrap();
        }
        // b contributed 2 novel artifacts (q=0.9 at n=2,4)
        assert_eq!(cache.len(), 6);
        assert!(cache.hits() >= 3);
    }

    #[test]
    fn cached_scenario_digest_identical_to_bare() {
        let cfg = SweepConfig { replicates: 3, seed: 5, threads: 2 };
        let bare =
            SpecScenario::new(ScenarioSpec::from_str(MINI).unwrap()).unwrap();
        let cold = run_sweep(&bare, &cfg).unwrap();
        let cache = PrepareCache::new();
        let cached = CachedSpecScenario::new(&bare, &cache);
        // cold pass fills the cache, warm pass runs entirely off it;
        // both collate to the bare scenario's digest
        let first = run_sweep(&cached, &cfg).unwrap();
        assert_eq!(cache.hits(), 0);
        let warm = run_sweep(&cached, &cfg).unwrap();
        assert_eq!(cache.misses(), 4);
        assert_eq!(cache.hits(), 4);
        assert_eq!(cold.digest(), first.digest());
        assert_eq!(cold.digest(), warm.digest());
    }
}
