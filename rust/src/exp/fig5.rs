//! Fig. 5: preemptible instances without bids (Sec. V).
//!
//! (a) accuracy-per-dollar across choices of the provisioned count n at
//!     preemption probability q = 0.5, with the Theorem-4 estimate
//!     n* ~ n_no-preempt / (1 - q) highlighted against "random" choices,
//!     plus the paper's No-preemption baseline (2 on-demand workers at
//!     the higher on-demand price);
//! (b) static n = 1 for J = 10^4 iterations vs the Theorem-5 dynamic
//!     schedule n_j = ceil(1.0004^{j-1}) run for the (much smaller) J'
//!     from Theorem 5 with chi = 1.
//!
//! Price model: a fixed preemptible unit price and a 3x on-demand price
//! (the GCP preemptible discount is ~70%).

use anyhow::Result;

use crate::coordinator::strategy::{DynamicWorkers, StaticWorkers};
use crate::preempt::PreemptionModel;
use crate::sim::PriceSource;
use crate::theory::bounds::{ErrorBound, SgdHyper};
use crate::theory::runtime_model::RuntimeModel;
use crate::theory::workers::WorkerProblem;

use super::run_synthetic;

pub const PREEMPTIBLE_PRICE: f64 = 0.1;
pub const ON_DEMAND_PRICE: f64 = 0.3;

#[derive(Clone, Debug)]
pub struct ProvisioningOutcome {
    pub label: String,
    pub n_or_eta: f64,
    pub iters: u64,
    pub cost: f64,
    pub final_error: f64,
    pub final_accuracy: f64,
    pub accuracy_per_dollar: f64,
}

#[derive(Clone, Debug)]
pub struct Fig5Output {
    /// panel (a): no-preemption baseline + n sweep at q = 0.5
    pub panel_a: Vec<ProvisioningOutcome>,
    /// the n Theorem 4's reasoning selects for panel (a)
    pub n_star: usize,
    /// panel (b): static n = 1 vs dynamic eta = 1.0004
    pub panel_b: Vec<ProvisioningOutcome>,
    /// Theorem-5 iteration count used by the dynamic run
    pub j_dynamic: u64,
}

pub struct Fig5Params {
    pub j: u64,
    pub q: f64,
    pub n_baseline: usize,
    pub n_sweep: Vec<usize>,
    pub eta: f64,
    pub seed: u64,
}

impl Default for Fig5Params {
    fn default() -> Self {
        Fig5Params {
            j: 10_000,
            q: 0.5,
            n_baseline: 2,
            n_sweep: vec![2, 4, 8, 16],
            eta: 1.0004,
            seed: 2020,
        }
    }
}

pub fn run(p: &Fig5Params) -> Result<Fig5Output> {
    let bound = ErrorBound::new(SgdHyper::paper_cnn());
    let runtime = RuntimeModel::Deterministic { r: 10.0 };
    let prices = PriceSource::Fixed(0.0); // strategies carry their price

    let mut panel_a = Vec::new();

    // ---- No-preemption baseline: n_baseline on-demand workers
    {
        let mut s = StaticWorkers {
            n: p.n_baseline,
            j: p.j,
            model: PreemptionModel::None,
            unit_price: ON_DEMAND_PRICE,
        };
        let r = run_synthetic(
            &mut s,
            bound,
            &prices,
            runtime,
            f64::INFINITY,
            p.seed,
        )?;
        panel_a.push(outcome(
            format!("no_preemption_n{}", p.n_baseline),
            p.n_baseline as f64,
            &r,
        ));
    }

    // ---- Theorem 4's scaling: to match the no-preemption baseline's
    // effective worker count under preemption q, provision
    // n* = n_baseline / (1 - q) (the paper's Fig. 5a argument).
    let n_star =
        ((p.n_baseline as f64) / (1.0 - p.q)).round().max(1.0) as usize;

    // ---- n sweep at q (includes n*)
    let mut sweep = p.n_sweep.clone();
    if !sweep.contains(&n_star) {
        sweep.push(n_star);
        sweep.sort_unstable();
    }
    for (k, n) in sweep.iter().enumerate() {
        let mut s = StaticWorkers {
            n: *n,
            j: p.j,
            model: PreemptionModel::Bernoulli { q: p.q },
            unit_price: PREEMPTIBLE_PRICE,
        };
        let r = run_synthetic(
            &mut s,
            bound,
            &prices,
            runtime,
            f64::INFINITY,
            p.seed + 10 + k as u64,
        )?;
        let label = if *n == n_star {
            format!("preempt_q{}_n{}_star", p.q, n)
        } else {
            format!("preempt_q{}_n{}", p.q, n)
        };
        panel_a.push(outcome(label, *n as f64, &r));
    }

    // ---- panel (b): static n = 1 vs dynamic eta
    let wp = WorkerProblem {
        bound,
        d: 1.0,
        chi: 1.0,
        eps: 0.1,
        theta_iters: p.j * 4,
    };
    let j_dynamic = wp.dynamic_iterations(p.eta, p.j);
    let mut panel_b = Vec::new();
    {
        let mut s = StaticWorkers {
            n: 1,
            j: p.j,
            model: PreemptionModel::Bernoulli { q: p.q },
            unit_price: PREEMPTIBLE_PRICE,
        };
        let r = run_synthetic(
            &mut s,
            bound,
            &prices,
            runtime,
            f64::INFINITY,
            p.seed + 50,
        )?;
        panel_b.push(outcome("static_n1".to_string(), 1.0, &r));
    }
    {
        let mut s = DynamicWorkers::new(
            1,
            p.eta,
            j_dynamic,
            PreemptionModel::Bernoulli { q: p.q },
            PREEMPTIBLE_PRICE,
            100_000,
        );
        let r = run_synthetic(
            &mut s,
            bound,
            &prices,
            runtime,
            f64::INFINITY,
            p.seed + 51,
        )?;
        panel_b.push(outcome(
            format!("dynamic_eta{}", p.eta),
            p.eta,
            &r,
        ));
    }

    Ok(Fig5Output { panel_a, n_star, panel_b, j_dynamic })
}

fn outcome(
    label: String,
    n_or_eta: f64,
    r: &crate::coordinator::scheduler::RunResult,
) -> ProvisioningOutcome {
    ProvisioningOutcome {
        label,
        n_or_eta,
        iters: r.iters,
        cost: r.cost,
        final_error: r.final_error,
        final_accuracy: r.final_accuracy,
        accuracy_per_dollar: if r.cost > 0.0 {
            r.final_accuracy / r.cost
        } else {
            0.0
        },
    }
}

pub fn print_summary(out: &Fig5Output) {
    println!("== Fig. 5a  (q sweep; Theorem-4 pick n* = {})", out.n_star);
    for o in &out.panel_a {
        println!(
            "  {:<24} n={:<5} cost={:<9.1} err={:<8.4} acc={:<7.4} \
             acc/$ = {:.6}",
            o.label,
            o.n_or_eta,
            o.cost,
            o.final_error,
            o.final_accuracy,
            o.accuracy_per_dollar
        );
    }
    println!("== Fig. 5b  (static vs dynamic; J' = {})", out.j_dynamic);
    for o in &out.panel_b {
        println!(
            "  {:<24} iters={:<6} cost={:<9.1} err={:<8.4} acc={:<7.4} \
             acc/$ = {:.6}",
            o.label,
            o.iters,
            o.cost,
            o.final_error,
            o.final_accuracy,
            o.accuracy_per_dollar
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theorem4_pick_beats_under_and_over_provisioning() {
        let p = Fig5Params { j: 6_000, ..Default::default() };
        let out = run(&p).unwrap();
        assert_eq!(out.n_star, 4);
        let get = |needle: &str| {
            out.panel_a
                .iter()
                .find(|o| o.label.contains(needle))
                .unwrap()
        };
        let star = get("n4_star");
        let big = get("n16");
        // the Theorem-4 pick has better accuracy-per-dollar than heavy
        // over-provisioning
        assert!(
            star.accuracy_per_dollar > big.accuracy_per_dollar,
            "star {} vs n16 {}",
            star.accuracy_per_dollar,
            big.accuracy_per_dollar
        );
        // and reaches (nearly) the no-preemption baseline's error
        let base = get("no_preemption");
        assert!(star.final_error < base.final_error * 1.15);
    }

    #[test]
    fn dynamic_beats_static_accuracy_per_dollar() {
        let p = Fig5Params { j: 10_000, ..Default::default() };
        let out = run(&p).unwrap();
        let stat = &out.panel_b[0];
        let dynm = &out.panel_b[1];
        assert!(out.j_dynamic < p.j);
        assert!(
            dynm.accuracy_per_dollar > stat.accuracy_per_dollar,
            "dynamic {} vs static {}",
            dynm.accuracy_per_dollar,
            stat.accuracy_per_dollar
        );
    }
}
