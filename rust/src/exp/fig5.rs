//! Fig. 5: preemptible instances without bids (Sec. V).
//!
//! (a) accuracy-per-dollar across choices of the provisioned count n at
//!     preemption probability q = 0.5, with the Theorem-4 estimate
//!     n* ~ n_no-preempt / (1 - q) highlighted against "random" choices,
//!     plus the paper's No-preemption baseline (2 on-demand workers at
//!     the higher on-demand price);
//! (b) static n = 1 for J = 10^4 iterations vs the Theorem-5 dynamic
//!     schedule n_j = ceil(1.0004^{j-1}) run for the (much smaller) J'
//!     from Theorem 5 with chi = 1.
//!
//! Price model: a fixed preemptible unit price and a 3x on-demand price
//! (the GCP preemptible discount is ~70%).
//!
//! All provisioning runs (baseline + n sweep + both panel-b schedules)
//! execute as parallel pool jobs with per-job RNG streams. [`Fig5Sweep`]
//! exposes the (n × q) grid as a replicated Monte-Carlo scenario whose
//! per-point context caches the exact preemption statistics (E[1/y],
//! P[y=0], Jensen penalty) once per grid point.

use anyhow::Result;

use crate::coordinator::strategy::{
    DynamicWorkers, StaticWorkers, Strategy,
};
use crate::preempt::{jensen_penalty, PreemptionModel, RecipTable};
use crate::sim::PriceSource;
use crate::sweep::{run_indexed, Grid, Scenario};
use crate::theory::bounds::{ErrorBound, SgdHyper};
use crate::theory::runtime_model::RuntimeModel;
use crate::theory::workers::WorkerProblem;
use crate::util::rng::Rng;

use super::run_synthetic_rng;

pub const PREEMPTIBLE_PRICE: f64 = 0.1;
pub const ON_DEMAND_PRICE: f64 = 0.3;

#[derive(Clone, Debug)]
pub struct ProvisioningOutcome {
    pub label: String,
    pub n_or_eta: f64,
    pub iters: u64,
    pub cost: f64,
    pub final_error: f64,
    pub final_accuracy: f64,
    pub accuracy_per_dollar: f64,
}

#[derive(Clone, Debug)]
pub struct Fig5Output {
    /// panel (a): no-preemption baseline + n sweep at q = 0.5
    pub panel_a: Vec<ProvisioningOutcome>,
    /// the n Theorem 4's reasoning selects for panel (a)
    pub n_star: usize,
    /// panel (b): static n = 1 vs dynamic eta = 1.0004
    pub panel_b: Vec<ProvisioningOutcome>,
    /// Theorem-5 iteration count used by the dynamic run
    pub j_dynamic: u64,
}

#[derive(Clone, Debug)]
pub struct Fig5Params {
    pub j: u64,
    pub q: f64,
    pub n_baseline: usize,
    pub n_sweep: Vec<usize>,
    pub eta: f64,
    pub seed: u64,
    /// sweep-pool workers for the provisioning runs
    pub threads: usize,
}

impl Default for Fig5Params {
    fn default() -> Self {
        Fig5Params {
            j: 10_000,
            q: 0.5,
            n_baseline: 2,
            n_sweep: vec![2, 4, 8, 16],
            eta: 1.0004,
            seed: 2020,
            threads: 1,
        }
    }
}

/// One provisioning run, fully specified (the pool job payload).
#[derive(Clone, Debug)]
enum ProvisionJob {
    Static {
        label: String,
        n_or_eta: f64,
        n: usize,
        j: u64,
        model: PreemptionModel,
        unit_price: f64,
    },
    Dynamic {
        label: String,
        eta: f64,
        j: u64,
        model: PreemptionModel,
        unit_price: f64,
    },
}

impl ProvisionJob {
    fn build(&self) -> Box<dyn Strategy> {
        match self {
            ProvisionJob::Static { n, j, model, unit_price, .. } => {
                Box::new(StaticWorkers {
                    n: *n,
                    j: *j,
                    model: model.clone(),
                    unit_price: *unit_price,
                })
            }
            ProvisionJob::Dynamic { eta, j, model, unit_price, .. } => {
                Box::new(DynamicWorkers::new(
                    1,
                    *eta,
                    *j,
                    model.clone(),
                    *unit_price,
                    100_000,
                ))
            }
        }
    }

    fn label(&self) -> &str {
        match self {
            ProvisionJob::Static { label, .. } => label,
            ProvisionJob::Dynamic { label, .. } => label,
        }
    }

    fn n_or_eta(&self) -> f64 {
        match self {
            ProvisionJob::Static { n_or_eta, .. } => *n_or_eta,
            ProvisionJob::Dynamic { eta, .. } => *eta,
        }
    }
}

pub fn run(p: &Fig5Params) -> Result<Fig5Output> {
    let bound = ErrorBound::new(SgdHyper::paper_cnn());
    let runtime = RuntimeModel::Deterministic { r: 10.0 };
    let prices = PriceSource::Fixed(0.0); // strategies carry their price

    // ---- Theorem 4's scaling: to match the no-preemption baseline's
    // effective worker count under preemption q, provision
    // n* = n_baseline / (1 - q) (the paper's Fig. 5a argument).
    let n_star =
        ((p.n_baseline as f64) / (1.0 - p.q)).round().max(1.0) as usize;

    // ---- panel (b) plan: Theorem-5 dynamic iteration count
    let wp = WorkerProblem {
        bound,
        d: 1.0,
        chi: 1.0,
        eps: 0.1,
        theta_iters: p.j * 4,
    };
    let j_dynamic = wp.dynamic_iterations(p.eta, p.j);

    // ---- assemble the full job list (panel a then panel b), keeping
    // the seed repo's per-run seed offsets (still a pure function of
    // the job, so any thread count reproduces them exactly)
    let mut jobs: Vec<ProvisionJob> = Vec::new();
    let mut seeds: Vec<u64> = Vec::new();
    jobs.push(ProvisionJob::Static {
        label: format!("no_preemption_n{}", p.n_baseline),
        n_or_eta: p.n_baseline as f64,
        n: p.n_baseline,
        j: p.j,
        model: PreemptionModel::None,
        unit_price: ON_DEMAND_PRICE,
    });
    seeds.push(p.seed);
    let mut sweep = p.n_sweep.clone();
    if !sweep.contains(&n_star) {
        sweep.push(n_star);
        sweep.sort_unstable();
    }
    for (k, n) in sweep.iter().enumerate() {
        let label = if *n == n_star {
            format!("preempt_q{}_n{}_star", p.q, n)
        } else {
            format!("preempt_q{}_n{}", p.q, n)
        };
        jobs.push(ProvisionJob::Static {
            label,
            n_or_eta: *n as f64,
            n: *n,
            j: p.j,
            model: PreemptionModel::Bernoulli { q: p.q },
            unit_price: PREEMPTIBLE_PRICE,
        });
        seeds.push(p.seed + 10 + k as u64);
    }
    let panel_a_len = jobs.len();
    jobs.push(ProvisionJob::Static {
        label: "static_n1".to_string(),
        n_or_eta: 1.0,
        n: 1,
        j: p.j,
        model: PreemptionModel::Bernoulli { q: p.q },
        unit_price: PREEMPTIBLE_PRICE,
    });
    seeds.push(p.seed + 50);
    jobs.push(ProvisionJob::Dynamic {
        label: format!("dynamic_eta{}", p.eta),
        eta: p.eta,
        j: j_dynamic,
        model: PreemptionModel::Bernoulli { q: p.q },
        unit_price: PREEMPTIBLE_PRICE,
    });
    seeds.push(p.seed + 51);

    // ---- run everything on the pool, one private RNG per job
    debug_assert_eq!(jobs.len(), seeds.len());
    let mut outcomes: Vec<ProvisioningOutcome> =
        run_indexed(p.threads, jobs.len(), |i| -> Result<ProvisioningOutcome> {
            let job = &jobs[i];
            let mut s = job.build();
            let mut rng = Rng::new(seeds[i]);
            let r = run_synthetic_rng(
                s.as_mut(),
                bound,
                &prices,
                runtime,
                f64::INFINITY,
                &mut rng,
            )?;
            Ok(outcome(job.label().to_string(), job.n_or_eta(), &r))
        })
        .into_iter()
        .collect::<Result<_>>()?;

    let panel_b = outcomes.split_off(panel_a_len);
    Ok(Fig5Output { panel_a: outcomes, n_star, panel_b, j_dynamic })
}

fn outcome(
    label: String,
    n_or_eta: f64,
    r: &crate::coordinator::scheduler::RunResult,
) -> ProvisioningOutcome {
    ProvisioningOutcome {
        label,
        n_or_eta,
        iters: r.iters,
        cost: r.cost,
        final_error: r.final_error,
        final_accuracy: r.final_accuracy,
        accuracy_per_dollar: if r.cost > 0.0 {
            r.final_accuracy / r.cost
        } else {
            0.0
        },
    }
}

pub fn print_summary(out: &Fig5Output) {
    println!("== Fig. 5a  (q sweep; Theorem-4 pick n* = {})", out.n_star);
    for o in &out.panel_a {
        println!(
            "  {:<24} n={:<5} cost={:<9.1} err={:<8.4} acc={:<7.4} \
             acc/$ = {:.6}",
            o.label,
            o.n_or_eta,
            o.cost,
            o.final_error,
            o.final_accuracy,
            o.accuracy_per_dollar
        );
    }
    println!("== Fig. 5b  (static vs dynamic; J' = {})", out.j_dynamic);
    for o in &out.panel_b {
        println!(
            "  {:<24} iters={:<6} cost={:<9.1} err={:<8.4} acc={:<7.4} \
             acc/$ = {:.6}",
            o.label,
            o.iters,
            o.cost,
            o.final_error,
            o.final_accuracy,
            o.accuracy_per_dollar
        );
    }
}

// ------------------------------------------------------------ sweep view

/// Fig. 5 as a Monte-Carlo sweep over the (n, q) provisioning grid. The
/// per-point context caches the exact preemption statistics — E[1/y],
/// P[y=0], the Jensen penalty, and the Theorem-4 provisioning match
/// `n_match_exact` (smallest fleet whose conditional E[1/y] is at least
/// as good as the no-preemption baseline's 1/n_baseline, found by
/// scanning a [`RecipTable`]) — once per point; replicates only pay for
/// the simulation itself.
pub struct Fig5Sweep {
    pub params: Fig5Params,
    pub grid: Grid,
}

impl Fig5Sweep {
    /// Default grid: n in {2,4,8,16} x q in {0.3,0.5,0.7}.
    pub fn paper(params: Fig5Params) -> Self {
        let grid = Grid::new()
            .axis("n", vec![2.0, 4.0, 8.0, 16.0])
            .axis("q", vec![0.3, 0.5, 0.7]);
        Fig5Sweep { params, grid }
    }
}

/// Cached per-point state: the preemption model and its exact statistics.
pub struct Fig5Ctx {
    n: usize,
    model: PreemptionModel,
    /// exact E[1/y | y > 0] at this point's fleet size
    recip: f64,
    p_zero: f64,
    jensen: f64,
    /// exact Theorem-4 match: smallest m with E[1/y(m)] <= 1/n_baseline
    /// (NaN when no fleet within the scanned range qualifies)
    n_match: f64,
}

impl Scenario for Fig5Sweep {
    type Ctx = Fig5Ctx;

    fn points(&self) -> usize {
        self.grid.num_points()
    }

    fn label(&self, point: usize) -> String {
        self.grid.label(point)
    }

    fn metrics(&self) -> Vec<&'static str> {
        vec![
            "cost",
            "final_error",
            "final_accuracy",
            "acc_per_dollar",
            "recip_exact",
            "p_zero",
            "jensen_penalty",
            "n_match_exact",
        ]
    }

    fn prepare(&self, point: usize) -> Result<Fig5Ctx> {
        let vals = self.grid.point(point);
        let (n, q) = (vals[0] as usize, vals[1]);
        let model = PreemptionModel::Bernoulli { q };
        // exact per-point statistics, computed once per sweep point and
        // shared by every replicate. The RecipTable memoises E[1/y] for
        // the whole fleet-size scan below (Fig. 5a's Theorem-4 argument
        // done exactly, not via the n_b/(1-q) heuristic).
        let n_base = self.params.n_baseline.max(1);
        let table = RecipTable::build(&model, n.max(8 * n_base));
        let n_match = (1..=table.n_max())
            .find(|&m| table.recip(m) <= 1.0 / n_base as f64)
            .map(|m| m as f64)
            .unwrap_or(f64::NAN);
        // the table always covers n (built to n.max(8 * n_base) above)
        Ok(Fig5Ctx {
            n,
            recip: table.recip(n),
            p_zero: model.p_zero(n),
            jensen: jensen_penalty(&model, n),
            n_match,
            model,
        })
    }

    fn run(
        &self,
        _point: usize,
        ctx: &Fig5Ctx,
        rng: &mut Rng,
    ) -> Result<Vec<f64>> {
        let bound = ErrorBound::new(SgdHyper::paper_cnn());
        let runtime = RuntimeModel::Deterministic { r: 10.0 };
        let prices = PriceSource::Fixed(0.0);
        let mut s = StaticWorkers {
            n: ctx.n,
            j: self.params.j,
            model: ctx.model.clone(),
            unit_price: PREEMPTIBLE_PRICE,
        };
        let r = run_synthetic_rng(
            &mut s,
            bound,
            &prices,
            runtime,
            f64::INFINITY,
            rng,
        )?;
        Ok(vec![
            r.cost,
            r.final_error,
            r.final_accuracy,
            if r.cost > 0.0 { r.final_accuracy / r.cost } else { 0.0 },
            ctx.recip,
            ctx.p_zero,
            ctx.jensen,
            ctx.n_match,
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theorem4_pick_beats_under_and_over_provisioning() {
        let p = Fig5Params { j: 6_000, ..Default::default() };
        let out = run(&p).unwrap();
        assert_eq!(out.n_star, 4);
        let get = |needle: &str| {
            out.panel_a
                .iter()
                .find(|o| o.label.contains(needle))
                .unwrap()
        };
        let star = get("n4_star");
        let big = get("n16");
        // the Theorem-4 pick has better accuracy-per-dollar than heavy
        // over-provisioning
        assert!(
            star.accuracy_per_dollar > big.accuracy_per_dollar,
            "star {} vs n16 {}",
            star.accuracy_per_dollar,
            big.accuracy_per_dollar
        );
        // and reaches (nearly) the no-preemption baseline's error
        let base = get("no_preemption");
        assert!(star.final_error < base.final_error * 1.15);
    }

    #[test]
    fn dynamic_beats_static_accuracy_per_dollar() {
        let p = Fig5Params { j: 10_000, ..Default::default() };
        let out = run(&p).unwrap();
        let stat = &out.panel_b[0];
        let dynm = &out.panel_b[1];
        assert!(out.j_dynamic < p.j);
        assert!(
            dynm.accuracy_per_dollar > stat.accuracy_per_dollar,
            "dynamic {} vs static {}",
            dynm.accuracy_per_dollar,
            stat.accuracy_per_dollar
        );
    }

    #[test]
    fn panels_identical_across_thread_counts() {
        let serial = Fig5Params { j: 2_000, ..Default::default() };
        let threaded = Fig5Params { threads: 8, ..serial.clone() };
        let a = run(&serial).unwrap();
        let b = run(&threaded).unwrap();
        for (x, y) in a
            .panel_a
            .iter()
            .chain(&a.panel_b)
            .zip(b.panel_a.iter().chain(&b.panel_b))
        {
            assert_eq!(x.label, y.label);
            assert_eq!(x.cost.to_bits(), y.cost.to_bits());
            assert_eq!(x.final_error.to_bits(), y.final_error.to_bits());
        }
    }
}
