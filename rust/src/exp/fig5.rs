//! Fig. 5: preemptible instances without bids (Sec. V).
//!
//! (a) accuracy-per-dollar across choices of the provisioned count n at
//!     preemption probability q = 0.5, with the Theorem-4 estimate
//!     n* ~ n_no-preempt / (1 - q) highlighted against "random" choices,
//!     plus the paper's No-preemption baseline (2 on-demand workers at
//!     the higher on-demand price);
//! (b) static n = 1 for J = 10^4 iterations vs the Theorem-5 dynamic
//!     schedule n_j = ceil(1.0004^{j-1}) run for the (much smaller) J'
//!     from Theorem 5 with chi = 1.
//!
//! Price model: a fixed preemptible unit price and a 3x on-demand price
//! (the GCP preemptible discount is ~70%).
//!
//! All provisioning runs (baseline + n sweep + both panel-b schedules)
//! execute as parallel pool jobs with per-job RNG streams, instantiated
//! from shared [`PlannedStrategy`] values. The replicated (n × q)
//! Monte-Carlo view is the `fig5` preset spec
//! (`examples/configs/fig5.toml`), whose per-point context caches the
//! exact preemption statistics (E[1/y], P[y=0], Jensen penalty) once per
//! grid point.

use anyhow::Result;

use crate::preempt::PreemptionModel;
use crate::sim::PriceSource;
use crate::sweep::run_indexed;
use crate::theory::bounds::{ErrorBound, SgdHyper};
use crate::theory::runtime_model::RuntimeModel;
use crate::theory::workers::WorkerProblem;
use crate::util::rng::Rng;

use super::{run_synthetic_rng, PlannedStrategy};

pub const PREEMPTIBLE_PRICE: f64 = 0.1;
pub const ON_DEMAND_PRICE: f64 = 0.3;

#[derive(Clone, Debug)]
pub struct ProvisioningOutcome {
    pub label: String,
    pub n_or_eta: f64,
    pub iters: u64,
    pub cost: f64,
    pub final_error: f64,
    pub final_accuracy: f64,
    pub accuracy_per_dollar: f64,
}

#[derive(Clone, Debug)]
pub struct Fig5Output {
    /// panel (a): no-preemption baseline + n sweep at q = 0.5
    pub panel_a: Vec<ProvisioningOutcome>,
    /// the n Theorem 4's reasoning selects for panel (a)
    pub n_star: usize,
    /// panel (b): static n = 1 vs dynamic eta = 1.0004
    pub panel_b: Vec<ProvisioningOutcome>,
    /// Theorem-5 iteration count used by the dynamic run
    pub j_dynamic: u64,
}

#[derive(Clone, Debug)]
pub struct Fig5Params {
    pub j: u64,
    pub q: f64,
    pub n_baseline: usize,
    pub n_sweep: Vec<usize>,
    pub eta: f64,
    pub seed: u64,
    /// sweep-pool workers for the provisioning runs
    pub threads: usize,
}

impl Default for Fig5Params {
    fn default() -> Self {
        Fig5Params {
            j: 10_000,
            q: 0.5,
            n_baseline: 2,
            n_sweep: vec![2, 4, 8, 16],
            eta: 1.0004,
            seed: 2020,
            threads: 1,
        }
    }
}

/// One provisioning run: a planned strategy plus its panel metadata.
#[derive(Clone, Debug)]
struct ProvisionJob {
    n_or_eta: f64,
    plan: PlannedStrategy,
    seed: u64,
}

pub fn run(p: &Fig5Params) -> Result<Fig5Output> {
    let bound = ErrorBound::new(SgdHyper::paper_cnn());
    let runtime = RuntimeModel::Deterministic { r: 10.0 };
    let prices = PriceSource::Fixed(0.0); // strategies carry their price

    // ---- Theorem 4's scaling: to match the no-preemption baseline's
    // effective worker count under preemption q, provision
    // n* = n_baseline / (1 - q) (the paper's Fig. 5a argument).
    let n_star =
        ((p.n_baseline as f64) / (1.0 - p.q)).round().max(1.0) as usize;

    // ---- panel (b) plan: Theorem-5 dynamic iteration count
    let wp = WorkerProblem {
        bound,
        d: 1.0,
        chi: 1.0,
        eps: 0.1,
        theta_iters: p.j * 4,
    };
    let j_dynamic = wp.dynamic_iterations(p.eta, p.j);

    // ---- assemble the full job list (panel a then panel b), keeping
    // the seed repo's per-run seed offsets (still a pure function of
    // the job, so any thread count reproduces them exactly)
    let mut jobs: Vec<ProvisionJob> = Vec::new();
    jobs.push(ProvisionJob {
        n_or_eta: p.n_baseline as f64,
        plan: PlannedStrategy::StaticWorkers {
            name: format!("no_preemption_n{}", p.n_baseline),
            n: p.n_baseline,
            j: p.j,
            model: PreemptionModel::None,
            unit_price: ON_DEMAND_PRICE,
        },
        seed: p.seed,
    });
    let mut sweep = p.n_sweep.clone();
    if !sweep.contains(&n_star) {
        sweep.push(n_star);
        sweep.sort_unstable();
    }
    for (k, n) in sweep.iter().enumerate() {
        let label = if *n == n_star {
            format!("preempt_q{}_n{}_star", p.q, n)
        } else {
            format!("preempt_q{}_n{}", p.q, n)
        };
        jobs.push(ProvisionJob {
            n_or_eta: *n as f64,
            plan: PlannedStrategy::StaticWorkers {
                name: label,
                n: *n,
                j: p.j,
                model: PreemptionModel::Bernoulli { q: p.q },
                unit_price: PREEMPTIBLE_PRICE,
            },
            seed: p.seed + 10 + k as u64,
        });
    }
    let panel_a_len = jobs.len();
    jobs.push(ProvisionJob {
        n_or_eta: 1.0,
        plan: PlannedStrategy::StaticWorkers {
            name: "static_n1".to_string(),
            n: 1,
            j: p.j,
            model: PreemptionModel::Bernoulli { q: p.q },
            unit_price: PREEMPTIBLE_PRICE,
        },
        seed: p.seed + 50,
    });
    jobs.push(ProvisionJob {
        n_or_eta: p.eta,
        plan: PlannedStrategy::DynamicWorkers {
            name: format!("dynamic_eta{}", p.eta),
            n0: 1,
            eta: p.eta,
            j: j_dynamic,
            model: PreemptionModel::Bernoulli { q: p.q },
            unit_price: PREEMPTIBLE_PRICE,
            cap: 100_000,
        },
        seed: p.seed + 51,
    });

    // ---- run everything on the pool, one private RNG per job
    let mut outcomes: Vec<ProvisioningOutcome> =
        run_indexed(p.threads, jobs.len(), |i| -> Result<ProvisioningOutcome> {
            let job = &jobs[i];
            let mut s = job.plan.build()?;
            let mut rng = Rng::new(job.seed);
            let r = run_synthetic_rng(
                s.as_mut(),
                bound,
                &prices,
                runtime,
                f64::INFINITY,
                &mut rng,
            )?;
            Ok(outcome(
                job.plan.name().to_string(),
                job.n_or_eta,
                &r,
            ))
        })
        .into_iter()
        .collect::<Result<_>>()?;

    let panel_b = outcomes.split_off(panel_a_len);
    Ok(Fig5Output { panel_a: outcomes, n_star, panel_b, j_dynamic })
}

fn outcome(
    label: String,
    n_or_eta: f64,
    r: &crate::coordinator::scheduler::RunResult,
) -> ProvisioningOutcome {
    ProvisioningOutcome {
        label,
        n_or_eta,
        iters: r.iters,
        cost: r.cost,
        final_error: r.final_error,
        final_accuracy: r.final_accuracy,
        accuracy_per_dollar: if r.cost > 0.0 {
            r.final_accuracy / r.cost
        } else {
            0.0
        },
    }
}

pub fn print_summary(out: &Fig5Output) {
    println!("== Fig. 5a  (q sweep; Theorem-4 pick n* = {})", out.n_star);
    for o in &out.panel_a {
        println!(
            "  {:<24} n={:<5} cost={:<9.1} err={:<8.4} acc={:<7.4} \
             acc/$ = {:.6}",
            o.label,
            o.n_or_eta,
            o.cost,
            o.final_error,
            o.final_accuracy,
            o.accuracy_per_dollar
        );
    }
    println!("== Fig. 5b  (static vs dynamic; J' = {})", out.j_dynamic);
    for o in &out.panel_b {
        println!(
            "  {:<24} iters={:<6} cost={:<9.1} err={:<8.4} acc={:<7.4} \
             acc/$ = {:.6}",
            o.label,
            o.iters,
            o.cost,
            o.final_error,
            o.final_accuracy,
            o.accuracy_per_dollar
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theorem4_pick_beats_under_and_over_provisioning() {
        let p = Fig5Params { j: 6_000, ..Default::default() };
        let out = run(&p).unwrap();
        assert_eq!(out.n_star, 4);
        let get = |needle: &str| {
            out.panel_a
                .iter()
                .find(|o| o.label.contains(needle))
                .unwrap()
        };
        let star = get("n4_star");
        let big = get("n16");
        // the Theorem-4 pick has better accuracy-per-dollar than heavy
        // over-provisioning
        assert!(
            star.accuracy_per_dollar > big.accuracy_per_dollar,
            "star {} vs n16 {}",
            star.accuracy_per_dollar,
            big.accuracy_per_dollar
        );
        // and reaches (nearly) the no-preemption baseline's error
        let base = get("no_preemption");
        assert!(star.final_error < base.final_error * 1.15);
    }

    #[test]
    fn dynamic_beats_static_accuracy_per_dollar() {
        let p = Fig5Params { j: 10_000, ..Default::default() };
        let out = run(&p).unwrap();
        let stat = &out.panel_b[0];
        let dynm = &out.panel_b[1];
        assert!(out.j_dynamic < p.j);
        assert!(
            dynm.accuracy_per_dollar > stat.accuracy_per_dollar,
            "dynamic {} vs static {}",
            dynm.accuracy_per_dollar,
            stat.accuracy_per_dollar
        );
    }

    #[test]
    fn panels_identical_across_thread_counts() {
        let serial = Fig5Params { j: 2_000, ..Default::default() };
        let threaded = Fig5Params { threads: 8, ..serial.clone() };
        let a = run(&serial).unwrap();
        let b = run(&threaded).unwrap();
        for (x, y) in a
            .panel_a
            .iter()
            .chain(&a.panel_b)
            .zip(b.panel_a.iter().chain(&b.panel_b))
        {
            assert_eq!(x.label, y.label);
            assert_eq!(x.cost.to_bits(), y.cost.to_bits());
            assert_eq!(x.final_error.to_bits(), y.final_error.to_bits());
        }
    }
}
