//! Work-stealing thread pool over `std::thread` + channels.
//!
//! [`run_indexed`] is the one primitive everything else builds on: run
//! `f(0..n)` across `threads` workers and return the outputs **in index
//! order**. Jobs are dealt round-robin into per-worker deques; a worker
//! pops its own queue from the front and, when empty, steals from the
//! back of another worker's queue, so an unlucky worker stuck on a slow
//! job cannot strand the jobs queued behind it.
//!
//! Determinism contract: if `f` is a pure function of its index (the
//! sweep harness guarantees this by deriving each job's RNG with
//! [`crate::util::rng::Rng::stream`]), the returned vector is identical
//! at any thread count — scheduling only changes *when* a job runs,
//! never *what* it computes, and collation is by index, not completion
//! order.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Mutex;
use std::thread;

/// How the pool scheduled one [`run_indexed_stats`] call: telemetry
/// only (trace span lines, DESIGN.md §12) — scheduling shape never
/// affects results, so none of this feeds a digest.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// workers actually spawned (1 = inline, no pool)
    pub workers: usize,
    /// jobs a worker popped from its own deque
    pub own: u64,
    /// jobs taken from another worker's deque
    pub stolen: u64,
}

/// Run `f` over `0..n` on up to `threads` workers; `out[i] == f(i)`.
///
/// `threads <= 1` (or `n <= 1`) runs inline on the caller's thread with
/// no pool at all, which keeps single-threaded runs trivially
/// deterministic and overhead-free.
pub fn run_indexed<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    run_indexed_stats(threads, n, f).0
}

/// [`run_indexed`] plus the scheduling tally. Same outputs, same
/// determinism contract — [`PoolStats`] only reports where each job
/// happened to run.
pub fn run_indexed_stats<T, F>(
    threads: usize,
    n: usize,
    f: F,
) -> (Vec<T>, PoolStats)
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return (Vec::new(), PoolStats::default());
    }
    let workers = threads.max(1).min(n);
    if workers == 1 {
        let out = (0..n).map(f).collect();
        let stats = PoolStats { workers: 1, own: n as u64, stolen: 0 };
        return (out, stats);
    }

    // deal jobs round-robin so every worker starts with local work
    let queues: Vec<Mutex<VecDeque<usize>>> = (0..workers)
        .map(|w| Mutex::new((w..n).step_by(workers).collect()))
        .collect();
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let (own, stolen) = (AtomicU64::new(0), AtomicU64::new(0));

    thread::scope(|scope| {
        for w in 0..workers {
            let tx = tx.clone();
            let queues = &queues;
            let f = &f;
            let (own, stolen) = (&own, &stolen);
            scope.spawn(move || {
                while let Some((i, was_steal)) = next_job(queues, w) {
                    if was_steal {
                        stolen.fetch_add(1, Ordering::Relaxed);
                    } else {
                        own.fetch_add(1, Ordering::Relaxed);
                    }
                    // receiver gone means the collector bailed; just stop
                    if tx.send((i, f(i))).is_err() {
                        return;
                    }
                }
            });
        }
        drop(tx); // collector's rx ends when the last worker clone drops
        for (i, out) in rx {
            slots[i] = Some(out);
        }
    });

    let out = slots
        .into_iter()
        .enumerate()
        .map(|(i, s)| s.unwrap_or_else(|| panic!("job {i} was never delivered")))
        .collect();
    let stats = PoolStats {
        workers,
        own: own.into_inner(),
        stolen: stolen.into_inner(),
    };
    (out, stats)
}

/// Pop own queue front, else steal the back of the fullest other queue
/// (the bool in the return marks a steal). Returns `None` only once a
/// full scan observes every queue empty — a lost steal race (the victim
/// drained between the scan and the lock) rescans instead of retiring
/// the worker, so no worker exits while another queue still holds jobs.
/// Terminates because jobs are only ever removed: each rescan sees a
/// strictly shrinking backlog.
fn next_job(
    queues: &[Mutex<VecDeque<usize>>],
    me: usize,
) -> Option<(usize, bool)> {
    if let Some(i) = queues[me].lock().unwrap().pop_front() {
        return Some((i, false));
    }
    loop {
        // victim selection: fullest queue first, so steals spread the
        // tail of a slow worker's backlog rather than ping-ponging
        // single jobs
        let mut best: Option<(usize, usize)> = None; // (len, victim)
        for (v, q) in queues.iter().enumerate() {
            if v == me {
                continue;
            }
            let len = q.lock().unwrap().len();
            if len > 0 && best.map(|(l, _)| len > l).unwrap_or(true) {
                best = Some((len, v));
            }
        }
        let (_, victim) = best?;
        if let Some(i) = queues[victim].lock().unwrap().pop_back() {
            return Some((i, true));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn outputs_are_in_index_order() {
        for threads in [1usize, 2, 4, 8] {
            let out = run_indexed(threads, 100, |i| i * i);
            let want: Vec<usize> = (0..100).map(|i| i * i).collect();
            assert_eq!(out, want, "threads={threads}");
        }
    }

    #[test]
    fn every_job_runs_exactly_once() {
        let counts: Vec<AtomicUsize> =
            (0..257).map(|_| AtomicUsize::new(0)).collect();
        run_indexed(8, 257, |i| {
            counts[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::SeqCst), 1, "job {i}");
        }
    }

    #[test]
    fn uneven_job_durations_still_collate_correctly() {
        // early indices sleep, forcing later ones to be stolen
        let out = run_indexed(4, 32, |i| {
            if i < 4 {
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
            i + 1
        });
        assert_eq!(out, (1..=32).collect::<Vec<_>>());
    }

    #[test]
    fn zero_and_one_jobs() {
        assert_eq!(run_indexed(4, 0, |i| i), Vec::<usize>::new());
        assert_eq!(run_indexed(4, 1, |i| i + 10), vec![10]);
    }

    #[test]
    fn more_threads_than_jobs() {
        assert_eq!(run_indexed(64, 3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn stats_account_for_every_job() {
        // inline path: everything is "own", one worker
        let (out, s) = run_indexed_stats(1, 5, |i| i);
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
        assert_eq!(s, PoolStats { workers: 1, own: 5, stolen: 0 });
        // pooled path: own + stolen covers every job exactly once
        let (out, s) = run_indexed_stats(4, 64, |i| {
            if i % 4 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            i
        });
        assert_eq!(out, (0..64).collect::<Vec<_>>());
        assert_eq!(s.workers, 4);
        assert_eq!(s.own + s.stolen, 64);
    }

    #[test]
    fn results_identical_across_thread_counts_with_stream_rng() {
        use crate::util::rng::Rng;
        let job = |i: usize| {
            let mut rng = Rng::stream(7, i as u64);
            (0..100).map(|_| rng.f64()).sum::<f64>()
        };
        let serial = run_indexed(1, 40, job);
        for threads in [2usize, 4, 8] {
            assert_eq!(serial, run_indexed(threads, 40, job));
        }
    }
}
