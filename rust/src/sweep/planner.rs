//! Job planning: expand a (grid points × replicates) sweep into a flat,
//! stably-numbered job list.
//!
//! Each job owns a *stream id* — `point * replicates + replicate` — that
//! seeds its private RNG via [`crate::util::rng::Rng::stream`]. The id is
//! a pure function of the job's identity, so the randomness a job sees is
//! independent of execution order, worker assignment, and thread count.

/// One unit of sweep work: a (grid point, replicate) pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Job {
    /// index into the scenario's grid points
    pub point: usize,
    /// replicate number within the point, `0..replicates`
    pub replicate: u64,
    /// RNG stream id: `point * replicates + replicate` (unique per job)
    pub stream: u64,
}

/// The flat job list for one sweep.
#[derive(Clone, Debug)]
pub struct JobPlan {
    pub points: usize,
    pub replicates: u64,
    pub jobs: Vec<Job>,
}

impl JobPlan {
    /// Point-major order: a point's replicates are adjacent, so the
    /// round-robin deal in the pool keeps each worker cycling through a
    /// small set of cached contexts.
    pub fn new(points: usize, replicates: u64) -> Self {
        let mut jobs = Vec::with_capacity(points * replicates as usize);
        for point in 0..points {
            for replicate in 0..replicates {
                jobs.push(Job {
                    point,
                    replicate,
                    stream: point as u64 * replicates + replicate,
                });
            }
        }
        JobPlan { points, replicates, jobs }
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_covers_the_product() {
        let plan = JobPlan::new(3, 4);
        assert_eq!(plan.len(), 12);
        // stream ids are exactly 0..12, each exactly once
        let mut streams: Vec<u64> = plan.jobs.iter().map(|j| j.stream).collect();
        streams.sort_unstable();
        assert_eq!(streams, (0..12).collect::<Vec<_>>());
        // point-major ordering
        assert_eq!(plan.jobs[0], Job { point: 0, replicate: 0, stream: 0 });
        assert_eq!(plan.jobs[4], Job { point: 1, replicate: 0, stream: 4 });
        assert_eq!(plan.jobs[11], Job { point: 2, replicate: 3, stream: 11 });
    }

    #[test]
    fn empty_plans() {
        assert!(JobPlan::new(0, 5).is_empty());
        assert!(JobPlan::new(5, 0).is_empty());
    }
}
