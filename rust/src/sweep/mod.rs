//! Parallel deterministic sweep harness.
//!
//! The paper's evaluation (Figs. 2–5) and every ROADMAP scaling scenario
//! reduce to the same shape: a grid of configurations, R Monte-Carlo
//! replicates per grid point, and per-point summary statistics. This
//! module makes that inner loop embarrassingly parallel *without changing
//! a single output bit*:
//!
//! * [`planner`] expands (points × replicates) into stably-numbered jobs,
//!   each owning an RNG derived as `Rng::stream(seed, job.stream)` — a
//!   pure function of job identity, never of execution order;
//! * [`pool`] runs jobs on a work-stealing `std::thread` pool and returns
//!   outputs in index order;
//! * [`Scenario::prepare`] builds each grid point's *context* (price-CDF
//!   estimates, generated traces, E[1/y] tables — anything pure in the
//!   point) exactly once per sweep instead of once per replicate;
//! * collation folds job outputs into per-point Welford accumulators in
//!   job order, so means/variances are bit-identical at any thread count
//!   ([`SweepResults::digest`] pins this in tests).
//!
//! Seeding guarantee: `(seed, grid, replicates)` fully determine the
//! results; `--threads` is a pure throughput knob. See DESIGN.md §3.
//!
//! # Example
//!
//! The two building blocks scenarios see — grids and pure replicate
//! streams:
//!
//! ```
//! use volatile_sgd::sweep::Grid;
//! use volatile_sgd::util::rng::Rng;
//!
//! let grid = Grid::new()
//!     .axis("n", vec![2.0, 4.0])
//!     .axis("q", vec![0.1, 0.5]);
//! assert_eq!(grid.num_points(), 4);
//! assert_eq!(grid.point(3), vec![4.0, 0.5]); // first axis slowest
//! assert_eq!(grid.label(3), "n=4 q=0.5");
//!
//! // a replicate's generator is a pure function of (seed, stream id):
//! // no parent state, no ordering dependence — thread-safe by value
//! let a = Rng::stream(2020, 3).next_u64();
//! assert_eq!(a, Rng::stream(2020, 3).next_u64());
//! assert_ne!(a, Rng::stream(2020, 4).next_u64());
//! ```

pub mod grid;
pub mod planner;
pub mod pool;

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::metrics::Throughput;
use crate::obs::{span_line, HistShard, Registry, TraceObs, TraceSink};
use crate::util::fnv::Fnv;
use crate::util::rng::Rng;
use crate::util::stats::OnlineStats;

pub use grid::Grid;
pub use planner::{Job, JobPlan};
pub use pool::{run_indexed, run_indexed_stats, PoolStats};

/// Optional telemetry for a sweep (DESIGN.md §12): a JSONL trace sink
/// for engine events + timing spans, and/or a metric registry for
/// per-stage latency histograms. `Telemetry::default()` is fully off —
/// and by the digest-neutrality contract (pinned per shipped preset in
/// `tests/integration_obs.rs`) switching either on never changes a
/// result bit: telemetry consumes no RNG, and wall-clock flows only
/// *out* of the sweep, never into a digest.
#[derive(Clone, Copy, Default)]
pub struct Telemetry<'a> {
    pub trace: Option<&'a TraceSink>,
    pub registry: Option<&'a Registry>,
}

impl<'a> Telemetry<'a> {
    pub fn off() -> Self {
        Telemetry::default()
    }

    fn enabled(&self) -> bool {
        self.trace.is_some() || self.registry.is_some()
    }

    /// Record one wall-clock span to both backends (histogram named
    /// `sweep_<name>_us`, span line named `name`).
    fn span(
        &self,
        name: &str,
        point: Option<usize>,
        wall_us: u64,
        extra: &[(&str, u64)],
    ) {
        if let Some(reg) = self.registry {
            reg.histogram(&format!("sweep_{name}_us")).record(wall_us);
        }
        if let Some(sink) = self.trace {
            sink.write_line(&span_line(name, point, wall_us, extra));
        }
    }
}

/// How a sweep runs: replicates per grid point, master seed, workers.
#[derive(Clone, Copy, Debug)]
pub struct SweepConfig {
    pub replicates: u64,
    pub seed: u64,
    pub threads: usize,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig { replicates: 8, seed: 2020, threads: 1 }
    }
}

/// A sweepable experiment: a grid of points, a cached per-point context,
/// and a replicate body that reports one f64 per metric.
///
/// Contract for determinism: `prepare` and `run` must be pure in their
/// arguments (all randomness through the provided `rng`); the harness
/// guarantees in return that results are identical at any thread count.
pub trait Scenario: Sync {
    /// Pure per-grid-point data computed once per sweep (CDF estimates,
    /// generated traces, bid plans, E[1/y] tables...). `Send + Sync`
    /// because replicate jobs on any worker borrow it concurrently.
    type Ctx: Send + Sync;

    /// Number of grid points.
    fn points(&self) -> usize;

    /// Human label for a point (used in tables and CSV).
    fn label(&self, point: usize) -> String;

    /// Names of the metrics each replicate reports, in order. Owned so
    /// config-driven scenarios can derive them (e.g. lineup-comparison
    /// metrics named after config-defined strategy labels).
    fn metrics(&self) -> Vec<String>;

    /// Build the cached context for one grid point.
    fn prepare(&self, point: usize) -> Result<Self::Ctx>;

    /// Run one replicate at a grid point. Non-finite metric values are
    /// collated as "missing" (e.g. a run that never reached the target
    /// accuracy) rather than poisoning the statistics.
    fn run(&self, point: usize, ctx: &Self::Ctx, rng: &mut Rng)
        -> Result<Vec<f64>>;

    /// Run a whole replicate block at a grid point — `rngs[r]` is
    /// replicate `r`'s stream — returning one metric vector per
    /// replicate, in stream order. The default is the scalar loop (one
    /// [`Scenario::run`] per stream), so every scenario is batchable;
    /// implementations may override with a genuinely batched executor
    /// (e.g. [`crate::sim::batch`]) provided the results stay
    /// bit-identical to the default — [`run_sweep_batched`] relies on
    /// that to keep digests equal to [`run_sweep`]'s.
    fn run_block(
        &self,
        point: usize,
        ctx: &Self::Ctx,
        rngs: &mut [Rng],
    ) -> Result<Vec<Vec<f64>>> {
        rngs.iter_mut()
            .map(|rng| self.run(point, ctx, rng))
            .collect()
    }

    /// [`Scenario::run`] with a trace observer attached. The default
    /// ignores the tracer (scenarios with no engine inside have no
    /// event stream to export); engine-backed scenarios override this
    /// to pass `tracer` into the run as an extra [`crate::sim::Observer`].
    /// Overrides must keep the run bit-identical to [`Scenario::run`] —
    /// the tracer is read-only and RNG-free by construction.
    fn run_traced(
        &self,
        point: usize,
        ctx: &Self::Ctx,
        rng: &mut Rng,
        tracer: &mut TraceObs,
    ) -> Result<Vec<f64>> {
        let _ = tracer;
        self.run(point, ctx, rng)
    }

    /// [`Scenario::run_block`] with one trace observer per replicate
    /// (`tracers[r]` observes stream `r`). Same contract as
    /// [`Scenario::run_traced`]: default ignores the tracers, overrides
    /// must stay bit-identical to the untraced block.
    fn run_block_traced(
        &self,
        point: usize,
        ctx: &Self::Ctx,
        rngs: &mut [Rng],
        tracers: &mut [TraceObs],
    ) -> Result<Vec<Vec<f64>>> {
        let _ = tracers;
        self.run_block(point, ctx, rngs)
    }
}

/// Collated statistics for one grid point.
#[derive(Clone, Debug)]
pub struct PointSummary {
    pub label: String,
    /// one Welford accumulator per metric, fed in job order
    pub stats: Vec<OnlineStats>,
    /// per metric: replicates whose value was non-finite
    pub missing: Vec<u64>,
}

/// The result of a sweep: per-point Welford statistics plus throughput.
#[derive(Clone, Debug)]
pub struct SweepResults {
    pub metric_names: Vec<String>,
    pub points: Vec<PointSummary>,
    pub throughput: Throughput,
}

/// Run a scenario under a config. Contexts are built in parallel (one
/// job per grid point), then replicate jobs run on the same pool;
/// collation is sequential in job order.
pub fn run_sweep<S: Scenario>(
    scenario: &S,
    cfg: &SweepConfig,
) -> Result<SweepResults> {
    run_sweep_with(scenario, cfg, Telemetry::off())
}

/// [`run_sweep`] with telemetry attached: per-point prepare and
/// per-replicate run latency histograms, prepare/run/collate/pool
/// timing spans, and (when a trace sink is given) the engine event
/// stream of every replicate. Bit-identical results to [`run_sweep`]
/// at any telemetry setting — the digest-neutrality contract.
pub fn run_sweep_with<S: Scenario>(
    scenario: &S,
    cfg: &SweepConfig,
    tel: Telemetry<'_>,
) -> Result<SweepResults> {
    let t0 = Instant::now();
    let npts = scenario.points();
    let metric_names = scenario.metrics();
    let nmetrics = metric_names.len();

    // phase 1: per-point contexts, once per sweep
    let ctxs: Vec<S::Ctx> =
        run_indexed(cfg.threads, npts, |p| {
            let tp = Instant::now();
            let ctx = scenario.prepare(p);
            if tel.enabled() {
                tel.span(
                    "prepare",
                    Some(p),
                    tp.elapsed().as_micros() as u64,
                    &[],
                );
            }
            ctx
        })
        .into_iter()
        .collect::<Result<_>>()?;

    // phase 2: replicate jobs
    let plan = JobPlan::new(npts, cfg.replicates);
    let (outputs, pool) =
        run_indexed_stats(cfg.threads, plan.len(), |i| {
            let job = plan.jobs[i];
            let mut rng = Rng::stream(cfg.seed, job.stream);
            let tr = Instant::now();
            let out = match tel.trace {
                Some(sink) => {
                    let mut tracer = TraceObs::new(
                        sink,
                        job.point,
                        job.replicate,
                        "scalar",
                    );
                    let out = scenario.run_traced(
                        job.point,
                        &ctxs[job.point],
                        &mut rng,
                        &mut tracer,
                    );
                    tracer.finish();
                    out
                }
                None => scenario.run(job.point, &ctxs[job.point], &mut rng),
            };
            if tel.enabled() {
                tel.span(
                    "run",
                    Some(job.point),
                    tr.elapsed().as_micros() as u64,
                    &[("replicate", job.replicate)],
                );
            }
            out
        });
    if tel.enabled() {
        tel.span(
            "pool",
            None,
            t0.elapsed().as_micros() as u64,
            &[
                ("workers", pool.workers as u64),
                ("own", pool.own),
                ("stolen", pool.stolen),
            ],
        );
        if let Some(reg) = tel.registry {
            reg.counter("sweep_pool_own_jobs").add(pool.own);
            reg.counter("sweep_pool_stolen_jobs").add(pool.stolen);
        }
    }

    // phase 3: deterministic collation in job order
    let tc = Instant::now();
    let mut points: Vec<PointSummary> = (0..npts)
        .map(|p| PointSummary {
            label: scenario.label(p),
            stats: vec![OnlineStats::new(); nmetrics],
            missing: vec![0; nmetrics],
        })
        .collect();
    for (i, out) in outputs.into_iter().enumerate() {
        let job = plan.jobs[i];
        let vals = out?;
        ensure!(
            vals.len() == nmetrics,
            "scenario returned {} metrics, declared {nmetrics}",
            vals.len()
        );
        let summary = &mut points[job.point];
        for (m, &v) in vals.iter().enumerate() {
            if v.is_finite() {
                summary.stats[m].push(v);
            } else {
                summary.missing[m] += 1;
            }
        }
    }
    if tel.enabled() {
        tel.span("collate", None, tc.elapsed().as_micros() as u64, &[]);
    }

    Ok(SweepResults {
        metric_names,
        points,
        throughput: Throughput {
            jobs: plan.len() as u64,
            elapsed_s: t0.elapsed().as_secs_f64(),
            threads: cfg.threads.max(1),
        },
    })
}

/// Run a scenario with one pool job per *grid point* instead of one per
/// (point, replicate): each job hands the point's whole replicate block
/// to [`Scenario::run_block`], which batched scenarios execute through
/// the structure-of-arrays kernel (`sim::batch`).
///
/// Digest-equal to [`run_sweep`] by construction: replicate `r` of
/// point `p` still draws from `Rng::stream(seed, p * replicates + r)`
/// (the same stream ids [`JobPlan`] assigns), blocks return metric
/// vectors in stream order, and collation folds them in the same
/// point-major job order. `throughput.jobs` keeps counting replicates
/// so jobs/s stays comparable across the two paths.
pub fn run_sweep_batched<S: Scenario>(
    scenario: &S,
    cfg: &SweepConfig,
) -> Result<SweepResults> {
    run_sweep_batched_with(scenario, cfg, Telemetry::off())
}

/// [`run_sweep_batched`] with telemetry attached — the batched
/// counterpart of [`run_sweep_with`], with the same digest-neutrality
/// contract. Per-replicate run latencies are accumulated in a
/// thread-local [`HistShard`] per point job and merged into the shared
/// registry histogram when the block completes.
pub fn run_sweep_batched_with<S: Scenario>(
    scenario: &S,
    cfg: &SweepConfig,
    tel: Telemetry<'_>,
) -> Result<SweepResults> {
    let t0 = Instant::now();
    let npts = scenario.points();
    let metric_names = scenario.metrics();
    let nmetrics = metric_names.len();

    // phase 1: per-point contexts, once per sweep (same as run_sweep)
    let ctxs: Vec<S::Ctx> =
        run_indexed(cfg.threads, npts, |p| {
            let tp = Instant::now();
            let ctx = scenario.prepare(p);
            if tel.enabled() {
                tel.span(
                    "prepare",
                    Some(p),
                    tp.elapsed().as_micros() as u64,
                    &[],
                );
            }
            ctx
        })
        .into_iter()
        .collect::<Result<_>>()?;

    // phase 2: one job per grid point, owning the point's whole
    // replicate block
    let (blocks, pool) = run_indexed_stats(cfg.threads, npts, |p| {
        let mut rngs: Vec<Rng> = (0..cfg.replicates)
            .map(|r| {
                Rng::stream(cfg.seed, p as u64 * cfg.replicates + r)
            })
            .collect();
        let tr = Instant::now();
        let out = match tel.trace {
            Some(sink) => {
                let mut tracers: Vec<TraceObs> = (0..cfg.replicates)
                    .map(|r| TraceObs::new(sink, p, r, "batched"))
                    .collect();
                let out = scenario.run_block_traced(
                    p,
                    &ctxs[p],
                    &mut rngs,
                    &mut tracers,
                );
                for t in &mut tracers {
                    t.finish();
                }
                out
            }
            None => scenario.run_block(p, &ctxs[p], &mut rngs),
        };
        if tel.enabled() {
            let wall = tr.elapsed().as_micros() as u64;
            if let Some(sink) = tel.trace {
                sink.write_line(&span_line(
                    "run",
                    Some(p),
                    wall,
                    &[("replicates", cfg.replicates)],
                ));
            }
            // `sweep_run_us` means *per-replicate* run latency on both
            // executors. The lockstep kernel interleaves its lanes, so
            // per-lane wall-clock is fiction here: spread the block
            // wall evenly across its replicates via a thread-local
            // shard, merged into the shared histogram at block end.
            if let (Some(reg), true) = (tel.registry, cfg.replicates > 0)
            {
                let mut shard = HistShard::default();
                for _ in 0..cfg.replicates {
                    shard.record(wall / cfg.replicates);
                }
                shard.merge_into(&reg.histogram("sweep_run_us"));
            }
        }
        out
    });
    if tel.enabled() {
        tel.span(
            "pool",
            None,
            t0.elapsed().as_micros() as u64,
            &[
                ("workers", pool.workers as u64),
                ("own", pool.own),
                ("stolen", pool.stolen),
            ],
        );
        if let Some(reg) = tel.registry {
            reg.counter("sweep_pool_own_jobs").add(pool.own);
            reg.counter("sweep_pool_stolen_jobs").add(pool.stolen);
        }
    }

    // phase 3: deterministic collation — point-major, replicate order
    // within each point: exactly run_sweep's job order
    let tc = Instant::now();
    let mut points: Vec<PointSummary> = (0..npts)
        .map(|p| PointSummary {
            label: scenario.label(p),
            stats: vec![OnlineStats::new(); nmetrics],
            missing: vec![0; nmetrics],
        })
        .collect();
    for (p, block) in blocks.into_iter().enumerate() {
        let block = block?;
        ensure!(
            block.len() as u64 == cfg.replicates,
            "scenario returned {} replicate outputs, expected {}",
            block.len(),
            cfg.replicates
        );
        let summary = &mut points[p];
        for vals in &block {
            ensure!(
                vals.len() == nmetrics,
                "scenario returned {} metrics, declared {nmetrics}",
                vals.len()
            );
            for (m, &v) in vals.iter().enumerate() {
                if v.is_finite() {
                    summary.stats[m].push(v);
                } else {
                    summary.missing[m] += 1;
                }
            }
        }
    }
    if tel.enabled() {
        tel.span("collate", None, tc.elapsed().as_micros() as u64, &[]);
    }

    Ok(SweepResults {
        metric_names,
        points,
        throughput: Throughput {
            jobs: npts as u64 * cfg.replicates,
            elapsed_s: t0.elapsed().as_secs_f64(),
            threads: cfg.threads.max(1),
        },
    })
}

impl SweepResults {
    /// Flatten into a CSV table: one row per grid point, with
    /// `mean/std/min/max/n/missing` columns per metric. Point labels are
    /// not representable in the numeric table; `print` carries them.
    pub fn to_table(&self) -> crate::util::csv::Table {
        let mut names: Vec<String> = vec!["point".to_string()];
        for m in &self.metric_names {
            for suffix in ["mean", "std", "min", "max", "n", "missing"] {
                names.push(format!("{m}_{suffix}"));
            }
        }
        let name_refs: Vec<&str> = names.iter().map(String::as_str).collect();
        let mut t = crate::util::csv::Table::new(&name_refs);
        for (p, point) in self.points.iter().enumerate() {
            let mut row = vec![p as f64];
            for (s, &miss) in point.stats.iter().zip(&point.missing) {
                let empty = s.count() == 0;
                row.push(s.mean());
                row.push(s.std());
                row.push(if empty { f64::NAN } else { s.min() });
                row.push(if empty { f64::NAN } else { s.max() });
                row.push(s.count() as f64);
                row.push(miss as f64);
            }
            t.push(row);
        }
        t
    }

    /// Machine-readable per-point summary: one row per grid point with
    /// its label and `mean/std/n/missing` per metric — the
    /// `sweep --out results.csv` payload, so downstream plotting never
    /// scrapes stdout.
    pub fn to_labeled_table(&self) -> crate::util::csv::StrTable {
        let mut names: Vec<String> = vec!["label".to_string()];
        for m in &self.metric_names {
            for suffix in ["mean", "std", "n", "missing"] {
                names.push(format!("{m}_{suffix}"));
            }
        }
        let name_refs: Vec<&str> = names.iter().map(String::as_str).collect();
        let mut t = crate::util::csv::StrTable::new(&name_refs);
        for point in &self.points {
            let mut row = vec![point.label.clone()];
            for (s, &miss) in point.stats.iter().zip(&point.missing) {
                row.push(format!("{}", s.mean()));
                row.push(format!("{}", s.std()));
                row.push(format!("{}", s.count()));
                row.push(format!("{miss}"));
            }
            t.push(row);
        }
        t
    }

    /// The same summary as JSON (hand-rolled: the build is offline and
    /// dependency-free, emitted via the shared [`crate::util::json`]
    /// convention). Non-finite statistics serialise as `null`.
    pub fn to_json(&self, scenario: &str, cfg: &SweepConfig) -> String {
        use crate::util::json::{esc, num};
        let mut out = String::new();
        out.push_str(&format!(
            "{{\n  \"scenario\": \"{}\",\n  \"seed\": {},\n  \
             \"replicates\": {},\n  \"threads\": {},\n  \
             \"digest\": \"{:016x}\",\n  \"metrics\": [",
            esc(scenario),
            cfg.seed,
            cfg.replicates,
            cfg.threads,
            self.digest()
        ));
        for (i, m) in self.metric_names.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!("\"{}\"", esc(m)));
        }
        out.push_str("],\n  \"points\": [\n");
        for (pi, p) in self.points.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"label\": \"{}\", \"metrics\": {{",
                esc(&p.label)
            ));
            for (mi, ((name, s), &miss)) in self
                .metric_names
                .iter()
                .zip(&p.stats)
                .zip(&p.missing)
                .enumerate()
            {
                if mi > 0 {
                    out.push_str(", ");
                }
                out.push_str(&format!(
                    "\"{}\": {{\"mean\": {}, \"std\": {}, \"n\": {}, \
                     \"missing\": {}}}",
                    esc(name),
                    num(s.mean()),
                    num(s.std()),
                    s.count(),
                    miss
                ));
            }
            out.push_str("}}");
            if pi + 1 < self.points.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Order- and thread-count-sensitive only if collation were broken:
    /// an FNV-1a hash over every label, count and statistic bit pattern.
    /// Two sweeps with the same seed must agree on this exactly.
    pub fn digest(&self) -> u64 {
        let mut h = Fnv::new();
        for name in &self.metric_names {
            h.bytes(name.as_bytes());
        }
        for p in &self.points {
            h.bytes(p.label.as_bytes());
            for (s, &miss) in p.stats.iter().zip(&p.missing) {
                h.u64(s.count());
                h.u64(miss);
                h.f64(s.mean());
                h.f64(s.variance());
                if s.count() > 0 {
                    h.f64(s.min());
                    h.f64(s.max());
                }
            }
        }
        h.finish()
    }

    /// Human-readable summary: one block per point, one line per metric.
    pub fn print(&self) {
        for p in &self.points {
            println!("  {}", p.label);
            for ((name, s), &miss) in
                self.metric_names.iter().zip(&p.stats).zip(&p.missing)
            {
                let miss_note = if miss > 0 {
                    format!("  ({miss} missing)")
                } else {
                    String::new()
                };
                println!(
                    "    {name:<18} mean={:<12.4} std={:<12.4} n={}{miss_note}",
                    s.mean(),
                    s.std(),
                    s.count()
                );
            }
        }
        println!("  {}", self.throughput);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A toy scenario: points are offsets, the metric is offset + a
    /// replicate-random draw; ctx proves `prepare` runs once per point.
    struct Toy {
        offsets: Vec<f64>,
    }

    impl Scenario for Toy {
        type Ctx = f64;

        fn points(&self) -> usize {
            self.offsets.len()
        }

        fn label(&self, point: usize) -> String {
            format!("offset={}", self.offsets[point])
        }

        fn metrics(&self) -> Vec<String> {
            vec!["value".to_string(), "draw".to_string()]
        }

        fn prepare(&self, point: usize) -> Result<f64> {
            Ok(self.offsets[point] * 10.0)
        }

        fn run(
            &self,
            _point: usize,
            ctx: &f64,
            rng: &mut Rng,
        ) -> Result<Vec<f64>> {
            let u = rng.f64();
            Ok(vec![ctx + u, u])
        }
    }

    #[test]
    fn identical_results_at_any_thread_count() {
        let toy = Toy { offsets: vec![1.0, 2.0, 3.0] };
        let base = SweepConfig { replicates: 16, seed: 99, threads: 1 };
        let serial = run_sweep(&toy, &base).unwrap();
        for threads in [2usize, 4, 8] {
            let cfg = SweepConfig { threads, ..base };
            let par = run_sweep(&toy, &cfg).unwrap();
            assert_eq!(serial.digest(), par.digest(), "threads={threads}");
            assert_eq!(
                serial.to_table().to_csv(),
                par.to_table().to_csv(),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn collation_counts_and_means() {
        let toy = Toy { offsets: vec![0.0, 5.0] };
        let cfg = SweepConfig { replicates: 200, seed: 3, threads: 4 };
        let out = run_sweep(&toy, &cfg).unwrap();
        assert_eq!(out.points.len(), 2);
        for (p, offset) in out.points.iter().zip([0.0f64, 5.0]) {
            assert_eq!(p.stats[0].count(), 200);
            assert_eq!(p.missing[0], 0);
            // value = 10 * offset + U(0,1)
            let want = offset * 10.0 + 0.5;
            assert!(
                (p.stats[0].mean() - want).abs() < 0.1,
                "mean {} vs {want}",
                p.stats[0].mean()
            );
        }
        assert_eq!(out.throughput.jobs, 400);
    }

    #[test]
    fn batched_harness_digest_equals_scalar() {
        let toy = Toy { offsets: vec![1.0, 2.0, 3.0] };
        let base = SweepConfig { replicates: 5, seed: 42, threads: 1 };
        let scalar = run_sweep(&toy, &base).unwrap();
        for threads in [1usize, 4, 8] {
            let cfg = SweepConfig { threads, ..base };
            let b = run_sweep_batched(&toy, &cfg).unwrap();
            assert_eq!(scalar.digest(), b.digest(), "threads={threads}");
            // jobs still counts replicates for cross-path comparability
            assert_eq!(b.throughput.jobs, 15);
            assert_eq!(
                scalar.to_labeled_table().to_csv(),
                b.to_labeled_table().to_csv()
            );
        }
    }

    #[test]
    fn telemetry_is_digest_neutral_and_emits_spans() {
        let toy = Toy { offsets: vec![1.0, 2.0] };
        let cfg = SweepConfig { replicates: 3, seed: 5, threads: 2 };
        let off = run_sweep(&toy, &cfg).unwrap();

        let dir = std::env::temp_dir()
            .join(format!("vsgd_sweep_tel_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("toy.jsonl");
        let sink = TraceSink::create(path.to_str().unwrap()).unwrap();
        sink.write_line(&crate::obs::meta_line("sweep", "toy", 5, 2));
        let reg = Registry::new();
        let tel = Telemetry { trace: Some(&sink), registry: Some(&reg) };
        let on = run_sweep_with(&toy, &cfg, tel).unwrap();
        let on_batched = run_sweep_batched_with(&toy, &cfg, tel).unwrap();
        sink.flush().unwrap();

        assert_eq!(off.digest(), on.digest());
        assert_eq!(off.digest(), on_batched.digest());
        // the trace validates and carries the expected span structure
        let text = std::fs::read_to_string(&path).unwrap();
        let sum = crate::obs::validate_trace(&text).unwrap();
        assert_eq!(sum.events, 0, "Toy has no engine inside");
        // each sweep: 2 prepare + run spans (2 per-replicate jobs x 3 /
        // 2 points) + pool + collate
        assert_eq!(sum.spans, (2 + 6 + 1 + 1) + (2 + 2 + 1 + 1));
        // histograms saw every stage
        let hists = reg.histogram_handles();
        let get = |name: &str| {
            hists.iter().find(|(n, _)| n == name).unwrap().1.count()
        };
        assert_eq!(get("sweep_prepare_us"), 4);
        assert_eq!(get("sweep_run_us"), 12); // 6 scalar + 6 shard-merged
        assert_eq!(get("sweep_collate_us"), 2);
        assert_eq!(get("sweep_pool_us"), 2);
        std::fs::remove_file(&path).ok();
        std::fs::remove_dir(&dir).ok();
    }

    #[test]
    fn different_seeds_differ() {
        let toy = Toy { offsets: vec![1.0] };
        let a = run_sweep(
            &toy,
            &SweepConfig { replicates: 8, seed: 1, threads: 2 },
        )
        .unwrap();
        let b = run_sweep(
            &toy,
            &SweepConfig { replicates: 8, seed: 2, threads: 2 },
        )
        .unwrap();
        assert_ne!(a.digest(), b.digest());
    }

    /// Non-finite metrics are counted missing, not averaged.
    struct Sometimes;

    impl Scenario for Sometimes {
        type Ctx = ();

        fn points(&self) -> usize {
            1
        }

        fn label(&self, _point: usize) -> String {
            "p".to_string()
        }

        fn metrics(&self) -> Vec<String> {
            vec!["maybe".to_string()]
        }

        fn prepare(&self, _point: usize) -> Result<()> {
            Ok(())
        }

        fn run(
            &self,
            _point: usize,
            _ctx: &(),
            rng: &mut Rng,
        ) -> Result<Vec<f64>> {
            Ok(vec![if rng.bool(0.5) { 1.0 } else { f64::NAN }])
        }
    }

    #[test]
    fn labeled_table_and_json_outputs() {
        let toy = Toy { offsets: vec![1.0, 2.0] };
        let cfg = SweepConfig { replicates: 4, seed: 1, threads: 2 };
        let out = run_sweep(&toy, &cfg).unwrap();

        let csv = out.to_labeled_table().to_csv();
        let mut lines = csv.lines();
        assert_eq!(
            lines.next().unwrap(),
            "label,value_mean,value_std,value_n,value_missing,\
             draw_mean,draw_std,draw_n,draw_missing"
        );
        assert_eq!(csv.lines().count(), 3); // header + 2 points
        assert!(csv.contains("offset=1,"));

        let json = out.to_json("toy", &cfg);
        assert!(json.contains("\"scenario\": \"toy\""));
        assert!(json.contains("\"seed\": 1"));
        assert!(json.contains(&format!("{:016x}", out.digest())));
        assert!(json.contains("\"offset=2\""));
        assert!(json.contains("\"n\": 4"));
        // crude structural sanity: balanced braces/brackets
        let bal = |open: char, close: char| {
            json.matches(open).count() == json.matches(close).count()
        };
        assert!(bal('{', '}') && bal('[', ']'));
    }

    #[test]
    fn missing_values_are_skipped() {
        let cfg = SweepConfig { replicates: 64, seed: 11, threads: 3 };
        let out = run_sweep(&Sometimes, &cfg).unwrap();
        let p = &out.points[0];
        assert_eq!(p.stats[0].count() + p.missing[0], 64);
        assert!(p.missing[0] > 0);
        assert_eq!(p.stats[0].mean(), 1.0);
    }
}
