//! Grid specification: named numeric axes crossed into a flat list of
//! points (row-major, first axis slowest).
//!
//! The figure sweeps are grids over things like (bid fraction, worker
//! count, preemption probability); scenarios decode a flat point index
//! into one value per axis with [`Grid::point`].

/// A cartesian product of named axes.
#[derive(Clone, Debug, Default)]
pub struct Grid {
    axes: Vec<(String, Vec<f64>)>,
}

impl Grid {
    pub fn new() -> Self {
        Grid { axes: Vec::new() }
    }

    /// Add an axis (builder-style). Empty axes are rejected: they would
    /// zero out the whole product, which is never what a sweep means.
    pub fn axis(mut self, name: &str, values: Vec<f64>) -> Self {
        assert!(!values.is_empty(), "axis '{name}' has no values");
        self.axes.push((name.to_string(), values));
        self
    }

    pub fn axis_names(&self) -> Vec<&str> {
        self.axes.iter().map(|(n, _)| n.as_str()).collect()
    }

    /// Number of grid points: the product of axis lengths (1 for no
    /// axes — the empty product; `axis()` rejects empty value lists, so
    /// the count is always positive).
    pub fn num_points(&self) -> usize {
        self.axes.iter().map(|(_, v)| v.len()).product()
    }

    /// Decode a flat index into one value per axis (first axis slowest).
    pub fn point(&self, mut idx: usize) -> Vec<f64> {
        assert!(
            idx < self.num_points(),
            "grid index {idx} out of {}",
            self.num_points()
        );
        let mut out = vec![0.0; self.axes.len()];
        for (k, (_, values)) in self.axes.iter().enumerate().rev() {
            out[k] = values[idx % values.len()];
            idx /= values.len();
        }
        out
    }

    /// Human label for a point: `"n=8 q=0.5"`.
    pub fn label(&self, idx: usize) -> String {
        let vals = self.point(idx);
        self.axes
            .iter()
            .zip(&vals)
            .map(|((name, _), v)| format!("{name}={v}"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_major_decode() {
        let g = Grid::new()
            .axis("a", vec![1.0, 2.0])
            .axis("b", vec![10.0, 20.0, 30.0]);
        assert_eq!(g.num_points(), 6);
        assert_eq!(g.point(0), vec![1.0, 10.0]);
        assert_eq!(g.point(2), vec![1.0, 30.0]);
        assert_eq!(g.point(3), vec![2.0, 10.0]);
        assert_eq!(g.point(5), vec![2.0, 30.0]);
        assert_eq!(g.label(3), "a=2 b=10");
        assert_eq!(g.axis_names(), vec!["a", "b"]);
    }

    #[test]
    fn single_axis_and_zero_axes() {
        let g = Grid::new().axis("x", vec![5.0]);
        assert_eq!(g.num_points(), 1);
        assert_eq!(g.point(0), vec![5.0]);
        assert_eq!(Grid::new().num_points(), 1); // empty product is the unit
    }

    #[test]
    #[should_panic]
    fn out_of_range_index_panics() {
        let g = Grid::new().axis("x", vec![1.0, 2.0]);
        let _ = g.point(2);
    }
}
