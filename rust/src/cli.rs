//! Clap-less argument parsing: `--key value` / `--flag` pairs after a
//! subcommand. Small on purpose — the config file carries anything
//! complex; flags override it.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Parsed command line: subcommand + flag map.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Self> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(cmd) = it.next() {
            if cmd.starts_with("--") {
                bail!("expected a subcommand before flags, got {cmd}");
            }
            args.command = cmd.clone();
        }
        while let Some(tok) = it.next() {
            let Some(key) = tok.strip_prefix("--") else {
                bail!("expected --flag, got '{tok}'");
            };
            // boolean flag if next token is absent or another flag
            let val = match it.peek() {
                Some(next) if !next.starts_with("--") => {
                    it.next().unwrap().clone()
                }
                _ => "true".to_string(),
            };
            if args.flags.insert(key.to_string(), val).is_some() {
                bail!("duplicate flag --{key}");
            }
        }
        Ok(args)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn f64(&self, key: &str, default: f64) -> Result<f64> {
        Ok(self.f64_opt(key)?.unwrap_or(default))
    }

    /// `Some(parsed)` when the flag is present, `None` when absent —
    /// for flags whose absence means "defer to the config default".
    pub fn f64_opt(&self, key: &str) -> Result<Option<f64>> {
        match self.flags.get(key) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| {
                anyhow::anyhow!("--{key} expects a number, got '{v}'")
            }),
        }
    }

    pub fn u64(&self, key: &str, default: u64) -> Result<u64> {
        Ok(self.u64_opt(key)?.unwrap_or(default))
    }

    /// `Some(parsed)` when the flag is present, `None` when absent —
    /// for flags whose absence means "defer to the config/spec default"
    /// rather than a fixed built-in.
    pub fn u64_opt(&self, key: &str) -> Result<Option<u64>> {
        match self.flags.get(key) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| {
                anyhow::anyhow!("--{key} expects an integer, got '{v}'")
            }),
        }
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.u64(key, default as u64)? as usize)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }

    /// Resolve the `--threads` flag: `0` — and an omitted flag — mean
    /// "one worker per available core" via
    /// [`std::thread::available_parallelism`] (falling back to 1 where
    /// the platform cannot report it). Any positive value is taken
    /// literally. Thread count is a pure throughput knob everywhere it
    /// appears (sweep, the figure harnesses, optimize): results are
    /// bit-identical at any value — see DESIGN.md §3.
    pub fn threads(&self) -> Result<usize> {
        match self.usize("threads", 0)? {
            0 => Ok(std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)),
            n => Ok(n),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = Args::parse(&sv(&[
            "simulate", "--n", "8", "--eps", "0.35", "--real",
        ]))
        .unwrap();
        assert_eq!(a.command, "simulate");
        assert_eq!(a.usize("n", 0).unwrap(), 8);
        assert_eq!(a.u64_opt("n").unwrap(), Some(8));
        assert_eq!(a.u64_opt("absent").unwrap(), None);
        assert_eq!(a.f64("eps", 0.0).unwrap(), 0.35);
        assert_eq!(a.f64_opt("eps").unwrap(), Some(0.35));
        assert_eq!(a.f64_opt("gone").unwrap(), None);
        assert!(a.bool("real"));
        assert!(!a.bool("missing"));
        assert_eq!(a.str("model", "cnn"), "cnn");
    }

    #[test]
    fn threads_zero_and_omitted_resolve_to_available_parallelism() {
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let omitted = Args::parse(&sv(&["sweep"])).unwrap();
        assert_eq!(omitted.threads().unwrap(), cores);
        let zero =
            Args::parse(&sv(&["sweep", "--threads", "0"])).unwrap();
        assert_eq!(zero.threads().unwrap(), cores);
        let three =
            Args::parse(&sv(&["sweep", "--threads", "3"])).unwrap();
        assert_eq!(three.threads().unwrap(), 3);
        assert!(Args::parse(&sv(&["sweep", "--threads", "x"]))
            .unwrap()
            .threads()
            .is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Args::parse(&sv(&["--nocmd"])).is_err());
        assert!(Args::parse(&sv(&["run", "bare"])).is_err());
        assert!(Args::parse(&sv(&["run", "--x", "1", "--x", "2"])).is_err());
        assert!(
            Args::parse(&sv(&["run", "--n", "abc"]))
                .unwrap()
                .u64("n", 0)
                .is_err()
        );
    }
}
