//! Synthetic byte-level corpus for the transformer LM workload.
//!
//! A second-order Markov chain over a 256-symbol alphabet with a Zipfian
//! stationary flavour: the entropy rate is well below ln(256), so a
//! language model that actually learns drives its loss visibly below the
//! uniform floor — giving the e2e example a meaningful loss curve without
//! any downloadable corpus.

use crate::util::rng::Rng;

/// Token stream generator + storage.
#[derive(Clone, Debug)]
pub struct MarkovCorpus {
    pub tokens: Vec<i32>,
    pub vocab: usize,
}

impl MarkovCorpus {
    /// Generate `len` tokens over `vocab` symbols. `branch` controls the
    /// per-context branching factor (smaller = lower entropy = easier).
    pub fn generate(len: usize, vocab: usize, branch: usize, rng: &mut Rng) -> Self {
        assert!(vocab >= 2 && branch >= 1 && len >= 2);
        // each context maps deterministically to `branch` candidate
        // successors chosen via a hash; transitions pick among them with
        // geometric weights. 70% of transitions condition on prev1 only
        // (order-1 structure an LM's bigram statistics pick up within a
        // few hundred SGD steps), 30% also mix in prev2 (order-2
        // structure that rewards attention context).
        let mut tokens = Vec::with_capacity(len);
        tokens.push(rng.below(vocab as u64) as i32);
        tokens.push(rng.below(vocab as u64) as i32);
        for i in 2..len {
            let a = tokens[i - 1] as u64;
            let b = if rng.bool(0.3) { tokens[i - 2] as u64 } else { 0 };
            // geometric choice among the branch candidates
            let mut k = 0usize;
            while k + 1 < branch && rng.bool(0.45) {
                k += 1;
            }
            let h = a
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(b.wrapping_mul(0xBF58_476D_1CE4_E5B9))
                .wrapping_add((k as u64).wrapping_mul(0x94D0_49BB_1331_11EB));
            // xor-fold to a symbol
            let sym = ((h ^ (h >> 29)).wrapping_mul(0xFF51_AFD7_ED55_8CCD)
                >> 33) % vocab as u64;
            tokens.push(sym as i32);
        }
        MarkovCorpus { tokens, vocab }
    }

    /// Sample a (inputs, targets) LM batch of shape [b, t]: targets are
    /// inputs shifted by one.
    pub fn batch(
        &self,
        b: usize,
        t: usize,
        rng: &mut Rng,
        xs: &mut Vec<i32>,
        ys: &mut Vec<i32>,
    ) {
        assert!(self.tokens.len() > t + 1);
        xs.clear();
        ys.clear();
        for _ in 0..b {
            let start =
                rng.below((self.tokens.len() - t - 1) as u64) as usize;
            xs.extend_from_slice(&self.tokens[start..start + t]);
            ys.extend_from_slice(&self.tokens[start + 1..start + t + 1]);
        }
    }

    /// Empirical unigram entropy (nats) — sanity signal for learnability.
    pub fn unigram_entropy(&self) -> f64 {
        let mut counts = vec![0u64; self.vocab];
        for &t in &self.tokens {
            counts[t as usize] += 1;
        }
        let n = self.tokens.len() as f64;
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.ln()
            })
            .sum()
    }

    /// Empirical order-2 conditional entropy (nats):
    /// H(X_t | X_{t-1}, X_{t-2}) — the chain's true order, and the loss
    /// floor an LM with >= 2 tokens of context can reach.
    pub fn trigram_cond_entropy(&self) -> f64 {
        use std::collections::HashMap;
        let mut joint: HashMap<(i32, i32, i32), u64> = HashMap::new();
        let mut marg: HashMap<(i32, i32), u64> = HashMap::new();
        for w in self.tokens.windows(3) {
            *joint.entry((w[0], w[1], w[2])).or_insert(0) += 1;
            *marg.entry((w[0], w[1])).or_insert(0) += 1;
        }
        let n = (self.tokens.len() - 2) as f64;
        let mut h = 0.0;
        for (&(a, b, _), &c) in &joint {
            let p_joint = c as f64 / n;
            let p_cond = c as f64 / marg[&(a, b)] as f64;
            h -= p_joint * p_cond.ln();
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_in_vocab() {
        let mut rng = Rng::new(4);
        let c = MarkovCorpus::generate(10_000, 256, 4, &mut rng);
        assert_eq!(c.tokens.len(), 10_000);
        assert!(c.tokens.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn entropy_is_learnably_low() {
        let mut rng = Rng::new(5);
        let c = MarkovCorpus::generate(200_000, 256, 4, &mut rng);
        let h_uni = c.unigram_entropy();
        let h_tri = c.trigram_cond_entropy();
        // unigrams look ~uniform (the successor hash spreads over the
        // vocab) but the order-2 structure is highly predictable: a model
        // with context can drive loss far below the ln(256) = 5.545 floor.
        assert!(h_uni > 4.0, "unigram entropy {h_uni}");
        assert!(h_tri < 2.0, "order-2 conditional entropy {h_tri}");
        assert!(h_tri < h_uni);
    }

    #[test]
    fn batch_shapes_and_shift() {
        let mut rng = Rng::new(6);
        let c = MarkovCorpus::generate(5_000, 256, 4, &mut rng);
        let (b, t) = (8, 64);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        c.batch(b, t, &mut rng, &mut xs, &mut ys);
        assert_eq!(xs.len(), b * t);
        assert_eq!(ys.len(), b * t);
        // target row is input row shifted by one within the corpus
        for row in 0..b {
            let x0 = xs[row * t + 1];
            let y0 = ys[row * t];
            assert_eq!(x0, y0);
        }
    }
}
