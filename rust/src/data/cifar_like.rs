//! Class-separable synthetic image dataset shaped like CIFAR-10.
//!
//! 10 classes; each class has a Gaussian prototype over the 3x32x32 = 3072
//! feature space plus per-sample noise, so a CNN trained on it exhibits
//! the same qualitative loss/accuracy-vs-iteration behaviour the paper's
//! figures track, while being generable offline in milliseconds. The
//! separation/noise ratio is tuned so accuracy climbs over thousands of
//! mini-batches rather than instantly (lest every strategy look alike).

use crate::util::rng::Rng;

pub const DIM: usize = 3 * 32 * 32;
pub const CLASSES: usize = 10;

/// In-memory dataset of f32 feature rows + integer labels.
#[derive(Clone, Debug)]
pub struct CifarLike {
    pub x: Vec<f32>, // row-major [n, DIM]
    pub y: Vec<i32>,
    pub n: usize,
}

impl CifarLike {
    /// Generate `n` samples. `difficulty` in (0, ~2]: larger = noisier
    /// (1.0 gives a task where the small CNN tops out ~90% test acc).
    pub fn generate(n: usize, difficulty: f64, rng: &mut Rng) -> Self {
        assert!(n > 0);
        // class prototypes: sparse-ish smooth patterns
        let mut protos = vec![0f32; CLASSES * DIM];
        for c in 0..CLASSES {
            let mut proto_rng = rng.split(c as u64 + 101);
            for d in 0..DIM {
                // smooth structure: low-frequency sinusoid keyed by class
                let t = d as f64 / DIM as f64;
                let wave = ((c + 1) as f64 * 2.5 * std::f64::consts::PI * t
                    + c as f64)
                    .sin();
                protos[c * DIM + d] =
                    (0.9 * wave + 0.45 * proto_rng.gaussian()) as f32;
            }
        }
        let mut x = vec![0f32; n * DIM];
        let mut y = vec![0i32; n];
        let noise = difficulty as f32;
        for i in 0..n {
            let c = rng.below(CLASSES as u64) as usize;
            y[i] = c as i32;
            for d in 0..DIM {
                x[i * DIM + d] = protos[c * DIM + d]
                    + noise * rng.gaussian() as f32;
            }
        }
        CifarLike { x, y, n }
    }

    /// Borrow sample `i` as (features, label).
    pub fn sample(&self, i: usize) -> (&[f32], i32) {
        (&self.x[i * DIM..(i + 1) * DIM], self.y[i])
    }

    /// Copy a batch given sample indices into contiguous buffers.
    pub fn gather(&self, idx: &[usize], xs: &mut Vec<f32>, ys: &mut Vec<i32>) {
        xs.clear();
        ys.clear();
        for &i in idx {
            let (f, l) = self.sample(i);
            xs.extend_from_slice(f);
            ys.push(l);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_shapes_and_labels() {
        let mut rng = Rng::new(1);
        let d = CifarLike::generate(100, 1.0, &mut rng);
        assert_eq!(d.x.len(), 100 * DIM);
        assert_eq!(d.y.len(), 100);
        assert!(d.y.iter().all(|&c| (0..CLASSES as i32).contains(&c)));
        // all classes present in a 100-sample draw with high probability
        let mut seen = [false; CLASSES];
        for &c in &d.y {
            seen[c as usize] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() >= 8);
    }

    #[test]
    fn classes_are_separable() {
        // nearest-prototype classification on clean data should beat 70%
        let mut rng = Rng::new(2);
        let d = CifarLike::generate(400, 1.0, &mut rng);
        // estimate per-class means from the first 300, test on the rest
        let mut means = vec![0f64; CLASSES * DIM];
        let mut counts = [0usize; CLASSES];
        for i in 0..300 {
            let (f, l) = d.sample(i);
            counts[l as usize] += 1;
            for (j, &v) in f.iter().enumerate() {
                means[l as usize * DIM + j] += v as f64;
            }
        }
        for c in 0..CLASSES {
            if counts[c] > 0 {
                for j in 0..DIM {
                    means[c * DIM + j] /= counts[c] as f64;
                }
            }
        }
        let mut correct = 0;
        for i in 300..400 {
            let (f, l) = d.sample(i);
            let mut best = (f64::INFINITY, 0usize);
            for c in 0..CLASSES {
                let dist: f64 = f
                    .iter()
                    .enumerate()
                    .map(|(j, &v)| {
                        let dd = v as f64 - means[c * DIM + j];
                        dd * dd
                    })
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == l as usize {
                correct += 1;
            }
        }
        assert!(correct > 70, "nearest-prototype acc {correct}/100");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let a = CifarLike::generate(10, 1.0, &mut r1);
        let b = CifarLike::generate(10, 1.0, &mut r2);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn gather_concatenates() {
        let mut rng = Rng::new(3);
        let d = CifarLike::generate(10, 1.0, &mut rng);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        d.gather(&[3, 7], &mut xs, &mut ys);
        assert_eq!(xs.len(), 2 * DIM);
        assert_eq!(ys, vec![d.y[3], d.y[7]]);
        assert_eq!(&xs[..DIM], d.sample(3).0);
    }
}
