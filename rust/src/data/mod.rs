//! Synthetic datasets (offline stand-ins for CIFAR-10 and a text corpus).

pub mod batcher;
pub mod cifar_like;
pub mod corpus;

pub use batcher::Batcher;
pub use cifar_like::CifarLike;
pub use corpus::MarkovCorpus;
