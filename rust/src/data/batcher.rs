//! Per-worker mini-batch assignment.
//!
//! Synchronous SGD gives each *active* worker an independent mini-batch
//! each iteration (paper Sec. III-A). The batcher deals disjoint random
//! index blocks per epoch (sampling without replacement within an epoch,
//! reshuffling between epochs), so gradients across workers in one
//! iteration are computed on disjoint data, like the Ray implementation
//! the paper used.

use crate::util::rng::Rng;

/// Epoch-shuffled index dealer.
#[derive(Clone, Debug)]
pub struct Batcher {
    n: usize,
    batch: usize,
    order: Vec<usize>,
    cursor: usize,
    epoch: u64,
}

impl Batcher {
    pub fn new(n: usize, batch: usize, rng: &mut Rng) -> Self {
        assert!(n >= batch && batch > 0);
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        Batcher { n, batch, order, cursor: 0, epoch: 0 }
    }

    /// Deal the next mini-batch of indices (reshuffles at epoch ends).
    pub fn next(&mut self, rng: &mut Rng) -> &[usize] {
        if self.cursor + self.batch > self.n {
            rng.shuffle(&mut self.order);
            self.cursor = 0;
            self.epoch += 1;
        }
        let s = self.cursor;
        self.cursor += self.batch;
        &self.order[s..s + self.batch]
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deals_disjoint_batches_within_epoch() {
        let mut rng = Rng::new(1);
        let mut b = Batcher::new(100, 10, &mut rng);
        let mut seen = vec![false; 100];
        for _ in 0..10 {
            for &i in b.next(&mut rng).to_vec().iter() {
                assert!(!seen[i], "index {i} dealt twice in epoch");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
        assert_eq!(b.epoch(), 0);
        b.next(&mut rng);
        assert_eq!(b.epoch(), 1);
    }

    #[test]
    fn uneven_tail_is_dropped_on_reshuffle() {
        let mut rng = Rng::new(2);
        let mut b = Batcher::new(25, 10, &mut rng);
        assert_eq!(b.next(&mut rng).len(), 10);
        assert_eq!(b.next(&mut rng).len(), 10);
        // only 5 left -> reshuffle, new epoch
        assert_eq!(b.next(&mut rng).len(), 10);
        assert_eq!(b.epoch(), 1);
    }

    #[test]
    #[should_panic]
    fn batch_larger_than_dataset_rejected() {
        let mut rng = Rng::new(3);
        Batcher::new(5, 10, &mut rng);
    }
}
