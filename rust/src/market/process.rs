//! I.i.d. spot-price distributions (the paper's F(.)).

use crate::util::erf::{norm_cdf, norm_ppf};
use crate::util::rng::Rng;

use super::cdf::EmpiricalCdf;

/// Distribution interface for the spot price p_t.
pub trait PriceDist {
    /// F(p) = P[p_t <= p], clamped to [0,1] outside the support.
    fn cdf(&self, p: f64) -> f64;
    /// Smallest p with F(p) >= u, u in [0,1].
    fn inv_cdf(&self, u: f64) -> f64;
    /// Draw one price.
    fn sample(&self, rng: &mut Rng) -> f64;
    /// Support [lo, hi].
    fn support(&self) -> (f64, f64);

    /// E[p_t | p_t <= b] * F(b): the running-cost integral
    /// `int_lo^b p f(p) dp`, default by numeric quadrature on the CDF
    /// (integration by parts: = b F(b) - int_lo^b F(p) dp).
    fn price_mass_below(&self, b: f64) -> f64 {
        let (lo, _) = self.support();
        let b = b.max(lo);
        const STEPS: usize = 2_000;
        let h = (b - lo) / STEPS as f64;
        if h <= 0.0 {
            return 0.0;
        }
        // trapezoid on F
        let mut int_f = 0.5 * (self.cdf(lo) + self.cdf(b));
        for i in 1..STEPS {
            int_f += self.cdf(lo + h * i as f64);
        }
        int_f *= h;
        b * self.cdf(b) - int_f
    }
}

/// The concrete price models used in the experiments.
#[derive(Clone, Debug)]
pub enum PriceModel {
    /// Uniform[lo, hi] — the paper's first synthetic distribution
    /// (Fig. 3a/3c uses Uniform[0.2, 1]).
    Uniform { lo: f64, hi: f64 },
    /// Gaussian(mean, std) truncated to [lo, hi] — the paper's second
    /// synthetic distribution (mean .6, std .175 on [0.2, 1]).
    TruncGaussian { mean: f64, std: f64, lo: f64, hi: f64 },
    /// Empirical CDF over samples (e.g. a replayed price trace) — how the
    /// strategies estimate F from history, as in Fig. 4.
    Empirical(EmpiricalCdf),
}

impl PriceModel {
    pub fn uniform_paper() -> Self {
        PriceModel::Uniform { lo: 0.2, hi: 1.0 }
    }

    pub fn gaussian_paper() -> Self {
        PriceModel::TruncGaussian { mean: 0.6, std: 0.175, lo: 0.2, hi: 1.0 }
    }

    fn trunc_gauss_z(mean: f64, std: f64, lo: f64, hi: f64) -> (f64, f64) {
        let a = norm_cdf((lo - mean) / std);
        let b = norm_cdf((hi - mean) / std);
        (a, b)
    }
}

impl PriceDist for PriceModel {
    fn cdf(&self, p: f64) -> f64 {
        match self {
            PriceModel::Uniform { lo, hi } => {
                ((p - lo) / (hi - lo)).clamp(0.0, 1.0)
            }
            PriceModel::TruncGaussian { mean, std, lo, hi } => {
                if p <= *lo {
                    return 0.0;
                }
                if p >= *hi {
                    return 1.0;
                }
                let (a, b) = Self::trunc_gauss_z(*mean, *std, *lo, *hi);
                ((norm_cdf((p - mean) / std) - a) / (b - a)).clamp(0.0, 1.0)
            }
            PriceModel::Empirical(e) => e.cdf(p),
        }
    }

    fn inv_cdf(&self, u: f64) -> f64 {
        let u = u.clamp(0.0, 1.0);
        match self {
            PriceModel::Uniform { lo, hi } => lo + u * (hi - lo),
            PriceModel::TruncGaussian { mean, std, lo, hi } => {
                if u <= 0.0 {
                    return *lo;
                }
                if u >= 1.0 {
                    return *hi;
                }
                let (a, b) = Self::trunc_gauss_z(*mean, *std, *lo, *hi);
                let p = (a + u * (b - a)).clamp(1e-12, 1.0 - 1e-12);
                (mean + std * norm_ppf(p)).clamp(*lo, *hi)
            }
            PriceModel::Empirical(e) => e.quantile(u),
        }
    }

    fn sample(&self, rng: &mut Rng) -> f64 {
        self.inv_cdf(rng.f64())
    }

    fn support(&self) -> (f64, f64) {
        match self {
            PriceModel::Uniform { lo, hi } => (*lo, *hi),
            PriceModel::TruncGaussian { lo, hi, .. } => (*lo, *hi),
            PriceModel::Empirical(e) => e.support(),
        }
    }

    fn price_mass_below(&self, b: f64) -> f64 {
        match self {
            // closed form for uniform: int_lo^b p/(hi-lo) dp
            PriceModel::Uniform { lo, hi } => {
                let b = b.clamp(*lo, *hi);
                (b * b - lo * lo) / (2.0 * (hi - lo))
            }
            _ => {
                // default quadrature
                let (lo, hi) = self.support();
                let b = b.clamp(lo, hi);
                const STEPS: usize = 2_000;
                let h = (b - lo) / STEPS as f64;
                if h <= 0.0 {
                    return 0.0;
                }
                let mut int_f = 0.5 * (self.cdf(lo) + self.cdf(b));
                for i in 1..STEPS {
                    int_f += self.cdf(lo + h * i as f64);
                }
                int_f *= h;
                b * self.cdf(b) - int_f
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_cdf_inverse_roundtrip() {
        let m = PriceModel::uniform_paper();
        for i in 0..=20 {
            let u = i as f64 / 20.0;
            let p = m.inv_cdf(u);
            assert!((m.cdf(p) - u).abs() < 1e-12);
        }
    }

    #[test]
    fn gaussian_cdf_monotone_and_bounded() {
        let m = PriceModel::gaussian_paper();
        let mut prev = -1.0;
        for i in 0..=100 {
            let p = 0.2 + 0.8 * i as f64 / 100.0;
            let c = m.cdf(p);
            assert!((0.0..=1.0).contains(&c));
            assert!(c >= prev);
            prev = c;
        }
        assert_eq!(m.cdf(0.1), 0.0);
        assert_eq!(m.cdf(1.5), 1.0);
    }

    #[test]
    fn gaussian_inverse_roundtrip() {
        let m = PriceModel::gaussian_paper();
        for &u in &[0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99] {
            let p = m.inv_cdf(u);
            assert!(
                (m.cdf(p) - u).abs() < 1e-5,
                "u={u} p={p} cdf={}",
                m.cdf(p)
            );
        }
    }

    #[test]
    fn sample_matches_cdf() {
        let m = PriceModel::gaussian_paper();
        let mut rng = Rng::new(1);
        let n = 100_000;
        let below: usize = (0..n)
            .filter(|_| m.sample(&mut rng) <= 0.6)
            .count();
        let expect = m.cdf(0.6);
        assert!(
            (below as f64 / n as f64 - expect).abs() < 0.01,
            "emp={} cdf={}",
            below as f64 / n as f64,
            expect
        );
    }

    #[test]
    fn uniform_price_mass_closed_form_matches_quadrature() {
        let m = PriceModel::uniform_paper();
        for &b in &[0.3, 0.5, 0.8, 1.0] {
            // quadrature via the trait default on a wrapper
            struct Wrap<'a>(&'a PriceModel);
            impl PriceDist for Wrap<'_> {
                fn cdf(&self, p: f64) -> f64 {
                    self.0.cdf(p)
                }
                fn inv_cdf(&self, u: f64) -> f64 {
                    self.0.inv_cdf(u)
                }
                fn sample(&self, rng: &mut Rng) -> f64 {
                    self.0.sample(rng)
                }
                fn support(&self) -> (f64, f64) {
                    self.0.support()
                }
            }
            let quad = Wrap(&m).price_mass_below(b);
            let exact = m.price_mass_below(b);
            assert!((quad - exact).abs() < 1e-5, "b={b}: {quad} vs {exact}");
        }
    }

    #[test]
    fn price_mass_below_is_conditional_mean_times_cdf() {
        // Monte-Carlo check on the Gaussian model
        let m = PriceModel::gaussian_paper();
        let mut rng = Rng::new(3);
        let b = 0.55;
        let n = 200_000;
        let mut mass = 0.0;
        for _ in 0..n {
            let p = m.sample(&mut rng);
            if p <= b {
                mass += p;
            }
        }
        mass /= n as f64;
        assert!(
            (mass - m.price_mass_below(b)).abs() < 2e-3,
            "mc={mass} quad={}",
            m.price_mass_below(b)
        );
    }
}
