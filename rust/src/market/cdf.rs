//! Empirical CDF with quantile inverse — how a user estimates F(.) from
//! observed spot-price history before bidding (Sec. VI: "we download the
//! historical price traces ... to estimate the probability distribution").

/// Empirical CDF over a finite sample (sorted once at construction).
#[derive(Clone, Debug)]
pub struct EmpiricalCdf {
    sorted: Vec<f64>,
}

impl EmpiricalCdf {
    pub fn new(mut samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty(), "empirical CDF needs >= 1 sample");
        assert!(
            samples.iter().all(|x| x.is_finite()),
            "non-finite price sample"
        );
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        EmpiricalCdf { sorted: samples }
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// F(p) = (# samples <= p) / n.
    pub fn cdf(&self, p: f64) -> f64 {
        let k = self.sorted.partition_point(|&x| x <= p);
        k as f64 / self.sorted.len() as f64
    }

    /// Quantile: smallest sample x with F(x) >= u (inverse CDF, right-
    /// continuous). u<=0 gives the min, u>=1 the max.
    pub fn quantile(&self, u: f64) -> f64 {
        let n = self.sorted.len();
        if u <= 0.0 {
            return self.sorted[0];
        }
        if u >= 1.0 {
            return self.sorted[n - 1];
        }
        let k = (u * n as f64).ceil() as usize;
        self.sorted[k.clamp(1, n) - 1]
    }

    pub fn support(&self) -> (f64, f64) {
        (self.sorted[0], self.sorted[self.sorted.len() - 1])
    }

    pub fn mean(&self) -> f64 {
        self.sorted.iter().sum::<f64>() / self.sorted.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{for_all, Gen};

    #[test]
    fn cdf_step_values() {
        let e = EmpiricalCdf::new(vec![3.0, 1.0, 2.0]);
        assert_eq!(e.cdf(0.5), 0.0);
        assert!((e.cdf(1.0) - 1.0 / 3.0).abs() < 1e-12);
        assert!((e.cdf(2.5) - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(e.cdf(3.0), 1.0);
    }

    #[test]
    fn quantile_bounds() {
        let e = EmpiricalCdf::new(vec![5.0, 1.0, 9.0]);
        assert_eq!(e.quantile(0.0), 1.0);
        assert_eq!(e.quantile(1.0), 9.0);
        assert_eq!(e.support(), (1.0, 9.0));
    }

    #[test]
    #[should_panic]
    fn rejects_empty() {
        EmpiricalCdf::new(vec![]);
    }

    #[test]
    #[should_panic]
    fn rejects_nan() {
        EmpiricalCdf::new(vec![1.0, f64::NAN]);
    }

    #[test]
    fn prop_quantile_cdf_galois() {
        // quantile(u) is the smallest x in the sample with cdf(x) >= u
        for_all("quantile-cdf galois connection", |g: &mut Gen| {
            let n = g.u64_in(1, 60) as usize;
            let xs = g.vec_f64(n, 0.0, 10.0);
            let e = EmpiricalCdf::new(xs);
            let u = g.f64_in(0.001, 0.999);
            let q = e.quantile(u);
            if e.cdf(q) + 1e-12 < u {
                return Err(format!("cdf(quantile({u}))={} < u", e.cdf(q)));
            }
            // any strictly smaller sample has cdf < u
            for &x in &e.sorted {
                if x < q && e.cdf(x) >= u {
                    return Err(format!("smaller sample {x} already has cdf>=u"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_cdf_monotone() {
        for_all("cdf monotone", |g: &mut Gen| {
            let n = g.u64_in(1, 40) as usize;
            let e = EmpiricalCdf::new(g.vec_f64(n, -5.0, 5.0));
            let a = g.f64_in(-6.0, 6.0);
            let b = g.f64_in(-6.0, 6.0);
            let (a, b) = if a <= b { (a, b) } else { (b, a) };
            if e.cdf(a) <= e.cdf(b) + 1e-12 {
                Ok(())
            } else {
                Err(format!("cdf({a})={} > cdf({b})={}", e.cdf(a), e.cdf(b)))
            }
        });
    }
}
