//! Spot-market substrate: price processes, CDFs, traces, bid admission.
//!
//! The paper's Section IV models the EC2 spot market as an i.i.d. price
//! `p_t` with CDF `F` supported on [p_lo, p_hi]; a worker bidding `b` is
//! active iff `b >= p_t` and pays the *spot price* (not the bid) per unit
//! time while active. This module provides:
//!
//! * [`PriceDist`] — the distribution interface (`cdf`, `inv_cdf`, `sample`)
//!   with the paper's two synthetic distributions (uniform, truncated
//!   Gaussian) plus an empirical CDF built from any sample set;
//! * [`trace`] — replayable time-stamped price traces in the shape of AWS
//!   `DescribeSpotPriceHistory` output, plus a regime-switching synthetic
//!   trace generator (the offline stand-in for real c5.xlarge history);
//! * [`bidding`] — bid vectors, persistent-request semantics and the
//!   active-worker-count resolution used by the scheduler;
//! * [`portfolio`] — multi-market portfolios (per-entry price process,
//!   preemption rate, speed multiplier) and the effective-price
//!   migration rule (DESIGN.md §10);
//! * [`tracefile`] — the strict CSV/JSON spot-history loader behind the
//!   `tracefile` market kind (content-hashed identity, grid resampling).

pub mod bidding;
pub mod cdf;
pub mod portfolio;
pub mod process;
pub mod trace;
pub mod tracefile;

pub use bidding::{BidVector, WorkerBid};
pub use cdf::EmpiricalCdf;
pub use portfolio::{MarketPortfolio, MigrationRule, PortfolioEntry};
pub use process::{PriceDist, PriceModel};
pub use trace::{SpotTrace, TraceGenConfig};
