//! Strict spot-price-history ingestion (`kind = "tracefile"`).
//!
//! [`SpotTrace::parse_csv`] is deliberately lenient — it sorts, dedups
//! and keeps whatever numeric columns it finds, which is right for
//! ad-hoc `--trace` files but wrong for *shipped* presets: a fixture
//! that silently reorders or drops rows would change results without
//! failing `--check`. This module is the strict counterpart used by the
//! `tracefile` market kind (DESIGN.md §10):
//!
//! * CSV (`timestamp,price` header, or headerless two-column) and JSON
//!   (an array of `{"timestamp": t, "price": p}` objects) are accepted;
//! * unknown columns/keys are rejected **by name**, never ignored;
//! * timestamps must be strictly increasing — the loader refuses to
//!   sort for you;
//! * prices must be finite and strictly positive (a negative or zero
//!   spot price is always a data error);
//! * an empty file (or one with a header and no rows) is an error.
//!
//! Times are shifted so the trace starts at 0 (EC2 histories carry
//! epoch timestamps; the engine clock starts at 0), and an optional
//! `resample_s` interval re-quantises the loaded path onto the engine's
//! price-revision grid: revisions at `0, dt, 2dt, ...` with the price
//! the raw trace showed at each grid time (piecewise-constant,
//! right-open — the same read rule [`SpotTrace::price_at`] applies).
//!
//! Identity is *content*, not path: [`content_fnv`] hashes the raw
//! bytes, and the spec fingerprints (DESIGN.md §9) absorb that hash, so
//! editing a fixture on disk invalidates every serve-daemon cache entry
//! that was computed from the old bytes.

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::util::fnv::Fnv;
use crate::util::json::JsonValue;

use super::trace::SpotTrace;

/// Resolve a trace path as the spec wrote it: tried verbatim first
/// (relative to the current directory — the repo root in CI), then
/// relative to the repository root the crate was built from, so
/// `cargo test` (whose working directory is `rust/`) finds shipped
/// fixtures like `examples/traces/*.csv` too.
pub fn resolve(path: &str) -> PathBuf {
    let p = Path::new(path);
    if p.exists() || !p.is_relative() {
        return p.to_path_buf();
    }
    if let Some(root) = Path::new(env!("CARGO_MANIFEST_DIR")).parent() {
        let fallback = root.join(p);
        if fallback.exists() {
            return fallback;
        }
    }
    p.to_path_buf()
}

/// FNV-1a over the file's raw bytes — the identity the scenario/point
/// fingerprints use for `trace`/`tracefile` markets (content, not
/// path: two paths to identical bytes fingerprint the same, and an
/// edited file fingerprints differently).
pub fn content_fnv(path: &str) -> Result<u64> {
    let resolved = resolve(path);
    let bytes = fs::read(&resolved).with_context(|| {
        format!("reading trace file {}", resolved.display())
    })?;
    let mut h = Fnv::new();
    h.bytes(&bytes);
    Ok(h.finish())
}

/// Load a strict trace file. Format is sniffed from the content: a
/// leading `[` means JSON, anything else is CSV.
pub fn load(path: &str) -> Result<SpotTrace> {
    let resolved = resolve(path);
    let text = fs::read_to_string(&resolved).with_context(|| {
        format!("reading trace file {}", resolved.display())
    })?;
    let parsed = if text.trim_start().starts_with('[') {
        parse_json(&text)
    } else {
        parse_csv(&text)
    };
    parsed.with_context(|| format!("trace file {}", resolved.display()))
}

/// Strict CSV: an optional `timestamp,price` header (exactly those
/// names, in that order), then two-column numeric rows.
pub fn parse_csv(text: &str) -> Result<SpotTrace> {
    let mut rows: Vec<(f64, f64)> = Vec::new();
    let mut saw_header = false;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let at = lineno + 1;
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        // a header is any line with a non-numeric first field; it must
        // name exactly the two supported columns
        if rows.is_empty()
            && !saw_header
            && fields.first().is_some_and(|f| f.parse::<f64>().is_err())
        {
            ensure!(
                fields == ["timestamp", "price"],
                "line {at}: unknown column(s) {:?} (the strict loader \
                 accepts exactly 'timestamp,price')",
                fields
                    .iter()
                    .filter(|f| !matches!(**f, "timestamp" | "price"))
                    .collect::<Vec<_>>(),
            );
            saw_header = true;
            continue;
        }
        ensure!(
            fields.len() == 2,
            "line {at}: expected 2 columns (timestamp,price), got {}",
            fields.len()
        );
        let t: f64 = fields[0].parse().map_err(|_| {
            anyhow::anyhow!("line {at}: bad timestamp '{}'", fields[0])
        })?;
        let p: f64 = fields[1].parse().map_err(|_| {
            anyhow::anyhow!("line {at}: bad price '{}'", fields[1])
        })?;
        check_row(t, p, at)?;
        if let Some((prev, _)) = rows.last() {
            ensure!(
                t > *prev,
                "line {at}: timestamps not strictly increasing \
                 ({prev} then {t}); the strict loader does not sort"
            );
        }
        rows.push((t, p));
    }
    finish(rows)
}

/// Strict JSON: a top-level array of objects, each with exactly the
/// keys `timestamp` and `price` (numbers).
pub fn parse_json(text: &str) -> Result<SpotTrace> {
    let v = JsonValue::parse(text)?;
    let JsonValue::Arr(items) = v else {
        bail!("expected a top-level JSON array of {{timestamp, price}}");
    };
    let mut rows: Vec<(f64, f64)> = Vec::new();
    for (i, item) in items.iter().enumerate() {
        let at = i + 1;
        let JsonValue::Obj(fields) = item else {
            bail!("entry {at}: expected an object with timestamp/price");
        };
        for (k, _) in fields {
            ensure!(
                matches!(k.as_str(), "timestamp" | "price"),
                "entry {at}: unknown key '{k}' (the strict loader \
                 accepts exactly 'timestamp' and 'price')"
            );
        }
        let t = item.get("timestamp").and_then(JsonValue::as_f64);
        let p = item.get("price").and_then(JsonValue::as_f64);
        let (Some(t), Some(p)) = (t, p) else {
            bail!("entry {at}: needs numeric 'timestamp' and 'price'");
        };
        check_row(t, p, at)?;
        if let Some((prev, _)) = rows.last() {
            ensure!(
                t > *prev,
                "entry {at}: timestamps not strictly increasing \
                 ({prev} then {t}); the strict loader does not sort"
            );
        }
        rows.push((t, p));
    }
    finish(rows)
}

fn check_row(t: f64, p: f64, at: usize) -> Result<()> {
    ensure!(t.is_finite(), "row {at}: non-finite timestamp {t}");
    ensure!(
        p.is_finite() && p > 0.0,
        "row {at}: price must be finite and > 0, got {p} \
         (negative/zero spot prices are a data error)"
    );
    Ok(())
}

fn finish(rows: Vec<(f64, f64)>) -> Result<SpotTrace> {
    ensure!(
        !rows.is_empty(),
        "empty trace file (no data rows): a tracefile market needs at \
         least one timestamp,price row"
    );
    // shift to the engine clock: the trace starts at t = 0
    let t0 = rows[0].0;
    let times = rows.iter().map(|(t, _)| t - t0).collect();
    let prices = rows.iter().map(|(_, p)| *p).collect();
    SpotTrace::new(times, prices)
}

/// Re-quantise a trace onto the engine's price-revision grid: one
/// revision every `interval_s` seconds from 0 to the last grid point at
/// or before the raw horizon, each carrying the price the raw trace
/// showed at that instant. The resampled horizon is that last grid
/// point (the deadline cap follows it).
pub fn resample(trace: &SpotTrace, interval_s: f64) -> Result<SpotTrace> {
    ensure!(
        interval_s.is_finite() && interval_s > 0.0,
        "resample_s must be finite and > 0, got {interval_s}"
    );
    let steps = (trace.horizon() / interval_s).floor() as u64;
    let times: Vec<f64> =
        (0..=steps).map(|k| k as f64 * interval_s).collect();
    let prices: Vec<f64> =
        times.iter().map(|&t| trace.price_at(t)).collect();
    SpotTrace::new(times, prices)
}

#[cfg(test)]
mod tests {
    use super::*;

    const CSV_FIXTURE: &str =
        include_str!("../../../examples/traces/ec2_c5xlarge_uswest2a.csv");
    const JSON_FIXTURE: &str =
        include_str!("../../../examples/traces/ec2_m5large_uswest2c.json");

    #[test]
    fn shipped_csv_fixture_parses_and_is_zero_based() {
        let t = parse_csv(CSV_FIXTURE).unwrap();
        assert_eq!(t.times[0], 0.0);
        assert!(t.times.len() >= 24, "fixture has a real history");
        assert!(t.horizon() > 0.0);
        assert!(t.prices.iter().all(|p| *p > 0.0));
    }

    #[test]
    fn shipped_json_fixture_parses_and_is_zero_based() {
        let t = parse_json(JSON_FIXTURE).unwrap();
        assert_eq!(t.times[0], 0.0);
        assert!(t.times.len() >= 24, "fixture has a real history");
        assert!(t.prices.iter().all(|p| *p > 0.0));
    }

    #[test]
    fn headerless_csv_is_accepted() {
        let t = parse_csv("100,0.5\n200,0.6\n").unwrap();
        assert_eq!(t.times, vec![0.0, 100.0]);
        assert_eq!(t.prices, vec![0.5, 0.6]);
    }

    #[test]
    fn unsorted_timestamps_are_rejected_not_sorted() {
        let err = parse_csv("timestamp,price\n200,0.5\n100,0.6\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("not strictly increasing"), "{err}");
        // ... unlike the lenient SpotTrace::parse_csv, which sorts
        assert!(SpotTrace::parse_csv("t,p\n200,0.5\n100,0.6\n").is_ok());
        let err = parse_json(
            "[{\"timestamp\": 2, \"price\": 0.5}, \
             {\"timestamp\": 1, \"price\": 0.6}]",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("not strictly increasing"), "{err}");
        // equal timestamps are "not strictly increasing" too
        assert!(parse_csv("100,0.5\n100,0.6\n").is_err());
    }

    #[test]
    fn negative_and_zero_prices_are_rejected() {
        let err =
            parse_csv("100,-0.5\n").unwrap_err().to_string();
        assert!(err.contains("got -0.5"), "{err}");
        assert!(parse_csv("100,0\n").is_err());
        assert!(
            parse_json("[{\"timestamp\": 1, \"price\": -1}]").is_err()
        );
    }

    #[test]
    fn empty_files_are_rejected() {
        for text in ["", "\n\n", "timestamp,price\n"] {
            let err = parse_csv(text).unwrap_err().to_string();
            assert!(err.contains("empty trace file"), "{text:?}: {err}");
        }
        assert!(parse_json("[]").unwrap_err().to_string().contains("empty"));
    }

    #[test]
    fn unknown_columns_are_rejected_by_name() {
        let err = parse_csv("timestamp,price,zone\n100,0.5,us\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("zone"), "names the column: {err}");
        let err = parse_csv("time,price\n100,0.5\n").unwrap_err().to_string();
        assert!(err.contains("time"), "names the column: {err}");
        let err = parse_json(
            "[{\"timestamp\": 1, \"price\": 0.5, \"az\": \"a\"}]",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("az"), "names the key: {err}");
    }

    #[test]
    fn resample_quantises_onto_the_revision_grid() {
        let t = parse_csv("0,0.5\n90,0.9\n250,0.7\n").unwrap();
        let r = resample(&t, 100.0).unwrap();
        assert_eq!(r.times, vec![0.0, 100.0, 200.0]);
        // right-open piecewise-constant reads at grid instants
        assert_eq!(r.prices, vec![0.5, 0.9, 0.9]);
        assert!(resample(&t, 0.0).is_err());
        assert!(resample(&t, f64::NAN).is_err());
    }

    #[test]
    fn content_fnv_is_content_not_path() {
        let dir = std::env::temp_dir();
        let a = dir.join("vsgd_tracefile_test_a.csv");
        let b = dir.join("vsgd_tracefile_test_b.csv");
        std::fs::write(&a, "100,0.5\n200,0.6\n").unwrap();
        std::fs::write(&b, "100,0.5\n200,0.6\n").unwrap();
        let ha = content_fnv(a.to_str().unwrap()).unwrap();
        let hb = content_fnv(b.to_str().unwrap()).unwrap();
        assert_eq!(ha, hb, "same bytes, different paths: same identity");
        std::fs::write(&b, "100,0.5\n200,0.7\n").unwrap();
        let hb2 = content_fnv(b.to_str().unwrap()).unwrap();
        assert_ne!(ha, hb2, "edited bytes: different identity");
        let _ = std::fs::remove_file(&a);
        let _ = std::fs::remove_file(&b);
    }
}
