//! Multi-market portfolios: named market entries with their own price
//! process, preemption rate and per-worker speed (DESIGN.md §10).
//!
//! The paper models one spot market with identical workers; the
//! production regime (Parcae, "Speeding up Deep Learning with
//! Transient Servers") is a *portfolio* of instance types / zones that
//! differ in price level, interruption rate and hardware speed. This
//! module holds the market-layer core of that model — entry metadata,
//! validation, effective-price comparison and the migration rule — and
//! stays independent of the simulation layer: price *processes* are
//! attached per entry by `exp::spec` (which builds a `sim::PriceSource`
//! per entry), and the slot loop that consumes all of this lives in
//! `exp::run_portfolio_engine`.
//!
//! The unit everything compares on is **effective price**
//! `price / speed`: dollars per unit of single-market-equivalent work.
//! A 1.6x-speed instance at $0.12 (effective $0.075) beats a 1.0x
//! instance at $0.08.

use anyhow::{ensure, Result};

/// One market in a portfolio: a label (unique within the portfolio), a
/// per-worker speed multiplier applied to the iteration runtime, and a
/// market-level interruption probability `q` drawn once per slot (the
/// whole fleet in this market loses the slot when it fires).
#[derive(Clone, Debug, PartialEq)]
pub struct PortfolioEntry {
    pub label: String,
    /// per-iteration runtime is divided by this (1.0 = paper baseline)
    pub speed: f64,
    /// per-slot market-level interruption probability, in [0, 1)
    pub q: f64,
}

/// A validated, ordered set of [`PortfolioEntry`]s. Order is
/// load-bearing: entry 0 is the "home" market classic single-market
/// strategies are pinned to, and the per-market RNG stream index
/// (DESIGN.md §10) is the entry's position.
#[derive(Clone, Debug, PartialEq)]
pub struct MarketPortfolio {
    pub entries: Vec<PortfolioEntry>,
}

impl MarketPortfolio {
    pub fn new(entries: Vec<PortfolioEntry>) -> Result<Self> {
        let p = MarketPortfolio { entries };
        p.validate()?;
        Ok(p)
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(
            !self.entries.is_empty(),
            "a portfolio needs at least one [[portfolio]] entry"
        );
        for (i, e) in self.entries.iter().enumerate() {
            ensure!(
                !e.label.is_empty(),
                "portfolio entry {i}: empty label"
            );
            ensure!(
                e.speed.is_finite() && e.speed > 0.0,
                "portfolio entry '{}': speed must be finite and > 0, \
                 got {}",
                e.label,
                e.speed
            );
            ensure!(
                e.q.is_finite() && (0.0..1.0).contains(&e.q),
                "portfolio entry '{}': q must be in [0, 1), got {}",
                e.label,
                e.q
            );
            for other in &self.entries[..i] {
                ensure!(
                    other.label != e.label,
                    "duplicate portfolio label '{}'",
                    e.label
                );
            }
        }
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Dollars per unit of single-market-equivalent work for entry `m`
    /// at spot price `price`.
    pub fn effective_price(&self, m: usize, price: f64) -> f64 {
        price / self.entries[m].speed
    }

    /// The cheapest *available* entry by effective price; ties break to
    /// the lowest index (deterministic, so digests are stable when two
    /// entries quote the same effective price). `None` when every
    /// market is interrupting this slot.
    pub fn best_entry(
        &self,
        prices: &[f64],
        available: &[bool],
    ) -> Option<usize> {
        debug_assert_eq!(prices.len(), self.entries.len());
        debug_assert_eq!(available.len(), self.entries.len());
        let mut best: Option<(usize, f64)> = None;
        for m in 0..self.entries.len() {
            if !available[m] {
                continue;
            }
            let eff = self.effective_price(m, prices[m]);
            if best.is_none_or(|(_, b)| eff < b) {
                best = Some((m, eff));
            }
        }
        best.map(|(m, _)| m)
    }
}

/// The `portfolio_migrate` placement rule: follow the cheapest
/// effective price, with hysteresis so the fleet does not thrash
/// between near-equal markets (each migration is billed as a
/// checkpoint + restart via `[overhead]`, so thrash is pure loss).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MigrationRule {
    /// migrate only when the best entry's effective price undercuts
    /// the current one by more than this fraction, in [0, 1)
    pub hysteresis: f64,
}

impl MigrationRule {
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.hysteresis.is_finite()
                && (0.0..1.0).contains(&self.hysteresis),
            "portfolio_migrate hysteresis must be in [0, 1), got {}",
            self.hysteresis
        );
        Ok(())
    }

    /// Where the fleet should move this slot, if anywhere. `current`'s
    /// own availability matters: an interrupting home market forces a
    /// move to the best available entry regardless of hysteresis.
    pub fn target(
        &self,
        port: &MarketPortfolio,
        current: usize,
        prices: &[f64],
        available: &[bool],
    ) -> Option<usize> {
        let best = port.best_entry(prices, available)?;
        if best == current {
            return None;
        }
        if !available[current] {
            return Some(best);
        }
        let cur_eff = port.effective_price(current, prices[current]);
        let best_eff = port.effective_price(best, prices[best]);
        (best_eff < cur_eff * (1.0 - self.hysteresis)).then_some(best)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn port() -> MarketPortfolio {
        MarketPortfolio::new(vec![
            PortfolioEntry { label: "cheap".into(), speed: 1.0, q: 0.1 },
            PortfolioEntry { label: "fast".into(), speed: 2.0, q: 0.05 },
        ])
        .unwrap()
    }

    #[test]
    fn validation_rejects_bad_entries() {
        assert!(MarketPortfolio::new(vec![]).is_err());
        let dup = MarketPortfolio::new(vec![
            PortfolioEntry { label: "a".into(), speed: 1.0, q: 0.0 },
            PortfolioEntry { label: "a".into(), speed: 1.5, q: 0.0 },
        ]);
        assert!(dup.unwrap_err().to_string().contains("duplicate"));
        for (speed, q) in
            [(0.0, 0.0), (-1.0, 0.0), (f64::NAN, 0.0), (1.0, 1.0), (1.0, -0.1)]
        {
            let e = PortfolioEntry { label: "a".into(), speed, q };
            assert!(
                MarketPortfolio::new(vec![e]).is_err(),
                "speed={speed} q={q} must be rejected"
            );
        }
    }

    #[test]
    fn best_entry_compares_effective_price_with_index_tiebreak() {
        let p = port();
        // fast at 0.15 is effectively 0.075 < cheap's 0.08
        assert_eq!(p.best_entry(&[0.08, 0.15], &[true, true]), Some(1));
        // exact effective tie (0.08 vs 0.16/2): lowest index wins
        assert_eq!(p.best_entry(&[0.08, 0.16], &[true, true]), Some(0));
        // availability masks entries out
        assert_eq!(p.best_entry(&[0.08, 0.15], &[true, false]), Some(0));
        assert_eq!(p.best_entry(&[0.08, 0.15], &[false, false]), None);
    }

    #[test]
    fn migration_rule_applies_hysteresis() {
        let p = port();
        let rule = MigrationRule { hysteresis: 0.1 };
        rule.validate().unwrap();
        // best (fast: eff 0.075) does not undercut cheap's 0.08 by 10%
        assert_eq!(rule.target(&p, 0, &[0.08, 0.15], &[true, true]), None);
        // eff 0.06 < 0.08 * 0.9: migrate
        assert_eq!(
            rule.target(&p, 0, &[0.08, 0.12], &[true, true]),
            Some(1)
        );
        // already on the best entry: stay
        assert_eq!(rule.target(&p, 1, &[0.08, 0.12], &[true, true]), None);
        // an interrupting current market forces the move
        assert_eq!(
            rule.target(&p, 0, &[0.08, 0.15], &[false, true]),
            Some(1)
        );
        // ... unless nowhere is available
        assert_eq!(rule.target(&p, 0, &[0.08, 0.15], &[false, false]), None);
        assert!(MigrationRule { hysteresis: 1.0 }.validate().is_err());
        assert!(MigrationRule { hysteresis: -0.1 }.validate().is_err());
    }
}
