//! Time-stamped spot price traces: AWS-format parsing, replay, and a
//! regime-switching synthetic generator.
//!
//! The paper's Fig. 4 replays historical c5.xlarge prices from
//! `DescribeSpotPriceHistory`. Real AWS history cannot be downloaded in
//! this offline build, so [`SpotTrace::generate`] synthesises a trace with
//! the documented qualitative features of 2019-era spot prices: a slowly
//! wandering base level, discrete price revisions (at most ~hourly — the
//! paper leans on "the spot price changes at most once per hour"), regime
//! shifts between calm and contended periods, and occasional demand spikes
//! toward the on-demand cap. The substitution is recorded in DESIGN.md §2.

use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::csv::parse_numeric_csv;
use crate::util::rng::Rng;

use super::cdf::EmpiricalCdf;

/// A piecewise-constant price path: price is `prices[i]` on
/// `[times[i], times[i+1])`; the last price extends to infinity.
#[derive(Clone, Debug)]
pub struct SpotTrace {
    /// revision timestamps in seconds, strictly increasing, starts at 0
    pub times: Vec<f64>,
    pub prices: Vec<f64>,
}

/// Parameters for the synthetic regime-switching generator.
#[derive(Clone, Debug)]
pub struct TraceGenConfig {
    /// total trace length in seconds
    pub horizon: f64,
    /// mean seconds between price revisions (<= 3600 per AWS discipline)
    pub revision_interval: f64,
    /// price floor (AWS never goes to 0)
    pub floor: f64,
    /// on-demand cap
    pub cap: f64,
    /// base (calm-regime) mean price
    pub base: f64,
    /// per-revision probability of switching calm <-> contended
    pub regime_switch_prob: f64,
    /// contended-regime price multiplier
    pub contended_mult: f64,
    /// per-revision probability of a spike to near the cap
    pub spike_prob: f64,
    /// OU-style mean reversion strength in [0,1]
    pub reversion: f64,
    /// per-revision relative noise std
    pub noise: f64,
}

impl Default for TraceGenConfig {
    fn default() -> Self {
        TraceGenConfig {
            horizon: 7.0 * 24.0 * 3600.0,
            revision_interval: 1800.0,
            floor: 0.068, // c5.xlarge-ish spot floor ($/h)
            cap: 0.17,    // c5.xlarge on-demand ($/h)
            base: 0.085,
            regime_switch_prob: 0.02,
            contended_mult: 1.45,
            spike_prob: 0.004,
            reversion: 0.15,
            noise: 0.035,
        }
    }
}

impl SpotTrace {
    pub fn new(times: Vec<f64>, prices: Vec<f64>) -> Result<Self> {
        if times.len() != prices.len() || times.is_empty() {
            bail!(
                "trace needs equal, non-zero times/prices lengths \
                 (got {} / {})",
                times.len(),
                prices.len()
            );
        }
        if !times.windows(2).all(|w| w[0] < w[1]) {
            bail!("trace timestamps must be strictly increasing");
        }
        if prices.iter().any(|p| !p.is_finite() || *p <= 0.0) {
            bail!("trace prices must be finite and positive");
        }
        Ok(SpotTrace { times, prices })
    }

    /// Parse a CSV with columns `timestamp,price` (header optional,
    /// `#` comments allowed) — the shape of `aws ec2
    /// describe-spot-price-history` output piped through a one-line jq.
    /// Timestamps are normalised so the trace starts at t=0.
    pub fn parse_csv(text: &str) -> Result<Self> {
        let (_, rows) = parse_numeric_csv(text);
        if rows.is_empty() {
            bail!("no data rows in trace CSV");
        }
        let mut pairs: Vec<(f64, f64)> = rows
            .iter()
            .map(|r| {
                if r.len() < 2 {
                    bail!("trace row needs >= 2 fields, got {}", r.len())
                } else {
                    Ok((r[0], r[1]))
                }
            })
            .collect::<Result<_>>()?;
        pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        pairs.dedup_by(|a, b| a.0 == b.0);
        let t0 = pairs[0].0;
        let times: Vec<f64> = pairs.iter().map(|(t, _)| t - t0).collect();
        let prices: Vec<f64> = pairs.iter().map(|(_, p)| *p).collect();
        Self::new(times, prices)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = fs::read_to_string(&path).with_context(|| {
            format!("reading trace {}", path.as_ref().display())
        })?;
        Self::parse_csv(&text)
    }

    /// Price in effect at time `t` (clamped to the trace ends).
    pub fn price_at(&self, t: f64) -> f64 {
        if t <= self.times[0] {
            return self.prices[0];
        }
        let i = self.times.partition_point(|&x| x <= t);
        self.prices[i - 1]
    }

    pub fn horizon(&self) -> f64 {
        *self.times.last().unwrap()
    }

    /// Empirical distribution of prices *weighted by holding time* — the
    /// right estimate of F for a piecewise-constant path (a price held for
    /// an hour counts 60x one held for a minute). `resolution` is the
    /// sampling step in seconds.
    pub fn empirical_cdf(&self, resolution: f64) -> EmpiricalCdf {
        assert!(resolution > 0.0);
        let mut samples = Vec::new();
        let mut t = 0.0;
        let end = self.horizon().max(resolution);
        while t <= end {
            samples.push(self.price_at(t));
            t += resolution;
        }
        EmpiricalCdf::new(samples)
    }

    /// Synthetic regime-switching generator (see module docs).
    pub fn generate(cfg: &TraceGenConfig, rng: &mut Rng) -> Self {
        let mut times = vec![0.0];
        let mut prices = Vec::new();
        let mut level = cfg.base;
        let mut contended = false;
        let mut t = 0.0;
        loop {
            if rng.bool(cfg.regime_switch_prob) {
                contended = !contended;
            }
            let target = if contended {
                cfg.base * cfg.contended_mult
            } else {
                cfg.base
            };
            // mean-reverting multiplicative walk
            level += cfg.reversion * (target - level);
            level *= 1.0 + cfg.noise * rng.gaussian();
            let mut p = level.clamp(cfg.floor, cfg.cap);
            if rng.bool(cfg.spike_prob) {
                p = cfg.cap * rng.uniform(0.92, 1.0);
            }
            prices.push(p);
            // next revision (exponential gaps, mean revision_interval)
            t += rng.exponential(1.0 / cfg.revision_interval);
            if t >= cfg.horizon {
                break;
            }
            times.push(t);
        }
        SpotTrace { times, prices }
    }

    /// Serialise to the same CSV shape `parse_csv` accepts.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("timestamp,price\n");
        for (t, p) in self.times.iter().zip(&self.prices) {
            out.push_str(&format!("{t},{p}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SpotTrace {
        SpotTrace::new(vec![0.0, 10.0, 20.0], vec![0.5, 0.7, 0.4]).unwrap()
    }

    #[test]
    fn price_at_is_piecewise_constant_right_open() {
        let tr = small();
        assert_eq!(tr.price_at(-1.0), 0.5);
        assert_eq!(tr.price_at(0.0), 0.5);
        assert_eq!(tr.price_at(9.999), 0.5);
        assert_eq!(tr.price_at(10.0), 0.7);
        assert_eq!(tr.price_at(19.0), 0.7);
        assert_eq!(tr.price_at(25.0), 0.4);
    }

    #[test]
    fn csv_roundtrip_normalises_t0() {
        let tr = SpotTrace::parse_csv("timestamp,price\n100,0.5\n110,0.7\n")
            .unwrap();
        assert_eq!(tr.times, vec![0.0, 10.0]);
        assert_eq!(tr.prices, vec![0.5, 0.7]);
        let again = SpotTrace::parse_csv(&tr.to_csv()).unwrap();
        assert_eq!(again.times, tr.times);
    }

    #[test]
    fn rejects_bad_traces() {
        assert!(SpotTrace::new(vec![], vec![]).is_err());
        assert!(SpotTrace::new(vec![0.0, 0.0], vec![1.0, 1.0]).is_err());
        assert!(SpotTrace::new(vec![0.0, 1.0], vec![1.0, -1.0]).is_err());
        assert!(SpotTrace::parse_csv("# nothing\n").is_err());
    }

    #[test]
    fn generator_respects_bounds_and_horizon() {
        let cfg = TraceGenConfig::default();
        let mut rng = Rng::new(42);
        let tr = SpotTrace::generate(&cfg, &mut rng);
        assert!(tr.times.len() > 100);
        assert!(tr.horizon() < cfg.horizon);
        for &p in &tr.prices {
            assert!(p >= cfg.floor - 1e-12 && p <= cfg.cap + 1e-12);
        }
        // mean revision gap should be near the configured interval
        let gaps: Vec<f64> =
            tr.times.windows(2).map(|w| w[1] - w[0]).collect();
        let mean_gap = gaps.iter().sum::<f64>() / gaps.len() as f64;
        assert!(
            (mean_gap - cfg.revision_interval).abs()
                < 0.15 * cfg.revision_interval,
            "mean gap {mean_gap}"
        );
    }

    #[test]
    fn generator_invariants_across_seeds() {
        // the invariants every consumer (replay, CDF estimation, CSV
        // round-trip) relies on, checked across many seeds and two
        // revision disciplines
        for seed in 0..20u64 {
            for cfg in [
                TraceGenConfig::default(),
                TraceGenConfig {
                    revision_interval: 3600.0, // the paper's "<= 1/hour"
                    ..TraceGenConfig::default()
                },
            ] {
                let mut rng = Rng::new(seed);
                let tr = SpotTrace::generate(&cfg, &mut rng);
                // times strictly increasing, starting at exactly 0
                assert_eq!(tr.times[0], 0.0, "seed {seed}");
                assert!(
                    tr.times.windows(2).all(|w| w[0] < w[1]),
                    "seed {seed}: times not strictly increasing"
                );
                assert_eq!(tr.times.len(), tr.prices.len());
                // prices within [floor, cap] (finite, positive implied)
                for &p in &tr.prices {
                    assert!(
                        p >= cfg.floor - 1e-12 && p <= cfg.cap + 1e-12,
                        "seed {seed}: price {p} outside [{}, {}]",
                        cfg.floor,
                        cfg.cap
                    );
                }
                // the whole path fits the horizon
                assert!(tr.horizon() < cfg.horizon, "seed {seed}");
                // revision discipline: mean gap tracks the configured
                // interval (exponential gaps, so individual gaps vary)
                let gaps: Vec<f64> =
                    tr.times.windows(2).map(|w| w[1] - w[0]).collect();
                let mean_gap =
                    gaps.iter().sum::<f64>() / gaps.len().max(1) as f64;
                assert!(gaps.len() > 50, "seed {seed}: degenerate trace");
                assert!(
                    (mean_gap - cfg.revision_interval).abs()
                        < 0.4 * cfg.revision_interval,
                    "seed {seed}: mean gap {mean_gap} vs {}",
                    cfg.revision_interval
                );
                // and the generated trace passes its own validator
                SpotTrace::new(tr.times.clone(), tr.prices.clone())
                    .expect("generated trace must validate");
            }
        }
    }

    #[test]
    fn generator_is_byte_identical_for_fixed_seed() {
        let cfg = TraceGenConfig::default();
        let a = SpotTrace::generate(&cfg, &mut Rng::new(2020));
        let b = SpotTrace::generate(&cfg, &mut Rng::new(2020));
        // exact f64 bit patterns, not approximate equality
        assert_eq!(a.times.len(), b.times.len());
        for (x, y) in a.times.iter().zip(&b.times) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in a.prices.iter().zip(&b.prices) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // and the serialised form (what sweeps cache and CSVs record)
        assert_eq!(a.to_csv(), b.to_csv());
        // stream-derived seeding is order-independent too
        let c = SpotTrace::generate(&cfg, &mut Rng::stream(99, 5));
        let d = SpotTrace::generate(&cfg, &mut Rng::stream(99, 5));
        assert_eq!(c.to_csv(), d.to_csv());
        assert_ne!(a.to_csv(), c.to_csv());
    }

    #[test]
    fn generator_visits_both_regimes() {
        let cfg = TraceGenConfig::default();
        let mut rng = Rng::new(7);
        let tr = SpotTrace::generate(&cfg, &mut rng);
        let lo_frac = tr
            .prices
            .iter()
            .filter(|&&p| p < cfg.base * 1.1)
            .count() as f64
            / tr.prices.len() as f64;
        assert!(lo_frac > 0.2 && lo_frac < 0.98, "lo_frac={lo_frac}");
    }

    #[test]
    fn empirical_cdf_weights_by_time() {
        // price 1.0 held 90s, price 2.0 held 10s -> F(1.5) ~ 0.9
        let tr =
            SpotTrace::new(vec![0.0, 90.0], vec![1.0, 2.0]).unwrap();
        // horizon is 90 (last revision); sample to 90s inclusive
        let cdf = tr.empirical_cdf(1.0);
        let f = cdf.cdf(1.5);
        assert!(f > 0.85 && f <= 1.0, "F(1.5)={f}");
    }
}
