//! Bid vectors and persistent-request admission semantics.
//!
//! Amazon's policy (Sec. IV): a bid is fixed at submission for the job's
//! lifetime; with *persistent* requests a worker resumes automatically
//! whenever the spot price falls back below its bid and exits when the job
//! completes. A worker is active iff `bid >= price`, and while active it
//! pays the prevailing *spot price*, not its bid.

/// One worker's standing bid.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WorkerBid {
    pub bid: f64,
}

/// The job's bid vector: `n1` workers at `b1` and `n - n1` at `b2 <= b1`
/// (the paper's two-group strategy; `n1 == n` degenerates to one bid).
#[derive(Clone, Debug)]
pub struct BidVector {
    bids: Vec<WorkerBid>,
    pub b1: f64,
    pub b2: f64,
    pub n1: usize,
}

impl BidVector {
    /// Uniform bid for all `n` workers.
    pub fn uniform(n: usize, b: f64) -> Self {
        assert!(n > 0);
        BidVector {
            bids: vec![WorkerBid { bid: b }; n],
            b1: b,
            b2: b,
            n1: n,
        }
    }

    /// Two-group bids: workers 0..n1 bid `b1`, workers n1..n bid `b2`.
    pub fn two_group(n: usize, n1: usize, b1: f64, b2: f64) -> Self {
        assert!(n > 0 && n1 > 0 && n1 <= n, "need 0 < n1 <= n");
        assert!(
            b2 <= b1,
            "second-group bid must not exceed first-group ({b2} > {b1})"
        );
        let mut bids = vec![WorkerBid { bid: b1 }; n1];
        bids.extend(vec![WorkerBid { bid: b2 }; n - n1]);
        BidVector { bids, b1, b2, n1 }
    }

    pub fn n(&self) -> usize {
        self.bids.len()
    }

    pub fn bids(&self) -> &[WorkerBid] {
        &self.bids
    }

    /// Indices of workers active at spot price `p` (persistent requests:
    /// activity is memoryless in the current price).
    pub fn active_set(&self, price: f64) -> Vec<usize> {
        (0..self.bids.len())
            .filter(|&i| self.bids[i].bid >= price)
            .collect()
    }

    /// [`BidVector::active_set`] into a caller-owned buffer (cleared
    /// first) — the allocation-free form the batched replicate executor
    /// uses on its per-slot hot path. Consumes no RNG, fills `out` with
    /// exactly the indices `active_set` would return.
    pub fn active_set_into(&self, price: f64, out: &mut Vec<usize>) {
        out.clear();
        out.extend(
            (0..self.bids.len()).filter(|&i| self.bids[i].bid >= price),
        );
    }

    /// Number of active workers at price `p` (paper's y(b) for this p).
    pub fn active_count(&self, price: f64) -> usize {
        self.bids.iter().filter(|b| b.bid >= price).count()
    }

    /// Per-time-unit cost when the spot price is `p`: active workers each
    /// pay the spot price.
    pub fn cost_rate(&self, price: f64) -> f64 {
        self.active_count(price) as f64 * price
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{for_all, Gen};

    #[test]
    fn uniform_all_or_nothing() {
        let v = BidVector::uniform(4, 0.5);
        assert_eq!(v.active_count(0.4), 4);
        assert_eq!(v.active_count(0.5), 4);
        assert_eq!(v.active_count(0.51), 0);
    }

    #[test]
    fn active_set_into_matches_active_set_and_clears_stale_contents() {
        for_all("active_set_into == active_set", |g: &mut Gen| {
            let n = g.u64_in(1, 16) as usize;
            let n1 = g.u64_in(1, n as u64) as usize;
            let b2 = g.f64_in(0.0, 1.0);
            let b1 = g.f64_in(b2, 1.0);
            let v = BidVector::two_group(n, n1, b1, b2);
            let p = g.f64_in(0.0, 1.3);
            let mut out = vec![usize::MAX; 5]; // stale junk must vanish
            v.active_set_into(p, &mut out);
            if out == v.active_set(p) {
                Ok(())
            } else {
                Err(format!("into={out:?} != {:?}", v.active_set(p)))
            }
        });
    }

    #[test]
    fn two_group_thresholds() {
        let v = BidVector::two_group(8, 3, 0.8, 0.4);
        assert_eq!(v.active_count(0.3), 8);
        assert_eq!(v.active_count(0.4), 8);
        assert_eq!(v.active_count(0.5), 3);
        assert_eq!(v.active_count(0.8), 3);
        assert_eq!(v.active_count(0.9), 0);
        assert_eq!(v.active_set(0.5), vec![0, 1, 2]);
    }

    #[test]
    fn cost_rate_is_count_times_price() {
        let v = BidVector::two_group(4, 2, 1.0, 0.5);
        assert_eq!(v.cost_rate(0.6), 2.0 * 0.6);
        assert_eq!(v.cost_rate(0.2), 4.0 * 0.2);
    }

    #[test]
    #[should_panic]
    fn rejects_b2_above_b1() {
        BidVector::two_group(4, 2, 0.4, 0.8);
    }

    #[test]
    fn prop_active_count_monotone_in_price() {
        for_all("active_count anti-monotone in price", |g: &mut Gen| {
            let n = g.u64_in(1, 16) as usize;
            let n1 = g.u64_in(1, n as u64) as usize;
            let b2 = g.f64_in(0.0, 1.0);
            let b1 = g.f64_in(b2, 1.0);
            let v = BidVector::two_group(n, n1, b1, b2);
            let p = g.f64_in(0.0, 1.2);
            let q = g.f64_in(p, 1.3);
            if v.active_count(p) >= v.active_count(q) {
                Ok(())
            } else {
                Err(format!(
                    "count({p})={} < count({q})={}",
                    v.active_count(p),
                    v.active_count(q)
                ))
            }
        });
    }

    #[test]
    fn prop_active_count_in_set_sizes() {
        // y(b) in {0, n1, n} only — the paper's three-level structure
        for_all("two-group y in {0,n1,n}", |g: &mut Gen| {
            let n = g.u64_in(2, 16) as usize;
            let n1 = g.u64_in(1, n as u64 - 1) as usize;
            let b2 = g.f64_in(0.1, 0.5);
            let b1 = g.f64_in(b2 + 0.01, 1.0);
            let v = BidVector::two_group(n, n1, b1, b2);
            let p = g.f64_in(0.0, 1.2);
            let y = v.active_count(p);
            if y == 0 || y == n1 || y == n {
                Ok(())
            } else {
                Err(format!("y={y} not in {{0,{n1},{n}}}"))
            }
        });
    }
}
