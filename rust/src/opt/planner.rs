//! The two-stage deterministic planner.
//!
//! **Stage 0 — lattice folding.** The candidate lattice is the spec's
//! (market × grid × strategy) point space. An axis scoped to one
//! strategy label (`strategy.<label>.*`) leaves every *other* entry's
//! configuration untouched, so the raw cross product contains exact
//! duplicates; each duplicate folds into the first point with the same
//! fingerprint (market, strategy, and the values of the axes that
//! actually reach it).
//!
//! **Stage 1 — analytic pruning.** Every unique candidate is planned
//! (`SpecScenario::prepare`; a plan that is infeasible in closed form —
//! eps below the fleet's noise floor, a Theorem-2 deadline that cannot
//! be met — is recorded and dropped). Candidates with an *admissible*
//! closed-form surface ([`super::surface`]) are then checked against
//! the `[objective]` hard constraints and against each other: a
//! candidate weakly dominated by a surviving admissible candidate is
//! provably not the optimum of any monotone objective and not on the
//! Pareto frontier, so it is discarded before a single replicate runs.
//! Heuristic candidates (adaptive policies, trace markets, overhead
//! models) are never pruned analytically.
//!
//! **Stage 2 — refinement by simulation.** Survivors run through the
//! existing sweep pool and event engine on a fixed successive-halving
//! ladder: rung k simulates every live candidate with `ladder[k]`
//! replicates, then keeps the best `keep_fraction` (never below
//! `min_keep`) by (feasible, objective score, candidate order).
//! Because the ladder is fixed and every upstream decision is a pure
//! function of collated results, the replicate RNG streams — derived
//! per rung from [`rung_seed`] — are pure functions of (seed, rung,
//! candidate order): the whole plan is digest-identical at any thread
//! count (DESIGN.md §3/§7).
//!
//! The outcome carries every lattice point's fate, the ranked
//! recommendations, the incumbent, and the Pareto frontier over the
//! simulated (cost, time, error) means at each candidate's deepest
//! rung.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::exp::spec::{PrepareCache, SpecCtx};
use crate::exp::SpecScenario;
use crate::obs::Registry;
use crate::sweep::{
    run_indexed, run_sweep, Scenario, SweepConfig, SweepResults,
};
use crate::util::fnv::Fnv;
use crate::util::rng::Rng;

use super::spec::{Objective, PlanSpec, SearchSpec};
use super::surface::{admissible_surface, beats, Surface};

/// The planner's internal refinement metrics, in column order.
pub const SIM_METRICS: [&str; 4] =
    ["total_cost", "total_time", "final_error", "iters"];

/// How the planner runs: master seed and worker threads. Both are pure
/// throughput/reproducibility knobs — the recommendation set is a
/// function of (spec, seed) only.
#[derive(Clone, Copy, Debug)]
pub struct PlannerConfig {
    pub seed: u64,
    pub threads: usize,
}

/// Why a lattice point never reached (or left) the simulation stage.
#[derive(Clone, Debug, PartialEq)]
pub enum Fate {
    /// exact duplicate of an earlier lattice point (candidate index)
    Folded { into: usize },
    /// the closed-form plan itself is infeasible (e.g. eps below the
    /// fleet's noise floor, deadline-infeasible bid problem)
    PlanError { error: String },
    /// admissible closed-form surface violates a hard constraint
    Infeasible { violated: String },
    /// admissible closed-form surface weakly dominated by the
    /// surviving candidate at this index
    Dominated { by: usize },
    /// reached the simulation ladder; `rung` is the deepest rung run
    Evaluated { rung: usize },
}

impl Fate {
    /// Short machine-readable tag for tables/JSON.
    pub fn tag(&self) -> &'static str {
        match self {
            Fate::Folded { .. } => "folded",
            Fate::PlanError { .. } => "plan_error",
            Fate::Infeasible { .. } => "infeasible",
            Fate::Dominated { .. } => "dominated",
            Fate::Evaluated { .. } => "evaluated",
        }
    }
}

/// Simulated summary statistics for one candidate at its deepest rung.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimStats {
    pub replicates: u64,
    pub cost_mean: f64,
    pub cost_std: f64,
    pub time_mean: f64,
    pub time_std: f64,
    pub err_mean: f64,
    pub err_std: f64,
    pub iters_mean: f64,
}

/// One lattice point, its closed-form surface (when admissible), and
/// everything the planner decided about it.
#[derive(Clone, Debug)]
pub struct Candidate {
    /// index into the base scenario's point space
    pub point: usize,
    /// the scenario's point label (market/grid/strategy parts)
    pub label: String,
    /// lineup entry label
    pub strategy: String,
    /// closed-form (cost, time, err) when admissible (DESIGN.md §7)
    pub surface: Option<Surface>,
    pub fate: Fate,
    /// simulated stats at the deepest rung this candidate ran
    pub sim: Option<SimStats>,
    /// 1-based final ranking among evaluated candidates
    pub rank: Option<usize>,
    /// satisfied every declared constraint on its simulated means
    pub feasible: bool,
    /// on the simulated Pareto frontier over (cost, time, err)
    pub frontier: bool,
}

/// One successive-halving rung as it actually ran — enough to replay
/// it exactly (`evaluate_rung` with these members/replicates/seed
/// reproduces the recorded statistics bit for bit).
#[derive(Clone, Debug)]
pub struct RungRecord {
    pub replicates: u64,
    pub seed: u64,
    /// candidate indices (into [`PlanOutcome::candidates`]) simulated
    pub members: Vec<usize>,
}

/// Tally of candidate fates.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FateCounts {
    pub folded: usize,
    pub plan_errors: usize,
    pub infeasible: usize,
    pub dominated: usize,
    pub evaluated: usize,
}

/// The planner's full product: every candidate's fate, the ranked
/// recommendation list, the incumbent and the Pareto frontier.
#[derive(Clone, Debug)]
pub struct PlanOutcome {
    pub name: String,
    pub objective: Objective,
    pub search: SearchSpec,
    pub seed: u64,
    /// raw lattice size before folding
    pub lattice_points: usize,
    pub candidates: Vec<Candidate>,
    /// candidate indices ranked best-first (feasible first, then
    /// deeper-rung evidence, then objective score, then candidate
    /// order)
    pub recommendations: Vec<usize>,
    /// best feasible recommendation, when any candidate is feasible
    pub incumbent: Option<usize>,
    pub rungs: Vec<RungRecord>,
}

impl PlanOutcome {
    pub fn incumbent_label(&self) -> Option<&str> {
        self.incumbent.map(|i| self.candidates[i].label.as_str())
    }

    /// Frontier labels in candidate order.
    pub fn frontier_labels(&self) -> Vec<&str> {
        self.candidates
            .iter()
            .filter(|c| c.frontier)
            .map(|c| c.label.as_str())
            .collect()
    }

    pub fn counts(&self) -> FateCounts {
        let mut c = FateCounts::default();
        for cand in &self.candidates {
            match cand.fate {
                Fate::Folded { .. } => c.folded += 1,
                Fate::PlanError { .. } => c.plan_errors += 1,
                Fate::Infeasible { .. } => c.infeasible += 1,
                Fate::Dominated { .. } => c.dominated += 1,
                Fate::Evaluated { .. } => c.evaluated += 1,
            }
        }
        c
    }

    /// FNV-1a digest over every decision and statistic the planner
    /// produced — the single line the CI determinism smoke diffs
    /// across thread counts (same algorithm as the sweep digest).
    pub fn digest(&self) -> u64 {
        let mut h = Fnv::new();
        h.bytes(self.name.as_bytes());
        h.u64(self.seed);
        h.u64(self.lattice_points as u64);
        for c in &self.candidates {
            h.bytes(c.label.as_bytes());
            h.bytes(c.strategy.as_bytes());
            match &c.fate {
                Fate::Folded { into } => {
                    h.u64(1);
                    h.u64(*into as u64);
                }
                Fate::PlanError { error } => {
                    h.u64(2);
                    h.bytes(error.as_bytes());
                }
                Fate::Infeasible { violated } => {
                    h.u64(3);
                    h.bytes(violated.as_bytes());
                }
                Fate::Dominated { by } => {
                    h.u64(4);
                    h.u64(*by as u64);
                }
                Fate::Evaluated { rung } => {
                    h.u64(5);
                    h.u64(*rung as u64);
                }
            }
            if let Some(s) = c.surface {
                h.f64(s.cost);
                h.f64(s.time);
                h.f64(s.err);
            }
            if let Some(s) = c.sim {
                h.u64(s.replicates);
                h.f64(s.cost_mean);
                h.f64(s.cost_std);
                h.f64(s.time_mean);
                h.f64(s.time_std);
                h.f64(s.err_mean);
                h.f64(s.err_std);
                h.f64(s.iters_mean);
            }
            h.u64(c.rank.map(|r| r as u64).unwrap_or(0));
            h.u64(c.feasible as u64);
            h.u64(c.frontier as u64);
        }
        for r in &self.rungs {
            h.u64(r.replicates);
            h.u64(r.seed);
            for &m in &r.members {
                h.u64(m as u64);
            }
        }
        h.u64(self.incumbent.map(|i| i as u64 + 1).unwrap_or(0));
        h.finish()
    }
}

/// Build the runnable candidate-lattice scenario for a plan: the
/// spec's scenario with the planner's internal metric set, validated
/// to `--check` grade (every lattice point resolves).
pub fn build_scenario(plan: &PlanSpec) -> Result<SpecScenario> {
    let mut spec = plan.scenario.clone();
    spec.metrics = SIM_METRICS.iter().map(|s| s.to_string()).collect();
    SpecScenario::new(spec)
}

/// Per-rung replicate seed: a SplitMix64-style mix of the master seed
/// and the rung index, so rungs draw independent streams while staying
/// pure functions of (seed, rung).
pub fn rung_seed(seed: u64, rung: usize) -> u64 {
    let mut z = seed
        ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(rung as u64 + 1);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The refinement-stage scenario: a subset of the base scenario's
/// points, each replicate executing the point's plan on the event
/// engine via the one shared [`SpecCtx::execute_engine`] path. The
/// planner passes the contexts it already prepared in stage 1 (`ctxs`)
/// so the expensive bid-plan solves and `E[1/y]` tables are built once
/// per candidate, not once per rung — `prepare` consumes no replicate
/// RNG, so a cached context and a fresh one are interchangeable bit
/// for bit (which is why the public [`evaluate_rung`] replay path can
/// prepare fresh and still reproduce recorded statistics exactly).
struct CandidateScenario<'a> {
    base: &'a SpecScenario,
    points: Vec<usize>,
    ctxs: Option<Vec<Arc<SpecCtx>>>,
}

impl Scenario for CandidateScenario<'_> {
    type Ctx = Arc<SpecCtx>;

    fn points(&self) -> usize {
        self.points.len()
    }

    fn label(&self, i: usize) -> String {
        self.base.label(self.points[i])
    }

    fn metrics(&self) -> Vec<String> {
        SIM_METRICS.iter().map(|s| s.to_string()).collect()
    }

    fn prepare(&self, i: usize) -> Result<Arc<SpecCtx>> {
        match &self.ctxs {
            Some(ctxs) => Ok(ctxs[i].clone()),
            None => self.base.prepare(self.points[i]).map(Arc::new),
        }
    }

    fn run(
        &self,
        _i: usize,
        ctx: &Arc<SpecCtx>,
        rng: &mut Rng,
    ) -> Result<Vec<f64>> {
        let r = ctx.execute_point(0, rng)?;
        Ok(vec![r.cost, r.elapsed, r.final_error, r.iters as f64])
    }
}

/// Run one refinement rung: simulate the given base-scenario points on
/// the sweep pool with `replicates` replicates at `seed`. Public so
/// the integration suite can re-verify a recommendation with exactly
/// the planner's streams: replaying a [`RungRecord`]'s members through
/// this function reproduces the recorded statistics bit for bit.
pub fn evaluate_rung(
    scenario: &SpecScenario,
    points: &[usize],
    replicates: u64,
    seed: u64,
    threads: usize,
) -> Result<SweepResults> {
    let cs = CandidateScenario {
        base: scenario,
        points: points.to_vec(),
        ctxs: None,
    };
    run_sweep(&cs, &SweepConfig { replicates, seed, threads })
}

/// A candidate's configuration fingerprint: market, strategy, and the
/// values of exactly the axes that reach its resolved configuration —
/// global axes (`job.*`, `runtime.*`, `market.*`, `sgd.*`,
/// `overhead.*`) reach everyone; `strategy.<label>.*` axes reach only
/// that entry. Values are keyed by bit pattern, so folding is exact.
fn fingerprint(sc: &SpecScenario, point: usize) -> String {
    let (m, g, s) = sc.decode(point);
    let spec = sc.spec();
    let label = &spec.strategies[s].label;
    let vals = sc.grid().point(g);
    let mut key = format!("m{m}/s{s}");
    for (axis, v) in spec.axes.iter().zip(&vals) {
        let reaches = match axis.path.strip_prefix("strategy.") {
            Some(rest) => rest
                .split_once('.')
                .map(|(l, _)| l == label)
                .unwrap_or(true),
            None => true,
        };
        if reaches {
            key.push_str(&format!("/{}={:016x}", axis.name, v.to_bits()));
        }
    }
    key
}

/// Run the full two-stage plan. Deterministic: the outcome (and its
/// digest) is a pure function of (spec, seed) at any thread count.
pub fn run_plan(plan: &PlanSpec, cfg: &PlannerConfig) -> Result<PlanOutcome> {
    run_plan_cached(plan, cfg, &PrepareCache::new())
}

/// [`run_plan`] with the stage-1 plan solves routed through a shared
/// tier-B [`PrepareCache`]: the serve daemon (`crate::serve`) passes
/// its process-wide cache so repeated or overlapping submissions solve
/// only their novel lattice points. Digest-identical to a fresh
/// [`run_plan`] at any thread count — prepare is pure per point
/// (DESIGN.md §3), so a shared cache changes *when* an artifact is
/// built, never what it contains.
pub fn run_plan_cached(
    plan: &PlanSpec,
    cfg: &PlannerConfig,
    cache: &PrepareCache,
) -> Result<PlanOutcome> {
    run_plan_instrumented(plan, cfg, cache, None)
}

/// [`run_plan_cached`] with per-stage wall-clock accounting into an
/// [`obs::Registry`](crate::obs::Registry): counters
/// `planner_stage0_us` (lattice folding), `planner_stage1_us` (plan
/// solves + analytic pruning) and `planner_stage2_us` (the refinement
/// ladder) accumulate microseconds across calls (DESIGN.md §12). Pure
/// telemetry: wall-clock never reaches the outcome or its digest, so
/// the instrumented and plain paths are bit-identical.
pub fn run_plan_instrumented(
    plan: &PlanSpec,
    cfg: &PlannerConfig,
    cache: &PrepareCache,
    registry: Option<&Registry>,
) -> Result<PlanOutcome> {
    let stage_us = |name: &str, t0: Instant| {
        if let Some(reg) = registry {
            reg.counter(name).add(t0.elapsed().as_micros() as u64);
        }
    };
    let scenario = build_scenario(plan)?;
    let npts = scenario.points();
    ensure!(npts > 0, "the candidate lattice is empty");

    // ---- stage 0: fold exact-duplicate lattice points
    let t0 = Instant::now();
    let mut candidates: Vec<Candidate> = Vec::with_capacity(npts);
    let mut seen: BTreeMap<String, usize> = BTreeMap::new();
    for p in 0..npts {
        let (_, _, s) = scenario.decode(p);
        let fp = fingerprint(&scenario, p);
        let fate = match seen.get(&fp) {
            Some(&into) => Fate::Folded { into },
            None => {
                seen.insert(fp, p);
                // provisional; overwritten by stage 1/2 below
                Fate::Evaluated { rung: 0 }
            }
        };
        candidates.push(Candidate {
            point: p,
            label: scenario.label(p),
            strategy: scenario.spec().strategies[s].label.clone(),
            surface: None,
            fate,
            sim: None,
            rank: None,
            feasible: false,
            frontier: false,
        });
    }

    stage_us("planner_stage0_us", t0);

    // ---- stage 1a: plan every unique candidate, extract surfaces
    let t1 = Instant::now();
    let uniq: Vec<usize> = candidates
        .iter()
        .enumerate()
        .filter(|(_, c)| !matches!(c.fate, Fate::Folded { .. }))
        .map(|(i, _)| i)
        .collect();
    let prepared: Vec<Result<(Arc<SpecCtx>, Option<Surface>)>> =
        run_indexed(cfg.threads, uniq.len(), |i| {
            let ctx =
                cache.get_or_prepare(&scenario, candidates[uniq[i]].point)?;
            // [[portfolio]] points have no single-market closed form:
            // every candidate is heuristic, never analytically pruned
            let surface = if ctx.is_portfolio() {
                None
            } else {
                admissible_surface(
                    &ctx.plans()[0],
                    ctx.bid_problem(),
                    ctx.bound(),
                    ctx.run_params().runtime,
                    ctx.run_params().idle_step,
                    ctx.iid_prices(),
                    // the *resolved* per-point overhead: an `overhead.*`
                    // axis can switch overhead on for some lattice points
                    // even when the base spec's table is absent, and those
                    // points must be heuristic (never pruned)
                    ctx.run_params().overhead.enabled(),
                )
            };
            Ok((ctx, surface))
        });
    // cache the prepared contexts: the refinement rungs reuse them, so
    // the expensive plan solves run once per candidate, not per rung
    let mut ctx_cache: Vec<Option<Arc<SpecCtx>>> = vec![None; npts];
    for (i, res) in prepared.into_iter().enumerate() {
        match res {
            Ok((ctx, surface)) => {
                candidates[uniq[i]].surface = surface;
                ctx_cache[uniq[i]] = Some(ctx);
            }
            Err(e) => {
                candidates[uniq[i]].fate =
                    Fate::PlanError { error: format!("{e:#}") };
            }
        }
    }

    // ---- stage 1b: analytic pruning over admissible surfaces
    if plan.search.prune {
        // hard constraints first: these surfaces are exact expectations,
        // so a closed-form violation is a provable one
        for &ci in &uniq {
            if !matches!(candidates[ci].fate, Fate::Evaluated { .. }) {
                continue;
            }
            if let Some(sf) = candidates[ci].surface {
                if let Some(v) =
                    plan.objective.violation(sf.cost, sf.time, sf.err)
                {
                    candidates[ci].fate = Fate::Infeasible { violated: v };
                }
            }
        }
        // weak dominance with order tie-break (a strict partial order:
        // every beaten candidate has an unbeaten witness)
        let admissible: Vec<usize> = uniq
            .iter()
            .copied()
            .filter(|&ci| {
                matches!(candidates[ci].fate, Fate::Evaluated { .. })
                    && candidates[ci].surface.is_some()
            })
            .collect();
        let beats_ci = |cj: usize, ci: usize| -> bool {
            match (&candidates[cj].surface, &candidates[ci].surface) {
                (Some(a), Some(b)) => beats(a, cj, b, ci),
                _ => false,
            }
        };
        let beaten: Vec<usize> = admissible
            .iter()
            .copied()
            .filter(|&ci| {
                admissible
                    .iter()
                    .any(|&cj| cj != ci && beats_ci(cj, ci))
            })
            .collect();
        let witnesses: Vec<(usize, usize)> = beaten
            .iter()
            .map(|&ci| {
                let by = admissible
                    .iter()
                    .copied()
                    .filter(|cj| !beaten.contains(cj))
                    .find(|&cj| beats_ci(cj, ci))
                    // unreachable by the partial-order argument, but
                    // never panic over a float oddity: fall back to any
                    // beating candidate
                    .or_else(|| {
                        admissible
                            .iter()
                            .copied()
                            .find(|&cj| cj != ci && beats_ci(cj, ci))
                    })
                    .expect("beaten candidate has a beating witness");
                (ci, by)
            })
            .collect();
        for (ci, by) in witnesses {
            candidates[ci].fate = Fate::Dominated { by };
        }
    }

    stage_us("planner_stage1_us", t1);

    // ---- stage 2: successive-halving refinement on the sweep pool
    let t2 = Instant::now();
    let mut alive: Vec<usize> = candidates
        .iter()
        .enumerate()
        .filter(|(_, c)| matches!(c.fate, Fate::Evaluated { .. }))
        .map(|(i, _)| i)
        .collect();
    let mut rungs: Vec<RungRecord> = Vec::new();
    for (rung, &reps) in plan.search.ladder.iter().enumerate() {
        if alive.is_empty() {
            break;
        }
        let seed = rung_seed(cfg.seed, rung);
        let points: Vec<usize> =
            alive.iter().map(|&ci| candidates[ci].point).collect();
        let ctxs: Vec<Arc<SpecCtx>> = alive
            .iter()
            .map(|&ci| {
                ctx_cache[ci]
                    .clone()
                    .expect("alive candidates were prepared in stage 1")
            })
            .collect();
        let cs = CandidateScenario {
            base: &scenario,
            points,
            ctxs: Some(ctxs),
        };
        let res = run_sweep(
            &cs,
            &SweepConfig { replicates: reps, seed, threads: cfg.threads },
        )?;
        for (k, &ci) in alive.iter().enumerate() {
            let stats = &res.points[k].stats;
            let sim = SimStats {
                replicates: reps,
                cost_mean: stats[0].mean(),
                cost_std: stats[0].std(),
                time_mean: stats[1].mean(),
                time_std: stats[1].std(),
                err_mean: stats[2].mean(),
                err_std: stats[2].std(),
                iters_mean: stats[3].mean(),
            };
            candidates[ci].feasible = plan.objective.feasible(
                sim.cost_mean,
                sim.time_mean,
                sim.err_mean,
            );
            candidates[ci].sim = Some(sim);
            candidates[ci].fate = Fate::Evaluated { rung };
        }
        rungs.push(RungRecord { replicates: reps, seed, members: alive.clone() });
        if rung + 1 < plan.search.ladder.len()
            && alive.len() > plan.search.min_keep
        {
            let mut ranked = alive.clone();
            ranked.sort_by(|&a, &b| rank_order(&candidates, &plan.objective, a, b));
            let keep = ((alive.len() as f64 * plan.search.keep_fraction)
                .ceil() as usize)
                .max(plan.search.min_keep)
                .min(alive.len());
            ranked.truncate(keep);
            ranked.sort_unstable();
            alive = ranked;
        }
    }

    stage_us("planner_stage2_us", t2);

    // ---- final ranking, incumbent, frontier
    let evaluated: Vec<usize> = candidates
        .iter()
        .enumerate()
        .filter(|(_, c)| matches!(c.fate, Fate::Evaluated { .. }))
        .map(|(i, _)| i)
        .collect();
    let mut recommendations = evaluated.clone();
    recommendations
        .sort_by(|&a, &b| rank_order(&candidates, &plan.objective, a, b));
    for (r, &ci) in recommendations.iter().enumerate() {
        candidates[ci].rank = Some(r + 1);
    }
    let incumbent = recommendations
        .iter()
        .copied()
        .find(|&ci| candidates[ci].feasible);
    // Pareto frontier over the deepest-rung simulated means, with the
    // same weak-dominance order the pruner uses
    let sim_surface = |ci: usize| -> Surface {
        let s = candidates[ci].sim.expect("evaluated candidate has stats");
        Surface { cost: s.cost_mean, time: s.time_mean, err: s.err_mean }
    };
    let on_frontier: Vec<usize> = evaluated
        .iter()
        .copied()
        .filter(|&ci| {
            !evaluated.iter().any(|&cj| {
                cj != ci && beats(&sim_surface(cj), cj, &sim_surface(ci), ci)
            })
        })
        .collect();
    for ci in on_frontier {
        candidates[ci].frontier = true;
    }

    Ok(PlanOutcome {
        name: scenario.spec().name.clone(),
        objective: plan.objective,
        search: plan.search.clone(),
        seed: cfg.seed,
        lattice_points: npts,
        candidates,
        recommendations,
        incumbent,
        rungs,
    })
}

/// Ranking order: feasible candidates first (a hard constraint
/// outranks evidence depth — if every deep survivor turns out
/// infeasible, a feasible shallow-rung candidate is still the best
/// recommendation on offer, with its thin `replicates` count visible
/// in the report), then *deeper-rung evidence first* (within a
/// feasibility class a culled candidate never outranks a survivor
/// whose statistics carry more replicates — the ladder's verdict
/// stands), then ascending objective score on the simulated means,
/// ties by candidate order. Mid-ladder culls compare members of the
/// same rung, so the depth key is a tie there and culling stays pure
/// score order. `total_cmp` keeps the sort deterministic even for
/// pathological float values.
fn rank_order(
    candidates: &[Candidate],
    objective: &Objective,
    a: usize,
    b: usize,
) -> std::cmp::Ordering {
    let (ca, cb) = (&candidates[a], &candidates[b]);
    let rung = |c: &Candidate| match c.fate {
        Fate::Evaluated { rung } => rung,
        _ => 0,
    };
    cb.feasible
        .cmp(&ca.feasible)
        .then_with(|| rung(cb).cmp(&rung(ca)))
        .then_with(|| {
            let score = |c: &Candidate| {
                let s = c.sim.expect("ranked candidate has stats");
                objective.score(s.cost_mean, s.time_mean)
            };
            score(ca).total_cmp(&score(cb))
        })
        .then_with(|| a.cmp(&b))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// static_workers on a fixed-price market with a unit-price axis:
    /// identical dynamics, doubled price — textbook dominance.
    const DOMINATED: &str = r#"
name = "dominated"
strategies = ["static_workers"]
axes = ["price"]

[objective]
goal = "min_cost"

[search]
ladder = [2]
min_keep = 1

[job]
n = 4
j = 100
preempt_q = 0.3

[runtime]
kind = "deterministic"
r = 10.0

[market]
kind = "fixed"

[axis.price]
path = "job.unit_price"
values = [1.0, 2.0]
"#;

    fn run(text: &str, threads: usize) -> PlanOutcome {
        let plan = PlanSpec::from_str(text).unwrap();
        run_plan(&plan, &PlannerConfig { seed: 11, threads }).unwrap()
    }

    #[test]
    fn dominated_candidate_is_pruned_with_a_surviving_witness() {
        let out = run(DOMINATED, 2);
        assert_eq!(out.lattice_points, 2);
        let c = out.counts();
        assert_eq!(c.dominated, 1);
        assert_eq!(c.evaluated, 1);
        // the doubled price is the dominated one; its witness survived
        assert_eq!(out.candidates[1].fate, Fate::Dominated { by: 0 });
        assert!(matches!(out.candidates[0].fate, Fate::Evaluated { .. }));
        let (a, b) = (
            out.candidates[0].surface.unwrap(),
            out.candidates[1].surface.unwrap(),
        );
        assert!(beats(&a, 0, &b, 1));
        assert_eq!(a.time, b.time);
        assert_eq!(a.err, b.err);
        assert!((b.cost - 2.0 * a.cost).abs() < 1e-9 * b.cost);
        // the survivor is the incumbent and alone on the frontier
        assert_eq!(out.incumbent, Some(0));
        assert_eq!(out.frontier_labels(), vec!["price=1"]);
    }

    #[test]
    fn prune_false_sends_everything_to_simulation() {
        let text = DOMINATED.replace("[search]", "[search]\nprune = false");
        let out = run(&text, 2);
        let c = out.counts();
        assert_eq!(c.dominated, 0);
        assert_eq!(c.evaluated, 2);
        // simulation reaches the same verdict: the cheap entry ranks
        // first and the expensive one is off the frontier on cost
        assert_eq!(out.recommendations[0], 0);
        assert!(out.candidates[0].frontier);
    }

    #[test]
    fn closed_form_constraint_violations_prune_before_simulation() {
        let text = DOMINATED.replace(
            "goal = \"min_cost\"",
            "goal = \"min_cost\"\nbudget = 0.001",
        );
        let out = run(&text, 1);
        let c = out.counts();
        // both candidates exceed the budget in closed form; nothing runs
        assert_eq!(c.infeasible, 2);
        assert_eq!(c.evaluated, 0);
        assert!(out.rungs.is_empty());
        assert!(out.incumbent.is_none());
        for cand in &out.candidates {
            if let Fate::Infeasible { violated } = &cand.fate {
                assert!(violated.contains("budget"), "{violated}");
            } else {
                panic!("expected Infeasible, got {:?}", cand.fate);
            }
        }
    }

    #[test]
    fn strategy_scoped_axes_fold_unaffected_entries() {
        let text = r#"
name = "folding"
strategies = ["a", "b"]
axes = ["eta"]

[objective]
goal = "min_cost"

[search]
ladder = [1]
min_keep = 1

[job]
n = 4
j = 50
preempt_q = 0.3

[runtime]
kind = "deterministic"
r = 10.0

[market]
kind = "fixed"

[strategy.a]
kind = "dynamic_workers"
eta = 1.2

[strategy.b]
kind = "static_workers"

[axis.eta]
path = "strategy.a.eta"
values = [1.2, 1.5, 2.0]
"#;
        let out = run(text, 2);
        assert_eq!(out.lattice_points, 6); // 3 eta x 2 strategies
        let c = out.counts();
        // b is untouched by the eta axis: 2 of its 3 points fold
        assert_eq!(c.folded, 2);
        assert_eq!(c.evaluated, 4);
        for cand in &out.candidates {
            if let Fate::Folded { into } = cand.fate {
                assert_eq!(out.candidates[into].strategy, "b");
                assert_eq!(cand.strategy, "b");
            }
        }
        // dynamic_workers is adaptive: heuristic, never pruned, no
        // surface; static_workers carries its exact surface
        for cand in &out.candidates {
            match cand.strategy.as_str() {
                "a" => assert!(cand.surface.is_none()),
                "b" if !matches!(cand.fate, Fate::Folded { .. }) => {
                    assert!(cand.surface.is_some())
                }
                _ => {}
            }
        }
    }

    #[test]
    fn infeasible_plans_are_recorded_not_fatal() {
        // eps = 0.35 sits below the n = 4 noise floor (K/4 = 0.5): the
        // Theorem-2 plan fails in closed form at n = 4, succeeds at 8
        let text = r#"
name = "floors"
strategies = ["one_bid"]
axes = ["n"]

[objective]
goal = "min_cost"
deadline = 300000.0

[search]
ladder = [1]
min_keep = 1

[job]
eps = 0.35
j = 2000

[runtime]
kind = "deterministic"
r = 10.0

[market]
kind = "uniform"
lo = 0.2
hi = 1.0

[axis.n]
path = "job.n"
values = [4, 8]
"#;
        let out = run(text, 2);
        let c = out.counts();
        assert_eq!(c.plan_errors, 1);
        assert_eq!(c.evaluated, 1);
        match &out.candidates[0].fate {
            Fate::PlanError { error } => {
                assert!(error.contains("noise floor"), "{error}")
            }
            other => panic!("expected PlanError, got {other:?}"),
        }
        assert_eq!(out.incumbent_label(), Some("n=8"));
    }

    #[test]
    fn ladder_culls_by_score_and_keeps_determinism() {
        let text = r#"
name = "ladder"
strategies = ["static_workers"]
axes = ["price"]

[objective]
goal = "min_cost"

[search]
ladder = [1, 2]
keep_fraction = 0.5
min_keep = 1
prune = false

[job]
n = 4
j = 60
preempt_q = 0.3

[runtime]
kind = "deterministic"
r = 10.0

[market]
kind = "fixed"

[axis.price]
path = "job.unit_price"
values = [1.0, 2.0, 3.0, 4.0]
"#;
        let serial = run(text, 1);
        let par = run(text, 8);
        assert_eq!(serial.digest(), par.digest());
        assert_eq!(serial.rungs.len(), 2);
        assert_eq!(serial.rungs[0].members, vec![0, 1, 2, 3]);
        // ceil(4 * 0.5) = 2 survivors; min_cost keeps the cheap prices
        assert_eq!(serial.rungs[1].members, vec![0, 1]);
        assert_eq!(
            serial.candidates[0].fate,
            Fate::Evaluated { rung: 1 }
        );
        assert_eq!(
            serial.candidates[3].fate,
            Fate::Evaluated { rung: 0 }
        );
        // culled candidates keep their rung-0 stats and still rank —
        // but always below the final-rung survivors: the ladder's own
        // verdict is never overturned by shallow-replicate noise
        assert!(serial.candidates[3].sim.is_some());
        assert_eq!(serial.recommendations, vec![0, 1, 2, 3]);
        assert_eq!(serial.incumbent, Some(0));
        // replaying the recorded final rung reproduces its stats
        let plan = PlanSpec::from_str(text).unwrap();
        let scenario = build_scenario(&plan).unwrap();
        let last = serial.rungs.last().unwrap();
        let points: Vec<usize> = last
            .members
            .iter()
            .map(|&ci| serial.candidates[ci].point)
            .collect();
        let replay = evaluate_rung(
            &scenario,
            &points,
            last.replicates,
            last.seed,
            3,
        )
        .unwrap();
        for (k, &ci) in last.members.iter().enumerate() {
            let sim = serial.candidates[ci].sim.unwrap();
            assert_eq!(replay.points[k].stats[0].mean(), sim.cost_mean);
            assert_eq!(replay.points[k].stats[1].mean(), sim.time_mean);
            assert_eq!(replay.points[k].stats[2].mean(), sim.err_mean);
        }
    }

    #[test]
    fn min_time_goal_reorders_recommendations() {
        // two fleet sizes on a preemptible platform: the bigger fleet
        // is faster (fewer dead slots at q = 0.6) but costlier
        let text = r#"
name = "goals"
strategies = ["static_workers"]
axes = ["n"]

[objective]
goal = "min_time"

[search]
ladder = [2]
min_keep = 1
prune = false

[job]
j = 80
preempt_q = 0.6
unit_price = 1.0

[runtime]
kind = "deterministic"
r = 10.0

[market]
kind = "fixed"

[axis.n]
path = "job.n"
values = [1, 8]
"#;
        let out = run(text, 2);
        assert_eq!(out.counts().evaluated, 2);
        let t = |i: usize| out.candidates[i].sim.unwrap().time_mean;
        let c = |i: usize| out.candidates[i].sim.unwrap().cost_mean;
        assert!(t(1) < t(0), "n=8 must be faster at q=0.6");
        assert!(c(1) > c(0), "n=8 must be costlier");
        assert_eq!(out.recommendations[0], 1, "min_time prefers n=8");
        let cost_text = text.replace("min_time", "min_cost");
        let out = run(&cost_text, 2);
        assert_eq!(out.recommendations[0], 0, "min_cost prefers n=1");
        // both sit on the (cost, time, err) frontier
        assert!(out.candidates[0].frontier && out.candidates[1].frontier);
    }
}
