//! Closed-form (cost, time, error) surfaces and the dominance order.
//!
//! Stage 1 of the planner evaluates every candidate's *analytic*
//! surface where one exists and is **admissible** — exact, in
//! expectation, for the process the engine simulates (DESIGN.md §7):
//!
//! * fixed-bid plans (`no_interruptions`, `one_bid`, `two_bids`,
//!   `bid_fractions`) under an i.i.d. price model: the paper's
//!   Lemma 1/2 and Theorem 2/3 forms via [`BidProblem`], with the
//!   Theorem-1 bound at the plan's exact `E[1/y(b)]`;
//! * `static_workers` under any preemption model: exact sums over the
//!   active-set distribution (`E[y R(y) | y > 0]` pairs the binomial
//!   pmf with the straggler runtime — y and R(y) are *not*
//!   independent), the idle-slot tax `idle_step * p0 / (1 - p0)`, and
//!   the Theorem-1 bound at the exact conditional `E[1/y]`.
//!
//! Everything else — staged/dynamic plans, the event-native policies,
//! trace-estimated markets, any `[overhead]` model — is *heuristic*
//! territory: no surface is produced, the candidate is never pruned,
//! and simulation is its only judge.

use crate::exp::PlannedStrategy;
use crate::preempt::PreemptionModel;
use crate::theory::bids::BidProblem;
use crate::theory::bounds::ErrorBound;
use crate::theory::runtime_model::RuntimeModel;
use crate::util::ln_binomial;

/// One candidate's closed-form outcome triple. Lower is better on
/// every axis.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Surface {
    /// expected total cost
    pub cost: f64,
    /// expected completion time
    pub time: f64,
    /// Theorem-1 error bound at the plan's iteration budget
    pub err: f64,
}

/// The planner's pruning order: `a` (at candidate index `a_idx`) beats
/// `b` when it is no worse on all three axes and either strictly
/// better somewhere or an exact tie broken by candidate order. The
/// tie-break folds duplicate surfaces deterministically (lowest index
/// survives); with it, "beats" is a strict partial order, so every
/// beaten candidate has an unbeaten witness that beats it — the
/// soundness property `tests/integration_opt.rs` re-checks.
pub fn beats(a: &Surface, a_idx: usize, b: &Surface, b_idx: usize) -> bool {
    let no_worse = a.cost <= b.cost && a.time <= b.time && a.err <= b.err;
    if !no_worse {
        return false;
    }
    let strictly = a.cost < b.cost || a.time < b.time || a.err < b.err;
    strictly || a_idx < b_idx
}

/// Active-set pmf over `y = 0..=n`, exact per model.
fn active_pmf(model: &PreemptionModel, n: usize) -> Vec<f64> {
    let mut pmf = vec![0.0; n + 1];
    match model {
        PreemptionModel::None => pmf[n] = 1.0,
        PreemptionModel::Bernoulli { q } => {
            // log-space binomial terms: stable for any q in (0,1) and
            // fleets far larger than we ever provision
            let (lq, lp) = (q.ln(), (1.0 - q).ln());
            for (y, slot) in pmf.iter_mut().enumerate() {
                *slot = (ln_binomial(n as u64, y as u64)
                    + y as f64 * lp
                    + (n - y) as f64 * lq)
                    .exp();
            }
        }
        PreemptionModel::Uniform => {
            for slot in pmf.iter_mut().skip(1) {
                *slot = 1.0 / n as f64;
            }
        }
    }
    pmf
}

/// The closed-form surface for one plan, `Some` only when admissible
/// for pruning (see the module docs / DESIGN.md §7). `bound` is the
/// point's Theorem-1 evaluator, `runtime`/`idle_step` the engine loop
/// parameters the static-workers forms must mirror exactly.
pub fn admissible_surface(
    plan: &PlannedStrategy,
    pb: Option<&BidProblem>,
    bound: &ErrorBound,
    runtime: RuntimeModel,
    idle_step: f64,
    iid_prices: bool,
    overhead_enabled: bool,
) -> Option<Surface> {
    if overhead_enabled {
        // checkpoint/restart accounting is engine-only; no closed form
        return None;
    }
    match plan {
        PlannedStrategy::Fixed { bids, j, .. } => {
            // Lemma 1/2 are exact for i.i.d. prices only; an empirical
            // CDF estimated from a trace replay is a heuristic stand-in
            if !iid_prices {
                return None;
            }
            let pb = pb?;
            let (n1, b1, b2) = (bids.n1, bids.b1, bids.b2);
            let recip = pb.expected_recip_two(n1, b1, b2);
            Some(Surface {
                cost: pb.expected_cost_two(*j, n1, b1, b2),
                time: pb.expected_time_two(*j, n1, b1, b2),
                err: bound.phi_const(*j, recip),
            })
        }
        PlannedStrategy::StaticWorkers {
            n, j, model, unit_price, ..
        } => {
            let pmf = active_pmf(model, *n);
            let p0 = pmf[0];
            let live = 1.0 - p0;
            if live <= 0.0 {
                return None; // q = 1 cannot happen (parser range), but
                             // never divide by zero on a surface
            }
            // E[R(y) | y>0] and E[y R(y) | y>0]: y and R(y) are coupled
            // through the straggler max, so both are pmf-weighted sums
            let (mut er, mut yer) = (0.0, 0.0);
            for (y, p) in pmf.iter().enumerate().skip(1) {
                let r = runtime.expected(y);
                er += p / live * r;
                yer += p / live * y as f64 * r;
            }
            let jf = *j as f64;
            Some(Surface {
                // every one of the J productive slots bills the active
                // workers at the flat preemptible price for the slot
                cost: jf * unit_price * yer,
                // J productive slots plus the expected idle-slot tax
                // (negative-binomial mean: J p0 / (1 - p0) idle slots)
                time: jf * er + jf * idle_step * p0 / live,
                err: bound.phi_const(*j, model.expected_recip(*n)),
            })
        }
        // staged bids, Theorem-5 growth, the event-native policies and
        // the portfolio/forecast placement plans adapt mid-run: their
        // closed forms are heuristic at best, so they are never pruned
        // — simulation is their only judge
        PlannedStrategy::Dynamic { .. }
        | PlannedStrategy::DynamicWorkers { .. }
        | PlannedStrategy::NoticeRebid { .. }
        | PlannedStrategy::ElasticFleet { .. }
        | PlannedStrategy::DeadlineAware { .. }
        | PlannedStrategy::PortfolioMigrate { .. }
        | PlannedStrategy::ProactiveMigrate { .. }
        | PlannedStrategy::LookaheadBid { .. } => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exp::{run_policy_engine, RunParams};
    use crate::market::BidVector;
    use crate::sim::PriceSource;
    use crate::theory::bounds::SgdHyper;
    use crate::util::rng::Rng;

    fn bound() -> ErrorBound {
        ErrorBound::new(SgdHyper::paper_cnn())
    }

    #[test]
    fn beats_is_weak_dominance_with_index_tiebreak() {
        let a = Surface { cost: 1.0, time: 2.0, err: 0.3 };
        let worse_cost = Surface { cost: 2.0, ..a };
        let tie = a;
        let tradeoff = Surface { cost: 0.5, time: 3.0, err: 0.3 };
        assert!(beats(&a, 0, &worse_cost, 1));
        assert!(!beats(&worse_cost, 1, &a, 0));
        // exact ties: only the lower index wins, never both
        assert!(beats(&a, 0, &tie, 1));
        assert!(!beats(&tie, 1, &a, 0));
        // a genuine tradeoff beats nobody
        assert!(!beats(&a, 0, &tradeoff, 1));
        assert!(!beats(&tradeoff, 1, &a, 0));
        // infinities lose cleanly, NaN never participates
        let inf = Surface { cost: f64::INFINITY, time: 2.0, err: 0.3 };
        assert!(beats(&a, 0, &inf, 1));
        let nan = Surface { cost: f64::NAN, time: 2.0, err: 0.3 };
        assert!(!beats(&a, 0, &nan, 1));
        assert!(!beats(&nan, 1, &a, 0));
    }

    #[test]
    fn active_pmf_sums_to_one_and_matches_moments() {
        let model = PreemptionModel::Bernoulli { q: 0.4 };
        for n in [1usize, 3, 8, 40] {
            let pmf = active_pmf(&model, n);
            let total: f64 = pmf.iter().sum();
            assert!((total - 1.0).abs() < 1e-12, "n={n}: sum {total}");
            assert!((pmf[0] - model.p_zero(n)).abs() < 1e-12);
            let mean: f64 =
                pmf.iter().enumerate().map(|(y, p)| y as f64 * p).sum();
            assert!((mean - model.mean_active(n)).abs() < 1e-10);
        }
    }

    /// The static-workers surface must be exact for the engine's own
    /// accounting: Monte-Carlo means from the real engine path converge
    /// to the closed forms.
    #[test]
    fn static_workers_surface_matches_engine_monte_carlo() {
        let model = PreemptionModel::Bernoulli { q: 0.4 };
        let plan = PlannedStrategy::StaticWorkers {
            name: "static".to_string(),
            n: 3,
            j: 200,
            model: model.clone(),
            unit_price: 2.0,
        };
        let runtime = RuntimeModel::ExpStragglers { lambda: 0.25, delta: 0.5 };
        let idle_step = 4.0;
        let sf = admissible_surface(
            &plan,
            None,
            &bound(),
            runtime,
            idle_step,
            false,
            false,
        )
        .unwrap();
        let params = RunParams::lockstep(runtime, f64::INFINITY);
        let prices = PriceSource::Fixed(0.0);
        let reps = 400;
        let (mut cost, mut time, mut err) = (0.0, 0.0, 0.0);
        for rep in 0..reps {
            let mut rng = Rng::stream(7, rep);
            let mut policy = plan.build_policy().unwrap();
            let r = run_policy_engine(
                policy.as_mut(),
                bound(),
                &prices,
                &params,
                &mut rng,
            )
            .unwrap();
            cost += r.cost / reps as f64;
            time += r.elapsed / reps as f64;
            err += r.final_error / reps as f64;
        }
        assert!(
            (cost - sf.cost).abs() < 0.05 * sf.cost,
            "cost mc={cost} exact={}",
            sf.cost
        );
        assert!(
            (time - sf.time).abs() < 0.05 * sf.time,
            "time mc={time} exact={}",
            sf.time
        );
        // the err surface is the third pruning axis (error_bound
        // constraints + dominance): the synthetic backend's recursion
        // is linear in 1/y, so phi_const(J, E[1/y | y>0]) is exactly
        // the expectation of the realized final error — Monte-Carlo
        // means must converge to it just like cost and time
        assert!(
            (err - sf.err).abs() < 0.05 * sf.err,
            "err mc={err} exact={}",
            sf.err
        );
    }

    #[test]
    fn fixed_bid_surface_reuses_the_theorem_forms() {
        let pb = BidProblem {
            bound: bound(),
            price: crate::market::PriceModel::uniform_paper(),
            runtime: RuntimeModel::Deterministic { r: 10.0 },
            n: 8,
            eps: 0.35,
            theta: 120_000.0,
        };
        let one = pb.optimal_one_bid().unwrap();
        let plan = PlannedStrategy::Fixed {
            name: "one_bid".to_string(),
            bids: BidVector::uniform(8, one.b),
            j: one.j,
        };
        let sf = admissible_surface(
            &plan,
            Some(&pb),
            &bound(),
            pb.runtime,
            4.0,
            true,
            false,
        )
        .unwrap();
        assert!((sf.cost - one.expected_cost).abs() < 1e-9 * one.expected_cost);
        assert!((sf.time - one.expected_time).abs() < 1e-9 * one.expected_time);
        assert!(sf.err <= pb.eps * (1.0 + 1e-9), "err {} vs eps", sf.err);
        // non-iid prices demote the same plan to heuristic
        assert!(admissible_surface(
            &plan,
            Some(&pb),
            &bound(),
            pb.runtime,
            4.0,
            false,
            false
        )
        .is_none());
        // any overhead model demotes everything
        assert!(admissible_surface(
            &plan,
            Some(&pb),
            &bound(),
            pb.runtime,
            4.0,
            true,
            true
        )
        .is_none());
    }

    #[test]
    fn adaptive_plans_have_no_admissible_surface() {
        let plan = PlannedStrategy::ElasticFleet {
            name: "elastic".to_string(),
            j: 100,
            table: crate::preempt::RecipTable::build(
                &PreemptionModel::Bernoulli { q: 0.3 },
                4,
            ),
            budget_rate: 1.0,
        };
        assert!(admissible_surface(
            &plan,
            None,
            &bound(),
            RuntimeModel::Deterministic { r: 10.0 },
            4.0,
            true,
            false
        )
        .is_none());
    }
}
