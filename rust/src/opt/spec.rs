//! Planner spec: `[objective]` + `[search]` on top of a scenario.
//!
//! A planner spec is an ordinary [`ScenarioSpec`] file — same `[job]` /
//! `[runtime]` / `[market]` / `[strategy.*]` / `[axis.*]` schema, same
//! strict unknown-key audit — plus two planner-only tables:
//!
//! * **`[objective]`** — what "best" means: `goal = "min_cost" |
//!   "min_time" | "weighted"` (with `weight_cost` / `weight_time`),
//!   and hard constraints on *expected* outcomes: `deadline` (time),
//!   `budget` (cost) and `error_bound` (training-error proxy);
//! * **`[search]`** — the successive-halving schedule: a fixed
//!   `ladder` of replicate counts, the `keep_fraction` culled between
//!   rungs, a `min_keep` floor, and a `prune` switch for the analytic
//!   stage.
//!
//! Two deliberate differences from sweep specs: the `metrics` key is
//! rejected (the planner reports its own cost/time/error columns), and
//! an absent `job.theta` inherits `objective.deadline` — the deadline
//! you constrain on is the deadline the Theorem 2/3 bid plans target.
//!
//! # Example
//!
//! ```
//! use volatile_sgd::opt::{Goal, PlanSpec};
//!
//! let plan = PlanSpec::from_str(r#"
//! name = "doc"
//! strategies = ["static_workers"]
//!
//! [objective]
//! goal = "min_cost"
//! budget = 5000.0
//!
//! [search]
//! ladder = [2, 4]
//!
//! [job]
//! n = 4
//! j = 100
//! preempt_q = 0.3
//!
//! [runtime]
//! kind = "deterministic"
//! r = 10.0
//!
//! [market]
//! kind = "fixed"
//! "#).unwrap();
//! assert_eq!(plan.objective.goal, Goal::MinCost);
//! assert_eq!(plan.search.ladder, vec![2, 4]);
//! ```

use anyhow::{bail, ensure, Context, Result};

use crate::config::toml::{Doc, TrackedDoc};
use crate::exp::spec::{reject_unknown_keys, SweepMode};
use crate::exp::ScenarioSpec;
use crate::util::fnv::Fnv;

/// Relative slack for constraint checks: a surface that is deadline-
/// *tight* by construction (Theorem 2 solves `E[tau] = theta` exactly)
/// must not be pruned over a last-bit rounding excess. Slack only ever
/// widens the feasible set, so pruning stays sound.
pub const CONSTRAINT_RTOL: f64 = 1e-9;

/// What the planner minimises over the feasible candidates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Goal {
    /// minimise expected cost (the paper's Sec. IV objective)
    MinCost,
    /// minimise expected completion time
    MinTime,
    /// minimise `weight_cost * cost + weight_time * time`
    Weighted { cost: f64, time: f64 },
}

impl Goal {
    /// The config-file name (what `objective.goal` parses).
    pub fn name(&self) -> &'static str {
        match self {
            Goal::MinCost => "min_cost",
            Goal::MinTime => "min_time",
            Goal::Weighted { .. } => "weighted",
        }
    }
}

/// The `[objective]` table: goal plus hard constraints on expected
/// outcomes. During analytic pruning the constraints read the
/// closed-form surfaces; during refinement and final ranking they read
/// the simulated means — DESIGN.md §7 spells out the two semantics.
#[derive(Clone, Copy, Debug)]
pub struct Objective {
    pub goal: Goal,
    /// max expected completion time
    pub deadline: Option<f64>,
    /// max expected total cost
    pub budget: Option<f64>,
    /// max expected training-error proxy
    pub error_bound: Option<f64>,
}

impl Objective {
    /// The scalar the planner ranks candidates by (lower is better).
    pub fn score(&self, cost: f64, time: f64) -> f64 {
        match self.goal {
            Goal::MinCost => cost,
            Goal::MinTime => time,
            Goal::Weighted { cost: wc, time: wt } => wc * cost + wt * time,
        }
    }

    /// First violated hard constraint, described — `None` when the
    /// point is feasible. Comparisons carry [`CONSTRAINT_RTOL`] slack.
    pub fn violation(
        &self,
        cost: f64,
        time: f64,
        err: f64,
    ) -> Option<String> {
        let over = |v: f64, lim: f64| v > lim * (1.0 + CONSTRAINT_RTOL);
        if let Some(t) = self.deadline {
            if over(time, t) {
                return Some(format!(
                    "expected time {time} exceeds deadline {t}"
                ));
            }
        }
        if let Some(b) = self.budget {
            if over(cost, b) {
                return Some(format!(
                    "expected cost {cost} exceeds budget {b}"
                ));
            }
        }
        if let Some(e) = self.error_bound {
            if over(err, e) {
                return Some(format!(
                    "expected error {err} exceeds error_bound {e}"
                ));
            }
        }
        None
    }

    pub fn feasible(&self, cost: f64, time: f64, err: f64) -> bool {
        self.violation(cost, time, err).is_none()
    }
}

/// The `[search]` table: the successive-halving refinement schedule.
#[derive(Clone, Debug)]
pub struct SearchSpec {
    /// replicate counts per rung, non-decreasing (default `[2, 4, 8]`);
    /// a *fixed* ladder is what keeps the planner's RNG streams pure
    /// functions of (seed, rung, candidate order) — DESIGN.md §7
    pub ladder: Vec<u64>,
    /// fraction of candidates kept between rungs, in (0, 1] (default 0.5)
    pub keep_fraction: f64,
    /// never cull below this many candidates (default 3)
    pub min_keep: usize,
    /// run the analytic pruning stage (default true; `false` sends the
    /// whole folded lattice to simulation — the pruning-audit switch)
    pub prune: bool,
}

impl Default for SearchSpec {
    fn default() -> Self {
        SearchSpec {
            ladder: vec![2, 4, 8],
            keep_fraction: 0.5,
            min_keep: 3,
            prune: true,
        }
    }
}

/// A fully-parsed planner spec: the candidate-lattice scenario, the
/// objective, and the search schedule.
#[derive(Clone, Debug)]
pub struct PlanSpec {
    pub scenario: ScenarioSpec,
    pub objective: Objective,
    pub search: SearchSpec,
}

impl PlanSpec {
    /// Content-addressed identity of the planner work this spec
    /// describes: the scenario fingerprint
    /// ([`ScenarioSpec::fingerprint`] — layout-invariant, seed-exempt)
    /// extended with every `[objective]` and `[search]` field. The
    /// serve daemon (`crate::serve`) keys its tier-A report cache on
    /// this plus the effective seed.
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv::new();
        h.bytes(b"plan-spec/v1");
        h.u64(self.scenario.fingerprint());
        h.str(self.objective.goal.name());
        if let Goal::Weighted { cost, time } = self.objective.goal {
            h.f64(cost);
            h.f64(time);
        }
        h.opt_f64(self.objective.deadline);
        h.opt_f64(self.objective.budget);
        h.opt_f64(self.objective.error_bound);
        h.u64(self.search.ladder.len() as u64);
        for &r in &self.search.ladder {
            h.u64(r);
        }
        h.f64(self.search.keep_fraction);
        h.u64(self.search.min_keep as u64);
        h.bool(self.search.prune);
        h.finish()
    }

    pub fn from_str(text: &str) -> Result<Self> {
        Self::from_doc(&Doc::parse(text)?)
    }

    pub fn from_file(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading plan spec {}", path.display()))?;
        Self::from_str(&text)
            .with_context(|| format!("parsing plan spec {}", path.display()))
    }

    pub fn from_doc(doc: &Doc) -> Result<Self> {
        let d = TrackedDoc::new(doc);

        // --------------------------------------------------- objective
        ensure!(
            d.has("objective.goal"),
            "missing required [objective] table (set objective.goal = \
             \"min_cost\" | \"min_time\" | \"weighted\")"
        );
        let goal = match d.require_str("objective.goal")?.as_str() {
            "min_cost" => Goal::MinCost,
            "min_time" => Goal::MinTime,
            "weighted" => {
                let cost = d.f64_or("objective.weight_cost", 1.0)?;
                let time = d.f64_or("objective.weight_time", 1.0)?;
                ensure!(
                    cost >= 0.0 && time >= 0.0 && cost + time > 0.0,
                    "objective weights must be >= 0 with a positive sum, \
                     got weight_cost={cost} weight_time={time}"
                );
                Goal::Weighted { cost, time }
            }
            other => bail!(
                "objective.goal must be min_cost | min_time | weighted, \
                 got '{other}'"
            ),
        };
        let positive = |key: &str, v: Option<f64>| -> Result<Option<f64>> {
            if let Some(v) = v {
                ensure!(v > 0.0, "objective.{key} must be > 0, got {v}");
            }
            Ok(v)
        };
        let objective = Objective {
            goal,
            deadline: positive("deadline", d.f64_opt("objective.deadline")?)?,
            budget: positive("budget", d.f64_opt("objective.budget")?)?,
            error_bound: positive(
                "error_bound",
                d.f64_opt("objective.error_bound")?,
            )?,
        };

        // ------------------------------------------------------ search
        let defaults = SearchSpec::default();
        let ladder = if d.has("search.ladder") {
            let vals = d.f64_array("search.ladder")?;
            ensure!(!vals.is_empty(), "search.ladder must not be empty");
            let mut out: Vec<u64> = Vec::with_capacity(vals.len());
            for v in vals {
                ensure!(
                    v.fract() == 0.0 && v >= 1.0,
                    "search.ladder entries must be integers >= 1, got {v}"
                );
                let r = v as u64;
                if let Some(&prev) = out.last() {
                    ensure!(
                        r >= prev,
                        "search.ladder must be non-decreasing, got {prev} \
                         then {r}"
                    );
                }
                out.push(r);
            }
            out
        } else {
            defaults.ladder
        };
        let keep_fraction =
            d.f64_or("search.keep_fraction", defaults.keep_fraction)?;
        ensure!(
            keep_fraction > 0.0 && keep_fraction <= 1.0,
            "search.keep_fraction must be in (0, 1], got {keep_fraction}"
        );
        let min_keep = d.usize_or("search.min_keep", defaults.min_keep)?;
        ensure!(min_keep >= 1, "search.min_keep must be >= 1");
        let search = SearchSpec {
            ladder,
            keep_fraction,
            min_keep,
            prune: d.bool_or("search.prune", defaults.prune)?,
        };

        // ---------------------------------------------------- scenario
        let mut scenario = ScenarioSpec::from_tracked(&d, false)?;
        ensure!(
            scenario.mode == SweepMode::PerStrategy,
            "optimize specs must use per_strategy mode: the candidate \
             lattice is (market x grid x strategy)"
        );
        ensure!(
            scenario.metrics.is_empty(),
            "optimize specs take no 'metrics' key — the planner reports \
             its own cost/time/error columns"
        );
        ensure!(
            scenario.replicates.is_none(),
            "optimize specs take no top-level 'replicates' key — the \
             [search] ladder governs replicate counts"
        );
        // the deadline you constrain on is the deadline the Theorem 2/3
        // bid plans target, unless the job pins its own theta
        if scenario.job.theta.is_none() {
            scenario.job.theta = objective.deadline;
        }
        reject_unknown_keys(&d, &scenario.strategies)?;
        Ok(PlanSpec { scenario, objective, search })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"
name = "mini_plan"
strategies = ["static_workers"]

[objective]
goal = "min_cost"
deadline = 9000.0

[search]
ladder = [2, 4]
keep_fraction = 0.5
min_keep = 1

[job]
n = 4
j = 100
preempt_q = 0.3

[runtime]
kind = "deterministic"
r = 10.0

[market]
kind = "fixed"
"#;

    #[test]
    fn parses_objective_search_and_scenario() {
        let p = PlanSpec::from_str(MINI).unwrap();
        assert_eq!(p.scenario.name, "mini_plan");
        assert_eq!(p.objective.goal, Goal::MinCost);
        assert_eq!(p.objective.deadline, Some(9000.0));
        assert_eq!(p.objective.budget, None);
        assert_eq!(p.search.ladder, vec![2, 4]);
        assert_eq!(p.search.min_keep, 1);
        assert!(p.search.prune);
        // an absent job.theta inherits the objective deadline
        assert_eq!(p.scenario.job.theta, Some(9000.0));
    }

    #[test]
    fn explicit_theta_wins_over_deadline_coupling() {
        let text = MINI.replace("j = 100", "j = 100\ntheta = 500.0");
        let p = PlanSpec::from_str(&text).unwrap();
        assert_eq!(p.scenario.job.theta, Some(500.0));
        assert_eq!(p.objective.deadline, Some(9000.0));
    }

    #[test]
    fn search_defaults_apply_without_a_table() {
        let table =
            "[search]\nladder = [2, 4]\nkeep_fraction = 0.5\nmin_keep = 1\n";
        let text = MINI.replace(table, "");
        assert_ne!(text, MINI, "the [search] table must be removed");
        let p = PlanSpec::from_str(&text).unwrap();
        assert_eq!(p.search.ladder, vec![2, 4, 8]);
        assert_eq!(p.search.keep_fraction, 0.5);
        assert_eq!(p.search.min_keep, 3);
    }

    #[test]
    fn weighted_goal_parses_and_scores() {
        let text = MINI.replace(
            "goal = \"min_cost\"",
            "goal = \"weighted\"\nweight_cost = 2.0\nweight_time = 0.5",
        );
        let p = PlanSpec::from_str(&text).unwrap();
        assert_eq!(p.objective.goal, Goal::Weighted { cost: 2.0, time: 0.5 });
        assert_eq!(p.objective.score(10.0, 4.0), 22.0);
    }

    #[test]
    fn bad_objectives_rejected() {
        for (needle, replacement, what) in [
            ("goal = \"min_cost\"", "goal = \"cheapest\"", "unknown goal"),
            ("deadline = 9000.0", "deadline = 0.0", "zero deadline"),
            ("deadline = 9000.0", "deadline = -1.0", "negative deadline"),
        ] {
            let bad = MINI.replace(needle, replacement);
            assert!(
                PlanSpec::from_str(&bad).is_err(),
                "{what} should be rejected"
            );
        }
        // [objective] is required
        let table = "[objective]\ngoal = \"min_cost\"\ndeadline = 9000.0";
        let no_obj = MINI.replace(table, "");
        let err = PlanSpec::from_str(&no_obj).unwrap_err().to_string();
        assert!(err.contains("[objective]"), "{err}");
        // weights must make sense
        let bad = MINI.replace(
            "goal = \"min_cost\"",
            "goal = \"weighted\"\nweight_cost = 0.0\nweight_time = 0.0",
        );
        assert!(PlanSpec::from_str(&bad).is_err());
    }

    #[test]
    fn bad_ladders_rejected() {
        for (replacement, what) in [
            ("ladder = []", "empty ladder"),
            ("ladder = [4, 2]", "decreasing ladder"),
            ("ladder = [0]", "zero replicates"),
            ("ladder = [1.5]", "fractional replicates"),
        ] {
            let bad = MINI.replace("ladder = [2, 4]", replacement);
            assert!(
                PlanSpec::from_str(&bad).is_err(),
                "{what} should be rejected"
            );
        }
        let bad = MINI.replace("keep_fraction = 0.5", "keep_fraction = 0.0");
        assert!(PlanSpec::from_str(&bad).is_err());
        let bad = MINI.replace("min_keep = 1", "min_keep = 0");
        assert!(PlanSpec::from_str(&bad).is_err());
    }

    #[test]
    fn metrics_key_rejected_in_planner_specs() {
        let bad = MINI.replace(
            "strategies = [\"static_workers\"]",
            "strategies = [\"static_workers\"]\nmetrics = [\"cost\"]",
        );
        let err = PlanSpec::from_str(&bad).unwrap_err().to_string();
        assert!(err.contains("metrics"), "{err}");
    }

    /// The sweep-level `replicates` key would be silently dead in a
    /// planner spec (the ladder governs replicate counts) — reject it
    /// so a copied-over sweep spec cannot quietly mean something else.
    #[test]
    fn replicates_key_rejected_in_planner_specs() {
        let bad = MINI.replace(
            "strategies = [\"static_workers\"]",
            "strategies = [\"static_workers\"]\nreplicates = 32",
        );
        let err = PlanSpec::from_str(&bad).unwrap_err().to_string();
        assert!(err.contains("replicates"), "{err}");
        assert!(err.contains("ladder"), "{err}");
    }

    #[test]
    fn unknown_keys_name_the_planner_tables() {
        let bad = MINI.replace("[objective]", "[objective]\ngoall = 1");
        let err = PlanSpec::from_str(&bad).unwrap_err().to_string();
        assert!(err.contains("objective.goall"), "{err}");
        assert!(err.contains("in table [objective]"), "{err}");
        let bad = MINI.replace("[search]", "[search]\nladders = [2]");
        let err = PlanSpec::from_str(&bad).unwrap_err().to_string();
        assert!(err.contains("search.ladders"), "{err}");
        // scenario-side typos still carry the lineup position logic
        let bad = MINI.replace("[job]", "[job]\nepss = 0.2");
        let err = PlanSpec::from_str(&bad).unwrap_err().to_string();
        assert!(err.contains("job.epss"), "{err}");
    }

    #[test]
    fn constraint_slack_spares_tight_surfaces() {
        let o = Objective {
            goal: Goal::MinCost,
            deadline: Some(1000.0),
            budget: None,
            error_bound: None,
        };
        // a deadline-tight surface with one ulp of rounding excess is
        // not a violation...
        assert!(o.violation(1.0, 1000.0 * (1.0 + 1e-12), 0.1).is_none());
        // ...a real excess is
        let v = o.violation(1.0, 1001.0, 0.1).unwrap();
        assert!(v.contains("deadline"), "{v}");
        assert!(!o.feasible(1.0, 1001.0, 0.1));
        assert!(o.feasible(1.0, 999.0, 0.1));
    }
}
