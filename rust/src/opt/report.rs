//! Planner outputs: ranked recommendation table, Pareto frontier, and
//! machine-readable CSV/JSON — through the same writers every sweep
//! output uses ([`StrTable`] with RFC-4180 quoting, the shared
//! hand-rolled JSON convention of [`crate::util::json`]).

use crate::util::csv::StrTable;
use crate::util::json;

use super::planner::{Fate, PlanOutcome};
use super::spec::Goal;

fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        String::new()
    }
}

/// The candidate's human-readable detail column: who dominated it,
/// which constraint it violated, or why its plan failed.
fn detail(outcome: &PlanOutcome, fate: &Fate) -> String {
    match fate {
        Fate::Evaluated { .. } => String::new(),
        Fate::Folded { into } => {
            format!("folded into '{}'", outcome.candidates[*into].label)
        }
        Fate::PlanError { error } => error.clone(),
        Fate::Infeasible { violated } => violated.clone(),
        Fate::Dominated { by } => {
            format!("dominated by '{}'", outcome.candidates[*by].label)
        }
    }
}

/// One row per lattice candidate: ranked recommendations first (best
/// to worst), then the pruned/folded remainder in lattice order.
pub fn to_csv(outcome: &PlanOutcome) -> StrTable {
    let mut t = StrTable::new(&[
        "rank",
        "label",
        "strategy",
        "fate",
        "feasible",
        "frontier",
        "score",
        "cost_mean",
        "cost_std",
        "time_mean",
        "time_std",
        "err_mean",
        "err_std",
        "iters_mean",
        "replicates",
        "rung",
        "exp_cost",
        "exp_time",
        "bound_err",
        "detail",
    ]);
    let row = |ci: usize| -> Vec<String> {
        let c = &outcome.candidates[ci];
        let (sim_cols, score) = match c.sim {
            Some(s) => (
                [
                    num(s.cost_mean),
                    num(s.cost_std),
                    num(s.time_mean),
                    num(s.time_std),
                    num(s.err_mean),
                    num(s.err_std),
                    num(s.iters_mean),
                    format!("{}", s.replicates),
                ],
                num(outcome.objective.score(s.cost_mean, s.time_mean)),
            ),
            None => (std::array::from_fn(|_| String::new()), String::new()),
        };
        let rung = match c.fate {
            Fate::Evaluated { rung } => format!("{rung}"),
            _ => String::new(),
        };
        let (exp_cost, exp_time, bound_err) = match c.surface {
            Some(s) => (num(s.cost), num(s.time), num(s.err)),
            None => (String::new(), String::new(), String::new()),
        };
        let mut r = vec![
            c.rank.map(|r| format!("{r}")).unwrap_or_default(),
            c.label.clone(),
            c.strategy.clone(),
            c.fate.tag().to_string(),
            format!("{}", c.feasible),
            format!("{}", c.frontier),
            score,
        ];
        r.extend(sim_cols);
        r.push(rung);
        r.push(exp_cost);
        r.push(exp_time);
        r.push(bound_err);
        r.push(detail(outcome, &c.fate));
        r
    };
    for &ci in &outcome.recommendations {
        t.push(row(ci));
    }
    for (ci, c) in outcome.candidates.iter().enumerate() {
        if !matches!(c.fate, Fate::Evaluated { .. }) {
            t.push(row(ci));
        }
    }
    t
}

/// The full outcome as JSON (hand-rolled: the build is offline and
/// dependency-free). Non-finite statistics serialise as `null`.
pub fn to_json(outcome: &PlanOutcome, threads: usize) -> String {
    let o = &outcome.objective;
    let opt_num = |v: Option<f64>| {
        v.map(json::num).unwrap_or_else(|| "null".to_string())
    };
    let mut out = String::new();
    out.push_str(&format!(
        "{{\n  \"planner\": \"{}\",\n  \"seed\": {},\n  \
         \"threads\": {},\n  \"digest\": \"{:016x}\",\n",
        json::esc(&outcome.name),
        outcome.seed,
        threads,
        outcome.digest()
    ));
    let goal = match o.goal {
        Goal::Weighted { cost, time } => format!(
            "{{\"name\": \"weighted\", \"weight_cost\": {}, \
             \"weight_time\": {}}}",
            json::num(cost),
            json::num(time)
        ),
        g => format!("{{\"name\": \"{}\"}}", g.name()),
    };
    out.push_str(&format!(
        "  \"objective\": {{\"goal\": {goal}, \"deadline\": {}, \
         \"budget\": {}, \"error_bound\": {}}},\n",
        opt_num(o.deadline),
        opt_num(o.budget),
        opt_num(o.error_bound)
    ));
    let ladder: Vec<String> =
        outcome.search.ladder.iter().map(|r| format!("{r}")).collect();
    out.push_str(&format!(
        "  \"search\": {{\"ladder\": [{}], \"keep_fraction\": {}, \
         \"min_keep\": {}, \"prune\": {}}},\n",
        ladder.join(", "),
        json::num(outcome.search.keep_fraction),
        outcome.search.min_keep,
        outcome.search.prune
    ));
    let counts = outcome.counts();
    out.push_str(&format!(
        "  \"lattice_points\": {},\n  \"counts\": {{\"folded\": {}, \
         \"plan_errors\": {}, \"infeasible\": {}, \"dominated\": {}, \
         \"evaluated\": {}}},\n",
        outcome.lattice_points,
        counts.folded,
        counts.plan_errors,
        counts.infeasible,
        counts.dominated,
        counts.evaluated
    ));
    out.push_str(&format!(
        "  \"incumbent\": {},\n",
        outcome
            .incumbent_label()
            .map(|l| format!("\"{}\"", json::esc(l)))
            .unwrap_or_else(|| "null".to_string())
    ));
    let frontier: Vec<String> = outcome
        .frontier_labels()
        .iter()
        .map(|l| format!("\"{}\"", json::esc(l)))
        .collect();
    out.push_str(&format!("  \"frontier\": [{}],\n", frontier.join(", ")));
    out.push_str("  \"candidates\": [\n");
    for (ci, c) in outcome.candidates.iter().enumerate() {
        let sim = match c.sim {
            Some(s) => format!(
                "{{\"replicates\": {}, \"cost_mean\": {}, \
                 \"cost_std\": {}, \"time_mean\": {}, \"time_std\": {}, \
                 \"err_mean\": {}, \"err_std\": {}, \"iters_mean\": {}}}",
                s.replicates,
                json::num(s.cost_mean),
                json::num(s.cost_std),
                json::num(s.time_mean),
                json::num(s.time_std),
                json::num(s.err_mean),
                json::num(s.err_std),
                json::num(s.iters_mean)
            ),
            None => "null".to_string(),
        };
        let analytic = match c.surface {
            Some(s) => format!(
                "{{\"exp_cost\": {}, \"exp_time\": {}, \"bound_err\": {}}}",
                json::num(s.cost),
                json::num(s.time),
                json::num(s.err)
            ),
            None => "null".to_string(),
        };
        out.push_str(&format!(
            "    {{\"label\": \"{}\", \"strategy\": \"{}\", \
             \"fate\": \"{}\", \"detail\": \"{}\", \"feasible\": {}, \
             \"frontier\": {}, \"rank\": {}, \"sim\": {sim}, \
             \"analytic\": {analytic}}}{}\n",
            json::esc(&c.label),
            json::esc(&c.strategy),
            c.fate.tag(),
            json::esc(&detail(outcome, &c.fate)),
            c.feasible,
            c.frontier,
            c.rank
                .map(|r| format!("{r}"))
                .unwrap_or_else(|| "null".to_string()),
            if ci + 1 < outcome.candidates.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n  \"rungs\": [\n");
    for (ri, r) in outcome.rungs.iter().enumerate() {
        let members: Vec<String> = r
            .members
            .iter()
            .map(|&ci| {
                format!("\"{}\"", json::esc(&outcome.candidates[ci].label))
            })
            .collect();
        out.push_str(&format!(
            "    {{\"replicates\": {}, \"seed\": {}, \"members\": [{}]}}{}\n",
            r.replicates,
            r.seed,
            members.join(", "),
            if ri + 1 < outcome.rungs.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Human-readable planner summary: counts, rung trace, the top of the
/// ranked table, the frontier, and the digest line the CI smoke diffs.
pub fn print(outcome: &PlanOutcome) {
    let counts = outcome.counts();
    println!(
        "== optimize {}  ({} lattice points: {} folded, {} plan errors, \
         {} infeasible, {} dominated, {} simulated)",
        outcome.name,
        outcome.lattice_points,
        counts.folded,
        counts.plan_errors,
        counts.infeasible,
        counts.dominated,
        counts.evaluated
    );
    for (ri, r) in outcome.rungs.iter().enumerate() {
        println!(
            "  rung {ri}: {} candidates x {} replicates",
            r.members.len(),
            r.replicates
        );
    }
    match outcome.incumbent_label() {
        Some(l) => println!("  incumbent: {l}"),
        None => println!("  incumbent: none (no feasible candidate)"),
    }
    let top = outcome.recommendations.len().min(8);
    for &ci in &outcome.recommendations[..top] {
        let c = &outcome.candidates[ci];
        let s = c.sim.expect("ranked candidates carry stats");
        println!(
            "  #{:<3} {:<28} cost={:<12.2} time={:<12.1} err={:<8.4} \
             {}{}",
            c.rank.unwrap_or(0),
            c.label,
            s.cost_mean,
            s.time_mean,
            s.err_mean,
            if c.feasible { "feasible" } else { "INFEASIBLE" },
            if c.frontier { "  [pareto]" } else { "" }
        );
    }
    if outcome.recommendations.len() > top {
        println!(
            "  ... {} more in the CSV/JSON output",
            outcome.recommendations.len() - top
        );
    }
    let frontier = outcome.frontier_labels();
    println!(
        "  pareto frontier ({} of {} simulated): {}",
        frontier.len(),
        counts.evaluated,
        frontier.join(" | ")
    );
    println!("  digest: {:016x}", outcome.digest());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::planner::{run_plan, PlannerConfig};
    use crate::opt::spec::PlanSpec;

    fn outcome() -> PlanOutcome {
        let plan = PlanSpec::from_str(
            r#"
name = "report"
strategies = ["static_workers"]
axes = ["price"]

[objective]
goal = "min_cost"

[search]
ladder = [2]
min_keep = 1

[job]
n = 4
j = 60
preempt_q = 0.3

[runtime]
kind = "deterministic"
r = 10.0

[market]
kind = "fixed"

[axis.price]
path = "job.unit_price"
values = [1.0, 2.0]
"#,
        )
        .unwrap();
        run_plan(&plan, &PlannerConfig { seed: 5, threads: 2 }).unwrap()
    }

    #[test]
    fn csv_has_every_candidate_once_recommendations_first() {
        let out = outcome();
        let t = to_csv(&out);
        assert_eq!(t.rows.len(), out.candidates.len());
        assert_eq!(t.columns[0], "rank");
        // first row is rank 1; the dominated candidate follows with an
        // empty rank and its witness named in the detail column
        assert_eq!(t.rows[0][0], "1");
        assert_eq!(t.rows[0][1], "price=1");
        assert_eq!(t.rows[1][0], "");
        assert_eq!(t.rows[1][3], "dominated");
        assert!(t.rows[1][19].contains("price=1"), "{}", t.rows[1][19]);
        // the CSV text itself is parseable: header + one line per row
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 1 + t.rows.len());
    }

    #[test]
    fn json_is_structurally_balanced_and_carries_the_digest() {
        let out = outcome();
        let json = to_json(&out, 2);
        assert!(json.contains("\"planner\": \"report\""));
        assert!(json.contains(&format!("{:016x}", out.digest())));
        assert!(json.contains("\"goal\": {\"name\": \"min_cost\"}"));
        assert!(json.contains("\"fate\": \"dominated\""));
        assert!(json.contains("\"incumbent\": \"price=1\""));
        let bal = |open: char, close: char| {
            json.matches(open).count() == json.matches(close).count()
        };
        assert!(bal('{', '}') && bal('[', ']'));
    }
}
