//! Automated strategy search: analytic pruning + simulated refinement.
//!
//! The paper's headline deliverable is not the sweep itself but the
//! *derived practical strategies* — "by understanding these trade-offs
//! between preemption probability, accuracy, and training time, we
//! derive practical strategies for configuring distributed SGD jobs."
//! `opt` is that layer: given an objective file (a scenario spec plus
//! `[objective]`/`[search]` tables — [`spec`]), the planner
//!
//! 1. **prunes analytically** ([`surface`], [`planner`] stage 1):
//!    evaluates the closed-form Theorem 2/3 cost/time/error surfaces
//!    (exact `E[1/y]` via `preempt`, `F(b)` via the market model) over
//!    the candidate lattice and discards provably dominated or
//!    constraint-violating configurations — before a single replicate
//!    runs;
//! 2. **refines by simulation** ([`planner`] stage 2): dispatches only
//!    the survivors through the existing `sweep` work-stealing pool
//!    and event engine (classic and event-native kinds alike, via
//!    `PlannedStrategy::build_policy`), successive-halving style on a
//!    fixed replicate ladder, shrinking the candidate set around the
//!    incumbent.
//!
//! The product ([`report`]) is a ranked recommendation table plus the
//! full Pareto frontier over (expected cost, expected time, error
//! bound / achieved proxy), emitted via the shared CSV/JSON writers
//! with a digest line that is bit-identical at any `--threads`
//! (DESIGN.md §7). The `volatile-sgd optimize --spec FILE` subcommand
//! is the CLI entry; `examples/configs/optimize_deadline.toml` ships
//! as the worked preset (deadline-constrained cost minimisation over
//! `one_bid` vs `elastic_fleet` vs `deadline_aware`).
//!
//! # Example
//!
//! ```
//! use volatile_sgd::opt::{self, PlanSpec, PlannerConfig};
//!
//! let plan = PlanSpec::from_str(r#"
//! name = "doc"
//! strategies = ["static_workers"]
//! axes = ["price"]
//!
//! [objective]
//! goal = "min_cost"
//!
//! [search]
//! ladder = [2]
//! min_keep = 1
//!
//! [job]
//! n = 4
//! j = 50
//! preempt_q = 0.3
//!
//! [runtime]
//! kind = "deterministic"
//! r = 10.0
//!
//! [market]
//! kind = "fixed"
//!
//! [axis.price]
//! path = "job.unit_price"
//! values = [1.0, 2.0]
//! "#).unwrap();
//! let out = opt::run_plan(&plan, &PlannerConfig { seed: 7, threads: 2 }).unwrap();
//! // the doubled unit price is provably dominated and never simulated
//! assert_eq!(out.counts().dominated, 1);
//! assert_eq!(out.incumbent_label(), Some("price=1"));
//! ```

pub mod planner;
pub mod report;
pub mod spec;
pub mod surface;

pub use planner::{
    build_scenario, evaluate_rung, run_plan, run_plan_cached,
    run_plan_instrumented, rung_seed, Candidate, Fate, FateCounts,
    PlanOutcome, PlannerConfig, RungRecord, SimStats, SIM_METRICS,
};
pub use spec::{Goal, Objective, PlanSpec, SearchSpec};
pub use surface::{admissible_surface, beats, Surface};

/// The shipped planner preset, embedded like the sweep presets so
/// `volatile-sgd optimize` works from any directory when `--spec` is
/// omitted.
pub fn preset_toml() -> &'static str {
    include_str!("../../../examples/configs/optimize_deadline.toml")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shipped_preset_parses_and_validates() {
        let plan = PlanSpec::from_str(preset_toml()).unwrap();
        assert_eq!(plan.scenario.name, "optimize_deadline");
        assert_eq!(plan.objective.goal, Goal::MinCost);
        assert!(plan.objective.deadline.is_some());
        // deadline coupling: the bid plans target the constraint
        assert_eq!(plan.scenario.job.theta, plan.objective.deadline);
        let sc = build_scenario(&plan).unwrap();
        use crate::sweep::Scenario;
        assert_eq!(sc.points(), 36); // 2 n x 3 budget x 2 thresh x 3 strategies
    }
}
