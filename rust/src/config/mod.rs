//! Configuration substrate: TOML-subset parser + typed experiment schema.

pub mod schema;
pub mod toml;

pub use schema::{ExperimentConfig, StrategyKind};
pub use toml::{Doc, TrackedDoc, Value};
