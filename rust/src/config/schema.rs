//! Typed experiment configuration assembled from a [`Doc`].
//!
//! One config file fully describes a run: model + artifacts, price model,
//! runtime model, SGD bound constants, the job constraints (eps, theta)
//! and the strategy. The shipped scenario specs under `examples/configs/`
//! use the richer sweep schema (`exp::spec`); this simpler single-run
//! shape drives `volatile-sgd simulate`. Example:
//!
//! ```toml
//! seed = 42
//! model = "cnn"
//! artifacts = "artifacts"
//!
//! [market]
//! kind = "uniform"      # uniform | gaussian | trace
//! lo = 0.2
//! hi = 1.0
//!
//! [runtime]
//! kind = "exp"          # exp | deterministic
//! lambda = 0.25
//! delta = 0.5
//!
//! [job]
//! n = 8
//! eps = 0.35
//! theta = 200000.0
//!
//! [strategy]
//! kind = "two_bids"     # no_interruption | one_bid | two_bids | dynamic
//! n1 = 4
//! ```

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::market::{PriceModel, SpotTrace};
use crate::sim::OverheadModel;
use crate::theory::bounds::{ErrorBound, SgdHyper};
use crate::theory::runtime_model::RuntimeModel;

use super::toml::Doc;

/// Which coordination strategy drives the job.
#[derive(Clone, Debug, PartialEq)]
pub enum StrategyKind {
    /// bid the support max (Sharma et al. baseline)
    NoInterruption,
    /// Theorem 2
    OneBid,
    /// Theorem 3 with a fixed group split
    TwoBids { n1: usize },
    /// Two-group bids placed directly at CDF fractions, no optimisation:
    /// `b1 = F^-1(f1)`, `b2 = F^-1(gamma * f1)` — the Fig. 2 surface
    /// parameterisation.
    BidFractions { n1: usize, f1: f64, gamma: f64 },
    /// Sec. VI dynamic strategy: staged growth + re-optimised bids
    DynamicBids { n1: usize, stage_iters: u64 },
    /// Sec. V static provisioning (Theorem 4)
    StaticWorkers,
    /// Sec. V dynamic n_j = ceil(n0 eta^{j-1}) (Theorem 5)
    DynamicWorkers { eta: f64 },
    /// Event-native (`sim::policy`, DESIGN.md §6): rebid after every
    /// preemption, bids scaled by `rebid_factor`
    NoticeRebid { rebid_factor: f64 },
    /// Event-native: resize the fleet at each price revision to keep
    /// expected spend under `budget_rate` $/unit-time
    ElasticFleet { budget_rate: f64 },
    /// Event-native: escalate to on-demand (bid = ∞) when the
    /// completion proxy drops below `escalate_threshold`
    DeadlineAware { escalate_threshold: f64 },
    /// Portfolio-only (`market::portfolio`, DESIGN.md §10): keep the
    /// fleet on the portfolio entry with the lowest effective price
    /// (`price / speed`), migrating on `PriceRevision` when the best
    /// entry undercuts the current one by more than `hysteresis`;
    /// each migration is billed as checkpoint + restart via
    /// `[overhead]`. Only valid in specs with a `[[portfolio]]` array.
    PortfolioMigrate { hysteresis: f64 },
    /// Portfolio-only, forecast-driven (`sim::forecast`, DESIGN.md
    /// §11): score every entry by forecast progress-per-dollar
    /// (sliding-window q̂ over `window` slots with Laplace
    /// `smoothing`, EWMA price level) and migrate *before* preemption
    /// when the best entry clears the `hysteresis` band after paying
    /// the move cost amortized over the `horizon_s` lookahead.
    ProactiveMigrate {
        hysteresis: f64,
        window: usize,
        horizon_s: f64,
        smoothing: f64,
    },
    /// Event-native, forecast-driven: re-plan the Theorem-2 bid
    /// against an EWMA price-level forecast (`window` span) whose
    /// regime detector re-anchors when an innovation exceeds
    /// `innovation_threshold` standard deviations.
    LookaheadBid { window: usize, innovation_threshold: f64 },
}

impl StrategyKind {
    /// The config-file name of this kind (what `from_name` parses and
    /// what `simulate` uses for output labels/paths).
    pub fn canonical_name(&self) -> &'static str {
        match self {
            StrategyKind::NoInterruption => "no_interruption",
            StrategyKind::OneBid => "one_bid",
            StrategyKind::TwoBids { .. } => "two_bids",
            StrategyKind::BidFractions { .. } => "bid_fractions",
            StrategyKind::DynamicBids { .. } => "dynamic",
            StrategyKind::StaticWorkers => "static_workers",
            StrategyKind::DynamicWorkers { .. } => "dynamic_workers",
            StrategyKind::NoticeRebid { .. } => "notice_rebid",
            StrategyKind::ElasticFleet { .. } => "elastic_fleet",
            StrategyKind::DeadlineAware { .. } => "deadline_aware",
            StrategyKind::PortfolioMigrate { .. } => "portfolio_migrate",
            StrategyKind::ProactiveMigrate { .. } => "proactive_migrate",
            StrategyKind::LookaheadBid { .. } => "lookahead_bid",
        }
    }

    /// True for the event-native policy kinds (`sim::policy`): they
    /// implement `Policy` directly, so they run only on the event
    /// engine — the pre-engine reference lockstep loop cannot model
    /// them, and `simulate`/sweeps build them via
    /// `PlannedStrategy::build_policy`.
    pub fn event_native(&self) -> bool {
        matches!(
            self,
            StrategyKind::NoticeRebid { .. }
                | StrategyKind::ElasticFleet { .. }
                | StrategyKind::DeadlineAware { .. }
                | StrategyKind::PortfolioMigrate { .. }
                | StrategyKind::ProactiveMigrate { .. }
                | StrategyKind::LookaheadBid { .. }
        )
    }

    /// Parse a kind name into a `StrategyKind` with defaults scaled to a
    /// fleet of `n` workers (`n1 = n/2`, the paper's split). Accepts the
    /// figure-label plural "no_interruptions" as an alias.
    pub fn from_name(name: &str, n: usize) -> Result<Self> {
        let n1 = (n / 2).max(1);
        Ok(match name {
            "no_interruption" | "no_interruptions" => {
                StrategyKind::NoInterruption
            }
            "one_bid" => StrategyKind::OneBid,
            "two_bids" => StrategyKind::TwoBids { n1 },
            "bid_fractions" => {
                StrategyKind::BidFractions { n1, f1: 0.5, gamma: 1.0 }
            }
            "dynamic" | "dynamic_bids" => {
                StrategyKind::DynamicBids { n1, stage_iters: 4_000 }
            }
            "static_workers" => StrategyKind::StaticWorkers,
            "dynamic_workers" => StrategyKind::DynamicWorkers { eta: 1.0004 },
            "notice_rebid" => StrategyKind::NoticeRebid { rebid_factor: 1.5 },
            "elastic_fleet" => {
                StrategyKind::ElasticFleet { budget_rate: 2.0 }
            }
            "deadline_aware" => {
                StrategyKind::DeadlineAware { escalate_threshold: 0.5 }
            }
            "portfolio_migrate" => {
                StrategyKind::PortfolioMigrate { hysteresis: 0.05 }
            }
            "proactive_migrate" => StrategyKind::ProactiveMigrate {
                hysteresis: 0.05,
                window: 64,
                horizon_s: 600.0,
                smoothing: 1.0,
            },
            "lookahead_bid" => StrategyKind::LookaheadBid {
                window: 64,
                innovation_threshold: 3.0,
            },
            other => bail!(
                "unknown strategy kind '{other}' (no_interruption | one_bid \
                 | two_bids | bid_fractions | dynamic | static_workers | \
                 dynamic_workers | notice_rebid | elastic_fleet | \
                 deadline_aware | portfolio_migrate | proactive_migrate | \
                 lookahead_bid)"
            ),
        })
    }
}

/// Fully-resolved experiment configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub seed: u64,
    pub model: String,
    pub artifacts_dir: PathBuf,
    pub price: PriceModel,
    /// raw trace when kind = "trace" (price is its empirical CDF)
    pub trace: Option<SpotTrace>,
    pub runtime: RuntimeModel,
    pub bound: ErrorBound,
    pub n: usize,
    pub eps: f64,
    pub theta: f64,
    pub j_fixed: Option<u64>,
    pub strategy: StrategyKind,
    /// preemption probability for Sec. V experiments
    pub preempt_q: f64,
    /// `[overhead]` worker-lifecycle model (checkpoint/restart costs),
    /// executed by the event engine; absent table = the paper's
    /// frictionless model
    pub overhead: OverheadModel,
    pub out_dir: PathBuf,
}

impl ExperimentConfig {
    pub fn from_doc(doc: &Doc) -> Result<Self> {
        let seed = doc.i64_or("seed", 42) as u64;
        let model = doc.str_or("model", "cnn").to_string();
        let artifacts_dir =
            PathBuf::from(doc.str_or("artifacts", "artifacts"));
        let out_dir = PathBuf::from(doc.str_or("out", "out"));

        // ------------------------------------------------------ market
        let mut trace = None;
        let price = match doc.str_or("market.kind", "uniform") {
            "uniform" => PriceModel::Uniform {
                lo: doc.f64_or("market.lo", 0.2),
                hi: doc.f64_or("market.hi", 1.0),
            },
            "gaussian" => PriceModel::TruncGaussian {
                mean: doc.f64_or("market.mean", 0.6),
                std: doc.f64_or("market.std", 0.175),
                lo: doc.f64_or("market.lo", 0.2),
                hi: doc.f64_or("market.hi", 1.0),
            },
            "trace" => {
                let path = doc.require_str("market.path")?;
                let tr = SpotTrace::load(path)?;
                let cdf = tr.empirical_cdf(doc.f64_or(
                    "market.cdf_resolution",
                    60.0,
                ));
                trace = Some(tr);
                PriceModel::Empirical(cdf)
            }
            other => bail!("unknown market.kind '{other}'"),
        };

        // ----------------------------------------------------- runtime
        let runtime = match doc.str_or("runtime.kind", "exp") {
            "exp" => RuntimeModel::ExpStragglers {
                lambda: doc.f64_or("runtime.lambda", 0.25),
                delta: doc.f64_or("runtime.delta", 0.5),
            },
            "deterministic" => RuntimeModel::Deterministic {
                r: doc.f64_or("runtime.r", 10.0),
            },
            other => bail!("unknown runtime.kind '{other}'"),
        };

        // ------------------------------------------------------- bound
        let defaults = SgdHyper::paper_cnn();
        let hyper = SgdHyper {
            alpha: doc.f64_or("sgd.alpha", defaults.alpha),
            c: doc.f64_or("sgd.c", defaults.c),
            mu: doc.f64_or("sgd.mu", defaults.mu),
            l: doc.f64_or("sgd.l", defaults.l),
            m: doc.f64_or("sgd.m", defaults.m),
            a0: doc.f64_or("sgd.a0", defaults.a0),
        };
        hyper.validate().map_err(|e| anyhow::anyhow!(e))?;

        // --------------------------------------------------------- job
        let n = doc.i64_or("job.n", 8) as usize;
        if n == 0 {
            bail!("job.n must be positive");
        }
        let eps = doc.f64_or("job.eps", 0.35);
        let theta = doc.f64_or("job.theta", 200_000.0);
        let j_fixed = doc.get("job.j").and_then(|v| v.as_int()).map(|j| j as u64);

        // ---------------------------------------------------- strategy
        let mut strategy =
            StrategyKind::from_name(doc.str_or("strategy.kind", "one_bid"), n)
                .context("strategy.kind")?;
        match &mut strategy {
            StrategyKind::TwoBids { n1 }
            | StrategyKind::BidFractions { n1, .. }
            | StrategyKind::DynamicBids { n1, .. } => {
                *n1 = doc.i64_or("strategy.n1", *n1 as i64) as usize;
            }
            _ => {}
        }
        match &mut strategy {
            StrategyKind::BidFractions { f1, gamma, .. } => {
                *f1 = doc.f64_or("strategy.f1", *f1);
                *gamma = doc.f64_or("strategy.gamma", *gamma);
                if !(*f1 > 0.0 && *f1 <= 1.0) {
                    bail!("strategy.f1 must be in (0, 1], got {f1}");
                }
                if !(0.0..=1.0).contains(gamma) {
                    bail!("strategy.gamma must be in [0, 1], got {gamma}");
                }
            }
            StrategyKind::DynamicBids { stage_iters, .. } => {
                *stage_iters =
                    doc.i64_or("strategy.stage_iters", *stage_iters as i64)
                        as u64;
            }
            StrategyKind::DynamicWorkers { eta } => {
                *eta = doc.f64_or("strategy.eta", *eta);
            }
            StrategyKind::NoticeRebid { rebid_factor } => {
                *rebid_factor =
                    doc.f64_or("strategy.rebid_factor", *rebid_factor);
                if !rebid_factor.is_finite() || *rebid_factor < 1.0 {
                    bail!(
                        "strategy.rebid_factor must be >= 1, got \
                         {rebid_factor}"
                    );
                }
            }
            StrategyKind::ElasticFleet { budget_rate } => {
                *budget_rate =
                    doc.f64_or("strategy.budget_rate", *budget_rate);
                if !budget_rate.is_finite() || *budget_rate <= 0.0 {
                    bail!(
                        "strategy.budget_rate must be finite and > 0, got \
                         {budget_rate}"
                    );
                }
            }
            StrategyKind::DeadlineAware { escalate_threshold } => {
                *escalate_threshold = doc.f64_or(
                    "strategy.escalate_threshold",
                    *escalate_threshold,
                );
                if !escalate_threshold.is_finite()
                    || *escalate_threshold <= 0.0
                    || *escalate_threshold > 1.0
                {
                    bail!(
                        "strategy.escalate_threshold must be in (0, 1], \
                         got {escalate_threshold}"
                    );
                }
            }
            StrategyKind::PortfolioMigrate { hysteresis } => {
                *hysteresis = doc.f64_or("strategy.hysteresis", *hysteresis);
                if !hysteresis.is_finite() || !(0.0..1.0).contains(hysteresis)
                {
                    bail!(
                        "strategy.hysteresis must be in [0, 1), got \
                         {hysteresis}"
                    );
                }
            }
            StrategyKind::ProactiveMigrate {
                hysteresis,
                window,
                horizon_s,
                smoothing,
            } => {
                *hysteresis = doc.f64_or("strategy.hysteresis", *hysteresis);
                if !hysteresis.is_finite() || !(0.0..1.0).contains(hysteresis)
                {
                    bail!(
                        "strategy.hysteresis must be in [0, 1), got \
                         {hysteresis}"
                    );
                }
                let w = doc.i64_or("strategy.window", *window as i64);
                if w < 1 {
                    bail!("strategy.window must be >= 1, got {w}");
                }
                *window = w as usize;
                *horizon_s = doc.f64_or("strategy.horizon_s", *horizon_s);
                if !horizon_s.is_finite() || *horizon_s <= 0.0 {
                    bail!(
                        "strategy.horizon_s must be finite and > 0, got \
                         {horizon_s}"
                    );
                }
                *smoothing = doc.f64_or("strategy.smoothing", *smoothing);
                if !smoothing.is_finite() || *smoothing < 0.0 {
                    bail!(
                        "strategy.smoothing must be finite and >= 0, got \
                         {smoothing}"
                    );
                }
            }
            StrategyKind::LookaheadBid { window, innovation_threshold } => {
                let w = doc.i64_or("strategy.window", *window as i64);
                if w < 1 {
                    bail!("strategy.window must be >= 1, got {w}");
                }
                *window = w as usize;
                *innovation_threshold = doc.f64_or(
                    "strategy.innovation_threshold",
                    *innovation_threshold,
                );
                if !innovation_threshold.is_finite()
                    || *innovation_threshold <= 0.0
                {
                    bail!(
                        "strategy.innovation_threshold must be finite and \
                         > 0, got {innovation_threshold}"
                    );
                }
            }
            _ => {}
        }
        match &strategy {
            StrategyKind::TwoBids { n1 }
            | StrategyKind::DynamicBids { n1, .. } => {
                if *n1 == 0 || *n1 >= n {
                    bail!("strategy.n1 must satisfy 0 < n1 < n");
                }
            }
            // the uniform degenerate n1 == n is meaningful for fractions
            StrategyKind::BidFractions { n1, .. } => {
                if *n1 == 0 || *n1 > n {
                    bail!("strategy.n1 must satisfy 0 < n1 <= n");
                }
            }
            _ => {}
        }

        // ---------------------------------------------------- overhead
        let ckpt_every = doc.i64_or("overhead.checkpoint_every_iters", 0);
        if ckpt_every < 0 {
            bail!(
                "overhead.checkpoint_every_iters must be >= 0, got \
                 {ckpt_every}"
            );
        }
        let overhead = OverheadModel {
            checkpoint_every_iters: ckpt_every as u64,
            checkpoint_cost_s: doc.f64_or("overhead.checkpoint_cost_s", 0.0),
            restart_delay_s: doc.f64_or("overhead.restart_delay_s", 0.0),
            lost_work_on_preempt: doc
                .bool_or("overhead.lost_work_on_preempt", false),
            preempt_notice_s: doc.f64_or("overhead.preempt_notice_s", 0.0),
        };
        overhead.validate()?;

        Ok(ExperimentConfig {
            seed,
            model,
            artifacts_dir,
            price,
            trace,
            runtime,
            bound: ErrorBound::new(hyper),
            n,
            eps,
            theta,
            j_fixed,
            strategy,
            preempt_q: doc.f64_or("job.preempt_q", 0.5),
            overhead,
            out_dir,
        })
    }

    pub fn from_str(text: &str) -> Result<Self> {
        Self::from_doc(&Doc::parse(text)?)
    }

    pub fn from_file(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_str(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_build() {
        let c = ExperimentConfig::from_str("").unwrap();
        assert_eq!(c.model, "cnn");
        assert_eq!(c.n, 8);
        assert_eq!(c.strategy, StrategyKind::OneBid);
        assert!(c.trace.is_none());
        assert!(!c.overhead.enabled());
    }

    #[test]
    fn overhead_table_parses_and_validates() {
        let c = ExperimentConfig::from_str(
            "[overhead]\ncheckpoint_every_iters = 50\n\
             checkpoint_cost_s = 5.0\nrestart_delay_s = 60.0\n\
             lost_work_on_preempt = true\n",
        )
        .unwrap();
        assert!(c.overhead.enabled());
        assert_eq!(c.overhead.checkpoint_every_iters, 50);
        assert_eq!(c.overhead.restart_delay_s, 60.0);
        assert!(c.overhead.lost_work_on_preempt);
        assert!(ExperimentConfig::from_str(
            "[overhead]\nrestart_delay_s = -3.0\n"
        )
        .is_err());
        assert!(ExperimentConfig::from_str(
            "[overhead]\ncheckpoint_every_iters = -50\n"
        )
        .is_err());
    }

    #[test]
    fn full_config_parses() {
        let c = ExperimentConfig::from_str(
            r#"
seed = 7
model = "lm_tiny"

[market]
kind = "gaussian"
mean = 0.6
std = 0.175

[runtime]
kind = "deterministic"
r = 12.0

[job]
n = 4
eps = 0.4
theta = 100.0
j = 500

[strategy]
kind = "two_bids"
n1 = 2
"#,
        )
        .unwrap();
        assert_eq!(c.seed, 7);
        assert!(matches!(c.price, PriceModel::TruncGaussian { .. }));
        assert!(matches!(
            c.runtime,
            RuntimeModel::Deterministic { r } if r == 12.0
        ));
        assert_eq!(c.j_fixed, Some(500));
        assert_eq!(c.strategy, StrategyKind::TwoBids { n1: 2 });
    }

    #[test]
    fn rejects_bad_strategy_split() {
        let bad = r#"
[job]
n = 4
[strategy]
kind = "two_bids"
n1 = 4
"#;
        assert!(ExperimentConfig::from_str(bad).is_err());
    }

    #[test]
    fn bid_fractions_parses() {
        let c = ExperimentConfig::from_str(
            "[job]\nn = 8\n[strategy]\nkind = \"bid_fractions\"\nn1 = 4\nf1 = 0.6\ngamma = 0.5\n",
        )
        .unwrap();
        assert_eq!(
            c.strategy,
            StrategyKind::BidFractions { n1: 4, f1: 0.6, gamma: 0.5 }
        );
        assert_eq!(c.strategy.canonical_name(), "bid_fractions");
        // out-of-range fractions are config errors, not downstream panics
        assert!(ExperimentConfig::from_str(
            "[strategy]\nkind = \"bid_fractions\"\ngamma = 3.0\n"
        )
        .is_err());
        assert!(ExperimentConfig::from_str(
            "[strategy]\nkind = \"bid_fractions\"\nf1 = 0.0\n"
        )
        .is_err());
    }

    #[test]
    fn kind_names_roundtrip() {
        for name in [
            "no_interruption",
            "one_bid",
            "two_bids",
            "bid_fractions",
            "dynamic",
            "static_workers",
            "dynamic_workers",
            "notice_rebid",
            "elastic_fleet",
            "deadline_aware",
            "portfolio_migrate",
            "proactive_migrate",
            "lookahead_bid",
        ] {
            let k = StrategyKind::from_name(name, 8).unwrap();
            assert_eq!(k.canonical_name(), name);
            assert_eq!(
                k.event_native(),
                matches!(
                    name,
                    "notice_rebid"
                        | "elastic_fleet"
                        | "deadline_aware"
                        | "portfolio_migrate"
                        | "proactive_migrate"
                        | "lookahead_bid"
                ),
                "{name}"
            );
        }
        // figure-label alias
        assert_eq!(
            StrategyKind::from_name("no_interruptions", 8).unwrap(),
            StrategyKind::NoInterruption
        );
        assert!(StrategyKind::from_name("zzz", 8).is_err());
    }

    #[test]
    fn event_native_kind_params_parse_and_validate() {
        let c = ExperimentConfig::from_str(
            "[strategy]\nkind = \"notice_rebid\"\nrebid_factor = 2.0\n",
        )
        .unwrap();
        assert_eq!(
            c.strategy,
            StrategyKind::NoticeRebid { rebid_factor: 2.0 }
        );
        let c = ExperimentConfig::from_str(
            "[strategy]\nkind = \"elastic_fleet\"\nbudget_rate = 0.8\n",
        )
        .unwrap();
        assert_eq!(c.strategy, StrategyKind::ElasticFleet { budget_rate: 0.8 });
        // out-of-range policy knobs are config errors, not panics
        assert!(ExperimentConfig::from_str(
            "[strategy]\nkind = \"notice_rebid\"\nrebid_factor = 0.5\n"
        )
        .is_err());
        assert!(ExperimentConfig::from_str(
            "[strategy]\nkind = \"elastic_fleet\"\nbudget_rate = 0.0\n"
        )
        .is_err());
        assert!(ExperimentConfig::from_str(
            "[strategy]\nkind = \"deadline_aware\"\nescalate_threshold = 1.5\n"
        )
        .is_err());
    }

    #[test]
    fn forecaster_kind_params_parse_and_validate() {
        let c = ExperimentConfig::from_str(
            "[strategy]\nkind = \"proactive_migrate\"\nwindow = 128\n\
             horizon_s = 900.0\nsmoothing = 0.5\nhysteresis = 0.1\n",
        )
        .unwrap();
        assert_eq!(
            c.strategy,
            StrategyKind::ProactiveMigrate {
                hysteresis: 0.1,
                window: 128,
                horizon_s: 900.0,
                smoothing: 0.5,
            }
        );
        let c = ExperimentConfig::from_str(
            "[strategy]\nkind = \"lookahead_bid\"\nwindow = 32\n\
             innovation_threshold = 4.0\n",
        )
        .unwrap();
        assert_eq!(
            c.strategy,
            StrategyKind::LookaheadBid {
                window: 32,
                innovation_threshold: 4.0,
            }
        );
        // out-of-range forecaster knobs are config errors, not panics
        for bad in [
            "[strategy]\nkind = \"proactive_migrate\"\nwindow = -3\n",
            "[strategy]\nkind = \"proactive_migrate\"\nwindow = 0\n",
            "[strategy]\nkind = \"proactive_migrate\"\nhorizon_s = 0.0\n",
            "[strategy]\nkind = \"proactive_migrate\"\nsmoothing = -1.0\n",
            "[strategy]\nkind = \"lookahead_bid\"\nwindow = 0\n",
            "[strategy]\nkind = \"lookahead_bid\"\n\
             innovation_threshold = 0.0\n",
        ] {
            assert!(
                ExperimentConfig::from_str(bad).is_err(),
                "must reject: {bad}"
            );
        }
    }

    #[test]
    fn rejects_unknown_kinds() {
        assert!(ExperimentConfig::from_str("[market]\nkind = \"zzz\"\n")
            .is_err());
        assert!(ExperimentConfig::from_str("[runtime]\nkind = \"zzz\"\n")
            .is_err());
        assert!(ExperimentConfig::from_str("[strategy]\nkind = \"zzz\"\n")
            .is_err());
    }

    #[test]
    fn rejects_unstable_sgd() {
        assert!(
            ExperimentConfig::from_str("[sgd]\nalpha = 100.0\n").is_err()
        );
    }
}
