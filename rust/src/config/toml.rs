//! Minimal TOML-subset parser (serde/toml are unavailable offline).
//!
//! Supported: `[section]` / `[a.b]` headers, `key = value` with string
//! ("..."), bool, integer, float, and flat arrays of those; `#` comments.
//! Keys are flattened to dotted paths: `[market] kind = "uniform"` becomes
//! `market.kind`. That covers every experiment config in this repo; the
//! parser rejects anything outside the subset loudly rather than guessing.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Bool(bool),
    Int(i64),
    Float(f64),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Floats accept integer literals too.
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// Flattened dotted-path -> value document.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    pub entries: BTreeMap<String, Value>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = BTreeMap::new();
        let mut prefix = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            let at = || format!("config line {}", lineno + 1);
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("{}: unterminated section header", at());
                }
                prefix = line[1..line.len() - 1].trim().to_string();
                if prefix.is_empty() {
                    bail!("{}: empty section name", at());
                }
                continue;
            }
            let eq = line
                .find('=')
                .with_context(|| format!("{}: expected key = value", at()))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                bail!("{}: empty key", at());
            }
            let val = parse_value(line[eq + 1..].trim())
                .with_context(|| at())?;
            let path = if prefix.is_empty() {
                key.to_string()
            } else {
                format!("{prefix}.{key}")
            };
            if entries.insert(path.clone(), val).is_some() {
                bail!("{}: duplicate key '{path}'", at());
            }
        }
        Ok(Doc { entries })
    }

    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    pub fn str_or<'a>(&'a self, path: &str, default: &'a str) -> &'a str {
        self.get(path).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn f64_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(Value::as_float).unwrap_or(default)
    }

    pub fn i64_or(&self, path: &str, default: i64) -> i64 {
        self.get(path).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(Value::as_bool).unwrap_or(default)
    }

    /// Required typed accessors.
    pub fn require_f64(&self, path: &str) -> Result<f64> {
        self.get(path)
            .and_then(Value::as_float)
            .with_context(|| format!("missing required float '{path}'"))
    }

    pub fn require_str(&self, path: &str) -> Result<&str> {
        self.get(path)
            .and_then(Value::as_str)
            .with_context(|| format!("missing required string '{path}'"))
    }
}

fn strip_comment(line: &str) -> &str {
    // a '#' outside quotes starts a comment
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(stripped) = s.strip_prefix('"') {
        let end = stripped
            .find('"')
            .context("unterminated string literal")?;
        if !stripped[end + 1..].trim().is_empty() {
            bail!("trailing junk after string literal");
        }
        return Ok(Value::Str(stripped[..end].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            bail!("unterminated array");
        }
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue; // trailing comma
                }
                let v = parse_value(part)?;
                if matches!(v, Value::Array(_)) {
                    bail!("nested arrays unsupported");
                }
                items.push(v);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value '{s}' (bare strings need quotes)")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let doc = Doc::parse(
            r#"
# experiment
seed = 42
name = "fig3"         # inline comment

[market]
kind = "uniform"
lo = 0.2
hi = 1.0

[strategy.two_bids]
n1 = 4
enabled = true
weights = [1, 2.5, 3]
"#,
        )
        .unwrap();
        assert_eq!(doc.i64_or("seed", 0), 42);
        assert_eq!(doc.require_str("name").unwrap(), "fig3");
        assert_eq!(doc.require_str("market.kind").unwrap(), "uniform");
        assert_eq!(doc.require_f64("market.lo").unwrap(), 0.2);
        assert_eq!(doc.i64_or("strategy.two_bids.n1", 0), 4);
        assert!(doc.bool_or("strategy.two_bids.enabled", false));
        let w = doc.get("strategy.two_bids.weights").unwrap();
        assert_eq!(w.as_array().unwrap().len(), 3);
        assert_eq!(w.as_array().unwrap()[1].as_float(), Some(2.5));
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = Doc::parse("x = 3\n").unwrap();
        assert_eq!(doc.f64_or("x", 0.0), 3.0);
        assert_eq!(doc.i64_or("x", 0), 3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Doc::parse("[unclosed\n").is_err());
        assert!(Doc::parse("= 3\n").is_err());
        assert!(Doc::parse("x = \n").is_err());
        assert!(Doc::parse("x = bareword\n").is_err());
        assert!(Doc::parse("x = \"unterminated\n").is_err());
        assert!(Doc::parse("x = [1, [2]]\n").is_err());
        assert!(Doc::parse("x = 1\nx = 2\n").is_err());
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = Doc::parse("x = \"a#b\"\n").unwrap();
        assert_eq!(doc.require_str("x").unwrap(), "a#b");
    }

    #[test]
    fn missing_required_errors() {
        let doc = Doc::parse("x = 1\n").unwrap();
        assert!(doc.require_f64("y").is_err());
        assert!(doc.require_str("x").is_err()); // wrong type
    }
}
