//! Minimal TOML-subset parser (serde/toml are unavailable offline).
//!
//! Supported: `[section]` / `[a.b]` headers, `[[section]]`
//! array-of-tables headers, `key = value` with string ("..."), bool,
//! integer, float, and flat arrays of those; `#` comments.
//! Keys are flattened to dotted paths: `[market] kind = "uniform"` becomes
//! `market.kind`; the i-th `[[portfolio]]` table becomes `portfolio.<i>.*`
//! (0-based), so array entries are addressable by the same dotted-path
//! grammar the sweep axes use. That covers every experiment config in this
//! repo; the parser rejects anything outside the subset loudly rather than
//! guessing.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Bool(bool),
    Int(i64),
    Float(f64),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Floats accept integer literals too.
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// Flattened dotted-path -> value document.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    pub entries: BTreeMap<String, Value>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = BTreeMap::new();
        let mut prefix = String::new();
        // per-name element counter for `[[name]]` array-of-tables
        let mut array_counts: BTreeMap<String, usize> = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            let at = || format!("config line {}", lineno + 1);
            if line.starts_with("[[") {
                if !line.ends_with("]]") {
                    bail!("{}: unterminated array-of-tables header", at());
                }
                let name = line[2..line.len() - 2].trim().to_string();
                if name.is_empty() {
                    bail!("{}: empty section name", at());
                }
                let idx = array_counts.entry(name.clone()).or_insert(0);
                prefix = format!("{name}.{idx}");
                *idx += 1;
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("{}: unterminated section header", at());
                }
                prefix = line[1..line.len() - 1].trim().to_string();
                if prefix.is_empty() {
                    bail!("{}: empty section name", at());
                }
                continue;
            }
            let eq = line
                .find('=')
                .with_context(|| format!("{}: expected key = value", at()))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                bail!("{}: empty key", at());
            }
            let val = parse_value(line[eq + 1..].trim())
                .with_context(|| at())?;
            let path = if prefix.is_empty() {
                key.to_string()
            } else {
                format!("{prefix}.{key}")
            };
            if entries.insert(path.clone(), val).is_some() {
                bail!("{}: duplicate key '{path}'", at());
            }
        }
        Ok(Doc { entries })
    }

    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    pub fn str_or<'a>(&'a self, path: &str, default: &'a str) -> &'a str {
        self.get(path).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn f64_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(Value::as_float).unwrap_or(default)
    }

    pub fn i64_or(&self, path: &str, default: i64) -> i64 {
        self.get(path).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(Value::as_bool).unwrap_or(default)
    }

    /// Required typed accessors.
    pub fn require_f64(&self, path: &str) -> Result<f64> {
        self.get(path)
            .and_then(Value::as_float)
            .with_context(|| format!("missing required float '{path}'"))
    }

    pub fn require_str(&self, path: &str) -> Result<&str> {
        self.get(path)
            .and_then(Value::as_str)
            .with_context(|| format!("missing required string '{path}'"))
    }
}

/// A [`Doc`] wrapper that records every key the schema reads, so the
/// loader can reject unconsumed (unknown / misspelled) keys by name
/// instead of silently applying defaults. Its typed getters are also
/// *strict*: a key that is present with the wrong type is an error,
/// never a silent fallback to the default — `job.n = "eight"` must not
/// quietly become `n = 8`.
///
/// The scenario-spec loader (`exp::spec`) is built on this; the older
/// [`super::schema::ExperimentConfig`] keeps the permissive accessors
/// for backwards compatibility with existing `simulate` configs.
pub struct TrackedDoc<'a> {
    doc: &'a Doc,
    used: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

fn type_name(v: &Value) -> &'static str {
    match v {
        Value::Str(_) => "a string",
        Value::Bool(_) => "a bool",
        Value::Int(_) => "an integer",
        Value::Float(_) => "a float",
        Value::Array(_) => "an array",
    }
}

impl<'a> TrackedDoc<'a> {
    pub fn new(doc: &'a Doc) -> Self {
        TrackedDoc { doc, used: Default::default() }
    }

    fn touch(&self, path: &str) {
        self.used.borrow_mut().insert(path.to_string());
    }

    /// Typed lookup: `None` when absent, error when present with the
    /// wrong type.
    fn typed<T>(
        &self,
        path: &str,
        want: &str,
        conv: impl Fn(&Value) -> Option<T>,
    ) -> Result<Option<T>> {
        self.touch(path);
        match self.doc.get(path) {
            None => Ok(None),
            Some(v) => conv(v).map(Some).ok_or_else(|| {
                anyhow::anyhow!(
                    "key '{path}' expects {want}, got {}",
                    type_name(v)
                )
            }),
        }
    }

    /// Marks `path` used and reports whether it is present.
    pub fn has(&self, path: &str) -> bool {
        self.touch(path);
        self.doc.get(path).is_some()
    }

    pub fn str_opt(&self, path: &str) -> Result<Option<String>> {
        self.typed(path, "a string", |v| v.as_str().map(str::to_string))
    }

    pub fn str_or(&self, path: &str, default: &str) -> Result<String> {
        Ok(self.str_opt(path)?.unwrap_or_else(|| default.to_string()))
    }

    pub fn require_str(&self, path: &str) -> Result<String> {
        self.str_opt(path)?
            .with_context(|| format!("missing required key '{path}'"))
    }

    pub fn f64_opt(&self, path: &str) -> Result<Option<f64>> {
        self.typed(path, "a number", Value::as_float)
    }

    pub fn f64_or(&self, path: &str, default: f64) -> Result<f64> {
        Ok(self.f64_opt(path)?.unwrap_or(default))
    }

    pub fn bool_opt(&self, path: &str) -> Result<Option<bool>> {
        self.typed(path, "a bool", Value::as_bool)
    }

    pub fn bool_or(&self, path: &str, default: bool) -> Result<bool> {
        Ok(self.bool_opt(path)?.unwrap_or(default))
    }

    pub fn u64_opt(&self, path: &str) -> Result<Option<u64>> {
        match self.typed(path, "a non-negative integer", Value::as_int)? {
            None => Ok(None),
            Some(i) if i >= 0 => Ok(Some(i as u64)),
            Some(i) => bail!("key '{path}' must be >= 0, got {i}"),
        }
    }

    pub fn u64_or(&self, path: &str, default: u64) -> Result<u64> {
        Ok(self.u64_opt(path)?.unwrap_or(default))
    }

    pub fn usize_opt(&self, path: &str) -> Result<Option<usize>> {
        Ok(self.u64_opt(path)?.map(|i| i as usize))
    }

    pub fn usize_or(&self, path: &str, default: usize) -> Result<usize> {
        Ok(self.usize_opt(path)?.unwrap_or(default))
    }

    /// A (possibly absent) array of strings; absent parses as empty.
    pub fn str_array_or_empty(&self, path: &str) -> Result<Vec<String>> {
        let arr = self.typed(path, "an array", |v| {
            v.as_array().map(<[Value]>::to_vec)
        })?;
        match arr {
            None => Ok(Vec::new()),
            Some(items) => items
                .iter()
                .map(|v| {
                    v.as_str().map(str::to_string).ok_or_else(|| {
                        anyhow::anyhow!(
                            "key '{path}' must be an array of strings, \
                             found {}",
                            type_name(v)
                        )
                    })
                })
                .collect(),
        }
    }

    /// A required array of numbers (ints promote to floats).
    pub fn f64_array(&self, path: &str) -> Result<Vec<f64>> {
        self.touch(path);
        let v = self
            .doc
            .get(path)
            .with_context(|| format!("missing required key '{path}'"))?;
        let items = v.as_array().ok_or_else(|| {
            anyhow::anyhow!(
                "key '{path}' expects an array of numbers, got {}",
                type_name(v)
            )
        })?;
        items
            .iter()
            .map(|item| {
                item.as_float().ok_or_else(|| {
                    anyhow::anyhow!(
                        "key '{path}' must contain only numbers, found {}",
                        type_name(item)
                    )
                })
            })
            .collect()
    }

    /// Every key the schema never consumed, in document (sorted path)
    /// order — for loaders that want to phrase their own rejection
    /// (e.g. `exp::spec` names the lineup position of a strategy
    /// table's stray key).
    pub fn unknown_keys(&self) -> Vec<String> {
        let used = self.used.borrow();
        self.doc
            .entries
            .keys()
            .filter(|k| !used.contains(*k))
            .cloned()
            .collect()
    }

    /// Reject any key the schema never consumed, naming each offender
    /// with its enclosing table (`'epss' in [job]`), not just the bare
    /// key.
    pub fn finish(&self) -> Result<()> {
        let unknown = self.unknown_keys();
        if !unknown.is_empty() {
            let described: Vec<String> =
                unknown.iter().map(|k| describe_key(k)).collect();
            bail!("unknown key(s) in spec: {}", described.join(", "));
        }
        Ok(())
    }
}

/// `"job.epss"` -> `"'job.epss' ('epss' in table [job])"`; a top-level
/// key stays bare. Unknown-key rejections name the enclosing table so
/// a typo inside `[strategy.rebid]` cannot be mistaken for a stray
/// top-level key.
pub fn describe_key(path: &str) -> String {
    match path.rsplit_once('.') {
        Some((table, key)) => {
            format!("'{path}' ('{key}' in table [{table}])")
        }
        None => format!("'{path}' (top level)"),
    }
}

fn strip_comment(line: &str) -> &str {
    // a '#' outside quotes starts a comment
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(stripped) = s.strip_prefix('"') {
        let end = stripped
            .find('"')
            .context("unterminated string literal")?;
        if !stripped[end + 1..].trim().is_empty() {
            bail!("trailing junk after string literal");
        }
        return Ok(Value::Str(stripped[..end].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            bail!("unterminated array");
        }
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue; // trailing comma
                }
                let v = parse_value(part)?;
                if matches!(v, Value::Array(_)) {
                    bail!("nested arrays unsupported");
                }
                items.push(v);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value '{s}' (bare strings need quotes)")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let doc = Doc::parse(
            r#"
# experiment
seed = 42
name = "fig3"         # inline comment

[market]
kind = "uniform"
lo = 0.2
hi = 1.0

[strategy.two_bids]
n1 = 4
enabled = true
weights = [1, 2.5, 3]
"#,
        )
        .unwrap();
        assert_eq!(doc.i64_or("seed", 0), 42);
        assert_eq!(doc.require_str("name").unwrap(), "fig3");
        assert_eq!(doc.require_str("market.kind").unwrap(), "uniform");
        assert_eq!(doc.require_f64("market.lo").unwrap(), 0.2);
        assert_eq!(doc.i64_or("strategy.two_bids.n1", 0), 4);
        assert!(doc.bool_or("strategy.two_bids.enabled", false));
        let w = doc.get("strategy.two_bids.weights").unwrap();
        assert_eq!(w.as_array().unwrap().len(), 3);
        assert_eq!(w.as_array().unwrap()[1].as_float(), Some(2.5));
    }

    #[test]
    fn array_of_tables_flattens_to_indexed_prefixes() {
        let doc = Doc::parse(
            r#"
[[portfolio]]
label = "cheap"
speed = 1.0

[[portfolio]]
label = "fast"
speed = 1.6

[market]
kind = "uniform"
"#,
        )
        .unwrap();
        assert_eq!(doc.require_str("portfolio.0.label").unwrap(), "cheap");
        assert_eq!(doc.require_f64("portfolio.0.speed").unwrap(), 1.0);
        assert_eq!(doc.require_str("portfolio.1.label").unwrap(), "fast");
        assert_eq!(doc.require_f64("portfolio.1.speed").unwrap(), 1.6);
        // a plain header after the array resets the prefix as usual
        assert_eq!(doc.require_str("market.kind").unwrap(), "uniform");
    }

    #[test]
    fn array_of_tables_counters_are_per_name() {
        let doc = Doc::parse("[[a]]\nx = 1\n[[b]]\nx = 2\n[[a]]\nx = 3\n")
            .unwrap();
        assert_eq!(doc.i64_or("a.0.x", 0), 1);
        assert_eq!(doc.i64_or("b.0.x", 0), 2);
        assert_eq!(doc.i64_or("a.1.x", 0), 3);
    }

    #[test]
    fn array_of_tables_rejects_malformed_headers() {
        assert!(Doc::parse("[[unclosed]\nx = 1\n").is_err());
        assert!(Doc::parse("[[ ]]\nx = 1\n").is_err());
        // duplicate keys inside one element are still duplicates
        assert!(Doc::parse("[[a]]\nx = 1\nx = 2\n").is_err());
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = Doc::parse("x = 3\n").unwrap();
        assert_eq!(doc.f64_or("x", 0.0), 3.0);
        assert_eq!(doc.i64_or("x", 0), 3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Doc::parse("[unclosed\n").is_err());
        assert!(Doc::parse("= 3\n").is_err());
        assert!(Doc::parse("x = \n").is_err());
        assert!(Doc::parse("x = bareword\n").is_err());
        assert!(Doc::parse("x = \"unterminated\n").is_err());
        assert!(Doc::parse("x = [1, [2]]\n").is_err());
        assert!(Doc::parse("x = 1\nx = 2\n").is_err());
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = Doc::parse("x = \"a#b\"\n").unwrap();
        assert_eq!(doc.require_str("x").unwrap(), "a#b");
    }

    #[test]
    fn missing_required_errors() {
        let doc = Doc::parse("x = 1\n").unwrap();
        assert!(doc.require_f64("y").is_err());
        assert!(doc.require_str("x").is_err()); // wrong type
    }

    #[test]
    fn tracked_doc_rejects_unconsumed_keys_by_name() {
        let doc = Doc::parse("a = 1\nzz = 2\n[job]\nepss = 0.3\n").unwrap();
        let d = TrackedDoc::new(&doc);
        assert_eq!(d.u64_or("a", 0).unwrap(), 1);
        assert_eq!(d.unknown_keys(), vec!["job.epss", "zz"]);
        let err = d.finish().unwrap_err().to_string();
        assert!(err.contains("job.epss"), "should name the key: {err}");
        // the enclosing table is named, not just the bare key
        assert!(err.contains("in table [job]"), "{err}");
        assert!(err.contains("'zz' (top level)"), "{err}");
    }

    #[test]
    fn tracked_doc_wrong_types_are_errors_not_defaults() {
        let doc =
            Doc::parse("n = \"eight\"\neps = true\nxs = [1, \"a\"]\n")
                .unwrap();
        let d = TrackedDoc::new(&doc);
        let err = d.u64_or("n", 8).unwrap_err().to_string();
        assert!(err.contains("'n'") && err.contains("integer"), "{err}");
        assert!(d.f64_or("eps", 0.35).is_err());
        assert!(d.bool_or("n", false).is_err());
        assert!(d.bool_or("eps", false).unwrap());
        assert!(d.bool_or("gone", true).unwrap());
        assert!(d.f64_array("xs").is_err());
        // absent keys still fall back to defaults
        assert_eq!(d.f64_or("missing", 0.5).unwrap(), 0.5);
        assert_eq!(d.str_or("also_missing", "x").unwrap(), "x");
    }

    #[test]
    fn tracked_doc_negative_int_rejected_for_u64() {
        let doc = Doc::parse("j = -5\n").unwrap();
        let d = TrackedDoc::new(&doc);
        assert!(d.u64_or("j", 1).is_err());
    }

    #[test]
    fn tracked_doc_arrays() {
        let doc = Doc::parse(
            "names = [\"a\", \"b\"]\nvals = [1, 2.5]\n",
        )
        .unwrap();
        let d = TrackedDoc::new(&doc);
        assert_eq!(
            d.str_array_or_empty("names").unwrap(),
            vec!["a".to_string(), "b".to_string()]
        );
        assert_eq!(d.f64_array("vals").unwrap(), vec![1.0, 2.5]);
        assert!(d.str_array_or_empty("absent").unwrap().is_empty());
        assert!(d.f64_array("absent").is_err());
        assert!(d.finish().is_ok());
    }
}
