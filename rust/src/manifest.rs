//! Parser for `artifacts/manifest.txt` — the contract between the python
//! AOT path and the rust runtime.
//!
//! Line-oriented format (see python/compile/aot.py):
//!
//! ```text
//! version 1
//! model cnn
//! d 546730
//! input_shape 32,3072
//! input_dtype f32
//! label_shape 32
//! meta classes 10
//! artifact grad cnn_grad.hlo.txt
//! theta0 cnn_theta0.f32 <sha16>
//! layer conv1_w 0 432 16,3,3,3
//! end
//! ```

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One named parameter tensor inside the flat theta vector.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerSpec {
    pub name: String,
    pub offset: usize,
    pub numel: usize,
    pub shape: Vec<usize>,
}

/// Everything the runtime needs to drive one model.
#[derive(Clone, Debug)]
pub struct ModelManifest {
    pub name: String,
    /// flat parameter count
    pub d: usize,
    pub input_shape: Vec<usize>,
    /// "f32" | "i32"
    pub input_dtype: String,
    pub label_shape: Vec<usize>,
    pub meta: HashMap<String, String>,
    /// kind ("grad"/"eval"/"apply") -> artifact path (absolute)
    pub artifacts: HashMap<String, PathBuf>,
    pub theta0_path: PathBuf,
    pub theta0_digest: String,
    pub layers: Vec<LayerSpec>,
}

impl ModelManifest {
    pub fn batch(&self) -> usize {
        self.input_shape[0]
    }

    pub fn classes(&self) -> Option<usize> {
        self.meta.get("classes").and_then(|c| c.parse().ok())
    }

    /// Total prediction slots per batch (CNN: batch; LM: batch*seq).
    pub fn preds_per_batch(&self) -> usize {
        self.label_shape.iter().product()
    }

    /// Load theta0 (raw little-endian f32) and validate the length.
    pub fn load_theta0(&self) -> Result<Vec<f32>> {
        let raw = fs::read(&self.theta0_path).with_context(|| {
            format!("reading {}", self.theta0_path.display())
        })?;
        if raw.len() != self.d * 4 {
            bail!(
                "theta0 {}: {} bytes, want {}",
                self.theta0_path.display(),
                raw.len(),
                self.d * 4
            );
        }
        Ok(raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    /// Layer lookup by name.
    pub fn layer(&self, name: &str) -> Option<&LayerSpec> {
        self.layers.iter().find(|l| l.name == name)
    }

    fn validate(&self) -> Result<()> {
        // layers must tile [0, d) exactly
        let mut spans: Vec<(usize, usize)> = self
            .layers
            .iter()
            .map(|l| (l.offset, l.numel))
            .collect();
        spans.sort();
        let mut pos = 0;
        for (off, numel) in &spans {
            if *off != pos {
                bail!(
                    "model {}: layer gap/overlap at offset {off} (expected {pos})",
                    self.name
                );
            }
            pos += numel;
        }
        if pos != self.d {
            bail!("model {}: layers cover {pos} of d={}", self.name, self.d);
        }
        for l in &self.layers {
            if l.shape.iter().product::<usize>() != l.numel {
                bail!("layer {}: shape/numel mismatch", l.name);
            }
        }
        for kind in ["grad", "eval", "apply"] {
            if !self.artifacts.contains_key(kind) {
                bail!("model {}: missing artifact '{kind}'", self.name);
            }
        }
        Ok(())
    }
}

/// The parsed manifest: all models keyed by name.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub models: HashMap<String, ModelManifest>,
}

impl Manifest {
    /// Parse manifest text; `base` is the artifacts directory relative
    /// paths resolve against.
    pub fn parse(text: &str, base: &Path) -> Result<Self> {
        let mut models = HashMap::new();
        let mut cur: Option<ModelManifest> = None;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let key = it.next().unwrap();
            let rest: Vec<&str> = it.collect();
            let at = || format!("manifest line {}", lineno + 1);
            match key {
                "version" => {
                    if rest != ["1"] {
                        bail!("{}: unsupported version {rest:?}", at());
                    }
                }
                "model" => {
                    if let Some(m) = cur.take() {
                        bail!(
                            "{}: model {} not terminated by 'end'",
                            at(),
                            m.name
                        );
                    }
                    cur = Some(ModelManifest {
                        name: rest.first().context("model needs a name")?.to_string(),
                        d: 0,
                        input_shape: vec![],
                        input_dtype: String::new(),
                        label_shape: vec![],
                        meta: HashMap::new(),
                        artifacts: HashMap::new(),
                        theta0_path: PathBuf::new(),
                        theta0_digest: String::new(),
                        layers: vec![],
                    });
                }
                "end" => {
                    let m = cur.take().with_context(|| {
                        format!("{}: 'end' with no open model", at())
                    })?;
                    m.validate()?;
                    models.insert(m.name.clone(), m);
                }
                _ => {
                    let m = cur.as_mut().with_context(|| {
                        format!("{}: '{key}' outside a model block", at())
                    })?;
                    match key {
                        "d" => m.d = rest[0].parse()?,
                        "input_shape" => {
                            m.input_shape = parse_dims(rest[0])?;
                        }
                        "input_dtype" => {
                            m.input_dtype = rest[0].to_string();
                        }
                        "label_shape" => {
                            m.label_shape = parse_dims(rest[0])?;
                        }
                        "meta" => {
                            m.meta.insert(
                                rest[0].to_string(),
                                rest[1..].join(" "),
                            );
                        }
                        "artifact" => {
                            m.artifacts.insert(
                                rest[0].to_string(),
                                base.join(rest[1]),
                            );
                        }
                        "theta0" => {
                            m.theta0_path = base.join(rest[0]);
                            m.theta0_digest = rest
                                .get(1)
                                .unwrap_or(&"")
                                .to_string();
                        }
                        "layer" => {
                            m.layers.push(LayerSpec {
                                name: rest[0].to_string(),
                                offset: rest[1].parse()?,
                                numel: rest[2].parse()?,
                                shape: parse_dims(rest[3])?,
                            });
                        }
                        other => {
                            bail!("{}: unknown key '{other}'", at())
                        }
                    }
                }
            }
        }
        if let Some(m) = cur {
            bail!("model {} not terminated by 'end'", m.name);
        }
        if models.is_empty() {
            bail!("manifest contains no models");
        }
        Ok(Manifest { models })
    }

    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.txt");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models.get(name).with_context(|| {
            format!(
                "model '{name}' not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }
}

fn parse_dims(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(|d| d.parse::<usize>().context("bad dim"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = "\
version 1
model toy
d 6
input_shape 2,3
input_dtype f32
label_shape 2
meta classes 3
artifact grad g.hlo.txt
artifact eval e.hlo.txt
artifact apply a.hlo.txt
theta0 t.f32 abcd
layer w 0 4 2,2
layer b 4 2 2
end
";

    #[test]
    fn parses_good_manifest() {
        let m = Manifest::parse(GOOD, Path::new("/art")).unwrap();
        let toy = m.model("toy").unwrap();
        assert_eq!(toy.d, 6);
        assert_eq!(toy.batch(), 2);
        assert_eq!(toy.classes(), Some(3));
        assert_eq!(toy.preds_per_batch(), 2);
        assert_eq!(
            toy.artifacts["grad"],
            PathBuf::from("/art/g.hlo.txt")
        );
        assert_eq!(toy.layer("b").unwrap().offset, 4);
        assert!(m.model("missing").is_err());
    }

    #[test]
    fn rejects_layer_gap() {
        let bad = GOOD.replace("layer b 4 2 2", "layer b 5 1 1");
        assert!(Manifest::parse(&bad, Path::new("/a")).is_err());
    }

    #[test]
    fn rejects_missing_artifact() {
        let bad = GOOD.replace("artifact apply a.hlo.txt\n", "");
        assert!(Manifest::parse(&bad, Path::new("/a")).is_err());
    }

    #[test]
    fn rejects_unterminated_model() {
        let bad = GOOD.replace("end\n", "");
        assert!(Manifest::parse(&bad, Path::new("/a")).is_err());
    }

    #[test]
    fn rejects_shape_numel_mismatch() {
        let bad = GOOD.replace("layer w 0 4 2,2", "layer w 0 4 2,3");
        assert!(Manifest::parse(&bad, Path::new("/a")).is_err());
    }

    #[test]
    fn parses_real_artifacts_if_present() {
        // integration hook: if `make artifacts` has run, the real manifest
        // must parse and contain the cnn model.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            let cnn = m.model("cnn").unwrap();
            assert!(cnn.d > 100_000);
            let theta = cnn.load_theta0().unwrap();
            assert_eq!(theta.len(), cnn.d);
        }
    }
}
