//! Batched replicate executor: all N replicates of one grid point run
//! as *lanes stepping together* through one structure-of-arrays kernel,
//! instead of N independent scalar [`Engine::run`] calls (DESIGN.md §8).
//!
//! Replicates at a grid point share everything pure — the planned
//! strategies, the price source (CDF estimates, traces), `E[1/y]`
//! tables — and differ only in their counter-based `Rng::stream`. The
//! batch exploits that: one call sets up shared state once, then
//! advances every live lane one slot per round. The per-slot hot path
//! is allocation-free — `Policy::decide_into` fills a scratch buffer
//! owned by a per-worker [`BatchArena`] (a `thread_local`, so one pool
//! worker reuses the same buffers across every replicate block it
//! executes) instead of allocating a fresh `active` vector per slot the
//! way `ActiveDecision` does.
//!
//! **Determinism contract.** Lanes never exchange data: each lane's
//! trajectory is a pure function of its own RNG stream, the shared
//! (immutable) point context and the engine parameters, so the lane
//! interleaving cannot change any per-lane result. Within a lane the
//! kernel consumes RNG and performs `CostMeter` operations in *exactly*
//! the scalar engine's per-slot order — price draw, `decide`, runtime
//! sample, backend step — and emits the same events to the policy in
//! the same order, so batched and scalar sweeps produce bit-identical
//! digests (`tests/integration_batch.rs` pins this for every shipped
//! preset at threads 1 and 8).
//!
//! The lockstep kernel covers every frictionless run, including the
//! event-native policies (it emits the full event stream, so
//! `NoticeRebid`/`ElasticFleet`/`DeadlineAware` react exactly as under
//! the scalar engine). Overhead-enabled runs (`[overhead]` presets)
//! fall back to one scalar [`Engine::run`] per lane inside the same
//! batch job — digest-identical trivially, and still amortizing the
//! shared per-point context.

use std::cell::RefCell;

use anyhow::{ensure, Result};

use crate::coordinator::backend::TrainingBackend;
use crate::metrics::{Point, Series};
use crate::obs::TraceObs;
use crate::util::rng::Rng;

use super::engine::{
    Engine, EngineParams, EngineResult, EngineState, Event, Observer, Policy,
};
use super::{CostMeter, PriceSource};

/// One replicate's mutable executors: a fresh policy and backend, built
/// per lane by the caller (plans and bounds are shared, instances are
/// not).
pub struct BatchLane {
    pub policy: Box<dyn Policy>,
    pub backend: Box<dyn TrainingBackend>,
}

/// Per-worker scratch reused across replicates and across batch jobs:
/// the `decide_into` active-set buffer plus the structure-of-arrays
/// lane state. Lives in a `thread_local`, so each sweep-pool worker
/// allocates its buffers once and then runs every replicate block it
/// steals out of them.
#[derive(Default)]
struct BatchArena {
    /// shared active-set scratch (only its *length* feeds the kernel,
    /// exactly like the scalar engine, which reads `decision.active.len()`)
    active: Vec<usize>,
    soa: LaneSoa,
}

/// Structure-of-arrays lane state: one entry per replicate, hot fields
/// packed by kind rather than by lane.
#[derive(Default)]
struct LaneSoa {
    meter: Vec<CostMeter>,
    iter: Vec<u64>,
    slots: Vec<u64>,
    target: Vec<u64>,
    last_err: Vec<f64>,
    last_acc: Vec<f64>,
    prev_price: Vec<f64>,
    was_active: Vec<bool>,
    interrupted: Vec<bool>,
    done: Vec<bool>,
    truncated: Vec<bool>,
    preemptions: Vec<u64>,
    restarts: Vec<u64>,
    series: Vec<Series>,
}

impl LaneSoa {
    /// Reset to `n` fresh lanes, reusing the vectors' capacity.
    fn reset(&mut self, n: usize, targets: &[u64], last: &[(f64, f64)]) {
        self.meter.clear();
        self.meter.resize(n, CostMeter::new());
        self.iter.clear();
        self.iter.resize(n, 0);
        self.slots.clear();
        self.slots.resize(n, 0);
        self.target.clear();
        self.target.extend_from_slice(targets);
        self.last_err.clear();
        self.last_acc.clear();
        for &(e, a) in last {
            self.last_err.push(e);
            self.last_acc.push(a);
        }
        self.prev_price.clear();
        self.prev_price.resize(n, 0.0);
        self.was_active.clear();
        self.was_active.resize(n, false);
        self.interrupted.clear();
        self.interrupted.resize(n, false);
        self.done.clear();
        // target 0 never enters the scalar while-loop either
        self.done.extend(targets.iter().map(|&t| t == 0));
        self.truncated.clear();
        self.truncated.resize(n, false);
        self.preemptions.clear();
        self.preemptions.resize(n, 0);
        self.restarts.clear();
        self.restarts.resize(n, 0);
        self.series.clear();
        self.series.resize_with(n, Series::default);
    }
}

thread_local! {
    static ARENA: RefCell<BatchArena> = RefCell::new(BatchArena::default());
}

/// Run one replicate block — lane `i` draws from `rngs[i]` — and return
/// per-lane [`EngineResult`]s, bit-identical to running each lane
/// through the scalar engine with the same RNG. RNGs are borrowed (not
/// consumed) so lineup-mode callers can thread the same streams through
/// successive entries, exactly as the scalar path does.
pub fn run_batch(
    params: &EngineParams,
    lanes: Vec<BatchLane>,
    prices: &PriceSource,
    rngs: &mut [Rng],
) -> Result<Vec<EngineResult>> {
    run_batch_traced(params, lanes, prices, rngs, &mut [])
}

/// [`run_batch`] with one optional [`TraceObs`] per lane (DESIGN.md
/// §12): `tracers` is either empty (no tracing) or index-aligned with
/// `lanes`. Tracers are strictly read-only on the kernel — no RNG, no
/// accounting — so a traced batch is bit-identical to an untraced one.
/// The overhead-enabled scalar fallback re-attributes each tracer's
/// `path` to `"scalar"` before running it, so trace lines report the
/// executor that actually ran the lane.
pub fn run_batch_traced(
    params: &EngineParams,
    lanes: Vec<BatchLane>,
    prices: &PriceSource,
    rngs: &mut [Rng],
    tracers: &mut [TraceObs<'_>],
) -> Result<Vec<EngineResult>> {
    ensure!(
        lanes.len() == rngs.len(),
        "run_batch: {} lanes but {} rng streams",
        lanes.len(),
        rngs.len()
    );
    ensure!(
        tracers.is_empty() || tracers.len() == lanes.len(),
        "run_batch: {} lanes but {} tracers",
        lanes.len(),
        tracers.len()
    );
    if lanes.is_empty() {
        return Ok(Vec::new());
    }
    ensure!(params.idle_step > 0.0, "idle_step must be > 0");
    ensure!(params.stride >= 1, "stride must be >= 1");
    params.overhead.validate()?;

    if params.overhead.enabled() {
        // checkpoint/rollback state is inherently per-lane and branchy;
        // run the full scalar engine per lane (same batch job, shared
        // point context — digest-identical by construction)
        let engine = Engine::new(*params);
        return lanes
            .into_iter()
            .zip(rngs.iter_mut())
            .enumerate()
            .map(|(i, (mut lane, rng))| match tracers.get_mut(i) {
                Some(t) => {
                    t.set_path("scalar");
                    engine.run(
                        lane.policy.as_mut(),
                        lane.backend.as_mut(),
                        prices,
                        rng,
                        &mut [t as &mut dyn Observer],
                    )
                }
                None => engine.run(
                    lane.policy.as_mut(),
                    lane.backend.as_mut(),
                    prices,
                    rng,
                    &mut [],
                ),
            })
            .collect();
    }

    ARENA.with(|cell| {
        let arena = &mut *cell.borrow_mut();
        run_lockstep(params, lanes, prices, rngs, arena, tracers)
    })
}

/// The frictionless structure-of-arrays kernel. Per lane and slot this
/// reproduces `Engine::run` with `OverheadModel::none()` semantics
/// verbatim: same RNG draws, same `CostMeter` calls, same event stream
/// (so event-native policies behave identically), same series stride.
fn run_lockstep(
    params: &EngineParams,
    mut lanes: Vec<BatchLane>,
    prices: &PriceSource,
    rngs: &mut [Rng],
    arena: &mut BatchArena,
    tracers: &mut [TraceObs<'_>],
) -> Result<Vec<EngineResult>> {
    let n = lanes.len();
    let targets: Vec<u64> =
        lanes.iter().map(|l| l.policy.target_iters()).collect();
    let last: Vec<(f64, f64)> = lanes
        .iter()
        .map(|l| (l.backend.error(), l.backend.accuracy()))
        .collect();
    let st = &mut arena.soa;
    st.reset(n, &targets, &last);
    let scratch = &mut arena.active;

    let mut live = st.done.iter().filter(|&&d| !d).count();
    while live > 0 {
        for i in 0..n {
            if st.done[i] {
                continue;
            }
            advance_slot(
                params,
                &mut lanes[i],
                prices,
                &mut rngs[i],
                st,
                i,
                scratch,
                tracers.get_mut(i),
            )?;
            if st.done[i] {
                live -= 1;
            }
        }
    }

    Ok((0..n)
        .map(|i| EngineResult {
            series: std::mem::take(&mut st.series[i]),
            iters: st.iter[i],
            cost: st.meter[i].cost(),
            elapsed: st.meter[i].elapsed(),
            idle_time: st.meter[i].idle_time(),
            final_error: st.last_err[i],
            final_accuracy: st.last_acc[i],
            truncated: st.truncated[i],
            preemptions: st.preemptions[i],
            restarts: st.restarts[i],
            checkpoints: 0,
            checkpoint_time: 0.0,
            restart_time: 0.0,
            lost_iters: 0,
        })
        .collect())
}

/// Advance lane `i` by one slot: the body of the scalar engine's while
/// loop, frictionless specialisation (no checkpoint/rollback arms).
#[allow(clippy::too_many_arguments)]
fn advance_slot(
    params: &EngineParams,
    lane: &mut BatchLane,
    prices: &PriceSource,
    rng: &mut Rng,
    st: &mut LaneSoa,
    i: usize,
    scratch: &mut Vec<usize>,
    mut tracer: Option<&mut TraceObs<'_>>,
) -> Result<()> {
    // one emit point, mirroring the engine's policy-then-recorder order
    macro_rules! emit {
        ($ev:expr, $active:expr, $price:expr) => {{
            let ev: Event = $ev;
            let state = EngineState {
                iter: st.iter[i],
                target: st.target[i],
                clock: st.meter[i].elapsed(),
                cost: st.meter[i].cost(),
                idle_time: st.meter[i].idle_time(),
                error: st.last_err[i],
                accuracy: st.last_acc[i],
                active: $active,
                price: $price,
            };
            lane.policy.on_event(&ev, &state)?;
            if let Some(t) = tracer.as_deref_mut() {
                t.on_event(&ev, &state);
            }
            if matches!(ev, Event::IterationDone)
                && (state.iter % params.stride == 0
                    || state.iter == state.target)
            {
                st.series[i].push(Point {
                    clock: state.clock,
                    iter: state.iter,
                    cost: state.cost,
                    error: state.error,
                    accuracy: state.accuracy,
                    active: state.active,
                });
            }
        }};
    }

    st.slots[i] += 1;
    if st.slots[i] > params.max_slots
        || st.meter[i].elapsed() >= params.theta_cap
    {
        st.truncated[i] = true;
        emit!(Event::DeadlineHit, 0, st.prev_price[i]);
        st.done[i] = true;
        return Ok(());
    }
    let price = prices.price_at(st.meter[i].elapsed(), rng);
    emit!(Event::PriceRevision { price }, 0, price);
    let charged = lane.policy.decide_into(price, rng, scratch);
    let y = scratch.len();
    if y == 0 {
        if st.was_active[i] {
            st.preemptions[i] += 1;
            st.was_active[i] = false;
            st.interrupted[i] = true;
            emit!(
                Event::WorkerPreempted {
                    notice: params.overhead.preempt_notice_s
                },
                0,
                price
            );
        }
        st.meter[i].idle(params.idle_step);
        return Ok(());
    }
    if st.interrupted[i] {
        st.restarts[i] += 1;
        st.interrupted[i] = false;
        emit!(Event::WorkerRestored, y, charged);
    }
    let dur = params.runtime.sample(y, rng);
    let stats = lane.backend.step(y, rng)?;
    st.meter[i].charge(y, charged, dur);
    st.iter[i] += 1;
    st.last_err[i] = stats.error;
    st.last_acc[i] = stats.accuracy;
    st.was_active[i] = true;
    st.prev_price[i] = charged;
    emit!(Event::IterationDone, y, charged);
    if st.iter[i] >= st.target[i] {
        st.done[i] = true;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SyntheticBackend;
    use crate::coordinator::strategy::{FixedBids, StaticWorkers, Strategy};
    use crate::market::{BidVector, PriceModel};
    use crate::preempt::{PreemptionModel, RecipTable};
    use crate::sim::policy::ElasticFleet;
    use crate::sim::{LockstepPolicy, OverheadModel};
    use crate::theory::bounds::{ErrorBound, SgdHyper};
    use crate::theory::runtime_model::RuntimeModel;

    fn bound() -> ErrorBound {
        ErrorBound::new(SgdHyper::paper_cnn())
    }

    fn params() -> EngineParams {
        EngineParams::lockstep(
            RuntimeModel::ExpStragglers { lambda: 0.25, delta: 0.5 },
            f64::INFINITY,
        )
    }

    fn assert_results_identical(a: &EngineResult, b: &EngineResult) {
        assert_eq!(a.iters, b.iters);
        assert_eq!(a.cost.to_bits(), b.cost.to_bits());
        assert_eq!(a.elapsed.to_bits(), b.elapsed.to_bits());
        assert_eq!(a.idle_time.to_bits(), b.idle_time.to_bits());
        assert_eq!(a.final_error.to_bits(), b.final_error.to_bits());
        assert_eq!(a.final_accuracy.to_bits(), b.final_accuracy.to_bits());
        assert_eq!(a.truncated, b.truncated);
        assert_eq!(a.preemptions, b.preemptions);
        assert_eq!(a.restarts, b.restarts);
        assert_eq!(a.series.len(), b.series.len());
        for (p, q) in a.series.points.iter().zip(&b.series.points) {
            assert_eq!(p.clock.to_bits(), q.clock.to_bits());
            assert_eq!(p.iter, q.iter);
            assert_eq!(p.cost.to_bits(), q.cost.to_bits());
            assert_eq!(p.active, q.active);
        }
    }

    /// Scalar oracle: one engine run per lane with the same streams.
    fn scalar<F>(mk: F, seeds: &[u64], p: &EngineParams, src: &PriceSource)
        -> (Vec<EngineResult>, Vec<Rng>)
    where
        F: Fn() -> BatchLane,
    {
        let engine = Engine::new(*p);
        let mut rngs: Vec<Rng> =
            seeds.iter().map(|&s| Rng::stream(7, s)).collect();
        let results = rngs
            .iter_mut()
            .map(|rng| {
                let mut lane = mk();
                engine
                    .run(
                        lane.policy.as_mut(),
                        lane.backend.as_mut(),
                        src,
                        rng,
                        &mut [],
                    )
                    .unwrap()
            })
            .collect();
        (results, rngs)
    }

    fn batched<F>(mk: F, seeds: &[u64], p: &EngineParams, src: &PriceSource)
        -> (Vec<EngineResult>, Vec<Rng>)
    where
        F: Fn() -> BatchLane,
    {
        let mut rngs: Vec<Rng> =
            seeds.iter().map(|&s| Rng::stream(7, s)).collect();
        let lanes = seeds.iter().map(|_| mk()).collect();
        let results = run_batch(p, lanes, src, &mut rngs).unwrap();
        (results, rngs)
    }

    fn check_equivalence<F>(mk: F, lanes: usize, src: &PriceSource)
    where
        F: Fn() -> BatchLane,
    {
        let seeds: Vec<u64> = (0..lanes as u64).collect();
        let p = params();
        let (want, mut want_rngs) = scalar(&mk, &seeds, &p, src);
        let (got, mut got_rngs) = batched(&mk, &seeds, &p, src);
        assert_eq!(want.len(), got.len());
        for (a, b) in want.iter().zip(&got) {
            assert_results_identical(a, b);
        }
        // post-run RNG states match too: the batch consumed the streams
        // in exactly the scalar order
        for (a, b) in want_rngs.iter_mut().zip(got_rngs.iter_mut()) {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    fn fixed_bids_lane() -> BatchLane {
        BatchLane {
            policy: Box::new(LockstepPolicy(Box::new(FixedBids::new(
                "two_bids",
                BidVector::two_group(8, 4, 0.8, 0.4),
                300,
            ))
                as Box<dyn Strategy>)),
            backend: Box::new(SyntheticBackend::new(bound())),
        }
    }

    #[test]
    fn batched_matches_scalar_fixed_bids_iid_prices() {
        let src = PriceSource::Iid(PriceModel::uniform_paper());
        for lanes in [1usize, 3, 8] {
            check_equivalence(fixed_bids_lane, lanes, &src);
        }
    }

    #[test]
    fn batched_matches_scalar_static_workers_bernoulli() {
        let mk = || BatchLane {
            policy: Box::new(LockstepPolicy(Box::new(StaticWorkers {
                label: "static".to_string(),
                n: 6,
                j: 200,
                model: PreemptionModel::Bernoulli { q: 0.4 },
                unit_price: 0.2,
            })
                as Box<dyn Strategy>)),
            backend: Box::new(SyntheticBackend::new(bound())),
        };
        check_equivalence(mk, 5, &PriceSource::Fixed(0.3));
    }

    #[test]
    fn batched_matches_scalar_uniform_preemption_model() {
        let mk = || BatchLane {
            policy: Box::new(LockstepPolicy(Box::new(StaticWorkers {
                label: "uniform".to_string(),
                n: 7,
                j: 150,
                model: PreemptionModel::Uniform,
                unit_price: 0.15,
            })
                as Box<dyn Strategy>)),
            backend: Box::new(SyntheticBackend::new(bound())),
        };
        check_equivalence(mk, 4, &PriceSource::Fixed(0.3));
    }

    #[test]
    fn batched_matches_scalar_event_native_elastic_fleet() {
        let model = PreemptionModel::Bernoulli { q: 0.3 };
        let table = RecipTable::build(&model, 12);
        let mk = move || BatchLane {
            policy: Box::new(ElasticFleet::new(
                "elastic",
                250,
                table.clone(),
                0.8,
            )),
            backend: Box::new(SyntheticBackend::new(bound())),
        };
        check_equivalence(mk, 5, &PriceSource::Iid(PriceModel::uniform_paper()));
    }

    #[test]
    fn batched_matches_scalar_with_theta_cap_truncation() {
        let src = PriceSource::Iid(PriceModel::uniform_paper());
        let seeds: Vec<u64> = (0..4).collect();
        let mut p = params();
        p.theta_cap = 500.0; // some lanes truncate mid-run
        let (want, _) = scalar(fixed_bids_lane, &seeds, &p, &src);
        let (got, _) = batched(fixed_bids_lane, &seeds, &p, &src);
        assert!(want.iter().any(|r| r.truncated));
        for (a, b) in want.iter().zip(&got) {
            assert_results_identical(a, b);
        }
    }

    #[test]
    fn overhead_fallback_matches_scalar_engine() {
        let src = PriceSource::Iid(PriceModel::uniform_paper());
        let seeds: Vec<u64> = (0..3).collect();
        let mut p = params();
        p.overhead = OverheadModel {
            checkpoint_every_iters: 25,
            checkpoint_cost_s: 2.0,
            restart_delay_s: 3.0,
            lost_work_on_preempt: true,
            preempt_notice_s: 0.0,
        };
        assert!(p.overhead.enabled());
        let (want, _) = scalar(fixed_bids_lane, &seeds, &p, &src);
        let (got, _) = batched(fixed_bids_lane, &seeds, &p, &src);
        for (a, b) in want.iter().zip(&got) {
            assert_results_identical(a, b);
            assert_eq!(a.checkpoints, b.checkpoints);
            assert_eq!(a.lost_iters, b.lost_iters);
        }
    }

    #[test]
    fn empty_batch_and_lane_rng_mismatch() {
        let src = PriceSource::Fixed(0.5);
        let out =
            run_batch(&params(), Vec::new(), &src, &mut []).unwrap();
        assert!(out.is_empty());
        let mut rngs = vec![Rng::new(1)];
        assert!(run_batch(&params(), Vec::new(), &src, &mut rngs).is_err());
    }
}
