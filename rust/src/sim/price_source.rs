//! Where the scheduler's per-slot spot price comes from.
//!
//! * `Iid` re-draws from the distribution each iteration slot (the
//!   paper's model in Secs. III–IV: prices i.i.d. across iterations, and
//!   re-drawn every `idle_step` seconds while the job is interrupted);
//! * `Trace` replays a time-stamped price path (Fig. 4), making prices
//!   auto-correlated — the robustness case the paper tests;
//! * `Fixed` is the preemptible-platform case (Sec. V): a stable price
//!   the whole run.

use crate::market::process::{PriceDist, PriceModel};
use crate::market::trace::SpotTrace;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub enum PriceSource {
    Iid(PriceModel),
    Trace(SpotTrace),
    Fixed(f64),
}

impl PriceSource {
    /// Price in effect at virtual time `clock`.
    pub fn price_at(&self, clock: f64, rng: &mut Rng) -> f64 {
        match self {
            PriceSource::Iid(m) => m.sample(rng),
            PriceSource::Trace(t) => t.price_at(clock),
            PriceSource::Fixed(p) => *p,
        }
    }

    /// True when prices move with the clock (trace replay) rather than
    /// per-draw — affects how long an idle wait should be before
    /// re-checking.
    pub fn time_driven(&self) -> bool {
        matches!(self, PriceSource::Trace(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_is_constant() {
        let s = PriceSource::Fixed(0.3);
        let mut rng = Rng::new(1);
        assert_eq!(s.price_at(0.0, &mut rng), 0.3);
        assert_eq!(s.price_at(1e9, &mut rng), 0.3);
        assert!(!s.time_driven());
    }

    #[test]
    fn trace_follows_clock() {
        let t =
            SpotTrace::new(vec![0.0, 100.0], vec![0.5, 0.9]).unwrap();
        let s = PriceSource::Trace(t);
        let mut rng = Rng::new(2);
        assert_eq!(s.price_at(50.0, &mut rng), 0.5);
        assert_eq!(s.price_at(150.0, &mut rng), 0.9);
        assert!(s.time_driven());
    }

    #[test]
    fn iid_draws_vary() {
        let s = PriceSource::Iid(PriceModel::uniform_paper());
        let mut rng = Rng::new(3);
        let a = s.price_at(0.0, &mut rng);
        let b = s.price_at(0.0, &mut rng);
        assert_ne!(a, b);
    }
}
