//! Virtual-clock simulation substrate: price sources over time and the
//! cost meter.

pub mod cost;
pub mod price_source;

pub use cost::CostMeter;
pub use price_source::PriceSource;
