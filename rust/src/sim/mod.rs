//! Virtual-clock simulation substrate: price sources over time, the
//! cost meter, and the discrete-event engine driving a run as typed
//! events through policies and observers (DESIGN.md §5).

pub mod cost;
pub mod engine;
pub mod price_source;

pub use cost::CostMeter;
pub use engine::{
    Engine, EngineParams, EngineResult, EngineState, Event, EventLog,
    LockstepPolicy, Observer, OverheadModel, Policy, SeriesRecorder,
};
pub use price_source::PriceSource;
