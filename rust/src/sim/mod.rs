//! Virtual-clock simulation substrate: price sources over time, the
//! cost meter, the discrete-event engine driving a run as typed events
//! through policies and observers (DESIGN.md §5), the suite of
//! event-reactive adaptive policies built on it (DESIGN.md §6), the
//! batched structure-of-arrays replicate executor (DESIGN.md §8), and
//! the forecast-driven proactive policy layer (DESIGN.md §11).

pub mod batch;
pub mod cost;
pub mod engine;
pub mod forecast;
pub mod policy;
pub mod price_source;

pub use batch::{run_batch, run_batch_traced, BatchLane};
pub use cost::CostMeter;
pub use engine::{
    Engine, EngineParams, EngineResult, EngineState, Event, EventLog,
    LockstepPolicy, Observer, OverheadModel, Policy, SeriesRecorder,
};
pub use forecast::{
    EwmaLevel, Forecaster, LookaheadBid, ProactiveMigrator,
    SlidingWindowRate,
};
pub use policy::{DeadlineAware, ElasticFleet, NoticeRebid};
pub use price_source::PriceSource;
