//! Forecast-driven proactive policy layer (DESIGN.md §11).
//!
//! Everything in PR 4/8 is *reactive*: `NoticeRebid` waits for the
//! preemption, `portfolio_migrate` waits for the price to already be
//! cheaper. Parcae (PAPERS.md) shows the better regime is *proactive* —
//! forecast interruption likelihood and price level from recent
//! history, then optimize expected progress ("liveput") over a
//! lookahead horizon and move *before* the market takes the fleet down.
//! This module supplies that layer in three pieces:
//!
//! * [`Forecaster`] — the online estimator contract: fed one scalar per
//!   observation from Observer-visible state, **never drawing RNG**, so
//!   a forecasting policy keeps sweep digests bit-identical at any
//!   thread count (the same determinism contract as `Policy::on_event`,
//!   DESIGN.md §6).
//! * [`SlidingWindowRate`] and [`EwmaLevel`] — the two concrete
//!   estimators: a per-market empirical preemption rate q̂ over a
//!   sliding window with Laplace smoothing, and an EWMA price level
//!   with a normalized-innovation regime-change detector.
//! * [`ProactiveMigrator`] and [`LookaheadBid`] — the policy layer:
//!   the `proactive_migrate` placement rule (scores every portfolio
//!   entry by forecast progress-per-dollar using the exact `E[1/y]`
//!   tables at q̂, consumed by `exp::run_portfolio_engine`) and the
//!   `lookahead_bid` [`Policy`] (re-plans the Theorem-2 bid against the
//!   forecast price level instead of the static distribution).
//!
//! # Example
//!
//! The sliding-window estimator is just arithmetic — no engine needed
//! to see the Laplace prior wash out:
//!
//! ```
//! use volatile_sgd::sim::forecast::{Forecaster, SlidingWindowRate};
//!
//! let mut qhat = SlidingWindowRate::new(8, 1.0);
//! assert_eq!(qhat.predict(), 0.5); // empty window: pure prior
//! for _ in 0..8 {
//!     qhat.observe_preempt(false);
//! }
//! assert_eq!(qhat.predict(), 0.1); // (0 + 1) / (8 + 2)
//! ```

use anyhow::Result;

use crate::coordinator::strategy::ActiveDecision;
use crate::market::{BidVector, MarketPortfolio};
use crate::preempt::binomial_expected_recip;
use crate::util::rng::Rng;

use super::engine::{EngineState, Event, Policy};

/// Observations a detector must accumulate after a (re-)anchor before
/// it may fire again: keeps the innovation variance estimate from
/// firing on its own startup transient (see `EwmaLevel`).
const DETECTOR_WARMUP: u64 = 16;

/// `E[1/y]` is undefined at q = 1; an all-preempted window forecasts
/// this close to certain interruption instead (the score it produces
/// is effectively zero, which is the right ranking).
const Q_FORECAST_CAP: f64 = 0.999_999;

/// Regime threshold used by `ProactiveMigrator`'s internal price
/// levels (the spec key `innovation_threshold` belongs to
/// `lookahead_bid`, whose bid plan actually consumes the detector).
const MIGRATOR_LEVEL_THRESHOLD: f64 = 6.0;

// ===================================================================
// Forecaster
// ===================================================================

/// An online, RNG-free estimator fed per-event from Observer-visible
/// state.
///
/// The contract mirrors `Policy::on_event` (DESIGN.md §6): `observe`
/// must be a *pure fold* over the observation stream — no randomness,
/// no clocks, no allocation proportional to history — so that feeding
/// the same stream twice leaves bitwise-identical state, and a policy
/// built on a forecaster costs the engine no RNG draws. That is the
/// whole reason forecast-driven sweeps keep bit-identical digests at
/// any thread count.
pub trait Forecaster {
    /// Fold one observation into the estimator state.
    fn observe(&mut self, x: f64);

    /// The current forecast (meaning depends on the estimator:
    /// probability for rates, price for levels).
    fn predict(&self) -> f64;

    /// Total observations folded in so far.
    fn observations(&self) -> u64;
}

// ===================================================================
// SlidingWindowRate
// ===================================================================

/// Per-market empirical preemption rate q̂ over a sliding window, with
/// Laplace smoothing.
///
/// Keeps the last `window` boolean outcomes in a ring buffer and
/// forecasts `q̂ = (hits + s) / (len + 2s)` where `s` is the smoothing
/// pseudo-count: `s = 1` is the classic add-one prior centred on 1/2,
/// `s = 0` is the raw empirical rate (and an *empty* raw window
/// forecasts 0 rather than 0/0).
#[derive(Clone, Debug)]
pub struct SlidingWindowRate {
    ring: Vec<bool>,
    head: usize,
    len: usize,
    hits: usize,
    smoothing: f64,
    seen: u64,
}

impl SlidingWindowRate {
    /// `window >= 1` outcomes are retained; `smoothing >= 0` is the
    /// Laplace pseudo-count.
    pub fn new(window: usize, smoothing: f64) -> Self {
        assert!(window >= 1, "window must be >= 1, got {window}");
        assert!(
            smoothing.is_finite() && smoothing >= 0.0,
            "smoothing must be finite and >= 0, got {smoothing}"
        );
        SlidingWindowRate {
            ring: vec![false; window],
            head: 0,
            len: 0,
            hits: 0,
            smoothing,
            seen: 0,
        }
    }

    /// Fold one slot outcome: was the market interrupting?
    pub fn observe_preempt(&mut self, preempted: bool) {
        if self.len == self.ring.len() {
            if self.ring[self.head] {
                self.hits -= 1;
            }
        } else {
            self.len += 1;
        }
        self.ring[self.head] = preempted;
        if preempted {
            self.hits += 1;
        }
        self.head = (self.head + 1) % self.ring.len();
        self.seen += 1;
    }

    /// The smoothed in-window rate (see type docs for the formula).
    pub fn rate(&self) -> f64 {
        if self.len == 0 && self.smoothing == 0.0 {
            return 0.0;
        }
        (self.hits as f64 + self.smoothing)
            / (self.len as f64 + 2.0 * self.smoothing)
    }

    /// The configured window length.
    pub fn window(&self) -> usize {
        self.ring.len()
    }
}

impl Forecaster for SlidingWindowRate {
    fn observe(&mut self, x: f64) {
        self.observe_preempt(x != 0.0);
    }

    fn predict(&self) -> f64 {
        self.rate()
    }

    fn observations(&self) -> u64 {
        self.seen
    }
}

// ===================================================================
// EwmaLevel
// ===================================================================

/// EWMA price level with a normalized-innovation regime-change
/// detector.
///
/// The level follows `level += α·(x - level)` with `α = 2/(window+1)`
/// (the usual span convention), and the innovation variance follows
/// the same EWMA of squared innovations. When an innovation exceeds
/// `threshold` estimated standard deviations the observation is
/// declared a *regime change*: the level re-anchors to the new value,
/// the variance resets, and [`shifts`](EwmaLevel::shifts) increments —
/// so after a contended/spot regime flip the level converges in one
/// step instead of one span.
///
/// The detector stays silent until [`DETECTOR_WARMUP`] observations
/// have accumulated since the last (re-)anchor: a freshly reset
/// variance estimate underestimates σ, and firing on that transient
/// would turn ordinary noise into phantom regimes. Consequence: two
/// true regime flips closer together than the warmup are detected as
/// one.
#[derive(Clone, Debug)]
pub struct EwmaLevel {
    alpha: f64,
    threshold: f64,
    level: f64,
    var: f64,
    seeded: bool,
    since_anchor: u64,
    seen: u64,
    shifts: u64,
}

impl EwmaLevel {
    /// `window >= 1` is the EWMA span; `threshold > 0` is the detector
    /// trip point in estimated standard deviations.
    pub fn new(window: usize, threshold: f64) -> Self {
        assert!(window >= 1, "window must be >= 1, got {window}");
        assert!(
            threshold.is_finite() && threshold > 0.0,
            "innovation threshold must be finite and > 0, got {threshold}"
        );
        EwmaLevel {
            alpha: 2.0 / (window as f64 + 1.0),
            threshold,
            level: 0.0,
            var: 0.0,
            seeded: false,
            since_anchor: 0,
            seen: 0,
            shifts: 0,
        }
    }

    /// Fold one price observation.
    pub fn observe_price(&mut self, x: f64) {
        self.seen += 1;
        if !self.seeded {
            self.seeded = true;
            self.anchor(x);
            return;
        }
        let innov = x - self.level;
        // tiny floor so a step out of a perfectly constant stream
        // (var = 0, the piecewise-constant trace case) still fires
        let sigma =
            self.var.sqrt().max(1e-12 + 1e-9 * self.level.abs());
        if self.since_anchor >= DETECTOR_WARMUP
            && innov.abs() > self.threshold * sigma
        {
            self.shifts += 1;
            self.anchor(x);
            return;
        }
        self.level += self.alpha * innov;
        self.var =
            (1.0 - self.alpha) * self.var + self.alpha * innov * innov;
        self.since_anchor += 1;
    }

    fn anchor(&mut self, x: f64) {
        self.level = x;
        self.var = 0.0;
        self.since_anchor = 1;
    }

    /// The current level estimate (0 until the first observation).
    pub fn level(&self) -> f64 {
        self.level
    }

    /// Regime changes detected so far.
    pub fn shifts(&self) -> u64 {
        self.shifts
    }
}

impl Forecaster for EwmaLevel {
    fn observe(&mut self, x: f64) {
        self.observe_price(x);
    }

    fn predict(&self) -> f64 {
        self.level()
    }

    fn observations(&self) -> u64 {
        self.seen
    }
}

// ===================================================================
// ProactiveMigrator
// ===================================================================

/// The `proactive_migrate` placement rule: forecast every portfolio
/// entry and move *before* preemption, not after the price.
///
/// Where `MigrationRule` (DESIGN.md §10) chases the cheapest current
/// effective price, this rule scores each entry by **forecast expected
/// progress per dollar**:
///
/// ```text
/// score_i = (1 - q̂_i) · speed_i / (E[1/y]|q̂_i · level_i)
/// ```
///
/// `(1 - q̂_i)` is the forecast fraction of productive slots over the
/// lookahead horizon (the portfolio `q` is market-level: the whole
/// fleet loses the slot), `E[1/y]` at the *forecast* q̂ is the exact
/// Theorem-1 convergence driver from [`binomial_expected_recip`], and
/// `level_i` is the EWMA price forecast — so a market that is cheap
/// right now but forecast-volatile scores below a slightly pricier
/// stable one, which is exactly the call the reactive rule gets wrong.
///
/// A proactive move must clear two gates: the hysteresis band
/// (`best > current·(1+hysteresis)`, the §10 anti-thrash dead-band
/// applied in score space) *and* the amortized move cost — the
/// checkpoint + restart seconds as a fraction of the lookahead
/// `horizon_s` discount the challenger's score, so short horizons
/// rightly refuse moves a long-horizon planner would take. When the
/// current market is interrupting the move is forced (to the
/// best-scoring *available* entry), mirroring `MigrationRule`.
///
/// All state updates are RNG-free folds of the slot's (prices,
/// availability) vector, which the portfolio engine already draws for
/// every market each slot.
#[derive(Clone, Debug)]
pub struct ProactiveMigrator {
    n: usize,
    hysteresis: f64,
    /// fraction of the lookahead horizon one move burns, clamped to 1
    move_penalty: f64,
    rates: Vec<SlidingWindowRate>,
    levels: Vec<EwmaLevel>,
}

impl ProactiveMigrator {
    /// `n` is the fleet size the `E[1/y]` score is evaluated at;
    /// `markets` the portfolio width; `move_cost_s` the full
    /// checkpoint + restart bill one migration pays.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        n: usize,
        markets: usize,
        hysteresis: f64,
        window: usize,
        horizon_s: f64,
        smoothing: f64,
        move_cost_s: f64,
    ) -> Self {
        assert!(n >= 1, "fleet size must be >= 1");
        assert!(markets >= 1, "portfolio must have >= 1 markets");
        assert!(
            hysteresis.is_finite() && (0.0..1.0).contains(&hysteresis),
            "hysteresis must be in [0, 1), got {hysteresis}"
        );
        assert!(
            horizon_s.is_finite() && horizon_s > 0.0,
            "horizon_s must be finite and > 0, got {horizon_s}"
        );
        assert!(
            move_cost_s.is_finite() && move_cost_s >= 0.0,
            "move cost must be finite and >= 0, got {move_cost_s}"
        );
        ProactiveMigrator {
            n,
            hysteresis,
            move_penalty: (move_cost_s / horizon_s).min(1.0),
            rates: (0..markets)
                .map(|_| SlidingWindowRate::new(window, smoothing))
                .collect(),
            levels: (0..markets)
                .map(|_| {
                    EwmaLevel::new(window, MIGRATOR_LEVEL_THRESHOLD)
                })
                .collect(),
        }
    }

    /// Fold one slot's per-market draws. The engine calls this before
    /// [`target`](ProactiveMigrator::target) every slot, so forecasts
    /// always include the slot being decided.
    pub fn observe_slot(&mut self, prices: &[f64], available: &[bool]) {
        debug_assert_eq!(prices.len(), self.rates.len());
        debug_assert_eq!(available.len(), self.rates.len());
        for m in 0..self.rates.len() {
            self.rates[m].observe_preempt(!available[m]);
            self.levels[m].observe_price(prices[m]);
        }
    }

    /// Forecast preemption rate for market `m` (capped below 1 so the
    /// `E[1/y]` score stays defined on an all-preempted window).
    pub fn rate(&self, m: usize) -> f64 {
        self.rates[m].rate().min(Q_FORECAST_CAP)
    }

    /// Forecast price level for market `m`.
    pub fn level(&self, m: usize) -> f64 {
        self.levels[m].level()
    }

    /// Forecast expected progress per dollar for entry `m` (see type
    /// docs for the formula).
    pub fn score(&self, port: &MarketPortfolio, m: usize) -> f64 {
        let q = self.rate(m);
        let recip = binomial_expected_recip(self.n, q);
        let level = self.level(m).max(1e-9);
        (1.0 - q) * port.entries[m].speed / (recip * level)
    }

    /// Where the fleet should move this slot, if anywhere. Same
    /// calling convention as `MigrationRule::target`: `None` when
    /// staying put (or when every market is interrupting), ties break
    /// to the lowest index so digests are stable.
    pub fn target(
        &self,
        port: &MarketPortfolio,
        current: usize,
        prices: &[f64],
        available: &[bool],
    ) -> Option<usize> {
        debug_assert_eq!(prices.len(), port.len());
        debug_assert_eq!(available.len(), port.len());
        if !available[current] {
            // forced move: best-scoring entry still up this slot
            let mut best: Option<(usize, f64)> = None;
            for m in 0..port.len() {
                if !available[m] {
                    continue;
                }
                let s = self.score(port, m);
                if best.is_none_or(|(_, b)| s > b) {
                    best = Some((m, s));
                }
            }
            return best.map(|(m, _)| m);
        }
        let cur = self.score(port, current);
        let mut best = (current, cur);
        for m in 0..port.len() {
            if m == current || !available[m] {
                continue;
            }
            let s = self.score(port, m);
            if s > best.1 {
                best = (m, s);
            }
        }
        if best.0 == current {
            return None;
        }
        // the challenger pays the move before it earns: discount by
        // the horizon fraction the move burns, then clear the band
        let challenger = best.1 * (1.0 - self.move_penalty);
        (challenger > cur * (1.0 + self.hysteresis)).then_some(best.0)
    }
}

// ===================================================================
// LookaheadBid
// ===================================================================

/// Re-plan the Theorem-2 bid against the forecast price level instead
/// of the static distribution.
///
/// Starts from the statically-planned bid vector (the Theorem-2
/// optimum against the spec's price CDF). On every
/// [`Event::PriceRevision`] the policy folds the price into an
/// [`EwmaLevel`] and rescales the whole vector by
/// `level / base_level`, where `base_level` is the static
/// distribution's mean — i.e. it re-plans *within the scale family*
/// of the original optimum. Under a pure proportional shift of the
/// price distribution (`p → c·p`, exactly what the regime-switching
/// trace generator's `contended_mult` does) the Theorem-2 optimal bid
/// scales by the same `c`, so the scale-family re-plan tracks the
/// true optimum through regime flips; the innovation detector makes
/// the level — and hence the bid — re-anchor in one revision when a
/// flip is detected. Bids saturate at `bid_cap` (the price-support
/// maximum, the repo's on-demand convention).
///
/// The policy is fully deterministic: no RNG in `decide`, none in
/// `on_event`, so it is digest-safe at any thread count and batches
/// like any other lane policy.
pub struct LookaheadBid {
    label: String,
    base: BidVector,
    bids: BidVector,
    j: u64,
    level: EwmaLevel,
    base_level: f64,
    bid_cap: f64,
    replans: u64,
}

impl LookaheadBid {
    /// `bids` is the static Theorem-2 plan; `base_level > 0` the
    /// static distribution's mean price; `bid_cap > 0` the saturation
    /// point; `window`/`innovation_threshold` parameterize the level
    /// forecaster.
    pub fn new(
        label: impl Into<String>,
        bids: BidVector,
        j: u64,
        window: usize,
        innovation_threshold: f64,
        base_level: f64,
        bid_cap: f64,
    ) -> Self {
        assert!(
            base_level.is_finite() && base_level > 0.0,
            "base price level must be finite and > 0, got {base_level}"
        );
        assert!(bid_cap > 0.0, "bid_cap must be > 0");
        LookaheadBid {
            label: label.into(),
            base: bids.clone(),
            bids,
            j,
            level: EwmaLevel::new(window, innovation_threshold),
            base_level,
            bid_cap,
            replans: 0,
        }
    }

    /// Current (b1, b2) after any re-planning so far.
    pub fn current_bids(&self) -> (f64, f64) {
        (self.bids.b1, self.bids.b2)
    }

    /// Regime changes the level forecaster has detected.
    pub fn regime_shifts(&self) -> u64 {
        self.level.shifts()
    }

    /// Price revisions that moved the plan.
    pub fn replans(&self) -> u64 {
        self.replans
    }

    fn replan(&mut self) {
        let scale = self.level.level() / self.base_level;
        let b1 = (self.base.b1 * scale).clamp(0.0, self.bid_cap);
        let b2 = (self.base.b2 * scale).clamp(0.0, self.bid_cap);
        if (b1, b2) != (self.bids.b1, self.bids.b2) {
            self.bids =
                BidVector::two_group(self.base.n(), self.base.n1, b1, b2);
            self.replans += 1;
        }
    }
}

impl Policy for LookaheadBid {
    fn name(&self) -> &str {
        &self.label
    }

    fn target_iters(&self) -> u64 {
        self.j
    }

    fn max_workers(&self) -> usize {
        self.bids.n()
    }

    fn decide(&mut self, price: f64, _rng: &mut Rng) -> ActiveDecision {
        ActiveDecision { active: self.bids.active_set(price), price }
    }

    fn decide_into(
        &mut self,
        price: f64,
        _rng: &mut Rng,
        active: &mut Vec<usize>,
    ) -> f64 {
        self.bids.active_set_into(price, active);
        price
    }

    fn on_event(&mut self, ev: &Event, _state: &EngineState) -> Result<()> {
        if let Event::PriceRevision { price } = ev {
            self.level.observe_price(*price);
            self.replan();
        }
        Ok(())
    }
}

// ===================================================================
// tests
// ===================================================================

#[cfg(test)]
mod tests {
    use super::*;
    use crate::market::PortfolioEntry;
    use crate::util::proptest::{close, for_all, Gen};

    fn port3() -> MarketPortfolio {
        MarketPortfolio::new(vec![
            PortfolioEntry { label: "stable".into(), speed: 1.0, q: 0.02 },
            PortfolioEntry { label: "slow".into(), speed: 0.7, q: 0.02 },
            PortfolioEntry {
                label: "volatile".into(),
                speed: 1.3,
                q: 0.3,
            },
        ])
        .unwrap()
    }

    // -------------------------------------------------- estimators

    #[test]
    fn window_rate_converges_to_true_q_on_stationary_streams() {
        for_all("window q-hat converges", |g: &mut Gen| {
            let q = g.f64_in(0.05, 0.9);
            let mut est = SlidingWindowRate::new(1024, 1.0);
            for _ in 0..4096 {
                est.observe_preempt(g.rng.bool(q));
            }
            // window std <= sqrt(0.25/1024) ~ 0.016; the bound below
            // is ~9 sigma, far outside any seeded case's reach
            close(est.rate(), q, 0.08, "sliding-window q-hat")
        });
    }

    #[test]
    fn window_rate_eviction_and_smoothing_are_exact() {
        let mut est = SlidingWindowRate::new(4, 0.0);
        for p in [true, true, true, true, false, false, false, false] {
            est.observe_preempt(p);
        }
        // the four trues were evicted by the four falses
        assert_eq!(est.rate(), 0.0);
        assert_eq!(est.observations(), 8);
        assert_eq!(est.window(), 4);

        let mut smoothed = SlidingWindowRate::new(8, 1.0);
        smoothed.observe_preempt(true);
        assert!((smoothed.rate() - 2.0 / 3.0).abs() < 1e-15);
    }

    #[test]
    fn window_rate_edge_cases() {
        // zero events, no smoothing: 0, not 0/0
        assert_eq!(SlidingWindowRate::new(8, 0.0).rate(), 0.0);
        // zero events, smoothed: the pure prior
        assert_eq!(SlidingWindowRate::new(8, 1.0).rate(), 0.5);
        // window = 1 tracks exactly the last outcome
        let mut one = SlidingWindowRate::new(1, 0.0);
        one.observe_preempt(true);
        assert_eq!(one.rate(), 1.0);
        one.observe_preempt(false);
        assert_eq!(one.rate(), 0.0);
        // all-preempted raw window forecasts 1.0 ...
        let mut all = SlidingWindowRate::new(4, 0.0);
        for _ in 0..6 {
            all.observe_preempt(true);
        }
        assert_eq!(all.rate(), 1.0);
        // ... and the migrator's capped view keeps E[1/y] defined
        let mut mig =
            ProactiveMigrator::new(4, 1, 0.05, 4, 600.0, 0.0, 16.0);
        for _ in 0..6 {
            mig.observe_slot(&[0.1], &[false]);
        }
        assert!(mig.rate(0) < 1.0);
        assert!(mig.score(&port3(), 0).is_finite());
    }

    #[test]
    fn ewma_detector_fires_on_regime_switch_and_reanchors() {
        let mut est = EwmaLevel::new(32, 4.0);
        let mut rng = Rng::new(7);
        for _ in 0..128 {
            est.observe_price(0.08 + rng.uniform(-0.004, 0.004));
        }
        assert_eq!(est.shifts(), 0, "stationary prefix must be silent");
        close(est.level(), 0.08, 0.01, "pre-switch level").unwrap();
        est.observe_price(0.16); // contended regime switches on
        assert_eq!(est.shifts(), 1, "switch must fire the detector");
        assert_eq!(est.level(), 0.16, "level re-anchors in one step");
    }

    #[test]
    fn ewma_detector_fires_on_step_out_of_constant_stream() {
        // piecewise-constant traces have zero innovation variance;
        // the sigma floor keeps the detector live there
        let mut est = EwmaLevel::new(16, 6.0);
        for _ in 0..32 {
            est.observe_price(0.1);
        }
        assert_eq!(est.shifts(), 0);
        est.observe_price(0.11);
        assert_eq!(est.shifts(), 1);
    }

    #[test]
    fn ewma_detector_silent_on_stationary_noise() {
        for_all("detector silent on noise", |g: &mut Gen| {
            let base = g.f64_in(0.05, 0.2);
            let amp = base * 0.1;
            let mut est = EwmaLevel::new(64, 6.0);
            for _ in 0..512 {
                est.observe_price(base + g.f64_in(-amp, amp));
            }
            if est.shifts() != 0 {
                return Err(format!(
                    "{} phantom regime(s) on bounded stationary noise",
                    est.shifts()
                ));
            }
            close(est.level(), base, 0.05, "level tracks the mean")
        });
    }

    #[test]
    fn forecaster_updates_are_bitwise_reproducible() {
        for_all("bitwise replay", |g: &mut Gen| {
            let xs = g.vec_f64(200, 0.01, 0.5);
            let mut a = EwmaLevel::new(16, 4.0);
            let mut b = EwmaLevel::new(16, 4.0);
            let mut ra = SlidingWindowRate::new(32, 1.0);
            let mut rb = SlidingWindowRate::new(32, 1.0);
            for &x in &xs {
                a.observe(x);
                ra.observe(if x > 0.25 { 1.0 } else { 0.0 });
            }
            for &x in &xs {
                b.observe(x);
                rb.observe(if x > 0.25 { 1.0 } else { 0.0 });
            }
            if a.predict().to_bits() != b.predict().to_bits()
                || a.shifts() != b.shifts()
                || ra.predict().to_bits() != rb.predict().to_bits()
            {
                return Err("replayed stream diverged bitwise".into());
            }
            Ok(())
        });
    }

    // -------------------------------------------------- migrator

    /// Feed `slots` observations where `volatile` (entry 2) is down
    /// every third slot but quotes the cheapest price.
    fn fed_migrator(slots: usize) -> ProactiveMigrator {
        let mut mig =
            ProactiveMigrator::new(8, 3, 0.05, 64, 600.0, 1.0, 16.0);
        for t in 0..slots {
            let down = t % 3 == 0;
            mig.observe_slot(&[0.085, 0.08, 0.055], &[true, true, !down]);
        }
        mig
    }

    #[test]
    fn migrator_stays_home_where_reactive_rule_chases_the_price() {
        let port = port3();
        let mig = fed_migrator(200);
        // q-hat for the volatile entry has converged near 1/3
        close(mig.rate(2), 1.0 / 3.0, 0.05, "volatile q-hat").unwrap();
        let prices = [0.085, 0.08, 0.055];
        let avail = [true, true, true];
        // the reactive rule sees only the cheap price and moves ...
        let reactive = crate::market::MigrationRule { hysteresis: 0.05 };
        assert_eq!(reactive.target(&port, 0, &prices, &avail), Some(2));
        // ... the forecast score knows the entry is a trap and stays
        assert_eq!(mig.target(&port, 0, &prices, &avail), None);
        assert!(
            mig.score(&port, 0) > mig.score(&port, 2),
            "stable must out-score volatile: {} vs {}",
            mig.score(&port, 0),
            mig.score(&port, 2)
        );
    }

    #[test]
    fn migrator_forced_move_picks_best_scoring_available_entry() {
        let port = port3();
        let mig = fed_migrator(200);
        let prices = [0.085, 0.08, 0.055];
        // home down: move to the best *available* forecast score —
        // entry 1, not the forecast-volatile entry 2
        assert_eq!(
            mig.target(&port, 0, &prices, &[false, true, true]),
            Some(1)
        );
        // everything down: nowhere to go
        assert_eq!(
            mig.target(&port, 0, &prices, &[false, false, false]),
            None
        );
    }

    #[test]
    fn migrator_horizon_gates_proactive_moves() {
        let port = port3();
        // entry 1 forecast-scores above entry 0 once entry 0 has seen
        // interruptions; a horizon shorter than the move cost must
        // still refuse the move
        let feed = |mig: &mut ProactiveMigrator| {
            for t in 0..200 {
                let down = t % 3 == 0;
                mig.observe_slot(
                    &[0.085, 0.08, 0.5],
                    &[!down, true, true],
                );
            }
        };
        let mut long =
            ProactiveMigrator::new(8, 3, 0.05, 64, 600.0, 1.0, 16.0);
        feed(&mut long);
        assert_eq!(
            long.target(&port, 0, &[0.085, 0.08, 0.5], &[true; 3]),
            Some(1),
            "long horizon migrates ahead of the next interruption"
        );
        let mut short =
            ProactiveMigrator::new(8, 3, 0.05, 64, 10.0, 1.0, 16.0);
        feed(&mut short);
        assert_eq!(
            short.target(&port, 0, &[0.085, 0.08, 0.5], &[true; 3]),
            None,
            "a horizon shorter than the move cost refuses the move"
        );
    }

    // -------------------------------------------------- lookahead bid

    fn state() -> EngineState {
        EngineState {
            iter: 0,
            target: 100,
            clock: 0.0,
            cost: 0.0,
            idle_time: 0.0,
            error: 1.0,
            accuracy: 0.0,
            active: 0,
            price: 0.1,
        }
    }

    #[test]
    fn lookahead_bid_rescales_with_the_forecast_level() {
        let mut pol = LookaheadBid::new(
            "look",
            BidVector::uniform(4, 0.1),
            100,
            16,
            6.0,
            0.1,
            0.5,
        );
        let st = state();
        // stationary prefix at the base level: plan unchanged
        for _ in 0..24 {
            pol.on_event(&Event::PriceRevision { price: 0.1 }, &st)
                .unwrap();
        }
        assert_eq!(pol.current_bids(), (0.1, 0.1));
        assert_eq!(pol.regime_shifts(), 0);
        // regime flip doubles the level: detector re-anchors and the
        // whole plan rescales by 2x in one revision
        pol.on_event(&Event::PriceRevision { price: 0.2 }, &st)
            .unwrap();
        assert_eq!(pol.regime_shifts(), 1);
        assert_eq!(pol.current_bids(), (0.2, 0.2));
        assert!(pol.replans() >= 1);
        // decide admits everyone below the rescaled bid, RNG-free
        let mut rng = Rng::new(1);
        assert_eq!(pol.decide(0.15, &mut rng).active.len(), 4);
    }

    #[test]
    fn lookahead_bid_saturates_at_the_cap() {
        let mut pol = LookaheadBid::new(
            "look",
            BidVector::uniform(2, 0.4),
            100,
            4,
            6.0,
            0.1,
            0.5,
        );
        let st = state();
        for _ in 0..24 {
            pol.on_event(&Event::PriceRevision { price: 0.1 }, &st)
                .unwrap();
        }
        pol.on_event(&Event::PriceRevision { price: 0.4 }, &st)
            .unwrap();
        // scale 4x would put the bid at 1.6; the cap holds it at 0.5
        assert_eq!(pol.current_bids(), (0.5, 0.5));
    }
}
