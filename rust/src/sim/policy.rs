//! Event-reactive adaptive policies: first-class [`Policy`] impls that
//! *use* the engine's event stream instead of ignoring it (DESIGN.md §6).
//!
//! The paper's Sec. V strategies fix their bids and fleet plans before
//! the run starts; every classic `StrategyKind` therefore runs through
//! the blanket [`LockstepPolicy`](super::LockstepPolicy) wrapper and
//! reacts only to completed iterations. These three policies implement
//! [`Policy`] directly and react to the events the lockstep API could
//! not express:
//!
//! * [`NoticeRebid`] — on [`Event::WorkerPreempted`], re-enters the
//!   market with its bids bumped by a configurable factor (the engine's
//!   `[overhead]` notice window emergency-checkpoints first, so no work
//!   is lost when the notice covers the checkpoint cost) — the
//!   Parcae-style proactive reaction to preemption notices;
//! * [`ElasticFleet`] — on [`Event::PriceRevision`], resizes its active
//!   worker target to keep the expected spend rate under a budget,
//!   picking the fleet size with the best exact `E[1/y | y > 0]` from a
//!   precomputed [`RecipTable`] — Scavenger-style cost/progress
//!   co-optimisation, online;
//! * [`DeadlineAware`] — tracks remaining iterations against the
//!   deadline horizon and escalates to on-demand (bid = ∞, every worker
//!   active at any price) when its completion proxy drops below a
//!   threshold — the paper's Sec. V-B dynamic strategy generalised to
//!   event time.
//!
//! **RNG-consumption contract (DESIGN.md §6).** `Policy::on_event` must
//! not draw randomness; all three policies make *deterministic*
//! decisions from the event stream and confine their stochastic choices
//! to `decide` (where [`ElasticFleet`] draws its preemption subset,
//! exactly like `StaticWorkers`). That is what keeps sweep digests
//! bit-identical at any thread count even for reactive runs.
//!
//! # Example
//!
//! An [`ElasticFleet`] retargets as soon as a price revision arrives —
//! no simulation required to see the arithmetic:
//!
//! ```
//! use volatile_sgd::preempt::{PreemptionModel, RecipTable};
//! use volatile_sgd::sim::policy::ElasticFleet;
//! use volatile_sgd::sim::{Event, EngineState, Policy};
//!
//! let model = PreemptionModel::Bernoulli { q: 0.5 };
//! let table = RecipTable::build(&model, 16);
//! // spend rate per worker = (1 - q) * price = 0.125; budget 0.6
//! let mut fleet = ElasticFleet::new("elastic", 100, table, 0.6);
//! let state = EngineState {
//!     iter: 0, target: 100, clock: 0.0, cost: 0.0, idle_time: 0.0,
//!     error: 1.0, accuracy: 0.0, active: 0, price: 0.25,
//! };
//! fleet.on_event(&Event::PriceRevision { price: 0.25 }, &state).unwrap();
//! assert_eq!(fleet.target(), 4); // 4 * 0.125 = 0.5 <= 0.6 < 5 * 0.125
//! ```

use anyhow::Result;

use crate::coordinator::strategy::ActiveDecision;
use crate::market::BidVector;
use crate::preempt::{PreemptionModel, RecipTable};
use crate::util::rng::Rng;

use super::engine::{EngineState, Event, Policy};

// ===================================================================
// NoticeRebid
// ===================================================================

/// Re-enter the market at a higher bid after every full interruption.
///
/// Starts from a fixed bid vector (typically the Theorem-2 optimal
/// one-bid plan). On [`Event::WorkerPreempted`] — the active set fell
/// to zero after running — both bid groups are multiplied by
/// `rebid_factor` (capped at `bid_cap`, normally the price-support
/// maximum, above which a bid keeps every worker active at any
/// realizable price). Workers always pay the *spot* price, never the
/// bid, so rebidding trades preemption frequency against admission at
/// higher prices.
///
/// Pair it with an `[overhead]` table whose `preempt_notice_s` covers
/// `checkpoint_cost_s`: the engine then emergency-checkpoints inside
/// the notice window ([`Event::CheckpointDone`] fires *before* the
/// `WorkerPreempted` that triggers the rebid), so no work is lost while
/// the policy repositions itself.
pub struct NoticeRebid {
    label: String,
    bids: BidVector,
    j: u64,
    rebid_factor: f64,
    bid_cap: f64,
    rebids: u64,
}

impl NoticeRebid {
    /// `bids` is the starting vector; `rebid_factor >= 1` scales both
    /// groups on every preemption; `bid_cap` saturates the growth.
    pub fn new(
        label: impl Into<String>,
        bids: BidVector,
        j: u64,
        rebid_factor: f64,
        bid_cap: f64,
    ) -> Self {
        assert!(rebid_factor >= 1.0, "rebid_factor must be >= 1");
        assert!(bid_cap > 0.0, "bid_cap must be > 0");
        NoticeRebid {
            label: label.into(),
            bids,
            j,
            rebid_factor,
            bid_cap,
            rebids: 0,
        }
    }

    /// Current (b1, b2) — grows monotonically, saturating at the cap.
    pub fn current_bids(&self) -> (f64, f64) {
        (self.bids.b1, self.bids.b2)
    }

    /// Number of preemption-triggered rebids so far.
    pub fn rebids(&self) -> u64 {
        self.rebids
    }
}

impl Policy for NoticeRebid {
    fn name(&self) -> &str {
        &self.label
    }

    fn target_iters(&self) -> u64 {
        self.j
    }

    fn max_workers(&self) -> usize {
        self.bids.n()
    }

    fn decide(&mut self, price: f64, _rng: &mut Rng) -> ActiveDecision {
        ActiveDecision { active: self.bids.active_set(price), price }
    }

    fn decide_into(
        &mut self,
        price: f64,
        _rng: &mut Rng,
        active: &mut Vec<usize>,
    ) -> f64 {
        self.bids.active_set_into(price, active);
        price
    }

    fn on_event(&mut self, ev: &Event, _state: &EngineState) -> Result<()> {
        if matches!(ev, Event::WorkerPreempted { .. }) {
            let b1 = (self.bids.b1 * self.rebid_factor).min(self.bid_cap);
            let b2 = (self.bids.b2 * self.rebid_factor).min(self.bid_cap);
            self.bids =
                BidVector::two_group(self.bids.n(), self.bids.n1, b1, b2);
            self.rebids += 1;
        }
        Ok(())
    }
}

// ===================================================================
// ElasticFleet
// ===================================================================

/// Resize the provisioned fleet on every price revision to keep the
/// expected spend rate under a budget.
///
/// The platform still preempts each provisioned worker per the
/// preemption model (the Sec. V setting); what the policy controls is
/// the provisioning *target*. At each [`Event::PriceRevision`] it
/// scans fleet sizes `1..=n_max` and keeps the one with the smallest
/// exact `E[1/y | y > 0]` (the Theorem-1 convergence driver, read from
/// the precomputed [`RecipTable`]) among those whose expected spend
/// rate — unconditional mean active workers times the prevailing price
/// — fits the budget. A fleet of 1 is always admissible: the job keeps
/// making progress even when the budget is momentarily blown, it just
/// refuses to scale.
///
/// Unlike `StaticWorkers` (which carries its own `unit_price`), the
/// fleet is billed at the *prevailing market price* — that is the
/// signal it reacts to. On a fixed-price market the target moves only
/// when an axis or override moves the price or budget.
pub struct ElasticFleet {
    label: String,
    j: u64,
    model: PreemptionModel,
    table: RecipTable,
    budget_rate: f64,
    n_target: usize,
}

impl ElasticFleet {
    /// `table` caps the fleet at `table.n_max()` and carries the
    /// preemption model; `budget_rate` is $/unit-time.
    pub fn new(
        label: impl Into<String>,
        j: u64,
        table: RecipTable,
        budget_rate: f64,
    ) -> Self {
        assert!(
            budget_rate.is_finite() && budget_rate > 0.0,
            "budget_rate must be finite and > 0"
        );
        ElasticFleet {
            label: label.into(),
            j,
            model: table.model().clone(),
            table,
            budget_rate,
            n_target: 1,
        }
    }

    /// The current provisioning target (updated at each price revision;
    /// the engine emits `PriceRevision` before calling `decide`, so the
    /// target always reflects the slot's price).
    pub fn target(&self) -> usize {
        self.n_target
    }

    /// The budget-feasible fleet size with the best exact `E[1/y]`:
    /// the resize arithmetic the unit tests pin against the table.
    fn retarget(&mut self, price: f64) {
        let mut best = 1usize;
        let mut best_recip = self.table.recip(1);
        for n in 2..=self.table.n_max() {
            let spend = self.model.mean_active(n) * price;
            if spend > self.budget_rate {
                continue;
            }
            let recip = self.table.recip(n);
            if recip < best_recip {
                best = n;
                best_recip = recip;
            }
        }
        self.n_target = best;
    }
}

impl Policy for ElasticFleet {
    fn name(&self) -> &str {
        &self.label
    }

    fn target_iters(&self) -> u64 {
        self.j
    }

    fn max_workers(&self) -> usize {
        self.table.n_max()
    }

    fn decide(&mut self, price: f64, rng: &mut Rng) -> ActiveDecision {
        ActiveDecision {
            active: self.model.draw_active(self.n_target, rng),
            price,
        }
    }

    fn decide_into(
        &mut self,
        price: f64,
        rng: &mut Rng,
        active: &mut Vec<usize>,
    ) -> f64 {
        self.model.draw_active_into(self.n_target, rng, active);
        price
    }

    fn on_event(&mut self, ev: &Event, _state: &EngineState) -> Result<()> {
        if let Event::PriceRevision { price } = ev {
            self.retarget(*price);
        }
        Ok(())
    }
}

// ===================================================================
// DeadlineAware
// ===================================================================

/// Escalate to on-demand when finishing by the deadline looks unlikely.
///
/// Runs a fixed bid vector (typically the Theorem-2 plan) and, on every
/// [`Event::PriceRevision`] and [`Event::IterationDone`], compares the
/// time left before `theta` against the Lemma-1 estimate of the time
/// still needed, `remaining_j * E[R(n)] / F(b)`. The ratio of the two,
/// clamped to `[0, 1]`, is a deterministic completion proxy; when it
/// drops below `threshold` the policy escalates — bids become infinite,
/// every worker is admitted at any price (the repo's "bid above the
/// cap" on-demand convention: workers still pay the spot price). The
/// escalation is one-way, mirroring the paper's Sec. V-B dynamic
/// strategy, which only ever adds capacity as the deadline nears.
pub struct DeadlineAware {
    label: String,
    bids: BidVector,
    j: u64,
    theta: f64,
    p_active: f64,
    slot_time: f64,
    threshold: f64,
    escalated: bool,
}

impl DeadlineAware {
    /// `p_active` is `F(b1)` of the starting bids (the per-slot
    /// iteration probability), `slot_time` the expected iteration
    /// runtime `E[R(n)]`, `threshold` the completion-proxy floor in
    /// `(0, 1]`.
    pub fn new(
        label: impl Into<String>,
        bids: BidVector,
        j: u64,
        theta: f64,
        p_active: f64,
        slot_time: f64,
        threshold: f64,
    ) -> Self {
        assert!(
            threshold > 0.0 && threshold <= 1.0,
            "threshold must be in (0, 1]"
        );
        assert!(slot_time > 0.0, "slot_time must be > 0");
        DeadlineAware {
            label: label.into(),
            bids,
            j,
            theta,
            p_active: p_active.clamp(1e-9, 1.0),
            slot_time,
            threshold,
            escalated: false,
        }
    }

    /// True once the policy has switched to on-demand.
    pub fn escalated(&self) -> bool {
        self.escalated
    }

    /// The deterministic completion proxy at (iter, clock):
    /// `min(1, time_left / est_time_needed)`.
    pub fn completion_proxy(&self, iter: u64, clock: f64) -> f64 {
        let remaining = self.j.saturating_sub(iter);
        if remaining == 0 {
            return 1.0;
        }
        let p = if self.escalated { 1.0 } else { self.p_active };
        let needed = remaining as f64 * self.slot_time / p;
        ((self.theta - clock) / needed).clamp(0.0, 1.0)
    }
}

impl Policy for DeadlineAware {
    fn name(&self) -> &str {
        &self.label
    }

    fn target_iters(&self) -> u64 {
        self.j
    }

    fn max_workers(&self) -> usize {
        self.bids.n()
    }

    fn decide(&mut self, price: f64, _rng: &mut Rng) -> ActiveDecision {
        let active = if self.escalated {
            (0..self.bids.n()).collect()
        } else {
            self.bids.active_set(price)
        };
        ActiveDecision { active, price }
    }

    fn decide_into(
        &mut self,
        price: f64,
        _rng: &mut Rng,
        active: &mut Vec<usize>,
    ) -> f64 {
        if self.escalated {
            active.clear();
            active.extend(0..self.bids.n());
        } else {
            self.bids.active_set_into(price, active);
        }
        price
    }

    fn on_event(&mut self, ev: &Event, state: &EngineState) -> Result<()> {
        if self.escalated {
            return Ok(());
        }
        if matches!(ev, Event::PriceRevision { .. } | Event::IterationDone)
            && self.completion_proxy(state.iter, state.clock)
                < self.threshold
        {
            self.escalated = true;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SyntheticBackend;
    use crate::market::SpotTrace;
    use crate::sim::{
        Engine, EngineParams, EventLog, OverheadModel, PriceSource,
    };
    use crate::theory::bounds::{ErrorBound, SgdHyper};
    use crate::theory::runtime_model::RuntimeModel;

    fn bound() -> ErrorBound {
        ErrorBound::new(SgdHyper::paper_cnn())
    }

    fn state(iter: u64, clock: f64) -> EngineState {
        EngineState {
            iter,
            target: 0,
            clock,
            cost: 0.0,
            idle_time: 0.0,
            error: 1.0,
            accuracy: 0.0,
            active: 0,
            price: 0.5,
        }
    }

    fn params(overhead: OverheadModel) -> EngineParams {
        EngineParams {
            runtime: RuntimeModel::Deterministic { r: 10.0 },
            idle_step: 4.0,
            theta_cap: f64::INFINITY,
            stride: 1,
            max_slots: 10_000,
            overhead,
        }
    }

    // ------------------------------------------------- NoticeRebid

    #[test]
    fn notice_rebid_bumps_and_saturates() {
        let mut p = NoticeRebid::new(
            "rebid",
            BidVector::uniform(4, 0.5),
            100,
            2.0,
            1.0,
        );
        assert_eq!(p.current_bids(), (0.5, 0.5));
        let ev = Event::WorkerPreempted { notice: 0.0 };
        p.on_event(&ev, &state(10, 100.0)).unwrap();
        assert_eq!(p.current_bids(), (1.0, 1.0));
        p.on_event(&ev, &state(20, 200.0)).unwrap();
        assert_eq!(p.current_bids(), (1.0, 1.0)); // capped
        assert_eq!(p.rebids(), 2);
        // non-preemption events never move the bids
        p.on_event(&Event::IterationDone, &state(21, 210.0)).unwrap();
        assert_eq!(p.rebids(), 2);
    }

    /// On a crafted trace (price spikes above the initial bid, then
    /// falls back), the engine's notice window emergency-checkpoints
    /// *before* the preemption event that triggers the rebid, and the
    /// bumped bid survives the next spike: the rebid ordering contract.
    #[test]
    fn notice_window_checkpoint_precedes_rebid() {
        // bid 0.5; spike to 0.8 at t in [40, 60): one full interruption
        // for the original bid, none after the rebid lifts b to 1.0
        let trace = SpotTrace::new(
            vec![0.0, 40.0, 60.0, 200.0, 260.0],
            vec![0.3, 0.8, 0.3, 0.8, 0.3],
        )
        .unwrap();
        let ov = OverheadModel {
            checkpoint_every_iters: 0,
            checkpoint_cost_s: 5.0,
            restart_delay_s: 0.0,
            lost_work_on_preempt: true,
            preempt_notice_s: 30.0, // covers the checkpoint cost
        };
        let mut policy = NoticeRebid::new(
            "rebid",
            BidVector::uniform(1, 0.5),
            40,
            2.0,
            1.0,
        );
        let mut b = SyntheticBackend::new(bound());
        let mut rng = crate::util::rng::Rng::new(7);
        let mut log = EventLog::new();
        let r = Engine::new(params(ov))
            .run(
                &mut policy,
                &mut b,
                &PriceSource::Trace(trace),
                &mut rng,
                &mut [&mut log],
            )
            .unwrap();
        assert_eq!(r.iters, 40);
        assert_eq!(r.preemptions, 1, "the rebid prevents the second spike");
        assert_eq!(policy.rebids(), 1);
        assert_eq!(policy.current_bids(), (1.0, 1.0));
        assert_eq!(r.lost_iters, 0, "notice covered the checkpoint");
        let kinds = log.kinds();
        let ck = kinds.iter().position(|k| *k == "checkpoint_done").unwrap();
        let pre = kinds.iter().position(|k| *k == "worker_preempted").unwrap();
        assert!(ck < pre, "emergency save precedes the rebid: {kinds:?}");
        // during the second spike the (rebid) worker keeps running
        assert_eq!(
            kinds.iter().filter(|k| **k == "worker_preempted").count(),
            1
        );
    }

    // ------------------------------------------------- ElasticFleet

    #[test]
    fn elastic_fleet_resize_matches_recip_table() {
        let model = PreemptionModel::Bernoulli { q: 0.5 };
        let table = RecipTable::build(&model, 16);
        let mut p = ElasticFleet::new("elastic", 100, table, 0.6);
        // per-worker spend at price 0.25: (1 - 0.5) * 0.25 = 0.125
        p.on_event(&Event::PriceRevision { price: 0.25 }, &state(0, 0.0))
            .unwrap();
        assert_eq!(p.target(), 4, "4 * 0.125 = 0.5 <= 0.6 < 5 * 0.125");
        // the chosen n is the feasible argmin of the exact E[1/y] table
        for n in 1..=16usize {
            let feasible = model.mean_active(n) * 0.25 <= 0.6;
            if feasible {
                assert!(
                    model.expected_recip(p.target())
                        <= model.expected_recip(n) + 1e-15,
                    "n={n} beats the chosen target"
                );
            }
        }
        // cheaper prices admit the full fleet; dearer ones shrink to 1
        p.on_event(&Event::PriceRevision { price: 0.01 }, &state(0, 0.0))
            .unwrap();
        assert_eq!(p.target(), 16);
        p.on_event(&Event::PriceRevision { price: 10.0 }, &state(0, 0.0))
            .unwrap();
        assert_eq!(p.target(), 1, "floor: never stop making progress");
    }

    #[test]
    fn elastic_fleet_draws_within_target() {
        let model = PreemptionModel::Bernoulli { q: 0.3 };
        let table = RecipTable::build(&model, 8);
        let mut p = ElasticFleet::new("elastic", 100, table, 0.7);
        p.on_event(&Event::PriceRevision { price: 0.25 }, &state(0, 0.0))
            .unwrap();
        let target = p.target(); // 0.7 * 0.25 = 0.175/worker -> n = 4
        assert_eq!(target, 4);
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..200 {
            let d = p.decide(0.25, &mut rng);
            assert!(d.active.len() <= target);
            assert!(d.active.iter().all(|&w| w < target));
            assert_eq!(d.price, 0.25, "billed at the market price");
        }
    }

    // ------------------------------------------------ DeadlineAware

    #[test]
    fn deadline_aware_escalates_at_threshold_crossing() {
        // 100 iters at 10 s, F(b) = 0.5: needs 2000 s of slack
        let mk = || {
            DeadlineAware::new(
                "deadline",
                BidVector::uniform(2, 0.5),
                100,
                10_000.0,
                0.5,
                10.0,
                0.5,
            )
        };
        let mut p = mk();
        // comfortable: 50 left -> needed 1000 s, 5000 s remain
        p.on_event(&Event::IterationDone, &state(50, 5_000.0)).unwrap();
        assert!(!p.escalated());
        // proxy exactly at the threshold does not trip (strictly below)
        let mut q = mk();
        q.on_event(&Event::IterationDone, &state(50, 9_500.0)).unwrap();
        assert!((q.completion_proxy(50, 9_500.0) - 0.5).abs() < 1e-12);
        assert!(!q.escalated());
        // 50 left, 400 s remain: proxy 0.4 < 0.5 -> escalate
        p.on_event(&Event::PriceRevision { price: 0.9 }, &state(50, 9_600.0))
            .unwrap();
        assert!(p.escalated());
        // escalated: every worker active at any price
        let mut rng = crate::util::rng::Rng::new(1);
        let d = p.decide(100.0, &mut rng);
        assert_eq!(d.active.len(), 2);
        // one-way: a later comfortable state does not de-escalate
        p.on_event(&Event::IterationDone, &state(99, 9_601.0)).unwrap();
        assert!(p.escalated());
    }

    #[test]
    fn deadline_aware_proxy_accounts_for_escalation() {
        let mut p = DeadlineAware::new(
            "deadline",
            BidVector::uniform(2, 0.5),
            100,
            1_000.0,
            0.5,
            10.0,
            0.5,
        );
        // pre-escalation the estimate divides by F(b)
        assert!((p.completion_proxy(0, 0.0) - 0.5).abs() < 1e-12);
        p.on_event(&Event::PriceRevision { price: 0.6 }, &state(0, 200.0))
            .unwrap();
        assert!(p.escalated());
        // post-escalation every slot runs: needed halves
        assert!((p.completion_proxy(0, 0.0) - 1.0).abs() < 1e-12);
        assert_eq!(p.completion_proxy(100, 999.0), 1.0, "done is done");
    }
}
