//! Discrete-event simulation engine: the run loop as a typed
//! event/policy/observer API (DESIGN.md §5).
//!
//! The paper's model reduces every run to "read price -> resolve active
//! set -> one synchronous iteration", and that lockstep loop used to be
//! hard-coded in `coordinator::scheduler::Scheduler::run`. This module
//! generalises it:
//!
//! * [`Event`] — the typed occurrences a run is made of (price
//!   revisions, preemptions/restorations, iterations, checkpoints, the
//!   deadline);
//! * [`Policy`] — the event-reactive decision maker. It supersedes
//!   [`Strategy`]: every existing `decide`/`on_iteration` strategy
//!   adapts via the blanket [`LockstepPolicy`] wrapper, so all seven
//!   `StrategyKind`s run unchanged;
//! * [`Observer`] — pluggable read-only hooks that absorb the
//!   recording concerns the old loop inlined ([`SeriesRecorder`] for
//!   stride-sampled series, [`EventLog`] for ordering assertions);
//! * [`OverheadModel`] — the worker-lifecycle overhead model
//!   (checkpoint cost, restart/recovery lag, lost work on preemption,
//!   preemption notice) that the lockstep loop could not express.
//!
//! **Determinism contract (non-negotiable, §3/§4).** With
//! `OverheadModel::none()` the engine consumes the replicate RNG stream
//! in *exactly* the order the paper's lockstep loop did — per slot:
//! price draw, `decide`, runtime sample, backend step — and performs
//! the identical `CostMeter` operations in the identical order, so
//! every shipped preset's sweep digest is bit-identical before and
//! after the redesign. `Scheduler::run_reference` keeps the verbatim
//! pre-engine loop as the oracle this equivalence is tested against
//! (`tests/integration_engine.rs`).
//!
//! # Example
//!
//! A classic strategy on the engine via the lockstep adapter, with an
//! [`EventLog`] observing the run:
//!
//! ```
//! use volatile_sgd::coordinator::backend::SyntheticBackend;
//! use volatile_sgd::coordinator::strategy::FixedBids;
//! use volatile_sgd::market::BidVector;
//! use volatile_sgd::sim::{
//!     Engine, EngineParams, EventLog, LockstepPolicy, PriceSource,
//! };
//! use volatile_sgd::theory::bounds::{ErrorBound, SgdHyper};
//! use volatile_sgd::theory::runtime_model::RuntimeModel;
//! use volatile_sgd::util::rng::Rng;
//!
//! let mut strategy = FixedBids::new("demo", BidVector::uniform(2, 1.0), 20);
//! let mut backend = SyntheticBackend::new(ErrorBound::new(SgdHyper::paper_cnn()));
//! let params = EngineParams {
//!     runtime: RuntimeModel::Deterministic { r: 10.0 },
//!     ..EngineParams::default()
//! };
//! let mut log = EventLog::new();
//! let result = Engine::new(params)
//!     .run(
//!         &mut LockstepPolicy(&mut strategy),
//!         &mut backend,
//!         &PriceSource::Fixed(0.5),
//!         &mut Rng::new(1),
//!         &mut [&mut log],
//!     )
//!     .unwrap();
//! assert_eq!(result.iters, 20);
//! assert_eq!(
//!     log.kinds().iter().filter(|k| **k == "iteration_done").count(),
//!     20,
//! );
//! ```

use anyhow::{ensure, Result};

use crate::coordinator::backend::TrainingBackend;
use crate::coordinator::strategy::{ActiveDecision, Strategy, StrategyState};
use crate::metrics::{Point, Series};
use crate::theory::runtime_model::RuntimeModel;
use crate::util::rng::Rng;

use super::{CostMeter, PriceSource};

// ===================================================================
// Events
// ===================================================================

/// One typed occurrence in a simulated run. Ordering rules and the
/// RNG-consumption contract per event type are documented in
/// DESIGN.md §5.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Event {
    /// A new slot's price is in effect (drawn/read *before* the policy
    /// decides). The only event that may consume RNG before it fires
    /// (the i.i.d. price draw itself).
    PriceRevision { price: f64 },
    /// The active set fell to zero after a slot that ran an iteration:
    /// a full interruption begins. `notice` is the advance warning the
    /// platform gives (e.g. a GCP 30 s / AWS 2 min notice); with
    /// `lost_work_on_preempt` and a notice long enough to cover
    /// `checkpoint_cost_s`, the engine takes an emergency checkpoint
    /// inside the window instead of losing work.
    WorkerPreempted { notice: f64 },
    /// The active set is non-empty again after an interruption (fires
    /// after the restart delay has been charged).
    WorkerRestored,
    /// One synchronous SGD iteration completed (the event
    /// [`LockstepPolicy`] maps onto `Strategy::on_iteration`).
    IterationDone,
    /// A checkpoint was written (periodic or emergency).
    CheckpointDone,
    /// The run was cut by `theta_cap` or the `max_slots` runaway guard.
    DeadlineHit,
}

impl Event {
    /// Stable machine-readable tag, payload dropped — the `kind` field
    /// of trace JSONL lines (`obs::trace`) and of [`EventLog::kinds`].
    pub fn kind(&self) -> &'static str {
        match self {
            Event::PriceRevision { .. } => "price_revision",
            Event::WorkerPreempted { .. } => "worker_preempted",
            Event::WorkerRestored => "worker_restored",
            Event::IterationDone => "iteration_done",
            Event::CheckpointDone => "checkpoint_done",
            Event::DeadlineHit => "deadline_hit",
        }
    }
}

/// Read-only run state handed to policies and observers with every
/// event. Values are as of the moment the event fires (e.g. at
/// [`Event::IterationDone`] the iteration's cost is already charged).
#[derive(Clone, Copy, Debug)]
pub struct EngineState {
    /// completed (net) iterations — rolled back on lost work
    pub iter: u64,
    /// the policy's target iteration count
    pub target: u64,
    /// virtual wall-clock (busy + idle)
    pub clock: f64,
    /// cumulative $ cost
    pub cost: f64,
    /// cumulative idle (zero-active) time
    pub idle_time: f64,
    /// latest error signal from the backend
    pub error: f64,
    /// latest accuracy signal from the backend
    pub accuracy: f64,
    /// active workers in the current slot (0 outside iterations)
    pub active: usize,
    /// price in effect: the spot draw at [`Event::PriceRevision`], the
    /// rate actually paid at iteration/checkpoint/restore events
    pub price: f64,
}

// ===================================================================
// Policy: the event-reactive decision maker
// ===================================================================

/// An event-reactive coordination policy — the engine-native
/// generalisation of [`Strategy`]. `decide` resolves the active set at
/// each price revision exactly as before; `on_event` sees *every*
/// engine event, so a policy can react to preemptions, restorations
/// and checkpoints rather than only to completed iterations (the
/// Parcae-style reactive case the lockstep API could not express).
pub trait Policy {
    fn name(&self) -> &str;

    /// Total SGD iterations this policy intends to run.
    fn target_iters(&self) -> u64;

    /// Upper bound on concurrently active workers (pool sizing).
    fn max_workers(&self) -> usize;

    /// Resolve the active set for the slot whose price is `price`.
    fn decide(&mut self, price: f64, rng: &mut Rng) -> ActiveDecision;

    /// [`Policy::decide`] into a caller-owned buffer, returning the
    /// charged price — the allocation-free form the batched replicate
    /// executor (`sim::batch`) calls per slot. Must consume the RNG and
    /// fill `active` exactly as `decide` would.
    fn decide_into(
        &mut self,
        price: f64,
        rng: &mut Rng,
        active: &mut Vec<usize>,
    ) -> f64 {
        let d = self.decide(price, rng);
        active.clear();
        active.extend_from_slice(&d.active);
        d.price
    }

    /// React to an engine event. Must not consume RNG (the §3 stream
    /// contract leaves all stochastic choices to `decide` and the
    /// engine itself).
    fn on_event(&mut self, ev: &Event, state: &EngineState) -> Result<()> {
        let _ = (ev, state);
        Ok(())
    }
}

/// Blanket adapter: any [`Strategy`] is a [`Policy`] that reacts only
/// to [`Event::IterationDone`] (mapped onto `Strategy::on_iteration`)
/// and ignores every other event — the paper's lockstep semantics as
/// one engine configuration. `Box<dyn Strategy>` and `&mut dyn
/// Strategy` adapt too via the delegating `Strategy` impls on those
/// types.
pub struct LockstepPolicy<S: Strategy>(pub S);

impl<S: Strategy> Policy for LockstepPolicy<S> {
    fn name(&self) -> &str {
        self.0.name()
    }

    fn target_iters(&self) -> u64 {
        self.0.target_iters()
    }

    fn max_workers(&self) -> usize {
        self.0.max_workers()
    }

    fn decide(&mut self, price: f64, rng: &mut Rng) -> ActiveDecision {
        self.0.decide(price, rng)
    }

    fn decide_into(
        &mut self,
        price: f64,
        rng: &mut Rng,
        active: &mut Vec<usize>,
    ) -> f64 {
        self.0.decide_into(price, rng, active)
    }

    fn on_event(&mut self, ev: &Event, state: &EngineState) -> Result<()> {
        if matches!(ev, Event::IterationDone) {
            self.0.on_iteration(&StrategyState {
                iter: state.iter,
                clock: state.clock,
                cost: state.cost,
                error: state.error,
            })?;
        }
        Ok(())
    }
}

// ===================================================================
// Observers
// ===================================================================

/// A read-only event hook. Observers absorb the recording concerns
/// the pre-engine loop inlined (series sampling, event audits); they
/// never consume RNG and never influence the run.
pub trait Observer {
    fn on_event(&mut self, ev: &Event, state: &EngineState);

    /// The portfolio runner announces the market index subsequent
    /// events belong to (single-market runs never call this). A no-op
    /// for observers that don't attribute events to markets.
    fn on_market(&mut self, m: usize) {
        let _ = m;
    }
}

/// Records a stride-sampled [`Series`] of the run trajectory — the
/// recording that `Scheduler::run` used to inline. A point is pushed
/// at every `stride`-th iteration and at the final (target) iteration,
/// exactly the pre-engine condition.
pub struct SeriesRecorder {
    stride: u64,
    series: Series,
}

impl SeriesRecorder {
    pub fn new(stride: u64) -> Self {
        SeriesRecorder { stride: stride.max(1), series: Series::default() }
    }

    pub fn into_series(self) -> Series {
        self.series
    }
}

impl Observer for SeriesRecorder {
    fn on_event(&mut self, ev: &Event, st: &EngineState) {
        if matches!(ev, Event::IterationDone)
            && (st.iter % self.stride == 0 || st.iter == st.target)
        {
            self.series.push(Point {
                clock: st.clock,
                iter: st.iter,
                cost: st.cost,
                error: st.error,
                accuracy: st.accuracy,
                active: st.active,
            });
        }
    }
}

/// Captures the full event sequence (with the iteration counter at
/// each event) for ordering assertions in tests and audits.
#[derive(Default)]
pub struct EventLog {
    pub events: Vec<(Event, u64)>,
}

impl EventLog {
    pub fn new() -> Self {
        Self::default()
    }

    /// The sequence of events, payloads dropped — convenient for
    /// ordering assertions.
    pub fn kinds(&self) -> Vec<&'static str> {
        self.events.iter().map(|(e, _)| e.kind()).collect()
    }
}

impl Observer for EventLog {
    fn on_event(&mut self, ev: &Event, st: &EngineState) {
        self.events.push((*ev, st.iter));
    }
}

// ===================================================================
// Overhead model
// ===================================================================

/// Worker-lifecycle overhead (checkpoint/restart costs and recovery
/// lag) — the failure modes that dominate real volatile-instance
/// training but that the paper's frictionless model sets to zero.
/// `OverheadModel::none()` is the paper's model and the digest-compat
/// default everywhere.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OverheadModel {
    /// write a checkpoint every this many completed iterations
    /// (0 = never checkpoint)
    pub checkpoint_every_iters: u64,
    /// wall-clock seconds one checkpoint takes (billed for the active
    /// workers at the slot's price)
    pub checkpoint_cost_s: f64,
    /// recovery lag after a full interruption: the restored workers
    /// are billed this long before iterations resume
    pub restart_delay_s: f64,
    /// on a full interruption, iterations since the last checkpoint
    /// are lost and recomputed (the backend state rolls back)
    pub lost_work_on_preempt: bool,
    /// advance preemption warning; a notice covering
    /// `checkpoint_cost_s` lets the engine emergency-checkpoint inside
    /// the window instead of losing work
    pub preempt_notice_s: f64,
}

impl OverheadModel {
    /// The paper's frictionless model: no checkpoints, no restart lag,
    /// no lost work. With this model the engine is RNG- and
    /// accounting-identical to the pre-engine lockstep loop.
    pub fn none() -> Self {
        OverheadModel {
            checkpoint_every_iters: 0,
            checkpoint_cost_s: 0.0,
            restart_delay_s: 0.0,
            lost_work_on_preempt: false,
            preempt_notice_s: 0.0,
        }
    }

    /// True when any overhead mechanism is switched on.
    pub fn enabled(&self) -> bool {
        self.checkpoint_every_iters > 0
            || self.restart_delay_s > 0.0
            || self.lost_work_on_preempt
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.checkpoint_cost_s.is_finite() && self.checkpoint_cost_s >= 0.0,
            "overhead.checkpoint_cost_s must be finite and >= 0, got {}",
            self.checkpoint_cost_s
        );
        ensure!(
            self.restart_delay_s.is_finite() && self.restart_delay_s >= 0.0,
            "overhead.restart_delay_s must be finite and >= 0, got {}",
            self.restart_delay_s
        );
        ensure!(
            self.preempt_notice_s.is_finite() && self.preempt_notice_s >= 0.0,
            "overhead.preempt_notice_s must be finite and >= 0, got {}",
            self.preempt_notice_s
        );
        Ok(())
    }
}

impl Default for OverheadModel {
    fn default() -> Self {
        Self::none()
    }
}

// ===================================================================
// Engine
// ===================================================================

/// Engine configuration: the loop knobs of the old `SchedulerParams`
/// plus the overhead model.
#[derive(Clone, Copy, Debug)]
pub struct EngineParams {
    pub runtime: RuntimeModel,
    /// idle re-check interval when no workers are active (paper: 4 s)
    pub idle_step: f64,
    /// hard wall-clock cap (usually the deadline theta, or a multiple)
    pub theta_cap: f64,
    /// record a series point every `stride` iterations
    pub stride: u64,
    /// runaway guard on total slots (idle + busy)
    pub max_slots: u64,
    pub overhead: OverheadModel,
}

impl Default for EngineParams {
    fn default() -> Self {
        EngineParams {
            runtime: RuntimeModel::paper_default(),
            idle_step: 4.0,
            theta_cap: f64::INFINITY,
            stride: 10,
            max_slots: 50_000_000,
            overhead: OverheadModel::none(),
        }
    }
}

impl EngineParams {
    /// The sweep harness's historical lockstep configuration (the
    /// pre-redesign `exp::run_synthetic_rng` constants): idle 4 s,
    /// stride 10, a 2x10^8 slot guard, frictionless overhead — the
    /// values every shipped preset digest is pinned against.
    pub fn lockstep(runtime: RuntimeModel, theta_cap: f64) -> Self {
        EngineParams {
            runtime,
            idle_step: 4.0,
            theta_cap,
            stride: 10,
            max_slots: 200_000_000,
            overhead: OverheadModel::none(),
        }
    }
}

/// Outcome of an engine run: the pre-engine `RunResult` fields plus
/// the overhead ledger (all zero under `OverheadModel::none()`, except
/// `preemptions`/`restarts`, which count full-interruption episodes in
/// any mode).
#[derive(Clone, Debug)]
pub struct EngineResult {
    pub series: Series,
    /// net completed iterations (lost work rolled back)
    pub iters: u64,
    pub cost: f64,
    pub elapsed: f64,
    pub idle_time: f64,
    pub final_error: f64,
    pub final_accuracy: f64,
    /// true if the run hit theta_cap/max_slots before finishing
    pub truncated: bool,
    /// full interruptions (active set fell to zero after running)
    pub preemptions: u64,
    /// recoveries from a full interruption
    pub restarts: u64,
    /// checkpoints written (periodic + emergency)
    pub checkpoints: u64,
    /// wall-clock spent writing checkpoints (billed)
    pub checkpoint_time: f64,
    /// wall-clock spent in post-interruption recovery (billed)
    pub restart_time: f64,
    /// iterations lost to preemptions and recomputed
    pub lost_iters: u64,
}

/// Drives one training run as a sequence of typed events.
pub struct Engine {
    pub params: EngineParams,
}

impl Engine {
    pub fn new(params: EngineParams) -> Self {
        Engine { params }
    }

    /// Run `policy` against `backend` on the virtual clock. `extra`
    /// observers see every event after the policy does; the engine
    /// always installs a [`SeriesRecorder`] whose output lands in
    /// [`EngineResult::series`].
    ///
    /// Event order within one slot (DESIGN.md §5): `PriceRevision`,
    /// then either (`WorkerPreempted` | idle wait) on an empty set, or
    /// (`WorkerRestored`?, `IterationDone`, `CheckpointDone`?) on a
    /// non-empty one; `DeadlineHit` fires at a slot boundary only.
    pub fn run(
        &self,
        policy: &mut dyn Policy,
        backend: &mut dyn TrainingBackend,
        prices: &PriceSource,
        rng: &mut Rng,
        extra: &mut [&mut dyn Observer],
    ) -> Result<EngineResult> {
        let p = &self.params;
        ensure!(p.idle_step > 0.0, "idle_step must be > 0");
        ensure!(p.stride >= 1, "stride must be >= 1");
        p.overhead.validate()?;
        let ov = p.overhead;

        let mut meter = CostMeter::new();
        let mut recorder = SeriesRecorder::new(p.stride);
        let mut iter = 0u64;
        let mut slots = 0u64;
        let target = policy.target_iters();
        let mut truncated = false;
        let mut last = (backend.error(), backend.accuracy());

        // overhead state: the last completed slot's active set / price
        // (needed to bill an emergency checkpoint inside the notice
        // window), the checkpointed state, and the ledger
        let mut was_active = false;
        let mut interrupted = false;
        let mut prev_y = 0usize;
        let mut prev_price = 0.0f64;
        let mut ckpt_iter = 0u64;
        let mut ckpt_state = backend.snapshot();
        let (mut preemptions, mut restarts, mut checkpoints) = (0u64, 0u64, 0u64);
        let (mut checkpoint_time, mut restart_time) = (0.0f64, 0.0f64);
        let mut lost_iters = 0u64;

        // the one dispatch point: policy first, built-in recorder, then
        // the caller's observers
        fn emit(
            policy: &mut dyn Policy,
            recorder: &mut SeriesRecorder,
            extra: &mut [&mut dyn Observer],
            ev: Event,
            st: EngineState,
        ) -> Result<()> {
            policy.on_event(&ev, &st)?;
            recorder.on_event(&ev, &st);
            for o in extra.iter_mut() {
                o.on_event(&ev, &st);
            }
            Ok(())
        }
        macro_rules! state {
            ($active:expr, $price:expr) => {
                EngineState {
                    iter,
                    target,
                    clock: meter.elapsed(),
                    cost: meter.cost(),
                    idle_time: meter.idle_time(),
                    error: last.0,
                    accuracy: last.1,
                    active: $active,
                    price: $price,
                }
            };
        }

        while iter < target {
            slots += 1;
            if slots > p.max_slots || meter.elapsed() >= p.theta_cap {
                truncated = true;
                emit(
                    policy,
                    &mut recorder,
                    extra,
                    Event::DeadlineHit,
                    state!(0, prev_price),
                )?;
                break;
            }
            let price = prices.price_at(meter.elapsed(), rng);
            emit(
                policy,
                &mut recorder,
                extra,
                Event::PriceRevision { price },
                state!(0, price),
            )?;
            let decision = policy.decide(price, rng);
            let y = decision.active.len();
            if y == 0 {
                if was_active {
                    // a full interruption begins
                    preemptions += 1;
                    if ov.lost_work_on_preempt && iter > ckpt_iter {
                        if ov.preempt_notice_s > 0.0
                            && ov.preempt_notice_s >= ov.checkpoint_cost_s
                        {
                            // the notice window covers an emergency
                            // checkpoint: the lapsing workers write it
                            // at the previous slot's price, keeping all
                            // progress
                            meter.charge(
                                prev_y,
                                prev_price,
                                ov.checkpoint_cost_s,
                            );
                            checkpoint_time += ov.checkpoint_cost_s;
                            checkpoints += 1;
                            ckpt_iter = iter;
                            ckpt_state = backend.snapshot();
                            emit(
                                policy,
                                &mut recorder,
                                extra,
                                Event::CheckpointDone,
                                state!(prev_y, prev_price),
                            )?;
                        } else {
                            // work since the last checkpoint is lost
                            // and will be recomputed
                            lost_iters += iter - ckpt_iter;
                            iter = ckpt_iter;
                            if let Some(s) = ckpt_state {
                                backend.restore(s);
                            }
                            last = (backend.error(), backend.accuracy());
                        }
                    }
                    was_active = false;
                    interrupted = true;
                    emit(
                        policy,
                        &mut recorder,
                        extra,
                        Event::WorkerPreempted { notice: ov.preempt_notice_s },
                        state!(0, price),
                    )?;
                }
                meter.idle(p.idle_step);
                continue;
            }
            if interrupted {
                // recovery lag: the restored workers are billed while
                // the job reloads its state, with no progress
                if ov.restart_delay_s > 0.0 {
                    meter.charge(y, decision.price, ov.restart_delay_s);
                    restart_time += ov.restart_delay_s;
                }
                restarts += 1;
                interrupted = false;
                emit(
                    policy,
                    &mut recorder,
                    extra,
                    Event::WorkerRestored,
                    state!(y, decision.price),
                )?;
            }
            let dur = p.runtime.sample(y, rng);
            let stats = backend.step(y, rng)?;
            meter.charge(y, decision.price, dur);
            iter += 1;
            last = (stats.error, stats.accuracy);
            was_active = true;
            prev_y = y;
            prev_price = decision.price;
            emit(
                policy,
                &mut recorder,
                extra,
                Event::IterationDone,
                state!(y, decision.price),
            )?;
            if ov.checkpoint_every_iters > 0
                && iter % ov.checkpoint_every_iters == 0
                && iter < target
            {
                meter.charge(y, decision.price, ov.checkpoint_cost_s);
                checkpoint_time += ov.checkpoint_cost_s;
                checkpoints += 1;
                ckpt_iter = iter;
                ckpt_state = backend.snapshot();
                emit(
                    policy,
                    &mut recorder,
                    extra,
                    Event::CheckpointDone,
                    state!(y, decision.price),
                )?;
            }
        }

        Ok(EngineResult {
            series: recorder.into_series(),
            iters: iter,
            cost: meter.cost(),
            elapsed: meter.elapsed(),
            idle_time: meter.idle_time(),
            final_error: last.0,
            final_accuracy: last.1,
            truncated,
            preemptions,
            restarts,
            checkpoints,
            checkpoint_time,
            restart_time,
            lost_iters,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SyntheticBackend;
    use crate::coordinator::strategy::FixedBids;
    use crate::market::BidVector;
    use crate::theory::bounds::{ErrorBound, SgdHyper};

    fn bound() -> ErrorBound {
        ErrorBound::new(SgdHyper::paper_cnn())
    }

    /// A scripted policy: one worker, active except at the scripted
    /// (1-based) slot numbers — deterministic preemption injection.
    struct Scripted {
        target: u64,
        idle_slots: Vec<u64>,
        slot: u64,
    }

    impl Scripted {
        fn new(target: u64, idle_slots: Vec<u64>) -> Self {
            Scripted { target, idle_slots, slot: 0 }
        }
    }

    impl Policy for Scripted {
        fn name(&self) -> &str {
            "scripted"
        }

        fn target_iters(&self) -> u64 {
            self.target
        }

        fn max_workers(&self) -> usize {
            1
        }

        fn decide(&mut self, _price: f64, _rng: &mut Rng) -> ActiveDecision {
            self.slot += 1;
            let active = if self.idle_slots.contains(&self.slot) {
                vec![]
            } else {
                vec![0]
            };
            ActiveDecision { active, price: 1.0 }
        }
    }

    fn params(overhead: OverheadModel, theta_cap: f64) -> EngineParams {
        EngineParams {
            runtime: RuntimeModel::Deterministic { r: 10.0 },
            idle_step: 4.0,
            theta_cap,
            stride: 1,
            max_slots: 10_000,
            overhead,
        }
    }

    #[test]
    fn preemption_during_run_rolls_back_to_checkpoint() {
        // checkpoint every 4 iters (free), preempt at slot 7 (after 6
        // iterations): iters 5..6 are lost, recomputed after a billed
        // 5 s restart delay
        let ov = OverheadModel {
            checkpoint_every_iters: 4,
            checkpoint_cost_s: 0.0,
            restart_delay_s: 5.0,
            lost_work_on_preempt: true,
            preempt_notice_s: 0.0,
        };
        let mut policy = Scripted::new(10, vec![7]);
        let mut b = SyntheticBackend::new(bound());
        let mut rng = Rng::new(1);
        let mut log = EventLog::new();
        let r = Engine::new(params(ov, f64::INFINITY))
            .run(
                &mut policy,
                &mut b,
                &PriceSource::Fixed(1.0),
                &mut rng,
                &mut [&mut log],
            )
            .unwrap();
        assert_eq!(r.iters, 10);
        assert_eq!(r.preemptions, 1);
        assert_eq!(r.restarts, 1);
        assert_eq!(r.lost_iters, 2); // iters 5 and 6, rolled back to 4
        assert!((r.restart_time - 5.0).abs() < 1e-12);
        // 12 executed iterations at 10 s + one idle slot + restart lag
        assert!((r.elapsed - (12.0 * 10.0 + 4.0 + 5.0)).abs() < 1e-9);
        // billed: 12 iterations + 5 s restart, 1 worker at price 1.0
        assert!((r.cost - (12.0 * 10.0 + 5.0)).abs() < 1e-9);
        // the rollback restores the learning state: the final error is
        // exactly 10 net single-worker iterations
        let mut fresh = SyntheticBackend::new(bound());
        let mut frng = Rng::new(2);
        for _ in 0..10 {
            fresh.step(1, &mut frng).unwrap();
        }
        assert!((r.final_error - fresh.error()).abs() < 1e-12);
        // event ordering: preempted strictly before restored, and the
        // last checkpoint before the preemption was at iter 4
        let kinds = log.kinds();
        let pre = kinds.iter().position(|k| *k == "worker_preempted").unwrap();
        let res = kinds.iter().position(|k| *k == "worker_restored").unwrap();
        assert!(pre < res, "{kinds:?}");
        let ck: Vec<u64> = log
            .events
            .iter()
            .filter(|(e, _)| matches!(e, Event::CheckpointDone))
            .map(|(_, i)| *i)
            .collect();
        assert_eq!(ck, vec![4, 8], "periodic checkpoints at 4 and 8");
        // the preemption event sees the rolled-back counter
        let (_, at) = log.events[log
            .events
            .iter()
            .position(|(e, _)| matches!(e, Event::WorkerPreempted { .. }))
            .unwrap()];
        assert_eq!(at, 4);
    }

    #[test]
    fn notice_window_covers_emergency_checkpoint() {
        // 30 s notice >= 10 s checkpoint cost: no work is lost, the
        // emergency checkpoint is billed at the lapsing slot's terms
        let ov = OverheadModel {
            checkpoint_every_iters: 100, // periodic effectively off
            checkpoint_cost_s: 10.0,
            restart_delay_s: 0.0,
            lost_work_on_preempt: true,
            preempt_notice_s: 30.0,
        };
        let mut policy = Scripted::new(6, vec![4]);
        let mut b = SyntheticBackend::new(bound());
        let mut rng = Rng::new(3);
        let mut log = EventLog::new();
        let r = Engine::new(params(ov, f64::INFINITY))
            .run(
                &mut policy,
                &mut b,
                &PriceSource::Fixed(1.0),
                &mut rng,
                &mut [&mut log],
            )
            .unwrap();
        assert_eq!(r.lost_iters, 0);
        assert_eq!(r.iters, 6);
        assert_eq!(r.checkpoints, 1);
        assert!((r.checkpoint_time - 10.0).abs() < 1e-12);
        // 6 iterations, no recomputation: 6 * 10 + ckpt 10 billed
        assert!((r.cost - (6.0 * 10.0 + 10.0)).abs() < 1e-9);
        let kinds = log.kinds();
        let ck = kinds.iter().position(|k| *k == "checkpoint_done").unwrap();
        let pre = kinds.iter().position(|k| *k == "worker_preempted").unwrap();
        assert!(ck < pre, "emergency checkpoint inside the notice: {kinds:?}");
    }

    #[test]
    fn checkpoint_coinciding_with_deadline() {
        // the 4th iteration's checkpoint pushes the clock to 45 s,
        // over the 42 s cap: the next slot fires DeadlineHit, after
        // CheckpointDone
        let ov = OverheadModel {
            checkpoint_every_iters: 4,
            checkpoint_cost_s: 5.0,
            restart_delay_s: 0.0,
            lost_work_on_preempt: false,
            preempt_notice_s: 0.0,
        };
        let mut policy = Scripted::new(100, vec![]);
        let mut b = SyntheticBackend::new(bound());
        let mut rng = Rng::new(4);
        let mut log = EventLog::new();
        let r = Engine::new(params(ov, 42.0))
            .run(
                &mut policy,
                &mut b,
                &PriceSource::Fixed(1.0),
                &mut rng,
                &mut [&mut log],
            )
            .unwrap();
        assert!(r.truncated);
        assert_eq!(r.iters, 4);
        assert_eq!(r.checkpoints, 1);
        let kinds = log.kinds();
        assert_eq!(kinds.last().unwrap(), &"deadline_hit");
        let ck = kinds.iter().position(|k| *k == "checkpoint_done").unwrap();
        assert!(ck < kinds.len() - 1, "checkpoint precedes the deadline");
    }

    #[test]
    fn lockstep_mode_emits_events_but_changes_nothing() {
        // overhead off: events fire, accounting equals the plain loop
        let mut s = FixedBids::new("noint", BidVector::uniform(2, 1.0), 50);
        let mut policy = LockstepPolicy(&mut s as &mut dyn Strategy);
        let mut b = SyntheticBackend::new(bound());
        let mut rng = Rng::new(5);
        let mut log = EventLog::new();
        let r = Engine::new(params(OverheadModel::none(), f64::INFINITY))
            .run(
                &mut policy,
                &mut b,
                &PriceSource::Fixed(0.5),
                &mut rng,
                &mut [&mut log],
            )
            .unwrap();
        assert_eq!(r.iters, 50);
        assert_eq!(r.lost_iters, 0);
        assert_eq!(r.checkpoint_time, 0.0);
        assert_eq!(r.restart_time, 0.0);
        assert!((r.cost - 2.0 * 0.5 * 10.0 * 50.0).abs() < 1e-9);
        assert_eq!(
            log.kinds().iter().filter(|k| **k == "iteration_done").count(),
            50
        );
        assert_eq!(r.series.len(), 50); // stride 1
    }

    #[test]
    fn series_recorder_matches_stride_contract() {
        let mut rec = SeriesRecorder::new(5);
        let mk = |iter| EngineState {
            iter,
            target: 12,
            clock: iter as f64,
            cost: iter as f64,
            idle_time: 0.0,
            error: 1.0,
            accuracy: 0.5,
            active: 2,
            price: 0.3,
        };
        for i in 1..=12 {
            rec.on_event(&Event::IterationDone, &mk(i));
        }
        let s = rec.into_series();
        let iters: Vec<u64> = s.points.iter().map(|p| p.iter).collect();
        assert_eq!(iters, vec![5, 10, 12]); // strides + final
    }
}
