//! Cost metering: the $ integral of (active workers x price) over time.
//!
//! Spot semantics (Sec. IV): while a worker is active it pays the
//! prevailing *spot price* per unit time; inactive workers pay nothing
//! (persistent requests queue for free). Preemptible semantics (Sec. V):
//! active workers pay the platform's fixed price. Both reduce to
//! `charge(y, price, duration)`.

/// Accumulates cost and time, with conservation checks.
#[derive(Clone, Copy, Debug, Default)]
pub struct CostMeter {
    total_cost: f64,
    busy_time: f64,
    idle_time: f64,
    worker_time: f64,
}

impl CostMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge `y` active workers at `price` for `duration`.
    pub fn charge(&mut self, y: usize, price: f64, duration: f64) {
        debug_assert!(price >= 0.0 && duration >= 0.0);
        self.total_cost += y as f64 * price * duration;
        self.busy_time += duration;
        self.worker_time += y as f64 * duration;
    }

    /// Record an idle (zero-active) wait.
    pub fn idle(&mut self, duration: f64) {
        debug_assert!(duration >= 0.0);
        self.idle_time += duration;
    }

    pub fn cost(&self) -> f64 {
        self.total_cost
    }

    /// Wall-clock = busy + idle.
    pub fn elapsed(&self) -> f64 {
        self.busy_time + self.idle_time
    }

    pub fn busy_time(&self) -> f64 {
        self.busy_time
    }

    pub fn idle_time(&self) -> f64 {
        self.idle_time
    }

    /// Total worker-seconds paid for.
    pub fn worker_time(&self) -> f64 {
        self.worker_time
    }

    /// Mean price actually paid per worker-second.
    pub fn mean_price(&self) -> f64 {
        if self.worker_time == 0.0 {
            0.0
        } else {
            self.total_cost / self.worker_time
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{for_all, Gen};

    #[test]
    fn basic_accounting() {
        let mut m = CostMeter::new();
        m.charge(4, 0.5, 10.0);
        m.idle(5.0);
        m.charge(2, 0.25, 4.0);
        assert!((m.cost() - (4.0 * 0.5 * 10.0 + 2.0 * 0.25 * 4.0)).abs() < 1e-12);
        assert_eq!(m.elapsed(), 19.0);
        assert_eq!(m.idle_time(), 5.0);
        assert_eq!(m.worker_time(), 48.0);
        assert!((m.mean_price() - m.cost() / 48.0).abs() < 1e-12);
    }

    #[test]
    fn zero_everything() {
        let m = CostMeter::new();
        assert_eq!(m.cost(), 0.0);
        assert_eq!(m.elapsed(), 0.0);
        assert_eq!(m.mean_price(), 0.0);
    }

    #[test]
    fn prop_cost_nonnegative_and_additive() {
        for_all("cost meter additivity", |g: &mut Gen| {
            let mut m = CostMeter::new();
            let mut manual = 0.0;
            let mut time = 0.0;
            for _ in 0..g.u64_in(1, 20) {
                let y = g.u64_in(0, 10) as usize;
                let p = g.f64_in(0.0, 2.0);
                let dur = g.f64_in(0.0, 100.0);
                if g.bool() {
                    m.charge(y, p, dur);
                    manual += y as f64 * p * dur;
                    time += dur;
                } else {
                    m.idle(dur);
                    time += dur;
                }
            }
            if m.cost() < -1e-12 {
                return Err("negative cost".into());
            }
            if (m.cost() - manual).abs() > 1e-9 * (1.0 + manual) {
                return Err(format!("cost {} != {}", m.cost(), manual));
            }
            if (m.elapsed() - time).abs() > 1e-9 * (1.0 + time) {
                return Err("time not conserved".into());
            }
            Ok(())
        });
    }
}
