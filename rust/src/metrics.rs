//! Run metrics: time series of (clock, iter, cost, error, accuracy, y)
//! plus summary extraction used by the figure harnesses, and the sweep
//! harness's throughput meter.

use std::fmt;

use crate::util::csv::Table;
use crate::util::stats::interp;

/// Throughput of a parallel sweep: jobs completed over wall-clock time.
#[derive(Clone, Copy, Debug)]
pub struct Throughput {
    pub jobs: u64,
    pub elapsed_s: f64,
    pub threads: usize,
}

impl Throughput {
    pub fn jobs_per_sec(&self) -> f64 {
        if self.elapsed_s > 1e-12 {
            self.jobs as f64 / self.elapsed_s
        } else {
            0.0
        }
    }
}

impl fmt::Display for Throughput {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} jobs in {:.2}s on {} thread{} ({:.1} jobs/s)",
            self.jobs,
            self.elapsed_s,
            self.threads,
            if self.threads == 1 { "" } else { "s" },
            self.jobs_per_sec()
        )
    }
}

/// One recorded point along a training run.
#[derive(Clone, Copy, Debug)]
pub struct Point {
    pub clock: f64,
    pub iter: u64,
    pub cost: f64,
    pub error: f64,
    pub accuracy: f64,
    pub active: usize,
}

/// A training-run trajectory (sampled every `stride` iterations).
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub points: Vec<Point>,
}

impl Series {
    pub fn push(&mut self, p: Point) {
        self.points.push(p);
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn last(&self) -> Option<&Point> {
        self.points.last()
    }

    /// Cost at which the run first reaches `target_acc` (linear
    /// interpolation along the trajectory); None if never reached.
    pub fn cost_at_accuracy(&self, target_acc: f64) -> Option<f64> {
        let hit = self
            .points
            .iter()
            .position(|p| p.accuracy >= target_acc)?;
        if hit == 0 {
            return Some(self.points[0].cost);
        }
        let (a, b) = (&self.points[hit - 1], &self.points[hit]);
        Some(interp(
            &[a.accuracy, b.accuracy],
            &[a.cost, b.cost],
            target_acc,
        ))
    }

    /// Clock time at which the run first reaches `target_acc`.
    pub fn time_at_accuracy(&self, target_acc: f64) -> Option<f64> {
        let hit = self
            .points
            .iter()
            .position(|p| p.accuracy >= target_acc)?;
        if hit == 0 {
            return Some(self.points[0].clock);
        }
        let (a, b) = (&self.points[hit - 1], &self.points[hit]);
        Some(interp(
            &[a.accuracy, b.accuracy],
            &[a.clock, b.clock],
            target_acc,
        ))
    }

    /// Cost at which error first drops to `target_err`.
    pub fn cost_at_error(&self, target_err: f64) -> Option<f64> {
        let hit = self.points.iter().position(|p| p.error <= target_err)?;
        Some(self.points[hit].cost)
    }

    /// Export as a CSV table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(&[
            "clock", "iter", "cost", "error", "accuracy", "active",
        ]);
        for p in &self.points {
            t.push(vec![
                p.clock,
                p.iter as f64,
                p.cost,
                p.error,
                p.accuracy,
                p.active as f64,
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn series() -> Series {
        let mut s = Series::default();
        for i in 0..10u64 {
            s.push(Point {
                clock: i as f64 * 10.0,
                iter: i,
                cost: i as f64 * 2.0,
                error: 1.0 / (i + 1) as f64,
                accuracy: i as f64 / 10.0,
                active: 4,
            });
        }
        s
    }

    #[test]
    fn cost_at_accuracy_interpolates() {
        let s = series();
        // accuracy 0.45 is halfway between points 4 (0.4) and 5 (0.5):
        // cost halfway between 8 and 10 = 9
        assert!((s.cost_at_accuracy(0.45).unwrap() - 9.0).abs() < 1e-9);
        assert_eq!(s.cost_at_accuracy(0.0).unwrap(), 0.0);
        assert!(s.cost_at_accuracy(0.95).is_none());
    }

    #[test]
    fn time_and_error_lookups() {
        let s = series();
        assert!((s.time_at_accuracy(0.45).unwrap() - 45.0).abs() < 1e-9);
        assert_eq!(s.cost_at_error(0.2).unwrap(), 8.0); // 1/(4+1)=0.2
        assert!(s.cost_at_error(0.01).is_none());
    }

    #[test]
    fn table_roundtrip() {
        let s = series();
        let t = s.table();
        assert_eq!(t.rows.len(), 10);
        assert_eq!(t.column("cost").unwrap()[3], 6.0);
    }

    #[test]
    fn throughput_rate_and_display() {
        let t = Throughput { jobs: 120, elapsed_s: 3.0, threads: 8 };
        assert!((t.jobs_per_sec() - 40.0).abs() < 1e-12);
        assert!(format!("{t}").contains("jobs/s"));
        let z = Throughput { jobs: 0, elapsed_s: 0.0, threads: 1 };
        assert_eq!(z.jobs_per_sec(), 0.0);
    }
}
