//! Preemption models for non-biddable volatile instances (Sec. V):
//! GCP preemptible VMs / Azure low-priority VMs, where the user controls
//! only the *provisioned* count n and the platform preempts at will.
//!
//! Implements the two Lemma-3 distributions exactly:
//! * Bernoulli(q): each provisioned worker is independently inactive with
//!   probability q each iteration, so the active count z ~ Binomial(n, 1-q)
//!   and the paper's y is z conditioned on z > 0;
//! * Uniform: y uniform on {1..n}.
//!
//! Provides exact `E[1/y]` evaluators (log-space binomial pmf; validated
//! against the Chao–Strawderman closed form for `E[1/(z+1)]` and against
//! Monte-Carlo in the tests) plus the Jensen penalty of Remark 1.
//!
//! # Example
//!
//! The exact statistics behind Theorem 4 / Lemma 3, and the memoised
//! table sweeps and budget policies consult:
//!
//! ```
//! use volatile_sgd::preempt::{PreemptionModel, RecipTable};
//!
//! let m = PreemptionModel::Bernoulli { q: 0.5 };
//! assert_eq!(m.p_zero(4), 0.0625);        // all four preempted
//! assert_eq!(m.mean_active(4), 2.0);      // unconditional E[y]
//! let table = RecipTable::build(&m, 8);   // E[1/y | y > 0], n = 1..=8
//! assert_eq!(
//!     table.recip(4).to_bits(),
//!     m.expected_recip(4).to_bits(),
//! );
//! // more provisioned workers -> better conditional averaging
//! assert!(table.recip(8) < table.recip(2));
//! ```

use crate::util::rng::Rng;
use crate::util::{harmonic, ln_binomial};

/// How the active worker count y_j is drawn each iteration.
#[derive(Clone, Debug)]
pub enum PreemptionModel {
    /// No preemption: y_j = n always (on-demand baseline).
    None,
    /// Each worker independently inactive w.p. q each iteration
    /// (Remark 2 / Lemma 3 second case). y_j | y_j > 0.
    Bernoulli { q: f64 },
    /// y_j uniform on {1..n} (Lemma 3 first case).
    Uniform,
}

impl PreemptionModel {
    /// Draw the active-worker *subset* out of n provisioned workers.
    /// May be empty (the scheduler accounts that time as idle).
    pub fn draw_active(&self, n: usize, rng: &mut Rng) -> Vec<usize> {
        assert!(n > 0);
        match self {
            PreemptionModel::None => (0..n).collect(),
            PreemptionModel::Bernoulli { q } => (0..n)
                .filter(|_| !rng.bool(*q))
                .collect(),
            PreemptionModel::Uniform => {
                let y = 1 + rng.below(n as u64) as usize;
                rng.sample_indices(n, y)
            }
        }
    }

    /// [`PreemptionModel::draw_active`] into a caller-owned buffer
    /// (cleared first) — the allocation-free form the batched replicate
    /// executor uses on its per-slot hot path. Consumes the RNG in
    /// *exactly* the same order as `draw_active` (Bernoulli: one bool
    /// per provisioned worker; Uniform: one `below` draw then the same
    /// Fisher–Yates shuffle `sample_indices` performs), so digests are
    /// unchanged.
    pub fn draw_active_into(
        &self,
        n: usize,
        rng: &mut Rng,
        out: &mut Vec<usize>,
    ) {
        assert!(n > 0);
        out.clear();
        match self {
            PreemptionModel::None => out.extend(0..n),
            PreemptionModel::Bernoulli { q } => {
                out.extend((0..n).filter(|_| !rng.bool(*q)));
            }
            PreemptionModel::Uniform => {
                let y = 1 + rng.below(n as u64) as usize;
                out.extend(0..n);
                rng.shuffle(out);
                out.truncate(y);
            }
        }
    }

    /// Exact E[1/y_j | y_j > 0] for n provisioned workers.
    pub fn expected_recip(&self, n: usize) -> f64 {
        match self {
            PreemptionModel::None => 1.0 / n as f64,
            PreemptionModel::Bernoulli { q } => {
                binomial_expected_recip(n, *q)
            }
            PreemptionModel::Uniform => uniform_expected_recip(n),
        }
    }

    /// P[y_j = 0] (the dead-time probability per iteration slot).
    pub fn p_zero(&self, n: usize) -> f64 {
        match self {
            PreemptionModel::None => 0.0,
            PreemptionModel::Bernoulli { q } => q.powi(n as i32),
            PreemptionModel::Uniform => 0.0,
        }
    }

    /// Unconditional mean active count E[y_j] (zero slots included) —
    /// the per-unit-time billing rate a fleet of `n` actually incurs,
    /// which is what budget-constrained policies size against
    /// (`ElasticFleet` in `sim::policy`).
    pub fn mean_active(&self, n: usize) -> f64 {
        match self {
            PreemptionModel::None => n as f64,
            PreemptionModel::Bernoulli { q } => n as f64 * (1.0 - q),
            // y is uniform on {1..n}: never zero
            PreemptionModel::Uniform => (n as f64 + 1.0) / 2.0,
        }
    }

    /// E[y_j | y_j > 0].
    pub fn expected_active(&self, n: usize) -> f64 {
        match self {
            PreemptionModel::None => n as f64,
            PreemptionModel::Bernoulli { q } => {
                let p0 = q.powi(n as i32);
                n as f64 * (1.0 - q) / (1.0 - p0)
            }
            PreemptionModel::Uniform => (n as f64 + 1.0) / 2.0,
        }
    }
}

/// Exact E[1/y] for y ~ Binomial(n, 1-q) conditioned on y > 0, evaluated
/// with log-space pmf terms for stability up to very large n.
pub fn binomial_expected_recip(n: usize, q: f64) -> f64 {
    assert!(n > 0);
    assert!((0.0..1.0).contains(&q), "q must be in [0,1), got {q}");
    if q == 0.0 {
        return 1.0 / n as f64;
    }
    let a = 1.0 - q; // per-worker active probability
    let (ln_a, ln_q) = (a.ln(), q.ln());
    let mut sum = 0.0;
    for k in 1..=n {
        let ln_pmf = ln_binomial(n as u64, k as u64)
            + k as f64 * ln_a
            + (n - k) as f64 * ln_q;
        sum += ln_pmf.exp() / k as f64;
    }
    let p0 = (n as f64 * ln_q).exp();
    sum / (1.0 - p0)
}

/// E[1/(z+1)] for z ~ Binomial(n, 1-q) *unconditioned* — the
/// Chao–Strawderman (1972) closed form used in the Lemma 3 proof:
/// (1 - q^{n+1}) / ((n+1)(1-q)).
pub fn chao_strawderman_recip_plus_one(n: usize, q: f64) -> f64 {
    assert!((0.0..1.0).contains(&q));
    (1.0 - q.powi(n as i32 + 1)) / ((n as f64 + 1.0) * (1.0 - q))
}

/// E[1/y] for y uniform on {1..n}: H_n / n.
pub fn uniform_expected_recip(n: usize) -> f64 {
    assert!(n > 0);
    harmonic(n as u64) / n as f64
}

/// Remark 1's Jensen penalty: E[1/y] - 1/E[y] >= 0, zero iff y is
/// deterministic. Quantifies the convergence cost of volatility.
pub fn jensen_penalty(model: &PreemptionModel, n: usize) -> f64 {
    model.expected_recip(n) - 1.0 / model.expected_active(n)
}

/// Precomputed E[1/y] for n = 1..=n_max under one preemption model.
///
/// Bernoulli E[1/y] is an O(n) sum per evaluation; a sweep that consults
/// it per replicate (or a solver scanning fleet sizes) pays O(n^2) per
/// grid point without memoisation. The sweep harness builds one table in
/// each grid point's prepare phase and shares it across all replicates.
#[derive(Clone, Debug)]
pub struct RecipTable {
    model: PreemptionModel,
    recip: Vec<f64>,
}

impl RecipTable {
    pub fn build(model: &PreemptionModel, n_max: usize) -> Self {
        assert!(n_max > 0);
        RecipTable {
            model: model.clone(),
            recip: (1..=n_max).map(|n| model.expected_recip(n)).collect(),
        }
    }

    pub fn n_max(&self) -> usize {
        self.recip.len()
    }

    pub fn model(&self) -> &PreemptionModel {
        &self.model
    }

    /// Cached E[1/y | y > 0] for a fleet of `n` (1 <= n <= n_max).
    pub fn recip(&self, n: usize) -> f64 {
        assert!(
            n >= 1 && n <= self.recip.len(),
            "n={n} outside table 1..={}",
            self.recip.len()
        );
        self.recip[n - 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{close, for_all, Gen};

    #[test]
    fn no_preemption_is_deterministic() {
        let m = PreemptionModel::None;
        assert_eq!(m.expected_recip(8), 1.0 / 8.0);
        assert_eq!(m.p_zero(8), 0.0);
        assert_eq!(jensen_penalty(&m, 8), 0.0);
        let mut rng = Rng::new(1);
        assert_eq!(m.draw_active(5, &mut rng).len(), 5);
    }

    /// `draw_active_into` must be `draw_active` with a caller buffer:
    /// same set AND the same number of RNG draws (the batched executor
    /// relies on bit-identical stream consumption), with stale buffer
    /// contents cleared.
    #[test]
    fn draw_active_into_matches_draw_active_and_rng_stream() {
        for_all("draw_active_into == draw_active", |g: &mut Gen| {
            let n = g.u64_in(1, 12) as usize;
            let m = match g.u64_in(0, 2) {
                0 => PreemptionModel::None,
                1 => PreemptionModel::Bernoulli { q: g.f64_in(0.0, 0.9) },
                _ => PreemptionModel::Uniform,
            };
            let seed = g.u64_in(0, u64::MAX - 1);
            let mut a = Rng::new(seed);
            let mut b = Rng::new(seed);
            let want = m.draw_active(n, &mut a);
            let mut got = vec![usize::MAX; 3]; // stale junk must vanish
            m.draw_active_into(n, &mut b, &mut got);
            if got != want {
                return Err(format!("{m:?}: {got:?} != {want:?}"));
            }
            if a.next_u64() != b.next_u64() {
                return Err(format!("{m:?}: RNG streams diverged"));
            }
            Ok(())
        });
    }

    #[test]
    fn bernoulli_recip_matches_monte_carlo() {
        let n = 8;
        let q = 0.5;
        let exact = binomial_expected_recip(n, q);
        let mut rng = Rng::new(3);
        let m = PreemptionModel::Bernoulli { q };
        let mut sum = 0.0;
        let mut cnt = 0u64;
        for _ in 0..300_000 {
            let y = m.draw_active(n, &mut rng).len();
            if y > 0 {
                sum += 1.0 / y as f64;
                cnt += 1;
            }
        }
        let mc = sum / cnt as f64;
        assert!((mc - exact).abs() < 2e-3, "mc={mc} exact={exact}");
    }

    #[test]
    fn bernoulli_recip_validates_against_chao_strawderman() {
        // E[1/(z+1)] closed form, z ~ Bin(n, 1-q): compare with direct sum
        for &(n, q) in &[(5usize, 0.3f64), (20, 0.5), (100, 0.8)] {
            let a = 1.0 - q;
            let mut direct = 0.0;
            for k in 0..=n {
                let ln_pmf = ln_binomial(n as u64, k as u64)
                    + k as f64 * a.ln()
                    + (n - k) as f64 * q.ln();
                direct += ln_pmf.exp() / (k as f64 + 1.0);
            }
            let cf = chao_strawderman_recip_plus_one(n, q);
            assert!((direct - cf).abs() < 1e-10, "n={n} q={q}");
        }
    }

    #[test]
    fn uniform_recip_is_harmonic_over_n() {
        assert!((uniform_expected_recip(1) - 1.0).abs() < 1e-12);
        assert!(
            (uniform_expected_recip(4) - (1.0 + 0.5 + 1.0 / 3.0 + 0.25) / 4.0)
                .abs()
                < 1e-12
        );
    }

    #[test]
    fn lemma3_uniform_bound() {
        // E[1/y] <= (ln n + 1)/n <= O(n^{-1/2}) — check the explicit bound
        for n in 1..200usize {
            let e = uniform_expected_recip(n);
            assert!(e <= ((n as f64).ln() + 1.0) / n as f64 + 1e-12);
            assert!(e <= 2.0 / (n as f64).sqrt());
        }
    }

    #[test]
    fn remark2_recip_increases_with_q() {
        let n = 10;
        let mut prev = 0.0;
        for i in 0..9 {
            let q = 0.1 * i as f64;
            let e = binomial_expected_recip(n, q);
            assert!(e >= prev - 1e-12, "q={q}: {e} < {prev}");
            prev = e;
        }
    }

    #[test]
    fn prop_jensen_penalty_nonnegative() {
        for_all("Jensen penalty >= 0 (Remark 1)", |g: &mut Gen| {
            let n = g.u64_in(1, 64) as usize;
            let q = g.f64_in(0.0, 0.95);
            for m in [
                PreemptionModel::None,
                PreemptionModel::Bernoulli { q },
                PreemptionModel::Uniform,
            ] {
                let pen = jensen_penalty(&m, n);
                if pen < -1e-10 {
                    return Err(format!("penalty {pen} < 0 for {m:?} n={n}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_expected_recip_decreases_with_n() {
        for_all("E[1/y] decreasing in n", |g: &mut Gen| {
            let n = g.u64_in(1, 100) as usize;
            let q = g.f64_in(0.0, 0.9);
            let m = PreemptionModel::Bernoulli { q };
            let a = m.expected_recip(n);
            let b = m.expected_recip(n + 1);
            if b <= a + 1e-12 {
                Ok(())
            } else {
                Err(format!("E[1/y] rose from {a} to {b} at n={n}, q={q}"))
            }
        });
    }

    #[test]
    fn prop_bernoulli_p_zero_and_mean() {
        for_all("binomial identities", |g: &mut Gen| {
            let n = g.u64_in(1, 40) as usize;
            let q = g.f64_in(0.05, 0.95);
            let m = PreemptionModel::Bernoulli { q };
            close(m.p_zero(n), q.powi(n as i32), 1e-12, "p_zero")?;
            // unconditional mean: E[y * 1{y>0}] = n(1-q) = mean_active
            let uncond = m.expected_active(n) * (1.0 - m.p_zero(n));
            close(uncond, n as f64 * (1.0 - q), 1e-9, "unconditional mean")?;
            close(m.mean_active(n), n as f64 * (1.0 - q), 1e-12, "mean_active")
        });
    }

    /// MC estimate of (E[1/y], E[y], P[y=0]) for y ~ Bin(n, 1-q) | y>0.
    fn monte_carlo_bernoulli(
        n: usize,
        q: f64,
        samples: u64,
        rng: &mut Rng,
    ) -> (f64, f64, f64) {
        let m = PreemptionModel::Bernoulli { q };
        let (mut recip, mut active, mut zeros) = (0.0, 0.0, 0u64);
        let mut nonzero = 0u64;
        for _ in 0..samples {
            let y = m.draw_active(n, rng).len();
            if y == 0 {
                zeros += 1;
            } else {
                nonzero += 1;
                recip += 1.0 / y as f64;
                active += y as f64;
            }
        }
        (
            recip / nonzero.max(1) as f64,
            active / nonzero.max(1) as f64,
            zeros as f64 / samples as f64,
        )
    }

    #[test]
    fn exact_stats_match_monte_carlo_across_models() {
        // exact E[1/y], E[y|y>0] and P[y=0] vs simulation, spanning the
        // issue's n/q ranges at MC-affordable sample counts
        let mut rng = Rng::new(0xF16);
        for &n in &[1usize, 2, 3, 4, 8, 16, 32, 64] {
            for &q in &[0.0, 0.2, 0.5, 0.8] {
                let m = PreemptionModel::Bernoulli { q };
                let samples = 40_000u64;
                let (mc_recip, mc_active, mc_p0) =
                    monte_carlo_bernoulli(n, q, samples, &mut rng);
                let tol = 4.0 / (samples as f64).sqrt();
                assert!(
                    (mc_recip - m.expected_recip(n)).abs() < tol,
                    "E[1/y] n={n} q={q}: mc={mc_recip} exact={}",
                    m.expected_recip(n)
                );
                assert!(
                    (mc_active - m.expected_active(n)).abs()
                        < tol * n as f64,
                    "E[y] n={n} q={q}: mc={mc_active} exact={}",
                    m.expected_active(n)
                );
                assert!(
                    (mc_p0 - m.p_zero(n)).abs() < tol,
                    "P[0] n={n} q={q}: mc={mc_p0} exact={}",
                    m.p_zero(n)
                );
            }
        }
    }

    #[test]
    fn chao_strawderman_cross_check_full_grid() {
        // the closed form E[1/(z+1)] vs the direct log-space pmf sum,
        // exactly, across the whole n in 1..=64, q in {0,0.1,..,0.9} grid
        for n in 1..=64usize {
            for qi in 0..10 {
                let q = 0.1 * qi as f64;
                let cf = chao_strawderman_recip_plus_one(n, q);
                let direct = if q == 0.0 {
                    // z = n deterministically
                    1.0 / (n as f64 + 1.0)
                } else {
                    let a = 1.0 - q;
                    (0..=n)
                        .map(|k| {
                            let ln_pmf = ln_binomial(n as u64, k as u64)
                                + k as f64 * a.ln()
                                + (n - k) as f64 * q.ln();
                            ln_pmf.exp() / (k as f64 + 1.0)
                        })
                        .sum()
                };
                assert!(
                    (direct - cf).abs() < 1e-9,
                    "n={n} q={q}: direct={direct} closed={cf}"
                );
            }
        }
    }

    #[test]
    fn exact_recip_consistent_with_chao_strawderman_bound() {
        // E[1/y | y>0] >= E[1/(y+1)] always (pointwise 1/y > 1/(y+1) and
        // conditioning on y>0 only raises the weight of small y), pinning
        // expected_recip against the independent closed form across the
        // full grid
        for n in 1..=64usize {
            for qi in 0..10 {
                let q = 0.1 * qi as f64;
                let recip = binomial_expected_recip(n, q);
                let cs = chao_strawderman_recip_plus_one(n, q);
                assert!(
                    recip >= cs - 1e-12,
                    "n={n} q={q}: E[1/y]={recip} < E[1/(z+1)]={cs}"
                );
            }
        }
    }

    #[test]
    fn recip_table_matches_direct_evaluation() {
        for model in [
            PreemptionModel::None,
            PreemptionModel::Uniform,
            PreemptionModel::Bernoulli { q: 0.45 },
        ] {
            let table = RecipTable::build(&model, 64);
            assert_eq!(table.n_max(), 64);
            for n in 1..=64 {
                assert_eq!(
                    table.recip(n).to_bits(),
                    model.expected_recip(n).to_bits(),
                    "{model:?} n={n}"
                );
            }
        }
    }

    #[test]
    #[should_panic]
    fn recip_table_rejects_out_of_range() {
        let t = RecipTable::build(&PreemptionModel::Uniform, 8);
        let _ = t.recip(9);
    }

    #[test]
    fn draw_active_uniform_has_uniform_count() {
        let m = PreemptionModel::Uniform;
        let mut rng = Rng::new(17);
        let n = 6;
        let mut counts = vec![0u32; n + 1];
        for _ in 0..60_000 {
            counts[m.draw_active(n, &mut rng).len()] += 1;
        }
        assert_eq!(counts[0], 0);
        for k in 1..=n {
            let f = counts[k] as f64 / 60_000.0;
            assert!((f - 1.0 / n as f64).abs() < 0.01, "k={k} f={f}");
        }
    }
}
