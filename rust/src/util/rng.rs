//! Deterministic pseudo-random numbers: xoshiro256++ seeded via SplitMix64.
//!
//! Every stochastic component of the simulator (spot prices, preemption
//! draws, straggler runtimes, dataset synthesis) takes an explicit `Rng`
//! so whole experiments are reproducible from a single `u64` seed; streams
//! are split with `Rng::split` (independent SplitMix64-derived states).

/// xoshiro256++ generator (Blackman & Vigna). Passes BigCrush; tiny state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the full 256-bit state from one u64 via SplitMix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (label keeps streams distinct even for
    /// equal parent states consumed at different points).
    pub fn split(&mut self, label: u64) -> Rng {
        Rng::new(self.next_u64() ^ label.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Counter-based stream splitting: a pure function of the
    /// `(seed, stream)` pair — no parent generator state, no dependence on
    /// call order or thread interleaving. This is the seeding contract the
    /// sweep harness relies on for "identical results at any thread
    /// count": job k always draws from `Rng::stream(seed, k)` no matter
    /// which worker runs it, or when.
    ///
    /// Construction: hash the pair down to one u64 with two SplitMix64
    /// absorption rounds, then expand to the full 256-bit xoshiro state
    /// via [`Rng::new`]. For a fixed seed the map `stream -> state` is
    /// injective (the second absorption is a bijection of `stream`), so
    /// replicates of one sweep can never collide; across distinct seeds
    /// collisions are birthday-bounded at ~2^32 pairs. See DESIGN.md §3.
    pub fn stream(seed: u64, stream: u64) -> Rng {
        let mut sm = seed;
        let a = splitmix64(&mut sm);
        let mut sm2 = stream ^ a.rotate_left(32);
        let b = splitmix64(&mut sm2);
        Rng::new(a ^ b)
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Bernoulli(p).
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (caches the second variate).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // u1 in (0,1] so ln is finite
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal(mean, std).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Binomial(n, p) by inversion for small n, normal approx for large.
    pub fn binomial(&mut self, n: u64, p: f64) -> u64 {
        if n == 0 || p <= 0.0 {
            return 0;
        }
        if p >= 1.0 {
            return n;
        }
        if n <= 1024 {
            (0..n).filter(|_| self.bool(p)).count() as u64
        } else {
            let mean = n as f64 * p;
            let std = (n as f64 * p * (1.0 - p)).sqrt();
            let x = self.normal(mean, std).round();
            x.clamp(0.0, n as f64) as u64
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from 0..n (k <= n), order randomised.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let mut a = Rng::new(7);
        let mut s1 = a.split(1);
        let mut s2 = a.split(2);
        let overlap = (0..64)
            .filter(|_| s1.next_u64() == s2.next_u64())
            .count();
        assert_eq!(overlap, 0);
    }

    #[test]
    fn stream_is_pure_and_deterministic() {
        let mut a = Rng::stream(42, 7);
        let mut b = Rng::stream(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ_by_id_and_seed() {
        let mut draws = std::collections::HashSet::new();
        // first draw of 64 streams under two seeds: all distinct
        for seed in [1u64, 2] {
            for k in 0..64u64 {
                assert!(draws.insert(Rng::stream(seed, k).next_u64()));
            }
        }
        // and distinct from the plain seeded generator
        assert!(draws.insert(Rng::new(1).next_u64()));
    }

    #[test]
    fn adjacent_streams_do_not_correlate() {
        let mut s1 = Rng::stream(9, 1000);
        let mut s2 = Rng::stream(9, 1001);
        let overlap = (0..256)
            .filter(|_| s1.next_u64() == s2.next_u64())
            .count();
        assert_eq!(overlap, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = r.uniform(0.2, 1.0);
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 0.6).abs() < 0.005, "mean={mean}");
        assert!((var - 0.64 / 12.0).abs() < 0.002, "var={var}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut m1, mut m2, mut m3) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let z = r.gaussian();
            m1 += z;
            m2 += z * z;
            m3 += z * z * z;
        }
        assert!((m1 / n as f64).abs() < 0.01);
        assert!((m2 / n as f64 - 1.0).abs() < 0.02);
        assert!((m3 / n as f64).abs() < 0.03);
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = Rng::new(9);
        let lambda = 2.5;
        let n = 100_000;
        let mean: f64 =
            (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut r = Rng::new(13);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn binomial_mean() {
        let mut r = Rng::new(17);
        let mean: f64 = (0..20_000)
            .map(|_| r.binomial(10, 0.3) as f64)
            .sum::<f64>()
            / 20_000.0;
        assert!((mean - 3.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(29);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }
}
