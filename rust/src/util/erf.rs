//! Error function / normal CDF (needed for the truncated-Gaussian spot
//! price model; libm's erf is not exposed by std).
//!
//! `erf` uses the Abramowitz–Stegun 7.1.26 rational approximation
//! (|error| <= 1.5e-7, plenty for price CDFs); `norm_ppf` is
//! Acklam's inverse-normal rational approximation refined by one Halley
//! step to ~1e-9.

/// erf(x) with absolute error <= 1.5e-7.
pub fn erf(x: f64) -> f64 {
    if x == 0.0 {
        return 0.0; // exact (the A–S polynomial leaves ~1e-9 residue)
    }
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    const A1: f64 = 0.254829592;
    const A2: f64 = -0.284496736;
    const A3: f64 = 1.421413741;
    const A4: f64 = -1.453152027;
    const A5: f64 = 1.061405429;
    const P: f64 = 0.3275911;
    let t = 1.0 / (1.0 + P * x);
    let y = 1.0
        - (((((A5 * t + A4) * t) + A3) * t + A2) * t + A1)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal CDF.
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Standard normal PDF.
pub fn norm_pdf(x: f64) -> f64 {
    (-0.5 * x * x).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Inverse standard normal CDF (Acklam + one Halley refinement).
pub fn norm_ppf(p: f64) -> f64 {
    assert!(
        p > 0.0 && p < 1.0,
        "norm_ppf domain is (0,1), got {p}"
    );
    // Acklam's coefficients
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;

    let x = if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5])
            * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r
                + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q
            + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };
    // one Halley step against the accurate-enough cdf
    let e = norm_cdf(x) - p;
    let u = e * (2.0 * std::f64::consts::PI).sqrt() * (x * x / 2.0).exp();
    x - u / (1.0 + x * u / 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        assert!((erf(0.0)).abs() < 1e-12);
        assert!((erf(1.0) - 0.8427007929).abs() < 2e-7);
        assert!((erf(2.0) - 0.9953222650).abs() < 2e-7);
        assert!((erf(-1.0) + 0.8427007929).abs() < 2e-7);
    }

    #[test]
    fn norm_cdf_symmetry() {
        for x in [-2.5, -1.0, -0.3, 0.0, 0.7, 1.9] {
            assert!((norm_cdf(x) + norm_cdf(-x) - 1.0).abs() < 1e-8);
        }
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-12);
        assert!((norm_cdf(1.959964) - 0.975).abs() < 1e-5);
    }

    #[test]
    fn ppf_inverts_cdf() {
        for &p in &[0.001, 0.01, 0.1, 0.3, 0.5, 0.7, 0.9, 0.99, 0.999] {
            let x = norm_ppf(p);
            assert!((norm_cdf(x) - p).abs() < 1e-6, "p={p} x={x}");
        }
    }

    #[test]
    #[should_panic]
    fn ppf_rejects_out_of_domain() {
        norm_ppf(0.0);
    }
}
