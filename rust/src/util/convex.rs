//! 1-D optimisation / root-finding used by the bid and eta solvers.
//!
//! The paper's optimisation problems reduce to one-dimensional searches:
//! Theorem 4 needs the root of the monotone H(J~) = eps, and the dynamic
//! worker problem (20)-(23) is convex in eta for fixed J, so golden-section
//! over the feasible interval is exact up to tolerance.

/// Golden-section minimisation of a unimodal `f` on [lo, hi].
/// Returns (argmin, min). ~1.44 log2((hi-lo)/tol) evaluations.
pub fn golden_section_min<F: FnMut(f64) -> f64>(
    mut f: F,
    lo: f64,
    hi: f64,
    tol: f64,
) -> (f64, f64) {
    assert!(lo <= hi, "golden_section_min: lo={lo} > hi={hi}");
    const INVPHI: f64 = 0.618_033_988_749_894_8; // 1/phi
    const INVPHI2: f64 = 0.381_966_011_250_105_2; // 1/phi^2
    let (mut a, mut b) = (lo, hi);
    let mut h = b - a;
    if h <= tol {
        let m = (a + b) / 2.0;
        return (m, f(m));
    }
    let mut c = a + INVPHI2 * h;
    let mut d = a + INVPHI * h;
    let mut yc = f(c);
    let mut yd = f(d);
    while h > tol {
        if yc < yd {
            b = d;
            d = c;
            yd = yc;
            h = b - a;
            c = a + INVPHI2 * h;
            yc = f(c);
        } else {
            a = c;
            c = d;
            yc = yd;
            h = b - a;
            d = a + INVPHI * h;
            yd = f(d);
        }
    }
    if yc < yd { (c, yc) } else { (d, yd) }
}

/// Bisection root of a monotone `f` with f(lo), f(hi) of opposite signs.
/// Returns None if no sign change on the bracket.
pub fn bisect_root<F: FnMut(f64) -> f64>(
    mut f: F,
    mut lo: f64,
    mut hi: f64,
    tol: f64,
) -> Option<f64> {
    let mut flo = f(lo);
    let fhi = f(hi);
    if flo == 0.0 {
        return Some(lo);
    }
    if fhi == 0.0 {
        return Some(hi);
    }
    if flo.signum() == fhi.signum() {
        return None;
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        let fm = f(mid);
        if fm == 0.0 || hi - lo < tol {
            return Some(mid);
        }
        if fm.signum() == flo.signum() {
            lo = mid;
            flo = fm;
        } else {
            hi = mid;
        }
    }
    Some(0.5 * (lo + hi))
}

/// Minimise a unimodal integer function on [lo, hi] by ternary search,
/// falling back to scan when the interval is small. Returns (argmin, min).
pub fn ternary_min_int<F: FnMut(i64) -> f64>(
    mut f: F,
    mut lo: i64,
    mut hi: i64,
) -> (i64, f64) {
    assert!(lo <= hi);
    while hi - lo > 8 {
        let m1 = lo + (hi - lo) / 3;
        let m2 = hi - (hi - lo) / 3;
        if f(m1) <= f(m2) {
            hi = m2 - 1;
        } else {
            lo = m1 + 1;
        }
    }
    let mut best = (lo, f(lo));
    for x in (lo + 1)..=hi {
        let y = f(x);
        if y < best.1 {
            best = (x, y);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_finds_parabola_min() {
        let (x, y) = golden_section_min(|x| (x - 1.7) * (x - 1.7) + 3.0, -10.0, 10.0, 1e-9);
        assert!((x - 1.7).abs() < 1e-6);
        assert!((y - 3.0).abs() < 1e-10);
    }

    #[test]
    fn golden_handles_boundary_min() {
        let (x, _) = golden_section_min(|x| x, 2.0, 5.0, 1e-9);
        assert!((x - 2.0).abs() < 1e-6);
    }

    #[test]
    fn bisect_finds_root() {
        let r = bisect_root(|x| x * x - 2.0, 0.0, 2.0, 1e-12).unwrap();
        assert!((r - std::f64::consts::SQRT_2).abs() < 1e-9);
    }

    #[test]
    fn bisect_rejects_no_sign_change() {
        assert!(bisect_root(|x| x * x + 1.0, -1.0, 1.0, 1e-9).is_none());
    }

    #[test]
    fn ternary_int_min() {
        let (x, y) = ternary_min_int(|x| ((x - 37) * (x - 37)) as f64, 0, 1000);
        assert_eq!(x, 37);
        assert_eq!(y, 0.0);
    }

    #[test]
    fn ternary_int_min_small_range() {
        let (x, _) = ternary_min_int(|x| (x as f64 - 2.2).abs(), 0, 4);
        assert_eq!(x, 2);
    }
}
