//! Zero-dependency numeric and testing substrate.
//!
//! Everything here exists because the build is fully offline: the only
//! crates available are `xla` and `anyhow`, so the RNG, statistics,
//! special functions, 1-D optimizers, CSV writer and property-test runner
//! are implemented from scratch (and unit-tested against closed forms).

pub mod convex;
pub mod csv;
pub mod erf;
pub mod fnv;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;

/// Round `x` up to the next multiple of `m`.
pub fn round_up(x: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    x.div_ceil(m) * m
}

/// `H_n = sum_{k=1}^{n} 1/k` (exact for small n, Euler–Mascheroni
/// expansion beyond 1e6 — error < 1e-12 there).
pub fn harmonic(n: u64) -> f64 {
    if n == 0 {
        return 0.0;
    }
    if n <= 1_000_000 {
        (1..=n).map(|k| 1.0 / k as f64).sum()
    } else {
        const EULER_GAMMA: f64 = 0.577_215_664_901_532_9;
        let x = n as f64;
        x.ln() + EULER_GAMMA + 1.0 / (2.0 * x) - 1.0 / (12.0 * x * x)
    }
}

/// ln C(n, k), numerically stable via ln-gamma.
pub fn ln_binomial(n: u64, k: u64) -> f64 {
    debug_assert!(k <= n);
    ln_factorial(n) - ln_factorial(k) - ln_factorial(n - k)
}

/// ln(n!) via Stirling for large n, exact accumulation otherwise.
pub fn ln_factorial(n: u64) -> f64 {
    if n < 256 {
        (2..=n).map(|k| (k as f64).ln()).sum()
    } else {
        // Stirling series: ln n! = n ln n - n + 0.5 ln(2 pi n) + 1/(12n) ...
        let x = n as f64;
        x * x.ln() - x + 0.5 * (2.0 * std::f64::consts::PI * x).ln()
            + 1.0 / (12.0 * x)
            - 1.0 / (360.0 * x * x * x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn harmonic_small_values() {
        assert_eq!(harmonic(0), 0.0);
        assert_eq!(harmonic(1), 1.0);
        assert!((harmonic(4) - (1.0 + 0.5 + 1.0 / 3.0 + 0.25)).abs() < 1e-12);
    }

    #[test]
    fn harmonic_asymptotic_consistency() {
        // exact sum and expansion agree at the 1e6 switch-over point
        let exact: f64 = (1..=1_000_000u64).map(|k| 1.0 / k as f64).sum();
        let x = 1_000_001f64;
        let approx = x.ln() + 0.577_215_664_901_532_9 + 1.0 / (2.0 * x);
        assert!((exact + 1.0 / x - approx).abs() < 1e-9);
    }

    #[test]
    fn ln_binomial_exact_small() {
        // C(10, 3) = 120
        assert!((ln_binomial(10, 3) - 120f64.ln()).abs() < 1e-10);
        assert!((ln_binomial(5, 0)).abs() < 1e-12);
        assert!((ln_binomial(5, 5)).abs() < 1e-12);
    }

    #[test]
    fn ln_factorial_stirling_matches_exact() {
        // check continuity at the 256 switch-over
        let exact: f64 = (2..=300u64).map(|k| (k as f64).ln()).sum();
        assert!((ln_factorial(300) - exact).abs() < 1e-8);
    }
}
