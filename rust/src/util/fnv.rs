//! FNV-1a, 64-bit — the repo's one digest primitive.
//!
//! Both the sweep harness ([`crate::sweep::SweepResults::digest`]) and
//! the planner ([`crate::opt`]) hash their collated outputs with this
//! exact algorithm so the CI determinism smokes can diff a single
//! `digest:` line. Floats are hashed by bit pattern: two results agree
//! on the digest iff they agree bit for bit.

/// Streaming FNV-1a hasher over bytes, integers and float bit patterns.
#[derive(Clone, Debug)]
pub struct Fnv(u64);

impl Default for Fnv {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv {
    pub fn new() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    pub fn bytes(&mut self, bs: &[u8]) {
        for &b in bs {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }

    pub fn u64(&mut self, x: u64) {
        self.bytes(&x.to_le_bytes());
    }

    /// Hash the exact bit pattern (NaN payloads included).
    pub fn f64(&mut self, x: f64) {
        self.bytes(&x.to_bits().to_le_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vector() {
        // FNV-1a("a") = 0xaf63dc4c8601ec8c
        let mut h = Fnv::new();
        h.bytes(b"a");
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn order_sensitive_and_bit_exact() {
        let mut a = Fnv::new();
        a.u64(1);
        a.f64(2.0);
        let mut b = Fnv::new();
        b.f64(2.0);
        b.u64(1);
        assert_ne!(a.finish(), b.finish());
        let mut c = Fnv::new();
        c.u64(1);
        c.f64(2.0);
        assert_eq!(a.finish(), c.finish());
        // -0.0 and 0.0 differ in bits, so they differ in digest
        let mut p = Fnv::new();
        p.f64(0.0);
        let mut m = Fnv::new();
        m.f64(-0.0);
        assert_ne!(p.finish(), m.finish());
    }
}
